"""Overlap scheduling: bucket the gradient tree, reduce while backward runs.

Backward produces gradients in reverse layer order, so the LAST layers'
gradients are ready while the FIRST layers are still differentiating.
A blocking reduce wastes that window; the overlap scheduler instead

  1. buckets the flattened gradient tree in reverse layer order into
     ~``bucket_bytes`` chunks (:func:`plan_buckets` — an oversize leaf
     becomes its own bucket rather than being split, because per-leaf
     compression keys are derived from the leaf NAME and splitting a leaf
     would change its dither);
  2. launches each bucket's compressed reduce as soon as its layers'
     gradients exist, while earlier layers still compute backward.

Bit-exactness is by construction, not by luck: every reducer in
``repro.comm.reducer`` derives per-leaf keys as
``fold_in(fold_in(key, step), name_salt(name))`` — a function of the leaf
name only, never of which bucket (or whether any bucket) the leaf rides
in. tests/test_overlap.py pins bucketed == blocking to zero ULP.

Inside a jitted step the "launch" is dataflow, not wall-clock — XLA is
free to interleave the bucket reduces with the remaining backward ops
because each bucket depends only on its own leaves. The honest wall-clock
story lives in ``repro.launch.costmodel.price_overlap`` (modeled) and the
per-bucket host timings of ``benchmarks/distributed_nodes.py`` (measured);
their agreement is a gated metric.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.comm.reducer import Reducer, ReducerTelemetry
from repro.utils.pytree import flatten_with_names

__all__ = ["BucketPlan", "OverlapReducer", "plan_buckets"]


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Static bucketing of a gradient tree: names + per-bucket byte totals.

    ``buckets[0]`` holds the leaves whose gradients backward finishes
    FIRST (the reverse of flatten order), so index order is launch order.
    """

    buckets: Tuple[Tuple[str, ...], ...]
    bucket_bytes: Tuple[int, ...]

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def total_bytes(self) -> int:
        return sum(self.bucket_bytes)


def plan_buckets(named_bytes: Sequence[Tuple[str, int]],
                 bucket_bytes: int, reverse: bool = True) -> BucketPlan:
    """Greedy fill in (reverse) flatten order into ~bucket_bytes buckets.

    A leaf larger than ``bucket_bytes`` gets a bucket of its own (leaves
    are never split — the compression key is per leaf name). A bucket
    closes when adding the next leaf would push it past the target, so
    every bucket except possibly the last is <= bucket_bytes unless a
    single leaf exceeds it.
    """
    if bucket_bytes <= 0:
        raise ValueError(f"bucket_bytes must be > 0, got {bucket_bytes}")
    order = list(reversed(named_bytes)) if reverse else list(named_bytes)
    buckets: List[Tuple[str, ...]] = []
    totals: List[int] = []
    cur: List[str] = []
    cur_bytes = 0
    for name, nbytes in order:
        if cur and cur_bytes + nbytes > bucket_bytes:
            buckets.append(tuple(cur))
            totals.append(cur_bytes)
            cur, cur_bytes = [], 0
        cur.append(name)
        cur_bytes += int(nbytes)
    if cur:
        buckets.append(tuple(cur))
        totals.append(cur_bytes)
    return BucketPlan(buckets=tuple(buckets), bucket_bytes=tuple(totals))


class OverlapReducer(Reducer):
    """Wrap any Reducer with reverse-layer-order bucket scheduling.

    ``reduce`` returns the same tree, bit-exact, as the wrapped reducer's
    single blocking call; telemetry totals sum over buckets with
    ``n_buckets`` recording the schedule. With ``collect_stats`` the
    wrapped reducer emits one comm-telemetry row PER BUCKET (launch/drain
    granularity on the metrics bus) instead of one per step.
    """

    def __init__(self, base: Reducer, bucket_bytes: int):
        self.base = base
        self.bucket_target = int(bucket_bytes)
        self.policy = base.policy
        self.n_nodes = base.n_nodes
        self.mesh = base.mesh
        self.pod_axis = base.pod_axis
        self.node_axis = base.node_axis
        self.topology = base.topology

    @property
    def stacked(self) -> bool:
        return self.base.stacked

    def init_state(self, params_or_grads: Any) -> Dict[str, Any]:
        return self.base.init_state(params_or_grads)

    def plan_for(self, grads: Any) -> BucketPlan:
        """The static schedule this tree reduces under (per-NODE bytes)."""
        div = self.n_nodes if self.stacked else 1
        named = [(name, leaf.size * np.dtype(leaf.dtype).itemsize
                  // max(div, 1))
                 for name, leaf in flatten_with_names(grads)]
        return plan_buckets(named, self.bucket_target)

    def reduce(self, grads: Any, key: jax.Array, step,
               state: Optional[Dict[str, Any]] = None
               ) -> Tuple[Any, ReducerTelemetry, Dict[str, Any]]:
        flat = flatten_with_names(grads)
        by_name = dict(flat)
        plan = self.plan_for(grads)
        state = dict(state or {})
        out: Dict[str, jax.Array] = {}
        tele: Optional[ReducerTelemetry] = None
        for names in plan.buckets:
            sub = {n: by_name[n] for n in names}
            sub_out, t, state = self.base.reduce(sub, key, step, state)
            out.update(sub_out)
            tele = t if tele is None else tele.accumulate(t)
        leaves = [out[name] for name, _ in flat]
        grads_mean = jax.tree.unflatten(jax.tree.structure(grads), leaves)
        return grads_mean, tele, state
