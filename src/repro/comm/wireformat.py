"""DEPRECATED shim: the packed NSD wire format moved to :mod:`repro.quant.wire`.

The PackedNSD container, bitmap helpers and pack/unpack references are
unchanged (numerics pinned bit-for-bit by tests/test_quant.py and the
``layer_sparsity`` / ``serve_bench`` zero-band gates); the move makes the
wire layout a backend of the registered ``nsd`` codec, which also grew a
Pallas chunk-local compact/expand path (``backend="pallas"``). Importing
this module warns once per process; update imports::

    from repro.comm import wireformat      # old
    from repro.quant import wire           # new (same functions)
"""
from __future__ import annotations

import warnings

from repro.quant.wire import (  # noqa: F401
    DEFAULT_CHUNK, HEADER_BYTES, PackedNSD, _compact, _expand, _pad2d,
    pack_bitmap, pack_indices, pack_nsd, popcount_u8, tile_mask_from_bitmap,
    tile_mask_from_packed, tile_nnz_from_bitmap, unpack_bitmap, unpack_nsd,
    wire_bytes_dense)

warnings.warn(
    "repro.comm.wireformat is deprecated; import repro.quant.wire instead "
    "(same layout and functions, now a backend of the 'nsd' codec)",
    DeprecationWarning, stacklevel=2)
