"""Butterfly (recursive-halving) inter-pod stage for the two-level reduce.

The binomial tree in ``repro.comm.hierarchy`` funnels every segment through
pod 0: the root's DCN line carries ceil(log2 G) full-segment packs up AND
the broadcast pack down, so its occupancy grows with log G while every
other line stays near 2 packs. This module replaces phases 2-3 with the
classic HPC recursive-halving/recursive-doubling exchange, keeping the
intra-pod ICI ring (phases 1 and 4) byte-identical:

  phase 2a  recursive-halving reduce-scatter over the pod axis: m =
            floor(log2 G) rounds; in round r pod g pairs with g XOR
            2^(m-1-r), keeps the half of its live range selected by bit
            (m-1-r) of g and sends the other half as a fresh NSD pack.
            After m rounds pod g owns piece [g*L/G2, (g+1)*L/G2) of the
            segment, fully reduced over pods. Non-power-of-two pod counts
            fold pods g >= G2 = 2^m into g - G2 with one extra pack before
            the rounds and receive the finished pack set after them.
  phase 2b  each pod packs its owned piece ONCE; recursive doubling
            forwards the piece packs VERBATIM (no repack), so after m
            rounds every pod holds the identical G2 packs.
  phase 4   the pack set rides around each pod's ICI ring verbatim; every
            node unpacks the SAME packs, so all N results are bit-exact
            equal by construction (the differential tests pin this).

Pack/occupancy accounting vs the tree, per segment:

    sequential packs   (P-1) + ceil(log2 G) + 1    — SAME as the tree
    (an element is re-quantized once per halving round it is sent in, or
    kept and re-quantized at the piece pack; either way depth m+1 inter-
    pod for 2^m pods, and the pre-fold pack supplies the +1 that makes
    ceil(log2 G) for ragged G)

    peak DCN line      every pod sends ~2B(1 - 1/G2) and receives the
    same, vs the tree root's ~2*log2(G)*B each way — the halving the
    ROADMAP asks for at G >= 8, strictly <= the tree from G >= 2.
    ``peak_dcn_bytes`` reports the MEASURED busiest line (sent+received).

Two implementations with identical per-hop math and identical keys (the
sim-vs-shard_map differential in tests/test_butterfly.py is bit-exact):

  * ``butterfly_allreduce_nsd`` — single-process simulation.
  * ``make_butterfly_allreduce`` — shard_map over a (pods, nodes) mesh;
    halving/doubling rounds are ``jax.lax.ppermute`` pairwise exchanges
    of PackedNSD pytrees over the pod axis.

With pods == 1 both collapse to the hierarchy's G == 1 path bit-exactly
(same phase-1 packs, same final-pack key), which pins the degenerate
butterfly == tree differential with zero tolerance.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.quant import wire as wf
from repro.comm.hierarchy import (_INTRA_SALT, _TREE_DOWN_SALT, _hier_shape,
                                  _mesh_axes, tree_rounds)
from repro.comm.reduce_base import PackCounter, hop_key, seg_len, segment
from repro.parallel.axes import shard_map_compat

_FOLD_SALT = 0xF01D  # non-power-of-two pre-fold packs
_HALVE_SALT = 0xBF1F  # recursive-halving reduce-scatter packs

__all__ = ["ButterflyConfig", "ButterflyTelemetry", "allreduce_butterfly",
           "butterfly_allreduce_nsd", "butterfly_rounds", "dense_reduce_bytes",
           "make_butterfly_allreduce"]


def butterfly_rounds(pods: int) -> int:
    """floor(log2(pods)): halving/doubling rounds over the pod axis."""
    return pods.bit_length() - 1 if pods > 1 else 0


@dataclasses.dataclass(frozen=True)
class ButterflyConfig:
    """Butterfly two-level reduce: N nodes = pods x (N // pods)."""

    pods: int = 2
    s: float = 1.0  # NSD scale for on-wire quantization
    chunk: int = wf.DEFAULT_CHUNK

    def __post_init__(self):
        if self.pods < 1:
            raise ValueError(f"pods must be >= 1, got {self.pods}")


class ButterflyTelemetry(NamedTuple):
    """HierTelemetry's fields; ``peak_dcn_bytes`` is the design target."""

    wire_bytes: jax.Array
    dense_bytes: jax.Array
    error_bound: jax.Array
    n_hops: int
    packs_per_segment: int
    wire_ici_bytes: jax.Array
    wire_dcn_bytes: jax.Array
    pods: int = 1
    per_pod: int = 1
    peak_dcn_bytes: Union[jax.Array, float] = 0.0

    @property
    def ratio(self) -> jax.Array:
        return self.wire_bytes / jnp.maximum(self.dense_bytes, 1.0)


def _zero_telemetry() -> ButterflyTelemetry:
    zero = jnp.float32(0.0)
    return ButterflyTelemetry(zero, zero, zero, 0, 0, zero, zero, 1, 1, zero)


def _piece_len(seg: int, pods: int) -> Tuple[int, int, int]:
    """(m, G2, piece): rounds, power-of-two core, per-pod piece length."""
    m = butterfly_rounds(pods)
    g2 = 1 << m
    return m, g2, -(-seg // g2)


def _hop_counts(g: int, p: int) -> Tuple[int, int]:
    """(ici pack-transfers, dcn pack-transfers) of the whole exchange."""
    m, g2, _ = _piece_len(1, g)
    ici = 2 * g * p * (p - 1)  # phase 1 + phase-4 pack-set forwarding
    # halving sends + doubling sends (one transfer may carry 2^j packs;
    # counted as transfers) + pre/post folds, per segment owner line
    dcn = p * (2 * m * g2 + 2 * (g - g2))
    return ici, dcn


def dense_reduce_bytes(size: int, pods: int, per_pod: int,
                       chunk: int = wf.DEFAULT_CHUNK) -> int:
    """Bytes the same butterfly exchange would move at dense f32.

    ICI matches the hierarchy (same ring phases). DCN: each line moves
    2 * (G - 1) * seg2 elements total (halving + doubling sum to
    seg2*(G2-1) each; folds add 2*seg2 per extra pod), vs the tree's
    2 * (G - 1) * seg — equal up to piece padding.
    """
    seg = seg_len(size, per_pod, chunk)
    _, g2, piece = _piece_len(seg, pods)
    ici = 2 * pods * per_pod * (per_pod - 1) * seg
    dcn = 2 * (pods - 1) * per_pod * piece * g2
    return (ici + dcn) * 4


def butterfly_allreduce_nsd(grads: Union[jax.Array, Sequence[jax.Array]],
                            key: jax.Array,
                            cfg: ButterflyConfig = ButterflyConfig()
                            ) -> Tuple[jax.Array, ButterflyTelemetry]:
    """Simulated butterfly two-level all-reduce of N stacked gradients.

    grads: (N, *shape) stacked array or list of N same-shape arrays, pod-
    major (node i lives in pod i // per_pod). Returns (mean over nodes,
    telemetry). N == 1 short-circuits (no wire).
    """
    if not isinstance(grads, jax.Array):
        grads = jnp.stack(list(grads))
    n = grads.shape[0]
    shape, dtype = grads.shape[1:], grads.dtype
    if n == 1:
        return grads[0], _zero_telemetry()
    G, Pn = _hier_shape(n, cfg.pods)
    m, G2, _ = _piece_len(1, G)

    flat = grads.astype(jnp.float32).reshape(n, -1)
    acc = [[segment(flat[g * Pn + p], Pn, cfg.chunk)[0] for p in range(Pn)]
           for g in range(G)]
    ctr = PackCounter(Pn)
    traffic = [jnp.float32(0.0) for _ in range(G)]

    def charge(pk, src, dst):
        b = pk.wire_bytes().astype(jnp.float32)
        traffic[src] = traffic[src] + b
        traffic[dst] = traffic[dst] + b

    # --- phase 1: intra-pod ring reduce-scatter (identical to hierarchy:
    # same per-hop math, same keys, so phase-1 packs match bit-exactly) ---
    for step in range(Pn - 1):
        packed = []
        for g in range(G):
            for p in range(Pn):
                c = (p - step) % Pn
                pk = wf.pack_nsd(acc[g][p][c],
                                 hop_key(key, _INTRA_SALT, step, g, p),
                                 cfg.s, cfg.chunk)
                ctr.count(pk, seg=c, link="ici")
                packed.append((g, p, c, pk))
        for g, p, c, pk in packed:
            dst = (p + 1) % Pn
            acc[g][dst] = acc[g][dst].at[c].set(
                acc[g][dst][c] + wf.unpack_nsd(pk))

    part = [[acc[g][(c - 1) % Pn][c] for c in range(Pn)] for g in range(G)]
    seg = int(part[0][0].shape[0])
    _, _, piece = _piece_len(seg, G)
    seg2 = piece * G2
    if seg2 > seg:
        part = [[jnp.pad(v, (0, seg2 - seg)) for v in row] for row in part]

    # --- phase 2a pre-fold: ragged pods g >= G2 send their whole partial
    # into the power-of-two core with one pack ---
    for g in range(G2, G):
        dst = g - G2
        for c in range(Pn):
            pk = wf.pack_nsd(part[g][c], hop_key(key, _FOLD_SALT, 0, g, c),
                             cfg.s, cfg.chunk)
            ctr.count(pk, seg=c, link="dcn")
            charge(pk, g, dst)
            part[dst][c] = part[dst][c] + wf.unpack_nsd(pk)

    # --- phase 2a: recursive-halving reduce-scatter over the pod axis ---
    live = [[part[g][c] for c in range(Pn)] for g in range(G2)]
    for r in range(m):
        bit = m - 1 - r
        half = piece << bit  # live width after this round
        sends = []
        for g in range(G2):
            keep = (g >> bit) & 1
            dst = g ^ (1 << bit)
            for c in range(Pn):
                block = live[g][c][(1 - keep) * half:(2 - keep) * half]
                pk = wf.pack_nsd(block, hop_key(key, _HALVE_SALT, r, g, c),
                                 cfg.s, cfg.chunk)
                ctr.count(pk, seg=c, link="dcn")
                charge(pk, g, dst)
                sends.append((dst, c, keep, pk))
        nxt = [[None] * Pn for _ in range(G2)]
        for dst, c, keep, pk in sends:
            # the receiver keeps the half the sender sent (they differ in
            # exactly this round's bit, so their live ranges coincide)
            dkeep = 1 - keep
            kept = live[dst][c][dkeep * half:(dkeep + 1) * half]
            nxt[dst][c] = kept + wf.unpack_nsd(pk)
        live = nxt

    # --- phase 2b: pack the owned piece once; recursive doubling forwards
    # the piece packs verbatim until every pod holds the identical set ---
    finals = [[wf.pack_nsd(live[g][c],
                           hop_key(key, _TREE_DOWN_SALT, 0, g, c),
                           cfg.s, cfg.chunk)
               for c in range(Pn)] for g in range(G2)]
    for g in range(G2):
        for c in range(Pn):
            ctr.count(finals[g][c], seg=c, link="dcn", hops=0)
    have = [[{g: finals[g][c]} for c in range(Pn)] for g in range(G2)]
    for j in range(m):
        stride = 1 << j
        snap = [[dict(have[g][c]) for c in range(Pn)] for g in range(G2)]
        for g in range(G2):
            dst = g ^ stride
            for c in range(Pn):
                for idx, pk in snap[g][c].items():
                    ctr.count(pk, link="dcn")
                    charge(pk, g, dst)
                    have[dst][c][idx] = pk

    # --- phase 2b post-fold: ragged pods receive the finished pack set ---
    for g in range(G2, G):
        src = g - G2
        for c in range(Pn):
            for pk in have[src][c].values():
                ctr.count(pk, link="dcn")
                charge(pk, src, g)

    # --- phase 4: the pack set rides around each pod's ICI ring verbatim;
    # every node unpacks the SAME G2 packs -> bit-exact consensus ---
    vals = []
    for c in range(Pn):
        for pk in have[0][c].values():
            ctr.count(pk, link="ici", hops=G * (Pn - 1))
        pieces = [wf.unpack_nsd(have[0][c][i]) for i in range(G2)]
        vals.append(jnp.concatenate(pieces)[:seg])

    total = jnp.concatenate(vals)
    size = 1
    for d in shape:
        size *= int(d)
    mean = (total[:size] / n).reshape(shape).astype(dtype)

    ici_hops, dcn_hops = _hop_counts(G, Pn)
    dense = jnp.float32(dense_reduce_bytes(flat.shape[1], G, Pn, cfg.chunk))
    return mean, ButterflyTelemetry(
        wire_bytes=ctr.wire_total, dense_bytes=dense,
        error_bound=jnp.max(ctr.bound) / n, n_hops=ici_hops + dcn_hops,
        packs_per_segment=(Pn - 1) + tree_rounds(G) + 1,
        wire_ici_bytes=ctr.wire["ici"], wire_dcn_bytes=ctr.wire["dcn"],
        pods=G, per_pod=Pn,
        peak_dcn_bytes=(jnp.max(jnp.stack(traffic)) if G > 1
                        else jnp.float32(0.0)))


def _mask_sel(mask: jax.Array, incoming, mine):
    """Per-entry select over the leading (G2) axis of a stacked pack."""
    def sel(a, b):
        mk = mask.reshape((mask.shape[0],) + (1,) * (a.ndim - 1))
        return jnp.where(mk, b, a)
    return jax.tree.map(sel, mine, incoming)


def make_butterfly_allreduce(mesh: Mesh,
                             cfg: ButterflyConfig = ButterflyConfig(),
                             pod_axis: str = "pods",
                             node_axis: str = "nodes"):
    """Build the shard_map butterfly reduce over a 2-D (pods, nodes) mesh.

    Returns ``fn(stacked, key) -> (means, wire_ici, wire_dcn, bounds,
    peak_dcn)`` with ``stacked`` (N, *shape) pod-major over the flattened
    mesh. Per-hop math and keys match ``butterfly_allreduce_nsd``
    bit-exactly; every halving/doubling round is a pairwise
    ``jax.lax.ppermute`` over the pod axis.
    """
    G, Pn = _mesh_axes(mesh, pod_axis, node_axis)
    if cfg.pods != G:
        raise ValueError(f"cfg.pods ({cfg.pods}) != mesh {pod_axis!r} axis "
                         f"size ({G})")
    m, G2, _ = _piece_len(1, G)
    fwd_nodes = [(i, (i + 1) % Pn) for i in range(Pn)]

    def bfly(stacked_local: jax.Array, key: jax.Array):
        local = stacked_local[0]  # (1, *shape) local slice of the stack
        g = jax.lax.axis_index(pod_axis)
        me = jax.lax.axis_index(node_axis)
        shape, dtype = local.shape, local.dtype
        acc, seg = segment(local.astype(jnp.float32).reshape(-1),
                           Pn, cfg.chunk)
        _, _, piece = _piece_len(seg, G)
        seg2 = piece * G2
        ctr = PackCounter(Pn)
        perm_n = partial(jax.lax.ppermute, axis_name=node_axis,
                         perm=fwd_nodes)
        in_core = (g < G2).astype(jnp.float32)
        # this device's share of its pod's DCN line traffic (sent+received)
        dcn_traffic = jnp.float32(0.0)

        # --- phase 1: intra-pod ring reduce-scatter (hierarchy-identical) ---
        for step in range(Pn - 1):
            c_send = (me - step) % Pn
            pk = wf.pack_nsd(jnp.take(acc, c_send, axis=0),
                             hop_key(key, _INTRA_SALT, step, g, me),
                             cfg.s, cfg.chunk)
            ctr.count(pk, seg=c_send, link="ici")
            pk_in = perm_n(pk)
            c_recv = (me - 1 - step) % Pn
            acc = acc.at[c_recv].set(
                jnp.take(acc, c_recv, axis=0) + wf.unpack_nsd(pk_in))

        c_own = (me + 1) % Pn
        live = jnp.pad(jnp.take(acc, c_own, axis=0), (0, seg2 - seg))

        # --- phase 2a pre-fold (SPMD: every device packs; only ragged
        # pods' packs count and cross the wire) ---
        if G2 < G:
            is_extra = (g >= G2).astype(jnp.float32)
            is_rcvr = (g < G - G2).astype(jnp.float32)
            pk = wf.pack_nsd(live, hop_key(key, _FOLD_SALT, 0, g, c_own),
                             cfg.s, cfg.chunk)
            ctr.count(pk, seg=c_own, link="dcn", weight=is_extra)
            perm = [(src, src - G2) for src in range(G2, G)]
            pk_in = jax.lax.ppermute(pk, axis_name=pod_axis, perm=perm)
            dcn_traffic += (pk.wire_bytes().astype(jnp.float32) * is_extra
                            + pk_in.wire_bytes().astype(jnp.float32)
                            * is_rcvr)
            # non-receivers get an all-zero pack from ppermute -> add 0
            live = live + wf.unpack_nsd(pk_in)

        # --- phase 2a: recursive halving over the pod axis ---
        for r in range(m):
            bit = m - 1 - r
            half = piece << bit
            keep = (g >> bit) & 1
            block = jax.lax.dynamic_slice(live, ((1 - keep) * half,),
                                          (half,))
            pk = wf.pack_nsd(block, hop_key(key, _HALVE_SALT, r, g, c_own),
                             cfg.s, cfg.chunk)
            ctr.count(pk, seg=c_own, link="dcn", weight=in_core)
            perm = [(a, a ^ (1 << bit)) for a in range(G2)]
            pk_in = jax.lax.ppermute(pk, axis_name=pod_axis, perm=perm)
            dcn_traffic += (pk.wire_bytes() + pk_in.wire_bytes()
                            ).astype(jnp.float32) * in_core
            kept = jax.lax.dynamic_slice(live, (keep * half,), (half,))
            live = kept + wf.unpack_nsd(pk_in)

        # --- phase 2b: pack the owned piece once; recursive doubling of
        # the stacked (G2, ...) pack set, entries selected by round mask ---
        pk_mine = wf.pack_nsd(live, hop_key(key, _TREE_DOWN_SALT, 0, g,
                                            c_own), cfg.s, cfg.chunk)
        ctr.count(pk_mine, seg=c_own, link="dcn", hops=0, weight=in_core)
        slot = jnp.clip(g, 0, G2 - 1)
        packs = jax.tree.map(
            lambda leaf: jnp.zeros((G2,) + leaf.shape, leaf.dtype
                                   ).at[slot].set(leaf), pk_mine)
        fixed = jnp.float32(wf.HEADER_BYTES
                            + pk_mine.n_chunks * (4 + cfg.chunk // 8))
        ar = jnp.arange(G2)

        def set_bytes(nnz_vec, members):
            """Measured bytes of the pack-set entries ``members`` selects."""
            per = fixed + nnz_vec.astype(jnp.float32)
            return jnp.sum(jnp.where(members, per, 0.0))

        for j in range(m):
            stride = 1 << j
            partner = g ^ stride
            perm = [(a, a ^ stride) for a in range(G2)]
            mine_mask = (ar >> j) == (g >> j)
            in_mask = (ar >> j) == (partner >> j)
            packs_in = jax.lax.ppermute(packs, axis_name=pod_axis, perm=perm)
            b_out = set_bytes(packs.nnz, mine_mask) * in_core
            b_in = set_bytes(packs_in.nnz, in_mask) * in_core
            ctr.count_bytes(b_out, link="dcn")
            dcn_traffic += b_out + b_in
            packs = _mask_sel(in_mask, packs_in, packs)

        # --- phase 2b post-fold: forward the finished set to ragged pods ---
        if G2 < G:
            is_extra = g >= G2
            is_sender = (g < G - G2).astype(jnp.float32)
            perm = [(a, a + G2) for a in range(G - G2)]
            packs_in = jax.lax.ppermute(packs, axis_name=pod_axis, perm=perm)
            every = jnp.ones((G2,), bool)
            b_out = set_bytes(packs.nnz, every) * is_sender
            b_in = (set_bytes(packs_in.nnz, every)
                    * is_extra.astype(jnp.float32))
            ctr.count_bytes(b_out, link="dcn")
            dcn_traffic += b_out + b_in
            packs = jax.tree.map(
                lambda a, b: jnp.where(
                    jnp.reshape(is_extra, (1,) * a.ndim), b, a),
                packs, packs_in)

        # --- phase 4: forward the pack set around the pod ring verbatim ---
        def set_values(pset):
            return jax.vmap(wf.unpack_nsd)(pset).reshape(-1)[:seg]

        out = jnp.zeros_like(acc).at[c_own].set(set_values(packs))
        cur = packs
        every = jnp.ones((G2,), bool)
        for h in range(1, Pn):
            cur = perm_n(cur)
            ctr.count_bytes(set_bytes(cur.nnz, every), link="ici")
            c = (me - h + 1) % Pn
            out = out.at[c].set(set_values(cur))

        # per-segment bound = sum over ALL packs that touched the segment
        bound = jax.lax.psum(ctr.bound, (pod_axis, node_axis))
        size = 1
        for d in shape:
            size *= int(d)
        n = G * Pn
        mean = (out.reshape(-1)[:size] / n).reshape(shape).astype(dtype)
        pod_line = jax.lax.psum(dcn_traffic, node_axis)
        peak = jax.lax.pmax(pod_line, pod_axis)
        return (mean[None], ctr.wire["ici"][None], ctr.wire["dcn"][None],
                (jnp.max(bound) / n)[None], peak[None])

    spec = P((pod_axis, node_axis))
    return jax.jit(shard_map_compat(
        bfly, mesh=mesh,
        in_specs=(spec, P()),
        out_specs=(spec, spec, spec, spec, spec)))


def allreduce_butterfly(grads, key, cfg: ButterflyConfig = ButterflyConfig(),
                        mesh: Mesh = None, pod_axis: str = "pods",
                        node_axis: str = "nodes"
                        ) -> Tuple[jax.Array, ButterflyTelemetry]:
    """Dispatch: shard_map butterfly when a 2-D multi-device mesh is given,
    else the single-process simulation (identical per-hop math)."""
    if not isinstance(grads, jax.Array):
        grads = jnp.stack(list(grads))
    n = grads.shape[0]
    if mesh is not None and n > 1:
        G, Pn = _mesh_axes(mesh, pod_axis, node_axis)
        if grads.shape[0] != G * Pn:
            raise ValueError(
                f"stacked node axis ({grads.shape[0]}) must equal the mesh "
                f"({pod_axis!r} x {node_axis!r}) size ({G}*{Pn}); a "
                "mismatched stack would silently drop gradients")
        fn = make_butterfly_allreduce(mesh, cfg, pod_axis, node_axis)
        means, w_ici, w_dcn, bounds, peak = fn(grads, key)
        flat_size = 1
        for d in grads.shape[1:]:
            flat_size *= int(d)
        ici_hops, dcn_hops = _hop_counts(G, Pn)
        wire_ici = jnp.sum(w_ici)
        wire_dcn = jnp.sum(w_dcn)
        tele = ButterflyTelemetry(
            wire_bytes=wire_ici + wire_dcn,
            dense_bytes=jnp.float32(
                dense_reduce_bytes(flat_size, G, Pn, cfg.chunk)),
            error_bound=bounds[0], n_hops=ici_hops + dcn_hops,
            packs_per_segment=(Pn - 1) + tree_rounds(G) + 1,
            wire_ici_bytes=wire_ici, wire_dcn_bytes=wire_dcn,
            pods=G, per_pod=Pn, peak_dcn_bytes=peak[0])
        return means[0], tele
    return butterfly_allreduce_nsd(grads, key, cfg)
