"""One front door for every compressed gradient reduce.

PRs 1-2 grew three parallel entry points — ``ring.allreduce_compressed``,
``hierarchy.make_hier_allreduce``/``allreduce_hier`` and the per-policy
``CommPolicy.reduce_cfg()`` config plumbing — and every consumer (ssgd,
Trainer, benchmarks) carried its own dispatch + telemetry glue across
them. This module collapses all of that into one protocol:

    red = comm.reducer(policy, mesh=None, n_nodes=N)
    grads_mean, telemetry, state = red.reduce(grads, key, step, state)

* ``grads`` is a gradient pytree; stacked reducers expect a leading
  (n_nodes, ...) axis per leaf, flat reducers (the Trainer's single-
  participant wire model) take the tree as-is.
* Key derivation is OWNED HERE and identical for every topology: leaf
  keys are ``fold_in(fold_in(key, step), name_salt(name))`` — exactly
  the scheme ssgd and the Trainer used before the redesign, so the
  migration is bit-exact (pinned by tests/test_reducer.py).
* ``telemetry`` is one typed :class:`ReducerTelemetry` regardless of
  topology; ``state`` carries error-feedback residuals (node-count
  independent, so elastic resizes migrate them losslessly — see
  ``repro.train.fault_tolerance``).
* ``policy.bucket_bytes > 0`` transparently wraps the reducer in the
  overlap scheduler (``repro.comm.overlap``): same keys per leaf, so
  bucketed and blocking reduces are bit-exact equal.

The old entry points remain as thin deprecation shims.

``parse_comm_program``/``format_comm_program`` give the reducer a launch-
DSL front door (the ``comm:`` section of the unified ``--program`` flag,
see ``repro.launch.program``).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.comm import butterfly as bfly_mod
from repro.comm import hierarchy as hier_mod
from repro.comm import ring as ring_mod
from repro.comm.butterfly import ButterflyConfig, butterfly_allreduce_nsd
from repro.comm.compression import (MODE_DENSE, MODE_TOPK_EF, TOPO_BUTTERFLY,
                                    TOPO_HIER, TOPO_PS, TOPO_RING, TOPOLOGIES,
                                    CommPolicy, ErrorFeedbackState,
                                    compress_leaf, compress_tree,
                                    init_comm_state, topk_error_feedback)
from repro.comm.hierarchy import HierConfig, hier_allreduce_nsd
from repro.comm.ring import RingConfig, ring_allreduce_nsd
from repro.quant import wire as wf
from repro.core.policy import name_salt
from repro.utils.pytree import tree_map_with_path_str

__all__ = ["Reducer", "ReducerTelemetry", "format_comm_program",
           "parse_comm_program", "reducer"]


class ReducerTelemetry(NamedTuple):
    """Typed per-reduce accounting, uniform across topologies.

    Traced f32 scalars unless noted. Fields a topology doesn't measure
    read 0 (``peak_dcn_bytes`` for ps/ring, ``error_bound`` for ps).
    ``n_buckets`` > 1 marks an overlap-scheduled reduce; totals then sum
    over buckets and ``error_bound``/``peak_dcn_bytes`` take the max.
    """

    wire_bytes: jax.Array
    dense_bytes: jax.Array
    error_bound: Union[jax.Array, float] = 0.0
    wire_ici_bytes: Union[jax.Array, float] = 0.0
    wire_dcn_bytes: Union[jax.Array, float] = 0.0
    peak_dcn_bytes: Union[jax.Array, float] = 0.0
    n_hops: int = 0  # static: total link traversals
    packs_per_segment: int = 0  # static: sequential re-quantizations
    pods: int = 1  # static
    per_pod: int = 1  # static
    n_buckets: int = 1  # static: 1 = blocking reduce

    @property
    def ratio(self) -> jax.Array:
        return self.wire_bytes / jnp.maximum(self.dense_bytes, 1.0)

    def accumulate(self, other: "ReducerTelemetry") -> "ReducerTelemetry":
        """Fold another reduce's telemetry in (bucketed/overlap reduces)."""
        return ReducerTelemetry(
            wire_bytes=self.wire_bytes + other.wire_bytes,
            dense_bytes=self.dense_bytes + other.dense_bytes,
            error_bound=jnp.maximum(self.error_bound, other.error_bound),
            wire_ici_bytes=self.wire_ici_bytes + other.wire_ici_bytes,
            wire_dcn_bytes=self.wire_dcn_bytes + other.wire_dcn_bytes,
            peak_dcn_bytes=jnp.maximum(self.peak_dcn_bytes,
                                       other.peak_dcn_bytes),
            n_hops=self.n_hops + other.n_hops,
            packs_per_segment=max(self.packs_per_segment,
                                  other.packs_per_segment),
            pods=max(self.pods, other.pods),
            per_pod=max(self.per_pod, other.per_pod),
            n_buckets=self.n_buckets + other.n_buckets)


def _zero_telemetry() -> ReducerTelemetry:
    zero = jnp.float32(0.0)
    return ReducerTelemetry(zero, zero, zero, zero, zero, zero,
                            0, 0, 1, 1, 1)


class Reducer:
    """Protocol: ``reduce(grads, key, step, state)`` for one topology.

    Subclasses implement ``_reduce``; this base owns state init and the
    collect_stats emission (one comm-telemetry row per reduce, same tag
    and totals the pre-redesign paths emitted).
    """

    topology: str = TOPO_PS

    def __init__(self, policy: CommPolicy, n_nodes: int = 1,
                 mesh=None, pod_axis: str = "pods",
                 node_axis: str = "nodes"):
        self.policy = policy
        self.n_nodes = int(n_nodes)
        self.mesh = mesh
        self.pod_axis = pod_axis
        self.node_axis = node_axis

    def init_state(self, params_or_grads: Any) -> Dict[str, Any]:
        """Zero EF residuals for leaves the policy routes through topk_ef.

        Residual shapes follow the LEAF (not the node axis), so the state
        survives elastic node-count changes bit-for-bit.
        """
        tree = params_or_grads
        if self.stacked:
            tree = jax.tree.map(lambda g: g[0], tree)
        return init_comm_state(tree, self.policy)

    @property
    def stacked(self) -> bool:
        """Whether ``reduce`` expects a leading (n_nodes, ...) leaf axis."""
        return False

    def reduce(self, grads: Any, key: jax.Array, step,
               state: Optional[Dict[str, Any]] = None
               ) -> Tuple[Any, ReducerTelemetry, Dict[str, Any]]:
        k_step = jax.random.fold_in(key, step)
        grads, tele, state = self._reduce(grads, k_step, dict(state or {}))
        if self.policy.collect_stats and not self._emits_stats:
            from repro.comm import telemetry as comm_tele
            comm_tele.emit(self.policy.stats_tag, tele.wire_bytes,
                           tele.dense_bytes)
        return grads, tele, state

    # subclasses that delegate to compress_tree (which emits its own comm
    # row) flip this so a reduce never double-counts
    _emits_stats = False

    def _reduce(self, grads, k_step, state):
        raise NotImplementedError


class _FlatPSReducer(Reducer):
    """Single-participant wire model: the Trainer path.

    Delegates to ``compress_tree`` with the step-folded key, so results,
    EF threading and stats emission are bit-identical to the pre-redesign
    ``Trainer._step``.
    """

    topology = TOPO_PS
    _emits_stats = True  # compress_tree emits under collect_stats

    def _reduce(self, grads, k_step, state):
        grads_hat, state, tele = compress_tree(grads, k_step, self.policy,
                                               state)
        return grads_hat, ReducerTelemetry(
            wire_bytes=tele["wire_bytes"], dense_bytes=tele["dense_bytes"],
            error_bound=jnp.float32(0.0), n_hops=1, packs_per_segment=1,
            per_pod=1), state


class _StackedPSReducer(Reducer):
    """Parameter-server shape over stacked (n_nodes, ...) gradients.

    Per-node compression with per-(leaf, worker) keys then the server
    average — bit-identical to the pre-redesign ``make_ssgd_step``
    compress path for dense/int8/nsd leaves. ``topk_ef`` leaves now get
    REAL error feedback (the redesign's upgrade over the old degrade-to-
    nsd): the residual lives server-side on the averaged gradient, so it
    is node-count independent and migrates bit-exact across elastic
    join/leave.
    """

    topology = TOPO_PS

    @property
    def stacked(self) -> bool:
        return True

    def _reduce(self, grads, k_step, state):
        n = self.n_nodes
        policy = self.policy
        totals = {"wire": jnp.float32(0.0), "dense": jnp.float32(0.0)}

        def leaf(name: str, g_nodes: jax.Array) -> jax.Array:
            size = int(g_nodes.size) // n
            mode = policy.mode_for(name, size)
            dense_bytes = jnp.float32(4 * size * n)
            totals["dense"] = totals["dense"] + dense_bytes
            if mode == MODE_DENSE:
                totals["wire"] = totals["wire"] + dense_bytes
                return jnp.mean(g_nodes, axis=0)
            k0 = jax.random.fold_in(k_step, name_salt(name))
            if mode == MODE_TOPK_EF:
                g_mean = jnp.mean(g_nodes, axis=0)
                sent, new_state = topk_error_feedback(
                    g_mean, state.get(name), policy.topk_frac)
                state[name] = new_state
                k = max(1, int(policy.topk_frac * size))
                # every node ships (int32 index, f32 value) per kept elem
                totals["wire"] = (totals["wire"]
                                  + jnp.float32(n * (8 * k + wf.HEADER_BYTES)))
                return sent

            def one(g, worker):
                kw = jax.random.fold_in(k0, worker)
                g_hat, wire, _ = compress_leaf(g, kw, mode, policy)
                return g_hat, wire.astype(jnp.float32)

            g_hat, wires = jax.vmap(one)(g_nodes, jnp.arange(n))
            totals["wire"] = totals["wire"] + jnp.sum(wires)
            return jnp.mean(g_hat, axis=0)

        grads_mean = tree_map_with_path_str(leaf, grads)
        return grads_mean, ReducerTelemetry(
            wire_bytes=totals["wire"], dense_bytes=totals["dense"],
            error_bound=jnp.float32(0.0), n_hops=n, packs_per_segment=1,
            per_pod=n), state


_SIM_FNS = {
    TOPO_RING: ring_allreduce_nsd,
    TOPO_HIER: hier_allreduce_nsd,
    TOPO_BUTTERFLY: butterfly_allreduce_nsd,
}
_MESH_FNS = {
    TOPO_RING: None,  # built lazily per reducer (see _built_fn)
    TOPO_HIER: hier_mod._make_hier_allreduce,
    TOPO_BUTTERFLY: bfly_mod.make_butterfly_allreduce,
}


class _AllReduceReducer(Reducer):
    """ring / hier / butterfly over stacked (n_nodes, ...) gradients.

    Per compressible leaf the stacked gradients go through the topology's
    compressed all-reduce (simulation by default; the shard_map program
    when a mesh is attached — identical per-hop math and keys, so the
    choice never changes results). Dense leaves average exactly, with the
    dense counterfactual of the SAME topology as both wire and dense
    bytes so ratios compare like for like. int8/topk_ef leaf modes
    degrade to nsd here: the reduce's wire format IS packed NSD.
    """

    def __init__(self, policy: CommPolicy, n_nodes: int = 1, mesh=None,
                 pod_axis: str = "pods", node_axis: str = "nodes"):
        super().__init__(policy, n_nodes, mesh, pod_axis, node_axis)
        self.topology = policy.topology
        if self.topology == TOPO_RING:
            self.cfg = RingConfig(s=policy.s, chunk=policy.chunk)
        elif self.topology == TOPO_HIER:
            self.cfg = HierConfig(pods=policy.pods, s=policy.s,
                                  chunk=policy.chunk)
        else:
            self.cfg = ButterflyConfig(pods=policy.pods, s=policy.s,
                                       chunk=policy.chunk)
        if self.topology != TOPO_RING and n_nodes % policy.pods != 0:
            raise ValueError(
                f"n_nodes ({n_nodes}) must be divisible by policy.pods "
                f"({policy.pods}) for the {self.topology!r} topology")
        self._fn = None  # lazily-built shard_map program (one per reducer;
        #                  jit retraces per leaf shape under the hood)

    @property
    def stacked(self) -> bool:
        return True

    def _topo_dense_bytes(self, size: int) -> float:
        n, policy = self.n_nodes, self.policy
        if self.topology == TOPO_HIER:
            return hier_mod.dense_reduce_bytes(
                size, policy.pods, n // policy.pods, policy.chunk)
        if self.topology == TOPO_BUTTERFLY:
            return bfly_mod.dense_reduce_bytes(
                size, policy.pods, n // policy.pods, policy.chunk)
        return ring_mod.dense_reduce_bytes(size, n, policy.chunk)

    def _allreduce(self, g_nodes, k0):
        if self.mesh is not None and self.n_nodes > 1:
            if self.topology == TOPO_RING:
                if self._fn is None:
                    self._fn = ring_mod.make_ring_allreduce(
                        self.mesh, self.node_axis, self.cfg)
                means, wires, bounds = self._fn(g_nodes, k0)
                n = self.n_nodes
                return means[0], ReducerTelemetry(
                    wire_bytes=jnp.sum(wires),
                    dense_bytes=jnp.float32(self._topo_dense_bytes(
                        int(g_nodes.size) // n)),
                    error_bound=bounds[0], n_hops=2 * n * (n - 1),
                    packs_per_segment=n, per_pod=n)
            if self._fn is None:
                self._fn = _MESH_FNS[self.topology](
                    self.mesh, self.cfg, self.pod_axis, self.node_axis)
            outs = self._fn(g_nodes, k0)
            means, w_ici, w_dcn, bounds = outs[:4]
            peak = outs[4][0] if len(outs) > 4 else jnp.float32(0.0)
            wire_ici, wire_dcn = jnp.sum(w_ici), jnp.sum(w_dcn)
            pods, per_pod = self.policy.pods, self.n_nodes // self.policy.pods
            mod = (hier_mod if self.topology == TOPO_HIER else bfly_mod)
            ici_hops, dcn_hops = mod._hop_counts(pods, per_pod)
            return means[0], ReducerTelemetry(
                wire_bytes=wire_ici + wire_dcn,
                dense_bytes=jnp.float32(self._topo_dense_bytes(
                    int(g_nodes.size) // self.n_nodes)),
                error_bound=bounds[0], wire_ici_bytes=wire_ici,
                wire_dcn_bytes=wire_dcn, peak_dcn_bytes=peak,
                n_hops=ici_hops + dcn_hops,
                packs_per_segment=(per_pod - 1)
                + hier_mod.tree_rounds(pods) + 1,
                pods=pods, per_pod=per_pod)
        mean, tele = _SIM_FNS[self.topology](g_nodes, k0, self.cfg)
        return mean, ReducerTelemetry(
            wire_bytes=tele.wire_bytes, dense_bytes=tele.dense_bytes,
            error_bound=tele.error_bound,
            wire_ici_bytes=getattr(tele, "wire_ici_bytes", 0.0),
            wire_dcn_bytes=getattr(tele, "wire_dcn_bytes", 0.0),
            peak_dcn_bytes=getattr(tele, "peak_dcn_bytes", 0.0),
            n_hops=tele.n_hops, packs_per_segment=tele.packs_per_segment,
            pods=getattr(tele, "pods", 1),
            per_pod=getattr(tele, "per_pod", self.n_nodes))

    def _reduce(self, grads, k_step, state):
        acc = {"tele": _zero_telemetry()}

        def leaf(name: str, g_nodes: jax.Array) -> jax.Array:
            size = int(g_nodes.size) // self.n_nodes
            mode = self.policy.mode_for(name, size)
            if mode == MODE_DENSE:
                db = jnp.float32(self._topo_dense_bytes(size))
                acc["tele"] = acc["tele"]._replace(
                    wire_bytes=acc["tele"].wire_bytes + db,
                    dense_bytes=acc["tele"].dense_bytes + db)
                return jnp.mean(g_nodes, axis=0)
            k0 = jax.random.fold_in(k_step, name_salt(name))
            mean, tele = self._allreduce(g_nodes, k0)
            t = acc["tele"]
            acc["tele"] = t._replace(
                wire_bytes=t.wire_bytes + tele.wire_bytes,
                dense_bytes=t.dense_bytes + tele.dense_bytes,
                error_bound=jnp.maximum(t.error_bound, tele.error_bound),
                wire_ici_bytes=t.wire_ici_bytes + tele.wire_ici_bytes,
                wire_dcn_bytes=t.wire_dcn_bytes + tele.wire_dcn_bytes,
                peak_dcn_bytes=t.peak_dcn_bytes + tele.peak_dcn_bytes,
                n_hops=t.n_hops + tele.n_hops,
                packs_per_segment=max(t.packs_per_segment,
                                      tele.packs_per_segment),
                pods=max(t.pods, tele.pods),
                per_pod=max(t.per_pod, tele.per_pod))
            return mean

        grads_mean = tree_map_with_path_str(leaf, grads)
        return grads_mean, acc["tele"], state


def reducer(policy: CommPolicy, mesh=None, *, n_nodes: Optional[int] = None,
            stacked: Optional[bool] = None, pod_axis: str = "pods",
            node_axis: str = "nodes") -> Reducer:
    """Build the Reducer a CommPolicy selects.

    ``n_nodes`` defaults to the mesh's data-parallel extent (pod axis x
    node axis when present, else the node axis), or 1 without a mesh.
    ``stacked`` (leading (n_nodes, ...) leaf axis) defaults to
    ``n_nodes > 1``; pass ``stacked=True`` with ``n_nodes=1`` for a
    degenerate stacked path (ssgd with one node). With
    ``policy.bucket_bytes > 0`` the reducer is wrapped in the overlap
    scheduler (``repro.comm.overlap``) — results stay bit-exact equal to
    the blocking reduce, telemetry gains per-bucket rows.
    """
    if n_nodes is None:
        if mesh is not None:
            n_nodes = int(mesh.shape[node_axis])
            if pod_axis in mesh.shape:
                n_nodes *= int(mesh.shape[pod_axis])
        else:
            n_nodes = 1
    if stacked is None:
        stacked = n_nodes > 1
    if policy.topology == TOPO_PS or not stacked:
        if stacked:
            red = _StackedPSReducer(policy, n_nodes, mesh,
                                    pod_axis, node_axis)
        else:
            red = _FlatPSReducer(policy, 1, mesh, pod_axis, node_axis)
    elif policy.topology in (TOPO_RING, TOPO_HIER, TOPO_BUTTERFLY):
        red = _AllReduceReducer(policy, n_nodes, mesh, pod_axis, node_axis)
    else:  # pragma: no cover - CommPolicy validates topology
        raise ValueError(policy.topology)
    if policy.bucket_bytes > 0:
        from repro.comm.overlap import OverlapReducer
        red = OverlapReducer(red, policy.bucket_bytes)
    return red


# ---------------------------------------------------------------------------
# comm: program DSL — the reducer's launch front door
# ---------------------------------------------------------------------------

_COMM_KEYS = {
    "topology": str, "default": str, "s": float, "chunk": int,
    "min_leaf_size": int, "topk_frac": float, "pods": int,
    "bucket_bytes": int, "stats": bool, "tag": str,
}
_KEY_TO_FIELD = {"stats": "collect_stats", "tag": "stats_tag"}


def parse_comm_program(spec: str, base: Optional[CommPolicy] = None
                       ) -> CommPolicy:
    """Parse a ``comm:`` program section into a CommPolicy.

    Grammar (``;``-separated clauses, same shape as the dither/memory
    program DSLs):

        topology=butterfly;pods=4;default=nsd;s=2.0;bucket_bytes=1048576;
        rule emb:dense;rule head:topk_ef

    ``rule PAT:MODE`` appends to ``overrides`` (first match wins);
    ``stats=1``/``tag=...`` map onto collect_stats/stats_tag. Unknown
    keys raise with the known-key list. Round-trips with
    :func:`format_comm_program` (pinned by tests/test_program.py).
    """
    policy = base or CommPolicy()
    kw: Dict[str, Any] = {}
    overrides = list(policy.overrides)
    for clause in spec.split(";"):
        clause = clause.strip()
        if not clause:
            continue
        if clause.startswith("rule "):
            body = clause[len("rule "):]
            if ":" not in body:
                raise ValueError(
                    f"comm rule needs PAT:MODE, got {clause!r}")
            pat, mode = body.split(":", 1)
            overrides.append((pat.strip(), mode.strip()))
            continue
        if "=" not in clause:
            raise ValueError(f"comm program clause {clause!r} is neither "
                             "key=value nor 'rule PAT:MODE'")
        k, v = (x.strip() for x in clause.split("=", 1))
        if k not in _COMM_KEYS:
            raise ValueError(f"unknown comm program key {k!r}; one of "
                             f"{sorted(_COMM_KEYS)}")
        typ = _COMM_KEYS[k]
        val = (v not in ("0", "false", "False")) if typ is bool else typ(v)
        kw[_KEY_TO_FIELD.get(k, k)] = val
    if kw.get("topology") is not None and kw["topology"] not in TOPOLOGIES:
        raise ValueError(f"unknown comm topology {kw['topology']!r}; one "
                         f"of {TOPOLOGIES}")
    return policy.replace(overrides=tuple(overrides), **kw)


def format_comm_program(policy: CommPolicy) -> str:
    """Render a CommPolicy as a ``comm:`` section (parse round-trips)."""
    default = CommPolicy()
    parts = []
    for key, typ in _COMM_KEYS.items():
        field = _KEY_TO_FIELD.get(key, key)
        val = getattr(policy, field)
        if val == getattr(default, field):
            continue
        if typ is bool:
            val = int(val)
        parts.append(f"{key}={val}")
    for pat, mode in policy.overrides:
        parts.append(f"rule {pat}:{mode}")
    return ";".join(parts)


# re-exported so "from repro.comm.reducer import *" carries the protocol's
# full vocabulary (state type included)
ErrorFeedbackState = ErrorFeedbackState
