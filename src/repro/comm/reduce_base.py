"""Shared machinery for compressed reduces (flat ring + hierarchical).

Both topologies in ``repro.comm`` move packed NSD segments between nodes
and account for the same three things the same way:

  * segmenting      a flat gradient is padded and split into chunk-aligned
                    segments, one per ring position;
  * hop keys        every pack that crosses a link gets a fresh PRNG key
                    folded from (salt, *position indices) so re-dither
                    noise is i.i.d. across hops, nodes, and levels;
  * accounting      wire bytes are MEASURED per pack (never estimated) and
                    the pointwise error bound is the running sum of the
                    Deltas of every pack whose quantization error lands in
                    a segment's final value (paper eq. 5/6 + |Q(x)-x| <=
                    Delta pointwise).

``ring.py`` and ``hierarchy.py`` import these helpers instead of each
carrying a private copy; the simulation and shard_map paths of both reduce
implementations share them too, which is what makes the sim-vs-shard_map
differential tests bit-exact.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class ReduceTelemetry(NamedTuple):
    """Per-reduce accounting shared by the flat ring and the hierarchy.

    ``packs_per_segment`` is the SEQUENTIAL pack depth: how many times one
    segment's value is re-quantized on its way to the final mean (the flat
    ring's N vs the hierarchy's (P-1) + ceil(log2 G) + 1). The error bound
    additionally sums the Deltas of packs from *other* nodes that merge
    into the segment, so it is not simply proportional to this count.
    """

    wire_bytes: jax.Array  # f32 scalar: total bytes crossing all links
    dense_bytes: jax.Array  # f32 scalar: same exchange at dense f32
    error_bound: jax.Array  # f32 scalar: max pointwise |result - mean| bound
    n_hops: int  # static: total link traversals
    packs_per_segment: int = 0  # static: sequential re-quantizations

    @property
    def ratio(self) -> jax.Array:
        return self.wire_bytes / jnp.maximum(self.dense_bytes, 1.0)


def seg_len(size: int, n: int, chunk: int) -> int:
    """Segment length: ceil(size / n) rounded up to a chunk multiple."""
    seg = -(-size // n)
    return -(-seg // chunk) * chunk


def segment(flat: jax.Array, n: int, chunk: int) -> Tuple[jax.Array, int]:
    """Pad a flat vector so it splits into n chunk-aligned segments."""
    size = flat.shape[0]
    seg = seg_len(size, n, chunk)
    padded = jnp.pad(flat, (0, n * seg - size))
    return padded.reshape(n, seg), seg


def hop_key(key: jax.Array, salt: int, *indices) -> jax.Array:
    """Fresh per-pack key: fold (salt, i0, i1, ...) into the base key.

    Indices may be Python ints or traced scalars (``jax.lax.axis_index``
    inside shard_map), so the sim and shard_map paths derive identical
    keys for the same logical pack.
    """
    k = jax.random.fold_in(key, salt)
    for i in indices:
        k = jax.random.fold_in(k, i)
    return k


class PackCounter:
    """Running wire-byte (per link class) + per-segment Delta accounting.

    ``weight`` lets the SPMD shard_map paths count a pack only on the
    device that actually sends it (a traced 0/1 mask); the sim paths call
    with the default weight of 1.
    """

    def __init__(self, n_segments: int):
        self.wire = {"ici": jnp.float32(0.0), "dcn": jnp.float32(0.0)}
        self.bound = jnp.zeros((n_segments,), jnp.float32)

    def count(self, packed, seg=None, link: str = "ici", hops: int = 1,
              weight=None) -> None:
        """Record a pack crossing ``hops`` links of class ``link``.

        ``seg`` (static or traced index) additionally charges the pack's
        Delta to that segment's error bound; pass None for forwarded-
        verbatim hops, whose error was already charged at pack time.
        """
        b = packed.wire_bytes().astype(jnp.float32) * hops
        d = packed.deltas[0]
        if weight is not None:
            w = weight.astype(jnp.float32) if hasattr(weight, "astype") \
                else jnp.float32(weight)
            b = b * w
            d = d * w
        self.wire[link] = self.wire[link] + b
        if seg is not None:
            self.bound = self.bound.at[seg].add(d)

    def count_bytes(self, nbytes, link: str = "ici") -> None:
        """Record raw bytes crossing a link class (already-packed payloads
        forwarded verbatim, e.g. a stacked set of piece packs — no Delta
        charge, the error was charged when each pack was created)."""
        self.wire[link] = self.wire[link] + jnp.float32(0.0) + nbytes

    @property
    def wire_total(self) -> jax.Array:
        return self.wire["ici"] + self.wire["dcn"]
