"""Compressed ring all-reduce: NSD gradients cross every hop in wire format.

The classic ring all-reduce moves 2*(N-1)/N of the gradient over each link
as dense f32. Here every hop carries the packed NSD representation instead:

  reduce-scatter   N-1 hops; each node adds its contribution to the partial
                   sum of one segment and RE-DITHERS it (a fresh NSD pack
                   with a per-(hop, node) key) before forwarding — the wire
                   never sees a dense partial sum, and because NSD noise is
                   zero-mean and i.i.d. across hops the re-quantization
                   errors average out rather than accumulate in expectation.
  all-gather       each completed segment is packed ONCE by its owner and
                   forwarded verbatim N-1 times (no reduction -> no repack).

Error accounting (paper eq. 5/6 + pointwise |Q(x) - x| <= Delta): segment c
is packed N-1 times during reduce-scatter and once at gather, so

    |result - dense_mean|  <=  (sum of those N packs' Deltas) / N

pointwise. ``RingTelemetry.error_bound`` reports that bound, measured from
the actual per-hop Deltas; tests assert against it. Wire bytes are measured
per pack (bitmap + non-zero levels), never estimated. The segmenting, hop-
key, and accounting helpers are shared with the two-level reduce in
``repro.comm.hierarchy`` via ``repro.comm.reduce_base`` — which also cuts
the flat ring's N sequential packs per segment down to
(P-1) + ceil(log2 G) + 1 when the node set spans pods (see that module).

Two implementations with identical per-hop math:

  * ``ring_allreduce_nsd`` — single-process simulation (a Python loop over
    nodes/hops). Runs anywhere, including the CPU test container; this is
    what the benchmarks and ``repro.distributed`` use by default.
  * ``make_ring_allreduce`` — the real thing: a ``shard_map`` program whose
    hops are ``jax.lax.ppermute`` of the PackedNSD pytree, so compressed
    bytes are what crosses the device boundary. Exercised under
    ``--xla_force_host_platform_device_count`` in tests/test_comm.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.quant import wire as wf
from repro.comm.reduce_base import (PackCounter, ReduceTelemetry, hop_key,
                                    seg_len, segment)
from repro.parallel.axes import shard_map_compat

_REDUCE_SALT = 0x51D5
_GATHER_SALT = 0xA11C

# Back-compat alias: the ring predates the shared base module.
RingTelemetry = ReduceTelemetry


@dataclasses.dataclass(frozen=True)
class RingConfig:
    s: float = 1.0  # NSD scale for on-wire quantization
    chunk: int = wf.DEFAULT_CHUNK


def dense_reduce_bytes(size: int, n: int, chunk: int = wf.DEFAULT_CHUNK
                       ) -> int:
    """Bytes the same N-node ring exchange would move at dense f32."""
    return 2 * n * (n - 1) * seg_len(size, n, chunk) * 4


def ring_allreduce_nsd(grads: Union[jax.Array, Sequence[jax.Array]],
                       key: jax.Array, cfg: RingConfig = RingConfig()
                       ) -> Tuple[jax.Array, RingTelemetry]:
    """Simulated compressed ring all-reduce of N stacked node gradients.

    grads: (N, *shape) stacked array or list of N same-shape arrays.
    Returns (mean over nodes, telemetry). N == 1 short-circuits (no wire).
    """
    if not isinstance(grads, jax.Array):
        grads = jnp.stack(list(grads))
    n = grads.shape[0]
    shape, dtype = grads.shape[1:], grads.dtype
    if n == 1:
        zero = jnp.float32(0.0)
        return grads[0], RingTelemetry(zero, zero, zero, 0, 0)

    flat = grads.astype(jnp.float32).reshape(n, -1)
    segs_per_node = []
    for i in range(n):
        segs, _ = segment(flat[i], n, cfg.chunk)
        segs_per_node.append(segs)
    # acc[i][c]: node i's current value for ring segment c
    acc: List[jax.Array] = list(segs_per_node)

    ctr = PackCounter(n)

    # --- reduce-scatter: segment c travels c -> c+1 -> ... -> c-1 ---
    for step in range(n - 1):
        packed = []
        for i in range(n):
            c = (i - step) % n
            p = wf.pack_nsd(acc[i][c], hop_key(key, _REDUCE_SALT, step, i),
                            cfg.s, cfg.chunk)
            packed.append((c, p))
            ctr.count(p, seg=c)
        for i in range(n):
            c, p = packed[i]
            j = (i + 1) % n
            acc[j] = acc[j].at[c].set(acc[j][c] + wf.unpack_nsd(p))

    # --- all-gather: owner (c-1) % n packs segment c once, forwards N-1x ---
    gathered = []
    for c in range(n):
        owner = (c - 1) % n
        p = wf.pack_nsd(acc[owner][c], hop_key(key, _GATHER_SALT, c, 0),
                        cfg.s, cfg.chunk)
        ctr.count(p, seg=c, hops=n - 1)
        gathered.append(wf.unpack_nsd(p))

    total = jnp.concatenate(gathered)
    size = 1
    for d in shape:
        size *= int(d)
    mean = (total[:size] / n).reshape(shape).astype(dtype)

    n_hops = n * (n - 1) * 2
    dense = jnp.float32(dense_reduce_bytes(flat.shape[1], n, cfg.chunk))
    return mean, RingTelemetry(wire_bytes=ctr.wire_total, dense_bytes=dense,
                               error_bound=jnp.max(ctr.bound) / n,
                               n_hops=n_hops, packs_per_segment=n)


def make_ring_allreduce(mesh: Mesh, axis_name: str,
                        cfg: RingConfig = RingConfig()):
    """Build the shard_map compressed ring all-reduce over ``axis_name``.

    Returns ``fn(stacked) -> (mean, wire_bytes)`` where ``stacked`` is
    (N, *shape) sharded over the mesh axis; every hop moves a PackedNSD
    pytree between neighboring devices via ``jax.lax.ppermute``.
    """
    n = mesh.shape[axis_name]
    fwd = [(i, (i + 1) % n) for i in range(n)]

    def ring(stacked_local: jax.Array, key: jax.Array):
        local = stacked_local[0]  # (1, *shape) local slice of the stack
        me = jax.lax.axis_index(axis_name)
        shape, dtype = local.shape, local.dtype
        acc, seg = segment(local.astype(jnp.float32).reshape(-1),
                           n, cfg.chunk)
        ctr = PackCounter(n)  # deltas of packs THIS node sent

        perm = partial(jax.lax.ppermute, axis_name=axis_name, perm=fwd)

        for step in range(n - 1):
            c_send = (me - step) % n
            p = wf.pack_nsd(jnp.take(acc, c_send, axis=0),
                            hop_key(key, _REDUCE_SALT, step, me),
                            cfg.s, cfg.chunk)
            ctr.count(p, seg=c_send)
            p_in = perm(p)
            c_recv = (me - 1 - step) % n
            acc = acc.at[c_recv].set(
                jnp.take(acc, c_recv, axis=0) + wf.unpack_nsd(p_in))

        c_own = (me + 1) % n  # node m finished segment m+1
        p = wf.pack_nsd(jnp.take(acc, c_own, axis=0),
                        hop_key(key, _GATHER_SALT, c_own, 0),
                        cfg.s, cfg.chunk)
        ctr.count(p, seg=c_own, hops=0)  # charge the Delta; bytes per hop
        out = jnp.zeros_like(acc).at[c_own].set(wf.unpack_nsd(p))
        cur = p
        for h in range(1, n):
            cur = perm(cur)
            ctr.count(cur)
            c = (me - h + 1) % n
            out = out.at[c].set(wf.unpack_nsd(cur))

        # per-segment bound = sum over ALL senders that touched the segment
        bound = jax.lax.psum(ctr.bound, axis_name)
        size = 1
        for d in shape:
            size *= int(d)
        mean = (out.reshape(-1)[:size] / n).reshape(shape).astype(dtype)
        return mean[None], ctr.wire_total[None], (jnp.max(bound) / n)[None]

    return jax.jit(shard_map_compat(
        ring, mesh=mesh,
        in_specs=(P(axis_name), P()),
        out_specs=(P(axis_name), P(axis_name), P(axis_name))))


def allreduce_compressed(grads, key, cfg=RingConfig(), mesh: Mesh = None,
                         axis_name: str = "nodes", pod_axis: str = "pods"):
    """Deprecated: dispatch reduces through ``repro.comm.reducer`` instead.

    Kept as a thin shim over the same internals the reducer uses — bit-
    identical results, pinned by tests/test_reducer.py.

    ``cfg`` selects the topology: a ``RingConfig`` runs the flat ring, a
    ``repro.comm.hierarchy.HierConfig`` the two-level (intra-pod ring +
    inter-pod tree) reduce. With a multi-device ``mesh`` the shard_map
    implementation runs (the hierarchy needs a 2-D (pod_axis, axis_name)
    mesh); otherwise the single-process simulation with identical per-hop
    math.
    """
    import warnings

    from repro.comm import hierarchy as hier  # local: avoid import cycle

    warnings.warn(
        "allreduce_compressed is deprecated; use repro.comm.reducer("
        "policy, mesh) which owns topology dispatch and telemetry",
        DeprecationWarning, stacklevel=2)
    if isinstance(cfg, hier.HierConfig):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            return hier.allreduce_hier(grads, key, cfg, mesh=mesh,
                                       pod_axis=pod_axis,
                                       node_axis=axis_name)
    if mesh is not None and mesh.shape[axis_name] > 1:
        if not isinstance(grads, jax.Array):
            grads = jnp.stack(list(grads))
        n = mesh.shape[axis_name]
        if grads.shape[0] != n:
            raise ValueError(
                f"stacked node axis ({grads.shape[0]}) must equal the mesh "
                f"{axis_name!r} axis size ({n}); a mismatched stack would "
                "silently drop gradients")
        fn = make_ring_allreduce(mesh, axis_name, cfg)
        means, wires, bounds = fn(grads, key)
        flat_size = 1
        for d in grads.shape[1:]:
            flat_size *= int(d)
        n_hops = 2 * n * (n - 1)
        tele = RingTelemetry(
            wire_bytes=jnp.sum(wires),
            dense_bytes=jnp.float32(
                dense_reduce_bytes(flat_size, n, cfg.chunk)),
            error_bound=bounds[0], n_hops=n_hops, packs_per_segment=n)
        return means[0], tele
    return ring_allreduce_nsd(grads, key, cfg)
