"""Gradient compression policy for the wire — per-leaf mode selection + EF.

``CommPolicy`` decides, per gradient pytree leaf, how that leaf crosses the
wire in a data-parallel exchange:

    dense    f32 passthrough                       (4 bytes/elem)
    int8     NSD -> (int8 k, f32 Delta), dense k   (1 byte/elem + 4)
    nsd      NSD -> packed wire format             (bitmap + non-zero levels;
                                                    see repro.quant.wire)
    topk_ef  top-k sparsification + error feedback (8 bytes/kept elem)

Any registered quant codec spec (``repro.quant``, e.g. ``"int4@g32"``) is
also a valid per-leaf mode: it rides the registry branch of
``compress_leaf`` with the codec's own measured wire bytes, so new formats
reach the wire without touching this module.

The NSD modes are the paper's operator on the comm side: unbiased, bounded
error, nothing to tune beyond ``s``. ``topk_ef`` is the meProp-lineage
comparator; its residual state (``ErrorFeedbackState``, migrated here from
``repro.distributed.ssgd``) must be threaded through steps by the caller.

Every compress call returns measured wire bytes alongside the decompressed
value, so callers get honest telemetry whether or not they route through
``repro.comm.telemetry``. Small leaves (biases, norm scales) default to
dense: their bitmap+header overhead exceeds the saving and quantizing them
buys nothing.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.quant import wire as wf
from repro.quant import codecs as qc
from repro.quant.registry import parse_spec
from repro.core.policy import name_salt
from repro.utils.pytree import flatten_with_names, tree_map_with_path_str

MODE_DENSE = "dense"
MODE_INT8 = "int8"
MODE_NSD = "nsd"
MODE_TOPK_EF = "topk_ef"
# The historical comm modes; any registered quant codec spec (e.g.
# "int4@g32") is ALSO a valid wire mode now — it rides the registry
# branch of ``compress_leaf`` with measured bytes from the codec.
MODES = (MODE_DENSE, MODE_INT8, MODE_NSD, MODE_TOPK_EF)


def _valid_comm_mode(mode: str) -> bool:
    if mode in MODES:
        return True
    try:
        parse_spec(mode)
        return True
    except ValueError:
        return False

# How the data-parallel reduce itself is organized (repro.comm.ring /
# repro.comm.hierarchy / repro.comm.butterfly). "ps" is the parameter-
# server shape: every node compresses independently and a central average
# follows (the original make_ssgd_step behavior). "ring", "hier" and
# "butterfly" route the stacked node gradients through the corresponding
# compressed all-reduce instead, so the wire carries re-dithered partial
# sums and telemetry gains the topology's error bound and sequential pack
# depth. All four are consumed through the single ``repro.comm.reducer``
# front door.
TOPO_PS = "ps"
TOPO_RING = "ring"
TOPO_HIER = "hier"
TOPO_BUTTERFLY = "butterfly"
TOPOLOGIES = (TOPO_PS, TOPO_RING, TOPO_HIER, TOPO_BUTTERFLY)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ErrorFeedbackState:
    residual: jax.Array


def topk_error_feedback(g: jax.Array, state: Optional[ErrorFeedbackState],
                        k_frac: float = 0.01
                        ) -> Tuple[jax.Array, ErrorFeedbackState]:
    """Top-k sparsification with error feedback (memory of dropped mass).

    Unbiasedness is restored asymptotically by the residual accumulator;
    composes with dithered backprop (which controls the *compute* side).
    """
    flat = g.reshape(-1)
    if state is not None:
        flat = flat + state.residual
    k = max(1, int(k_frac * flat.size))
    mag = jnp.abs(flat)
    thresh = jax.lax.top_k(mag, k)[0][-1]
    mask = mag >= thresh
    sent = jnp.where(mask, flat, 0)
    residual = flat - sent
    return sent.reshape(g.shape), ErrorFeedbackState(residual=residual)


@dataclasses.dataclass(frozen=True)
class CommPolicy:
    """Per-run configuration of the gradient wire path."""

    default: str = MODE_NSD
    s: float = 1.0  # NSD scale for the comm-side quantization
    chunk: int = wf.DEFAULT_CHUNK
    topk_frac: float = 0.01
    min_leaf_size: int = 256  # leaves smaller than this stay dense
    overrides: tuple = ()  # ((name_substring, mode), ...), first match wins
    collect_stats: bool = False  # route per-leaf bytes into comm telemetry
    stats_tag: str = "comm/"
    topology: str = TOPO_PS  # how the data-parallel reduce is organized
    pods: int = 1  # node grouping for TOPO_HIER/BUTTERFLY (N = pods*per_pod)
    # overlap scheduling: > 0 buckets the gradient tree in reverse layer
    # order into ~bucket_bytes chunks and launches each bucket's reduce as
    # its layers finish backward (repro.comm.overlap); 0 keeps the single
    # blocking reduce. Bit-exact either way (per-leaf keys are bucket-
    # independent).
    bucket_bytes: int = 0

    def __post_init__(self):
        for m in (self.default,) + tuple(m for _, m in self.overrides):
            if not _valid_comm_mode(m):
                raise ValueError(
                    f"unknown comm mode {m!r}; one of {MODES} or a "
                    f"registered quant codec spec (repro.quant)")
        if self.topology not in TOPOLOGIES:
            raise ValueError(f"unknown comm topology {self.topology!r}; "
                             f"one of {TOPOLOGIES}")
        if self.pods < 1:
            raise ValueError(f"pods must be >= 1, got {self.pods}")
        if self.bucket_bytes < 0:
            raise ValueError(
                f"bucket_bytes must be >= 0, got {self.bucket_bytes}")

    def reduce_cfg(self):
        """Deprecated: the per-topology config dataclasses are an internal
        detail of ``repro.comm.reducer`` now; build a Reducer instead."""
        import warnings
        warnings.warn(
            "CommPolicy.reduce_cfg() is deprecated; use "
            "repro.comm.reducer(policy, ...) which owns topology dispatch",
            DeprecationWarning, stacklevel=2)
        from repro.comm.butterfly import ButterflyConfig
        from repro.comm.hierarchy import HierConfig
        from repro.comm.ring import RingConfig
        if self.topology == TOPO_RING:
            return RingConfig(s=self.s, chunk=self.chunk)
        if self.topology == TOPO_HIER:
            return HierConfig(pods=self.pods, s=self.s, chunk=self.chunk)
        if self.topology == TOPO_BUTTERFLY:
            return ButterflyConfig(pods=self.pods, s=self.s, chunk=self.chunk)
        return None

    def mode_for(self, name: str, size: int) -> str:
        for pat, mode in self.overrides:
            if pat in name:
                return mode
        if size < self.min_leaf_size:
            return MODE_DENSE
        return self.default

    def replace(self, **kw) -> "CommPolicy":
        return dataclasses.replace(self, **kw)


# A passthrough policy: every leaf dense — useful as a telemetry baseline.
DENSE = CommPolicy(default=MODE_DENSE)


def compress_leaf(g: jax.Array, key: jax.Array, mode: str,
                  policy: CommPolicy,
                  state: Optional[ErrorFeedbackState] = None):
    """One leaf through the wire: returns (g_hat, wire_bytes, new_state).

    ``g_hat`` is what the receiving end reconstructs; ``wire_bytes`` is a
    traced int32 scalar of what crossed the link.
    """
    dense_bytes = wf.wire_bytes_dense(g.shape, jnp.float32)
    if mode == MODE_DENSE:
        return g, jnp.int32(dense_bytes), state
    if mode == MODE_INT8:
        q = qc.nsd_int8(g, key, policy.s)
        return (q.dequantize(g.dtype),
                jnp.int32(g.size + 4 + wf.HEADER_BYTES), state)
    if mode == MODE_NSD:
        p = wf.pack_nsd(g, key, policy.s, policy.chunk)
        return wf.unpack_nsd(p), p.wire_bytes(), state
    if mode == MODE_TOPK_EF:
        sent, new_state = topk_error_feedback(g, state, policy.topk_frac)
        k = max(1, int(policy.topk_frac * g.size))
        # int32 index + f32 value per kept element
        return sent, jnp.int32(8 * k + wf.HEADER_BYTES), new_state
    # any registered quant codec spec (e.g. "int4@g32"): encode/decode
    # through the registry with the codec's own measured wire bytes
    enc = qc.encode(mode, g, key)
    g_hat = qc.decode(mode, enc).astype(g.dtype)
    return g_hat, qc.measured_bytes(mode, enc), state


def init_comm_state(grads: Any, policy: CommPolicy) -> Dict[str, Any]:
    """Zero EF residuals for the leaves the policy routes through topk_ef."""
    states: Dict[str, Any] = {}
    for name, g in flatten_with_names(grads):
        if policy.mode_for(name, int(g.size)) == MODE_TOPK_EF:
            states[name] = ErrorFeedbackState(
                residual=jnp.zeros((int(g.size),), jnp.float32))
    return states


def compress_tree(grads: Any, key: jax.Array, policy: CommPolicy,
                  states: Optional[Dict[str, Any]] = None):
    """Route a gradient pytree through the wire, leaf by leaf.

    Returns (grads_hat, new_states, telemetry) where telemetry holds traced
    scalars ``wire_bytes`` / ``dense_bytes`` (and emits them to the comm
    sink when ``policy.collect_stats``).
    """
    states = dict(states or {})
    # f32 accumulators: int32 wraps at ~536M params worth of dense bytes
    totals = {"wire": jnp.float32(0.0), "dense": jnp.float32(0.0)}

    def one(name: str, g: jax.Array) -> jax.Array:
        mode = policy.mode_for(name, int(g.size))
        leaf_key = jax.random.fold_in(key, name_salt(name))
        g_hat, wire, new_state = compress_leaf(
            g, leaf_key, mode, policy, states.get(name))
        if new_state is not None:
            states[name] = new_state
        totals["wire"] = totals["wire"] + wire.astype(jnp.float32)
        totals["dense"] = totals["dense"] + jnp.float32(
            wf.wire_bytes_dense(g.shape, jnp.float32))
        return g_hat

    grads_hat = tree_map_with_path_str(one, grads)
    telemetry = {"wire_bytes": totals["wire"], "dense_bytes": totals["dense"]}
    if policy.collect_stats:
        from repro.comm import telemetry as tele
        tele.emit(policy.stats_tag, totals["wire"], totals["dense"])
    return grads_hat, states, telemetry
