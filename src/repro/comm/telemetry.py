"""Comm-side telemetry: bytes-on-wire counters + roofline pricing.

Thin facade over the process-local sink in ``repro.core.stats`` (the same
io_callback machinery the dither sparsity telemetry uses, so one ``reset``
clears both) plus the bridge to ``repro.launch`` cost accounting: measured
wire bytes -> seconds on the TPU v5e ICI, comparable against the
compute/memory roofline terms.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax

from repro.obs import metrics as statslib


class CommTelemetry(NamedTuple):
    """Aggregated view of one tag's exchanges."""

    wire_bytes: float
    dense_bytes: float
    n_records: int

    @property
    def ratio(self) -> float:
        return (self.wire_bytes / self.dense_bytes
                if self.dense_bytes else float("nan"))


def emit(tag: str, wire_bytes: jax.Array, dense_bytes: jax.Array) -> None:
    """Record one exchange's byte counts (callable from inside jit)."""
    statslib.emit_comm(tag, wire_bytes, dense_bytes)


def reset() -> None:
    statslib.reset()


def summary() -> Dict[str, CommTelemetry]:
    return {
        tag: CommTelemetry(wire_bytes=row["wire_bytes"],
                           dense_bytes=row["dense_bytes"],
                           n_records=row["n_records"])
        for tag, row in statslib.comm_summary().items()
    }


def totals() -> CommTelemetry:
    """All tags folded together."""
    wire = dense = 0.0
    n = 0
    for t in summary().values():
        wire += t.wire_bytes
        dense += t.dense_bytes
        n += t.n_records
    return CommTelemetry(wire_bytes=wire, dense_bytes=dense, n_records=n)


def wire_seconds(wire_bytes: float) -> float:
    """Price measured wire bytes on the target interconnect.

    Imported lazily: ``repro.launch`` is the deployment layer and must not
    become an import-time dependency of the comm subsystem.
    """
    from repro.launch.costmodel import price_wire_bytes
    return price_wire_bytes(wire_bytes)
