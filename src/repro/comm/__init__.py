"""repro.comm — NSD gradients as a first-class wire format.

wireformat.py   DEPRECATED shim over ``repro.quant.wire`` — the packed
                (deltas + bitmap + non-zero int8 levels) layout is the
                registered ``nsd`` codec's wire backend now
reduce_base.py  segmenting / hop-key / wire+bound accounting shared by
                the reduce topologies (sim and shard_map paths)
ring.py         flat compressed ring all-reduce (re-dithered partial
                sums); shard_map real path + single-device simulation
hierarchy.py    two-level reduce: intra-pod ring over ICI + inter-pod
                binomial tree over DCN; fewer sequential packs per
                segment and a tighter error bound than the flat ring
butterfly.py    recursive-halving/-doubling DCN variant of the inter-pod
                stage: same pack depth as the tree at roughly half the
                peak inter-pod link occupancy (G >= 4)
compression.py  per-leaf CommPolicy (dense/int8/nsd/topk_ef) + error
                feedback residuals + reduce-topology selection
reducer.py      THE front door: ``reducer(policy, mesh) -> Reducer`` with
                ``reduce(grads, key, step)`` + typed telemetry; owns
                topology dispatch and per-leaf key derivation. The older
                per-topology entry points (``allreduce_compressed``,
                ``allreduce_hier``/``make_hier_allreduce``,
                ``CommPolicy.reduce_cfg``) are deprecation shims over it.
overlap.py      reverse-layer-order bucket scheduling: launch each
                bucket's reduce while backward still runs; bit-exact vs
                the blocking reduce by key construction
telemetry.py    bytes-on-wire counters (via the obs metrics bus) +
                roofline pricing of measured wire bytes
"""
from repro.comm.compression import (
    DENSE,
    MODE_DENSE,
    MODE_INT8,
    MODE_NSD,
    MODE_TOPK_EF,
    TOPO_BUTTERFLY,
    TOPO_HIER,
    TOPO_PS,
    TOPO_RING,
    TOPOLOGIES,
    CommPolicy,
    ErrorFeedbackState,
    compress_leaf,
    compress_tree,
    init_comm_state,
    topk_error_feedback,
)
from repro.comm.butterfly import (
    ButterflyConfig,
    ButterflyTelemetry,
    allreduce_butterfly,
    butterfly_allreduce_nsd,
    butterfly_rounds,
    make_butterfly_allreduce,
)
from repro.comm.hierarchy import (
    HierConfig,
    HierTelemetry,
    allreduce_hier,
    hier_allreduce_nsd,
    make_hier_allreduce,
    tree_rounds,
)
from repro.comm.overlap import BucketPlan, OverlapReducer, plan_buckets
from repro.comm.reduce_base import ReduceTelemetry
from repro.comm.reducer import (
    Reducer,
    ReducerTelemetry,
    format_comm_program,
    parse_comm_program,
    reducer,
)
from repro.comm.ring import (
    RingConfig,
    RingTelemetry,
    allreduce_compressed,
    make_ring_allreduce,
    ring_allreduce_nsd,
)
from repro.quant.wire import (
    DEFAULT_CHUNK,
    PackedNSD,
    pack_bitmap,
    pack_indices,
    pack_nsd,
    popcount_u8,
    tile_mask_from_bitmap,
    tile_mask_from_packed,
    tile_nnz_from_bitmap,
    unpack_bitmap,
    unpack_nsd,
    wire_bytes_dense,
)
from repro.comm import telemetry

__all__ = [
    "DENSE", "MODE_DENSE", "MODE_INT8", "MODE_NSD", "MODE_TOPK_EF",
    "TOPO_BUTTERFLY", "TOPO_HIER", "TOPO_PS", "TOPO_RING", "TOPOLOGIES",
    "CommPolicy", "ErrorFeedbackState", "compress_leaf", "compress_tree",
    "init_comm_state", "topk_error_feedback",
    "ButterflyConfig", "ButterflyTelemetry", "allreduce_butterfly",
    "butterfly_allreduce_nsd", "butterfly_rounds", "make_butterfly_allreduce",
    "HierConfig", "HierTelemetry", "allreduce_hier", "hier_allreduce_nsd",
    "make_hier_allreduce", "tree_rounds", "ReduceTelemetry",
    "BucketPlan", "OverlapReducer", "plan_buckets",
    "Reducer", "ReducerTelemetry", "format_comm_program",
    "parse_comm_program", "reducer",
    "RingConfig", "RingTelemetry", "allreduce_compressed",
    "make_ring_allreduce", "ring_allreduce_nsd",
    "DEFAULT_CHUNK", "PackedNSD", "pack_bitmap", "pack_indices", "pack_nsd",
    "popcount_u8", "tile_mask_from_bitmap", "tile_mask_from_packed",
    "tile_nnz_from_bitmap", "unpack_bitmap", "unpack_nsd",
    "wire_bytes_dense",
    "telemetry",
]
