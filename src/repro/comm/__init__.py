"""repro.comm — NSD gradients as a first-class wire format.

wireformat.py   packed (deltas + bitmap + non-zero int8 levels) layout,
                jnp pack/unpack references, measured wire bytes
reduce_base.py  segmenting / hop-key / wire+bound accounting shared by
                both reduce topologies (sim and shard_map paths)
ring.py         flat compressed ring all-reduce (re-dithered partial
                sums); shard_map real path + single-device simulation
hierarchy.py    two-level reduce: intra-pod ring over ICI + inter-pod
                binomial tree over DCN; fewer sequential packs per
                segment and a tighter error bound than the flat ring
compression.py  per-leaf CommPolicy (dense/int8/nsd/topk_ef) + error
                feedback residuals + reduce-topology selection
telemetry.py    bytes-on-wire counters (via repro.core.stats) + roofline
                pricing of measured wire bytes
"""
from repro.comm.compression import (
    DENSE,
    MODE_DENSE,
    MODE_INT8,
    MODE_NSD,
    MODE_TOPK_EF,
    TOPO_HIER,
    TOPO_PS,
    TOPO_RING,
    TOPOLOGIES,
    CommPolicy,
    ErrorFeedbackState,
    compress_leaf,
    compress_tree,
    init_comm_state,
    topk_error_feedback,
)
from repro.comm.hierarchy import (
    HierConfig,
    HierTelemetry,
    allreduce_hier,
    hier_allreduce_nsd,
    make_hier_allreduce,
    tree_rounds,
)
from repro.comm.reduce_base import ReduceTelemetry
from repro.comm.ring import (
    RingConfig,
    RingTelemetry,
    allreduce_compressed,
    make_ring_allreduce,
    ring_allreduce_nsd,
)
from repro.comm.wireformat import (
    DEFAULT_CHUNK,
    PackedNSD,
    pack_bitmap,
    pack_indices,
    pack_nsd,
    popcount_u8,
    tile_mask_from_bitmap,
    tile_mask_from_packed,
    tile_nnz_from_bitmap,
    unpack_bitmap,
    unpack_nsd,
    wire_bytes_dense,
)
from repro.comm import telemetry

__all__ = [
    "DENSE", "MODE_DENSE", "MODE_INT8", "MODE_NSD", "MODE_TOPK_EF",
    "TOPO_HIER", "TOPO_PS", "TOPO_RING", "TOPOLOGIES",
    "CommPolicy", "ErrorFeedbackState", "compress_leaf", "compress_tree",
    "init_comm_state", "topk_error_feedback",
    "HierConfig", "HierTelemetry", "allreduce_hier", "hier_allreduce_nsd",
    "make_hier_allreduce", "tree_rounds", "ReduceTelemetry",
    "RingConfig", "RingTelemetry", "allreduce_compressed",
    "make_ring_allreduce", "ring_allreduce_nsd",
    "DEFAULT_CHUNK", "PackedNSD", "pack_bitmap", "pack_indices", "pack_nsd",
    "popcount_u8", "tile_mask_from_bitmap", "tile_mask_from_packed",
    "tile_nnz_from_bitmap", "unpack_bitmap", "unpack_nsd",
    "wire_bytes_dense",
    "telemetry",
]
