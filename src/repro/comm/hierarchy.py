"""Two-level compressed all-reduce: intra-pod NSD ring + inter-pod tree.

The flat ring in ``repro.comm.ring`` re-dithers each segment N-1 times, so
its sequential pack depth — and with it the pointwise error bound — grows
linearly with node count. Real pod-scale deployments are not flat: nodes
inside a pod share a fast ICI axis while pods talk over a much slower DCN
axis. This module reduces over that hierarchy instead, for N = G pods of
P nodes each:

  phase 1  intra-pod ring reduce-scatter: P-1 hops over ICI, re-dithered
           per hop exactly like the flat ring. Node (g, p) ends up owning
           segment c = (p+1) mod P of pod g's partial sum.
  phase 2  inter-pod binomial-tree reduce: ceil(log2 G) rounds over DCN.
           Each segment's per-pod owner acts as that segment's pod leader:
           in round r the owner in pod g with g mod 2^(r+1) == 2^r packs
           its partial (fresh per-(round, pod, segment) key) and sends it
           to pod g - 2^r, which unpacks and accumulates. Non-power-of-two
           pod counts just skip absent partners.
  phase 3  the root pod's owner packs the finished global segment ONCE;
           that pack is forwarded VERBATIM back down the tree (G-1 hops
           over DCN, no repack) ...
  phase 4  ... and around each pod's ring (P-1 hops per pod over ICI, no
           repack), so every node reconstructs the identical value.

Pack/error accounting (paper eq. 5/6, |Q(x) - x| <= Delta pointwise): a
segment crosses only

    (P-1) + ceil(log2 G) + 1   sequential packs   (flat ring: N)

and its final value absorbs the Deltas of G*(P-1) intra packs + (G-1)
tree packs + 1 broadcast pack = N packs total — the same COUNT as the
flat ring's N, but each intra/tree pack quantizes a pod-sized partial sum
(std ~ sqrt(P), sqrt(2^r P)) instead of the flat ring's ever-growing
global partial (std up to ~ sqrt(N)), so the summed Deltas — and the
reported ``error_bound`` — are strictly tighter on the same input.
Telemetry splits measured wire bytes by link class (ICI vs DCN) so
``repro.launch.costmodel`` can price the two axes separately and show when
the tree wins.

Two implementations with identical per-hop math and identical keys:

  * ``hier_allreduce_nsd`` — single-process simulation (Python loops).
  * ``make_hier_allreduce`` — shard_map over a 2-D (pods, nodes) mesh;
    every hop is a ``jax.lax.ppermute`` of the PackedNSD pytree (over the
    node axis for ICI hops, the pod axis for DCN hops). Exercised under
    ``--xla_force_host_platform_device_count`` in tests/test_hierarchy.py,
    including a non-power-of-two pod count.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.quant import wire as wf
from repro.comm.reduce_base import PackCounter, hop_key, seg_len, segment
from repro.parallel.axes import shard_map_compat

_INTRA_SALT = 0x1C1A  # intra-pod ring reduce-scatter packs
_TREE_UP_SALT = 0x7EE0  # inter-pod tree-reduce packs
_TREE_DOWN_SALT = 0xB0AD  # the single broadcast pack per segment


def tree_rounds(pods: int) -> int:
    """ceil(log2(pods)): rounds of the binomial tree over the pod axis."""
    return (pods - 1).bit_length() if pods > 1 else 0


@dataclasses.dataclass(frozen=True)
class HierConfig:
    """Two-level reduce configuration: N nodes = pods x (N // pods)."""

    pods: int = 2
    s: float = 1.0  # NSD scale for on-wire quantization
    chunk: int = wf.DEFAULT_CHUNK

    def __post_init__(self):
        if self.pods < 1:
            raise ValueError(f"pods must be >= 1, got {self.pods}")


class HierTelemetry(NamedTuple):
    """ReduceTelemetry plus the per-link-class split the cost model needs."""

    wire_bytes: jax.Array  # f32 scalar: total bytes crossing all links
    dense_bytes: jax.Array  # f32 scalar: same exchange at dense f32
    error_bound: jax.Array  # f32 scalar: max pointwise |result - mean| bound
    n_hops: int  # static: total link traversals (both classes)
    packs_per_segment: int  # static: sequential re-quantizations
    wire_ici_bytes: jax.Array  # f32 scalar: intra-pod (fast axis) bytes
    wire_dcn_bytes: jax.Array  # f32 scalar: inter-pod (slow axis) bytes
    pods: int = 1  # static: G
    per_pod: int = 1  # static: P
    # f32 scalar: max over pods of DCN bytes through that pod's slow-axis
    # link (sent + received) — the busiest-line occupancy the butterfly
    # variant (repro.comm.butterfly) is designed to cut. 0.0 where the
    # path doesn't track it (shard_map dispatch, zero telemetry).
    peak_dcn_bytes: Union[jax.Array, float] = 0.0

    @property
    def ratio(self) -> jax.Array:
        return self.wire_bytes / jnp.maximum(self.dense_bytes, 1.0)


def _hier_shape(n: int, pods: int) -> Tuple[int, int]:
    if n % pods != 0:
        raise ValueError(
            f"node count ({n}) must be divisible by the pod count ({pods}); "
            "ragged pods would leave some gradients out of the reduce")
    return pods, n // pods


def _mesh_axes(mesh: Mesh, pod_axis: str, node_axis: str) -> Tuple[int, int]:
    """Validate the 2-D (pod, node) mesh precondition with a real error."""
    missing = [a for a in (pod_axis, node_axis) if a not in mesh.shape]
    if missing:
        raise ValueError(
            f"hierarchical reduce needs a 2-D ({pod_axis!r}, {node_axis!r}) "
            f"mesh; this mesh has axes {tuple(mesh.shape)} (missing "
            f"{missing}) — build one with launch.mesh.make_node_mesh("
            f"NodeTopology(pods=..., nodes_per_pod=...))")
    return mesh.shape[pod_axis], mesh.shape[node_axis]


def _zero_telemetry() -> HierTelemetry:
    zero = jnp.float32(0.0)
    return HierTelemetry(zero, zero, zero, 0, 0, zero, zero, 1, 1)


def _hop_counts(g: int, p: int) -> Tuple[int, int]:
    """(ici segment-hops, dcn segment-hops) of the whole exchange."""
    ici = 2 * g * p * (p - 1)  # reduce-scatter + gather forwarding
    dcn = 2 * p * (g - 1)  # tree up + tree down, per segment owner line
    return ici, dcn


def dense_reduce_bytes(size: int, pods: int, per_pod: int,
                       chunk: int = wf.DEFAULT_CHUNK) -> int:
    """Bytes the same two-level exchange would move at dense f32."""
    ici, dcn = _hop_counts(pods, per_pod)
    return (ici + dcn) * seg_len(size, per_pod, chunk) * 4


def hier_allreduce_nsd(grads: Union[jax.Array, Sequence[jax.Array]],
                       key: jax.Array, cfg: HierConfig = HierConfig()
                       ) -> Tuple[jax.Array, HierTelemetry]:
    """Simulated two-level compressed all-reduce of N stacked gradients.

    grads: (N, *shape) stacked array or list of N same-shape arrays, pod-
    major (node i lives in pod i // per_pod). Returns (mean over nodes,
    telemetry). N == 1 short-circuits (no wire).
    """
    if not isinstance(grads, jax.Array):
        grads = jnp.stack(list(grads))
    n = grads.shape[0]
    shape, dtype = grads.shape[1:], grads.dtype
    if n == 1:
        return grads[0], _zero_telemetry()
    G, Pn = _hier_shape(n, cfg.pods)

    flat = grads.astype(jnp.float32).reshape(n, -1)
    # acc[g][p]: (Pn, seg) — node (g, p)'s current view of its pod's segments
    acc = [[segment(flat[g * Pn + p], Pn, cfg.chunk)[0] for p in range(Pn)]
           for g in range(G)]
    ctr = PackCounter(Pn)

    # --- phase 1: intra-pod ring reduce-scatter (re-dither per hop) ---
    for step in range(Pn - 1):
        packed = []
        for g in range(G):
            for p in range(Pn):
                c = (p - step) % Pn
                pk = wf.pack_nsd(acc[g][p][c],
                                 hop_key(key, _INTRA_SALT, step, g, p),
                                 cfg.s, cfg.chunk)
                ctr.count(pk, seg=c, link="ici")
                packed.append((g, p, c, pk))
        for g, p, c, pk in packed:
            dst = (p + 1) % Pn
            acc[g][dst] = acc[g][dst].at[c].set(
                acc[g][dst][c] + wf.unpack_nsd(pk))

    # partial[g][c]: pod g's sum of segment c, held by owner (c-1) % Pn
    part = [[acc[g][(c - 1) % Pn][c] for c in range(Pn)] for g in range(G)]

    # per-pod DCN line traffic (sent + received) for the peak-occupancy
    # telemetry the butterfly variant gates against
    traffic = [jnp.float32(0.0) for _ in range(G)]

    # --- phase 2: inter-pod binomial tree reduce (re-pack per combine) ---
    rounds = tree_rounds(G)
    for r in range(rounds):
        stride = 1 << r
        for g in range(G):
            if g % (2 * stride) != stride:
                continue
            dst = g - stride
            for c in range(Pn):
                pk = wf.pack_nsd(part[g][c],
                                 hop_key(key, _TREE_UP_SALT, r, g, c),
                                 cfg.s, cfg.chunk)
                ctr.count(pk, seg=c, link="dcn")
                b = pk.wire_bytes().astype(jnp.float32)
                traffic[g] = traffic[g] + b
                traffic[dst] = traffic[dst] + b
                part[dst][c] = part[dst][c] + wf.unpack_nsd(pk)

    # --- phase 3+4: root packs once; forwarded verbatim down the tree
    # (G-1 DCN hops) then around each pod's ring (P-1 ICI hops per pod) ---
    finals = []
    for c in range(Pn):
        pk = wf.pack_nsd(part[0][c], hop_key(key, _TREE_DOWN_SALT, 0, 0, c),
                         cfg.s, cfg.chunk)
        ctr.count(pk, seg=c, link="dcn", hops=G - 1)
        ctr.count(pk, link="ici", hops=G * (Pn - 1))
        b = pk.wire_bytes().astype(jnp.float32)
        for r in range(rounds - 1, -1, -1):
            stride = 1 << r
            for src in range(0, G, 2 * stride):
                if src + stride < G:
                    traffic[src] = traffic[src] + b
                    traffic[src + stride] = traffic[src + stride] + b
        finals.append(wf.unpack_nsd(pk))

    total = jnp.concatenate(finals)
    size = 1
    for d in shape:
        size *= int(d)
    mean = (total[:size] / n).reshape(shape).astype(dtype)

    ici_hops, dcn_hops = _hop_counts(G, Pn)
    dense = jnp.float32(dense_reduce_bytes(flat.shape[1], G, Pn, cfg.chunk))
    return mean, HierTelemetry(
        wire_bytes=ctr.wire_total, dense_bytes=dense,
        error_bound=jnp.max(ctr.bound) / n, n_hops=ici_hops + dcn_hops,
        packs_per_segment=(Pn - 1) + rounds + 1,
        wire_ici_bytes=ctr.wire["ici"], wire_dcn_bytes=ctr.wire["dcn"],
        pods=G, per_pod=Pn,
        peak_dcn_bytes=(jnp.max(jnp.stack(traffic)) if G > 1
                        else jnp.float32(0.0)))


def make_hier_allreduce(mesh: Mesh, cfg: HierConfig = HierConfig(),
                        pod_axis: str = "pods", node_axis: str = "nodes"):
    """Deprecated: build reduces through ``repro.comm.reducer`` instead.

    Thin shim over the internal builder the reducer consumes; results are
    bit-identical (pinned by tests/test_reducer.py)."""
    import warnings
    warnings.warn(
        "make_hier_allreduce is deprecated; use repro.comm.reducer("
        "policy, mesh) which owns topology dispatch and telemetry",
        DeprecationWarning, stacklevel=2)
    return _make_hier_allreduce(mesh, cfg, pod_axis, node_axis)


def _make_hier_allreduce(mesh: Mesh, cfg: HierConfig = HierConfig(),
                         pod_axis: str = "pods", node_axis: str = "nodes"):
    """Build the shard_map two-level reduce over a 2-D (pods, nodes) mesh.

    Returns ``fn(stacked, key) -> (means, wire_ici, wire_dcn, bounds)``
    with ``stacked`` (N, *shape) pod-major over the flattened mesh; every
    ICI hop is a ppermute over ``node_axis``, every DCN hop a ppermute
    over ``pod_axis``. Per-hop math and keys match ``hier_allreduce_nsd``
    bit-exactly.
    """
    G, Pn = _mesh_axes(mesh, pod_axis, node_axis)
    if cfg.pods != G:
        raise ValueError(f"cfg.pods ({cfg.pods}) != mesh {pod_axis!r} axis "
                         f"size ({G})")
    rounds = tree_rounds(G)
    fwd_nodes = [(i, (i + 1) % Pn) for i in range(Pn)]

    def hier(stacked_local: jax.Array, key: jax.Array):
        local = stacked_local[0]  # (1, *shape) local slice of the stack
        g = jax.lax.axis_index(pod_axis)
        me = jax.lax.axis_index(node_axis)
        shape, dtype = local.shape, local.dtype
        acc, seg = segment(local.astype(jnp.float32).reshape(-1),
                           Pn, cfg.chunk)
        ctr = PackCounter(Pn)
        perm_n = partial(jax.lax.ppermute, axis_name=node_axis,
                         perm=fwd_nodes)

        # --- phase 1: intra-pod ring reduce-scatter over the node axis ---
        for step in range(Pn - 1):
            c_send = (me - step) % Pn
            pk = wf.pack_nsd(jnp.take(acc, c_send, axis=0),
                             hop_key(key, _INTRA_SALT, step, g, me),
                             cfg.s, cfg.chunk)
            ctr.count(pk, seg=c_send, link="ici")
            pk_in = perm_n(pk)
            c_recv = (me - 1 - step) % Pn
            acc = acc.at[c_recv].set(
                jnp.take(acc, c_recv, axis=0) + wf.unpack_nsd(pk_in))

        c_own = (me + 1) % Pn  # this node owns its pod's sum of c_own
        part = jnp.take(acc, c_own, axis=0)

        # --- phase 2: binomial tree over the pod axis (SPMD: every device
        # packs, but only actual senders' packs count and cross the wire;
        # non-receivers get an all-zero pack from ppermute -> add 0) ---
        for r in range(rounds):
            stride = 1 << r
            is_sender = (g % (2 * stride)) == stride
            pk = wf.pack_nsd(part, hop_key(key, _TREE_UP_SALT, r, g, c_own),
                             cfg.s, cfg.chunk)
            ctr.count(pk, seg=c_own, link="dcn", weight=is_sender)
            perm = [(src, src - stride) for src in range(G)
                    if src % (2 * stride) == stride]
            pk_in = jax.lax.ppermute(pk, axis_name=pod_axis, perm=perm)
            part = part + wf.unpack_nsd(pk_in)

        # --- phase 3: pod 0's owner packs the global segment once, then
        # the pack travels down the tree verbatim (receivers ADOPT it) ---
        pk = wf.pack_nsd(part, hop_key(key, _TREE_DOWN_SALT, 0, 0, c_own),
                         cfg.s, cfg.chunk)
        is_root = (g == 0)
        ctr.count(pk, seg=c_own, link="dcn", hops=0, weight=is_root)
        for r in range(rounds - 1, -1, -1):
            stride = 1 << r
            # holders after round r+1 are pods == 0 mod 2*stride
            is_sender = ((g % (2 * stride)) == 0) & (g + stride < G)
            ctr.count(pk, link="dcn", weight=is_sender)
            perm = [(src, src + stride) for src in range(0, G, 2 * stride)
                    if src + stride < G]
            pk_in = jax.lax.ppermute(pk, axis_name=pod_axis, perm=perm)
            is_recv = (g % (2 * stride)) == stride
            pk = jax.tree.map(lambda a, b: jnp.where(is_recv, b, a),
                              pk, pk_in)

        # --- phase 4: forward the final pack around the pod ring ---
        out = jnp.zeros_like(acc).at[c_own].set(wf.unpack_nsd(pk))
        cur = pk
        for h in range(1, Pn):
            cur = perm_n(cur)
            ctr.count(cur, link="ici")
            c = (me - h + 1) % Pn
            out = out.at[c].set(wf.unpack_nsd(cur))

        # per-segment bound = sum over ALL packs that touched the segment
        bound = jax.lax.psum(ctr.bound, (pod_axis, node_axis))
        size = 1
        for d in shape:
            size *= int(d)
        n = G * Pn
        mean = (out.reshape(-1)[:size] / n).reshape(shape).astype(dtype)
        return (mean[None], ctr.wire["ici"][None], ctr.wire["dcn"][None],
                (jnp.max(bound) / n)[None])

    spec = P((pod_axis, node_axis))
    return jax.jit(shard_map_compat(
        hier, mesh=mesh,
        in_specs=(spec, P()),
        out_specs=(spec, spec, spec, spec)))


def allreduce_hier(grads, key, cfg: HierConfig = HierConfig(),
                   mesh: Mesh = None, pod_axis: str = "pods",
                   node_axis: str = "nodes"
                   ) -> Tuple[jax.Array, HierTelemetry]:
    """Deprecated: dispatch reduces through ``repro.comm.reducer`` instead.

    Shard_map two-level reduce when a 2-D multi-device mesh is given, else
    the single-process simulation (identical per-hop math). Kept as a thin
    shim over the same internals the reducer uses — bit-identical results,
    pinned by tests/test_reducer.py."""
    import warnings
    warnings.warn(
        "allreduce_hier is deprecated; use repro.comm.reducer(policy, "
        "mesh) which owns topology dispatch and telemetry",
        DeprecationWarning, stacklevel=2)
    if not isinstance(grads, jax.Array):
        grads = jnp.stack(list(grads))
    n = grads.shape[0]
    if mesh is not None and n > 1:
        G, Pn = _mesh_axes(mesh, pod_axis, node_axis)
        if grads.shape[0] != G * Pn:
            raise ValueError(
                f"stacked node axis ({grads.shape[0]}) must equal the mesh "
                f"({pod_axis!r} x {node_axis!r}) size ({G}*{Pn}); a "
                "mismatched stack would silently drop gradients")
        fn = _make_hier_allreduce(mesh, cfg, pod_axis, node_axis)
        means, w_ici, w_dcn, bounds = fn(grads, key)
        flat_size = 1
        for d in grads.shape[1:]:
            flat_size *= int(d)
        ici_hops, dcn_hops = _hop_counts(G, Pn)
        wire_ici = jnp.sum(w_ici)
        wire_dcn = jnp.sum(w_dcn)
        tele = HierTelemetry(
            wire_bytes=wire_ici + wire_dcn,
            dense_bytes=jnp.float32(
                dense_reduce_bytes(flat_size, G, Pn, cfg.chunk)),
            error_bound=bounds[0], n_hops=ici_hops + dcn_hops,
            packs_per_segment=(Pn - 1) + tree_rounds(G) + 1,
            wire_ici_bytes=wire_ici, wire_dcn_bytes=wire_dcn,
            pods=G, per_pod=Pn)
        return means[0], tele
    return hier_allreduce_nsd(grads, key, cfg)
