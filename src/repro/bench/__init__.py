"""repro.bench — machine-readable benchmark results + regression gate.

Schema (``BenchResult``/``SuiteRun``/``Gate``) and comparator
(``compare_runs``) shared by every suite under ``benchmarks/`` and the
``benchmarks.suite`` runner that writes ``BENCH_<suite>.json`` files and
enforces tolerance bands against committed baselines.
"""
from repro.bench.compare import CompareReport, Finding, compare_runs
from repro.bench.schema import (BOUND_SLACK, SCHEMA_VERSION, BenchResult,
                                Gate, SuiteRun, git_sha, make_suite_run)

__all__ = [
    "BOUND_SLACK",
    "SCHEMA_VERSION",
    "BenchResult",
    "CompareReport",
    "Finding",
    "Gate",
    "SuiteRun",
    "compare_runs",
    "git_sha",
    "make_suite_run",
]
