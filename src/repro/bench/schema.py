"""Machine-readable benchmark results: the ``BenchResult`` wire schema.

Every suite under ``benchmarks/`` emits a list of ``BenchResult``s; the
runner (``benchmarks.suite``) wraps them in a ``SuiteRun`` with the
provenance needed to interpret a number six months later — git sha, jax
version, backend platform, quick/full flag — and writes one
``BENCH_<suite>.json`` per suite. The JSON round trip is exact
(``tests/test_bench.py``).

Two metric classes live side by side in one result:

* ``value`` — the wall-clock headline (``unit`` says what it measures).
  Timing on shared CI runners is noise, so it is recorded for the
  trajectory but never gated.
* ``derived`` — named scalar stats (accuracy, sparsity, wire ratio,
  packs per segment ...). A suite declares which of these are
  regression-gated, and with what tolerance band, via ``gates``. The
  comparator (``repro.bench.compare``) only ever fails on gated metrics.

Tolerance bands follow the ``tests/stat_utils.py`` philosophy: derive the
band from what the metric *is* (deterministic telemetry -> near-zero band,
short stochastic training -> a band covering seed/platform jitter) instead
of sprinkling ad-hoc fudge factors at comparison time.
"""
from __future__ import annotations

import dataclasses
import subprocess
from typing import Any, Dict, List, Optional

SCHEMA_VERSION = 1

# Multiplicative headroom applied on top of every band for f32/accumulation
# noise — mirrors stat_utils.BOUND_SLACK, not a statistical fudge factor.
BOUND_SLACK = 1.001


@dataclasses.dataclass(frozen=True)
class Gate:
    """Tolerance band for one gated metric.

    band = max(rel * |baseline|, abs); ``direction`` says which drift is a
    regression: "low" (metric must not drop below baseline - band, e.g.
    accuracy/sparsity), "high" (must not rise above baseline + band, e.g.
    wire ratio, error bound), "both" (either way, e.g. exact invariants
    with abs == 0).
    """

    rel: float = 0.0
    abs: float = 0.0
    direction: str = "both"

    def band(self, baseline: float) -> float:
        return max(self.rel * abs(baseline), self.abs)

    def check(self, baseline: float, current: float) -> bool:
        """True when ``current`` is within the band around ``baseline``."""
        b = self.band(baseline) * BOUND_SLACK + abs(baseline) * (
            BOUND_SLACK - 1.0)
        lo_ok = current >= baseline - b
        hi_ok = current <= baseline + b
        if self.direction == "low":
            return lo_ok
        if self.direction == "high":
            return hi_ok
        return lo_ok and hi_ok

    def to_dict(self) -> Dict[str, Any]:
        return {"rel": self.rel, "abs": self.abs,
                "direction": self.direction}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Gate":
        return cls(rel=float(d.get("rel", 0.0)), abs=float(d.get("abs", 0.0)),
                   direction=str(d.get("direction", "both")))


@dataclasses.dataclass(frozen=True)
class BenchResult:
    """One benchmark row: headline timing + gated derived stats."""

    name: str  # stable id, e.g. "table1/lenet5" — the comparator's join key
    value: float  # headline metric (timing; recorded, never gated)
    unit: str = "us"
    derived: Dict[str, float] = dataclasses.field(default_factory=dict)
    gates: Dict[str, Gate] = dataclasses.field(default_factory=dict)
    context: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def derived_str(self) -> str:
        """Legacy ``name,us,derived`` CSV cell (benchmarks.run output)."""
        parts = [f"{k}={v:.4g}" for k, v in self.derived.items()]
        parts += [f"{k}={v}" for k, v in self.context.items()]
        return " ".join(parts)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "value": self.value,
            "unit": self.unit,
            "derived": dict(self.derived),
            "gates": {k: g.to_dict() for k, g in self.gates.items()},
            "context": dict(self.context),
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "BenchResult":
        return cls(
            name=str(d["name"]),
            value=float(d["value"]),
            unit=str(d.get("unit", "us")),
            derived={k: float(v) for k, v in d.get("derived", {}).items()},
            gates={k: Gate.from_dict(g)
                   for k, g in d.get("gates", {}).items()},
            context=dict(d.get("context", {})),
        )


@dataclasses.dataclass(frozen=True)
class SuiteRun:
    """All results of one suite execution plus provenance."""

    suite: str
    results: List[BenchResult]
    git_sha: str = "unknown"
    jax_version: str = "unknown"
    platform: str = "unknown"
    quick: bool = True
    schema_version: int = SCHEMA_VERSION

    def by_name(self) -> Dict[str, BenchResult]:
        return {r.name: r for r in self.results}

    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema_version": self.schema_version,
            "suite": self.suite,
            "git_sha": self.git_sha,
            "jax_version": self.jax_version,
            "platform": self.platform,
            "quick": self.quick,
            "results": [r.to_dict() for r in self.results],
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SuiteRun":
        return cls(
            suite=str(d["suite"]),
            results=[BenchResult.from_dict(r) for r in d.get("results", [])],
            git_sha=str(d.get("git_sha", "unknown")),
            jax_version=str(d.get("jax_version", "unknown")),
            platform=str(d.get("platform", "unknown")),
            quick=bool(d.get("quick", True)),
            schema_version=int(d.get("schema_version", SCHEMA_VERSION)),
        )


def git_sha(cwd: Optional[str] = None) -> str:
    """Best-effort HEAD sha for provenance; never raises."""
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=10,
                             cwd=cwd)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def make_suite_run(suite: str, results: List[BenchResult], *,
                   quick: bool = True) -> SuiteRun:
    """Stamp a result list with this process's provenance."""
    import jax

    return SuiteRun(
        suite=suite, results=list(results), git_sha=git_sha(),
        jax_version=jax.__version__,
        platform=jax.default_backend(), quick=quick)
