"""Regression comparator: a current ``SuiteRun`` vs a committed baseline.

Policy (exercised case by case in ``tests/test_bench.py``):

* no baseline file        -> every bench reports ``no-baseline``; PASS.
  (The gate cannot block the very commit that introduces a suite; the
  baseline lands with it.)
* bench only in baseline  -> ``missing``; FAIL. A silently dropped bench
  is how perf regressions hide.
* bench only in current   -> ``new``; PASS (it has nothing to regress
  against — committing the refreshed baseline makes it binding).
* gated metric drifts outside its band -> ``regression``; FAIL.
* gated metric within band, or ungated metric (timing, context) -> PASS;
  ungated drift is still listed so the trajectory stays visible.

The *current* run's gates are authoritative: tolerances live in suite
code, and retightening a band in a PR must take effect in that same PR
even though the committed baseline still carries the old one.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.bench.schema import BenchResult, SuiteRun

# statuses that fail the gate
FAILING = ("regression", "missing")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One (bench, metric) comparison outcome."""

    bench: str
    metric: str
    status: str  # ok | drift | regression | missing | new | no-baseline
    #              | mode-mismatch (quick run vs full baseline or v.v.)
    baseline: float = float("nan")
    current: float = float("nan")
    band: float = float("nan")

    @property
    def failing(self) -> bool:
        return self.status in FAILING

    def render(self) -> str:
        if self.status in ("new", "missing", "no-baseline",
                           "mode-mismatch"):
            return f"  [{self.status:>14s}] {self.bench}"
        line = (f"  [{self.status:>10s}] {self.bench} :: {self.metric} "
                f"baseline={self.baseline:.6g} current={self.current:.6g}")
        if self.status != "drift":  # ungated metrics have no band
            line += f" band=±{self.band:.3g}"
        return line


@dataclasses.dataclass(frozen=True)
class CompareReport:
    suite: str
    findings: List[Finding]

    @property
    def regressions(self) -> List[Finding]:
        return [f for f in self.findings if f.failing]

    @property
    def ok(self) -> bool:
        return not self.regressions

    def render(self, verbose: bool = False) -> str:
        lines = [f"{self.suite}: "
                 f"{'OK' if self.ok else 'REGRESSION'} "
                 f"({len(self.findings)} checks, "
                 f"{len(self.regressions)} failing)"]
        for f in self.findings:
            if verbose or f.failing or f.status in ("new", "no-baseline",
                                                    "mode-mismatch",
                                                    "drift"):
                lines.append(f.render())
        return "\n".join(lines)


def compare_result(current: BenchResult,
                   baseline: BenchResult) -> List[Finding]:
    """Compare every gated metric of one bench against its baseline."""
    findings = []
    for metric, gate in current.gates.items():
        cur = current.derived.get(metric)
        base = baseline.derived.get(metric)
        if cur is None:
            # a gate naming a metric the suite never emitted is a suite bug
            findings.append(Finding(current.name, metric, "regression",
                                    band=gate.band(0.0)))
            continue
        if base is None:
            findings.append(Finding(current.name, metric, "new",
                                    current=cur))
            continue
        ok = gate.check(base, cur)
        findings.append(Finding(
            current.name, metric, "ok" if ok else "regression",
            baseline=base, current=cur, band=gate.band(base)))
    # ungated drift report (timing + uncovered derived): informational
    for metric in ("value",):
        findings.append(Finding(current.name, metric, "drift",
                                baseline=baseline.value,
                                current=current.value))
    return findings


def compare_runs(current: SuiteRun,
                 baseline: Optional[SuiteRun]) -> CompareReport:
    if baseline is None:
        return CompareReport(current.suite, [
            Finding(r.name, "*", "no-baseline") for r in current.results])
    if baseline.quick != current.quick:
        # quick and full runs use different shapes/step counts, so their
        # numbers are not comparable — gating would fail spuriously.
        # Report the mismatch (visible, non-failing) instead.
        return CompareReport(current.suite, [
            Finding(r.name, "*", "mode-mismatch")
            for r in current.results])
    cur_by: Dict[str, BenchResult] = current.by_name()
    base_by: Dict[str, BenchResult] = baseline.by_name()
    findings: List[Finding] = []
    for name in base_by:
        if name not in cur_by:
            findings.append(Finding(name, "*", "missing"))
    for name, cur in cur_by.items():
        if name not in base_by:
            findings.append(Finding(name, "*", "new", current=cur.value))
            continue
        findings.extend(compare_result(cur, base_by[name]))
    return CompareReport(current.suite, findings)
