"""Uniform model interface over the zoo families.

Every architecture config builds a ``Model`` whose members close over the
family's functional implementation. The launcher, trainer, serving engine
and dry-run only ever talk to this interface.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import cnn as cnn_mod
from repro.models import encdec as encdec_mod
from repro.models import hybrid as hybrid_mod
from repro.models import mamba as mamba_mod
from repro.models import transformer as tf_mod


@dataclasses.dataclass
class Model:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio | cnn
    cfg: Any
    init: Callable  # (key) -> (params, specs)
    loss: Callable  # (params, batch, ctx=None, taps=None) -> scalar
    forward: Callable  # (params, batch, ctx=None) -> outputs
    # serving (None for encoder-only / cnn)
    init_cache: Optional[Callable] = None  # (batch, max_len) -> cache
    cache_specs: Optional[Callable] = None
    decode_step: Optional[Callable] = None  # (params, cache, token, t)
    # (params, tokens (B,S), max_len, **extras) -> (logits, cache, t);
    # extras: patch_embeds (vlm), frames (encdec). The reference path for
    # serve.greedy_generate across every decoding family.
    prefill: Optional[Callable] = None
    # dry-run/meta
    param_count: int = 0
    active_param_count: int = 0
    sub_quadratic: bool = False  # may run long_500k
    has_decode: bool = True

    def train_batch_specs(self, batch: int, seq: int) -> Dict[str, Any]:
        """ShapeDtypeStructs for one training batch (dry-run inputs)."""
        raise NotImplementedError


def lm_model(cfg: tf_mod.LMConfig, family: str) -> Model:
    def loss(params, batch, ctx=None, taps=None):
        return tf_mod.loss_fn(params, cfg, batch, ctx=ctx, taps=taps)

    def forward(params, batch, ctx=None):
        return tf_mod.forward(params, cfg, batch["tokens"], ctx=ctx,
                              patch_embeds=batch.get("patch_embeds"))

    m = Model(
        name=cfg.name, family=family, cfg=cfg,
        init=lambda key: tf_mod.init_lm(key, cfg),
        loss=loss, forward=forward,
        init_cache=lambda b, s: tf_mod.init_cache(cfg, b, s),
        cache_specs=lambda b, s: tf_mod.cache_specs(cfg, b, s),
        decode_step=lambda p, c, tok, t, ctx=None: tf_mod.decode_step(
            p, cfg, c, tok, t, ctx=ctx),
        prefill=lambda p, tokens, max_len, patch_embeds=None: tf_mod.prefill(
            p, cfg, tokens, max_len, patch_embeds=patch_embeds),
        param_count=cfg.param_count,
        active_param_count=cfg.active_param_count,
        sub_quadratic=(cfg.window is not None),
    )

    def train_specs(batch: int, seq: int):
        specs = {
            "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        }
        if cfg.vlm_patches:
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (batch, cfg.vlm_patches, cfg.vit_dim), jnp.float32)
            # text positions shrink so total stays at seq
            specs["tokens"] = jax.ShapeDtypeStruct(
                (batch, seq - cfg.vlm_patches), jnp.int32)
            specs["labels"] = jax.ShapeDtypeStruct(
                (batch, seq - cfg.vlm_patches), jnp.int32)
        return specs

    m.train_batch_specs = train_specs
    return m


def ssm_model(cfg: mamba_mod.SSMLMConfig) -> Model:
    def loss(params, batch, ctx=None, taps=None):
        return mamba_mod.loss_fn(params, cfg, batch, ctx=ctx, taps=taps)

    def forward(params, batch, ctx=None):
        return mamba_mod.forward(params, cfg, batch["tokens"], ctx=ctx)

    m = Model(
        name=cfg.name, family="ssm", cfg=cfg,
        init=lambda key: mamba_mod.init_ssm_lm(key, cfg),
        loss=loss, forward=forward,
        init_cache=lambda b, s: mamba_mod.init_cache(cfg, b, s),
        cache_specs=lambda b, s: mamba_mod.cache_specs(cfg, b, s),
        decode_step=lambda p, c, tok, t, ctx=None: mamba_mod.decode_step(
            p, cfg, c, tok, t, ctx=ctx),
        prefill=lambda p, tokens, max_len: mamba_mod.prefill(
            p, cfg, tokens, max_len),
        param_count=cfg.param_count,
        active_param_count=cfg.active_param_count,
        sub_quadratic=True,
    )
    m.train_batch_specs = lambda b, s: {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    return m


def hybrid_model(cfg: hybrid_mod.HybridConfig) -> Model:
    def loss(params, batch, ctx=None, taps=None):
        return hybrid_mod.loss_fn(params, cfg, batch, ctx=ctx, taps=taps)

    def forward(params, batch, ctx=None):
        return hybrid_mod.forward(params, cfg, batch["tokens"], ctx=ctx)

    m = Model(
        name=cfg.name, family="hybrid", cfg=cfg,
        init=lambda key: hybrid_mod.init_hybrid_lm(key, cfg),
        loss=loss, forward=forward,
        init_cache=lambda b, s: hybrid_mod.init_cache(cfg, b, s),
        cache_specs=lambda b, s: hybrid_mod.cache_specs(cfg, b, s),
        decode_step=lambda p, c, tok, t, ctx=None: hybrid_mod.decode_step(
            p, cfg, c, tok, t, ctx=ctx),
        prefill=lambda p, tokens, max_len: hybrid_mod.prefill(
            p, cfg, tokens, max_len),
        param_count=cfg.param_count,
        active_param_count=cfg.active_param_count,
        sub_quadratic=True,
    )
    m.train_batch_specs = lambda b, s: {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    return m


def encdec_model(cfg: encdec_mod.EncDecConfig) -> Model:
    def loss(params, batch, ctx=None, taps=None):
        return encdec_mod.loss_fn(params, cfg, batch, ctx=ctx, taps=taps)

    def forward(params, batch, ctx=None):
        return encdec_mod.forward(params, cfg, batch, ctx=ctx)

    m = Model(
        name=cfg.name, family="audio", cfg=cfg,
        init=lambda key: encdec_mod.init_encdec(key, cfg),
        loss=loss, forward=forward,
        init_cache=lambda b, s: encdec_mod.init_cache(cfg, b, s),
        cache_specs=lambda b, s: encdec_mod.cache_specs(cfg, b, s),
        decode_step=lambda p, c, tok, t, ctx=None: encdec_mod.decode_step(
            p, cfg, c, tok, t, ctx=ctx),
        prefill=lambda p, tokens, max_len, frames=None: encdec_mod.prefill(
            p, cfg, tokens, max_len, frames),
        param_count=cfg.param_count,
        active_param_count=cfg.active_param_count,
        sub_quadratic=False,
    )
    m.train_batch_specs = lambda b, s: {
        "frames": jax.ShapeDtypeStruct((b, cfg.n_frames, cfg.d_model),
                                       jnp.float32),
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    return m


def cnn_model(cfg: cnn_mod.CNNConfig) -> Model:
    def loss(params, batch, ctx=None, taps=None):
        return cnn_mod.loss_fn(params, cfg, batch, ctx=ctx, taps=taps)

    def forward(params, batch, ctx=None):
        return cnn_mod.cnn_forward(params, cfg, batch["images"], ctx=ctx)

    m = Model(
        name=cfg.name, family="cnn", cfg=cfg,
        init=lambda key: cnn_mod.init_cnn(key, cfg),
        loss=loss, forward=forward, has_decode=False,
    )
    m.train_batch_specs = lambda b, s: {
        "images": jax.ShapeDtypeStruct(
            (b, cfg.img_size, cfg.img_size, cfg.in_channels), jnp.float32),
        "labels": jax.ShapeDtypeStruct((b,), jnp.int32),
    }
    return m
