"""The paper's own experiment models: MLPs, LeNets, AlexNet/VGG11 (CIFAR-
reduced, per paper §4), ResNet18. Used by the Table-1 / fig-4 / fig-5/6
reproduction benchmarks.

BatchNorm matters here: the paper's analysis hinges on BN *destroying* the
natural ReLU-derivative sparsity of the pre-activation gradients (Table 1:
LeNet5 baseline 2% sparse vs AlexNet 91%), which is exactly what dithered
backprop restores. So VGG11/ResNet18/LeNet5 carry BN, AlexNet/MLPs do not.

All dense/conv layers route through repro.core -> full dithered coverage;
every pre-activation carries a probe ``tap`` for Table-1 telemetry.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import conv2d, dense
from repro.core.probe import tap
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    arch: str  # mlp | lenet300100 | lenet5 | alexnet | vgg11 | resnet18
    n_classes: int = 10
    in_channels: int = 3
    img_size: int = 32
    hidden: Tuple[int, ...] = (500, 500)  # for mlp
    dtype: Any = jnp.float32

    @property
    def param_count(self) -> int:
        # exact count comes from the init tree; this is for interface parity
        return 0

    active_param_count = param_count


# ---------------------------------------------------------------------------
# batch norm (training mode, batch statistics; returns updated running stats)
# ---------------------------------------------------------------------------

def init_bn(ini: L.Init, name: str, c: int) -> None:
    ini.ones(f"{name}_g", (c,), (None,))
    ini.zeros(f"{name}_b", (c,), (None,))


def batchnorm(x, g, b, eps=1e-5):
    axes = tuple(range(x.ndim - 1))
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axes, keepdims=True)
    var = jnp.var(xf, axes, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * g + b).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP / LeNet-300-100 (fully-connected, the paper's meProp protocol models)
# ---------------------------------------------------------------------------

def init_mlp_model(key, cfg: CNNConfig):
    ini = L.Init(key, cfg.dtype)
    d_in = cfg.img_size * cfg.img_size * cfg.in_channels
    dims = (d_in,) + tuple(cfg.hidden) + (cfg.n_classes,)
    for i in range(len(dims) - 1):
        ini.normal(f"fc{i}_w", (dims[i], dims[i + 1]), (None, None),
                   fan_in=dims[i])
        ini.zeros(f"fc{i}_b", (dims[i + 1],), (None,))
    return ini.build()


def mlp_forward(params, cfg: CNNConfig, x, *, ctx=None, taps=None):
    B = x.shape[0]
    h = x.reshape(B, -1).astype(cfg.dtype)
    n = len(cfg.hidden) + 1
    for i in range(n):
        z = dense(h, params[f"fc{i}_w"], params[f"fc{i}_b"], ctx=ctx,
                  name=f"fc{i}")
        z = tap(z, taps, f"fc{i}")
        h = jax.nn.relu(z) if i < n - 1 else z
    return h


# ---------------------------------------------------------------------------
# LeNet5 (with BN, per the paper's density observation)
# ---------------------------------------------------------------------------

def init_lenet5(key, cfg: CNNConfig):
    ini = L.Init(key, cfg.dtype)
    ini.normal("c1_w", (5, 5, cfg.in_channels, 6), (None, None, None, None),
               fan_in=25 * cfg.in_channels)
    ini.zeros("c1_b", (6,), (None,))
    init_bn(ini, "bn1", 6)
    ini.normal("c2_w", (5, 5, 6, 16), (None, None, None, None), fan_in=150)
    ini.zeros("c2_b", (16,), (None,))
    init_bn(ini, "bn2", 16)
    d1 = _lenet5_flat(cfg.img_size) * 16
    ini.normal("fc1_w", (d1, 120), (None, None), fan_in=d1)
    ini.zeros("fc1_b", (120,), (None,))
    ini.normal("fc2_w", (120, 84), (None, None), fan_in=120)
    ini.zeros("fc2_b", (84,), (None,))
    ini.normal("fc3_w", (84, cfg.n_classes), (None, None), fan_in=84)
    ini.zeros("fc3_b", (cfg.n_classes,), (None,))
    return ini.build()


def _lenet5_flat(img: int) -> int:
    s = img
    s = s // 2  # conv SAME + pool
    s = s // 2
    return s * s


def lenet5_forward(params, cfg: CNNConfig, x, *, ctx=None, taps=None):
    h = x.astype(cfg.dtype)
    z = conv2d(h, params["c1_w"], params["c1_b"], padding="SAME", ctx=ctx,
               name="c1")
    z = tap(z, taps, "c1")
    h = jax.nn.relu(batchnorm(z, params["bn1_g"], params["bn1_b"]))
    h = _maxpool(h)
    z = conv2d(h, params["c2_w"], params["c2_b"], padding="SAME", ctx=ctx,
               name="c2")
    z = tap(z, taps, "c2")
    h = jax.nn.relu(batchnorm(z, params["bn2_g"], params["bn2_b"]))
    h = _maxpool(h)
    h = h.reshape(h.shape[0], -1)
    for i, nm in enumerate(["fc1", "fc2", "fc3"]):
        z = dense(h, params[f"{nm}_w"], params[f"{nm}_b"], ctx=ctx, name=nm)
        z = tap(z, taps, nm)
        h = jax.nn.relu(z) if i < 2 else z
    return h


def _maxpool(x, k=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID")


# ---------------------------------------------------------------------------
# AlexNet (CIFAR-reduced: FC hidden 2048, no BN) / VGG11 (CIFAR, BN, FC 512)
# ---------------------------------------------------------------------------

_ALEX_CONVS = [(64, 3, 2), (192, 3, 1), (384, 3, 1), (256, 3, 1), (256, 3, 1)]


def init_alexnet(key, cfg: CNNConfig):
    ini = L.Init(key, cfg.dtype)
    cin = cfg.in_channels
    for i, (cout, k, _) in enumerate(_ALEX_CONVS):
        ini.normal(f"c{i}_w", (k, k, cin, cout), (None,) * 4, fan_in=k * k * cin)
        ini.zeros(f"c{i}_b", (cout,), (None,))
        cin = cout
    d_flat = 256 * 2 * 2  # 32 -> /2 conv -> /2 pool -> /2 pool -> /2 pool
    for i, (din, dout) in enumerate(
            [(d_flat, 2048), (2048, 2048), (2048, cfg.n_classes)]):
        ini.normal(f"fc{i}_w", (din, dout), (None, None), fan_in=din)
        ini.zeros(f"fc{i}_b", (dout,), (None,))
    return ini.build()


def alexnet_forward(params, cfg: CNNConfig, x, *, ctx=None, taps=None):
    h = x.astype(cfg.dtype)
    pools = {0, 1, 4}
    for i, (cout, k, stride) in enumerate(_ALEX_CONVS):
        z = conv2d(h, params[f"c{i}_w"], params[f"c{i}_b"],
                   strides=(stride, stride), padding="SAME", ctx=ctx,
                   name=f"c{i}")
        z = tap(z, taps, f"c{i}")
        h = jax.nn.relu(z)
        if i in pools:
            h = _maxpool(h)
    h = h.reshape(h.shape[0], -1)
    for i in range(3):
        z = dense(h, params[f"fc{i}_w"], params[f"fc{i}_b"], ctx=ctx,
                  name=f"fc{i}")
        z = tap(z, taps, f"fc{i}")
        h = jax.nn.relu(z) if i < 2 else z
    return h


_VGG11 = [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"]


def init_vgg11(key, cfg: CNNConfig):
    ini = L.Init(key, cfg.dtype)
    cin, ci = cfg.in_channels, 0
    for v in _VGG11:
        if v == "M":
            continue
        ini.normal(f"c{ci}_w", (3, 3, cin, v), (None,) * 4, fan_in=9 * cin)
        ini.zeros(f"c{ci}_b", (v,), (None,))
        init_bn(ini, f"bn{ci}", v)
        cin, ci = v, ci + 1
    for i, (din, dout) in enumerate(
            [(512, 512), (512, 512), (512, cfg.n_classes)]):
        ini.normal(f"fc{i}_w", (din, dout), (None, None), fan_in=din)
        ini.zeros(f"fc{i}_b", (dout,), (None,))
    return ini.build()


def vgg11_forward(params, cfg: CNNConfig, x, *, ctx=None, taps=None):
    h = x.astype(cfg.dtype)
    ci = 0
    for v in _VGG11:
        if v == "M":
            h = _maxpool(h)
            continue
        z = conv2d(h, params[f"c{ci}_w"], params[f"c{ci}_b"], padding="SAME",
                   ctx=ctx, name=f"c{ci}")
        z = tap(z, taps, f"c{ci}")
        h = jax.nn.relu(batchnorm(z, params[f"bn{ci}_g"], params[f"bn{ci}_b"]))
        ci += 1
    h = h.reshape(h.shape[0], -1)
    for i in range(3):
        z = dense(h, params[f"fc{i}_w"], params[f"fc{i}_b"], ctx=ctx,
                  name=f"fc{i}")
        z = tap(z, taps, f"fc{i}")
        h = jax.nn.relu(z) if i < 2 else z
    return h


# ---------------------------------------------------------------------------
# ResNet18 (CIFAR stem, BN)
# ---------------------------------------------------------------------------

_RESNET18 = [(64, 2, 1), (128, 2, 2), (256, 2, 2), (512, 2, 2)]


def init_resnet18(key, cfg: CNNConfig):
    ini = L.Init(key, cfg.dtype)
    ini.normal("stem_w", (3, 3, cfg.in_channels, 64), (None,) * 4,
               fan_in=9 * cfg.in_channels)
    init_bn(ini, "stem_bn", 64)
    cin = 64
    bi = 0
    for cout, blocks, stride in _RESNET18:
        for b in range(blocks):
            s = stride if b == 0 else 1
            ini.normal(f"b{bi}_w1", (3, 3, cin, cout), (None,) * 4,
                       fan_in=9 * cin)
            init_bn(ini, f"b{bi}_bn1", cout)
            ini.normal(f"b{bi}_w2", (3, 3, cout, cout), (None,) * 4,
                       fan_in=9 * cout)
            init_bn(ini, f"b{bi}_bn2", cout)
            if s != 1 or cin != cout:
                ini.normal(f"b{bi}_wd", (1, 1, cin, cout), (None,) * 4,
                           fan_in=cin)
                init_bn(ini, f"b{bi}_bnd", cout)
            cin = cout
            bi += 1
    ini.normal("fc_w", (512, cfg.n_classes), (None, None), fan_in=512)
    ini.zeros("fc_b", (cfg.n_classes,), (None,))
    return ini.build()


def resnet18_forward(params, cfg: CNNConfig, x, *, ctx=None, taps=None):
    h = x.astype(cfg.dtype)
    z = conv2d(h, params["stem_w"], padding="SAME", ctx=ctx, name="stem")
    z = tap(z, taps, "stem")
    h = jax.nn.relu(batchnorm(z, params["stem_bn_g"], params["stem_bn_b"]))
    bi = 0
    for cout, blocks, stride in _RESNET18:
        for b in range(blocks):
            s = stride if b == 0 else 1
            idn = h
            z = conv2d(h, params[f"b{bi}_w1"], strides=(s, s), padding="SAME",
                       ctx=ctx, name=f"b{bi}_c1")
            z = tap(z, taps, f"b{bi}_c1")
            h2 = jax.nn.relu(batchnorm(z, params[f"b{bi}_bn1_g"],
                                       params[f"b{bi}_bn1_b"]))
            z = conv2d(h2, params[f"b{bi}_w2"], padding="SAME", ctx=ctx,
                       name=f"b{bi}_c2")
            z = tap(z, taps, f"b{bi}_c2")
            h2 = batchnorm(z, params[f"b{bi}_bn2_g"], params[f"b{bi}_bn2_b"])
            if f"b{bi}_wd" in params:
                idn = conv2d(idn, params[f"b{bi}_wd"], strides=(s, s),
                             padding="SAME", ctx=ctx, name=f"b{bi}_cd")
                idn = batchnorm(idn, params[f"b{bi}_bnd_g"],
                                params[f"b{bi}_bnd_b"])
            h = jax.nn.relu(h2 + idn)
            bi += 1
    h = jnp.mean(h, axis=(1, 2))
    return dense(h, params["fc_w"], params["fc_b"], ctx=ctx, name="fc")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_FORWARDS: Dict[str, Tuple[Callable, Callable]] = {
    "mlp": (init_mlp_model, mlp_forward),
    "lenet300100": (init_mlp_model, mlp_forward),
    "lenet5": (init_lenet5, lenet5_forward),
    "alexnet": (init_alexnet, alexnet_forward),
    "vgg11": (init_vgg11, vgg11_forward),
    "resnet18": (init_resnet18, resnet18_forward),
}


def init_cnn(key, cfg: CNNConfig):
    return _FORWARDS[cfg.arch][0](key, cfg)


def cnn_forward(params, cfg: CNNConfig, x, *, ctx=None, taps=None):
    return _FORWARDS[cfg.arch][1](params, cfg, x, ctx=ctx, taps=taps)


def loss_fn(params, cfg: CNNConfig, batch, *, ctx=None, taps=None):
    logits = cnn_forward(params, cfg, batch["images"], ctx=ctx, taps=taps)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def accuracy(params, cfg: CNNConfig, batch) -> jax.Array:
    logits = cnn_forward(params, cfg, batch["images"])
    return jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(
        jnp.float32))


def tap_shapes(cfg: CNNConfig, batch: int) -> Dict[str, Tuple[int, ...]]:
    """Pre-activation shapes for the probe (Table-1 telemetry)."""
    img, C = cfg.img_size, cfg.in_channels
    if cfg.arch in ("mlp", "lenet300100"):
        dims = tuple(cfg.hidden) + (cfg.n_classes,)
        return {f"fc{i}": (batch, d) for i, d in enumerate(dims)}
    if cfg.arch == "lenet5":
        s2 = img // 2
        return {
            "c1": (batch, img, img, 6), "c2": (batch, s2, s2, 16),
            "fc1": (batch, 120), "fc2": (batch, 84),
            "fc3": (batch, cfg.n_classes),
        }
    if cfg.arch == "alexnet":
        shapes = {}
        s = img
        pools = {0, 1, 4}
        for i, (cout, k, stride) in enumerate(_ALEX_CONVS):
            s = -(-s // stride)
            shapes[f"c{i}"] = (batch, s, s, cout)
            if i in pools:
                s //= 2
        shapes.update({"fc0": (batch, 2048), "fc1": (batch, 2048),
                       "fc2": (batch, cfg.n_classes)})
        return shapes
    if cfg.arch == "vgg11":
        shapes = {}
        s, ci = img, 0
        for v in _VGG11:
            if v == "M":
                s //= 2
                continue
            shapes[f"c{ci}"] = (batch, s, s, v)
            ci += 1
        shapes.update({"fc0": (batch, 512), "fc1": (batch, 512),
                       "fc2": (batch, cfg.n_classes)})
        return shapes
    if cfg.arch == "resnet18":
        shapes = {"stem": (batch, img, img, 64)}
        s = img
        bi = 0
        for cout, blocks, stride in _RESNET18:
            for b in range(blocks):
                if b == 0:
                    s = -(-s // stride)
                shapes[f"b{bi}_c1"] = (batch, s, s, cout)
                shapes[f"b{bi}_c2"] = (batch, s, s, cout)
                bi += 1
        return shapes
    raise ValueError(cfg.arch)
