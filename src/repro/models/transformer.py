"""Decoder-only LM transformer family.

Covers the dense LMs (qwen2.5, gemma, gemma3, minitron), the MoE LMs (dbrx,
moonshot) and the VLM backbone (internvl2: text decoder + projected visual
prefix). Layers are stacked and scanned (``lax.scan``) for train/prefill so
compile time is O(1) in depth; decode unrolls layers in Python because
windowed and global layers carry different cache shapes.

Every projection goes through ``repro.core.dense`` → dithered backprop
coverage is total (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dense
from repro.core.policy import DitherCtx
from repro.core.probe import tap
from repro.models import layers as L
from repro.models.moe import MoEConfig, init_moe, moe_layer
from repro.parallel.axes import shard_act


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    act: str = "swiglu"
    norm: str = "rmsnorm"
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    rope_scaling: float = 1.0
    window: Optional[int] = None  # sliding-window size for local layers
    window_pattern: int = 0  # N -> every (N+1)th layer is global; 0 -> all global
    softcap: Optional[float] = None
    embed_scale: bool = False  # gemma multiplies embeddings by sqrt(d)
    moe: Optional[MoEConfig] = None
    # VLM (internvl2): visual prefix fed as precomputed patch embeddings
    vlm_patches: int = 0
    vit_dim: int = 0
    dtype: Any = jnp.bfloat16
    remat: bool = True  # activation checkpointing per block in training
    scan_unroll: bool = False  # unroll layers (dry-run cost accounting)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def attn_cfg(self, window: Optional[int]) -> L.AttnConfig:
        return L.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.hd,
            qkv_bias=self.qkv_bias, rope_theta=self.rope_theta,
            rope_scaling=self.rope_scaling, window=window,
            softcap=self.softcap, causal=True,
        )

    def layer_is_local(self, i: int) -> bool:
        if self.window is None:
            return False
        if self.window_pattern == 0:
            return True
        return (i + 1) % (self.window_pattern + 1) != 0

    @property
    def param_count(self) -> int:
        """Total parameters (for roofline MODEL_FLOPS = 6 N D)."""
        d, f, V, hd = self.d_model, self.d_ff, self.vocab, self.hd
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.moe is None:
            nff = 3 if self.act in ("swiglu", "geglu") else 2
            mlp = nff * d * f
        else:
            m = self.moe
            mlp = 3 * m.n_experts * d * m.d_ff_expert + d * m.n_experts
            if m.n_shared:
                mlp += 3 * d * m.d_ff_expert * m.n_shared
        per_layer = attn + mlp + 2 * d
        emb = V * d * (1 if self.tie_embeddings else 2)
        proj = self.vlm_patches and (self.vit_dim * d + d * d) or 0
        return self.n_layers * per_layer + emb + d + proj

    @property
    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k + shared experts)."""
        if self.moe is None:
            return self.param_count
        m = self.moe
        d = self.d_model
        dense_total = self.param_count - self.n_layers * 3 * m.n_experts * d * m.d_ff_expert
        active_mlp = self.n_layers * 3 * m.top_k * d * m.d_ff_expert
        return dense_total + active_mlp


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key: jax.Array, cfg: LMConfig) -> Tuple[L.Params, L.Specs]:
    ini = L.Init(key, cfg.dtype)
    ka, km = jax.random.split(ini.next_key())
    attn_p, attn_s = L.init_attention(ka, cfg.attn_cfg(cfg.window), cfg.dtype)
    sub = L.Init(jax.random.PRNGKey(0), cfg.dtype)
    sub.params, sub.specs = attn_p, attn_s
    ini.sub("attn", sub)
    if cfg.moe is not None:
        moe_p, moe_s = init_moe(km, cfg.d_model, cfg.moe, cfg.dtype)
        msub = L.Init(jax.random.PRNGKey(0), cfg.dtype)
        msub.params, msub.specs = moe_p, moe_s
        ini.sub("moe", msub)
    else:
        mlp_p, mlp_s = L.init_mlp(
            km, L.MLPConfig(cfg.d_model, cfg.d_ff, cfg.act), cfg.dtype)
        msub = L.Init(jax.random.PRNGKey(0), cfg.dtype)
        msub.params, msub.specs = mlp_p, mlp_s
        ini.sub("mlp", msub)
    ini.ones("ln1", (cfg.d_model,), (None,))
    ini.ones("ln2", (cfg.d_model,), (None,))
    return ini.build()


def init_lm(key: jax.Array, cfg: LMConfig) -> Tuple[L.Params, L.Specs]:
    keys = jax.random.split(key, cfg.n_layers + 3)
    emb_p, emb_s = L.init_embedding(keys[0], cfg.vocab, cfg.d_model, cfg.dtype)
    blocks = [_init_block(keys[1 + i], cfg) for i in range(cfg.n_layers)]
    stacked_p, stacked_s = L.stack_layers(blocks)
    params: Dict[str, Any] = {"embed": emb_p, "layers": stacked_p}
    specs: Dict[str, Any] = {"embed": emb_s, "layers": stacked_s}
    ini = L.Init(keys[-2], cfg.dtype)
    ini.ones("ln_f", (cfg.d_model,), (None,))
    if not cfg.tie_embeddings:
        ini.normal("lm_head", (cfg.d_model, cfg.vocab), ("embed", "vocab"),
                   stddev=0.02)
    if cfg.vlm_patches:
        ini.normal("vit_proj1", (cfg.vit_dim, cfg.d_model), (None, "embed"),
                   fan_in=cfg.vit_dim)
        ini.normal("vit_proj2", (cfg.d_model, cfg.d_model), ("embed", "embed"),
                   fan_in=cfg.d_model)
    head_p, head_s = ini.build()
    params["head"] = head_p
    specs["head"] = head_s
    return params, specs


# ---------------------------------------------------------------------------
# forward (train / prefill) — scanned blocks
# ---------------------------------------------------------------------------

def _block(cfg: LMConfig, p: L.Params, x: jax.Array, positions: jax.Array,
           is_local, ctx: Optional[DitherCtx], layer_tag: str,
           taps=None) -> Tuple[jax.Array, jax.Array, Tuple]:
    """One transformer block. is_local: traced bool for the window pattern.

    The residual stream is pinned (batch-sharded, model-replicated) at the
    block edges and around each norm so XLA cannot re-shard the f32 norm
    interior across the model axis (it did: 2.6 GB/layer f32 all-reduces in
    the norm backward — §Perf qwen/It1)."""
    x = shard_act(x, ("batch", "seq", "act_embed"))
    h = (L.rms_norm(x, p["ln1"]) if cfg.norm == "rmsnorm"
         else L.rms_norm(x, p["ln1"]))
    h = shard_act(h, ("batch", "seq", "act_embed"))
    acfg_local = cfg.attn_cfg(cfg.window)
    acfg_full = cfg.attn_cfg(None)
    B, S = x.shape[0], x.shape[1]
    pos_b = jnp.broadcast_to(positions, (B, S))
    if cfg.window is not None and cfg.window_pattern > 0:
        m_local = L.attention_mask(pos_b, pos_b, acfg_local)
        m_full = L.attention_mask(pos_b, pos_b, acfg_full)
        mask = jnp.where(is_local, m_local, m_full)
        # masks are selected per layer; attention itself is window-agnostic
        attn_out, kv = _attend_with_mask(p["attn"], h, pos_b, acfg_full, mask,
                                         ctx, f"{layer_tag}.attn")
    else:
        acfg = acfg_local if cfg.window is not None else acfg_full
        mask = L.attention_mask(pos_b, pos_b, acfg)
        attn_out, kv = _attend_with_mask(p["attn"], h, pos_b, acfg, mask,
                                         ctx, f"{layer_tag}.attn")
    attn_out = tap(attn_out, taps, f"{layer_tag}.attn_out")
    x = shard_act(x + attn_out, ("batch", "seq", "act_embed"))
    h = shard_act(L.rms_norm(x, p["ln2"]), ("batch", "seq", "act_embed"))
    if cfg.moe is not None:
        y, aux = moe_layer(p["moe"], h, cfg.moe, ctx, name=f"{layer_tag}.moe")
    else:
        y = L.mlp(p["mlp"], h, L.MLPConfig(cfg.d_model, cfg.d_ff, cfg.act),
                  ctx=ctx, name=f"{layer_tag}.mlp")
        aux = jnp.zeros((), jnp.float32)
    y = tap(y, taps, f"{layer_tag}.mlp_out")
    return shard_act(x + y, ("batch", "seq", "act_embed")), aux, kv


def _attend_with_mask(p, h, pos_b, acfg, mask, ctx, name):
    """attention() with a precomputed mask (window selected by traced flag).

    q/k/v are constrained on the FUSED head dim (H*hd, KV*hd) *before* the
    head reshape — the fused dims divide any TP width even when the head
    counts do not (qwen: 40 heads on a 16-way model axis), which otherwise
    left XLA free to invent 8-way gathers of f32 q tensors (§Perf qwen/It2).
    """
    B, S = h.shape[0], h.shape[1]
    H, KV, hd = acfg.n_heads, acfg.n_kv_heads, acfg.head_dim
    q = dense(h, p["wq"], p.get("bq"), ctx=ctx, name=f"{name}.q")
    k = dense(h, p["wk"], p.get("bk"), ctx=ctx, name=f"{name}.k")
    v = dense(h, p["wv"], p.get("bv"), ctx=ctx, name=f"{name}.v")
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    # per-head constraints fall back to replication when H % tp != 0 (qwen:
    # 40 heads / 16) — constraining the FUSED dim instead was tried and
    # REFUTED (§Perf qwen/It2: the reshape from 320-wide shards to 128-wide
    # heads forced relayouts, coll_s 32.9 -> 47.8). XLA's own choice plus
    # the seq-parallel rules variant (qwen/It4) is what actually wins.
    q = shard_act(q, ("batch", "attn_seq", "act_heads", None))
    k = shard_act(k, ("batch", "attn_seq", "act_heads", None))
    v = shard_act(v, ("batch", "attn_seq", "act_heads", None))
    q = L.apply_rope(q, pos_b, acfg.rope_theta, acfg.rope_scaling)
    k = L.apply_rope(k, pos_b, acfg.rope_theta, acfg.rope_scaling)
    y = L._sdpa(q, k, v, mask, acfg.softcap)
    y = y.reshape(B, S, H * hd)
    y = shard_act(y, ("batch", "attn_seq", "act_heads"))
    y = dense(y, p["wo"], ctx=ctx, name=f"{name}.o")
    return shard_act(y, ("batch", "seq", "act_embed")), (k, v)


def _embed_inputs(params, cfg: LMConfig, tokens: jax.Array,
                  patch_embeds: Optional[jax.Array], ctx) -> jax.Array:
    x = L.embed(params["embed"], tokens)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if cfg.vlm_patches and patch_embeds is not None:
        pe = dense(patch_embeds.astype(x.dtype), params["head"]["vit_proj1"],
                   ctx=ctx, name="vit_proj1")
        pe = dense(jax.nn.gelu(pe), params["head"]["vit_proj2"], ctx=ctx,
                   name="vit_proj2")
        x = jnp.concatenate([pe, x], axis=1)  # visual prefix
    return x


def forward(params: L.Params, cfg: LMConfig, tokens: jax.Array, *,
            ctx: Optional[DitherCtx] = None,
            patch_embeds: Optional[jax.Array] = None,
            taps=None) -> Tuple[jax.Array, jax.Array]:
    """tokens (B, S) -> (logits (B, S_total, V), aux_loss)."""
    x = _embed_inputs(params, cfg, tokens, patch_embeds, ctx)
    B, S_tot = x.shape[0], x.shape[1]
    positions = jnp.arange(S_tot)[None, :]
    local_flags = jnp.asarray(
        [cfg.layer_is_local(i) for i in range(cfg.n_layers)])

    if taps is not None:
        # probe mode: unrolled layers so taps address individual layers
        aux_total = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            p_i = L.layer_slice(params["layers"], i)
            x, aux, _ = _block(cfg, p_i, x, positions, local_flags[i], ctx,
                               f"L{i}", taps=taps)
            aux_total = aux_total + aux
    else:
        def scan_body(carry, inp):
            x = carry
            p_i, is_local = inp
            x, aux, _ = _block(cfg, p_i, x, positions, is_local, ctx, "L")
            return x, aux

        body = scan_body
        if cfg.remat:
            body = jax.checkpoint(
                scan_body, policy=jax.checkpoint_policies.nothing_saveable)
        x, auxs = jax.lax.scan(body, x, (params["layers"], local_flags),
                               unroll=cfg.n_layers if cfg.scan_unroll else 1)
        aux_total = jnp.sum(auxs)

    x = L.rms_norm(x, params["head"]["ln_f"])
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x, ctx=ctx)
    else:
        logits = dense(x, params["head"]["lm_head"], ctx=ctx, name="lm_head")
        logits = shard_act(logits, ("batch", "seq", "act_vocab"))
    return logits, aux_total


def loss_fn(params: L.Params, cfg: LMConfig, batch: Dict[str, jax.Array], *,
            ctx: Optional[DitherCtx] = None, taps=None) -> jax.Array:
    """Next-token cross-entropy (+ MoE aux). batch: tokens, labels, [patches]."""
    logits, aux = forward(
        params, cfg, batch["tokens"], ctx=ctx,
        patch_embeds=batch.get("patch_embeds"), taps=taps)
    labels = batch["labels"]
    if cfg.vlm_patches and batch.get("patch_embeds") is not None:
        logits = logits[:, -labels.shape[1]:, :]  # loss on text positions only
    logits_f = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits_f, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = np.prod(labels.shape)
    return jnp.sum(nll) / denom + aux


# ---------------------------------------------------------------------------
# decode (serving) — unrolled layers, per-layer cache shapes
# ---------------------------------------------------------------------------

def cache_buf_len(cfg: LMConfig, i: int, max_len: int) -> int:
    if cfg.layer_is_local(i):
        return min(cfg.window, max_len)
    return max_len


def init_cache(cfg: LMConfig, batch: int, max_len: int,
               dtype=None) -> List[Tuple[jax.Array, jax.Array]]:
    dtype = dtype or cfg.dtype
    cache = []
    for i in range(cfg.n_layers):
        s_buf = cache_buf_len(cfg, i, max_len)
        kv = (jnp.zeros((batch, s_buf, cfg.n_kv_heads, cfg.hd), dtype),
              jnp.zeros((batch, s_buf, cfg.n_kv_heads, cfg.hd), dtype))
        cache.append(kv)
    return cache


def cache_specs(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    """ShapeDtypeStructs for the cache (dry-run input_specs)."""
    dtype = dtype or cfg.dtype
    return [
        (jax.ShapeDtypeStruct(
            (batch, cache_buf_len(cfg, i, max_len), cfg.n_kv_heads, cfg.hd),
            dtype),) * 2
        for i in range(cfg.n_layers)
    ]


def decode_step(params: L.Params, cfg: LMConfig, cache,
                token: jax.Array, t: jax.Array, *,
                ctx: Optional[DitherCtx] = None):
    """One decoding step. token: (B, 1) ids; t: scalar position shared by
    the batch, or per-slot (B,) positions (t < 0 = inactive slot, see
    ``L.attention``). Returns (logits (B, 1, V), new_cache)."""
    x = L.embed(params["embed"], token)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    positions = L.decode_positions(t)
    new_cache = []
    for i in range(cfg.n_layers):
        p = L.layer_slice(params["layers"], i)
        h = L.rms_norm(x, p["ln1"])
        acfg = cfg.attn_cfg(cfg.window if cfg.layer_is_local(i) else None)
        attn_out, kv = L.attention(
            p["attn"], h, positions, acfg, ctx=ctx, name=f"L{i}.attn",
            kv_cache=cache[i], cache_index=t)
        x = x + attn_out
        h = L.rms_norm(x, p["ln2"])
        if cfg.moe is not None:
            y, _ = moe_layer(p["moe"], h, cfg.moe, ctx, name=f"L{i}.moe")
        else:
            y = L.mlp(p["mlp"], h,
                      L.MLPConfig(cfg.d_model, cfg.d_ff, cfg.act),
                      ctx=ctx, name=f"L{i}.mlp")
        x = x + y
        new_cache.append(kv)
    x = L.rms_norm(x, params["head"]["ln_f"])
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x)
    else:
        logits = dense(x, params["head"]["lm_head"], name="lm_head")
    return logits, new_cache


def prefill(params: L.Params, cfg: LMConfig, tokens: jax.Array, max_len: int,
            patch_embeds: Optional[jax.Array] = None):
    """Run the full prompt, build a decode cache. Returns (logits, cache, t)."""
    x = _embed_inputs(params, cfg, tokens, patch_embeds, None)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)[None, :]
    cache = []
    for i in range(cfg.n_layers):
        p = L.layer_slice(params["layers"], i)
        h = L.rms_norm(x, p["ln1"])
        acfg = cfg.attn_cfg(cfg.window if cfg.layer_is_local(i) else None)
        pos_b = jnp.broadcast_to(positions, (B, S))
        mask = L.attention_mask(pos_b, pos_b, acfg)
        attn_out, (k, v) = _attend_with_mask(
            p["attn"], h, pos_b, acfg, mask, None, f"L{i}.attn")
        x = x + attn_out
        h = L.rms_norm(x, p["ln2"])
        if cfg.moe is not None:
            y, _ = moe_layer(p["moe"], h, cfg.moe, None, name=f"L{i}.moe")
        else:
            y = L.mlp(p["mlp"], h,
                      L.MLPConfig(cfg.d_model, cfg.d_ff, cfg.act),
                      name=f"L{i}.mlp")
        x = x + y
        # place prompt K/V into the decode buffer
        s_buf = cache_buf_len(cfg, i, max_len)
        K = jnp.zeros((B, s_buf, cfg.n_kv_heads, cfg.hd), cfg.dtype)
        V = jnp.zeros((B, s_buf, cfg.n_kv_heads, cfg.hd), cfg.dtype)
        if s_buf >= S:
            K = jax.lax.dynamic_update_slice(K, k.astype(K.dtype), (0, 0, 0, 0))
            V = jax.lax.dynamic_update_slice(V, v.astype(V.dtype), (0, 0, 0, 0))
        else:
            # window buffer: keep the last s_buf positions, ring-aligned
            tail_k = k[:, S - s_buf:, :, :].astype(K.dtype)
            tail_v = v[:, S - s_buf:, :, :].astype(V.dtype)
            # position p sits at slot p % s_buf (prefix_len = 0 here)
            roll = (S - s_buf) % s_buf
            K = jnp.roll(tail_k, shift=roll, axis=1)
            V = jnp.roll(tail_v, shift=roll, axis=1)
        cache.append((K, V))
    x = L.rms_norm(x, params["head"]["ln_f"])
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x)
    else:
        logits = dense(x, params["head"]["lm_head"], name="lm_head")
    return logits, cache, jnp.asarray(S - 1, jnp.int32)
