"""Shared neural-net layers: initializers, norms, rotary, attention, MLPs.

All weight-bearing contractions route through ``repro.core.dense`` /
``dithered_einsum`` so dithered backprop covers them uniformly (paper eq. 7-9
applied at every layer). Activations get logical-axis sharding constraints
via ``repro.parallel.axes.shard_act``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dense
from repro.core.policy import DitherCtx
from repro.parallel.axes import shard_act

Params = Dict[str, Any]
Specs = Dict[str, Any]


# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------

class Init:
    """Key-splitting parameter initializer that also builds the spec tree."""

    def __init__(self, key: jax.Array, dtype=jnp.float32):
        self._key = key
        self.dtype = dtype
        self.params: Params = {}
        self.specs: Specs = {}

    def next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def normal(self, name: str, shape, axes, *, stddev: Optional[float] = None,
               fan_in: Optional[int] = None, dtype=None) -> None:
        if stddev is None:
            fi = fan_in if fan_in is not None else shape[0]
            stddev = 1.0 / np.sqrt(max(fi, 1))
        self.params[name] = (
            jax.random.normal(self.next_key(), shape, jnp.float32) * stddev
        ).astype(dtype or self.dtype)
        self.specs[name] = tuple(axes)

    def zeros(self, name: str, shape, axes, dtype=None) -> None:
        self.params[name] = jnp.zeros(shape, dtype or self.dtype)
        self.specs[name] = tuple(axes)

    def ones(self, name: str, shape, axes, dtype=None) -> None:
        self.params[name] = jnp.ones(shape, dtype or self.dtype)
        self.specs[name] = tuple(axes)

    def const(self, name: str, value: jax.Array, axes) -> None:
        self.params[name] = value.astype(self.dtype)
        self.specs[name] = tuple(axes)

    def sub(self, name: str, init: "Init") -> None:
        self.params[name] = init.params
        self.specs[name] = init.specs

    def build(self) -> Tuple[Params, Specs]:
        return self.params, self.specs


def stack_layers(layer_trees):
    """Stack per-layer (params, specs) into scanned (L, ...) params."""
    params = jax.tree.map(lambda *xs: jnp.stack(xs), *[p for p, _ in layer_trees])
    specs = jax.tree.map(
        lambda s: (None,) + tuple(s),
        layer_trees[0][1],
        is_leaf=lambda s: isinstance(s, tuple) and all(
            a is None or isinstance(a, str) for a in s),
    )
    return params, specs


def layer_slice(stacked: Params, idx: int) -> Params:
    """Static per-layer view of scanned (L, ...) params (decode path)."""
    return jax.tree.map(lambda a: a[idx], stacked)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
             zero_centered: bool = False) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    s = (1.0 + scale.astype(jnp.float32)) if zero_centered else scale.astype(jnp.float32)
    return (y * s).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def act_fn(name: str):
    return {
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
        "tanh": jnp.tanh,
    }[name]


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0,
               scaling: float = 1.0) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S).

    theta <= 0 disables rotary (absolute/learned-position models, whisper)."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)
    ang = positions[..., None].astype(jnp.float32) / scaling * freqs  # (..., S, D/2)
    ang = ang[..., None, :]  # add head dim
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, optional sliding window, train/prefill + decode)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rope_scaling: float = 1.0
    window: Optional[int] = None  # sliding-window size (None = full)
    softcap: Optional[float] = None
    prefix_len: int = 0  # meta/visual tokens always attendable
    causal: bool = True


def init_attention(key: jax.Array, cfg: AttnConfig, dtype) -> Tuple[Params, Specs]:
    ini = Init(key, dtype)
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ini.normal("wq", (d, H * hd), ("embed", "q_heads"), fan_in=d)
    ini.normal("wk", (d, KV * hd), ("embed", "kv_heads"), fan_in=d)
    ini.normal("wv", (d, KV * hd), ("embed", "kv_heads"), fan_in=d)
    ini.normal("wo", (H * hd, d), ("q_heads", "embed"), fan_in=H * hd)
    if cfg.qkv_bias:
        ini.zeros("bq", (H * hd,), ("q_heads",))
        ini.zeros("bk", (KV * hd,), ("kv_heads",))
        ini.zeros("bv", (KV * hd,), ("kv_heads",))
    return ini.build()


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def attention_mask(q_pos: jax.Array, k_pos: jax.Array, cfg: AttnConfig,
                   valid_k: Optional[jax.Array] = None) -> jax.Array:
    """(..., Sq, Sk) boolean mask. q_pos/k_pos are position indices."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if cfg.causal:
        m = kp <= qp
    else:
        m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if cfg.window is not None:
        in_window = kp > (qp - cfg.window)
        if cfg.prefix_len > 0:
            in_window = in_window | (kp < cfg.prefix_len)
        m = m & in_window
    if valid_k is not None:
        m = m & valid_k[..., None, :]
    return m


def _sdpa(q, k, v, mask, softcap=None):
    """Grouped-query SDPA. q: (B,Sq,H,D); k/v: (B,Sk,KV,D) with KV | H.

    The query heads are grouped against their KV head directly (einsum over
    a (KV, G) split) — K/V are NEVER materialized at H heads, which matters
    enormously for GQA decode (a 40:8 model would otherwise touch 5x the
    cache bytes). mask: (B,Sq,Sk) or (Sq,Sk).
    """
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D)
    scale = 1.0 / np.sqrt(D)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None]
        logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, D)


def decode_positions(t: jax.Array) -> jax.Array:
    """Rope/mask positions for one decode step from the cache index ``t``.

    Scalar t (shared position) -> (1,), broadcast over the batch; vector t
    (per-slot positions, (B,)) -> (B, 1). The trailing unit axis is what
    keeps ``apply_rope`` broadcasting against (B, 1, H, D) tokens — a bare
    (B,) vector would broadcast to (B, B, ...).
    """
    t = jnp.asarray(t, jnp.int32)
    if t.ndim == 0:
        return t[None]
    return t[:, None]


def ring_write_slot(t: jax.Array, s_buf: int, prefix: int) -> jax.Array:
    """Buffer slot for absolute position t. Slots [0, prefix) are pinned to
    the prefix (meta/visual tokens); the rest is a ring of size s_buf-prefix."""
    ring = s_buf - prefix
    return jnp.where(t < prefix, t, prefix + (t - prefix) % ring)


def ring_slot_positions(t: jax.Array, s_buf: int, prefix: int):
    """(abs_pos, valid) per slot, given the newest written position is t."""
    slot = jnp.arange(s_buf)
    ring = s_buf - prefix
    rel = prefix + (t - prefix) % ring  # slot just written (when t >= prefix)
    abs_ring = t - ((rel - slot) % ring)
    in_prefix = slot < prefix
    pos = jnp.where(in_prefix, slot, abs_ring)
    valid = jnp.where(
        in_prefix, slot <= t, (abs_ring >= prefix) & (abs_ring <= t)
    )
    return pos, valid


def attention(params: Params, x: jax.Array, positions: jax.Array,
              cfg: AttnConfig, *, ctx: Optional[DitherCtx] = None,
              name: str = "attn",
              kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
              cache_index: Optional[jax.Array] = None,
              x_kv: Optional[jax.Array] = None):
    """Attention layer (GQA; optional sliding window; optional cross-attn).

    Train/prefill: kv_cache None -> self-attend over x. Returns (y, (k, v)).
    Decode: kv_cache=(K, V) with buffer layout (B, S_buf, KV, hd); x is the
    new token (B, 1, d); cache_index is the scalar absolute position t.
    Windowed layers use a ring buffer (S_buf = window + prefix_len).
    Cross-attention: pass x_kv (encoder states), kv_cache=None.
    """
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = x if x_kv is None else x_kv

    q = dense(x, params["wq"], params.get("bq"), ctx=ctx, name=f"{name}.q")
    k = dense(src, params["wk"], params.get("bk"), ctx=ctx, name=f"{name}.k")
    v = dense(src, params["wv"], params.get("bv"), ctx=ctx, name=f"{name}.v")
    q = _split_heads(q, H, hd)
    k = _split_heads(k, KV, hd)
    v = _split_heads(v, KV, hd)
    q = shard_act(q, ("batch", "seq", "act_heads", None))
    k = shard_act(k, ("batch", "seq", "act_heads", None))
    v = shard_act(v, ("batch", "seq", "act_heads", None))

    if x_kv is None:  # rope only for self-attention
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_scaling)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_scaling)

    if kv_cache is not None:
        t = jnp.asarray(cache_index, jnp.int32)
        if hasattr(kv_cache, "update_and_view"):
            # paged cache (repro.serve.kvcache.PagedKV): the cache object
            # owns write/seal/decode; t is per-slot (B,), t < 0 = inactive
            K, V, k_pos_b, valid_b, out_cache = kv_cache.update_and_view(
                k, v, t)
            q_pos_b = t[:, None]
            mask = attention_mask(q_pos_b, k_pos_b, cfg, valid_k=valid_b)
        elif t.ndim == 0:
            K, V = kv_cache  # (B, S_buf, KV, hd)
            s_buf = K.shape[1]
            write_at = ring_write_slot(t, s_buf, cfg.prefix_len)
            K = jax.lax.dynamic_update_slice(
                K, k.astype(K.dtype), (0, write_at, 0, 0))
            V = jax.lax.dynamic_update_slice(
                V, v.astype(V.dtype), (0, write_at, 0, 0))
            k_pos, valid = ring_slot_positions(t, s_buf, cfg.prefix_len)
            k_pos_b = jnp.broadcast_to(k_pos, (B, s_buf))
            valid_b = jnp.broadcast_to(valid, (B, s_buf))
            q_pos_b = jnp.broadcast_to(t, (B, 1))
            mask = attention_mask(q_pos_b, k_pos_b, cfg, valid_k=valid_b)
            out_cache = (K, V)
        else:
            # per-slot positions t (B,); t < 0 marks an inactive slot — its
            # write parks out of bounds (scatter drop) and its mask is all
            # invalid (softmax goes uniform; callers discard the output)
            K, V = kv_cache
            s_buf = K.shape[1]
            rows = jnp.arange(B)
            write_at = ring_write_slot(t, s_buf, cfg.prefix_len)
            write_at = jnp.where(t >= 0, write_at, s_buf)  # park inactive
            K = K.at[rows, write_at].set(k[:, 0].astype(K.dtype),
                                         mode="drop")
            V = V.at[rows, write_at].set(v[:, 0].astype(V.dtype),
                                         mode="drop")
            k_pos_b, valid_b = jax.vmap(
                lambda tt: ring_slot_positions(tt, s_buf, cfg.prefix_len))(t)
            valid_b = valid_b & (t >= 0)[:, None]
            q_pos_b = t[:, None]
            mask = attention_mask(q_pos_b, k_pos_b, cfg, valid_k=valid_b)
            out_cache = (K, V)
        y = _sdpa(q, K.astype(q.dtype), V.astype(q.dtype), mask, cfg.softcap)
    else:
        pos_b = jnp.broadcast_to(positions, (B,) + positions.shape[-1:])
        if x_kv is None:
            mask = attention_mask(pos_b, pos_b, cfg)
        else:
            mask = None  # cross-attention: attend over all encoder states
        y = _sdpa(q, k, v, mask, cfg.softcap)
        out_cache = (k, v)

    y = y.reshape(B, y.shape[1], H * hd)
    y = shard_act(y, ("batch", "seq", "act_heads"))
    y = dense(y, params["wo"], ctx=ctx, name=f"{name}.o")
    y = shard_act(y, ("batch", "seq", "act_embed"))
    return y, out_cache


def cross_attention_cached(params: Params, x: jax.Array,
                           enc_kv: Tuple[jax.Array, jax.Array],
                           cfg: AttnConfig, *, ctx=None, name="xattn"):
    """Decode-time cross-attention over precomputed encoder K/V (no write)."""
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(dense(x, params["wq"], params.get("bq"), ctx=ctx,
                           name=f"{name}.q"), H, hd)
    K, V = enc_kv
    y = _sdpa(q, K.astype(q.dtype), V.astype(q.dtype), None, cfg.softcap)
    y = y.reshape(B, y.shape[1], H * hd)
    return dense(y, params["wo"], ctx=ctx, name=f"{name}.o")


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLPConfig:
    d_model: int
    d_ff: int
    kind: str = "swiglu"  # swiglu | geglu | relu2 | gelu


def init_mlp(key: jax.Array, cfg: MLPConfig, dtype) -> Tuple[Params, Specs]:
    ini = Init(key, dtype)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.kind in ("swiglu", "geglu"):
        ini.normal("w_gate", (d, f), ("embed", "mlp"), fan_in=d)
        ini.normal("w_up", (d, f), ("embed", "mlp"), fan_in=d)
    else:
        ini.normal("w_up", (d, f), ("embed", "mlp"), fan_in=d)
    ini.normal("w_down", (f, d), ("mlp", "embed"), fan_in=f)
    return ini.build()


def mlp(params: Params, x: jax.Array, cfg: MLPConfig, *,
        ctx: Optional[DitherCtx] = None, name: str = "mlp") -> jax.Array:
    if cfg.kind in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.kind == "swiglu" else jax.nn.gelu
        g = dense(x, params["w_gate"], ctx=ctx, name=f"{name}.gate")
        u = dense(x, params["w_up"], ctx=ctx, name=f"{name}.up")
        h = act(g) * u
    else:
        h = act_fn(cfg.kind)(dense(x, params["w_up"], ctx=ctx, name=f"{name}.up"))
    h = shard_act(h, ("batch", "seq", "act_mlp"))
    y = dense(h, params["w_down"], ctx=ctx, name=f"{name}.down")
    return shard_act(y, ("batch", "seq", "act_embed"))


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def init_embedding(key: jax.Array, vocab: int, d_model: int, dtype
                   ) -> Tuple[Params, Specs]:
    ini = Init(key, dtype)
    ini.normal("table", (vocab, d_model), ("vocab", "embed"), stddev=0.02)
    return ini.build()


def embed(params: Params, tokens: jax.Array) -> jax.Array:
    y = params["table"][tokens]
    return shard_act(y, ("batch", "seq", "act_embed"))


def unembed(params: Params, x: jax.Array, *, ctx: Optional[DitherCtx] = None,
            name: str = "lm_head", table: Optional[jax.Array] = None) -> jax.Array:
    w = (table if table is not None else params["table"]).T
    logits = dense(x, w.astype(x.dtype), ctx=ctx, name=name)
    return shard_act(logits, ("batch", "seq", "act_vocab"))
