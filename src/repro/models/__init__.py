"""Model zoo: LM transformers (dense/MoE/VLM), SSM, hybrid, enc-dec, CNNs."""
from repro.models.api import (
    Model, lm_model, ssm_model, hybrid_model, encdec_model, cnn_model,
)

__all__ = ["Model", "lm_model", "ssm_model", "hybrid_model", "encdec_model",
           "cnn_model"]
