"""Hymba-style hybrid-head model (arXiv:2411.13676): every layer runs an
attention branch and a Mamba/SSM branch *in parallel* on the same input,
normalizes each branch output and averages them. Sliding-window attention on
all but 3 layers (first / middle / last are global), plus learnable meta
tokens prepended to the sequence.

Sub-quadratic by construction (window + O(1) SSM state) → carries long_500k.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import DitherCtx
from repro.models import layers as L
from repro.models import mamba as M
from repro.models.transformer import _attend_with_mask


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 64
    d_state: int = 16
    expand: int = 2
    window: int = 1024
    n_meta_tokens: int = 128
    rope_theta: float = 10_000.0
    act: str = "swiglu"
    dtype: Any = jnp.bfloat16
    tie_embeddings: bool = True
    remat: bool = True
    scan_unroll: bool = False

    @property
    def ssm(self) -> M.SSMConfig:
        return M.SSMConfig(
            d_model=self.d_model, d_inner=self.expand * self.d_model,
            head_dim=self.head_dim, d_state=self.d_state)

    def global_layers(self) -> Tuple[int, ...]:
        return (0, self.n_layers // 2, self.n_layers - 1)

    def layer_is_local(self, i: int) -> bool:
        return i not in self.global_layers()

    def attn_cfg(self, window, prefix_len=0) -> L.AttnConfig:
        return L.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
            rope_theta=self.rope_theta, window=window,
            prefix_len=prefix_len, causal=True)

    @property
    def param_count(self) -> int:
        d, hd = self.d_model, self.head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        c = self.ssm
        ssm = (d * c.d_in_proj + c.d_conv * c.conv_dim + c.d_inner * d +
               3 * c.n_heads + 2 * c.d_inner)
        nff = 3 if self.act in ("swiglu", "geglu") else 2
        per_layer = attn + ssm + nff * d * self.d_ff + 4 * d
        return (self.n_layers * per_layer + self.vocab * d + d +
                self.n_meta_tokens * d)

    @property
    def active_param_count(self) -> int:
        return self.param_count


def _init_block(key: jax.Array, cfg: HybridConfig) -> Tuple[L.Params, L.Specs]:
    ini = L.Init(key, cfg.dtype)
    attn_p, attn_s = L.init_attention(
        ini.next_key(), cfg.attn_cfg(cfg.window), cfg.dtype)
    sub = L.Init(jax.random.PRNGKey(0), cfg.dtype)
    sub.params, sub.specs = attn_p, attn_s
    ini.sub("attn", sub)
    mix_p, mix_s = M.init_mamba_mixer(ini.next_key(), cfg.ssm, cfg.dtype)
    sub = L.Init(jax.random.PRNGKey(0), cfg.dtype)
    sub.params, sub.specs = mix_p, mix_s
    ini.sub("mixer", sub)
    mlp_p, mlp_s = L.init_mlp(
        ini.next_key(), L.MLPConfig(cfg.d_model, cfg.d_ff, cfg.act), cfg.dtype)
    sub = L.Init(jax.random.PRNGKey(0), cfg.dtype)
    sub.params, sub.specs = mlp_p, mlp_s
    ini.sub("mlp", sub)
    ini.ones("ln1", (cfg.d_model,), (None,))
    ini.ones("ln2", (cfg.d_model,), (None,))
    # per-branch output norms + learnable mixing scales (Hymba beta)
    ini.ones("norm_attn", (cfg.d_model,), (None,))
    ini.ones("norm_ssm", (cfg.d_model,), (None,))
    return ini.build()


def init_hybrid_lm(key: jax.Array, cfg: HybridConfig) -> Tuple[L.Params, L.Specs]:
    keys = jax.random.split(key, cfg.n_layers + 3)
    emb_p, emb_s = L.init_embedding(keys[0], cfg.vocab, cfg.d_model, cfg.dtype)
    blocks = [_init_block(keys[1 + i], cfg) for i in range(cfg.n_layers)]
    stacked_p, stacked_s = L.stack_layers(blocks)
    ini = L.Init(keys[-1], cfg.dtype)
    ini.ones("ln_f", (cfg.d_model,), (None,))
    ini.normal("meta_tokens", (cfg.n_meta_tokens, cfg.d_model),
               (None, "embed"), stddev=0.02)
    head_p, head_s = ini.build()
    return ({"embed": emb_p, "layers": stacked_p, "head": head_p},
            {"embed": emb_s, "layers": stacked_s, "head": head_s})


def _block(cfg: HybridConfig, p, x, pos_b, is_local, ctx, tag):
    h = L.rms_norm(x, p["ln1"])
    acfg_local = cfg.attn_cfg(cfg.window, cfg.n_meta_tokens)
    acfg_full = cfg.attn_cfg(None)
    m_local = L.attention_mask(pos_b, pos_b, acfg_local)
    m_full = L.attention_mask(pos_b, pos_b, acfg_full)
    mask = jnp.where(is_local, m_local, m_full)
    attn_y, _ = _attend_with_mask(p["attn"], h, pos_b, acfg_full, mask, ctx,
                                  f"{tag}.attn")
    ssm_y = M.mamba_mixer(p["mixer"], h, cfg.ssm, ctx=ctx, name=f"{tag}.ssm")
    mixed = 0.5 * (L.rms_norm(attn_y, p["norm_attn"]) +
                   L.rms_norm(ssm_y, p["norm_ssm"]))
    x = x + mixed
    h = L.rms_norm(x, p["ln2"])
    y = L.mlp(p["mlp"], h, L.MLPConfig(cfg.d_model, cfg.d_ff, cfg.act),
              ctx=ctx, name=f"{tag}.mlp")
    return x + y


def forward(params, cfg: HybridConfig, tokens: jax.Array, *,
            ctx: Optional[DitherCtx] = None, taps=None):
    x = L.embed(params["embed"], tokens)
    B = x.shape[0]
    meta = jnp.broadcast_to(
        params["head"]["meta_tokens"][None],
        (B, cfg.n_meta_tokens, cfg.d_model)).astype(x.dtype)
    x = jnp.concatenate([meta, x], axis=1)
    S_tot = x.shape[1]
    pos_b = jnp.broadcast_to(jnp.arange(S_tot)[None, :], (B, S_tot))
    local_flags = jnp.asarray(
        [cfg.layer_is_local(i) for i in range(cfg.n_layers)])

    def body(x, inp):
        p, is_local = inp
        return _block(cfg, p, x, pos_b, is_local, ctx, "L"), None

    f = body
    if cfg.remat:
        f = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(f, x, (params["layers"], local_flags),
                        unroll=cfg.n_layers if cfg.scan_unroll else 1)
    x = x[:, cfg.n_meta_tokens:, :]  # drop meta positions
    x = L.rms_norm(x, params["head"]["ln_f"])
    logits = L.unembed(params["embed"], x, ctx=ctx)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: HybridConfig, batch, *, ctx=None, taps=None):
    logits, _ = forward(params, cfg, batch["tokens"], ctx=ctx, taps=taps)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def cache_buf_len(cfg: HybridConfig, i: int, max_len: int) -> int:
    total = max_len + cfg.n_meta_tokens
    if cfg.layer_is_local(i):
        return min(cfg.window + cfg.n_meta_tokens, total)
    return total


def init_cache(cfg: HybridConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    cache = []
    for i in range(cfg.n_layers):
        s_buf = cache_buf_len(cfg, i, max_len)
        cache.append({
            "kv": (jnp.zeros((batch, s_buf, cfg.n_kv_heads, cfg.head_dim), dtype),
                   jnp.zeros((batch, s_buf, cfg.n_kv_heads, cfg.head_dim), dtype)),
            "ssm": M.MambaCache.init(cfg.ssm, batch, dtype),
        })
    return cache


def cache_specs(cfg: HybridConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    out = []
    for i in range(cfg.n_layers):
        s_buf = cache_buf_len(cfg, i, max_len)
        kv = jax.ShapeDtypeStruct(
            (batch, s_buf, cfg.n_kv_heads, cfg.head_dim), dtype)
        out.append({"kv": (kv, kv),
                    "ssm": M.MambaCache.specs(cfg.ssm, batch, dtype)})
    return out


def decode_step_x(params, cfg: HybridConfig, cache, x: jax.Array,
                  t: jax.Array, *, ctx=None):
    """Embedding-level decode step: x (B, 1, d_model) already embedded.

    Shared by token decode, the meta-token cache bootstrap, and prefill.
    Returns (hidden (B, 1, d_model), new_cache) — the caller norms/unembeds.
    """
    positions = L.decode_positions(t)
    new_cache = []
    for i in range(cfg.n_layers):
        p = L.layer_slice(params["layers"], i)
        h = L.rms_norm(x, p["ln1"])
        local = cfg.layer_is_local(i)
        acfg = cfg.attn_cfg(cfg.window if local else None,
                            cfg.n_meta_tokens if local else 0)
        attn_y, kv = L.attention(
            p["attn"], h, positions, acfg, ctx=ctx, name=f"L{i}.attn",
            kv_cache=cache[i]["kv"], cache_index=t)
        ssm_y, ssm_state = M.mamba_decode_step(
            p["mixer"], h, cache[i]["ssm"], cfg.ssm, name=f"L{i}.ssm")
        mixed = 0.5 * (L.rms_norm(attn_y, p["norm_attn"]) +
                       L.rms_norm(ssm_y, p["norm_ssm"]))
        x = x + mixed
        h = L.rms_norm(x, p["ln2"])
        y = L.mlp(p["mlp"], h, L.MLPConfig(cfg.d_model, cfg.d_ff, cfg.act),
                  name=f"L{i}.mlp")
        x = x + y
        new_cache.append({"kv": kv, "ssm": ssm_state})
    return x, new_cache


def decode_step(params, cfg: HybridConfig, cache, token: jax.Array,
                t: jax.Array, *, ctx=None):
    """t is the position over (meta + text); callers start at n_meta_tokens."""
    x = L.embed(params["embed"], token)
    x, new_cache = decode_step_x(params, cfg, cache, x, t, ctx=ctx)
    x = L.rms_norm(x, params["head"]["ln_f"])
    logits = L.unembed(params["embed"], x)
    return logits, new_cache


def bootstrap_cache(params, cfg: HybridConfig, batch: int, max_len: int):
    """Fresh decode cache with the learnable meta tokens replayed in.

    Decode starts at position ``cfg.n_meta_tokens``; the meta prefix is fed
    through the same decode step (embedding-level — meta tokens have no
    vocabulary ids) so windowed layers pin it into their prefix slots.
    """
    cache = init_cache(cfg, batch, max_len)
    meta = params["head"]["meta_tokens"].astype(cfg.dtype)  # (M, d)

    def body(c, i):
        x = jnp.broadcast_to(meta[i][None, None], (batch, 1, cfg.d_model))
        _, c = decode_step_x(params, cfg, c, x, i)
        return c, None

    cache, _ = jax.lax.scan(body, cache, jnp.arange(cfg.n_meta_tokens))
    return cache


def prefill(params, cfg: HybridConfig, tokens: jax.Array, max_len: int):
    """tokens (B, S) -> (logits (B, S, V), cache, t) via the decode path.

    t is the position of the last prompt token over (meta + text), i.e.
    ``n_meta_tokens + S - 1`` — pass ``t + 1`` to the next decode step.
    """
    B, S = tokens.shape
    cache = bootstrap_cache(params, cfg, B, max_len)

    def body(c, inp):
        tok, pos = inp
        logits, c = decode_step(params, cfg, c, tok[:, None], pos)
        return c, logits[:, 0]

    cache, logits_seq = jax.lax.scan(
        body, cache, (tokens.T, cfg.n_meta_tokens + jnp.arange(S)))
    return (jnp.moveaxis(logits_seq, 0, 1), cache,
            jnp.asarray(cfg.n_meta_tokens + S - 1, jnp.int32))
