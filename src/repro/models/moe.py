"""Mixture-of-Experts layer (dbrx-style 16e top-4, moonshot 64e top-6 + shared).

Two dispatch strategies:

* ``einsum`` — reference dense-dispatch with (T, E, C) one-hot masks. Exact,
  simple, O(T*E*C) memory: used for smoke tests / single-host examples and
  as the oracle the a2a path is tested against.
* ``a2a``   — production expert parallelism under ``jax.shard_map``: tokens
  are sharded over the data axes, experts over the "model" axis; dispatch is
  two ``all_to_all`` hops with fixed per-expert capacity (token dropping).
  This is the collective pattern real MoE systems (DeepSeek/Megablocks) use
  and is what the multi-pod dry-run exercises for the MoE archs.

Dithered backprop applies *inside* the expert FFN einsums (and the router),
so the paper's technique covers the dominant MoE FLOPs too.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import dense, dithered_einsum
from repro.core.policy import DitherCtx
from repro.models.layers import Init, Params, Specs, act_fn
from repro.parallel import axes as axlib


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0  # DeepSeek-style always-on shared experts
    capacity_factor: float = 1.25
    dispatch: str = "auto"  # auto | einsum | a2a
    aux_loss_coef: float = 0.01
    act: str = "swiglu"
    # int8-quantize the a2a payloads (absmax per shard, fwd AND bwd hops via
    # custom_vjp) — halves dispatch wire bytes; the paper's own "gradients
    # fit in 8 bits" observation applied to the token/grad traffic.
    a2a_int8: bool = False


def init_moe(key: jax.Array, d_model: int, cfg: MoEConfig, dtype
             ) -> Tuple[Params, Specs]:
    ini = Init(key, dtype)
    E, f = cfg.n_experts, cfg.d_ff_expert
    ini.normal("router", (d_model, E), ("embed", None), fan_in=d_model)
    ini.normal("w_gate", (E, d_model, f), ("expert", "embed", "expert_mlp"),
               fan_in=d_model)
    ini.normal("w_up", (E, d_model, f), ("expert", "embed", "expert_mlp"),
               fan_in=d_model)
    ini.normal("w_down", (E, f, d_model), ("expert", "expert_mlp", "embed"),
               fan_in=f)
    if cfg.n_shared:
        fs = cfg.d_ff_expert * cfg.n_shared
        ini.normal("ws_gate", (d_model, fs), ("embed", "mlp"), fan_in=d_model)
        ini.normal("ws_up", (d_model, fs), ("embed", "mlp"), fan_in=d_model)
        ini.normal("ws_down", (fs, d_model), ("mlp", "embed"), fan_in=fs)
    return ini.build()


def _routing(params, x2d, cfg: MoEConfig, ctx):
    """Router top-k: returns (choices (T,k), probs (T,k), aux_loss)."""
    logits = dense(x2d, params["router"], ctx=ctx, name="moe.router")
    logits = logits.astype(jnp.float32)
    probs_full = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs_full, cfg.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize
    # switch-style load-balance aux loss
    T, E = logits.shape
    density = jnp.mean(
        jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), axis=0)
    density_proxy = jnp.mean(probs_full, axis=0)
    aux = jnp.sum(density * density_proxy) * E * cfg.aux_loss_coef
    return top_i, top_p, aux


def _expert_ffn(w_gate, w_up, w_down, xe, cfg: MoEConfig, ctx,
                name: str) -> jax.Array:
    """Batched per-expert FFN. xe: (E, C, d) -> (E, C, d)."""
    act = act_fn("silu" if cfg.act == "swiglu" else "gelu")
    g = dithered_einsum("ecd,edf->ecf", xe, w_gate, ctx=ctx, name=f"{name}.gate")
    u = dithered_einsum("ecd,edf->ecf", xe, w_up, ctx=ctx, name=f"{name}.up")
    h = act(g) * u
    return dithered_einsum("ecf,efd->ecd", h, w_down, ctx=ctx, name=f"{name}.down")


def _shared_ffn(params, x2d, cfg: MoEConfig, ctx, name: str) -> jax.Array:
    act = act_fn("silu" if cfg.act == "swiglu" else "gelu")
    g = dense(x2d, params["ws_gate"], ctx=ctx, name=f"{name}.sgate")
    u = dense(x2d, params["ws_up"], ctx=ctx, name=f"{name}.sup")
    return dense(act(g) * u, params["ws_down"], ctx=ctx, name=f"{name}.sdown")


# ---------------------------------------------------------------------------
# einsum (reference) dispatch
# ---------------------------------------------------------------------------

def _positions_in_expert(choices: jax.Array, n_experts: int) -> jax.Array:
    """For flattened choices (N,), position of each among same-expert picks."""
    onehot = jax.nn.one_hot(choices, n_experts, dtype=jnp.int32)  # (N, E)
    pos = jnp.cumsum(onehot, axis=0) * onehot  # 1-based at the picked column
    return jnp.sum(pos, axis=-1) - 1  # (N,)


def moe_einsum(params: Params, x2d: jax.Array, cfg: MoEConfig,
               ctx: Optional[DitherCtx], name: str = "moe"):
    T, d = x2d.shape
    E, k = cfg.n_experts, cfg.top_k
    cap = max(1, int(cfg.capacity_factor * T * k / E))
    top_i, top_p, aux = _routing(params, x2d, cfg, ctx)

    flat_choice = top_i.reshape(-1)  # (T*k,)
    pos = _positions_in_expert(flat_choice, E)  # (T*k,)
    keep = pos < cap
    disp = (
        jax.nn.one_hot(flat_choice, E, dtype=x2d.dtype)[:, :, None]
        * jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                         dtype=x2d.dtype)[:, None, :-1]
    )  # (T*k, E, cap)
    disp = disp.reshape(T, k, E, cap)
    combine = disp * top_p.astype(x2d.dtype)[:, :, None, None]

    xe = jnp.einsum("tkec,td->ecd", disp, x2d)
    he = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"],
                     xe, cfg, ctx, name)
    out = jnp.einsum("tkec,ecd->td", combine, he)
    if cfg.n_shared:
        out = out + _shared_ffn(params, x2d, cfg, ctx, name)
    return out, aux


# ---------------------------------------------------------------------------
# int8-on-the-wire all_to_all (both directions quantized via custom_vjp)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _int8_a2a(x: jax.Array, axis_name: str) -> jax.Array:
    return _int8_a2a_fwd(x, axis_name)[0]


def _quantized_hop(x: jax.Array, axis_name: str) -> jax.Array:
    """absmax-int8 the payload, a2a the int8 + tiny per-source scales."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, jnp.finfo(jnp.float32).tiny) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    q_recv = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0,
                                tiled=False)
    ep = q.shape[0]
    scales = jnp.broadcast_to(scale, (ep, 1, 1, 1))
    scales_recv = jax.lax.all_to_all(scales, axis_name, split_axis=0,
                                     concat_axis=0, tiled=False)
    return (q_recv.astype(jnp.float32) * scales_recv).astype(x.dtype)


def _int8_a2a_fwd(x, axis_name):
    return _quantized_hop(x, axis_name), None


def _int8_a2a_bwd(axis_name, _, g):
    # transpose of a2a is a2a; the gradient hop is quantized too (the
    # paper's 8-bit-gradients claim applied to the wire)
    return (_quantized_hop(g, axis_name),)


_int8_a2a.defvjp(_int8_a2a_fwd, _int8_a2a_bwd)


def _dispatch_a2a(x: jax.Array, axis_name: str, int8_wire: bool) -> jax.Array:
    if int8_wire:
        return _int8_a2a(x, axis_name)
    return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0,
                              tiled=False)


# ---------------------------------------------------------------------------
# all-to-all expert-parallel dispatch (shard_map)
# ---------------------------------------------------------------------------

def moe_a2a(params: Params, x2d: jax.Array, cfg: MoEConfig,
            ctx: Optional[DitherCtx], name: str = "moe"):
    """Tokens sharded over ALL mesh axes, experts over "model". Two a2a hops.

    Token rows must be split across the model axis too: with x replicated
    along "model", every expert column routes (and the experts then process)
    the SAME token population — a silent ep-fold redundancy. This was
    measured in the dry-run as a 16x FLOP bloat on dbrx (useful_ratio 0.043)
    and fixed in §Perf hillclimb iteration dbrx/It1.
    """
    rules = axlib.current_rules()
    assert rules is not None, "a2a dispatch needs sharding rules installed"
    mesh = rules.mesh
    ep_axis = "model"
    ep = mesh.shape[ep_axis]
    E = cfg.n_experts
    assert E % ep == 0, (E, ep)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    token_axes = data_axes + (ep_axis,)

    key = ctx.key_for(name) if ctx is not None else jax.random.PRNGKey(0)
    policy = ctx.policy if ctx is not None else None
    program = ctx.program if ctx is not None else None
    # traced per-step policy state crosses the shard_map boundary as explicit
    # (replicated) inputs: the step for knob schedules, and the controller's
    # per-layer log-scales stacked into one vector (dict rebuilt inside from
    # the static name tuple) — closures over outer tracers are not portable
    # across shard_map implementations.
    step = (ctx.step if ctx is not None and ctx.step is not None
            else jnp.zeros((), jnp.int32))
    ctrl_names = tuple(sorted(ctx.ctrl)) if ctx is not None and ctx.ctrl else ()
    ctrl_vec = (jnp.stack([ctx.ctrl[n] for n in ctrl_names])
                if ctrl_names else jnp.zeros((0,), jnp.float32))

    def body(x_loc, router, w_gate_loc, w_up_loc, w_down_loc, key, step,
             ctrl_vec):
        # x_loc: (T_loc, d); w_*_loc: (E_loc, ...) — this device's experts
        T_loc, d = x_loc.shape
        E_loc = E // ep
        k = cfg.top_k
        cap = max(1, int(cfg.capacity_factor * T_loc * k / E))
        ctrl = ({n: ctrl_vec[i] for i, n in enumerate(ctrl_names)}
                if ctrl_names else None)
        inner_ctx = (DitherCtx(key=key, policy=policy, program=program,
                               step=step, ctrl=ctrl,
                               recorder=ctx.recorder if ctx else None,
                               memory=ctx.memory if ctx else None,
                               mem_recorder=(ctx.mem_recorder if ctx
                                             else None))
                     if policy is not None else None)

        top_i, top_p, aux = _routing({"router": router}, x_loc, cfg, inner_ctx)
        flat_choice = top_i.reshape(-1)  # (T_loc*k,)
        pos = _positions_in_expert(flat_choice, E)
        keep = pos < cap

        # scatter tokens into the (E, cap, d) send layout
        send = jnp.zeros((E, cap, d), x_loc.dtype)
        tok_idx = jnp.repeat(jnp.arange(T_loc), k)
        safe_e = jnp.where(keep, flat_choice, 0)
        safe_p = jnp.where(keep, pos, 0)
        vals = jnp.where(keep[:, None], x_loc[tok_idx], 0)
        send = send.at[safe_e, safe_p].add(vals)

        # a2a hop 1: (ep, E_loc, cap, d) -> gather my experts' tokens
        send = send.reshape(ep, E_loc, cap, d)
        recv = _dispatch_a2a(send, ep_axis, cfg.a2a_int8)
        # recv: (ep, E_loc, cap, d) = per-source tokens for my local experts
        xe = jnp.moveaxis(recv, 0, 1).reshape(E_loc, ep * cap, d)
        he = _expert_ffn(w_gate_loc, w_up_loc, w_down_loc, xe, cfg,
                         inner_ctx, name)
        # reverse a2a
        back = jnp.moveaxis(he.reshape(E_loc, ep, cap, d), 1, 0)
        got = _dispatch_a2a(back, ep_axis, cfg.a2a_int8)
        got = got.reshape(E, cap, d)

        # combine: gather each choice's output, weight by prob, mask dropped
        out_choice = got[safe_e, safe_p]
        out_choice = jnp.where(keep[:, None], out_choice, 0)
        out = jnp.sum(
            out_choice.reshape(T_loc, k, d)
            * top_p.astype(x_loc.dtype)[:, :, None],
            axis=1,
        )
        aux = jax.lax.pmean(aux, data_axes + (ep_axis,))
        return out, aux

    out, aux = axlib.shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(token_axes, None), P(None, None), P(ep_axis, None, None),
                  P(ep_axis, None, None), P(ep_axis, None, None), P(), P(),
                  P()),
        out_specs=(P(token_axes, None), P()),
        check=False,
    )(x2d, params["router"], params["w_gate"], params["w_up"],
      params["w_down"], key, step, ctrl_vec)

    if cfg.n_shared:
        shared = _shared_ffn(params, x2d, cfg, ctx, name)
        out = out + shared
    return out, aux


def moe_layer(params: Params, x: jax.Array, cfg: MoEConfig,
              ctx: Optional[DitherCtx], name: str = "moe"):
    """x: (B, S, d) -> (y, aux_loss). Picks the dispatch strategy."""
    B, S, d = x.shape
    x2d = x.reshape(B * S, d)
    mode = cfg.dispatch
    if mode == "auto":
        rules = axlib.current_rules()
        ok = rules is not None and "model" in rules.mesh.shape \
            and cfg.n_experts % rules.mesh.shape["model"] == 0 \
            and rules.mesh.shape["model"] > 1
        if ok:
            # token rows must divide the full token-sharding extent
            # (decode steps with batch < n_devices fall back to einsum)
            n_tok_shards = 1
            for a in ("pod", "data", "model"):
                n_tok_shards *= rules.mesh.shape.get(a, 1)
            ok = (B * S) % n_tok_shards == 0
        mode = "a2a" if ok else "einsum"
    fn = moe_a2a if mode == "a2a" else moe_einsum
    out, aux = fn(params, x2d, cfg, ctx, name)
    return out.reshape(B, S, d), aux
