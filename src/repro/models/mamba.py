"""Mamba-2 (state-space duality / SSD), after Dao & Gu 2024 (arXiv:2405.21060).

Chunked SSD for training/prefill (within-chunk quadratic term + cross-chunk
state recurrence), O(1)-state single-token decode for serving — this is the
sub-quadratic family that carries the ``long_500k`` shape cells.

Dithered backprop covers the in/out projections (the FLOP-dominant dense
matmuls). The state recurrence itself is elementwise and stays exact — see
DESIGN.md §5 (mamba2 row).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dense
from repro.core.policy import DitherCtx
from repro.core.probe import tap
from repro.models import layers as L
from repro.parallel.axes import shard_act


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_inner: int  # expand * d_model
    head_dim: int  # P
    d_state: int  # N
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1
    # dtype of the intra-chunk (quadratic) einsum OPERANDS; accumulation is
    # always f32 (preferred_element_type). "bf16" halves the bytes of the
    # (B,nc,Q,Q,H) score/decay tensors — §Perf mamba2/It1.
    intra_dtype: str = "f32"

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state

    @property
    def d_in_proj(self) -> int:
        return 2 * self.d_inner + 2 * self.n_groups * self.d_state + self.n_heads


def init_mamba_mixer(key: jax.Array, cfg: SSMConfig, dtype) -> Tuple[L.Params, L.Specs]:
    ini = L.Init(key, dtype)
    ini.normal("in_proj", (cfg.d_model, cfg.d_in_proj), ("embed", "ssm_inner"),
               fan_in=cfg.d_model)
    ini.normal("conv_w", (cfg.d_conv, cfg.conv_dim), (None, "ssm_inner"),
               stddev=1.0 / np.sqrt(cfg.d_conv))
    ini.zeros("conv_b", (cfg.conv_dim,), ("ssm_inner",))
    # A in (-exp) parameterization; dt bias set for softplus(dt) in [dt_min, dt_max]
    a_init = jnp.log(jnp.linspace(1.0, 16.0, cfg.n_heads))
    ini.const("A_log", a_init, (None,))
    dt = jnp.exp(jax.random.uniform(ini.next_key(), (cfg.n_heads,)) *
                 (np.log(cfg.dt_max) - np.log(cfg.dt_min)) + np.log(cfg.dt_min))
    ini.const("dt_bias", dt + jnp.log(-jnp.expm1(-dt)), (None,))
    ini.zeros("D", (cfg.n_heads,), (None,))
    ini.ones("norm", (cfg.d_inner,), ("ssm_inner",))
    ini.normal("out_proj", (cfg.d_inner, cfg.d_model), ("ssm_inner", "embed"),
               fan_in=cfg.d_inner)
    return ini.build()


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x (B,S,C), w (K,C) -> (B,S,C)."""
    K, C = w.shape
    y = jax.lax.conv_general_dilated(
        x, w[:, None, :],  # (K, 1, C) HIO with feature groups
        window_strides=(1,), padding=[(K - 1, 0)],
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=C,
    )
    return y + b


def _ssd_chunked(x, dt, A, Bm, Cm, cfg: SSMConfig,
                 h0: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x: (B,S,H,P) dt: (B,S,H) A: (H,) Bm/Cm: (B,S,G,N)
    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    Bsz, S, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(cfg.chunk, S)
    S_orig = S
    if S % Q != 0:
        # pad the tail: dt=0 there => decay=1 and zero state contribution,
        # so earlier (causal) outputs are exact; padded outputs are sliced off
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // Q
    rep = H // G

    # heads are kept factored as (G, rep) — B/C are NEVER repeated to H
    # (repeating them 32x was measured as a pure bytes/FLOP tax, §Perf
    # mamba2/It4): the group dim broadcasts inside the einsums instead.
    xc = x.reshape(Bsz, nc, Q, G, rep, Pd)
    dtc = dt.reshape(Bsz, nc, Q, G, rep)
    Bg = Bm.reshape(Bsz, nc, Q, G, N)
    Cg = Cm.reshape(Bsz, nc, Q, G, N)

    dA = dtc * A.reshape(G, rep)  # (B,nc,Q,G,rep), negative
    cum = jnp.cumsum(dA, axis=2)  # within-chunk cumulative log-decay

    # ---- intra-chunk (quadratic in Q) ----
    # L[i,j] = exp(cum_i - cum_j) for i >= j (exp/cumsum stay f32; only the
    # matmul OPERANDS drop to intra_dtype, accumulating in f32)
    op_dtype = jnp.bfloat16 if cfg.intra_dtype == "bf16" else jnp.float32
    diff = cum[:, :, :, None] - cum[:, :, None, :, :, :]  # (B,nc,Q,Q,G,rep)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(tri[None, None, :, :, None, None], jnp.exp(diff), 0.0)
    # scores are per-GROUP (shared by rep heads): 1/rep of the naive FLOPs
    scores = jnp.einsum("bcign,bcjgn->bcijg", Cg.astype(op_dtype),
                        Bg.astype(op_dtype),
                        preferred_element_type=jnp.float32)
    M = scores[..., None] * Lmat * dtc[:, :, None, :, :, :]
    y_intra = jnp.einsum("bcijgr,bcjgrp->bcigrp", M.astype(op_dtype),
                         xc.astype(op_dtype),
                         preferred_element_type=jnp.float32)

    # ---- chunk states ----
    decay_to_end = jnp.exp(cum[:, :, -1:] - cum)  # (B,nc,Q,G,rep)
    states = jnp.einsum(
        "bcjgr,bcjgn,bcjgrp->bcgrnp",
        (decay_to_end * dtc).astype(jnp.float32),
        Bg.astype(jnp.float32), xc.astype(jnp.float32))

    # ---- cross-chunk recurrence over nc (sequential scan, nc is small) ----
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))  # (B,nc,G,rep)

    def scan_fn(h, inp):
        st, dec = inp  # (B,G,rep,N,P), (B,G,rep)
        h_new = h * dec[:, :, :, None, None] + st
        return h_new, h

    if h0 is None:
        h0 = jnp.zeros((Bsz, G, rep, N, Pd), jnp.float32)
    else:
        h0 = h0.reshape(Bsz, G, rep, N, Pd)
    h_final, h_prev = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # (B,nc,G,rep,N,P)

    # ---- inter-chunk contribution ----
    # C stays grouped; the per-head decay scales the OUTPUT (P-sized), not a
    # repeated (N-sized) C tensor
    y_inter = jnp.einsum(
        "bcign,bcgrnp->bcigrp", Cg.astype(op_dtype),
        h_prev.astype(op_dtype), preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(cum)[..., None]

    y = (y_intra + y_inter).reshape(Bsz, S, H, Pd)
    return y[:, :S_orig], h_final.reshape(Bsz, H, N, Pd)


def mamba_mixer(params: L.Params, x: jax.Array, cfg: SSMConfig, *,
                ctx: Optional[DitherCtx] = None, name: str = "ssm",
                taps=None) -> jax.Array:
    """Full Mamba-2 mixer for train/prefill. x: (B,S,d_model)."""
    B, S, _ = x.shape
    H, Pd, G, N = cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state
    zxbcdt = dense(x, params["in_proj"], ctx=ctx, name=f"{name}.in")
    zxbcdt = tap(zxbcdt, taps, f"{name}.in_out")
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt,
        [cfg.d_inner, 2 * cfg.d_inner, 2 * cfg.d_inner + G * N,
         2 * cfg.d_inner + 2 * G * N],
        axis=-1,
    )
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(
        _causal_conv(conv_in, params["conv_w"], params["conv_b"]))
    xs, Bm, Cm = jnp.split(
        conv_out, [cfg.d_inner, cfg.d_inner + G * N], axis=-1)
    xs = xs.reshape(B, S, H, Pd)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))
    y, _ = _ssd_chunked(xs, dt, A, Bm, Cm, cfg)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * \
        xs.astype(jnp.float32)
    y = y.reshape(B, S, cfg.d_inner).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), params["norm"])
    y = shard_act(y, ("batch", "seq", "act_ssm_inner"))
    return dense(y, params["out_proj"], ctx=ctx, name=f"{name}.out")


class MambaCache:
    """Decode cache = {"conv": window, "state": SSM state} (dict keys make
    the leaves identifiable for sharding-rule assignment in the dry-run)."""

    @staticmethod
    def init(cfg: SSMConfig, batch: int, dtype) -> Dict[str, jax.Array]:
        return {
            "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.conv_dim), dtype),
            "state": jnp.zeros(
                (batch, cfg.n_heads, cfg.d_state, cfg.head_dim), jnp.float32),
        }

    @staticmethod
    def specs(cfg: SSMConfig, batch: int, dtype):
        return {
            "conv": jax.ShapeDtypeStruct(
                (batch, cfg.d_conv - 1, cfg.conv_dim), dtype),
            "state": jax.ShapeDtypeStruct(
                (batch, cfg.n_heads, cfg.d_state, cfg.head_dim), jnp.float32),
        }


def mamba_decode_step(params: L.Params, x: jax.Array, cache, cfg: SSMConfig,
                      *, name: str = "ssm"):
    """One token. x: (B,1,d_model). Returns (y (B,1,d), new_cache)."""
    B = x.shape[0]
    H, Pd, G, N = cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state
    conv_state, h = cache["conv"], cache["state"]
    zxbcdt = dense(x[:, 0], params["in_proj"], name=f"{name}.in")
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt,
        [cfg.d_inner, 2 * cfg.d_inner, 2 * cfg.d_inner + G * N,
         2 * cfg.d_inner + 2 * G * N],
        axis=-1,
    )
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)  # (B, conv_dim)
    window = jnp.concatenate([conv_state, conv_in[:, None, :]], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32))
    new_conv_state = window[:, 1:, :].astype(conv_state.dtype)

    xs, Bm, Cm = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + G * N],
                           axis=-1)
    xs = xs.reshape(B, H, Pd)
    Bm = jnp.repeat(Bm.reshape(B, G, N), H // G, axis=1)  # (B,H,N)
    Cm = jnp.repeat(Cm.reshape(B, G, N), H // G, axis=1)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))  # (B,H)
    decay = jnp.exp(dt * A)  # (B,H)
    h_new = h * decay[:, :, None, None] + jnp.einsum(
        "bh,bhn,bhp->bhnp", dt, Bm, xs.astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", Cm, h_new)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, cfg.d_inner).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), params["norm"])
    y = dense(y, params["out_proj"], name=f"{name}.out")
    return y[:, None, :], {"conv": new_conv_state, "state": h_new}


# ---------------------------------------------------------------------------
# full SSM language model (mamba2-370m)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SSMLMConfig:
    name: str
    n_layers: int
    vocab: int
    ssm: SSMConfig
    dtype: Any = jnp.bfloat16
    tie_embeddings: bool = True
    remat: bool = True
    scan_unroll: bool = False

    @property
    def d_model(self) -> int:
        return self.ssm.d_model

    @property
    def param_count(self) -> int:
        c = self.ssm
        per_layer = (c.d_model * c.d_in_proj + c.d_conv * c.conv_dim +
                     c.d_inner * c.d_model + 3 * c.n_heads + 2 * c.d_inner +
                     c.d_model)
        emb = self.vocab * c.d_model * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb

    @property
    def active_param_count(self) -> int:
        return self.param_count


def init_ssm_lm(key: jax.Array, cfg: SSMLMConfig) -> Tuple[L.Params, L.Specs]:
    keys = jax.random.split(key, cfg.n_layers + 2)
    emb_p, emb_s = L.init_embedding(keys[0], cfg.vocab, cfg.d_model, cfg.dtype)
    blocks = []
    for i in range(cfg.n_layers):
        ini = L.Init(keys[1 + i], cfg.dtype)
        mix_p, mix_s = init_mamba_mixer(ini.next_key(), cfg.ssm, cfg.dtype)
        sub = L.Init(jax.random.PRNGKey(0), cfg.dtype)
        sub.params, sub.specs = mix_p, mix_s
        ini.sub("mixer", sub)
        ini.ones("ln", (cfg.d_model,), (None,))
        blocks.append(ini.build())
    stacked_p, stacked_s = L.stack_layers(blocks)
    ini = L.Init(keys[-1], cfg.dtype)
    ini.ones("ln_f", (cfg.d_model,), (None,))
    head_p, head_s = ini.build()
    return ({"embed": emb_p, "layers": stacked_p, "head": head_p},
            {"embed": emb_s, "layers": stacked_s, "head": head_s})


def forward(params, cfg: SSMLMConfig, tokens: jax.Array, *,
            ctx: Optional[DitherCtx] = None, taps=None):
    x = L.embed(params["embed"], tokens)

    if taps is not None:
        for i in range(cfg.n_layers):
            p = L.layer_slice(params["layers"], i)
            h = L.rms_norm(x, p["ln"])
            x = x + mamba_mixer(p["mixer"], h, cfg.ssm, ctx=ctx,
                                name=f"L{i}.ssm", taps=taps)
    else:
        def body(x, p):
            h = L.rms_norm(x, p["ln"])
            return x + mamba_mixer(p["mixer"], h, cfg.ssm, ctx=ctx,
                                   name="L.ssm"), None

        f = body
        if cfg.remat:
            f = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(f, x, params["layers"],
                            unroll=cfg.n_layers if cfg.scan_unroll else 1)

    x = L.rms_norm(x, params["head"]["ln_f"])
    logits = L.unembed(params["embed"], x, ctx=ctx)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: SSMLMConfig, batch, *, ctx=None, taps=None):
    logits, _ = forward(params, cfg, batch["tokens"], ctx=ctx, taps=taps)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def init_cache(cfg: SSMLMConfig, batch: int, max_len: int, dtype=None):
    del max_len  # O(1) state
    dtype = dtype or cfg.dtype
    return [MambaCache.init(cfg.ssm, batch, dtype)
            for _ in range(cfg.n_layers)]


def cache_specs(cfg: SSMLMConfig, batch: int, max_len: int, dtype=None):
    del max_len
    dtype = dtype or cfg.dtype
    return [MambaCache.specs(cfg.ssm, batch, dtype)
            for _ in range(cfg.n_layers)]


def decode_step(params, cfg: SSMLMConfig, cache, token: jax.Array,
                t: jax.Array, *, ctx=None):
    del t  # stateful: position-free
    x = L.embed(params["embed"], token)
    new_cache = []
    for i in range(cfg.n_layers):
        p = L.layer_slice(params["layers"], i)
        h = L.rms_norm(x, p["ln"])
        y, kv = mamba_decode_step(p["mixer"], h, cache[i], cfg.ssm,
                                  name=f"L{i}.ssm")
        x = x + y
        new_cache.append(kv)
    x = L.rms_norm(x, params["head"]["ln_f"])
    logits = L.unembed(params["embed"], x)
    return logits, new_cache


def prefill(params, cfg: SSMLMConfig, tokens: jax.Array, max_len: int):
    """Token-by-token prompt scan through the decode state.

    tokens (B, S) -> (logits (B, S, V), cache, t = S - 1). The decode
    recurrence IS the model here (no separate bulk path is needed for
    correctness — the chunked SSD forward is a training-time optimization),
    so prefill scans ``decode_step`` to keep serving numerics identical to
    the decode loop that follows.
    """
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len)

    def body(c, tok):
        logits, c = decode_step(params, cfg, c, tok[:, None],
                                jnp.zeros((), jnp.int32))
        return c, logits[:, 0]

    cache, logits_seq = jax.lax.scan(body, cache, tokens.T)
    return (jnp.moveaxis(logits_seq, 0, 1), cache,
            jnp.asarray(S - 1, jnp.int32))
