"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment the conv/mel frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings (B, n_frames, d_model) straight into the
encoder. Encoder = bidirectional pre-LN blocks with sinusoidal positions;
decoder = causal self-attn + cross-attn + GELU MLP with learned positions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dense
from repro.core.policy import DitherCtx
from repro.models import layers as L
from repro.models.transformer import _attend_with_mask


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    n_layers: int  # per stack (encoder AND decoder)
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    n_frames: int = 1500  # encoder positions (mel frontend output length)
    max_target: int = 448
    act: str = "gelu"
    dtype: Any = jnp.bfloat16
    remat: bool = True
    scan_unroll: bool = False

    @property
    def hd(self) -> int:
        return self.d_model // self.n_heads

    def attn_cfg(self, causal: bool) -> L.AttnConfig:
        return L.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.hd, causal=causal,
            rope_theta=0.0)

    @property
    def param_count(self) -> int:
        d, f = self.d_model, self.d_ff
        attn = 4 * d * d
        mlp = 2 * d * f
        enc_layer = attn + mlp + 4 * d
        dec_layer = 2 * attn + mlp + 6 * d
        return (self.n_layers * (enc_layer + dec_layer) + self.vocab * d +
                self.max_target * d + 2 * d)

    @property
    def active_param_count(self) -> int:
        return self.param_count


def _sinusoid(n_pos: int, d: int) -> np.ndarray:
    pos = np.arange(n_pos)[:, None]
    dim = np.arange(d // 2)[None, :]
    inv = 1.0 / (10000 ** (dim / max(d // 2 - 1, 1)))
    ang = pos * inv
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=1).astype(np.float32)


def _init_block(key, cfg: EncDecConfig, cross: bool):
    ini = L.Init(key, cfg.dtype)
    attn_p, attn_s = L.init_attention(ini.next_key(), cfg.attn_cfg(True),
                                      cfg.dtype)
    sub = L.Init(jax.random.PRNGKey(0), cfg.dtype)
    sub.params, sub.specs = attn_p, attn_s
    ini.sub("attn", sub)
    if cross:
        x_p, x_s = L.init_attention(ini.next_key(), cfg.attn_cfg(False),
                                    cfg.dtype)
        sub = L.Init(jax.random.PRNGKey(0), cfg.dtype)
        sub.params, sub.specs = x_p, x_s
        ini.sub("xattn", sub)
        ini.ones("lnx_s", (cfg.d_model,), (None,))
        ini.zeros("lnx_b", (cfg.d_model,), (None,))
    mlp_p, mlp_s = L.init_mlp(
        ini.next_key(), L.MLPConfig(cfg.d_model, cfg.d_ff, cfg.act), cfg.dtype)
    sub = L.Init(jax.random.PRNGKey(0), cfg.dtype)
    sub.params, sub.specs = mlp_p, mlp_s
    ini.sub("mlp", sub)
    for nm in ("ln1", "ln2"):
        ini.ones(f"{nm}_s", (cfg.d_model,), (None,))
        ini.zeros(f"{nm}_b", (cfg.d_model,), (None,))
    return ini.build()


def init_encdec(key: jax.Array, cfg: EncDecConfig) -> Tuple[L.Params, L.Specs]:
    keys = jax.random.split(key, 2 * cfg.n_layers + 3)
    enc = [_init_block(keys[i], cfg, cross=False) for i in range(cfg.n_layers)]
    dec = [_init_block(keys[cfg.n_layers + i], cfg, cross=True)
           for i in range(cfg.n_layers)]
    enc_p, enc_s = L.stack_layers(enc)
    dec_p, dec_s = L.stack_layers(dec)
    emb_p, emb_s = L.init_embedding(keys[-3], cfg.vocab, cfg.d_model, cfg.dtype)
    ini = L.Init(keys[-2], cfg.dtype)
    ini.normal("dec_pos", (cfg.max_target, cfg.d_model), (None, "embed"),
               stddev=0.01)
    ini.ones("ln_enc_s", (cfg.d_model,), (None,))
    ini.zeros("ln_enc_b", (cfg.d_model,), (None,))
    ini.ones("ln_dec_s", (cfg.d_model,), (None,))
    ini.zeros("ln_dec_b", (cfg.d_model,), (None,))
    head_p, head_s = ini.build()
    return ({"enc": enc_p, "dec": dec_p, "embed": emb_p, "head": head_p},
            {"enc": enc_s, "dec": dec_s, "embed": emb_s, "head": head_s})


def _ln(x, p, name):
    return L.layer_norm(x, p[f"{name}_s"], p[f"{name}_b"])


def encode(params, cfg: EncDecConfig, frames: jax.Array, *,
           ctx: Optional[DitherCtx] = None) -> jax.Array:
    """frames: (B, n_frames, d_model) precomputed embeddings (frontend stub)."""
    B, S, _ = frames.shape
    pos = jnp.asarray(_sinusoid(S, cfg.d_model))
    x = frames.astype(cfg.dtype) + pos[None].astype(cfg.dtype)
    pos_b = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    acfg = cfg.attn_cfg(causal=False)

    def body(x, p):
        h = _ln(x, p, "ln1")
        y, _ = _attend_with_mask(p["attn"], h, pos_b, acfg, None, ctx, "enc.attn")
        x = x + y
        h = _ln(x, p, "ln2")
        return x + L.mlp(p["mlp"], h,
                         L.MLPConfig(cfg.d_model, cfg.d_ff, cfg.act),
                         ctx=ctx, name="enc.mlp"), None

    f = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(f, x, params["enc"],
                        unroll=cfg.n_layers if cfg.scan_unroll else 1)
    return _ln(x, params["head"], "ln_enc")


def decode_train(params, cfg: EncDecConfig, tokens: jax.Array,
                 enc_out: jax.Array, *, ctx=None) -> jax.Array:
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    pos_table = params["head"]["dec_pos"]
    n_pos = pos_table.shape[0]
    pos_idx = jnp.minimum(jnp.arange(S), n_pos - 1)
    x = x + pos_table[pos_idx][None].astype(x.dtype)
    pos_b = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    acfg = cfg.attn_cfg(causal=True)
    mask = L.attention_mask(pos_b, pos_b, acfg)

    def body(x, p):
        h = _ln(x, p, "ln1")
        y, _ = _attend_with_mask(p["attn"], h, pos_b, acfg, mask, ctx,
                                 "dec.attn")
        x = x + y
        h = _ln(x, p, "lnx")
        y, _ = L.attention(p["xattn"], h, pos_b, cfg.attn_cfg(False),
                           ctx=ctx, name="dec.xattn", x_kv=enc_out)
        x = x + y
        h = _ln(x, p, "ln2")
        return x + L.mlp(p["mlp"], h,
                         L.MLPConfig(cfg.d_model, cfg.d_ff, cfg.act),
                         ctx=ctx, name="dec.mlp"), None

    f = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(f, x, params["dec"],
                        unroll=cfg.n_layers if cfg.scan_unroll else 1)
    x = _ln(x, params["head"], "ln_dec")
    return L.unembed(params["embed"], x, ctx=ctx)


def forward(params, cfg: EncDecConfig, batch: Dict[str, jax.Array], *,
            ctx=None, taps=None):
    enc_out = encode(params, cfg, batch["frames"], ctx=ctx)
    logits = decode_train(params, cfg, batch["tokens"], enc_out, ctx=ctx)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: EncDecConfig, batch, *, ctx=None, taps=None):
    logits, _ = forward(params, cfg, batch, ctx=ctx)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# serving: encoder runs once (prefill); decoder steps with self-KV + enc-KV
# ---------------------------------------------------------------------------

def init_cache(cfg: EncDecConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    kvshape = (batch, max_len, cfg.n_kv_heads, cfg.hd)
    enc_shape = (batch, cfg.n_frames, cfg.n_kv_heads, cfg.hd)
    return [{
        "self": (jnp.zeros(kvshape, dtype), jnp.zeros(kvshape, dtype)),
        "cross": (jnp.zeros(enc_shape, dtype), jnp.zeros(enc_shape, dtype)),
    } for _ in range(cfg.n_layers)]


def cache_specs(cfg: EncDecConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    kv = jax.ShapeDtypeStruct((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype)
    ekv = jax.ShapeDtypeStruct((batch, cfg.n_frames, cfg.n_kv_heads, cfg.hd),
                               dtype)
    return [{"self": (kv, kv), "cross": (ekv, ekv)}
            for _ in range(cfg.n_layers)]


def precompute_cross_kv(params, cfg: EncDecConfig, enc_out: jax.Array):
    out = []
    for i in range(cfg.n_layers):
        p = L.layer_slice(params["dec"], i)
        k = dense(enc_out, p["xattn"]["wk"])
        v = dense(enc_out, p["xattn"]["wv"])
        B, S = enc_out.shape[0], enc_out.shape[1]
        out.append((k.reshape(B, S, cfg.n_kv_heads, cfg.hd),
                    v.reshape(B, S, cfg.n_kv_heads, cfg.hd)))
    return out


def decode_step(params, cfg: EncDecConfig, cache, token: jax.Array,
                t: jax.Array, *, ctx=None):
    x = L.embed(params["embed"], token)
    pos_table = params["head"]["dec_pos"]
    t = jnp.asarray(t, jnp.int32)
    # clip below too: per-slot decode uses t = -1 for inactive slots
    pos_idx = jnp.clip(t, 0, pos_table.shape[0] - 1)
    pe = pos_table[pos_idx].astype(x.dtype)  # scalar t -> (d,); (B,) -> (B,d)
    x = x + (pe[None, None] if t.ndim == 0 else pe[:, None])
    positions = L.decode_positions(t)
    new_cache = []
    for i in range(cfg.n_layers):
        p = L.layer_slice(params["dec"], i)
        h = _ln(x, p, "ln1")
        y, kv = L.attention(p["attn"], h, positions, cfg.attn_cfg(True),
                            name=f"dec{i}.attn", kv_cache=cache[i]["self"],
                            cache_index=t)
        x = x + y
        h = _ln(x, p, "lnx")
        y = L.cross_attention_cached(p["xattn"], h, cache[i]["cross"],
                                     cfg.attn_cfg(False), name=f"dec{i}.xattn")
        x = x + y
        h = _ln(x, p, "ln2")
        x = x + L.mlp(p["mlp"], h, L.MLPConfig(cfg.d_model, cfg.d_ff, cfg.act),
                      name=f"dec{i}.mlp")
        new_cache.append({"self": kv, "cross": cache[i]["cross"]})
    x = _ln(x, params["head"], "ln_dec")
    logits = L.unembed(params["embed"], x)
    return logits, new_cache


def prefill(params, cfg: EncDecConfig, tokens: jax.Array, max_len: int,
            frames: jax.Array):
    """Encoder pass + teacher-forced decoder scan into a decode cache.

    tokens (B, S), frames (B, n_frames, d_model) ->
    (logits (B, S, V), cache, t = S - 1).
    """
    B, S = tokens.shape
    enc_out = encode(params, cfg, frames)
    cross = precompute_cross_kv(params, cfg, enc_out)
    cache = init_cache(cfg, B, max_len)
    cache = [{"self": c["self"], "cross": cross[i]}
             for i, c in enumerate(cache)]

    def body(c, inp):
        tok, pos = inp
        logits, c = decode_step(params, cfg, c, tok[:, None], pos)
        return c, logits[:, 0]

    cache, logits_seq = jax.lax.scan(body, cache, (tokens.T, jnp.arange(S)))
    return (jnp.moveaxis(logits_seq, 0, 1), cache,
            jnp.asarray(S - 1, jnp.int32))
