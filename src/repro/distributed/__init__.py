from repro.distributed.ssgd import (
    SSGDConfig, ErrorFeedbackState, int8_allreduce_sim, make_ssgd_step,
    shard_batch, topk_error_feedback,
)

__all__ = ["SSGDConfig", "ErrorFeedbackState", "int8_allreduce_sim",
           "make_ssgd_step", "shard_batch", "topk_error_feedback"]
