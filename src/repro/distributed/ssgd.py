"""Synchronous SGD with per-node dithered backprop (paper §3.6 / §4.3).

The paper's argument: NSD noise is zero-mean with bounded variance, so with
N data-parallel workers the server-side average cancels most of it — the
dither scale ``s`` can GROW with N (more per-node sparsity, fewer per-node
ops) at constant final accuracy. We reproduce the experiment by simulating
N nodes: per-node sub-batches, per-node dither keys (folded from the worker
index), gradient averaging, shared parameters.

The communication side is one call: ``make_ssgd_step`` builds a
``repro.comm.reducer`` from the optional ``CommPolicy`` and the step
routes the stacked node gradients through ``Reducer.reduce`` — topology
dispatch (ps / ring / hier / butterfly), per-leaf keys, wire telemetry
and overlap bucketing all live behind that protocol now. Error-feedback
residual state is threaded through the step (``comm_state`` in, new state
out) so elastic restarts can checkpoint and migrate it; see
``repro.train.fault_tolerance``.

``int8_allreduce_sim`` and the re-exported ``topk_error_feedback`` /
``ErrorFeedbackState`` (implemented in ``repro.comm.compression``) remain
for the single-tensor analogues.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.comm.compression import (TOPO_PS, CommPolicy, ErrorFeedbackState,
                                    topk_error_feedback)
from repro.comm.reducer import reducer as comm_reducer
from repro.core.policy import DitherCtx, DitherPolicy
from repro.core.schedule import PolicyProgram, as_program
from repro.obs.trace import annotate
from repro.models.api import Model
from repro.optim import OptConfig, apply_updates
from repro import quant

__all__ = ["SSGDConfig", "ErrorFeedbackState", "int8_allreduce_sim",
           "make_ssgd_step", "shard_batch", "topk_error_feedback"]


@dataclasses.dataclass(frozen=True)
class SSGDConfig:
    n_nodes: int = 4
    s_schedule: str = "sqrt"  # fixed | linear | sqrt: how s scales with N
    s_base: float = 1.0

    def s_for_n(self) -> float:
        if self.s_schedule == "fixed":
            return self.s_base
        if self.s_schedule == "linear":
            return self.s_base * self.n_nodes
        # static hyperparameter math stays on the host: no device array here
        return self.s_base * math.sqrt(self.n_nodes)


def make_ssgd_step(model: Model, opt_cfg: OptConfig, dcfg: SSGDConfig,
                   base_policy: DitherPolicy | PolicyProgram,
                   comm_policy: Optional[CommPolicy] = None, *,
                   phase_step: int = 0, memory=None, grad_accum: int = 1,
                   mesh=None):
    """One SSGD step: N per-node dithered grads -> reduce -> update.

    The batch leaves must have a leading (n_nodes, per_node_batch, ...) axis.
    Per-node dither keys are folded from (step, worker) so noise is i.i.d.
    across nodes — the cancellation the paper relies on.

    ``base_policy`` may be a :class:`repro.core.schedule.PolicyProgram`:
    every node resolves per-layer rules and knob schedules from the SAME
    program on the SAME traced step (and, when the program carries a
    sparsity controller, the SAME ``ctrl`` log-scale tree passed to the
    returned step function), so all data-parallel nodes see identical
    policies by construction. A plain DitherPolicy keeps the legacy
    behavior: its ``s`` is replaced by ``dcfg.s_for_n()``; a program is
    used verbatim (its author owns the s/N trade). The static variant
    phase is the one active at ``phase_step``.

    With ``comm_policy`` the node gradients cross the wire through a
    ``repro.comm.reducer`` built once here: topology ("ps" keeps the
    parameter-server shape, "ring"/"hier"/"butterfly" run the compressed
    all-reduces; ``bucket_bytes`` > 0 overlap-buckets any of them), keys,
    telemetry and error feedback all live behind that protocol. Step
    metrics gain ``comm_wire_bytes`` / ``comm_dense_bytes`` (plus
    ``comm_error_bound`` and the ICI/DCN byte split on the all-reduce
    topologies).

    ``grad_accum`` > 1 accumulates that many micro-batches per node (each
    with its own micro dither key, matching the Trainer's scan) BEFORE
    the reduce, so gradients are dithered and packed once per accumulated
    step, not once per micro-batch — wire bytes and EF residual updates
    are identical to a single-micro step of the same effective batch.

    The returned step is

        step_fn(params, opt_state, batch, key, ctrl=None, comm_state=None)
            -> (params, opt_state, metrics, comm_state)

    ``comm_state`` carries error-feedback residuals for leaves the policy
    routes through ``topk_ef`` (node-count independent, applied to the
    reduced mean) — seed it with ``repro.comm.init_comm_state(params,
    comm_policy)`` or the reducer's ``init_state`` and thread it through
    steps; checkpoint it to survive restarts and elastic resizes.
    Migration note: before the reducer redesign this function returned a
    3-tuple and took no ``comm_state`` — see README "Distributed
    training" for the table.

    ``memory`` is a ``repro.memory`` MemoryPolicy (or spec string)
    selecting each dithered layer's residual codec / remat on every node —
    static per layer, baked into the compiled step exactly as the Trainer
    path does (tests pin the two paths numerically identical).
    """
    from repro.memory.policy import as_memory_policy

    program = as_program(base_policy)
    if isinstance(base_policy, DitherPolicy):
        program = program.replace(base=base_policy.replace(s=dcfg.s_for_n()))
    policy = program.phase_policy_at(phase_step)
    memory = as_memory_policy(memory)
    if grad_accum < 1:
        raise ValueError(f"grad_accum must be >= 1, got {grad_accum}")

    red = None
    if comm_policy is not None:
        eff_policy = comm_policy
        if comm_policy.topology != TOPO_PS and dcfg.n_nodes == 1:
            # a 1-node all-reduce has no wire; keep the historical behavior
            # of still measuring the ps-shaped compression
            eff_policy = comm_policy.replace(topology=TOPO_PS)
        red = comm_reducer(eff_policy, mesh, n_nodes=dcfg.n_nodes,
                           stacked=True)

    def node_grad(params, node_batch, base_key, step, worker, ctrl):
        ctx = DitherCtx.for_step(base_key, step, policy, worker=worker,
                                 program=program, ctrl=ctrl or None,
                                 memory=memory)
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, node_batch, ctx=ctx))(params)
        return loss, grads

    def all_node_grads(params, sharded_batch, base_key, step, ctrl):
        workers = jnp.arange(dcfg.n_nodes)
        if grad_accum == 1:
            return jax.vmap(
                lambda b, w: node_grad(params, b, base_key, step, w, ctrl),
                in_axes=(0, 0))(sharded_batch, workers)

        # (n, ga*b, ...) -> (ga, n, b, ...): scan over micro-batches, each
        # with its own micro key (Trainer idiom), accumulate before reduce
        def micros(x):
            n, total = x.shape[0], x.shape[1]
            assert total % grad_accum == 0, (total, grad_accum)
            return x.reshape((n, grad_accum, total // grad_accum)
                             + x.shape[2:]).swapaxes(0, 1)

        mbs = jax.tree.map(micros, sharded_batch)

        def one_micro(carry, xs):
            i, mb = xs
            k_i = jax.random.fold_in(base_key, i)
            losses_i, grads_i = jax.vmap(
                lambda b, w: node_grad(params, b, k_i, step, w, ctrl),
                in_axes=(0, 0))(mb, workers)
            acc_l, acc_g = carry
            return (acc_l + losses_i,
                    jax.tree.map(jnp.add, acc_g, grads_i)), None

        init = (jnp.zeros((dcfg.n_nodes,), jnp.float32),
                jax.tree.map(
                    lambda p: jnp.zeros((dcfg.n_nodes,) + p.shape, p.dtype),
                    params))
        (losses, grads), _ = jax.lax.scan(
            one_micro, init, (jnp.arange(grad_accum), mbs))
        inv = 1.0 / grad_accum
        return losses * inv, jax.tree.map(lambda g: g * inv, grads)

    def ssgd_step(params, opt_state, sharded_batch, base_key, ctrl=None,
                  comm_state=None):
        step = opt_state["step"]
        with annotate("ssgd/grad"):
            losses, grads = all_node_grads(params, sharded_batch, base_key,
                                           step, ctrl)
        comm_metrics = {}
        if red is not None:
            with annotate("ssgd/reduce"):
                grads, tele, comm_state = red.reduce(
                    grads, base_key, step, comm_state)
            comm_metrics = {"comm_wire_bytes": tele.wire_bytes,
                            "comm_dense_bytes": tele.dense_bytes}
            if red.topology != TOPO_PS:
                comm_metrics.update(
                    comm_error_bound=tele.error_bound,
                    comm_wire_ici_bytes=tele.wire_ici_bytes,
                    comm_wire_dcn_bytes=tele.wire_dcn_bytes,
                    comm_peak_dcn_bytes=tele.peak_dcn_bytes)
        else:
            # no wire: plain server-side average of the noisy node grads
            grads = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
        with annotate("ssgd/update"):
            params, opt_state, metrics = apply_updates(
                params, grads, opt_state, opt_cfg)
        metrics["loss"] = jnp.mean(losses)
        metrics.update(comm_metrics)
        return params, opt_state, metrics, comm_state

    return jax.jit(ssgd_step), policy


def shard_batch(batch: Dict[str, jax.Array], n_nodes: int
                ) -> Dict[str, jax.Array]:
    def reshape(x):
        b = x.shape[0]
        assert b % n_nodes == 0, (b, n_nodes)
        return x.reshape((n_nodes, b // n_nodes) + x.shape[1:])

    return {k: reshape(v) for k, v in batch.items()}


# ---------------------------------------------------------------------------
# single-tensor comm analogues (kept for tests/benchmarks; the pytree-level
# machinery lives in repro.comm)
# ---------------------------------------------------------------------------

def int8_allreduce_sim(grads_per_node: List, key: jax.Array):
    """Each node NSD-quantizes its gradient to (int8, delta) before the
    reduce — the comm-side use of the paper's operator. Returns the average
    of dequantized tensors (what a quantized ring all-reduce would yield)."""
    n = len(grads_per_node)
    acc = None
    for i, g in enumerate(grads_per_node):
        q = quant.nsd_int8(g, jax.random.fold_in(key, i), 1.0)
        deq = q.dequantize()
        acc = deq if acc is None else acc + deq
    return acc / n
