"""Synchronous SGD with per-node dithered backprop (paper §3.6 / §4.3).

The paper's argument: NSD noise is zero-mean with bounded variance, so with
N data-parallel workers the server-side average cancels most of it — the
dither scale ``s`` can GROW with N (more per-node sparsity, fewer per-node
ops) at constant final accuracy. We reproduce the experiment by simulating
N nodes: per-node sub-batches, per-node dither keys (folded from the worker
index), gradient averaging, shared parameters.

The communication side lives in ``repro.comm``: ``make_ssgd_step`` takes an
optional ``CommPolicy`` that routes each node's gradient through the packed
NSD wire format (or int8 / top-k+EF) before the server-side reduce, with
measured bytes-on-wire telemetry. ``int8_allreduce_sim`` and the re-exported
``topk_error_feedback`` / ``ErrorFeedbackState`` (now implemented in
``repro.comm.compression``) remain for the single-tensor analogues.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.comm.compression import (TOPO_HIER, TOPO_PS, CommPolicy,
                                    ErrorFeedbackState, compress_leaf,
                                    topk_error_feedback)
from repro.comm import hierarchy as hier_mod
from repro.comm import ring as ring_mod
from repro.comm.hierarchy import hier_allreduce_nsd
from repro.comm.ring import ring_allreduce_nsd
from repro.core import nsd
from repro.core import stats as statslib
from repro.core.policy import DitherCtx, DitherPolicy, name_salt
from repro.core.schedule import PolicyProgram, as_program
from repro.obs.trace import annotate
from repro.models.api import Model
from repro.optim import OptConfig, apply_updates
from repro.utils.pytree import tree_map_with_path_str

__all__ = ["SSGDConfig", "ErrorFeedbackState", "int8_allreduce_sim",
           "make_ssgd_step", "shard_batch", "topk_error_feedback"]


@dataclasses.dataclass(frozen=True)
class SSGDConfig:
    n_nodes: int = 4
    s_schedule: str = "sqrt"  # fixed | linear | sqrt: how s scales with N
    s_base: float = 1.0

    def s_for_n(self) -> float:
        if self.s_schedule == "fixed":
            return self.s_base
        if self.s_schedule == "linear":
            return self.s_base * self.n_nodes
        # static hyperparameter math stays on the host: no device array here
        return self.s_base * math.sqrt(self.n_nodes)


def make_ssgd_step(model: Model, opt_cfg: OptConfig, dcfg: SSGDConfig,
                   base_policy: DitherPolicy | PolicyProgram,
                   comm_policy: Optional[CommPolicy] = None, *,
                   phase_step: int = 0, memory=None):
    """One SSGD step: N per-node dithered grads -> server average -> update.

    The batch leaves must have a leading (n_nodes, per_node_batch, ...) axis.
    Per-node dither keys are folded from (step, worker) so noise is i.i.d.
    across nodes — the cancellation the paper relies on.

    ``base_policy`` may be a :class:`repro.core.schedule.PolicyProgram`:
    every node resolves per-layer rules and knob schedules from the SAME
    program on the SAME traced step (and, when the program carries a
    sparsity controller, the SAME ``ctrl`` log-scale tree passed to the
    returned step function), so all data-parallel nodes see identical
    policies by construction. A plain DitherPolicy keeps the legacy
    behavior: its ``s`` is replaced by ``dcfg.s_for_n()``; a program is
    used verbatim (its author owns the s/N trade). The static variant
    phase is the one active at ``phase_step``.

    With ``comm_policy`` the node->server hop goes through the wire: each
    node's gradient leaves are compressed per the policy (per-node keys, so
    the comm-side NSD noise also cancels in the average) and the step's
    metrics gain ``comm_wire_bytes`` / ``comm_dense_bytes``.

    ``comm_policy.topology`` selects how that reduce is organized: the
    default "ps" keeps the parameter-server shape above; "ring" and "hier"
    replace the compress-then-average with the corresponding compressed
    all-reduce from ``repro.comm`` (flat ring / intra-pod ring + inter-pod
    tree with ``comm_policy.pods`` pods), whose re-dithered partial sums
    are what a real deployment would put on the wire. Those topologies add
    ``comm_error_bound`` (the reduce's pointwise bound vs the dense mean)
    to the step metrics.

    ``memory`` is a ``repro.memory`` MemoryPolicy (or spec string)
    selecting each dithered layer's residual codec / remat on every node —
    static per layer, baked into the compiled step exactly as the Trainer
    path does (tests pin the two paths numerically identical).
    """
    from repro.memory.policy import as_memory_policy

    program = as_program(base_policy)
    if isinstance(base_policy, DitherPolicy):
        program = program.replace(base=base_policy.replace(s=dcfg.s_for_n()))
    policy = program.phase_policy_at(phase_step)
    memory = as_memory_policy(memory)

    def node_grad(params, node_batch, base_key, step, worker, ctrl):
        ctx = DitherCtx.for_step(base_key, step, policy, worker=worker,
                                 program=program, ctrl=ctrl or None,
                                 memory=memory)
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, node_batch, ctx=ctx))(params)
        return loss, grads

    def compress_node_grads(grads, base_key, step):
        """Per-node, per-leaf wire compression before the server reduce.

        Reuses ``repro.comm.compression.compress_leaf`` (vmapped over the
        node axis) so wire-byte accounting has a single source of truth.
        EF is not available here (per-node residual state lives with the
        node, not the step), so topk_ef leaves fall back to NSD packing.
        """
        totals = {"wire": jnp.float32(0.0), "dense": jnp.float32(0.0)}

        def leaf(name: str, g_nodes: jax.Array) -> jax.Array:
            size = int(g_nodes.size) // dcfg.n_nodes
            mode = comm_policy.mode_for(name, size)
            if mode == "topk_ef":
                mode = "nsd"
            dense_bytes = jnp.float32(4 * size * dcfg.n_nodes)
            totals["dense"] = totals["dense"] + dense_bytes
            if mode == "dense":
                totals["wire"] = totals["wire"] + dense_bytes
                return g_nodes
            k0 = jax.random.fold_in(
                jax.random.fold_in(base_key, step), name_salt(name))

            def one(g, worker):
                kw = jax.random.fold_in(k0, worker)
                g_hat, wire, _ = compress_leaf(g, kw, mode, comm_policy)
                return g_hat, wire.astype(jnp.float32)

            g_hat, wires = jax.vmap(one)(g_nodes,
                                         jnp.arange(dcfg.n_nodes))
            totals["wire"] = totals["wire"] + jnp.sum(wires)
            return g_hat

        grads = tree_map_with_path_str(leaf, grads)
        return grads, totals

    def allreduce_node_grads(grads, base_key, step):
        """Topology-selected compressed all-reduce of the stacked grads.

        Per-leaf: compressible leaves go through the ring/hierarchy sim
        (``repro.comm.ring`` / ``repro.comm.hierarchy`` — identical math
        to the shard_map programs), returning the already-averaged tree;
        dense leaves average exactly. The compressed reduce's wire format
        IS packed NSD, so int8/topk_ef leaf modes degrade to ``nsd`` on
        this path (as ``compress_node_grads`` already does for topk_ef:
        per-node EF residual state lives with the node, not the step).
        Every leaf's ``dense`` counterfactual is the byte count the SAME
        topology would move at f32 (``dense_reduce_bytes``), so the
        wire/dense ratio compares like for like.
        """
        cfg = comm_policy.reduce_cfg()
        n = dcfg.n_nodes
        totals = {"wire": jnp.float32(0.0), "dense": jnp.float32(0.0),
                  "bound": jnp.float32(0.0)}

        def topo_dense_bytes(size: int) -> float:
            if comm_policy.topology == TOPO_HIER:
                return hier_mod.dense_reduce_bytes(
                    size, comm_policy.pods, n // comm_policy.pods,
                    comm_policy.chunk)
            return ring_mod.dense_reduce_bytes(size, n, comm_policy.chunk)

        def leaf(name: str, g_nodes: jax.Array) -> jax.Array:
            size = int(g_nodes.size) // n
            mode = comm_policy.mode_for(name, size)
            if mode == "dense":
                db = jnp.float32(topo_dense_bytes(size))
                totals["dense"] = totals["dense"] + db
                totals["wire"] = totals["wire"] + db
                return jnp.mean(g_nodes, axis=0)
            k0 = jax.random.fold_in(
                jax.random.fold_in(base_key, step), name_salt(name))
            if comm_policy.topology == TOPO_HIER:
                mean, tele = hier_allreduce_nsd(g_nodes, k0, cfg)
            else:
                mean, tele = ring_allreduce_nsd(g_nodes, k0, cfg)
            totals["wire"] = totals["wire"] + tele.wire_bytes
            totals["dense"] = totals["dense"] + tele.dense_bytes
            totals["bound"] = jnp.maximum(totals["bound"], tele.error_bound)
            return mean

        grads = tree_map_with_path_str(leaf, grads)
        return grads, totals

    def ssgd_step(params, opt_state, sharded_batch, base_key, ctrl=None):
        step = opt_state["step"]
        workers = jnp.arange(dcfg.n_nodes)
        with annotate("ssgd/grad"):
            losses, grads = jax.vmap(
                lambda b, w: node_grad(params, b, base_key, step, w, ctrl),
                in_axes=(0, 0))(sharded_batch, workers)
        comm_metrics = {}
        reduced = False
        if comm_policy is not None:
            if comm_policy.topology != TOPO_PS and dcfg.n_nodes > 1:
                with annotate("ssgd/reduce"):
                    grads, totals = allreduce_node_grads(
                        grads, base_key, step)
                comm_metrics = {"comm_wire_bytes": totals["wire"],
                                "comm_dense_bytes": totals["dense"],
                                "comm_error_bound": totals["bound"]}
                reduced = True
            else:
                with annotate("ssgd/reduce"):
                    grads, totals = compress_node_grads(
                        grads, base_key, step)
                comm_metrics = {"comm_wire_bytes": totals["wire"],
                                "comm_dense_bytes": totals["dense"]}
            if comm_policy.collect_stats:
                statslib.emit_comm(comm_policy.stats_tag, totals["wire"],
                                   totals["dense"])
        if not reduced:
            # parameter server: average the (already noisy) node gradients
            grads = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
        with annotate("ssgd/update"):
            params, opt_state, metrics = apply_updates(
                params, grads, opt_state, opt_cfg)
        metrics["loss"] = jnp.mean(losses)
        metrics.update(comm_metrics)
        return params, opt_state, metrics

    return jax.jit(ssgd_step), policy


def shard_batch(batch: Dict[str, jax.Array], n_nodes: int
                ) -> Dict[str, jax.Array]:
    def reshape(x):
        b = x.shape[0]
        assert b % n_nodes == 0, (b, n_nodes)
        return x.reshape((n_nodes, b // n_nodes) + x.shape[1:])

    return {k: reshape(v) for k, v in batch.items()}


# ---------------------------------------------------------------------------
# single-tensor comm analogues (kept for tests/benchmarks; the pytree-level
# machinery lives in repro.comm)
# ---------------------------------------------------------------------------

def int8_allreduce_sim(grads_per_node: List, key: jax.Array):
    """Each node NSD-quantizes its gradient to (int8, delta) before the
    reduce — the comm-side use of the paper's operator. Returns the average
    of dequantized tensors (what a quantized ring all-reduce would yield)."""
    n = len(grads_per_node)
    acc = None
    for i, g in enumerate(grads_per_node):
        q = nsd.nsd_quantize_int8(g, jax.random.fold_in(key, i), s=1.0)
        deq = q.dequantize()
        acc = deq if acc is None else acc + deq
    return acc / n
