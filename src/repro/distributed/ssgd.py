"""Synchronous SGD with per-node dithered backprop (paper §3.6 / §4.3).

The paper's argument: NSD noise is zero-mean with bounded variance, so with
N data-parallel workers the server-side average cancels most of it — the
dither scale ``s`` can GROW with N (more per-node sparsity, fewer per-node
ops) at constant final accuracy. We reproduce the experiment by simulating
N nodes: per-node sub-batches, per-node dither keys (folded from the worker
index), gradient averaging, shared parameters.

Also provides the communication-side analogues for real clusters
(int8-quantized and top-k+error-feedback gradient reduction).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import nsd
from repro.core.policy import DitherCtx, DitherPolicy
from repro.models.api import Model
from repro.optim import OptConfig, apply_updates, init_opt_state


@dataclasses.dataclass(frozen=True)
class SSGDConfig:
    n_nodes: int = 4
    s_schedule: str = "sqrt"  # fixed | linear | sqrt: how s scales with N
    s_base: float = 1.0

    def s_for_n(self) -> float:
        if self.s_schedule == "fixed":
            return self.s_base
        if self.s_schedule == "linear":
            return self.s_base * self.n_nodes
        return self.s_base * float(jnp.sqrt(self.n_nodes))


def make_ssgd_step(model: Model, opt_cfg: OptConfig, dcfg: SSGDConfig,
                   base_policy: DitherPolicy):
    """One SSGD step: N per-node dithered grads -> server average -> update.

    The batch leaves must have a leading (n_nodes, per_node_batch, ...) axis.
    Per-node dither keys are folded from (step, worker) so noise is i.i.d.
    across nodes — the cancellation the paper relies on.
    """
    policy = base_policy.replace(s=dcfg.s_for_n())

    def node_grad(params, node_batch, base_key, step, worker):
        ctx = DitherCtx.for_step(base_key, step, policy, worker=worker)
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, node_batch, ctx=ctx))(params)
        return loss, grads

    def ssgd_step(params, opt_state, sharded_batch, base_key):
        step = opt_state["step"]
        workers = jnp.arange(dcfg.n_nodes)
        losses, grads = jax.vmap(
            lambda b, w: node_grad(params, b, base_key, step, w),
            in_axes=(0, 0))(sharded_batch, workers)
        # parameter server: average the (already noisy) node gradients
        grads = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
        params, opt_state, metrics = apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics["loss"] = jnp.mean(losses)
        return params, opt_state, metrics

    return jax.jit(ssgd_step), policy


def shard_batch(batch: Dict[str, jax.Array], n_nodes: int
                ) -> Dict[str, jax.Array]:
    def reshape(x):
        b = x.shape[0]
        assert b % n_nodes == 0, (b, n_nodes)
        return x.reshape((n_nodes, b // n_nodes) + x.shape[1:])

    return {k: reshape(v) for k, v in batch.items()}


# ---------------------------------------------------------------------------
# gradient compression for the wire (real-cluster comm analogues)
# ---------------------------------------------------------------------------

def int8_allreduce_sim(grads_per_node: List, key: jax.Array):
    """Each node NSD-quantizes its gradient to (int8, delta) before the
    reduce — the comm-side use of the paper's operator. Returns the average
    of dequantized tensors (what a quantized ring all-reduce would yield)."""
    n = len(grads_per_node)
    acc = None
    for i, g in enumerate(grads_per_node):
        q = nsd.nsd_quantize_int8(g, jax.random.fold_in(key, i), s=1.0)
        deq = q.dequantize()
        acc = deq if acc is None else acc + deq
    return acc / n


@dataclasses.dataclass
class ErrorFeedbackState:
    residual: jax.Array


def topk_error_feedback(g: jax.Array, state: Optional[ErrorFeedbackState],
                        k_frac: float = 0.01
                        ) -> Tuple[jax.Array, ErrorFeedbackState]:
    """Top-k sparsification with error feedback (memory of dropped mass).

    Unbiasedness is restored asymptotically by the residual accumulator;
    composes with dithered backprop (which controls the *compute* side).
    """
    flat = g.reshape(-1)
    if state is not None:
        flat = flat + state.residual
    k = max(1, int(k_frac * flat.size))
    mag = jnp.abs(flat)
    thresh = jax.lax.top_k(mag, k)[0][-1]
    mask = mag >= thresh
    sent = jnp.where(mask, flat, 0)
    residual = flat - sent
    return sent.reshape(g.shape), ErrorFeedbackState(residual=residual)
