"""Serving workers: one engine per hosted model, a supervisor above them.

A :class:`Worker` wraps one :class:`~repro.serve.engine.Engine` for one
model-zoo config and gives it a stable name — the name is the tag its
per-tick rows carry on the ``serve`` obs stream, so health detectors and
run logs distinguish workers for free. A :class:`Supervisor` hosts several
workers (several zoo configs side by side), round-robins ticks across
them, routes requests by model name, and runs a
:class:`~repro.obs.monitor.MonitorSuite` with a
:class:`~repro.obs.monitor.ServeMonitor` over the shared stream — a
stalled worker trips a critical event; ``escalate=True`` turns that into
a raised :class:`~repro.obs.monitor.MonitorAlert`.

Everything is in-process and single-host: the point is the scheduling and
health surface, not RPC.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.models.api import Model
from repro.obs.monitor import MonitorSuite, ServeMonitor
from repro.serve.engine import Engine, Request, ServeConfig
from repro.utils import get_logger

log = get_logger("serve.worker")


@dataclasses.dataclass
class WorkerHealth:
    """Snapshot of one worker's state for health checks."""

    name: str
    model: str
    ticks: int
    active_slots: int
    queue_depth: int
    finished: int
    preemptions: int
    rejected: int

    @property
    def idle(self) -> bool:
        return self.active_slots == 0 and self.queue_depth == 0


class Worker:
    """One named engine hosting one model config."""

    def __init__(self, name: str, model: Model, params, cfg: ServeConfig):
        self.name = name
        self.model = model
        self.engine = Engine(model, params, cfg, name=name)
        self.results: Dict[int, List[int]] = {}
        self._finished = 0

    def submit(self, req: Request) -> bool:
        return self.engine.submit(req)

    def tick(self) -> None:
        self.engine.step()
        done = self.engine._finished
        if done:
            self._finished += len(done)
            self.results.update(done)
            self.engine._finished = {}

    @property
    def idle(self) -> bool:
        eng = self.engine
        return (all(s is None for s in eng._slots)
                and eng.sched.queue_depth == 0)

    def health(self) -> WorkerHealth:
        eng = self.engine
        return WorkerHealth(
            name=self.name, model=self.model.name, ticks=eng._tick,
            active_slots=sum(s is not None for s in eng._slots),
            queue_depth=eng.sched.queue_depth, finished=self._finished,
            preemptions=eng.preemptions, rejected=eng.sched.rejected)


class Supervisor:
    """Hosts several workers; routes by model name, ticks round-robin."""

    def __init__(self, *, escalate: bool = False, max_backlog: float = 32.0,
                 stall_ticks: int = 8):
        self.workers: Dict[str, Worker] = {}
        self.monitors = MonitorSuite(
            [ServeMonitor(max_backlog=max_backlog, min_rows=stall_ticks)],
            escalate=escalate)
        self._uid = 0
        self._route: Dict[int, str] = {}  # uid -> worker name

    def add_worker(self, name: str, model: Model, params,
                   cfg: ServeConfig) -> Worker:
        if name in self.workers:
            raise ValueError(f"duplicate worker name {name!r}")
        w = Worker(name, model, params, cfg)
        self.workers[name] = w
        log.info("worker %s hosting %s (batch=%d, kv=%s%s)", name,
                 model.name, cfg.max_batch, cfg.kv_mode,
                 f"/page{cfg.kv_page}" if cfg.kv_page else "/dense")
        return w

    def _worker_for(self, model_name: Optional[str]) -> Worker:
        if model_name is None:
            if len(self.workers) != 1:
                raise ValueError("model name required with several workers")
            return next(iter(self.workers.values()))
        for w in self.workers.values():
            if w.model.name == model_name or w.name == model_name:
                return w
        raise KeyError(f"no worker hosts {model_name!r}; have "
                       f"{[w.model.name for w in self.workers.values()]}")

    def submit(self, prompt, max_new_tokens: int = 16,
               model: Optional[str] = None) -> Optional[int]:
        """Route a prompt; returns the request uid, or None when the
        worker's queue bound rejected it."""
        w = self._worker_for(model)
        uid = self._uid
        self._uid += 1
        ok = w.submit(Request(uid, np.asarray(prompt, np.int32),
                              max_new_tokens=max_new_tokens))
        if not ok:
            return None
        self._route[uid] = w.name
        return uid

    def tick(self) -> None:
        """One supervisor tick: every worker steps, then health runs."""
        for w in self.workers.values():
            w.tick()
        step = max(w.engine._tick for w in self.workers.values())
        self.monitors.tick(step)

    def run(self, max_ticks: int = 256) -> Dict[int, List[int]]:
        """Tick until every worker drains or ``max_ticks``; returns all
        finished {uid: tokens} accumulated so far."""
        for _ in range(max_ticks):
            self.tick()
            if all(w.idle for w in self.workers.values()):
                break
        out: Dict[int, List[int]] = {}
        for w in self.workers.values():
            out.update(w.results)
        return out

    def result(self, uid: int) -> Optional[List[int]]:
        name = self._route.get(uid)
        if name is None:
            return None
        return self.workers[name].results.get(uid)

    def health(self) -> List[WorkerHealth]:
        return [w.health() for w in self.workers.values()]
