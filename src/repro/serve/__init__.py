from repro.serve.engine import Engine, Request, ServeConfig, greedy_generate
from repro.serve.kvcache import KV_MODES, PagedKV, init_paged, pages_for
from repro.serve.scheduler import PagePool, Scheduler, SchedulerConfig
from repro.serve.worker import Supervisor, Worker, WorkerHealth

__all__ = [
    "Engine", "Request", "ServeConfig", "greedy_generate",
    "KV_MODES", "PagedKV", "init_paged", "pages_for",
    "PagePool", "Scheduler", "SchedulerConfig",
    "Supervisor", "Worker", "WorkerHealth",
]
