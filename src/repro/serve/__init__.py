from repro.serve.engine import Engine, Request, ServeConfig, greedy_generate

__all__ = ["Engine", "Request", "ServeConfig", "greedy_generate"]
