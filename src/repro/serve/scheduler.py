"""Admission control + page-pool-aware scheduling (host side).

The engine owns the device step; this module owns the host bookkeeping
around it: a bounded request queue, an active-token budget, and — in paged
mode — the shared physical page pool with per-slot allocation, release,
and the free-list arithmetic behind preemption decisions.

Policy (deliberately simple, deterministic, and test-pinned):

* FIFO admission, gated by queue bound and ``max_active_tokens`` (the sum
  of prompt + max_new_tokens across active slots).
* A request whose worst-case footprint can never fit the pool is rejected
  at submit time — admitting it would deadlock the preemption loop.
* On pool exhaustion the engine preempts the *youngest* active slot
  (least work lost; its request requeues at the FRONT with the tokens it
  already generated folded into the replay prompt, so greedy decoding
  reproduces the same output after re-admission).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, List, Optional

import numpy as np

from repro.serve.kvcache import pages_for


class PagePool:
    """Free-list allocator over ``n_pages`` physical pages."""

    def __init__(self, n_pages: int, page: int):
        if n_pages < 1 or page < 1:
            raise ValueError("n_pages and page must be >= 1")
        self.n_pages = int(n_pages)
        self.page = int(page)
        # LIFO free list: recently released pages are re-used first, which
        # keeps the working set of physical ids small and deterministic
        self._free: List[int] = list(range(n_pages - 1, -1, -1))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` pages, all-or-nothing; None when the pool is short."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        return out

    def free(self, ids) -> None:
        for pid in ids:
            pid = int(pid)
            if not 0 <= pid < self.n_pages or pid in self._free:
                raise ValueError(f"double/invalid free of page {pid}")
            self._free.append(pid)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_queue: int = 0  # pending requests bound; 0 = unbounded
    max_active_tokens: int = 0  # sum(prompt+max_new) over active; 0 = unbounded


class Scheduler:
    """Queue + (optional) page-table bookkeeping for ``max_batch`` slots."""

    def __init__(self, cfg: SchedulerConfig, max_batch: int,
                 max_pages_per_slot: int = 0,
                 pool: Optional[PagePool] = None):
        self.cfg = cfg
        self.max_batch = int(max_batch)
        self.pool = pool
        self.max_pages = int(max_pages_per_slot)
        self._queue: Deque = collections.deque()
        self._pages: List[List[int]] = [[] for _ in range(max_batch)]
        self.rejected = 0  # queue-bound rejections (telemetry)

    # ------------------------------------------------------------- queue
    def submit(self, req, *, tokens_worst_case: int) -> bool:
        """Enqueue; False when the queue bound rejects it. Raises when the
        request can NEVER fit the pool (admitting it would deadlock)."""
        if self.pool is not None:
            need = pages_for(tokens_worst_case, self.pool.page)
            cap = min(self.pool.n_pages, self.max_pages or need)
            if need > cap:
                raise ValueError(
                    f"request needs {need} pages (prompt+max_new="
                    f"{tokens_worst_case}) but the pool caps at {cap}")
        if self.cfg.max_queue and len(self._queue) >= self.cfg.max_queue:
            self.rejected += 1
            return False
        self._queue.append(req)
        return True

    def requeue_front(self, req) -> None:
        """Preempted work goes to the head: it already holds progress."""
        self._queue.appendleft(req)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def next_request(self, active_tokens: int, tokens_of) -> Optional[object]:
        """Pop the head request if the token budget admits it."""
        if not self._queue:
            return None
        head = self._queue[0]
        if (self.cfg.max_active_tokens
                and active_tokens + tokens_of(head)
                > self.cfg.max_active_tokens):
            return None
        return self._queue.popleft()

    # ------------------------------------------------------------- pages
    def slot_pages(self, slot: int) -> List[int]:
        return self._pages[slot]

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow slot's mapping to cover ``n_tokens`` positions; False when
        the pool cannot supply the missing pages (caller preempts)."""
        if self.pool is None:
            return True
        need = pages_for(n_tokens, self.pool.page)
        have = len(self._pages[slot])
        if need <= have:
            return True
        got = self.pool.alloc(need - have)
        if got is None:
            return False
        self._pages[slot].extend(got)
        return True

    def release(self, slot: int) -> None:
        if self.pool is not None and self._pages[slot]:
            self.pool.free(self._pages[slot])
        self._pages[slot] = []

    def table(self) -> np.ndarray:
        """(max_batch, max_pages) physical-id table, -1 for unmapped."""
        t = np.full((self.max_batch, max(self.max_pages, 1)), -1, np.int32)
        for s, ids in enumerate(self._pages):
            t[s, :len(ids)] = ids
        return t
