"""Paged KV cache: fixed-size pages from a shared pool, codec-encoded.

vLLM-style layout adapted to the repo's codec family: each attention layer
owns a pool of ``n_pages`` physical pages, each holding ``page`` token
positions of K and V. A slot's logical pages map to physical ids through a
per-slot ``page_table`` (shared across layers — every layer sees the same
token positions); the host-side free list lives in
``repro.serve.scheduler.PagePool``.

Pages are *sealed* through the quant engine (``repro.quant``): while a
slot writes
positions into its current page, the raw values sit in a per-slot fp
``tail`` buffer; the micro-step that fills the page's last position encodes
the tail (fp32 passthrough / bf16 / int8 affine-per-row / NSD wire format —
the same bit-exact-tested family the residual store uses, the paper's
§"8-bit compatibility" argument applied to inference memory) and scatters
it into the pool. Reads gather the slot's pages, decode them, and overlay
the raw tail, so the newest (unsealed) positions are always exact.

Everything is shape-static and SPMD-uniform: inactive slots carry t < 0,
their writes park one index out of bounds (JAX scatter drops them) and
their key positions are masked invalid. ``update_and_view`` is the single
hook ``repro.models.layers.attention`` calls — models never see the page
math.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro import quant as codec

# The documented common set; any registered quant codec spec (e.g.
# "int4@g32") is a valid page mode — init_paged validates through the
# registry, so new codecs reach KV pages with zero code here.
KV_MODES = ("fp32", "bf16", "int8", "nsd")


def _encode_page(mode: str, x: jax.Array, key: jax.Array):
    """Encode one (page, KV, hd) tail page; vmapped over pages."""
    return codec.encode(mode, x, codec.resid_key(key))


def _decode_page(mode: str, enc):
    return codec.decode(mode, enc)


def page_stored_nbytes(mode: str, page: int, n_kv: int, hd: int) -> int:
    """Static capacity bytes of one encoded K+V page (fp32 accounting)."""
    return 2 * codec.stored_nbytes(mode, (page, n_kv, hd), jnp.float32)


def page_dense_nbytes(page: int, n_kv: int, hd: int) -> int:
    """Dense fp32 counterfactual bytes of one K+V page."""
    return 2 * codec.dense_nbytes((page, n_kv, hd), jnp.float32)


def pages_for(n_tokens: int, page: int) -> int:
    """Logical pages covering ``n_tokens`` positions."""
    return -(-int(n_tokens) // page)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PagedKV:
    """One attention layer's paged K/V state (jit-safe pytree).

    ``pool_k``/``pool_v`` are the codec-encoded page stores: for fp32 a raw
    (n_pages, page, KV, hd) array, otherwise the codec's container with an
    added leading n_pages axis (built by vmapped encode, so the static
    shape metadata stays per-page). ``page_table`` maps (slot, logical
    page) -> physical id, -1 for unmapped.
    """

    pool_k: object
    pool_v: object
    tail_k: jax.Array  # (B, page, KV, hd) raw current-page buffer
    tail_v: jax.Array
    page_table: jax.Array  # (B, max_pages) int32
    key: jax.Array  # base PRNG key; per-page streams fold in the page id
    mode: str = dataclasses.field(metadata=dict(static=True), default="fp32")
    page: int = dataclasses.field(metadata=dict(static=True), default=16)
    n_pages: int = dataclasses.field(metadata=dict(static=True), default=0)

    @property
    def max_pages(self) -> int:
        return self.page_table.shape[1]

    @property
    def view_len(self) -> int:
        return self.max_pages * self.page

    def with_table(self, table: jax.Array) -> "PagedKV":
        return dataclasses.replace(
            self, page_table=jnp.asarray(table, jnp.int32))

    def update_and_view(self, k: jax.Array, v: jax.Array, t: jax.Array
                        ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                   jax.Array, "PagedKV"]:
        """Write one token per slot, seal filled pages, return the view.

        k/v: (B, 1, KV, hd) new projections; t: (B,) absolute positions,
        t < 0 for inactive slots. Returns (K, V, k_pos, valid, new_cache)
        with K/V (B, max_pages*page, KV, hd) and valid masking both unused
        view positions and inactive slots.
        """
        B = t.shape[0]
        page, n_pages, P = self.page, self.n_pages, self.max_pages
        rows = jnp.arange(B)
        active = t >= 0
        off = jnp.where(active, t % page, page)  # park inactive (drop)
        cur = jnp.clip(jnp.where(active, t // page, 0), 0, P - 1)

        tail_k = self.tail_k.at[rows, off].set(
            k[:, 0].astype(self.tail_k.dtype), mode="drop")
        tail_v = self.tail_v.at[rows, off].set(
            v[:, 0].astype(self.tail_v.dtype), mode="drop")

        # seal: the write that fills a page encodes + scatters it; rows not
        # sealing park at pid == n_pages (dropped). Encoding all B tails is
        # wasted work on non-seal ticks but keeps the step SPMD-uniform.
        mapped = self.page_table[rows, cur]
        seal = active & (t % page == page - 1) & (mapped >= 0)
        pid = jnp.where(seal, mapped, n_pages)
        keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(self.key, pid)
        enc_k = jax.vmap(lambda x, kk: _encode_page(self.mode, x, kk))(
            tail_k.astype(jnp.float32), keys)
        enc_v = jax.vmap(lambda x, kk: _encode_page(self.mode, x, kk))(
            tail_v.astype(jnp.float32), keys)
        pool_k = jax.tree.map(
            lambda pool, new: pool.at[pid].set(new, mode="drop"),
            self.pool_k, enc_k)
        pool_v = jax.tree.map(
            lambda pool, new: pool.at[pid].set(new, mode="drop"),
            self.pool_v, enc_v)

        # view: gather + decode this slot's pages, overlay the raw tail
        ids = jnp.clip(self.page_table, 0, max(n_pages - 1, 0))  # (B, P)
        dec = jax.vmap(jax.vmap(lambda e: _decode_page(self.mode, e)))
        K = dec(jax.tree.map(lambda a: a[ids], pool_k))
        V = dec(jax.tree.map(lambda a: a[ids], pool_v))
        K = K.reshape(B, P * page, *K.shape[3:])
        V = V.reshape(B, P * page, *V.shape[3:])
        overlay = jax.vmap(
            lambda full, tail, c: jax.lax.dynamic_update_slice(
                full, tail.astype(full.dtype), (c * page, 0, 0)))
        K = overlay(K, tail_k, cur)
        V = overlay(V, tail_v, cur)

        k_pos = jnp.broadcast_to(jnp.arange(P * page), (B, P * page))
        valid = (k_pos <= t[:, None]) & active[:, None]
        new = dataclasses.replace(self, pool_k=pool_k, pool_v=pool_v,
                                  tail_k=tail_k, tail_v=tail_v)
        return K, V, k_pos, valid, new


def init_paged(mode: str, batch: int, max_len: int, n_pages: int, page: int,
               n_kv: int, hd: int, dtype, key: jax.Array) -> PagedKV:
    """Zero-initialized paged cache for one layer.

    ``max_len`` bounds the logical pages per slot; ``n_pages`` is the
    shared physical pool (oversubscription is the scheduler's job).
    """
    try:
        codec.validate_spec(mode)
    except ValueError:
        raise ValueError(f"kv mode {mode!r}: one of {KV_MODES} or a "
                         f"registered quant codec spec") from None
    if page < 1 or n_pages < 1:
        raise ValueError("page and n_pages must be >= 1")
    max_pages = pages_for(max_len, page)
    zero = jnp.zeros((page, n_kv, hd), jnp.float32)
    enc_one = _encode_page(mode, zero, key)
    pool = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_pages,) + a.shape).copy(), enc_one)
    tail = jnp.zeros((batch, page, n_kv, hd), dtype)
    table = jnp.full((batch, max_pages), -1, jnp.int32)
    return PagedKV(pool_k=pool, pool_v=pool, tail_k=tail, tail_v=tail,
                   page_table=table, key=key, mode=mode, page=page,
                   n_pages=n_pages)
