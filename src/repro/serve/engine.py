"""Throughput-oriented serving engine: chunked prefill + paged KV cache.

A fixed pool of B slots advances in SPMD-uniform jitted ticks. Each tick
feeds up to ``chunk`` tokens per slot through a ``lax.scan`` of decode
micro-steps: slots still consuming their prompt feed a prompt chunk
(chunked prefill), slots in steady state feed the token they generated
last tick, empty slots ride along fully masked. Every slot carries its own
position counter — a request admitted at tick 40 writes cache position 0,
not 40 — and inactive micro-steps are encoded as position ``t = -1``
(writes park out of bounds and drop; attention masks the slot entirely;
state-space caches are reselected to their old value).

KV storage is either the dense per-slot buffers from ``Model.init_cache``
(``kv_page=0``) or the paged, codec-quantized pool in
``repro.serve.kvcache`` — admission, page allocation, and
preemption-and-recompute on pool exhaustion live in
``repro.serve.scheduler``. A preempted request requeues at the front with
its generated tokens folded into the replay prompt, so greedy decoding
completes with the same output it would have produced uninterrupted.

Per-tick telemetry (occupancy, fed/generated tokens, KV capacity bytes vs
the dense fp32 counterfactual) lands on the ``serve`` obs stream.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.obs.bus import get_bus
from repro.obs.trace import span
from repro.serve import kvcache
from repro.serve.scheduler import PagePool, Scheduler, SchedulerConfig
from repro.utils import get_logger

log = get_logger("serve")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    eos_id: int = -1  # -1: never stop early
    chunk: int = 8  # prompt tokens fused into one tick (chunked prefill)
    kv_mode: str = "fp32"  # fp32 | bf16 | int8 | nsd (paged mode only)
    kv_page: int = 0  # tokens per KV page; 0 = dense per-slot buffers
    kv_pool_pages: int = 0  # physical pages; 0 = auto (no oversubscription)
    max_queue: int = 0  # pending-request bound; 0 = unbounded
    max_active_tokens: int = 0  # admission token budget; 0 = unbounded


def _is_paged(x) -> bool:
    return hasattr(x, "update_and_view")


def _select_cache(active: jax.Array, new, old):
    """Per-slot cache select: keep ``old`` rows where the slot was inactive
    this micro-step. Paged caches pass through — their writes are already
    masked internally by the t < 0 convention (pool leaves are page-major,
    not batch-major, so a tree-wide where would be wrong for them)."""
    B = active.shape[0]

    def sel(n, o):
        if _is_paged(n):
            return n
        assert n.shape[0] == B, f"cache leaf not batch-major: {n.shape}"
        return jnp.where(active.reshape((B,) + (1,) * (n.ndim - 1)), n, o)

    return jax.tree.map(sel, new, old, is_leaf=_is_paged)


def _copy_slot(cache, template, i: int):
    """Reset slot ``i`` to the template row (fresh mamba state / hybrid
    meta-bootstrapped KV). Paged leaves skip — replayed positions overwrite
    and stale ones stay masked."""

    def cp(c, tpl):
        if _is_paged(c):
            return c
        return c.at[i].set(tpl[i])

    return jax.tree.map(cp, cache, template, is_leaf=_is_paged)


class Engine:
    def __init__(self, model: Model, params: Any, cfg: ServeConfig,
                 name: str = "engine"):
        assert model.decode_step is not None, f"{model.name} cannot decode"
        if model.family == "audio":
            raise ValueError(
                "encoder-decoder models need per-request encoder features; "
                "serve them through greedy_generate(model, ..., frames=...)")
        if cfg.chunk < 1:
            raise ValueError("chunk must be >= 1")
        self.model = model
        self.params = params
        self.cfg = cfg
        self.name = name
        B = cfg.max_batch
        # position of text token 0 (hybrid prepends learnable meta tokens)
        self._pos_base = int(getattr(model.cfg, "n_meta_tokens", 0))

        pool = None
        self._max_pages = 0
        if cfg.kv_page > 0:
            self._max_pages = kvcache.pages_for(cfg.max_len, cfg.kv_page)
            n_pages = (cfg.kv_pool_pages
                       or B * self._max_pages)
            pool = PagePool(n_pages, cfg.kv_page)
            self.cache = self._paged_cache(n_pages)
            # paged leaves skip slot reset (replay overwrites, t-masking
            # hides the rest), so the template is the cache itself
            self._template = self.cache
        else:
            self._template = self._fresh_cache()
            self.cache = self._template
        self.sched = Scheduler(
            SchedulerConfig(max_queue=cfg.max_queue,
                            max_active_tokens=cfg.max_active_tokens),
            B, self._max_pages, pool)

        self._slots: List[Optional[Request]] = [None] * B
        self._prompt: List[Optional[np.ndarray]] = [None] * B  # replay prompt
        self._fed = np.zeros(B, np.int64)  # prompt tokens consumed
        self._remaining = np.zeros(B, np.int64)
        self._next_tok = np.zeros(B, np.int64)  # steady-state feed token
        self._seq = np.zeros(B, np.int64)  # admission order (for preemption)
        self._admit_counter = 0
        self._tick = 0
        self.preemptions = 0
        self._finished: Dict[int, List[int]] = {}
        self._table_pushed: Optional[np.ndarray] = None

    # ------------------------------------------------------------ caches
    def _fresh_cache(self):
        """Per-slot reset template. Hybrid models replay their meta-token
        prefix in (decode starts at position n_meta_tokens)."""
        B, S = self.cfg.max_batch, self.cfg.max_len
        if self.model.family == "hybrid":
            from repro.models import hybrid as hy
            return jax.jit(
                lambda p: hy.bootstrap_cache(p, self.model.cfg, B, S)
            )(self.params)
        return self.model.init_cache(B, S)

    def _paged_cache(self, n_pages: int):
        cfg, mcfg = self.cfg, self.model.cfg
        dense = self.model.init_cache(cfg.max_batch, cfg.max_len)
        if not all(isinstance(c, tuple) and len(c) == 2 for c in dense):
            raise ValueError(
                f"paged KV needs per-layer (K, V) caches; {self.model.name} "
                f"({self.model.family}) keeps other state — use kv_page=0")
        if getattr(mcfg, "window", None) is not None:
            raise ValueError(
                "paged KV does not cover sliding-window ring buffers yet; "
                "use kv_page=0 for windowed configs")
        key = jax.random.PRNGKey(0x9A6E)
        out = []
        for i, (K, _) in enumerate(dense):
            _, _, n_kv, hd = K.shape
            out.append(kvcache.init_paged(
                cfg.kv_mode, cfg.max_batch, cfg.max_len, n_pages,
                cfg.kv_page, n_kv, hd, K.dtype, jax.random.fold_in(key, i)))
        # dual byte accounting for telemetry: encoded capacity per sealed
        # page vs its dense fp32 counterfactual, summed over layers
        self._page_bytes = sum(
            kvcache.page_stored_nbytes(cfg.kv_mode, cfg.kv_page, K.shape[2],
                                       K.shape[3]) for K, _ in dense)
        self._page_dense = sum(
            kvcache.page_dense_nbytes(cfg.kv_page, K.shape[2], K.shape[3])
            for K, _ in dense)
        return out

    def _push_table(self) -> None:
        if self.cfg.kv_page <= 0:
            return
        table = self.sched.table()
        if (self._table_pushed is not None
                and np.array_equal(table, self._table_pushed)):
            return
        dev = jnp.asarray(table)
        self.cache = [c.with_table(dev) if _is_paged(c) else c
                      for c in self.cache]
        self._table_pushed = table

    def _kv_bytes(self) -> tuple:
        """(capacity bytes, dense fp32 counterfactual) of live KV state."""
        if self.cfg.kv_page > 0:
            used = self.sched.pool.used_pages
            return used * self._page_bytes, used * self._page_dense
        n = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                for l in jax.tree.leaves(self.cache))
        return n, n

    # ------------------------------------------------------------ request API
    def submit(self, req: Request) -> bool:
        """Enqueue a request; False when the queue bound rejects it."""
        req.out_tokens = []
        worst = len(req.prompt) + req.max_new_tokens
        return self.sched.submit(req, tokens_worst_case=worst)

    def _tokens_of(self, req: Request) -> int:
        return len(req.prompt) + req.max_new_tokens

    def _active_tokens(self) -> int:
        return sum(self._tokens_of(r) for r in self._slots if r is not None)

    def _admit(self) -> None:
        for i in range(self.cfg.max_batch):
            if self._slots[i] is not None:
                continue
            req = self.sched.next_request(self._active_tokens(),
                                          self._tokens_of)
            if req is None:
                return
            if req.max_new_tokens - len(req.out_tokens) <= 0:
                # nothing to generate: complete without occupying a slot
                self._finish_tokens(req)
                continue
            self._slots[i] = req
            # replay = original prompt + whatever a preempted run already
            # generated; greedy decode reproduces the rest deterministically
            self._prompt[i] = np.concatenate(
                [np.asarray(req.prompt, np.int64),
                 np.asarray(req.out_tokens, np.int64)])
            self._fed[i] = 0
            self._remaining[i] = req.max_new_tokens - len(req.out_tokens)
            self._seq[i] = self._admit_counter
            self._admit_counter += 1
            self.cache = _copy_slot(self.cache, self._template, i)

    def _finish_tokens(self, req: Request) -> None:
        self._finished[req.uid] = req.out_tokens
        log.info("request %d finished (%d tokens)", req.uid,
                 len(req.out_tokens))

    def _finish_slot(self, i: int) -> None:
        self._finish_tokens(self._slots[i])
        self._slots[i] = None
        self._prompt[i] = None
        self.sched.release(i)

    def _preempt(self, i: int) -> None:
        req = self._slots[i]
        self.preemptions += 1
        log.info("preempting request %d (slot %d, %d generated)", req.uid, i,
                 len(req.out_tokens))
        self._slots[i] = None
        self._prompt[i] = None
        self.sched.release(i)
        self.sched.requeue_front(req)

    # ------------------------------------------------------------ stepping
    @functools.lru_cache(maxsize=None)
    def _step_fn(self, C: int):
        decode = self.model.decode_step

        def step(params, cache, tok_block, n_feed, pos0):
            def body(cache, i):
                active = i < n_feed
                t = jnp.where(active, pos0 + i, -1)
                tok = jax.lax.dynamic_slice_in_dim(tok_block, i, 1, axis=1)
                logits, new_cache = decode(params, cache, tok, t)
                return _select_cache(active, new_cache, cache), logits[:, 0]

            cache, logits_seq = jax.lax.scan(body, cache, jnp.arange(C))
            idx = jnp.clip(n_feed - 1, 0, C - 1)
            last = jnp.take_along_axis(
                jnp.moveaxis(logits_seq, 0, 1), idx[:, None, None],
                axis=1)[:, 0]
            return jnp.argmax(last, axis=-1).astype(jnp.int32), cache

        return jax.jit(step)

    def _plan(self):
        """Per-slot feed plan for this tick; allocates pages, preempting
        the youngest slot when the pool runs dry."""
        B, C = self.cfg.max_batch, self.cfg.chunk
        plan = {}  # slot -> (tokens, n_feed, pos0)
        order = sorted((s for s in range(B) if self._slots[s] is not None),
                       key=lambda s: self._seq[s])
        for s in order:
            if self._slots[s] is None:  # preempted by an earlier iteration
                continue
            prompt, fed = self._prompt[s], int(self._fed[s])
            if fed < len(prompt):
                n = min(C, len(prompt) - fed)
                toks = prompt[fed:fed + n]
            else:
                n = 1
                toks = np.asarray([self._next_tok[s]], np.int64)
            while not self.sched.ensure(s, fed + n):
                victims = [v for v in range(B) if self._slots[v] is not None]
                victim = max(victims, key=lambda v: self._seq[v])
                self._preempt(victim)
                plan.pop(victim, None)
                if victim == s:
                    break
            if self._slots[s] is None:
                continue
            plan[s] = (toks, n, self._pos_base + fed)
        return plan

    def step(self) -> None:
        """One engine tick: admit, plan pages, run the fused chunk."""
        with span("serve/admit"):
            self._admit()
            plan = self._plan()
            self._push_table()
        B = self.cfg.max_batch
        C = self.cfg.chunk if any(n > 1 for _, n, _ in plan.values()) else 1
        tok_block = np.zeros((B, C), np.int32)
        n_feed = np.zeros(B, np.int32)
        pos0 = np.zeros(B, np.int32)
        for s, (toks, n, p0) in plan.items():
            tok_block[s, :n] = toks
            n_feed[s] = n
            pos0[s] = p0

        active = sum(s is not None for s in self._slots)
        gen = 0
        if plan:
            with span("serve/decode"):
                nxt, self.cache = self._step_fn(C)(
                    self.params, self.cache, jnp.asarray(tok_block),
                    jnp.asarray(n_feed), jnp.asarray(pos0))
            nxt_np = np.asarray(nxt)
            for s in list(plan):
                if self._slots[s] is None:
                    continue
                _, n, _ = plan[s]
                self._fed[s] += n
                if self._fed[s] < len(self._prompt[s]):
                    continue  # still prefilling; no sample point yet
                tok = int(nxt_np[s])
                req = self._slots[s]
                req.out_tokens.append(tok)
                gen += 1
                self._remaining[s] -= 1
                self._next_tok[s] = tok
                if self._remaining[s] <= 0 or tok == self.cfg.eos_id:
                    self._finish_slot(s)

        kv_bytes, kv_dense = self._kv_bytes()
        get_bus().record("serve", self.name, np.array(
            [self._tick, active, self.sched.queue_depth,
             int(n_feed.sum()), gen, float(kv_bytes), float(kv_dense)],
            np.float32))
        self._tick += 1

    def run(self, max_ticks: int = 64) -> Dict[int, List[int]]:
        """Tick until idle or ``max_ticks``; returns {uid: tokens} finished
        during this call (requests still queued/active stay pending)."""
        self._finished = {}
        for _ in range(max_ticks):
            self.step()
            if (all(s is None for s in self._slots)
                    and self.sched.queue_depth == 0):
                break
        return self._finished


def greedy_generate(model: Model, params, prompt, n_new: int,
                    max_len: int = 256, **extras) -> List[int]:
    """Single-sequence reference path: ``Model.prefill`` + greedy decode.

    Covers every decoding family uniformly (transformer/ssm/hybrid via
    their prefill; encoder-decoder via ``frames=...``). The engine's
    fp32-page output is gated bit-exact against this in serve_bench.
    """
    if model.prefill is None:
        raise ValueError(f"{model.name} has no prefill")
    if n_new <= 0:
        return []
    prompt = jnp.asarray(prompt, jnp.int32)
    if prompt.ndim == 1:
        prompt = prompt[None]
    logits, cache, t = model.prefill(params, prompt, max_len, **extras)
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    out = [int(tok[0, 0])]
    step = jax.jit(lambda p, c, tk, tt: model.decode_step(p, c, tk, tt))
    for _ in range(n_new - 1):
        t = t + 1
        logits, cache = step(params, cache, tok, t)
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        out.append(int(tok[0, 0]))
    return out
