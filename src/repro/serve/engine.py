"""Batched serving engine: slot-based continuous batching over the model
zoo's prefill/decode steps.

A fixed pool of B slots runs one decode step per tick for every active slot
(SPMD-friendly: the jitted step always sees the full (B, 1) token block).
Finished/empty slots decode padding and are ignored. Prefill currently runs
per request at the engine level (the dry-run covers the batched 32k prefill
cell; fusing prefill into the decode ticks — chunked prefill — is left as a
documented extension point).
"""
from __future__ import annotations

import dataclasses
import queue
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.obs.bus import get_bus
from repro.obs.trace import span
from repro.utils import get_logger

log = get_logger("serve")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    eos_id: int = -1  # -1: never stop early


class Engine:
    def __init__(self, model: Model, params: Any, cfg: ServeConfig):
        assert model.decode_step is not None, f"{model.name} cannot decode"
        self.model = model
        self.params = params
        self.cfg = cfg
        self._queue: "queue.Queue[Request]" = queue.Queue()
        self._slots: List[Optional[Request]] = [None] * cfg.max_batch
        self._remaining = np.zeros(cfg.max_batch, np.int32)
        self.cache = model.init_cache(cfg.max_batch, cfg.max_len)
        self.t = jnp.zeros((), jnp.int32)
        self.tokens = jnp.zeros((cfg.max_batch, 1), jnp.int32)
        self._tick = 0  # host-side tick counter for the "serve" stream
        self._decode = jax.jit(
            lambda p, c, tok, t: model.decode_step(p, c, tok, t))

    # ------------------------------------------------------------------ API
    def submit(self, req: Request) -> None:
        req.out_tokens = []
        self._queue.put(req)

    def _admit(self) -> None:
        for i in range(self.cfg.max_batch):
            if self._slots[i] is None and not self._queue.empty():
                req = self._queue.get()
                self._slots[i] = req
                self._remaining[i] = req.max_new_tokens
                # teacher-forced "prefill": feed prompt tokens one step at a
                # time into this slot (slot-aligned positions keep the step
                # SPMD-uniform; bulk prefill is exercised by prefill_32k)
                for tok in req.prompt:
                    self.tokens = self.tokens.at[i, 0].set(int(tok))

    def step(self) -> None:
        """One decode tick for all slots."""
        with span("serve/admit"):
            self._admit()
        # per-tick occupancy telemetry (host-side record; ticks are bounded
        # by run()'s max_ticks, so the bus stays bounded too)
        get_bus().record("serve", "engine", np.array(
            [self._tick, sum(s is not None for s in self._slots),
             self._queue.qsize()], np.float32))
        self._tick += 1
        with span("serve/decode"):
            logits, self.cache = self._decode(
                self.params, self.cache, self.tokens, self.t)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        nxt_np = np.asarray(nxt)
        for i, req in enumerate(self._slots):
            if req is None:
                continue
            tok = int(nxt_np[i])
            req.out_tokens.append(tok)
            self._remaining[i] -= 1
            if self._remaining[i] <= 0 or tok == self.cfg.eos_id:
                log.info("request %d finished (%d tokens)", req.uid,
                         len(req.out_tokens))
                self._slots[i] = None
        self.tokens = nxt[:, None]
        self.t = self.t + 1

    def run(self, max_ticks: int = 64) -> Dict[int, List[int]]:
        done: Dict[int, List[int]] = {}
        for _ in range(max_ticks):
            active_before = {r.uid: r for r in self._slots if r}
            self.step()
            for uid, req in active_before.items():
                if req not in self._slots:
                    done[uid] = req.out_tokens
            if all(s is None for s in self._slots) and self._queue.empty():
                break
        return done


def greedy_generate(model: Model, params, prompt: jax.Array,
                    n_new: int, max_len: int = 256):
    """Single-sequence reference path: prefill + greedy decode loop.

    Used by tests to check prefill/decode consistency against the full
    forward pass.
    """
    from repro.models import transformer as tf

    logits, cache, t = tf.prefill(params, model.cfg, prompt, max_len)
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    out = [int(tok[0, 0])]
    step = jax.jit(lambda p, c, tk, tt: model.decode_step(p, c, tk, tt))
    for i in range(n_new - 1):
        t = t + 1
        logits, cache = step(params, cache, tok, t)
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        out.append(int(tok[0, 0]))
    return out
