"""Optimizers (no optax in this environment — built from scratch).

SGD-momentum matches the paper's training recipe (momentum 0.9, weight decay
5e-4); AdamW is the LM default. bf16 params keep an f32 master copy in the
optimizer state (mixed-precision convention), f32 params update in place.
Optimizer state mirrors the parameter sharding specs, so TP/DP sharding of
the train step extends to the moments automatically.

Moments can live *encoded* through the quant engine: ``mu_codec`` /
``nu_codec`` name a deterministic registered codec spec (``repro.quant``,
e.g. ``"m8"`` per-row absmax int8 momentum, ``"u8"`` sqrt-domain uint8
second moment, or ``"int4@g32"``). The moment is decoded at the top of
``apply_updates``, updated in f32, and re-encoded before it lands back in
the state, so the optimizer math itself never changes; only storage does.
Dithered codecs (needs_key) are rejected — moments re-encode every step
with no RNG stream, and a biased re-quantization cycle wants determinism.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"  # adamw | sgd
    lr: float = 1e-3
    momentum: float = 0.9  # sgd
    b1: float = 0.9  # adamw
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: Optional[float] = 1.0
    schedule: str = "constant"  # constant | cosine | step
    warmup_steps: int = 0
    total_steps: int = 10_000
    step_decay_every: int = 100  # paper: lr-decay 0.1/100
    step_decay_rate: float = 0.1
    min_lr_ratio: float = 0.1
    # deterministic quant codec specs for stored moments (None = dense f32)
    mu_codec: Optional[str] = None
    nu_codec: Optional[str] = None  # adamw only

    def __post_init__(self):
        for field, mode in (("mu_codec", self.mu_codec),
                            ("nu_codec", self.nu_codec)):
            if mode is None:
                continue
            # lazy: repro.quant imports repro.core at module level
            from repro.quant.registry import get_codec, parse_spec

            spec = parse_spec(mode)
            if get_codec(spec.codec).needs_key:
                raise ValueError(
                    f"{field}={mode!r}: moment codecs must be deterministic "
                    f"(re-encoded every step without an RNG stream)")


def schedule_lr(cfg: OptConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    base = jnp.asarray(cfg.lr, jnp.float32)
    warm = jnp.minimum(1.0, (s + 1) / jnp.maximum(cfg.warmup_steps, 1)) \
        if cfg.warmup_steps > 0 else 1.0
    if cfg.schedule == "cosine":
        t = jnp.clip((s - cfg.warmup_steps) /
                     jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        mult = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "step":
        mult = cfg.step_decay_rate ** jnp.floor(s / cfg.step_decay_every)
    else:
        mult = 1.0
    return base * warm * mult


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def _needs_master(p) -> bool:
    return p.dtype in (jnp.bfloat16, jnp.float16)


def _enc_moment(mode: Optional[str], x: jax.Array):
    if mode is None:
        return x
    from repro import quant

    return quant.encode(mode, x)


def _dec_moment(mode: Optional[str], enc) -> jax.Array:
    if mode is None:
        return enc
    from repro import quant

    return quant.decode(mode, enc)


def init_opt_state(params, cfg: OptConfig) -> Dict[str, Any]:
    def master(p):
        return p.astype(jnp.float32) if _needs_master(p) else jnp.zeros((), jnp.int8)

    def zeros_mu(p):
        return _enc_moment(cfg.mu_codec, jnp.zeros(p.shape, jnp.float32))

    def zeros_nu(p):
        return _enc_moment(cfg.nu_codec, jnp.zeros(p.shape, jnp.float32))

    state: Dict[str, Any] = {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(master, params),
    }
    if cfg.name == "adamw":
        state["mu"] = jax.tree.map(zeros_mu, params)
        state["nu"] = jax.tree.map(zeros_nu, params)
    elif cfg.name == "sgd":
        state["mu"] = jax.tree.map(zeros_mu, params)
    else:
        raise ValueError(cfg.name)
    return state


def _moment_spec_template(mode: str):
    """Replicated (all-None) spec subtree shaped like the codec container.

    Container structure is shape-independent, so one eval_shape template
    covers every param; encoded moments are small and replicate fine.
    """
    from repro import quant

    template = jax.eval_shape(
        lambda: quant.encode(mode, jnp.zeros((2, 2), jnp.float32)))
    return jax.tree.map(lambda _: None, template)


def opt_state_specs(param_specs, cfg: OptConfig):
    """Logical-axis spec tree mirroring init_opt_state's structure.

    Encoded moments (``mu_codec`` / ``nu_codec``) swap each param's spec
    leaf for a container-shaped subtree of None (replicated) so the tree
    still matches the state leaf-for-leaf.
    """
    def is_spec(s):
        return s is None or (isinstance(s, tuple) and all(
            a is None or isinstance(a, str) for a in s))

    def moment_specs(mode: Optional[str]):
        if mode is None:
            return jax.tree.map(lambda s: s, param_specs, is_leaf=is_spec)
        sub = _moment_spec_template(mode)
        return jax.tree.map(lambda s: sub, param_specs, is_leaf=is_spec)

    scalar = ()
    master = jax.tree.map(lambda s: s, param_specs, is_leaf=is_spec)
    out = {"step": scalar, "master": master}
    if cfg.name in ("adamw", "sgd"):
        out["mu"] = moment_specs(cfg.mu_codec)
    if cfg.name == "adamw":
        out["nu"] = moment_specs(cfg.nu_codec)
    return out


def apply_updates(params, grads, state, cfg: OptConfig
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One optimizer step. Returns (params, state, metrics)."""
    step = state["step"]
    lr = schedule_lr(cfg, step)
    metrics = {"lr": lr}
    if cfg.grad_clip is not None:
        grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
        metrics["grad_norm"] = gn

    def get_master(p, m):
        return m if _needs_master(p) else p.astype(jnp.float32)

    masters = jax.tree.map(get_master, params, state["master"])

    # encoded moments: decode -> f32 update -> re-encode (storage only;
    # the optimizer math below is unchanged)
    def dec_tree(mode, tree, template):
        # template (the params tree) supplies the leaf positions; tree.map
        # hands each corresponding codec-container SUBTREE to the decode
        if mode is None:
            return tree
        return jax.tree.map(lambda _, enc: _dec_moment(mode, enc),
                            template, tree)

    def enc_tree(mode, tree):
        if mode is None:
            return tree
        return jax.tree.map(lambda m: _enc_moment(mode, m), tree)

    if cfg.name == "adamw":
        b1, b2 = cfg.b1, cfg.b2
        t = (step + 1).astype(jnp.float32)
        mu_in = dec_tree(cfg.mu_codec, state["mu"], params)
        nu_in = dec_tree(cfg.nu_codec, state["nu"], params)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          mu_in, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            nu_in, grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(w, m, v):
            mhat = m / bc1
            vhat = v / bc2
            return w - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                             + cfg.weight_decay * w)

        new_masters = jax.tree.map(upd, masters, mu, nu)
        new_state = dict(state, step=step + 1,
                         mu=enc_tree(cfg.mu_codec, mu),
                         nu=enc_tree(cfg.nu_codec, nu))
    elif cfg.name == "sgd":
        mu_in = dec_tree(cfg.mu_codec, state["mu"], params)
        mu = jax.tree.map(
            lambda m, g, w: cfg.momentum * m + g.astype(jnp.float32)
            + cfg.weight_decay * w,
            mu_in, grads, masters)
        new_masters = jax.tree.map(lambda w, m: w - lr * m, masters, mu)
        new_state = dict(state, step=step + 1,
                         mu=enc_tree(cfg.mu_codec, mu))
    else:
        raise ValueError(cfg.name)

    def put_back(p, w):
        return w.astype(p.dtype)

    new_params = jax.tree.map(put_back, params, new_masters)

    def keep_master(p, w):
        return w if _needs_master(p) else jnp.zeros((), jnp.int8)

    new_state["master"] = jax.tree.map(keep_master, params, new_masters)
    return new_params, new_state, metrics
