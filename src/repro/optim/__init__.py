from repro.optim.optimizers import (
    OptConfig, apply_updates, clip_by_global_norm, init_opt_state,
    opt_state_specs, schedule_lr,
)

__all__ = ["OptConfig", "apply_updates", "clip_by_global_norm",
           "init_opt_state", "opt_state_specs", "schedule_lr"]
