"""internvl2-2b [vlm]: 24L d2048 16H (GQA kv=8) ff8192 V=92553 — InternLM2
backbone + InternViT frontend STUB (precomputed patch embeds -> MLP
projector -> 256 visual prefix tokens). [arXiv:2404.16821]"""
import jax.numpy as jnp
from repro.models.api import lm_model
from repro.models.transformer import LMConfig

ARCH_ID = "internvl2-2b"


def config():
    return lm_model(LMConfig(
        name=ARCH_ID, n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
        d_ff=8192, vocab=92553, head_dim=128, act="swiglu",
        tie_embeddings=False, rope_theta=1_000_000.0, dtype=jnp.bfloat16,
        vlm_patches=256, vit_dim=1024,
    ), family="vlm")


def smoke():
    return lm_model(LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab=512, head_dim=32, act="swiglu",
        tie_embeddings=False, dtype=jnp.float32, remat=False,
        vlm_patches=8, vit_dim=64,
    ), family="vlm")
