"""hymba-1.5b [hybrid]: 32L d1600 25H (GQA kv=5) ff5504 V=32001,
parallel attn+mamba heads, ssm_state=16, meta tokens, SWA + 3 global.
[arXiv:2411.13676]"""
import jax.numpy as jnp
from repro.models.api import hybrid_model
from repro.models.hybrid import HybridConfig

ARCH_ID = "hymba-1.5b"


def config():
    return hybrid_model(HybridConfig(
        name=ARCH_ID, n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab=32001, head_dim=64, d_state=16, expand=2,
        window=1024, n_meta_tokens=128, dtype=jnp.bfloat16,
    ))


def smoke():
    return hybrid_model(HybridConfig(
        name=ARCH_ID + "-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=512, head_dim=16, d_state=8,
        expand=2, window=8, n_meta_tokens=4, dtype=jnp.float32, remat=False,
    ))
