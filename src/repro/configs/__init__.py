"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.shapes import SHAPES, ShapeCase, applicable  # noqa: F401

_MODULES: Dict[str, str] = {
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "gemma-2b": "repro.configs.gemma_2b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "minitron-8b": "repro.configs.minitron_8b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "whisper-small": "repro.configs.whisper_small",
}

ARCH_IDS = tuple(_MODULES)


def get_model(arch_id: str):
    """Full-size config (dry-run only: never materialize these params)."""
    return importlib.import_module(_MODULES[arch_id]).config()


def get_smoke_model(arch_id: str):
    """Reduced same-family config for CPU smoke tests."""
    return importlib.import_module(_MODULES[arch_id]).smoke()
