"""gemma-2b [dense]: 18L d2048 8H (MQA kv=1) ff16384 V=256000, GeGLU,
head_dim=256. [arXiv:2403.08295]"""
import jax.numpy as jnp
from repro.models.api import lm_model
from repro.models.transformer import LMConfig

ARCH_ID = "gemma-2b"


def config():
    return lm_model(LMConfig(
        name=ARCH_ID, n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
        d_ff=16384, vocab=256000, head_dim=256, act="geglu",
        tie_embeddings=True, embed_scale=True, rope_theta=10_000.0,
        dtype=jnp.bfloat16,
    ), family="dense")


def smoke():
    return lm_model(LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=1, d_ff=256, vocab=512, head_dim=32, act="geglu",
        tie_embeddings=True, embed_scale=True, dtype=jnp.float32, remat=False,
    ), family="dense")
