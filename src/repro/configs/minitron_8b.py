"""minitron-8b [dense]: 32L d4096 32H (GQA kv=8) ff16384 V=256000 —
pruned Nemotron-4 (squared-ReLU MLP per lineage). [arXiv:2407.14679]"""
import jax.numpy as jnp
from repro.models.api import lm_model
from repro.models.transformer import LMConfig

ARCH_ID = "minitron-8b"


def config():
    return lm_model(LMConfig(
        name=ARCH_ID, n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=16384, vocab=256000, head_dim=128, act="relu2",
        tie_embeddings=False, rope_theta=10_000.0, dtype=jnp.bfloat16,
    ), family="dense")


def smoke():
    return lm_model(LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab=512, head_dim=32, act="relu2",
        tie_embeddings=False, dtype=jnp.float32, remat=False,
    ), family="dense")
