"""whisper-small [audio]: 12L enc + 12L dec, d768 12H (MHA kv=12) ff3072
V=51865, conv/mel frontend STUB (precomputed frame embeds, 1500 frames).
[arXiv:2212.04356]"""
import jax.numpy as jnp
from repro.models.api import encdec_model
from repro.models.encdec import EncDecConfig

ARCH_ID = "whisper-small"


def config():
    return encdec_model(EncDecConfig(
        name=ARCH_ID, n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab=51865, n_frames=1500, dtype=jnp.bfloat16,
    ))


def smoke():
    return encdec_model(EncDecConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=512, n_frames=16, dtype=jnp.float32,
        remat=False,
    ))
