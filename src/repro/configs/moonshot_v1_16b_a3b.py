"""moonshot-v1-16b-a3b [moe]: 48L d2048 16H (MHA kv=16) ff1408/expert
V=163840, 64 experts top-6 + 2 shared (DeepSeek-style).
[hf:moonshotai/Moonlight-16B-A3B]"""
import jax.numpy as jnp
from repro.models.api import lm_model
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

ARCH_ID = "moonshot-v1-16b-a3b"


def config():
    return lm_model(LMConfig(
        name=ARCH_ID, n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab=163840, head_dim=128, act="swiglu",
        tie_embeddings=False, rope_theta=50_000.0, dtype=jnp.bfloat16,
        moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                      a2a_int8=True),  # §Perf dbrx/It2
    ), family="moe")


def smoke():
    return lm_model(LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab=512, head_dim=32, act="swiglu",
        tie_embeddings=False, dtype=jnp.float32, remat=False,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=1,
                      dispatch="einsum"),
    ), family="moe")
