"""The assigned input-shape grid (4 shapes x 10 archs = 40 cells)."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCase("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCase("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCase("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCase("long_500k", "decode", 524_288, 1),
}

# long_500k requires sub-quadratic attention (run: ssm / hybrid / sliding-
# window-dominant; skip: pure full-attention archs — see DESIGN.md §5).
LONG_CTX_ARCHS = ("mamba2-370m", "hymba-1.5b", "gemma3-4b")


def applicable(arch_id: str, shape_name: str, has_decode: bool) -> Optional[str]:
    """None if the cell runs; otherwise a skip reason (recorded in the grid)."""
    case = SHAPES[shape_name]
    if case.kind == "decode" and not has_decode:
        return "encoder-only arch: no decode step"
    if shape_name == "long_500k" and arch_id not in LONG_CTX_ARCHS:
        if arch_id == "whisper-small":
            return "decoder context is 448 by construction; 500k n/a"
        return "pure full-attention arch: 500k dense KV is the quadratic regime"
    return None
