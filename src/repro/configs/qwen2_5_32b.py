"""qwen2.5-32b [dense]: 64L d5120 40H (GQA kv=8) ff27648 V=152064, QKV bias.
[hf:Qwen/Qwen2.5-32B; config lineage via Qwen2.5-0.5B per assignment]"""
import jax.numpy as jnp
from repro.models.api import lm_model
from repro.models.transformer import LMConfig

ARCH_ID = "qwen2.5-32b"


def config():
    return lm_model(LMConfig(
        name=ARCH_ID, n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=27648, vocab=152064, head_dim=128, act="swiglu", qkv_bias=True,
        tie_embeddings=False, rope_theta=1_000_000.0, dtype=jnp.bfloat16,
    ), family="dense")


def smoke():
    return lm_model(LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab=512, head_dim=32, act="swiglu",
        qkv_bias=True, tie_embeddings=False, dtype=jnp.float32, remat=False,
    ), family="dense")
