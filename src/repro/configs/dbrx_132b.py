"""dbrx-132b [moe]: 40L d6144 48H (GQA kv=8) ff10752/expert V=100352,
16 experts top-4 fine-grained. [hf:databricks/dbrx-base; unverified]"""
import jax.numpy as jnp
from repro.models.api import lm_model
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

ARCH_ID = "dbrx-132b"


def config():
    return lm_model(LMConfig(
        name=ARCH_ID, n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=10752, vocab=100352, head_dim=128, act="swiglu",
        tie_embeddings=False, rope_theta=500_000.0, dtype=jnp.bfloat16,
        moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752,
                      a2a_int8=True),  # §Perf dbrx/It2
    ), family="moe")


def smoke():
    return lm_model(LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=512, head_dim=32, act="swiglu",
        tie_embeddings=False, dtype=jnp.float32, remat=False,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                      dispatch="einsum"),
    ), family="moe")
