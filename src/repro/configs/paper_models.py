"""The paper's own experiment models (Table 1 / fig 4): MLP-(500,500),
LeNet-300-100, LeNet5, AlexNet-CIFAR (hidden 2048), VGG11-CIFAR (FC 512),
ResNet18 — built on synthetic stand-ins for MNIST/CIFAR (offline container).
"""
from repro.models.api import cnn_model
from repro.models.cnn import CNNConfig


def mlp_mnist(hidden=(500, 500)):
    return cnn_model(CNNConfig(name="mlp-mnist", arch="mlp", n_classes=10,
                               in_channels=1, img_size=28, hidden=hidden))


def lenet300100():
    return cnn_model(CNNConfig(name="lenet300100", arch="lenet300100",
                               n_classes=10, in_channels=1, img_size=28,
                               hidden=(300, 100)))


def lenet5():
    return cnn_model(CNNConfig(name="lenet5", arch="lenet5", n_classes=10,
                               in_channels=1, img_size=28))


def alexnet_cifar(n_classes=10):
    return cnn_model(CNNConfig(name=f"alexnet-c{n_classes}", arch="alexnet",
                               n_classes=n_classes, in_channels=3, img_size=32))


def vgg11_cifar(n_classes=10):
    return cnn_model(CNNConfig(name=f"vgg11-c{n_classes}", arch="vgg11",
                               n_classes=n_classes, in_channels=3, img_size=32))


def resnet18_cifar(n_classes=10):
    return cnn_model(CNNConfig(name=f"resnet18-c{n_classes}", arch="resnet18",
                               n_classes=n_classes, in_channels=3, img_size=32))
