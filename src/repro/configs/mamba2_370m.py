"""mamba2-370m [ssm]: 48L d1024 attn-free V=50280, SSD state=128,
headdim=64 (expand=2 -> d_inner=2048, 32 SSM heads). [arXiv:2405.21060]"""
import jax.numpy as jnp
from repro.models.api import ssm_model
from repro.models.mamba import SSMConfig, SSMLMConfig

ARCH_ID = "mamba2-370m"


def config():
    return ssm_model(SSMLMConfig(
        name=ARCH_ID, n_layers=48, vocab=50280,
        ssm=SSMConfig(d_model=1024, d_inner=2048, head_dim=64, d_state=128,
                      n_groups=1, d_conv=4, chunk=256),
        dtype=jnp.bfloat16,
        # §Perf mamba2/It6: at 370M the activations fit without remat;
        # dropping the recompute pass bought +27% roofline fraction
        remat=False,
    ))


def smoke():
    return ssm_model(SSMLMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, vocab=512,
        ssm=SSMConfig(d_model=64, d_inner=128, head_dim=32, d_state=16,
                      n_groups=1, d_conv=4, chunk=8),
        dtype=jnp.float32, remat=False,
    ))
