"""gemma3-4b [dense]: 34L d2560 8H (GQA kv=4) ff10240 V=262144,
5:1 local:global sliding window (1024), 128k context, head_dim=256.
[hf:google/gemma-3-4b-pt lineage; unverified per assignment]"""
import jax.numpy as jnp
from repro.models.api import lm_model
from repro.models.transformer import LMConfig

ARCH_ID = "gemma3-4b"


def config():
    return lm_model(LMConfig(
        name=ARCH_ID, n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
        d_ff=10240, vocab=262144, head_dim=256, act="geglu",
        tie_embeddings=True, embed_scale=True, rope_theta=1_000_000.0,
        window=1024, window_pattern=5, dtype=jnp.bfloat16,
    ), family="dense")


def smoke():
    return lm_model(LMConfig(
        name=ARCH_ID + "-smoke", n_layers=6, d_model=64, n_heads=2,
        n_kv_heads=1, d_ff=128, vocab=512, head_dim=32, act="geglu",
        tie_embeddings=True, embed_scale=True, window=8, window_pattern=5,
        dtype=jnp.float32, remat=False,
    ), family="dense")
