from repro.utils.pytree import (
    tree_size,
    tree_bytes,
    tree_map_with_path_str,
    flatten_with_names,
)
from repro.utils.logging import get_log_context, get_logger, set_log_context

__all__ = [
    "tree_size",
    "tree_bytes",
    "tree_map_with_path_str",
    "flatten_with_names",
    "get_log_context",
    "get_logger",
    "set_log_context",
]
