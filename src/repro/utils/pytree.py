"""Pytree helpers used across the framework."""
from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np


def tree_size(tree: Any) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: Any) -> int:
    """Total bytes across all leaves (uses dtype itemsize)."""
    total = 0
    for x in jax.tree.leaves(tree):
        itemsize = np.dtype(x.dtype).itemsize
        total += int(np.prod(x.shape)) * itemsize
    return total


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_path_str(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """tree_map where fn receives ("a/b/c", leaf)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn(_path_str(path), leaf), tree
    )


def flatten_with_names(tree: Any) -> list[tuple[str, Any]]:
    """Flatten to [(path_string, leaf)] pairs, deterministic order."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(_path_str(path), leaf) for path, leaf in flat]
