"""Minimal structured logging for the framework.

Two output modes, selected by the ``REPRO_LOG_FORMAT`` environment
variable at logger creation:

* default — the historical human-readable single line
  (``HH:MM:SS L name :: message``)
* ``json`` — one strict-JSON object per line (``ts``, ``level``,
  ``logger``, ``msg`` + the process log context), so host logs can be
  joined against the obs run log: :func:`set_log_context` stamps
  ``run_id`` / ``step`` (the run-log exporter and the training loop keep
  them current), and every subsequent record carries them.
"""
from __future__ import annotations

import json
import logging
import os
import sys
from typing import Any, Dict

_FMT = "%(asctime)s %(levelname).1s %(name)s :: %(message)s"

# process-wide fields merged into every JSON log record (run_id, step, ...)
_LOG_CONTEXT: Dict[str, Any] = {}


def set_log_context(**fields: Any) -> None:
    """Merge fields into the process log context; ``None`` removes a key."""
    for k, v in fields.items():
        if v is None:
            _LOG_CONTEXT.pop(k, None)
        else:
            _LOG_CONTEXT[k] = v


def get_log_context() -> Dict[str, Any]:
    return dict(_LOG_CONTEXT)


class JsonFormatter(logging.Formatter):
    """One strict-JSON object per line, joinable with the obs run log."""

    def format(self, record: logging.LogRecord) -> str:
        obj: Dict[str, Any] = {
            "ts": self.formatTime(record, "%Y-%m-%dT%H:%M:%S"),
            "level": record.levelname,
            "logger": record.name,
            "msg": record.getMessage(),
        }
        obj.update(_LOG_CONTEXT)
        if record.exc_info:
            obj["exc"] = self.formatException(record.exc_info)
        # default=str: a non-serializable context value must not kill the
        # log line; allow_nan=False keeps consumers strict (float fields in
        # context are host scalars, never NaN by construction)
        return json.dumps(obj, default=str, allow_nan=False)


def _make_formatter() -> logging.Formatter:
    if os.environ.get("REPRO_LOG_FORMAT", "").lower() == "json":
        return JsonFormatter()
    return logging.Formatter(_FMT, datefmt="%H:%M:%S")


def get_logger(name: str) -> logging.Logger:
    logger = logging.getLogger(name)
    if not logger.handlers:
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(_make_formatter())
        logger.addHandler(handler)
        logger.setLevel(os.environ.get("REPRO_LOGLEVEL", "INFO"))
        logger.propagate = False
    return logger
