"""repro.quant — the one quantization engine.

Every quantized representation in the repo resolves through this package:
gradient cotangents (``repro.core``), the reduce wire format
(``repro.comm``), the residual store (``repro.memory``), KV-cache pages
(``repro.serve``) and optimizer moments (``repro.optim``) all parse spec
strings with :func:`parse_spec` and call the capabilities below — no
subsystem carries private encode/decode code anymore.

    spec.py      QuantSpec IR: dtype/bits, scale granularity, dither mode,
                 sparsity layout
    registry.py  Codec base class + registration; parse_spec front door
    codecs.py    the built-in formats (fp32/remat/bf16/int8/nsd/
                 int8_absmax/int4/m8/u8) + the facade dispatch
    wire.py      the packed NSD wire layout (moved from
                 ``repro.comm.wireformat``), jnp + Pallas backends

The legacy entry points (``repro.memory.codec``, ``repro.comm.wireformat``,
``repro.core.nsd.nsd_quantize*``, ``repro.core.int8.quantize_int8``) are
deprecation shims over this package, pinned bit-exact by
tests/test_quant.py.
"""
from repro.quant.codecs import (
    DEFAULT_INT4_GROUP,
    DEFAULT_NSD_S,
    MODE_BF16,
    MODE_FP32,
    MODE_INT8,
    MODE_NSD,
    MODE_REMAT,
    MODES,
    RESID_SALT,
    Bf16Residual,
    Int4Grouped,
    Int8Residual,
    RowQuant8,
    SqrtRowQuant8,
    absmax_int8,
    capacity_bytes,
    decode,
    encode,
    error_bound,
    measured_bytes,
    nsd_fakequant,
    nsd_int8,
    packed_layout,
    parse_mode,
    quantize,
    resid_key,
    stored_nbytes,
    validate_mode,
)
from repro.quant.program import (
    QuantProgram,
    format_quant_program,
    parse_quant_program,
)
from repro.quant.registry import (
    Codec,
    codec_names,
    dense_nbytes,
    get_codec,
    parse_spec,
    register,
    validate_spec,
)
from repro.quant.spec import QuantSpec
from repro.quant import wire

__all__ = [
    "DEFAULT_INT4_GROUP", "DEFAULT_NSD_S", "MODE_BF16", "MODE_FP32",
    "MODE_INT8", "MODE_NSD", "MODE_REMAT", "MODES", "RESID_SALT",
    "Bf16Residual", "Int4Grouped", "Int8Residual", "RowQuant8",
    "SqrtRowQuant8", "absmax_int8", "capacity_bytes", "decode", "encode",
    "error_bound", "measured_bytes", "nsd_fakequant", "nsd_int8",
    "packed_layout", "parse_mode", "quantize", "resid_key",
    "stored_nbytes", "validate_mode",
    "Codec", "codec_names", "dense_nbytes", "get_codec", "parse_spec",
    "register", "validate_spec",
    "QuantProgram", "format_quant_program", "parse_quant_program",
    "QuantSpec", "wire",
]
