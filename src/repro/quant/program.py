"""The ``quant:`` section of the unified ``--program`` DSL.

One place to pick codecs for every quantization surface of a run::

    quant: grad=int4@g32;wire=nsd@1;resid=int8;mu=m8;nu=u8

keys (each optional, ';'-separated, every value a registered codec spec):
  grad=SPEC    cotangent codec (DitherPolicy.grad_codec — replaces the
               variant's built-in NSD quantizer on dithered layers)
  wire=SPEC    default per-leaf comm mode (CommPolicy.default)
  resid=SPEC   default residual mode (shorthand for
               ``memory: default=SPEC``; conflicts with an explicit
               memory section are an error, not a silent preference)
  mu=SPEC      stored first-moment codec (OptConfig.mu_codec;
               deterministic codecs only)
  nu=SPEC      stored second-moment codec (OptConfig.nu_codec)

The KV-cache surface is not here: serving picks its page codec at engine
build time (``--serve kv=...`` / ``init_paged``), which accepts the same
registered specs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.quant.registry import get_codec, parse_spec, validate_spec

_KEYS = ("grad", "wire", "resid", "mu", "nu")

# a literal, not a __doc__ slice: -OO strips docstrings (schedule.py idiom)
_SPEC_DOC = """\
';'-separated key=SPEC clauses; keys: grad (cotangent codec), wire (comm
default mode), resid (residual default mode), mu / nu (stored optimizer
moment codecs, deterministic only). Every SPEC is a registered quant codec
spec, e.g. int4@g32, nsd@0.5, m8, u8.
"""


@dataclasses.dataclass(frozen=True)
class QuantProgram:
    """Parsed ``quant:`` section; None = surface not overridden."""

    grad: Optional[str] = None
    wire: Optional[str] = None
    resid: Optional[str] = None
    mu: Optional[str] = None
    nu: Optional[str] = None

    def __bool__(self) -> bool:
        return any(getattr(self, k) is not None for k in _KEYS)


def parse_quant_program(spec: str) -> QuantProgram:
    """Parse ``grad=...;wire=...;...`` into a validated QuantProgram."""
    out = {}
    for clause in spec.split(";"):
        clause = clause.strip()
        if not clause:
            continue
        key, sep, value = clause.partition("=")
        key = key.strip()
        value = value.strip()
        if not sep or not value or key not in _KEYS:
            raise ValueError(
                f"cannot parse quant clause {clause!r}; expected key=SPEC "
                f"with key in {_KEYS}\n{_SPEC_DOC}")
        if key in out:
            raise ValueError(f"duplicate quant key {key!r}")
        validate_spec(value)
        if key in ("mu", "nu") and get_codec(parse_spec(value).codec).needs_key:
            raise ValueError(
                f"quant clause {clause!r}: moment codecs must be "
                f"deterministic (no RNG stream at re-encode)")
        out[key] = value
    return QuantProgram(**out)


def format_quant_program(qp: QuantProgram) -> str:
    """Render back to section text (parse round-trips)."""
    return ";".join(f"{k}={getattr(qp, k)}" for k in _KEYS
                    if getattr(qp, k) is not None)
