"""The codec registry: one entry point from spec strings to capabilities.

A :class:`Codec` owns every capability of one quantization format:

    make_spec(param)             "@param" grammar -> QuantSpec
    encode(spec, x, key)         tensor -> encoded container (jit-safe pytree)
    decode(spec, enc)            inverse (exact or bounded, see error_bound)
    quantize(spec, x, key)       decode(encode(x)) — the fake-quant form
    stored_nbytes(spec, shape, dtype)   static HBM capacity of the encoding
    capacity_bytes(spec, enc)    static bytes of a concrete encoding
    measured_bytes(spec, enc)    traced occupancy-aware bytes (wire figure)
    error_bound(spec, enc)       per-element |decode - x| upper bound, or
                                 None when the round trip is exact
    packed_layout(spec, shape, dtype)   buffer inventory of the encoding
    compute_on_packed(...)       optional: consume the packed form directly
                                 (int8 MXU matmul, bsp tile-skip backward)

Each capability may carry per-backend implementations in ``backends``
(``{"capability": {"jnp": fn, "pallas": fn | None}}``); the method itself
is the ``jnp`` reference. Registration is module-import-time
(``repro.quant.codecs`` registers the built-ins); downstream code resolves
spec strings through :func:`parse_spec` and never hard-codes a format.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.quant.spec import QuantSpec


def _nelems(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


def dense_nbytes(shape, dtype) -> int:
    """Bytes the dense tensor occupies (what an encoding replaces)."""
    return _nelems(shape) * jnp.dtype(dtype).itemsize


class Codec:
    """Base class: one registered quantization format (see module doc).

    Subclasses must implement ``make_spec`` / ``encode`` / ``decode`` /
    ``stored_nbytes``; everything else has honest defaults. ``needs_key``
    declares whether encode requires an RNG key (dithered codecs) — codecs
    with ``needs_key=False`` are deterministic and usable for optimizer
    moments, which re-encode every step without an RNG stream.
    """

    name: str = ""
    needs_key: bool = True
    backends: Dict[str, Dict[str, Optional[callable]]] = {}

    def make_spec(self, param: str) -> QuantSpec:
        raise NotImplementedError

    def encode(self, spec: QuantSpec, x: jax.Array,
               key: Optional[jax.Array]):
        raise NotImplementedError

    def decode(self, spec: QuantSpec, enc) -> jax.Array:
        raise NotImplementedError

    def quantize(self, spec: QuantSpec, x: jax.Array,
                 key: Optional[jax.Array]) -> jax.Array:
        return self.decode(spec, self.encode(spec, x, key))

    def stored_nbytes(self, spec: QuantSpec, shape, dtype) -> int:
        raise NotImplementedError

    def capacity_bytes(self, spec: QuantSpec, enc) -> int:
        return self.stored_nbytes(spec, enc.shape, enc.dtype)

    def measured_bytes(self, spec: QuantSpec, enc) -> jax.Array:
        return jnp.int32(self.capacity_bytes(spec, enc))

    def error_bound(self, spec: QuantSpec, enc) -> Optional[jax.Array]:
        """Per-element upper bound on |decode(enc) - x|; None = exact."""
        return None

    def packed_layout(self, spec: QuantSpec, shape, dtype
                      ) -> Dict[str, object]:
        """Buffer inventory of the encoding for ``shape``/``dtype``."""
        x = jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))
        key = jax.ShapeDtypeStruct((2,), jnp.uint32) if self.needs_key \
            else None
        enc = jax.eval_shape(functools.partial(self.encode, spec), x, key)
        flat, _ = jax.tree_util.tree_flatten_with_path(enc)
        buffers: List[Tuple[str, tuple, str]] = []
        for path, leaf in flat:
            pname = "".join(str(p) for p in path).lstrip(".") or "data"
            buffers.append((pname, tuple(leaf.shape),
                            jnp.dtype(leaf.dtype).name))
        return {"codec": self.name, "layout": spec.layout,
                "buffers": buffers,
                "capacity_bytes": self.stored_nbytes(spec, shape, dtype),
                "dense_bytes": dense_nbytes(shape, dtype)}

    def compute_on_packed(self, spec: QuantSpec, enc, *operands,
                          backend: str = "jnp"):
        raise NotImplementedError(
            f"codec {self.name!r} has no compute_on_packed capability")


_REGISTRY: Dict[str, Codec] = {}


def register(codec: Codec) -> Codec:
    if not codec.name:
        raise ValueError("codec must set a name")
    if codec.name in _REGISTRY:
        raise ValueError(f"codec {codec.name!r} already registered")
    _REGISTRY[codec.name] = codec
    return codec


def get_codec(name: str) -> Codec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown codec {name!r}; registered: {codec_names()}") from None


def codec_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


@functools.lru_cache(maxsize=None)
def parse_spec(mode: str) -> QuantSpec:
    """Resolve a spec string (``"nsd@0.5"``, ``"int4@g32"``) to a QuantSpec.

    The codec before ``@`` must be registered; the codec's own
    ``make_spec`` owns the parameter grammar, so new formats bring their
    parameters without touching this front door.
    """
    kind, _, param = mode.partition("@")
    return get_codec(kind).make_spec(param)


def validate_spec(mode: str) -> str:
    parse_spec(mode)
    return mode
