"""`QuantSpec` — the quantization IR every codec resolves to.

A spec string like ``"int8"``, ``"nsd@0.5"`` or ``"int4@g32"`` parses (via
the codec registry, ``repro.quant.registry.parse_spec``) into one frozen
:class:`QuantSpec` describing *what* the encoded representation is:

    codec        registry name ("fp32", "bf16", "int8", "nsd", "int4", ...)
    bits         payload bits per element (32, 16, 8, 4)
    granularity  scale granularity: "tensor" | "row" | "group" | "chunk"
    group        elements per scale group (granularity == "group")
    dither       "none" | "uniform" (NSD-style subtractive-free dither) |
                 "stochastic-round" (absmax int8 with a key)
    layout       "dense" | "row-affine" | "grouped" | "bitmap+levels"
    param        the codec's @-parameter (NSD scale s, int4 group size)
    chunk        wire chunk size (layout == "bitmap+levels")

The spec is pure data — hashable, static, safe to stamp into
``StaticSpec`` / custom_vjp static arguments. All behavior (encode /
decode / byte accounting / error bounds / compute-on-packed) lives on the
registered :class:`repro.quant.registry.Codec` the spec names.
"""
from __future__ import annotations

import dataclasses

GRANULARITIES = ("tensor", "row", "group", "chunk")
DITHERS = ("none", "uniform", "stochastic-round")
LAYOUTS = ("dense", "row-affine", "grouped", "bitmap+levels")


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """One quantization format, fully resolved (see module docstring)."""

    codec: str
    bits: int = 32
    granularity: str = "tensor"
    group: int = 0
    dither: str = "none"
    layout: str = "dense"
    param: float = 0.0
    chunk: int = 0

    def __post_init__(self):
        if self.granularity not in GRANULARITIES:
            raise ValueError(
                f"granularity {self.granularity!r}: one of {GRANULARITIES}")
        if self.dither not in DITHERS:
            raise ValueError(f"dither {self.dither!r}: one of {DITHERS}")
        if self.layout not in LAYOUTS:
            raise ValueError(f"layout {self.layout!r}: one of {LAYOUTS}")
        if self.granularity == "group" and self.group < 1:
            raise ValueError(
                f"group granularity needs group >= 1, got {self.group}")

    @property
    def mode(self) -> str:
        """The canonical spec string this parses back from."""
        if self.codec == "nsd":
            return f"nsd@{self.param:g}"
        if self.codec == "int4":
            return f"int4@g{self.group}"
        return self.codec

    def replace(self, **kw) -> "QuantSpec":
        return dataclasses.replace(self, **kw)
