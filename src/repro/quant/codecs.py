"""Built-in codecs: the repo's one home for quantized representations.

Every format that used to live in a subsystem-private encode/decode pair is
a registered :class:`repro.quant.registry.Codec` here, resolved from spec
strings through ``parse_spec``:

    fp32         identity passthrough (the parity arm)
    remat        storage *mode*, not a format: identity here; the memory
                 subsystem wraps the op in jax.checkpoint instead of storing
    bf16         2-byte truncation; exact for bf16-representable values
    int8         affine per-row (residual-store lineage): q = round((x -
                 min_row)/scale_row) - 128, scale_row = range_row/255;
                 error bounded by scale_row/2 per element
    nsd[@S]      the paper's operator in the comm wire layout
                 (``repro.quant.wire``); bit-exact vs ``repro.core.nsd``
                 for the same key; jnp + Pallas backends
    int8_absmax  per-tensor symmetric absmax (Banner-style forward path;
                 ``core/int8`` lineage); optional stochastic rounding;
                 compute_on_packed = the int8 MXU matmul
    int4[@gG]    4-bit grouped-scale, two values per stored byte, one f32
                 scale per G elements (default 32); deterministic
                 round-to-nearest, error bounded by scale_group/2. NEW in
                 the quant subsystem — reaches gradients, wire, residuals,
                 KV pages and moments with no per-subsystem code.
    m8           optimizer momentum: per-row symmetric absmax int8,
                 deterministic (re-encoded every step without a key)
    u8           optimizer second moment: sqrt-domain per-row absmax uint8
                 (v >= 0; quantize sqrt(v), decode square) — relative
                 resolution where adam's rsqrt needs it

NSD/int8 behavior is pinned bit-exact against the pre-migration
implementations (``repro.memory.codec`` / ``repro.comm.wireformat`` /
``repro.core.nsd`` / ``repro.core.int8`` — now deprecated shims over this
module) by tests/test_quant.py and the zero-band suite gates.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import int8 as int8lib
from repro.core import nsd
from repro.quant import wire
from repro.quant.registry import (Codec, _nelems, dense_nbytes, get_codec,
                                  parse_spec, register)
from repro.quant.spec import QuantSpec

# "nsd" residuals want fidelity (they feed the weight-gradient product),
# so the default dither scale is gentler than the gradient-side s=2.
DEFAULT_NSD_S = 1.0

DEFAULT_INT4_GROUP = 32

# Salt folded into the layer key for the residual encode so the activation
# dither draws an RNG stream independent of the backward's cotangent dither.
RESID_SALT = 0x4E5D


def resid_key(key: jax.Array) -> jax.Array:
    """The residual-encode RNG stream for a layer's per-step key."""
    return jax.random.fold_in(key, RESID_SALT)


# ---------------------------------------------------------------------------
# canonical quantize helpers (the non-deprecated homes of the core math)
# ---------------------------------------------------------------------------

def absmax_int8(x: jax.Array,
                key: Optional[jax.Array] = None) -> int8lib.QuantTensor:
    """Per-tensor absmax int8; stochastic rounding when ``key`` is given.

    The canonical home of ``repro.core.int8.quantize_int8`` (now a
    deprecated shim over this function); math unchanged, bit-exact.
    """
    scale = int8lib.absmax_scale(x)
    v = x.astype(jnp.float32) / scale
    if key is not None:
        v = v + jax.random.uniform(key, x.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(v), -127, 127).astype(jnp.int8)
    return int8lib.QuantTensor(q=q, scale=scale)


def nsd_fakequant(x: jax.Array, key: jax.Array, s: float) -> jax.Array:
    """Paper-faithful NSD fake-quant: Delta * k in x.dtype.

    The canonical home of ``repro.core.nsd.nsd_quantize`` (deprecated
    shim); composes the undeprecated core primitives, bit-exact.
    """
    delta = nsd.compute_delta(x, s)
    k = nsd.nsd_indices(x, key, delta)
    return (k.astype(jnp.float32) * delta).astype(x.dtype)


def nsd_int8(x: jax.Array, key: jax.Array, s: float) -> nsd.QuantizedGrad:
    """NSD to (int8 k, f32 Delta) — home of ``nsd.nsd_quantize_int8``."""
    delta = nsd.compute_delta(x, s)
    k = nsd.nsd_indices(x, key, delta)
    return nsd.QuantizedGrad(k=k.astype(jnp.int8), delta=delta)


# ---------------------------------------------------------------------------
# encoded containers (jit-safe: static shape/dtype metadata)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Bf16Residual:
    data: jax.Array  # bf16, original shape
    dtype: str = dataclasses.field(metadata=dict(static=True),
                                   default="float32")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Int8Residual:
    """Affine per-row int8: value ~= (q + 128) * scale + lo, row-wise."""

    q: jax.Array  # int8 (rows, cols) — rows = prod(shape[:-1])
    scale: jax.Array  # f32 (rows, 1): range / 255 (guarded > 0)
    lo: jax.Array  # f32 (rows, 1): per-row minimum
    shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True),
                                               default=())
    dtype: str = dataclasses.field(metadata=dict(static=True),
                                   default="float32")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Int4Grouped:
    """4-bit grouped-scale: two values per byte, one f32 scale per group.

    ``packed[g, b]`` holds elements ``2b`` (low nibble) and ``2b+1`` (high
    nibble) of group ``g``, each an unsigned 4-bit code ``q + 8`` with
    ``q = round(x / scale_g) in [-7, 7]``.
    """

    packed: jax.Array  # uint8 (n_groups, group // 2)
    scale: jax.Array  # f32 (n_groups, 1): absmax / 7 (guarded > 0)
    shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True),
                                               default=())
    dtype: str = dataclasses.field(metadata=dict(static=True),
                                   default="float32")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RowQuant8:
    """Per-row symmetric absmax int8: value ~= q * scale, row-wise."""

    q: jax.Array  # int8 (rows, cols)
    scale: jax.Array  # f32 (rows, 1): absmax / 127 (guarded > 0)
    shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True),
                                               default=())
    dtype: str = dataclasses.field(metadata=dict(static=True),
                                   default="float32")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SqrtRowQuant8:
    """Sqrt-domain per-row uint8 for non-negative tensors: v ~= (q*scale)^2."""

    q: jax.Array  # uint8 (rows, cols): round(sqrt(v) / scale)
    scale: jax.Array  # f32 (rows, 1): max_row(sqrt(v)) / 255 (guarded > 0)
    shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True),
                                               default=())
    dtype: str = dataclasses.field(metadata=dict(static=True),
                                   default="float32")


def _rows_cols(shape) -> Tuple[int, int]:
    cols = int(shape[-1]) if shape else 1
    return _nelems(shape) // cols, cols


def _no_param(name: str, param: str) -> None:
    if param:
        raise ValueError(f"codec {name!r} takes no @-parameter, got "
                         f"{param!r}")


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------

class Fp32Codec(Codec):
    name = "fp32"
    needs_key = False

    def make_spec(self, param: str) -> QuantSpec:
        _no_param(self.name, param)
        return QuantSpec(codec=self.name, bits=32, layout="dense")

    def encode(self, spec, x, key=None):
        return x

    def decode(self, spec, enc):
        return enc

    def stored_nbytes(self, spec, shape, dtype) -> int:
        return dense_nbytes(shape, dtype)


class RematMode(Fp32Codec):
    """Not a format: the memory subsystem reruns the forward instead of
    storing. Registered so ``"remat"`` validates through the one front
    door; identity + dense accounting here (honest: remat keeps the raw op
    inputs live across the checkpoint boundary)."""

    name = "remat"


class Bf16Codec(Codec):
    name = "bf16"
    needs_key = False

    def make_spec(self, param: str) -> QuantSpec:
        _no_param(self.name, param)
        return QuantSpec(codec=self.name, bits=16, layout="dense")

    def encode(self, spec, x, key=None):
        return Bf16Residual(data=x.astype(jnp.bfloat16),
                            dtype=jnp.dtype(x.dtype).name)

    def decode(self, spec, enc):
        return enc.data.astype(jnp.dtype(enc.dtype))

    def stored_nbytes(self, spec, shape, dtype) -> int:
        return _nelems(shape) * 2

    def capacity_bytes(self, spec, enc) -> int:
        return _nelems(enc.data.shape) * 2

    def error_bound(self, spec, enc):
        # bf16 keeps 8 significand bits: |x - bf16(x)| <= 2^-8 |x|, so in
        # terms of the DECODED value the safe bound is 2^-7 |decoded|.
        return jnp.abs(self.decode(spec, enc)) * jnp.float32(2.0 ** -7)


class Int8RowAffineCodec(Codec):
    name = "int8"
    needs_key = False

    def make_spec(self, param: str) -> QuantSpec:
        _no_param(self.name, param)
        return QuantSpec(codec=self.name, bits=8, granularity="row",
                         layout="row-affine")

    def encode(self, spec, x, key=None):
        cols = x.shape[-1] if x.ndim else 1
        x2 = x.astype(jnp.float32).reshape(-1, cols)
        lo = jnp.min(x2, axis=1, keepdims=True)
        hi = jnp.max(x2, axis=1, keepdims=True)
        scale = jnp.maximum(hi - lo, jnp.finfo(jnp.float32).tiny) / 255.0
        q = jnp.round((x2 - lo) / scale) - 128.0
        q = jnp.clip(q, -128, 127).astype(jnp.int8)
        return Int8Residual(q=q, scale=scale, lo=lo, shape=tuple(x.shape),
                            dtype=jnp.dtype(x.dtype).name)

    def decode(self, spec, enc):
        x2 = (enc.q.astype(jnp.float32) + 128.0) * enc.scale + enc.lo
        return x2.reshape(enc.shape).astype(jnp.dtype(enc.dtype))

    def stored_nbytes(self, spec, shape, dtype) -> int:
        rows, _ = _rows_cols(shape)
        return _nelems(shape) + rows * 8  # q int8 + per-row (scale, lo) f32

    def error_bound(self, spec, enc):
        return jnp.broadcast_to(enc.scale * 0.5,
                                enc.q.shape).reshape(enc.shape)


class NsdCodec(Codec):
    """The paper's operator in wire layout; see ``repro.quant.wire``."""

    name = "nsd"
    needs_key = True

    def __init__(self):
        self.backends = {
            "encode": {"jnp": None, "pallas": None},
            "decode": {"jnp": None, "pallas": None},
            "compute_on_packed": {"jnp": None, "pallas": None},
        }

    def make_spec(self, param: str) -> QuantSpec:
        s = float(param) if param else DEFAULT_NSD_S
        if not s > 0:
            raise ValueError(f"nsd spec: s must be > 0, got {s}")
        return QuantSpec(codec=self.name, bits=8, granularity="chunk",
                         dither="uniform", layout="bitmap+levels", param=s,
                         chunk=wire.DEFAULT_CHUNK)

    def encode(self, spec, x, key, backend: str = "jnp"):
        if key is None:
            raise ValueError("nsd encode needs an RNG key (dithered codec)")
        return wire.pack_nsd(x, key, spec.param,
                             spec.chunk or wire.DEFAULT_CHUNK,
                             backend=backend)

    def decode(self, spec, enc, backend: str = "jnp"):
        return wire.unpack_nsd(enc, backend=backend)

    def stored_nbytes(self, spec, shape, dtype) -> int:
        chunk = spec.chunk or wire.DEFAULT_CHUNK
        n = _nelems(shape)
        padded = ((n + chunk - 1) // chunk) * chunk
        n_chunks = padded // chunk
        # levels capacity + bitmap + per-chunk deltas + nnz scalar
        return padded + padded // 8 + 4 * n_chunks + 4

    def measured_bytes(self, spec, enc) -> jax.Array:
        return enc.wire_bytes()

    def error_bound(self, spec, enc):
        # NSD error is < Delta per element (|x + nu - Delta k| <= Delta/2,
        # |nu| <= Delta/2). Valid for non-saturated elements (|k| < 127) —
        # the clip is a safety net, not part of the bound.
        n = _nelems(enc.shape)
        per_elem = jnp.broadcast_to(
            enc.deltas[:, None], (enc.n_chunks, enc.chunk)).reshape(-1)
        return per_elem[:n].reshape(enc.shape)

    def compute_on_packed(self, spec, enc, x, w, *, backend: str = "jnp"):
        """Both backward products of y = x @ w from the packed cotangent.

        ``enc`` is the PackedNSD of the 2-D pre-activation gradient g~
        (T, N); x: (T, K); w: (K, N). The pallas backend rebuilds the int8
        k tensor + tile mask from the wire bitmap and runs the
        tile-skipping bsp matmuls (``repro.kernels.ops``); the jnp
        reference dequantizes and runs dense products.
        """
        T, N = (int(d) for d in enc.shape)
        if backend == "pallas":
            from repro.kernels import ops

            mask = wire.unpack_bitmap(enc.bitmap).reshape(-1)
            k2d = wire._expand(enc.levels, mask)[: T * N].reshape(T, N)
            q = ops.quantized_from_indices(k2d, enc.deltas[0])
            return ops.bsp_backward_from_quantized(q, x, w,
                                                   int8_operands=True)
        g2d = wire.unpack_nsd(enc).astype(jnp.float32)
        x2d = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        dx = (g2d @ w.astype(jnp.float32).T).reshape(x.shape)
        dw = x2d.T @ g2d
        return dx.astype(x.dtype), dw.astype(w.dtype)


class Int8AbsmaxCodec(Codec):
    """Per-tensor symmetric absmax int8 (``core/int8`` lineage)."""

    name = "int8_absmax"
    needs_key = False  # key optional: stochastic rounding

    def make_spec(self, param: str) -> QuantSpec:
        _no_param(self.name, param)
        return QuantSpec(codec=self.name, bits=8,
                         dither="stochastic-round", layout="dense")

    def encode(self, spec, x, key=None):
        return absmax_int8(x, key)

    def decode(self, spec, enc):
        return enc.q.astype(jnp.float32) * enc.scale

    def stored_nbytes(self, spec, shape, dtype) -> int:
        return _nelems(shape) + 4

    def capacity_bytes(self, spec, enc) -> int:
        return _nelems(enc.q.shape) + 4

    def error_bound(self, spec, enc):
        # scale/2 deterministic; the stochastic-rounding path adds +-0.5
        # before rounding, so the safe bound covering both is one scale.
        return jnp.broadcast_to(enc.scale, enc.q.shape)

    def compute_on_packed(self, spec, enc_x, enc_w, *, backend: str = "jnp",
                          out_dtype=jnp.float32):
        """int8 x int8 -> int32 matmul, rescaled on exit (MXU-native)."""
        return int8lib.int8_matmul(enc_x, enc_w, out_dtype=out_dtype)


class Int4GroupedCodec(Codec):
    """4-bit grouped-scale — the quant subsystem's proof of 'one PR'."""

    name = "int4"
    needs_key = False

    def make_spec(self, param: str) -> QuantSpec:
        raw = param.lstrip("g") if param else ""
        group = int(raw) if raw else DEFAULT_INT4_GROUP
        if group < 2 or group % 2:
            raise ValueError(
                f"int4 spec: group must be even and >= 2, got {group}")
        return QuantSpec(codec=self.name, bits=4, granularity="group",
                         group=group, layout="grouped", param=float(group))

    def encode(self, spec, x, key=None):
        g = spec.group
        flat = x.astype(jnp.float32).reshape(-1)
        pad = (-flat.shape[0]) % g
        flat = jnp.pad(flat, (0, pad))
        g2 = flat.reshape(-1, g)
        scale = jnp.maximum(jnp.max(jnp.abs(g2), axis=1, keepdims=True),
                            jnp.finfo(jnp.float32).tiny) / 7.0
        v = (jnp.clip(jnp.round(g2 / scale), -7, 7) + 8).astype(jnp.uint8)
        packed = (v[:, 0::2] | (v[:, 1::2] << 4)).astype(jnp.uint8)
        return Int4Grouped(packed=packed, scale=scale, shape=tuple(x.shape),
                           dtype=jnp.dtype(x.dtype).name)

    def decode(self, spec, enc):
        lo = (enc.packed & 0xF).astype(jnp.int32) - 8
        hi = (enc.packed >> 4).astype(jnp.int32) - 8
        q = jnp.stack([lo, hi], axis=-1).reshape(enc.packed.shape[0], -1)
        vals = (q.astype(jnp.float32) * enc.scale).reshape(-1)
        n = _nelems(enc.shape)
        return vals[:n].reshape(enc.shape).astype(jnp.dtype(enc.dtype))

    def stored_nbytes(self, spec, shape, dtype) -> int:
        g = spec.group
        n = _nelems(shape)
        n_groups = (n + g - 1) // g
        return n_groups * (g // 2) + 4 * n_groups  # nibbles + f32 scales

    def error_bound(self, spec, enc):
        g = spec.group
        n = _nelems(enc.shape)
        per_elem = jnp.broadcast_to(enc.scale * 0.5,
                                    (enc.scale.shape[0], g)).reshape(-1)
        return per_elem[:n].reshape(enc.shape)


class M8MomentCodec(Codec):
    """Optimizer momentum: per-row symmetric absmax int8, deterministic."""

    name = "m8"
    needs_key = False

    def make_spec(self, param: str) -> QuantSpec:
        _no_param(self.name, param)
        return QuantSpec(codec=self.name, bits=8, granularity="row",
                         layout="row-affine")

    def encode(self, spec, x, key=None):
        cols = x.shape[-1] if x.ndim else 1
        x2 = x.astype(jnp.float32).reshape(-1, cols)
        amax = jnp.max(jnp.abs(x2), axis=1, keepdims=True)
        scale = jnp.maximum(amax, jnp.finfo(jnp.float32).tiny) / 127.0
        q = jnp.clip(jnp.round(x2 / scale), -127, 127).astype(jnp.int8)
        return RowQuant8(q=q, scale=scale, shape=tuple(x.shape),
                         dtype=jnp.dtype(x.dtype).name)

    def decode(self, spec, enc):
        x2 = enc.q.astype(jnp.float32) * enc.scale
        return x2.reshape(enc.shape).astype(jnp.dtype(enc.dtype))

    def stored_nbytes(self, spec, shape, dtype) -> int:
        rows, _ = _rows_cols(shape)
        return _nelems(shape) + rows * 4

    def error_bound(self, spec, enc):
        return jnp.broadcast_to(enc.scale * 0.5,
                                enc.q.shape).reshape(enc.shape)


class U8SqrtMomentCodec(Codec):
    """Optimizer second moment: sqrt-domain per-row uint8 (v >= 0)."""

    name = "u8"
    needs_key = False

    def make_spec(self, param: str) -> QuantSpec:
        _no_param(self.name, param)
        return QuantSpec(codec=self.name, bits=8, granularity="row",
                         layout="row-affine")

    def encode(self, spec, x, key=None):
        cols = x.shape[-1] if x.ndim else 1
        r = jnp.sqrt(jnp.maximum(x.astype(jnp.float32), 0.0)
                     ).reshape(-1, cols)
        rmax = jnp.max(r, axis=1, keepdims=True)
        scale = jnp.maximum(rmax, jnp.finfo(jnp.float32).tiny) / 255.0
        q = jnp.clip(jnp.round(r / scale), 0, 255).astype(jnp.uint8)
        return SqrtRowQuant8(q=q, scale=scale, shape=tuple(x.shape),
                             dtype=jnp.dtype(x.dtype).name)

    def decode(self, spec, enc):
        r = enc.q.astype(jnp.float32) * enc.scale
        return jnp.square(r).reshape(enc.shape).astype(jnp.dtype(enc.dtype))

    def stored_nbytes(self, spec, shape, dtype) -> int:
        rows, _ = _rows_cols(shape)
        return _nelems(shape) + rows * 4

    def error_bound(self, spec, enc):
        # |v - v_hat| = |r - r_hat| (r + r_hat) <= (s/2)(2 r_hat + s/2)
        # with r-domain error <= scale/2 and r <= r_hat + s/2.
        s = jnp.broadcast_to(enc.scale, enc.q.shape)
        r_hat = enc.q.astype(jnp.float32) * enc.scale
        return ((s * 0.5) * (2.0 * r_hat + s * 0.5)).reshape(enc.shape)


register(Fp32Codec())
register(RematMode())
register(Bf16Codec())
register(Int8RowAffineCodec())
register(NsdCodec())
register(Int8AbsmaxCodec())
register(Int4GroupedCodec())
register(M8MomentCodec())
register(U8SqrtMomentCodec())


# ---------------------------------------------------------------------------
# legacy mode grammar (repro.memory.codec compat, now registry-backed)
# ---------------------------------------------------------------------------

MODE_FP32 = "fp32"
MODE_BF16 = "bf16"
MODE_INT8 = "int8"
MODE_NSD = "nsd"
MODE_REMAT = "remat"
MODES = (MODE_FP32, MODE_BF16, MODE_INT8, MODE_NSD, MODE_REMAT)


def parse_mode(mode: str) -> Tuple[str, float]:
    """``"nsd@0.5"`` -> ("nsd", 0.5); other specs get (codec, 0.0).

    The legacy ``repro.memory.codec`` grammar, generalized: any registered
    codec spec parses (so ``"int4@g32"`` is a valid residual/KV mode); the
    (kind, param) pair keeps its historical meaning for the original five,
    and an unregistered codec keeps the historical error wording.
    """
    try:
        spec = parse_spec(mode)
    except ValueError as e:
        if "unknown codec" in str(e):
            raise ValueError(
                f"unknown residual mode {mode!r}; a registered quant codec "
                f"spec (see repro.quant.codec_names)") from None
        raise
    return spec.codec, spec.param if spec.codec == MODE_NSD else 0.0


def validate_mode(mode: str) -> str:
    parse_mode(mode)
    return mode


# ---------------------------------------------------------------------------
# facade dispatch: one entry point per capability
# ---------------------------------------------------------------------------

def encode(mode: str, x: jax.Array, key: Optional[jax.Array] = None):
    """Encode under a spec string; fp32/remat return ``x`` itself."""
    spec = parse_spec(mode)
    if spec.codec in (MODE_FP32, MODE_REMAT):
        return x
    return get_codec(spec.codec).encode(spec, x, key)


def decode(mode: str, enc):
    """Inverse of :func:`encode` (exact or bounded; see error_bound)."""
    spec = parse_spec(mode)
    if spec.codec in (MODE_FP32, MODE_REMAT):
        return enc
    return get_codec(spec.codec).decode(spec, enc)


def quantize(mode: str, x: jax.Array, key: Optional[jax.Array] = None
             ) -> jax.Array:
    """decode(encode(x)) — the fake-quant round trip."""
    spec = parse_spec(mode)
    if spec.codec in (MODE_FP32, MODE_REMAT):
        return x
    return get_codec(spec.codec).quantize(spec, x, key)


def stored_nbytes(mode: str, shape, dtype) -> int:
    """Shape-static bytes the encoding occupies in HBM (capacity)."""
    spec = parse_spec(mode)
    return get_codec(spec.codec).stored_nbytes(spec, shape, dtype)


def capacity_bytes(mode: str, enc) -> int:
    """Static HBM-resident bytes of a concrete encoding."""
    spec = parse_spec(mode)
    if spec.codec in (MODE_FP32, MODE_REMAT):
        return dense_nbytes(enc.shape, enc.dtype)
    return get_codec(spec.codec).capacity_bytes(spec, enc)


def measured_bytes(mode: str, enc) -> jax.Array:
    """Occupancy-aware bytes (traced i32): the wire figure for nsd,
    static capacity for every other codec."""
    spec = parse_spec(mode)
    if spec.codec in (MODE_FP32, MODE_REMAT):
        return jnp.int32(dense_nbytes(enc.shape, enc.dtype))
    return get_codec(spec.codec).measured_bytes(spec, enc)


def error_bound(mode: str, enc):
    """Per-element |decode - x| upper bound, or None when exact."""
    spec = parse_spec(mode)
    if spec.codec in (MODE_FP32, MODE_REMAT):
        return None
    return get_codec(spec.codec).error_bound(spec, enc)


def packed_layout(mode: str, shape, dtype):
    spec = parse_spec(mode)
    return get_codec(spec.codec).packed_layout(spec, shape, dtype)
