"""Packed wire representation of an NSD-quantized tensor.

The paper's distributed argument (§3.6) is that NSD gradients are cheap to
*communicate*, not just to compute with: at the operating points of Table 1
(~80-95% exact zeros, <=8-bit non-zeros) almost all of a dense f32 gradient
is wire waste. This module defines the wire format that realizes that:

    header        4 bytes   (element count)
    deltas        4 bytes per chunk   (f32 step size; per-chunk so future
                                       block-wise scaling rides for free —
                                       NSD fills every entry with the same
                                       per-tensor Delta)
    bitmap        chunk/8 bytes per chunk  (1 bit per element: non-zero?)
    levels        1 byte per NON-ZERO element (int8 k, compacted in order)

so wire bytes = 4 + n_chunks*(4 + chunk/8) + nnz — measured, not estimated.
At the paper's ~92% sparsity point with chunk=256 this is ~5-6% of dense
f32 (bitmap 1/32 + levels 0.08/4 + per-chunk overhead), comfortably under
the 25% acceptance bar.

``pack_nsd``/``unpack_nsd`` are the jnp reference implementation; the
bitmap halves are mirrored by the Pallas kernel pair in
``repro.kernels.pack`` and the levels compact/expand halves by
``repro.kernels.levels`` (select with ``backend="pallas"`` on
``pack_indices``/``unpack_nsd`` — bit-exact vs the jnp path, which does a
full-length cumsum per compact). The round trip is bit-exact against
``repro.core.nsd``: for the same PRNG key, ``unpack_nsd(pack_nsd(x, key,
s)) == nsd.nsd_quantize_int8(x, key, s).dequantize()`` with zero tolerance
(tests/test_comm.py).

Everything is shape-static so it jits and rides through ``shard_map`` /
``ppermute``: the ``levels`` buffer keeps capacity for the all-nonzero worst
case with the live prefix length in ``nnz``; only ``wire_bytes`` (a traced
scalar) reflects what would actually cross a link.

This module lived at ``repro.comm.wireformat`` before the quant subsystem
unified the codec paths; that name remains as a deprecated re-export shim.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import nsd

DEFAULT_CHUNK = 256  # elements per chunk; must be a multiple of 8
HEADER_BYTES = 4
_BIT_WEIGHTS = (1, 2, 4, 8, 16, 32, 64, 128)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedNSD:
    """An NSD-quantized tensor in wire layout (shape-static, jit-safe)."""

    levels: jax.Array  # int8 (n_chunks * chunk,) — non-zero ks compacted
    #                    to the front in flat row-major order, zero padded
    bitmap: jax.Array  # uint8 (n_chunks, chunk // 8) — LSB-first occupancy
    deltas: jax.Array  # f32 (n_chunks,) — step size per chunk
    nnz: jax.Array  # int32 scalar — live prefix length of ``levels``
    shape: Tuple[int, ...] = dataclasses.field(
        metadata=dict(static=True), default=())
    dtype: str = dataclasses.field(metadata=dict(static=True), default="float32")
    chunk: int = dataclasses.field(metadata=dict(static=True),
                                   default=DEFAULT_CHUNK)

    @property
    def n_chunks(self) -> int:
        return self.bitmap.shape[0]

    def wire_bytes(self) -> jax.Array:
        """Bytes this tensor occupies on the wire (traced int32 scalar)."""
        fixed = HEADER_BYTES + self.n_chunks * (4 + self.chunk // 8)
        return jnp.int32(fixed) + self.nnz

    def dense_bytes(self) -> int:
        """Bytes of the dense f32 tensor this replaces (static)."""
        n = 1
        for d in self.shape:
            n *= int(d)
        return n * 4


def _padded_size(n: int, chunk: int) -> int:
    return ((n + chunk - 1) // chunk) * chunk


def pack_bitmap(bits: jax.Array) -> jax.Array:
    """(..., 8m) bool/int occupancy -> (..., m) uint8, LSB-first.

    This is the jnp reference for ``repro.kernels.pack.bitmap_pack_blocked``.
    """
    b = (bits != 0).astype(jnp.int32)
    b8 = b.reshape(bits.shape[:-1] + (bits.shape[-1] // 8, 8))
    w = jnp.asarray(_BIT_WEIGHTS, jnp.int32)
    return jnp.sum(b8 * w, axis=-1).astype(jnp.uint8)


def unpack_bitmap(bitmap: jax.Array) -> jax.Array:
    """(..., m) uint8 -> (..., 8m) bool, inverse of ``pack_bitmap``."""
    shifts = jnp.arange(8, dtype=jnp.int32)
    bits = (bitmap[..., None].astype(jnp.int32) >> shifts) & 1
    return bits.reshape(bitmap.shape[:-1] + (bitmap.shape[-1] * 8,)) != 0


def popcount_u8(x: jax.Array) -> jax.Array:
    """Per-byte population count (SWAR, int32 math) of a uint8 array."""
    v = x.astype(jnp.int32)
    v = v - ((v >> 1) & 0x55)
    v = (v & 0x33) + ((v >> 2) & 0x33)
    return (v + (v >> 4)) & 0x0F


def _pad2d(x: jax.Array, m: int, n: int) -> jax.Array:
    M, N = x.shape
    pm, pn = (-M) % m, (-N) % n
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def tile_nnz_from_bitmap(bitmap: jax.Array, bm: int = 128, bk: int = 128
                         ) -> jax.Array:
    """Per-tile non-zero counts straight from a packed 2-D occupancy bitmap.

    ``bitmap``: (M, K//8) uint8 as produced by
    ``repro.kernels.pack.bitmap_pack_blocked`` (byte b of row i covers
    elements 8b..8b+7). Returns int32 (ceil(M/bm), ceil(K/8/(bk/8))) tile
    counts via a popcount reduction — the bitmap is never expanded to
    element bits, so this is the 1/8th-bandwidth path the backward matmul
    uses to derive its tile mask from the *wire* representation.
    """
    assert bk % 8 == 0, bk
    bkb = bk // 8
    pc = _pad2d(popcount_u8(bitmap), bm, bkb)
    M, KB = pc.shape
    return pc.reshape(M // bm, bm, KB // bkb, bkb).sum((1, 3))


def tile_mask_from_bitmap(bitmap: jax.Array, bm: int = 128, bk: int = 128
                          ) -> jax.Array:
    """(M//bm, K//bk) int32 tile-occupancy mask from a packed 2-D bitmap.

    Any-bit-set reduction (a byte is occupied iff non-zero); shapes that
    are not tile multiples are zero-padded, so padded tiles read 0 =
    skip. Equals ``dense tile mask of the int8 k tensor`` bit-exactly
    (pinned by tests/test_kernels.py).
    """
    assert bk % 8 == 0, bk
    bkb = bk // 8
    nz = _pad2d((bitmap != 0).astype(jnp.int32), bm, bkb)
    M, KB = nz.shape
    tiles = nz.reshape(M // bm, bm, KB // bkb, bkb).sum((1, 3))
    return (tiles > 0).astype(jnp.int32)


def tile_mask_from_packed(p: PackedNSD, bm: int = 128, bk: int = 128
                          ) -> jax.Array:
    """Tile mask for a 2-D tensor directly from its wire-format bitmap.

    Routes through a (M, K//8) byte view when rows are byte-aligned
    (K % 8 == 0) — no bit expansion; otherwise falls back to unpacking
    the bitmap to element bits (bytes straddle rows). Either way the
    result equals the dense-computed tile mask for any shape, including
    all-zero, non-chunk-multiple and single-tile cases (property-tested).
    """
    assert len(p.shape) == 2, p.shape
    M, K = (int(d) for d in p.shape)
    flat = p.bitmap.reshape(-1)
    if K % 8 == 0:
        b2d = flat[: M * K // 8].reshape(M, K // 8)
        return tile_mask_from_bitmap(b2d, bm, bk)
    bits = unpack_bitmap(flat)[: M * K].reshape(M, K)
    occ = _pad2d(bits.astype(jnp.int32), bm, bk)
    Mp, Kp = occ.shape
    tiles = occ.reshape(Mp // bm, bm, Kp // bk, bk).sum((1, 3))
    return (tiles > 0).astype(jnp.int32)


def _compact(k_flat: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Move the non-zeros of an int8 vector to the front, in order."""
    n = k_flat.shape[0]
    nz = k_flat != 0
    pos = jnp.cumsum(nz.astype(jnp.int32)) - 1
    tgt = jnp.where(nz, pos, n)  # out-of-bounds for zeros -> dropped
    levels = jnp.zeros((n,), jnp.int8).at[tgt].set(k_flat, mode="drop")
    return levels, jnp.sum(nz.astype(jnp.int32))


def _expand(levels: jax.Array, mask_flat: jax.Array) -> jax.Array:
    """Inverse of ``_compact`` given the occupancy mask."""
    pos = jnp.cumsum(mask_flat.astype(jnp.int32)) - 1
    return jnp.where(mask_flat, levels[jnp.clip(pos, 0, None)],
                     jnp.zeros((), jnp.int8))


def _compact_pallas(k_flat: jax.Array, chunk: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """``_compact`` via the chunk-local Pallas kernel + a short assembly.

    The kernel compacts each 256-element chunk independently (column-local
    butterfly routing in VMEM, no global cumsum); the global levels buffer
    is then assembled with a cumsum over the *per-chunk counts* — n/256x
    shorter than the jnp path's element cumsum — and one scatter. Stable
    order per chunk + chunks concatenated in order == the jnp result
    bit-exactly (pinned in tests/test_levels_kernel.py).
    """
    from repro.kernels.levels.levels import levels_compact_blocked

    n = k_flat.shape[0]
    n_chunks = n // chunk
    local_t, counts = levels_compact_blocked(
        k_flat.reshape(n_chunks, chunk).T)
    starts = jnp.cumsum(counts) - counts
    i = jnp.arange(chunk, dtype=jnp.int32)[:, None]
    tgt = jnp.where(i < counts[None, :], starts[None, :] + i, n)
    levels = jnp.zeros((n,), jnp.int8).at[tgt.T.reshape(-1)].set(
        local_t.T.reshape(-1), mode="drop")
    return levels, jnp.sum(counts)


def _expand_pallas(levels: jax.Array, mask_flat: jax.Array, chunk: int
                   ) -> jax.Array:
    """``_expand`` via the chunk-local Pallas kernel (see _compact_pallas)."""
    from repro.kernels.levels.levels import levels_expand_blocked

    n = mask_flat.shape[0]
    n_chunks = n // chunk
    m2 = mask_flat.reshape(n_chunks, chunk)
    counts = jnp.sum(m2.astype(jnp.int32), axis=1)
    starts = jnp.cumsum(counts) - counts
    i = jnp.arange(chunk, dtype=jnp.int32)[None, :]
    idx = starts[:, None] + i
    local = jnp.where(i < counts[:, None],
                      levels[jnp.clip(idx, 0, n - 1)],
                      jnp.zeros((), jnp.int8))
    out_t = levels_expand_blocked(local.T, m2.T.astype(jnp.int8))
    return out_t.T.reshape(-1)


def pack_indices(k: jax.Array, delta: jax.Array, shape: Tuple[int, ...],
                 dtype, chunk: int = DEFAULT_CHUNK, *,
                 backend: str = "jnp") -> PackedNSD:
    """Pack precomputed NSD indices (int8/int32 k) + scalar delta.

    Split out from ``pack_nsd`` so callers that already ran the fused
    quantization kernel (which emits k directly) can skip requantizing.
    ``backend="pallas"`` compacts the levels through
    ``repro.kernels.levels`` (chunk must be 256), bit-exact vs the jnp
    full-cumsum path.
    """
    assert chunk % 8 == 0, chunk
    flat = k.astype(jnp.int8).reshape(-1)
    padded = _padded_size(flat.shape[0], chunk)
    flat = jnp.pad(flat, (0, padded - flat.shape[0]))
    n_chunks = padded // chunk
    if backend == "pallas" and chunk == 256:
        levels, nnz = _compact_pallas(flat, chunk)
    else:
        levels, nnz = _compact(flat)
    bitmap = pack_bitmap((flat != 0).reshape(n_chunks, chunk))
    deltas = jnp.broadcast_to(delta.astype(jnp.float32), (n_chunks,))
    return PackedNSD(levels=levels, bitmap=bitmap, deltas=deltas, nnz=nnz,
                     shape=tuple(shape), dtype=jnp.dtype(dtype).name,
                     chunk=chunk)


def pack_nsd(x: jax.Array, key: jax.Array, s: float,
             chunk: int = DEFAULT_CHUNK, *, backend: str = "jnp"
             ) -> PackedNSD:
    """NSD-quantize ``x`` and lay it out in wire format.

    Uses the exact ``repro.core.nsd`` operator (per-tensor Delta = s*std,
    dither noise drawn over the ORIGINAL shape) so the round trip is
    bit-identical to ``nsd.nsd_quantize_int8(x, key, s).dequantize()``.
    """
    delta = nsd.compute_delta(x, s)
    k = nsd.nsd_indices(x, key, delta)
    return pack_indices(k, delta, x.shape, x.dtype, chunk, backend=backend)


def unpack_nsd(p: PackedNSD, *, backend: str = "jnp") -> jax.Array:
    """Reconstruct the dequantized tensor from wire layout alone."""
    mask = unpack_bitmap(p.bitmap).reshape(-1)
    if backend == "pallas" and p.chunk == 256:
        k = _expand_pallas(p.levels, mask, p.chunk)
    else:
        k = _expand(p.levels, mask)
    vals = (k.astype(jnp.float32).reshape(p.n_chunks, p.chunk)
            * p.deltas[:, None]).reshape(-1)
    n = 1
    for d in p.shape:
        n *= int(d)
    return vals[:n].reshape(p.shape).astype(jnp.dtype(p.dtype))


def wire_bytes_dense(shape, dtype=jnp.float32) -> int:
    """Bytes a dense tensor of this shape/dtype occupies on the wire."""
    n = 1
    for d in shape:
        n *= int(d)
    return n * jnp.dtype(dtype).itemsize
