"""Residual codecs: compressed storage of forward residuals (paper §3.5).

The paper's thesis is that backward-pass signals tolerate aggressive
stochastic quantization; the custom_vjp layers in ``repro.core.dithered``
nevertheless used to save their forward residual — the activation ``x``
that the weight-gradient product needs — as dense fp32, so activation
memory, not compute, capped batch size on the dry-run grid. A
``ResidualCodec`` encodes that residual at ``fwd`` time into a compact
jit-safe pytree and decodes it in ``bwd``:

    fp32      identity passthrough (the legacy behavior; the parity arm)
    bf16      2-byte truncation, exact round trip of the bf16-representable
              values
    int8      affine per-row: q = round((x - min_row)/scale_row) - 128 with
              scale_row = range_row/255 — the reconstruction error is
              BOUNDED by scale_row/2 per element (characterized, not exact;
              pinned by tests/test_memory*.py)
    nsd       the paper's own operator in the comm wire layout
              (``repro.comm.wireformat``: per-chunk delta + occupancy
              bitmap + compacted int8 levels). encode->decode is BIT-EXACT
              against ``nsd.nsd_quantize`` for the same key, i.e. the only
              loss is the (unbiased, eq. 5/6-bounded) NSD quantization
              itself. ``"nsd@S"`` selects the dither scale (default
              ``DEFAULT_NSD_S``; residuals want fidelity, so it is gentler
              than the gradient-side default s=2).
    remat     no codec: the op is wrapped in ``jax.checkpoint`` and the
              VJP recomputes the forward from the op inputs instead of
              consuming stored derived residuals. At op granularity the
              checkpoint inputs are the activations themselves, so this is
              the recompute-vs-decode *reference arm* (the ungated
              ``memory_bench`` timing row), not a storage win — span-level
              remat is a ROADMAP follow-up.

Codec selection is per layer and STATIC (it rides ``StaticSpec.residual``
through the custom_vjp), so knob schedules never recompile because of it
(compile-counter pins in tests/test_memory.py). Two byte accountings are
exposed: ``stored_nbytes`` is the shape-static capacity the encoded pytree
occupies in HBM (what the dry-run max-batch estimate prices), and
``measured_bytes`` is the traced occupancy-aware figure (for ``nsd``, the
wire-format bytes a byte-true compacted store would hold) that the
``repro.core.stats`` memory telemetry records.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

MODE_FP32 = "fp32"
MODE_BF16 = "bf16"
MODE_INT8 = "int8"
MODE_NSD = "nsd"
MODE_REMAT = "remat"
MODES = (MODE_FP32, MODE_BF16, MODE_INT8, MODE_NSD, MODE_REMAT)

# "nsd" residuals want fidelity (they feed the weight-gradient product),
# so the default dither scale is gentler than the gradient-side s=2.
DEFAULT_NSD_S = 1.0

# Salt folded into the layer key for the residual encode so the activation
# dither draws an RNG stream independent of the backward's cotangent dither.
RESID_SALT = 0x4E5D


def resid_key(key: jax.Array) -> jax.Array:
    """The residual-encode RNG stream for a layer's per-step key."""
    return jax.random.fold_in(key, RESID_SALT)


@functools.lru_cache(maxsize=None)
def parse_mode(mode: str) -> Tuple[str, float]:
    """``"nsd@0.5"`` -> ("nsd", 0.5); plain modes get their default param."""
    kind, _, param = mode.partition("@")
    if kind not in MODES:
        raise ValueError(
            f"unknown residual mode {mode!r}; one of {MODES} "
            f"(nsd may carry a scale: 'nsd@0.5')")
    if param and kind != MODE_NSD:
        raise ValueError(
            f"residual mode {mode!r}: only 'nsd' takes an @-parameter")
    if kind == MODE_NSD:
        s = float(param) if param else DEFAULT_NSD_S
        if not s > 0:
            raise ValueError(f"residual mode {mode!r}: s must be > 0")
        return kind, s
    return kind, 0.0


def validate_mode(mode: str) -> str:
    parse_mode(mode)
    return mode


def _nelems(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


def dense_nbytes(shape, dtype) -> int:
    """Bytes the dense residual occupies (what the codec replaces)."""
    return _nelems(shape) * jnp.dtype(dtype).itemsize


# ---------------------------------------------------------------------------
# encoded-residual containers (jit-safe: static shape/dtype metadata)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Bf16Residual:
    data: jax.Array  # bf16, original shape
    dtype: str = dataclasses.field(metadata=dict(static=True),
                                   default="float32")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Int8Residual:
    """Affine per-row int8: value ~= (q + 128) * scale + lo, row-wise."""

    q: jax.Array  # int8 (rows, cols) — rows = prod(shape[:-1])
    scale: jax.Array  # f32 (rows, 1): range / 255 (guarded > 0)
    lo: jax.Array  # f32 (rows, 1): per-row minimum
    shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True),
                                               default=())
    dtype: str = dataclasses.field(metadata=dict(static=True),
                                   default="float32")


# ---------------------------------------------------------------------------
# encode / decode dispatch
# ---------------------------------------------------------------------------

def encode(mode: str, x: jax.Array, key: jax.Array):
    """Encode a residual under ``mode``; fp32/remat return ``x`` itself."""
    kind, param = parse_mode(mode)
    if kind in (MODE_FP32, MODE_REMAT):
        return x
    if kind == MODE_BF16:
        return Bf16Residual(data=x.astype(jnp.bfloat16),
                            dtype=jnp.dtype(x.dtype).name)
    if kind == MODE_INT8:
        cols = x.shape[-1] if x.ndim else 1
        x2 = x.astype(jnp.float32).reshape(-1, cols)
        lo = jnp.min(x2, axis=1, keepdims=True)
        hi = jnp.max(x2, axis=1, keepdims=True)
        scale = jnp.maximum(hi - lo, jnp.finfo(jnp.float32).tiny) / 255.0
        q = jnp.round((x2 - lo) / scale) - 128.0
        q = jnp.clip(q, -128, 127).astype(jnp.int8)
        return Int8Residual(q=q, scale=scale, lo=lo, shape=tuple(x.shape),
                            dtype=jnp.dtype(x.dtype).name)
    # nsd: the comm wire layout, bit-exact vs repro.core.nsd for this key
    from repro.comm import wireformat

    return wireformat.pack_nsd(x, key, param)


def decode(mode: str, enc):
    """Inverse of :func:`encode` (exact for fp32/bf16-representable/nsd's
    quantized values; within scale/2 per element for int8)."""
    kind, _ = parse_mode(mode)
    if kind in (MODE_FP32, MODE_REMAT):
        return enc
    if kind == MODE_BF16:
        return enc.data.astype(jnp.dtype(enc.dtype))
    if kind == MODE_INT8:
        x2 = (enc.q.astype(jnp.float32) + 128.0) * enc.scale + enc.lo
        return x2.reshape(enc.shape).astype(jnp.dtype(enc.dtype))
    from repro.comm import wireformat

    return wireformat.unpack_nsd(enc)


# ---------------------------------------------------------------------------
# byte accounting
# ---------------------------------------------------------------------------

def stored_nbytes(mode: str, shape, dtype) -> int:
    """Shape-static bytes the encoded residual occupies in HBM (capacity:
    the ``nsd`` levels buffer keeps worst-case room, see wireformat)."""
    kind, _ = parse_mode(mode)
    n = _nelems(shape)
    if kind in (MODE_FP32, MODE_REMAT):
        # remat saves the raw op inputs across the checkpoint boundary —
        # honest accounting: same bytes as fp32, zero decode cost.
        return dense_nbytes(shape, dtype)
    if kind == MODE_BF16:
        return n * 2
    if kind == MODE_INT8:
        rows = n // int(shape[-1]) if shape else 1
        return n + rows * 8  # q int8 + per-row (scale, lo) f32
    from repro.comm import wireformat

    chunk = wireformat.DEFAULT_CHUNK
    padded = ((n + chunk - 1) // chunk) * chunk
    n_chunks = padded // chunk
    # levels capacity + bitmap + per-chunk deltas + nnz scalar
    return padded + padded // 8 + 4 * n_chunks + 4


def capacity_bytes(mode: str, enc) -> int:
    """Static HBM-resident bytes of an encoded residual (the buffers that
    actually stay live between fwd and bwd — for ``nsd`` the worst-case
    levels capacity, NOT the occupancy figure). This is the number to size
    batch headroom from; :func:`measured_bytes` is the tighter
    wire-equivalent figure a byte-true compacted store would hold."""
    kind, _ = parse_mode(mode)
    if kind == MODE_BF16:
        return _nelems(enc.data.shape) * 2
    return stored_nbytes(mode, enc.shape, enc.dtype)


def measured_bytes(mode: str, enc) -> jax.Array:
    """Occupancy-aware bytes (traced i32): for ``nsd`` the wire-format
    figure (bitmap + live levels prefix + deltas), static capacity for
    every other mode."""
    kind, _ = parse_mode(mode)
    if kind == MODE_NSD:
        return enc.wire_bytes()
    return jnp.int32(capacity_bytes(mode, enc))
