"""DEPRECATED shim: the residual codecs moved to :mod:`repro.quant`.

The fp32/bf16/int8/nsd/remat residual formats are now registered codecs in
the one quantization engine (``repro.quant.codecs``), resolved through the
same spec strings this module accepted (numerics pinned bit-for-bit by
tests/test_quant.py and the ``memory_bench`` zero-band gates — and the
grammar widened: any registered codec, e.g. ``"int4@g32"``, is now a valid
residual mode). Importing this module warns once per process; update
imports::

    from repro.memory import codec        # old
    from repro import quant as codec      # new (same functions)
"""
from __future__ import annotations

import warnings

from repro.quant.codecs import (  # noqa: F401
    DEFAULT_NSD_S, MODE_BF16, MODE_FP32, MODE_INT8, MODE_NSD, MODE_REMAT,
    MODES, RESID_SALT, Bf16Residual, Int8Residual, capacity_bytes, decode,
    encode, measured_bytes, parse_mode, quantize, resid_key, stored_nbytes,
    validate_mode)
from repro.quant.registry import _nelems, dense_nbytes  # noqa: F401

warnings.warn(
    "repro.memory.codec is deprecated; import repro.quant instead "
    "(same API, bit-exact, over the codec registry)",
    DeprecationWarning, stacklevel=2)
