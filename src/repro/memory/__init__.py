"""repro.memory — compressed residual store & per-layer rematerialization.

codec.py       DEPRECATED shim over ``repro.quant`` — the residual formats
               are registered codecs in the one quantization engine now
policy.py      MemoryPolicy per-layer rules + the --memory-program DSL
accounting.py  eval_shape residual-footprint reports for the dry-run grid

The re-exports below come straight from ``repro.quant`` (bit-exact, same
API), so ``repro.memory.encode`` etc. keep working without the deprecation
warning that importing ``repro.memory.codec`` itself raises.
"""
from repro.memory.accounting import footprint_totals, residual_report
from repro.quant.codecs import (
    DEFAULT_NSD_S,
    MODE_BF16,
    MODE_FP32,
    MODE_INT8,
    MODE_NSD,
    MODE_REMAT,
    MODES,
    capacity_bytes,
    decode,
    dense_nbytes,
    encode,
    measured_bytes,
    parse_mode,
    resid_key,
    stored_nbytes,
    validate_mode,
)
from repro.memory.policy import (
    MemoryPolicy,
    MemoryRule,
    as_memory_policy,
    parse_memory_program,
)

__all__ = [
    "DEFAULT_NSD_S", "MODE_BF16", "MODE_FP32", "MODE_INT8", "MODE_NSD",
    "MODE_REMAT", "MODES", "capacity_bytes", "decode", "dense_nbytes",
    "encode", "measured_bytes", "parse_mode", "resid_key", "stored_nbytes",
    "validate_mode",
    "MemoryPolicy", "MemoryRule", "as_memory_policy", "parse_memory_program",
    "footprint_totals", "residual_report",
]
