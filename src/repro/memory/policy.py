"""Per-layer residual-memory policy: which codec (or remat) each layer gets.

``MemoryPolicy`` mirrors the rule machinery of
``repro.core.schedule.LayerRule`` — ordered glob/substring patterns, last
match wins — but selects a *residual mode* (any registered quant codec
spec, ``repro.quant``; the legacy five are ``MODES``)
instead of dither knobs. Resolution happens by static layer name at trace
time through :meth:`repro.core.policy.DitherCtx.resolve`, which stamps the
mode onto the resolved ``StaticSpec.residual``; the choice is therefore
static per layer and can never invalidate the compiled step on a knob
schedule (the PR-4 traced-knobs invariant, pinned by compile-counter
tests in tests/test_memory.py).

The subsystem covers the layers dithered backprop covers: a layer whose
dither resolution is ``None`` (policy off / excluded) runs the plain
primal with autodiff's own dense residuals.

CLI surface (``--memory-program`` on ``launch/train.py`` and
``launch/dryrun.py``)::

    default=nsd;rule fc0:int8;rule c*:remat;rule lm_head:fp32

clauses separated by ';':
  default=MODE          base mode for every dithered layer (default fp32)
  rule PATTERN:MODE     per-layer override; glob when the pattern contains
                        */?/[, substring otherwise; last match wins
MODE: any registered quant codec spec (repro.quant.codec_names()),
      e.g. fp32 | bf16 | int8 | nsd | nsd@S | int4@gG | m8 | remat
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

from repro.core.schedule import pattern_matches
from repro.quant.codecs import MODE_FP32, validate_mode

# a literal, not a __doc__ slice: -OO strips docstrings (schedule.py idiom)
_SPEC_DOC = """\
clauses separated by ';':
  default=MODE          base mode for every dithered layer (default fp32)
  rule PATTERN:MODE     per-layer override; glob when the pattern contains
                        */?/[, substring otherwise; last match wins
MODE: any registered quant codec spec (repro.quant.codec_names()),
      e.g. fp32 | bf16 | int8 | nsd | nsd@S | int4@gG | m8 | remat
"""


@dataclasses.dataclass(frozen=True)
class MemoryRule:
    """``pattern -> residual mode`` for the matching layers."""

    pattern: str = "*"
    mode: str = MODE_FP32

    def __post_init__(self):
        if not self.pattern:
            raise ValueError("MemoryRule: pattern must be a non-empty string")
        try:
            validate_mode(self.mode)
        except ValueError as e:
            raise ValueError(f"MemoryRule({self.pattern!r}): {e}") from None

    def matches(self, name: str) -> bool:
        return pattern_matches(self.pattern, name)


@dataclasses.dataclass(frozen=True)
class MemoryPolicy:
    """Ordered per-layer residual rules over a default mode (frozen and
    hashable, so it can ride in jit closures / static arguments)."""

    default: str = MODE_FP32
    rules: Tuple[MemoryRule, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(self.rules))
        try:
            validate_mode(self.default)
        except ValueError as e:
            raise ValueError(f"MemoryPolicy: {e}") from None

    def mode_for(self, name: str) -> str:
        mode = self.default
        for rule in self.rules:
            if rule.matches(name):
                mode = rule.mode
        return mode

    def replace(self, **kw) -> "MemoryPolicy":
        return dataclasses.replace(self, **kw)


def parse_memory_program(spec: str) -> MemoryPolicy:
    """Parse the ``--memory-program`` spec string (grammar in the module
    docstring, printed verbatim in every parse error)."""
    default = MODE_FP32
    rules = []
    for clause in (c.strip() for c in spec.split(";")):
        if not clause:
            continue
        if clause.startswith("rule "):
            body = clause[len("rule "):]
            if ":" not in body:
                raise ValueError(
                    f"memory-program clause {clause!r}: rule syntax is "
                    f"'rule PATTERN:MODE'; grammar:\n{_SPEC_DOC}")
            pattern, mode = body.split(":", 1)
            rules.append(MemoryRule(pattern=pattern.strip(),
                                    mode=mode.strip()))
            continue
        if clause.startswith("default="):
            default = clause[len("default="):].strip()
            validate_mode(default)
            continue
        raise ValueError(
            f"memory-program: cannot parse clause {clause!r}; grammar:\n"
            + _SPEC_DOC)
    return MemoryPolicy(default=default, rules=tuple(rules))


def as_memory_policy(x: Union[None, str, MemoryPolicy]
                     ) -> Optional[MemoryPolicy]:
    """Lift a spec string (or pass through a MemoryPolicy / None)."""
    if x is None or isinstance(x, MemoryPolicy):
        return x
    if isinstance(x, str):
        return parse_memory_program(x) if x else None
    raise TypeError(
        f"expected MemoryPolicy, spec string or None, got {type(x)!r}")
