"""Residual-footprint accounting: bytes the backward keeps alive, per layer.

``residual_report`` traces one loss evaluation under ``jax.eval_shape``
(no FLOPs, no allocation — the same recorder mechanism as
``schedule.discover_layer_names``) with a ``mem_recorder`` ctx, and returns
``{layer_name: (stored_bytes, dense_bytes)}`` for every layer the dither
policy covers: ``stored`` is the shape-static capacity of the encoded
residual under the memory policy, ``dense`` what the legacy fp32 store
would hold. The dry-run grid prices the totals through
``launch.costmodel.price_memory`` into a peak-residual-per-chip figure and
a max-batch estimate per cell.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import VARIANT_PAPER, DitherCtx, DitherPolicy
from repro.core.schedule import as_program
from repro.memory.policy import MemoryPolicy, as_memory_policy


def residual_report(loss_fn: Callable, params, batch, *,
                    policy=None,
                    memory: Optional[MemoryPolicy | str] = None,
                    step: int = 0) -> Dict[str, Tuple[int, int]]:
    """Per-layer ``(stored_bytes, dense_bytes)`` of one loss evaluation.

    ``loss_fn(params, batch, ctx)`` must thread ctx like ``Model.loss``;
    ``params``/``batch`` may be ShapeDtypeStructs. ``policy`` is the dither
    policy or program the run uses (default: the paper variant, which
    covers every ditherable layer); layers it leaves un-dithered do not
    appear — autodiff owns their residuals.

    Caveat (same as XLA's cost analysis): a ``lax.scan``-stacked model
    traces its layer body ONCE, so scanned stacks report one body's worth
    of residual bytes, not depth x body. Compression ratios are unaffected
    (every layer of a uniform stack scales identically); absolute totals
    for scanned models are per-body figures.
    """
    program = as_program(policy if policy is not None
                         else DitherPolicy(variant=VARIANT_PAPER))
    phase0 = program.phase_policy_at(step)
    rec: Dict[str, Tuple[int, int]] = {}
    ctx = DitherCtx(key=jax.random.PRNGKey(0), policy=phase0,
                    program=program, step=jnp.asarray(step, jnp.int32),
                    memory=as_memory_policy(memory), mem_recorder=rec)
    jax.eval_shape(lambda p, b: loss_fn(p, b, ctx), params, batch)
    return rec


def footprint_totals(report: Dict[str, Tuple[int, int]]) -> Tuple[int, int]:
    """(total stored, total dense) bytes over a :func:`residual_report`."""
    stored = sum(s for s, _ in report.values())
    dense = sum(d for _, d in report.values())
    return stored, dense
