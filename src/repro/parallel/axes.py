"""Logical-axis sharding: the single place where model dims meet mesh axes.

Models annotate every parameter dim and key activations with *logical* axis
names ("embed", "mlp", "q_heads", ...). A ``Rules`` object maps logical names
to mesh axes; conversion checks divisibility and silently falls back to
replication for dims the mesh cannot split (e.g. 40 query heads on a 16-way
model axis) — the fallback is *recorded* so the dry-run can report it.

Rules are installed with a context manager, so model code stays mesh-free
and single-device tests/smoke runs see no sharding machinery at all.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

MeshAxes = Union[None, str, Tuple[str, ...]]

# Interconnect class per mesh axis name: collectives over a "dcn" axis
# cross the slow inter-pod network; every other axis rides intra-pod ICI.
# repro.launch.mesh.NodeTopology consults this for axes it doesn't own and
# repro.launch.costmodel prices the two classes at separate bandwidths.
LINK_KINDS = {"pod": "dcn", "pods": "dcn"}


def axis_link_kind(axis_name: str) -> str:
    """"ici" | "dcn" for a mesh axis name (default: ici)."""
    return LINK_KINDS.get(axis_name, "ici")


def shard_map_compat(f, *, mesh, in_specs, out_specs, check: bool = True):
    """``shard_map`` across jax versions: the entry point moved from
    ``jax.experimental.shard_map`` to ``jax.shard_map`` and the replication
    check was renamed ``check_rep`` -> ``check_vma`` (at different releases,
    so all four combinations exist in the wild)."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    import inspect
    params = inspect.signature(sm).parameters
    check_kw = "check_vma" if "check_vma" in params else "check_rep"
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{check_kw: check})


@dataclasses.dataclass
class Rules:
    """logical axis name -> mesh axis (or tuple of axes, or None)."""

    mapping: Dict[str, MeshAxes]
    mesh: Mesh

    fallbacks: list = dataclasses.field(default_factory=list)

    def _axis_size(self, axes: MeshAxes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        size = 1
        for a in axes:
            size *= self.mesh.shape[a]
        return size

    def resolve_dim(self, logical: Optional[str], dim_size: int) -> MeshAxes:
        if logical is None:
            return None
        axes = self.mapping.get(logical)
        if axes is None:
            return None
        n = self._axis_size(axes)
        if n == 1:
            return None
        if dim_size % n != 0:
            self.fallbacks.append((logical, dim_size, axes))
            return None
        return axes

    def pspec(self, logical_axes: Sequence[Optional[str]],
              shape: Sequence[int]) -> PartitionSpec:
        assert len(logical_axes) == len(shape), (logical_axes, shape)
        used: set = set()
        parts = []
        for name, dim in zip(logical_axes, shape):
            axes = self.resolve_dim(name, dim)
            # one mesh axis may shard at most one tensor dim
            flat = (axes,) if isinstance(axes, str) else (axes or ())
            if any(a in used for a in flat):
                parts.append(None)
                continue
            used.update(flat)
            parts.append(axes)
        return PartitionSpec(*parts)

    def sharding(self, logical_axes: Sequence[Optional[str]],
                 shape: Sequence[int]) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec(logical_axes, shape))


_CURRENT: contextvars.ContextVar[Optional[Rules]] = contextvars.ContextVar(
    "repro_sharding_rules", default=None
)


def current_rules() -> Optional[Rules]:
    return _CURRENT.get()


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    token = _CURRENT.set(rules)
    try:
        yield rules
    finally:
        _CURRENT.reset(token)


def shard_act(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """Sharding-constrain an activation; no-op when no rules installed."""
    rules = current_rules()
    if rules is None:
        return x
    if len(logical_axes) != x.ndim:
        return x
    spec = rules.pspec(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def spec_tree_to_shardings(specs: Any, rules: Rules, params: Any) -> Any:
    """Convert a logical-axes tree (mirroring params) to NamedShardings."""
    def conv(spec, p):
        shape = p.shape if hasattr(p, "shape") else np.shape(p)
        if spec is None or len(spec) != len(shape):
            # rank mismatch (e.g. scalar master-weight placeholders) -> replicate
            spec = (None,) * len(shape)
        return rules.sharding(spec, shape)

    return jax.tree.map(
        conv, specs, params,
        is_leaf=lambda s: s is None or (isinstance(s, tuple) and all(
            a is None or isinstance(a, str) for a in s)),
    )


# ---------------------------------------------------------------------------
# Standard rule sets (hillclimbing edits these)
# ---------------------------------------------------------------------------

def tp_dp_rules(mesh: Mesh, *, fsdp: bool = False, seq_shard: bool = False,
                data_axes: Tuple[str, ...] = None) -> Rules:
    """Megatron-style TP over "model", DP over ("pod","data").

    fsdp=True additionally shards the non-TP weight dim over "data" (weight-
    gathered on use) — used for big-weight/small-batch decode cells.
    seq_shard=True shards the sequence dim of activations over "model"
    (sequence parallelism for the long-context cells).
    """
    if data_axes is None:
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    mapping: Dict[str, MeshAxes] = {
        # parameters
        "embed": ("data" if fsdp and "data" in mesh.shape else None),
        "vocab": "model",
        "mlp": "model",
        "q_heads": "model",
        "kv_heads": "model",
        "expert": "model",
        "expert_mlp": None,
        "ssm_inner": "model",
        "ssm_state": None,
        "conv_w": None,
        # activations
        "batch": data_axes,
        "seq": ("model" if seq_shard else None),
        "attn_seq": ("model" if seq_shard else None),  # follows seq (It5 refuted decoupling)
        "act_embed": None,
        "act_heads": "model",
        "act_mlp": "model",
        "act_vocab": "model",
        "act_expert": "model",
        "act_ssm_inner": "model",
    }
    return Rules(mapping=mapping, mesh=mesh)
