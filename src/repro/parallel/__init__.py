from repro.parallel import axes
from repro.parallel.axes import Rules, shard_act, use_rules, current_rules, tp_dp_rules

__all__ = ["axes", "Rules", "shard_act", "use_rules", "current_rules", "tp_dp_rules"]
