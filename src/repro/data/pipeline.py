"""Sharded, prefetching input pipeline.

On a real cluster every host loads only its slice of the global batch
(process_index-based striding) and the arrays are formed into globally-
sharded jax.Arrays via ``make_array_from_process_local_data``. On one host
this degrades gracefully to plain device_put. A background thread keeps
``prefetch`` batches in flight so step N+1's host->device copy overlaps
step N's compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


class ShardedLoader:
    """Wraps a (step -> host batch) function into a prefetched iterator."""

    def __init__(self, batch_fn: Callable[[int], Dict[str, jax.Array]],
                 mesh: Optional[Mesh] = None,
                 batch_axes: tuple = ("data",),
                 prefetch: int = 2, start_step: int = 0):
        self.batch_fn = batch_fn
        self.mesh = mesh
        self.batch_axes = batch_axes
        self.prefetch = prefetch
        self._step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # -- host-side sharding -------------------------------------------------
    def _host_slice(self, global_batch: int) -> slice:
        n_proc = jax.process_count()
        per = global_batch // n_proc
        i = jax.process_index()
        return slice(i * per, (i + 1) * per)

    def _to_device(self, batch: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        if self.mesh is None:
            return {k: jax.device_put(v) for k, v in batch.items()}
        out = {}
        for k, v in batch.items():
            spec = PartitionSpec(self.batch_axes) if v.ndim >= 1 \
                else PartitionSpec()
            sh = NamedSharding(self.mesh, spec)
            if jax.process_count() > 1:
                out[k] = jax.make_array_from_process_local_data(sh, np.asarray(v))
            else:
                out[k] = jax.device_put(v, sh)
        return out

    # -- prefetch thread ----------------------------------------------------
    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                batch = self.batch_fn(step)
                self._q.put((step, self._to_device(batch)), timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
