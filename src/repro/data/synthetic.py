"""Deterministic synthetic datasets (the container is offline).

* ``TokenStream`` — zipf-distributed token sequences with a planted bigram
  structure so an LM actually has something to learn (loss decreases).
* ``make_classification`` — MNIST/CIFAR-shaped image classification built
  from class prototypes + noise; linearly-ish separable at low noise so the
  paper's CNNs train to high accuracy in a few hundred steps.

Everything is seeded and reproducible across hosts: sample i of epoch e is a
pure function of (seed, e, i), which is what lets the distributed trainer
shard by host without coordination (and re-shard after elastic resize).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    zipf_a: float = 1.2


def _bigram_table(vocab: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.permutation(vocab)


def token_batch(cfg: TokenStreamConfig, step: int) -> Dict[str, jax.Array]:
    """Batch ``step`` of the stream: half-zipf noise, half planted bigrams."""
    rng = np.random.default_rng((cfg.seed, step))
    ranks = rng.zipf(cfg.zipf_a, size=(cfg.batch, cfg.seq_len + 1))
    toks = np.minimum(ranks - 1, cfg.vocab - 1).astype(np.int32)
    table = _bigram_table(cfg.vocab, cfg.seed)
    # plant: every even position deterministically maps to table[prev]
    nxt = table[toks[:, :-1]]
    mask = (np.arange(cfg.seq_len)[None, :] % 2) == 1
    seq = np.where(mask, nxt, toks[:, 1:])
    full = np.concatenate([toks[:, :1], seq], axis=1)
    return {
        "tokens": jnp.asarray(full[:, :-1]),
        "labels": jnp.asarray(full[:, 1:]),
    }


@dataclasses.dataclass(frozen=True)
class ClassifConfig:
    n_classes: int = 10
    img_size: int = 28
    channels: int = 1
    noise: float = 0.35
    seed: int = 0


def _prototypes(cfg: ClassifConfig) -> np.ndarray:
    rng = np.random.default_rng(cfg.seed)
    return rng.normal(
        0, 1, (cfg.n_classes, cfg.img_size, cfg.img_size, cfg.channels)
    ).astype(np.float32)


def classification_batch(cfg: ClassifConfig, step: int, batch: int
                         ) -> Dict[str, jax.Array]:
    rng = np.random.default_rng((cfg.seed, 7, step))
    labels = rng.integers(0, cfg.n_classes, size=(batch,))
    protos = _prototypes(cfg)
    x = protos[labels] + cfg.noise * rng.normal(
        0, 1, (batch, cfg.img_size, cfg.img_size, cfg.channels))
    return {"images": jnp.asarray(x.astype(np.float32)),
            "labels": jnp.asarray(labels.astype(np.int32))}


def classification_eval_set(cfg: ClassifConfig, n: int = 1024,
                            batch: int = 256) -> Iterator[Dict[str, jax.Array]]:
    for i in range(n // batch):
        yield classification_batch(cfg, step=1_000_000 + i, batch=batch)
