from repro.data.synthetic import (
    ClassifConfig, TokenStreamConfig, classification_batch,
    classification_eval_set, token_batch,
)
from repro.data.pipeline import ShardedLoader

__all__ = ["ClassifConfig", "TokenStreamConfig", "classification_batch",
           "classification_eval_set", "token_batch", "ShardedLoader"]
