"""One ``--program`` front door for the launcher DSLs.

The launchers historically grew one flag per subsystem DSL
(``--policy-program`` for the dither schedule, ``--memory-program`` for
residual codecs) and were about to grow a third for the comm policy. This
module unifies them behind a single spec with section prefixes::

    --program "dither: phase@0=off;phase@30=paper;rule lm_head:off \
               memory: default=nsd;rule fc0:int8 \
               comm: topology=butterfly;pods=4;bucket_bytes=1048576 \
               quant: grad=int4@g32;mu=m8;nu=u8"

A section starts at a whitespace-separated token beginning with one of
``dither:`` / ``memory:`` / ``comm:`` / ``quant:``; everything until the
next section marker belongs to it and is handed VERBATIM to that
subsystem's existing parser (``repro.core.schedule.parse_program``,
``repro.memory.policy.parse_memory_program``,
``repro.comm.reducer.parse_comm_program``,
``repro.quant.parse_quant_program``) — this module owns only the
splitting, so each DSL's grammar stays where it lives. Colons inside
clauses (``rule lm_head:off``) never start a section because only the
known prefixes do.

``--policy-program`` / ``--memory-program`` remain as deprecated aliases
(merged into the corresponding section; collisions are errors), see
``merge_legacy_flags``. Round-trip pinned by tests/test_program.py.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

SECTIONS = ("dither", "memory", "comm", "quant")

__all__ = ["SECTIONS", "LaunchSpec", "format_program", "merge_legacy_flags",
           "parse_program"]


@dataclasses.dataclass(frozen=True)
class LaunchSpec:
    """The raw DSL sections of one ``--program`` spec."""

    dither: str = ""
    memory: str = ""
    comm: str = ""
    quant: str = ""

    def dither_program(self, base):
        """Resolve the dither section to a PolicyProgram over ``base``."""
        from repro.core.schedule import parse_program as parse_dither
        return parse_dither(self.dither, base=base) if self.dither else None

    def memory_policy(self):
        """Resolve the memory section to a MemoryPolicy (None if empty)."""
        if not self.memory:
            return None
        from repro.memory.policy import parse_memory_program
        return parse_memory_program(self.memory)

    def comm_policy(self, base=None):
        """Resolve the comm section to a CommPolicy (None if empty)."""
        if not self.comm:
            return None
        from repro.comm.reducer import parse_comm_program
        return parse_comm_program(self.comm, base)

    def quant_overrides(self):
        """Resolve the quant section to a QuantProgram (None if empty)."""
        if not self.quant:
            return None
        from repro.quant import parse_quant_program
        return parse_quant_program(self.quant)


def parse_program(spec: str) -> LaunchSpec:
    """Split a ``--program`` spec into its sections.

    The spec must START with a section marker — a bare DSL string is
    ambiguous (which subsystem?), so it is an error that names the legacy
    single-purpose flags as the migration hint.
    """
    sections = {name: [] for name in SECTIONS}
    current: Optional[str] = None
    for tok in spec.split():
        for name in SECTIONS:
            prefix = name + ":"
            if tok.startswith(prefix):
                if sections[name]:
                    raise ValueError(
                        f"duplicate {prefix!r} section in --program spec")
                current = name
                tok = tok[len(prefix):]
                break
        if current is None:
            raise ValueError(
                f"--program spec must start with a section prefix "
                f"({', '.join(s + ':' for s in SECTIONS)}); got {tok!r}. "
                "Migrating from --policy-program? That string goes under "
                "'dither:'; --memory-program under 'memory:'.")
        if tok:
            sections[current].append(tok)
    return LaunchSpec(**{name: " ".join(parts)
                         for name, parts in sections.items()})


def format_program(spec: LaunchSpec) -> str:
    """Render a LaunchSpec back to ``--program`` text (parse round-trips)."""
    parts = []
    for name in SECTIONS:
        body = getattr(spec, name)
        if body:
            parts.append(f"{name}: {body}")
    return " ".join(parts)


def merge_legacy_flags(program: str, policy_program: str = "",
                       memory_program: str = "") -> LaunchSpec:
    """Combine ``--program`` with the deprecated per-DSL flags.

    Each legacy flag maps onto its section; supplying both the flag AND
    that section in ``--program`` is a hard error (silently preferring
    one would mask a config mistake). Legacy flags warn.
    """
    spec = parse_program(program) if program else LaunchSpec()
    for flag, field, value in (("--policy-program", "dither",
                                policy_program),
                               ("--memory-program", "memory",
                                memory_program)):
        if not value:
            continue
        warnings.warn(
            f"{flag} is deprecated; use --program \"{field}: {value}\"",
            DeprecationWarning, stacklevel=2)
        if getattr(spec, field):
            raise ValueError(
                f"{flag} conflicts with the '{field}:' section of "
                "--program; specify one")
        spec = dataclasses.replace(spec, **{field: value})
    return spec
