"""Roofline-term extraction from compiled (AOT) artifacts.

This container is CPU-only; TPU v5e is the *target*. The three terms are
derived statically per (arch x shape x mesh) cell:

    compute_s    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory_s     = HLO_bytes_per_chip / HBM_bw
    collective_s = collective_bytes_per_chip / link_bw

``compiled.cost_analysis()`` on an SPMD-partitioned module reports the
per-device module (verified empirically: a 512-way sharded matmul reports
1/512th of the global FLOPs), so the formulas above already match the
assignment's "global / (chips x peak)" convention.

collective_bytes comes from parsing the optimized HLO: result types of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops, converted to *per-chip bytes on the wire* with ring-algorithm factors
and the collective's group size. The raw operand-sum metric the assignment
asks for is reported alongside (``naive_collective_bytes``).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

# --- TPU v5e per-chip constants (assignment-provided) ---
PEAK_BF16_FLOPS = 197e12
PEAK_INT8_OPS = 394e12
HBM_BW = 819e9  # bytes/s
HBM_CAP = 16e9  # bytes of HBM per chip (v5e: 16 GB)
ICI_BW = 50e9  # bytes/s per link (intra-pod)
# Inter-pod data-center network: ~50 Gbps per host NIC. An order of
# magnitude below ICI — the gap the hierarchical reduce is built around.
DCN_BW = 6.25e9  # bytes/s per pod-crossing link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        inner = m.group(1).strip()
        if inner:
            return len(inner.split(","))
    return 1


@dataclasses.dataclass
class CollectiveRecord:
    op: str
    result_bytes: int
    group_size: int

    @property
    def operand_bytes(self) -> int:
        """Bytes of the per-chip input operand."""
        if self.op == "all-gather":
            return self.result_bytes // max(self.group_size, 1)
        if self.op == "reduce-scatter":
            return self.result_bytes * self.group_size
        return self.result_bytes

    @property
    def wire_bytes(self) -> int:
        """Ring-algorithm per-chip bytes actually crossing links."""
        n = max(self.group_size, 1)
        if n == 1:
            return 0
        if self.op == "all-reduce":
            return int(2 * (n - 1) / n * self.operand_bytes)
        if self.op == "all-gather":
            return int((n - 1) / n * self.result_bytes)
        if self.op == "reduce-scatter":
            return int((n - 1) / n * self.operand_bytes)
        if self.op == "all-to-all":
            return int((n - 1) / n * self.operand_bytes)
        return self.operand_bytes  # collective-permute


def parse_collectives(hlo_text: str) -> List[CollectiveRecord]:
    recs: List[CollectiveRecord] = []
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        if m.group(3) == "-done":  # async pair: count the -start only
            continue
        type_str, op = m.group(1), m.group(2)
        recs.append(CollectiveRecord(
            op=op, result_bytes=_type_bytes(type_str),
            group_size=_group_size(line)))
    return recs


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    flops_per_chip: float
    bytes_per_chip: float
    wire_bytes_per_chip: float
    naive_collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_global: float
    useful_ratio: float
    roofline_fraction: float
    collectives_by_op: Dict[str, float]
    memory_stats: Dict[str, float]

    def row(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


def analyze(*, arch: str, shape: str, mesh_name: str, n_chips: int,
            cost: Dict[str, float], hlo_text: str,
            model_flops_global: float,
            memory_stats: Optional[Dict[str, float]] = None,
            peak_flops: float = PEAK_BF16_FLOPS) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    recs = parse_collectives(hlo_text)
    wire = float(sum(r.wire_bytes for r in recs))
    naive = float(sum(r.operand_bytes for r in recs))
    by_op: Dict[str, float] = {}
    for r in recs:
        by_op[r.op] = by_op.get(r.op, 0.0) + r.wire_bytes

    compute_s = flops / peak_flops
    memory_s = byts / HBM_BW
    collective_s = wire / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = model_flops_global / max(flops * n_chips, 1.0)
    bound = max(compute_s, memory_s, collective_s)
    frac = (model_flops_global / (n_chips * peak_flops)) / max(bound, 1e-30)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_chips=n_chips,
        flops_per_chip=flops, bytes_per_chip=byts,
        wire_bytes_per_chip=wire, naive_collective_bytes=naive,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops_global=model_flops_global,
        useful_ratio=useful, roofline_fraction=frac,
        collectives_by_op=by_op, memory_stats=memory_stats or {},
    )


def model_flops(kind: str, n_active_params: float, seq: int, batch: int
                ) -> float:
    """MODEL_FLOPS = 6 N D for training, 2 N D for inference passes."""
    tokens = seq * batch if kind in ("train", "prefill") else batch
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_params * tokens
