"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax initialization).

Target hardware: TPU v5e pods — 256 chips/pod, (16, 16) 2D slice per pod;
multi-pod adds a leading "pod" axis over DCN. Per-chip constants used by the
roofline harness live in repro.launch.roofline.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def _auto_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """jax.make_mesh across jax versions: ``axis_types`` (and the Auto axis
    type itself) only exist in newer releases; older ones default to Auto."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(
        shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _auto_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (elastic re-mesh after failures uses this)."""
    return _auto_mesh(shape, axes)


def host_device_mesh(n_model: int = 1, n_data: Optional[int] = None) -> Mesh:
    """Mesh over however many (host) devices exist — used by tests."""
    n = jax.device_count()
    if n_data is None:
        n_data = n // n_model
    return make_mesh((n_data, n_model), ("data", "model"))
