"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax initialization).

Target hardware: TPU v5e pods — 256 chips/pod, (16, 16) 2D slice per pod;
multi-pod adds a leading "pod" axis over DCN. Per-chip constants used by the
roofline harness live in repro.launch.roofline.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.parallel.axes import axis_link_kind


def _auto_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """jax.make_mesh across jax versions: ``axis_types`` (and the Auto axis
    type itself) only exist in newer releases; older ones default to Auto."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(
        shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _auto_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (elastic re-mesh after failures uses this)."""
    return _auto_mesh(shape, axes)


def host_device_mesh(n_model: int = 1, n_data: Optional[int] = None) -> Mesh:
    """Mesh over however many (host) devices exist — used by tests."""
    n = jax.device_count()
    if n_data is None:
        n_data = n // n_model
    return make_mesh((n_data, n_model), ("data", "model"))


@dataclasses.dataclass(frozen=True)
class NodeTopology:
    """Physical layout of the data-parallel node set: pods x nodes-per-pod.

    The descriptor the comm subsystem plans its reduce around: collectives
    over ``node_axis`` ride the fast intra-pod interconnect (ICI),
    collectives over ``pod_axis`` cross the slow inter-pod network (DCN).
    ``repro.comm.hierarchy`` reduces over the two axes separately;
    ``repro.launch.costmodel.price_reduce`` prices each axis at its own
    bandwidth. ``flat()`` describes a single-pod (pure-ring) layout.
    """

    pods: int = 1
    nodes_per_pod: int = 1
    pod_axis: str = "pods"
    node_axis: str = "nodes"

    def __post_init__(self):
        if self.pods < 1 or self.nodes_per_pod < 1:
            raise ValueError(f"degenerate topology {self}")

    @classmethod
    def flat(cls, n_nodes: int) -> "NodeTopology":
        return cls(pods=1, nodes_per_pod=n_nodes)

    @property
    def n_nodes(self) -> int:
        return self.pods * self.nodes_per_pod

    def link_kind(self, axis_name: str) -> str:
        """"dcn" for the pod axis, else the generic axis registry."""
        if axis_name == self.pod_axis:
            return "dcn"
        if axis_name == self.node_axis:
            return "ici"
        return axis_link_kind(axis_name)

    def mesh(self) -> Mesh:
        """Build the mesh this topology describes (2-D unless single-pod)."""
        if self.pods == 1:
            return _auto_mesh((self.nodes_per_pod,), (self.node_axis,))
        return _auto_mesh((self.pods, self.nodes_per_pod),
                          (self.pod_axis, self.node_axis))


def make_node_mesh(topo: NodeTopology) -> Mesh:
    """Mesh for a data-parallel node set laid out per ``topo``."""
    return topo.mesh()
