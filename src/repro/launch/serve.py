"""Serving launcher: batched greedy decoding with the slot engine.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b \
        --preset smoke --requests 4 --new-tokens 8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_model, get_smoke_model
from repro.serve import Engine, Request, ServeConfig
from repro.utils import get_logger

log = get_logger("serve-cli")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    model = (get_smoke_model if args.preset == "smoke" else get_model)(
        args.arch)
    if model.decode_step is None:
        raise SystemExit(f"{args.arch} has no decode step")
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params,
                 ServeConfig(max_batch=max(args.requests, 2),
                             max_len=args.max_len))
    rng = np.random.default_rng(0)
    vocab = getattr(model.cfg, "vocab", 512)
    for uid in range(args.requests):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, vocab, size=4),
                           max_new_tokens=args.new_tokens))
    done = eng.run(max_ticks=args.new_tokens * 2 + 8)
    for uid, toks in sorted(done.items()):
        log.info("request %d -> %s", uid, toks)
    print(f"served {len(done)}/{args.requests} requests")


if __name__ == "__main__":
    main()
