"""Serving launcher: multi-worker supervisor over the slot engines.

A ``--serve`` spec in the same section-prefixed shape as the trainer's
``--program`` configures one worker per ``worker <arch>:`` section::

    PYTHONPATH=src python -m repro.launch.serve \
        --serve "worker gemma-2b: batch=4;kv=int8;page=16;chunk=8 \
                 worker mamba2-370m: batch=2" \
        --requests 16 --new-tokens 8 --run-dir /tmp/serve-run

Each section's clauses are ``key=value`` pairs mapped onto
:class:`~repro.serve.engine.ServeConfig` (``batch``, ``max_len``,
``chunk``, ``kv`` mode, ``page`` size, ``pool`` pages, ``queue`` bound,
``budget`` active-token bound). Synthetic traffic is spread round-robin
across workers; ``--run-dir`` exports the ``serve`` stream rows and any
monitor events through the standard run-log path. The legacy single-model
flags (``--arch``, ``--preset``) still work and build a one-worker spec.
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_model, get_smoke_model
from repro.obs.monitor import MonitorSuite, ServeMonitor
from repro.obs.runlog import run_obs
from repro.serve import ServeConfig, Supervisor
from repro.utils import get_logger

log = get_logger("serve-cli")

_KEYS = ("batch", "max_len", "chunk", "kv", "page", "pool", "queue",
         "budget")


def parse_serve_spec(spec: str) -> List[Tuple[str, Dict[str, str]]]:
    """Split a ``--serve`` spec into (arch, {key: value}) worker sections.

    Grammar mirrors ``--program``: a section starts at the token pair
    ``worker <arch>:``; its clauses are ``;``-separated ``key=value``
    pairs and extend to the next ``worker`` marker.
    """
    toks = spec.split()
    if not toks or toks[0] != "worker":
        raise ValueError(
            f"--serve spec must start with 'worker <arch>:', got {spec!r}")
    out: List[Tuple[str, List[str]]] = []
    i = 0
    while i < len(toks):
        if toks[i] != "worker":
            out[-1][1].append(toks[i])
            i += 1
            continue
        if i + 1 >= len(toks) or not toks[i + 1].endswith(":"):
            raise ValueError("'worker' must be followed by '<arch>:'")
        out.append((toks[i + 1][:-1], []))
        i += 2
    sections = []
    for arch, clause_toks in out:
        if arch not in ARCH_IDS:
            raise ValueError(f"unknown arch {arch!r}; one of {ARCH_IDS}")
        kv: Dict[str, str] = {}
        for clause in " ".join(clause_toks).split(";"):
            clause = clause.strip()
            if not clause:
                continue
            if "=" not in clause:
                raise ValueError(f"clause {clause!r} is not key=value")
            k, v = clause.split("=", 1)
            if k not in _KEYS:
                raise ValueError(f"unknown serve key {k!r}; one of {_KEYS}")
            kv[k] = v
        sections.append((arch, kv))
    return sections


def serve_config(kv: Dict[str, str]) -> ServeConfig:
    return ServeConfig(
        max_batch=int(kv.get("batch", 4)),
        max_len=int(kv.get("max_len", 128)),
        chunk=int(kv.get("chunk", 8)),
        kv_mode=kv.get("kv", "fp32"),
        kv_page=int(kv.get("page", 0)),
        kv_pool_pages=int(kv.get("pool", 0)),
        max_queue=int(kv.get("queue", 0)),
        max_active_tokens=int(kv.get("budget", 0)),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--serve", default=None,
                    help="worker spec: 'worker <arch>: k=v;k=v worker ...'")
    ap.add_argument("--arch", choices=ARCH_IDS, default=None,
                    help="legacy single-worker shorthand")
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-ticks", type=int, default=0,
                    help="0: auto from request sizes")
    ap.add_argument("--run-dir", default=None)
    args = ap.parse_args()

    if (args.serve is None) == (args.arch is None):
        raise SystemExit("exactly one of --serve / --arch is required")
    if args.serve:
        sections = parse_serve_spec(args.serve)
    else:
        sections = [(args.arch, {"max_len": str(args.max_len)})]

    obs = None
    if args.run_dir:
        obs = run_obs(args.run_dir,
                      context={"launcher": "serve",
                               "workers": [a for a, _ in sections]},
                      monitors=[ServeMonitor()])

    sup = Supervisor()
    sup.monitors = MonitorSuite([ServeMonitor()]) if obs is None \
        else obs.monitors
    get = get_smoke_model if args.preset == "smoke" else get_model
    for arch, kv in sections:
        model = get(arch)
        if model.decode_step is None:
            raise SystemExit(f"{arch} has no decode step")
        params, _ = model.init(jax.random.PRNGKey(0))
        sup.add_worker(arch, model, params, serve_config(kv))

    rng = np.random.default_rng(0)
    names = list(sup.workers)
    expected = []
    for i in range(args.requests):
        w = sup.workers[names[i % len(names)]]
        vocab = getattr(w.model.cfg, "vocab", 512)
        uid = sup.submit(rng.integers(0, vocab, size=4),
                         max_new_tokens=args.new_tokens, model=w.name)
        if uid is None:
            log.warning("request %d rejected (queue bound)", i)
        else:
            expected.append(uid)

    ticks = args.max_ticks or (
        args.requests * (args.new_tokens + 2) + 8)
    done = sup.run(max_ticks=ticks)
    for uid, toks in sorted(done.items()):
        log.info("request %d -> %s", uid, toks)
    for h in sup.health():
        log.info("%s: ticks=%d finished=%d preempt=%d rejected=%d",
                 h.name, h.ticks, h.finished, h.preemptions, h.rejected)
    if obs is not None:
        obs.finish()
    print(f"served {len(done)}/{len(expected)} requests "
          f"across {len(sup.workers)} worker(s)")


if __name__ == "__main__":
    main()
