"""Launchers: mesh construction, dry-run, training and serving CLIs.

NOTE: do not import repro.launch.dryrun from library code — importing it
sets XLA_FLAGS for 512 host devices (dry-run only).
"""
from repro.launch.mesh import host_device_mesh, make_mesh, make_production_mesh

__all__ = ["host_device_mesh", "make_mesh", "make_production_mesh"]
