"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-370m \
        --preset smoke --steps 50 --dither paper --s 2.0

Presets:
    smoke  — the arch's reduced config, tiny batch (CPU-runnable)
    full   — the assigned full config (needs a real cluster; on CPU this is
             only useful with --dry-run-first to validate the mesh)

On a multi-host cluster, call jax.distributed.initialize() via
--distributed (standard TPU pod env) before anything touches devices.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_IDS, get_model, get_smoke_model
from repro.core.policy import DitherPolicy
from repro.data import TokenStreamConfig, token_batch
from repro.launch.program import format_program, merge_legacy_flags
from repro.optim import OptConfig
from repro.train import Trainer, TrainerConfig
from repro.utils import get_logger

log = get_logger("train")


def batch_fn_for(model, batch: int, seq: int):
    cfg = model.cfg
    vocab = getattr(cfg, "vocab", 512)
    tcfg = TokenStreamConfig(vocab=vocab, seq_len=seq, batch=batch)

    def fn(step: int):
        b = token_batch(tcfg, step)
        if model.family == "audio":
            import jax.numpy as jnp
            import numpy as np
            rng = np.random.default_rng(step)
            b["frames"] = jnp.asarray(rng.normal(
                0, 1, (batch, cfg.n_frames, cfg.d_model)).astype(np.float32))
        if model.family == "vlm" and cfg.vlm_patches:
            import jax.numpy as jnp
            import numpy as np
            rng = np.random.default_rng(step)
            b["patch_embeds"] = jnp.asarray(rng.normal(
                0, 1, (batch, cfg.vlm_patches, cfg.vit_dim)).astype(np.float32))
        return b

    return fn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--dither", choices=["off", "paper", "int8", "row",
                                         "meprop"], default="paper")
    ap.add_argument("--s", type=float, default=2.0)
    ap.add_argument("--program", default="",
                    help="unified run program with 'dither:'/'memory:'/"
                    "'comm:'/'quant:' sections, e.g. \"dither: phase@0=off;"
                    "phase@30=paper;rule lm_head:off memory: default=nsd;"
                    "rule fc0:int8 comm: topology=butterfly;pods=4;"
                    "bucket_bytes=1048576 quant: grad=int4@g32;mu=m8;"
                    "nu=u8\" (see repro.launch.program). "
                    "The dither section builds on --dither/--s as the "
                    "base policy; the comm section attaches a gradient "
                    "CommPolicy to the trainer; the quant section picks "
                    "registered codecs per surface (grad/wire/resid/mu/nu, "
                    "see repro.quant.program).")
    ap.add_argument("--policy-program", default="",
                    help="DEPRECATED: use --program \"dither: ...\". "
                    "Per-layer/step policy program spec "
                    "(see repro.core.schedule).")
    ap.add_argument("--memory-program", default="",
                    help="DEPRECATED: use --program \"memory: ...\". "
                    "Per-layer residual-memory spec (see repro.memory).")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--run-dir", default="",
                    help="observability run directory: drains the metrics "
                    "bus (dither/comm/memory/phase/train/monitor streams) "
                    "into JSONL + a provenance manifest; render with "
                    "'python -m repro.obs.report <run-dir>'")
    ap.add_argument("--escalate-monitors", action="store_true",
                    help="with --run-dir: critical health events (NaN "
                    "loss, sparsity collapse) raise instead of warn")
    ap.add_argument("--distributed", action="store_true")
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    model = (get_smoke_model if args.preset == "smoke" else get_model)(
        args.arch)
    spec = merge_legacy_flags(args.program, args.policy_program,
                              args.memory_program)
    qo = spec.quant_overrides()
    policy = (None if args.dither == "off"
              else DitherPolicy(variant=args.dither, s=args.s))
    if qo is not None and qo.grad is not None:
        # applied to the BASE policy so dither-program phases/rules inherit
        # the cotangent codec (schedule.resolve_layer carries base.grad_codec)
        policy = ((policy or DitherPolicy(variant="off", s=args.s))
                  .replace(grad_codec=qo.grad))
    if spec.dither:
        # --dither off stays off as the base: only explicit program clauses
        # (phases / rule variants) re-enable dithering
        base = (policy if policy is not None
                else DitherPolicy(variant="off", s=args.s))
        policy = spec.dither_program(base)
    comm_policy = spec.comm_policy()
    memory_program = spec.memory
    if qo is not None:
        if qo.wire is not None:
            from repro.comm import CommPolicy

            comm_policy = (comm_policy.replace(default=qo.wire)
                           if comm_policy is not None
                           else CommPolicy(default=qo.wire))
        if qo.resid is not None:
            if memory_program:
                raise ValueError(
                    "quant: resid= conflicts with the 'memory:' section "
                    "(its default= clause); specify one")
            memory_program = f"default={qo.resid}"
    obs = None
    if args.run_dir:
        from repro.obs import run_obs

        obs = run_obs(
            args.run_dir,
            context={"tool": "train", "arch": args.arch,
                     "preset": args.preset, "steps": args.steps,
                     "dither": args.dither, "s": args.s,
                     "program": format_program(spec)},
            escalate=args.escalate_monitors)
    trainer = Trainer(
        model,
        OptConfig(name="adamw", lr=args.lr, schedule="cosine",
                  warmup_steps=max(args.steps // 20, 1),
                  total_steps=args.steps,
                  mu_codec=qo.mu if qo is not None else None,
                  nu_codec=qo.nu if qo is not None else None),
        TrainerConfig(total_steps=args.steps, grad_accum=args.grad_accum,
                      log_every=max(args.steps // 10, 1),
                      ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every),
        policy=policy,
        comm_policy=comm_policy,
        memory_policy=memory_program or None,
        obs=obs,
    )
    fn = batch_fn_for(model, args.batch, args.seq)
    counter = iter(range(10**9))

    def it():
        while True:
            yield fn(next(counter))

    out = trainer.fit(it())
    log.info("final loss: %.4f",
             out["history"][-1]["loss"] if out["history"] else float("nan"))
    if args.run_dir:
        log.info("run dir: %s (render: python -m repro.obs.report %s)",
                 args.run_dir, args.run_dir)


if __name__ == "__main__":
    main()
