"""Layer-anchored cost correction for scanned programs.

XLA's cost analysis counts a while-loop (lax.scan) body ONCE, so a scanned
L-layer train step under-reports FLOPs / bytes / collective traffic by ~L x.
Unrolling the real depth is exact but costs ~10 min of XLA time per cell on
this 1-core container (measured: qwen2.5-32b train_4k, 507 s).

Instead we lower tiny *unrolled* anchor programs at FULL width and solve for
the per-layer costs:

    uniform stacks:   F(L) = N + L*B          anchors L in {1, 2}
    gemma3 (5:1):     F    = N + nl*Bl + ng*Bg  anchors {1, 2, P, 2P}
    hymba (3 global): F    = (N + 3*Bg) + nl*Bl anchors {4, 5}

where N = non-loop cost (embeddings, head, optimizer), B = per-layer body.
The correction applies identically to flops, bytes-accessed, and per-op
collective wire bytes (the HLO text also prints the loop body once).

The full-depth scanned program is still lowered+compiled by the dry-run —
that is the deliverable that proves the distribution config works; anchors
only fix the *accounting*.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

from repro.launch import roofline as rl
from repro.models import api as model_api
from repro.models.hybrid import HybridConfig
from repro.models.transformer import LMConfig
from repro.utils import get_logger

log = get_logger("costmodel")


def price_wire_bytes(wire_bytes: float, *, link_bw: float = rl.ICI_BW,
                     n_links: int = 1) -> float:
    """Seconds the measured comm-subsystem wire bytes occupy the interconnect.

    ``wire_bytes`` is the *measured* total from ``repro.comm`` telemetry
    (bitmap + non-zero levels + per-chunk deltas), not an HLO estimate — the
    packed exchange never appears as a collective in HLO, so the parser in
    ``repro.launch.roofline`` cannot see it. This is the pricing hook that
    puts it on the same axis as the roofline's ``collective_s`` term.
    """
    return float(wire_bytes) / (link_bw * max(n_links, 1))


@dataclasses.dataclass(frozen=True)
class LinkPricing:
    """Per-link-class bandwidths for the two-level interconnect."""

    ici_bw: float = rl.ICI_BW  # fast intra-pod axis
    dcn_bw: float = rl.DCN_BW  # slow inter-pod axis


def price_reduce(tele, *, nodes: int, pods: int = 1,
                 pricing: LinkPricing = LinkPricing()) -> Dict[str, float]:
    """Model the ICI/DCN seconds of one measured compressed all-reduce.

    ``tele`` is a ``RingTelemetry`` or ``HierTelemetry``. The model prices
    MEASURED wire bytes; only the parallelism assumptions are modeled:

    Flat ring over N nodes spanning G pods (pod-contiguous layout): every
    round all N links carry one packed segment in parallel, so per-link
    bytes are wire/N; G of the N links cross pods, and when G > 1 each
    round is gated by a DCN link. ``dcn_s`` is then the critical path and
    ``ici_s`` the (overlapped) time the intra-pod links are busy.

    Hierarchical reduce: the intra-pod phases run all G*P ring links in
    parallel (per-link bytes = wire_ici / (G*P)); the tree phases run the
    P per-segment owner lines in parallel and serialize 2*ceil(log2 G)
    pack transfers per line out of the 2*(G-1) total, so the DCN critical
    path is wire_dcn/P scaled by that ratio. Phases are serialized:
    ``total_s = ici_s + dcn_s``.
    """
    ici_s = dcn_s = 0.0
    if hasattr(tele, "wire_ici_bytes"):  # hierarchical: measured split
        g, p = int(tele.pods), int(tele.per_pod)
        ici_s = float(tele.wire_ici_bytes) / max(g * p, 1) / pricing.ici_bw
        if g > 1:
            critical = 2 * (g - 1).bit_length()  # up + down rounds
            total_packs = 2 * (g - 1)
            dcn_s = (float(tele.wire_dcn_bytes) / max(p, 1)
                     * critical / total_packs / pricing.dcn_bw)
        total_s = ici_s + dcn_s  # phases serialize
    else:  # flat ring: per-link bytes, gated by DCN when spanning pods
        per_link = float(tele.wire_bytes) / max(nodes, 1)
        ici_s = per_link / pricing.ici_bw
        dcn_s = per_link / pricing.dcn_bw if pods > 1 else 0.0
        total_s = max(ici_s, dcn_s)  # same rounds, gated by slowest link
    return {"ici_s": ici_s, "dcn_s": dcn_s, "total_s": total_s}


def price_step_comm(wire_bytes: float, *, pods: int = 1,
                    pricing: LinkPricing = LinkPricing()) -> Dict[str, float]:
    """Bound the link seconds of one training step's gradient exchange.

    Used by the Trainer, which measures per-step wire bytes but has no
    node axis of its own: ``comm_ici_s`` assumes the exchange stays on the
    fast axis, ``comm_dcn_s`` the slow axis when the configured topology
    spans pods (0 otherwise). The two bracket the real deployment.
    """
    return {
        "comm_ici_s": float(wire_bytes) / pricing.ici_bw,
        "comm_dcn_s": (float(wire_bytes) / pricing.dcn_bw
                       if pods > 1 else 0.0),
    }


def price_overlap(bucket_bytes, bucket_comm_s, *, bwd_s: float,
                  ready_s=None) -> Dict[str, object]:
    """Price an overlap schedule: how much comm time backward hides.

    ``bucket_bytes`` are the per-bucket gradient bytes in LAUNCH order
    (bucket 0 = last layers, ready first — see
    ``repro.comm.overlap.BucketPlan``) and ``bucket_comm_s`` the seconds
    each bucket's reduce occupies the link (modeled via
    :func:`price_reduce` / :func:`price_wire_bytes`, or measured host
    timings — same recurrence either way, which is what makes
    modeled-vs-measured overlap efficiency a meaningful gate).

    Ready times default to the backward-progress proxy: bucket i's
    gradients exist once its share of backward compute is done, taken
    proportional to cumulative gradient bytes —
    ``ready_i = bwd_s * cum_bytes_i / total_bytes``. Pass ``ready_s`` to
    override (e.g. measured grad-availability stamps).

    The link is serial, so launches queue::

        start_i = max(ready_i, end_{i-1});   end_i = start_i + comm_i

    ``exposed_s`` is the comm tail sticking out past backward
    (``max(0, end_last - bwd_s)``), ``hidden_s`` the rest, and
    ``overlap_efficiency = hidden_s / total_comm_s`` (1.0 = fully
    hidden; a blocking reduce scores 0.0). ``step_s`` vs ``serial_s``
    is the wall-clock the schedule buys.
    """
    bb = [float(b) for b in bucket_bytes]
    cc = [float(c) for c in bucket_comm_s]
    if len(bb) != len(cc):
        raise ValueError(f"bucket_bytes ({len(bb)}) and bucket_comm_s "
                         f"({len(cc)}) must align")
    total_bytes = sum(bb)
    if ready_s is None:
        cum = 0.0
        ready = []
        for b in bb:
            cum += b
            ready.append(bwd_s * (cum / total_bytes if total_bytes else 1.0))
    else:
        ready = [float(r) for r in ready_s]
    launch, drain = [], []
    end = 0.0
    for r, c in zip(ready, cc):
        start = max(r, end)
        end = start + c
        launch.append(start)
        drain.append(end)
    total_comm = sum(cc)
    exposed = max(0.0, (drain[-1] if drain else 0.0) - float(bwd_s))
    hidden = total_comm - exposed
    return {
        "launch_s": launch,
        "drain_s": drain,
        "total_comm_s": total_comm,
        "exposed_s": exposed,
        "hidden_s": hidden,
        "overlap_efficiency": (hidden / total_comm) if total_comm > 0
        else 1.0,
        "step_s": max(float(bwd_s), drain[-1] if drain else 0.0),
        "serial_s": float(bwd_s) + total_comm,
    }


def compression_speedup(wire_bytes: float, dense_bytes: float) -> float:
    """How much interconnect time the packed exchange saves vs dense f32."""
    if wire_bytes <= 0:
        return float("inf")
    return float(dense_bytes) / float(wire_bytes)


def price_memory(stored_bytes: float, dense_bytes: float, *,
                 n_chips: int = 1, batch: int = 1,
                 fixed_bytes_per_chip: float = 0.0,
                 hbm_bytes: float = rl.HBM_CAP) -> Dict[str, float]:
    """Price a step's residual store against per-chip HBM capacity.

    ``stored_bytes``/``dense_bytes`` are the MEASURED (or eval_shape
    -accounted, see ``repro.memory.accounting``) global residual totals of
    one training step at ``batch``; ``fixed_bytes_per_chip`` is the
    batch-independent footprint (params + optimizer state + compiler
    temps), typically ``memory_analysis().argument_size_in_bytes``. The
    max-batch estimate assumes residuals scale linearly with batch (they
    are activations) and everything else stays fixed:

        est_max_batch = batch * (hbm - fixed) / residual_per_chip

    reported for both the compressed store and the dense-fp32 store —
    their ratio is the batch headroom the codec buys. A modeled estimate
    (residuals are the dominant, but not the only, batch-proportional
    term), not a measured ceiling.
    """
    out = {
        "residual_stored_per_chip": float(stored_bytes) / max(n_chips, 1),
        "residual_dense_per_chip": float(dense_bytes) / max(n_chips, 1),
        "residual_compression": (float(dense_bytes) / float(stored_bytes)
                                 if stored_bytes > 0 else float("inf")),
    }
    headroom = max(hbm_bytes - float(fixed_bytes_per_chip), 0.0)
    for kind in ("stored", "dense"):
        per_chip = out[f"residual_{kind}_per_chip"]
        out[f"est_max_batch_{kind}"] = (
            float(batch) * headroom / per_chip if per_chip > 0
            else float("inf"))
    return out


def rebuild(model: model_api.Model, **overrides) -> model_api.Model:
    cfg = dataclasses.replace(model.cfg, **overrides)
    if model.family in ("dense", "moe", "vlm"):
        return model_api.lm_model(cfg, family=model.family)
    if model.family == "ssm":
        return model_api.ssm_model(cfg)
    if model.family == "hybrid":
        return model_api.hybrid_model(cfg)
    if model.family == "audio":
        return model_api.encdec_model(cfg)
    raise ValueError(model.family)


def _measure(lower_fn: Callable[[model_api.Model], object],
             model: model_api.Model, n_layers: int) -> Dict[str, float]:
    anchor = rebuild(model, n_layers=n_layers, scan_unroll=True)
    lowered = lower_fn(anchor)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    recs = rl.parse_collectives(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire": float(sum(r.wire_bytes for r in recs)),
        "naive": float(sum(r.operand_bytes for r in recs)),
    }


def _lincomb(a: Dict[str, float], b: Dict[str, float], ca: float, cb: float
             ) -> Dict[str, float]:
    return {k: ca * a[k] + cb * b[k] for k in a}


def corrected_costs(model: model_api.Model,
                    lower_fn: Callable[[model_api.Model], object]
                    ) -> Tuple[Dict[str, float], Dict[str, object]]:
    """Returns (corrected totals per chip, debug info)."""
    cfg = model.cfg
    L = cfg.n_layers

    if isinstance(cfg, LMConfig) and cfg.window is not None \
            and cfg.window_pattern > 0:
        P = cfg.window_pattern + 1
        f1 = _measure(lower_fn, model, 1)  # N + Bl
        f2 = _measure(lower_fn, model, 2)  # N + 2 Bl
        fp = _measure(lower_fn, model, P)  # N + (P-1) Bl + Bg
        body_l = _lincomb(f2, f1, 1.0, -1.0)
        nonloop = _lincomb(f1, body_l, 1.0, -1.0)
        body_g = {k: fp[k] - nonloop[k] - (P - 1) * body_l[k] for k in f1}
        n_glob = sum(1 for i in range(L) if not cfg.layer_is_local(i))
        n_loc = L - n_glob
        total = {k: nonloop[k] + n_loc * body_l[k] + n_glob * body_g[k]
                 for k in f1}
        dbg = {"anchors": (1, 2, P), "n_local": n_loc, "n_global": n_glob}
        return total, dbg

    if isinstance(cfg, HybridConfig):
        # global layers are always 3 (first/middle/last) for n_layers >= 4
        f4 = _measure(lower_fn, model, 4)  # N + 3 Bg + 1 Bl
        f5 = _measure(lower_fn, model, 5)  # N + 3 Bg + 2 Bl
        body_l = _lincomb(f5, f4, 1.0, -1.0)
        total = {k: f4[k] + (L - 3 - 1) * body_l[k] for k in f4}
        dbg = {"anchors": (4, 5), "n_local": L - 3, "n_global": 3}
        return total, dbg

    f1 = _measure(lower_fn, model, 1)
    f2 = _measure(lower_fn, model, 2)
    body = _lincomb(f2, f1, 1.0, -1.0)
    total = {k: f1[k] + (L - 1) * body[k] for k in f1}
    return total, {"anchors": (1, 2)}
