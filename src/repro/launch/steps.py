"""Train / serve step factories shared by the trainer, server and dry-run."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.policy import DitherCtx, DitherPolicy
from repro.core.schedule import PolicyProgram, as_program
from repro.models.api import Model
from repro.optim import OptConfig, apply_updates, init_opt_state


def make_train_step(model: Model, opt_cfg: OptConfig,
                    policy: Optional[DitherPolicy | PolicyProgram] = None,
                    *, phase_step: int = 0, memory=None):
    """(params, opt_state, batch, base_key) -> (params, opt_state, metrics).

    The dither key is folded from (base_key, step) so noise is fresh each
    step; under pjit the per-layer fold-ins give i.i.d. noise across the
    whole pre-activation tensor regardless of sharding.

    ``policy`` may be a PolicyProgram: per-layer rules and knob schedules
    resolve on the traced step inside this one compiled function. The
    *variant* phase is static per trace — this factory bakes the phase
    active at ``phase_step`` (the Trainer drives phases across a run;
    dry-runs lower the phase they ask for). ``memory`` is a
    ``repro.memory`` MemoryPolicy (or spec string) selecting each dithered
    layer's residual codec / remat — static per layer, baked here.
    """
    from repro.memory.policy import as_memory_policy

    program = as_program(policy)
    phase_policy = (program.phase_policy_at(phase_step)
                    if program is not None else None)
    memory = as_memory_policy(memory)

    def train_step(params, opt_state, batch, base_key):
        step = opt_state["step"]
        ctx = None
        if phase_policy is not None and program.step_enabled(phase_policy):
            ctx = DitherCtx.for_step(base_key, step, phase_policy,
                                     program=program, memory=memory)

        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, ctx=ctx))(params)
        params, opt_state, metrics = apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        return model.loss(params, batch)

    return eval_step


def make_prefill_step(model: Model):
    """Forward over the full prompt (the prefill_32k shape cells).

    For LM families this is the logits pass (cache construction is the
    serving engine's job); cost-wise it is the attention+MLP forward at
    full sequence length, which is what the roofline measures.
    """

    def prefill_step(params, batch):
        out = model.forward(params, batch)
        return out[0] if isinstance(out, tuple) else out

    return prefill_step


def make_decode_step(model: Model):
    """One new token against a seq_len-deep KV cache (decode shape cells)."""

    def serve_step(params, cache, token, t):
        logits, new_cache = model.decode_step(params, cache, token, t)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], new_cache

    return serve_step


def init_train_state(model: Model, opt_cfg: OptConfig, key: jax.Array):
    params, specs = model.init(key)
    opt_state = init_opt_state(params, opt_cfg)
    return params, opt_state, specs
