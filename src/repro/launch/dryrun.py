import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init), which is why they precede the module docs.

_DOC = """Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape) cell this lowers + compiles the real
train/prefill/serve step for the production mesh — single-pod (16, 16) and
multi-pod (2, 16, 16) — using ShapeDtypeStruct stand-ins (no allocation),
prints memory_analysis() / cost_analysis(), and extracts the roofline terms
(repro.launch.roofline). Failures (sharding mismatch, unsupported
collective) are bugs in the framework, not in the harness.

Usage:
    python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
    python -m repro.launch.dryrun --all --multi-pod --out results.json
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, applicable, get_model
from repro.core.policy import DitherPolicy
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step, \
    make_train_step
from repro.optim import OptConfig, init_opt_state, opt_state_specs
from repro.parallel import axes as axlib
from repro.utils import get_logger

log = get_logger("dryrun")


def _sds_with_sharding(tree, spec_tree, rules: axlib.Rules):
    shardings = axlib.spec_tree_to_shardings(spec_tree, rules, tree)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree, shardings)


def _batch_sds(batch_specs: Dict[str, Any], rules: axlib.Rules):
    def attach(name, s):
        if s.ndim == 1:
            ax = ("batch",)
        elif s.ndim == 2:
            ax = ("batch", "seq")
        elif s.ndim == 3:
            ax = ("batch", "seq", None)
        else:
            ax = ("batch",) + (None,) * (s.ndim - 1)
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=rules.sharding(ax, s.shape))

    return {k: attach(k, v) for k, v in batch_specs.items()}


def _cache_axes_for_path(path: str, ndim: int):
    if "conv" in path:  # conv window (B, K-1, conv_dim)
        return ("batch", None, "act_ssm_inner")
    if "state" in path:  # SSM state (B, H, N, P)
        return ("batch", "act_heads", None, None)
    # KV buffers (B, S_buf, KV, hd)
    return ("batch", "cache_seq", "cache_heads", None)


def _cache_sds(cache_specs, rules: axlib.Rules):
    from repro.utils.pytree import tree_map_with_path_str

    def attach(path, s):
        ax = _cache_axes_for_path(path, s.ndim)
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=rules.sharding(ax, s.shape))

    return tree_map_with_path_str(attach, cache_specs)


# dense-LM families where the fsdp_seq layout measured best (§Perf qwen/It4:
# sequence-parallel activations over "model" + per-use weight gathering
# beats Megatron TP at 1M-token steps: frac 0.1019 -> 0.1184)
FSDP_SEQ_ARCHS = ("qwen2.5-32b", "gemma-2b", "gemma3-4b", "minitron-8b",
                  "internvl2-2b")


def make_rules(mesh, shape_case, arch_id: str) -> axlib.Rules:
    """Sharding ruleset per cell kind (the hillclimb edits live here)."""
    kind = shape_case.kind
    fsdp = kind == "decode" and shape_case.global_batch < 8 * mesh.shape.get(
        "data", 1)
    rules = axlib.tp_dp_rules(mesh, fsdp=fsdp)
    if kind == "decode":
        # KV cache sharded along SEQ over "model" (flash-decoding style
        # partial attention): GQA archs with kv_heads < tp-width otherwise
        # replicate the whole cache per chip column. Measured on qwen
        # decode_32k: cache 68.7 -> 4.3 GB/chip, mem_s -32%, useful +48%
        # (§Perf decode/It1).
        pass  # applied below via the cache_* mapping defaults
    if kind in ("train", "prefill") and arch_id in FSDP_SEQ_ARCHS:
        rules.mapping["seq"] = "model"
        rules.mapping["attn_seq"] = "model"
        for k in ("act_embed", "act_heads", "act_mlp", "act_vocab",
                  "act_ssm_inner", "act_expert"):
            rules.mapping[k] = None
    rules.mapping["cache_batch"] = rules.mapping["batch"]
    rules.mapping["cache_heads"] = None
    rules.mapping["cache_seq"] = "model"
    if shape_case.name == "long_500k":
        # batch=1: the data axis is idle for activations; shard the cache
        # sequence dim instead (sequence parallelism for the KV/state path)
        rules.mapping["cache_seq"] = "data"
        rules.mapping["batch"] = None
        rules.mapping["cache_batch"] = None
    if kind == "decode" and shape_case.global_batch < _axsize(mesh, ("pod", "data")):
        rules.mapping["batch"] = tuple(
            a for a in ("pod",) if a in mesh.shape) or None
    return rules


def _axsize(mesh, names) -> int:
    n = 1
    for a in names:
        if a in mesh.shape:
            n *= mesh.shape[a]
    return n


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    status: str  # OK | SKIPPED | FAILED
    reason: str = ""
    compile_s: float = 0.0
    report: Optional[Dict[str, Any]] = None


def _lower_for_case(model, case, rules, policy, opt_name, memory=None):
    """Lower the real step for one cell (used for the full model AND for the
    layer-anchor cost models). Must run inside use_rules(rules)."""
    key = jax.ShapeDtypeStruct(
        (2,), jnp.uint32, sharding=rules.sharding((None,), (2,)))
    # eval_shape can't return the (string-typed) spec tree; capture it as a
    # trace side-effect — specs are plain Python tuples.
    spec_box = {}

    def _init_params_only(k):
        p, s = model.init(k)
        spec_box["specs"] = s
        return p

    params_shape = jax.eval_shape(_init_params_only, jax.random.PRNGKey(0))
    specs = spec_box["specs"]
    params_sds = _sds_with_sharding(params_shape, specs, rules)

    if case.kind == "train":
        opt_cfg = OptConfig(name=opt_name)
        opt_shape = jax.eval_shape(
            lambda p: init_opt_state(p, opt_cfg), params_shape)
        opt_sds = _sds_with_sharding(
            opt_shape, opt_state_specs(specs, opt_cfg), rules)
        batch_sds = _batch_sds(
            model.train_batch_specs(case.global_batch, case.seq_len), rules)
        step = make_train_step(model, opt_cfg, policy, memory=memory)
        return jax.jit(step).lower(params_sds, opt_sds, batch_sds, key)
    if case.kind == "prefill":
        batch_sds = _batch_sds(
            model.train_batch_specs(case.global_batch, case.seq_len), rules)
        step = make_prefill_step(model)
        return jax.jit(step).lower(params_sds, batch_sds)
    # decode
    cache_sds = _cache_sds(
        model.cache_specs(case.global_batch, case.seq_len), rules)
    tok = jax.ShapeDtypeStruct(
        (case.global_batch, 1), jnp.int32,
        sharding=rules.sharding(("batch", None), (case.global_batch, 1)))
    t_sds = jax.ShapeDtypeStruct((), jnp.int32)
    step = make_decode_step(model)
    return jax.jit(step).lower(params_sds, cache_sds, tok, t_sds)


def _residual_memory_stats(model, case, policy, memory, n_chips: int,
                           mem_stats: Dict[str, Any]) -> Dict[str, float]:
    """Residual-footprint accounting for one train cell: eval_shape the
    loss with a recorder ctx (no FLOPs), price the stored/dense totals
    against per-chip HBM, and estimate the max batch the cell supports
    under each store (repro.memory.accounting + costmodel.price_memory)."""
    from repro.launch import costmodel
    from repro.memory.accounting import footprint_totals, residual_report

    params_sds = jax.eval_shape(lambda k: model.init(k)[0],
                                jax.random.PRNGKey(0))
    batch_sds = model.train_batch_specs(case.global_batch, case.seq_len)
    report = residual_report(
        lambda p, b, c: model.loss(p, b, ctx=c), params_sds, batch_sds,
        policy=policy, memory=memory)
    stored, dense = footprint_totals(report)
    if dense <= 0:  # policy covers no layers -> autodiff owns residuals
        return {}
    priced = costmodel.price_memory(
        stored, dense, n_chips=n_chips, batch=case.global_batch,
        fixed_bytes_per_chip=float(mem_stats.get("argument_bytes", 0)))
    out = {"residual_layers": float(len(report)),
           "residual_stored_bytes": float(stored),
           "residual_dense_bytes": float(dense)}
    # keep the artifacts strict-JSON-safe: drop inf/nan estimates
    out.update({k: v for k, v in priced.items() if np.isfinite(v)})
    return out


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
             policy: Optional[DitherPolicy] = None,
             rules_override=None, opt_name: str = "adamw",
             correct_costs: bool = True, model_override=None,
             memory=None, verbose: bool = True) -> CellResult:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    case = SHAPES[shape_name]
    model = model_override if model_override is not None else get_model(arch_id)
    skip = applicable(arch_id, shape_name, model.has_decode)
    if skip:
        return CellResult(arch_id, shape_name, mesh_name, "SKIPPED", skip)

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    rules = (rules_override or make_rules)(mesh, case, arch_id)

    t0 = time.time()
    try:
        with axlib.use_rules(rules):
            lowered = _lower_for_case(model, case, rules, policy, opt_name,
                                      memory=memory)
            compiled = lowered.compile()
        compile_s = time.time() - t0
        # cost_analysis() returns a bare dict on newer jax, a one-element
        # list of dicts on the 0.4.x line CI pins
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        cost = dict(ca)
        mem = compiled.memory_analysis()
        mem_stats = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
        }
        hlo = compiled.as_text()
        cost_dbg = {}
        if correct_costs and case.kind in ("train", "prefill") \
                and getattr(model.cfg, "n_layers", 1) > 2:
            # scan bodies are counted ONCE by XLA cost analysis: re-derive
            # totals from unrolled 1-2 layer anchors (launch/costmodel.py)
            from repro.launch import costmodel

            def anchor_lower(m):
                with axlib.use_rules(rules):
                    return _lower_for_case(m, case, rules, policy, opt_name,
                                           memory=memory)

            totals, cost_dbg = costmodel.corrected_costs(model, anchor_lower)
            cost["flops"] = totals["flops"]
            cost["bytes accessed"] = totals["bytes"]
            cost_dbg["corrected_wire"] = totals["wire"]
            cost_dbg["corrected_naive"] = totals["naive"]
        report = rl.analyze(
            arch=arch_id, shape=shape_name, mesh_name=mesh_name,
            n_chips=n_chips, cost=cost, hlo_text=hlo,
            model_flops_global=rl.model_flops(
                case.kind, model.active_param_count, case.seq_len,
                case.global_batch),
            memory_stats=mem_stats)
        if cost_dbg:
            report.wire_bytes_per_chip = cost_dbg["corrected_wire"]
            report.naive_collective_bytes = cost_dbg["corrected_naive"]
            report.collective_s = report.wire_bytes_per_chip / rl.ICI_BW
            terms = {"compute": report.compute_s, "memory": report.memory_s,
                     "collective": report.collective_s}
            report.dominant = max(terms, key=terms.get)
            bound = max(terms.values())
            report.roofline_fraction = (
                report.model_flops_global / (n_chips * rl.PEAK_BF16_FLOPS)
            ) / max(bound, 1e-30)
            report.useful_ratio = report.model_flops_global / max(
                report.flops_per_chip * n_chips, 1.0)
            report.memory_stats["cost_anchors"] = str(cost_dbg.get("anchors"))
        if case.kind == "train" and policy is not None:
            try:
                report.memory_stats.update(_residual_memory_stats(
                    model, case, policy, memory, n_chips, mem_stats))
            except Exception as e:  # noqa: BLE001 — accounting is advisory
                report.memory_stats["residual_error"] = (
                    f"{type(e).__name__}: {e}")
        if verbose:
            log.info(
                "%s x %s [%s] OK compile=%.1fs flops/chip=%.3e bytes/chip=%.3e "
                "wire/chip=%.3e dominant=%s frac=%.3f",
                arch_id, shape_name, mesh_name, compile_s,
                report.flops_per_chip, report.bytes_per_chip,
                report.wire_bytes_per_chip, report.dominant,
                report.roofline_fraction)
            log.info("memory_analysis: %s", mem_stats)
        return CellResult(arch_id, shape_name, mesh_name, "OK",
                          compile_s=compile_s, report=report.row())
    except Exception as e:  # noqa: BLE001 — report, don't crash the grid
        if verbose:
            traceback.print_exc()
        return CellResult(arch_id, shape_name, mesh_name, "FAILED",
                          reason=f"{type(e).__name__}: {e}",
                          compile_s=time.time() - t0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--dither", choices=["off", "paper", "int8", "row"],
                    default="paper")
    ap.add_argument("--program", default="",
                    help="unified run program with 'dither:'/'memory:'/"
                    "'comm:' sections (see repro.launch.program); the "
                    "dither section drives the lowered step, the memory "
                    "section the residual accounting, and the comm "
                    "section is validated + recorded in the run context")
    ap.add_argument("--policy-program", default="",
                    help="DEPRECATED: use --program \"dither: ...\" (see "
                    "repro.core.schedule.parse_program)")
    ap.add_argument("--memory-program", default="",
                    help="DEPRECATED: use --program \"memory: ...\" (see "
                    "repro.memory)")
    ap.add_argument("--out", default="")
    ap.add_argument("--run-dir", default="",
                    help="observability run directory: each cell's "
                    "lower+compile wall-clock lands in the phase stream, "
                    "renderable offline via "
                    "'python -m repro.obs.report <run-dir>'")
    args = ap.parse_args()

    from repro.launch.program import format_program, merge_legacy_flags

    spec = merge_legacy_flags(args.program, args.policy_program,
                              args.memory_program)
    policy = None if args.dither == "off" else DitherPolicy(variant=args.dither)
    if spec.dither:
        policy = spec.dither_program(
            policy if policy is not None else DitherPolicy(variant="off"))
    memory = spec.memory_policy()
    spec.comm_policy()  # validate the comm section even though the grid
    # itself prices wire bytes from the lowered HLO, not the CommPolicy
    cells = []
    if args.all:
        targets = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        targets = [(args.arch, args.shape)]
    runlog = None
    if args.run_dir:
        from repro.obs.runlog import RunLog

        runlog = RunLog(args.run_dir, context={
            "tool": "dryrun", "dither": args.dither,
            "program": format_program(spec)})
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    from repro.obs.trace import get_tracer, span

    for i, (arch, shape) in enumerate(targets):
        get_tracer().set_step(i)
        for mp in meshes:
            # the roofline table is single-pod only; multi-pod cells just
            # prove the "pod" axis lowers, so skip the anchor compiles there
            with span("cell"), span(f"{arch}:{shape}"):
                res = run_cell(arch, shape, multi_pod=mp, policy=policy,
                               memory=memory, correct_costs=not mp)
            cells.append(dataclasses.asdict(res))
            print(f"{res.arch:22s} {res.shape:12s} {res.mesh:8s} "
                  f"{res.status:8s} {res.reason[:80]}")
    n_ok = sum(c["status"] == "OK" for c in cells)
    n_fail = sum(c["status"] == "FAILED" for c in cells)
    n_skip = sum(c["status"] == "SKIPPED" for c in cells)
    print(f"\ntotal={len(cells)} ok={n_ok} skipped={n_skip} failed={n_fail}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(cells, f, indent=1)
        print(f"wrote {args.out}")
    if runlog is not None:
        runlog.close()
        print(f"run dir: {args.run_dir} "
              f"(render: python -m repro.obs.report {args.run_dir})")


if __name__ == "__main__":
    main()
