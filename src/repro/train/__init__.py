from repro.train.checkpoint import CheckpointManager, list_steps
from repro.train.fault_tolerance import (
    ElasticSSGD, PreemptionGuard, RestartPlan, StragglerConfig,
    StragglerDetector, StaticHealthSource, make_restart_plan,
    plan_elastic_mesh, snap_pods,
)
from repro.train.trainer import Trainer, TrainerConfig

__all__ = ["CheckpointManager", "list_steps", "ElasticSSGD",
           "snap_pods", "PreemptionGuard",
           "RestartPlan", "StragglerConfig", "StragglerDetector",
           "StaticHealthSource", "make_restart_plan", "plan_elastic_mesh",
           "Trainer", "TrainerConfig"]
