"""Training runtime: loop + grad accumulation + checkpoints + fault hooks.

Single-host (tests/examples) and pjit multi-device paths share this loop;
distribution enters only through the sharding rules installed around jit.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.comm.compression import CommPolicy, init_comm_state
from repro.comm.reducer import reducer as comm_reducer
from repro.core.policy import DitherCtx, DitherPolicy
from repro.core.schedule import ControllerDriver, PolicyProgram, as_program
from repro.models.api import Model
from repro.obs.trace import annotate
from repro.optim import OptConfig, apply_updates, init_opt_state
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import PreemptionGuard
from repro.utils import get_logger

log = get_logger("trainer")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    grad_accum: int = 1
    log_every: int = 10
    ckpt_every: int = 0  # 0 = off
    ckpt_dir: str = ""
    keep_ckpts: int = 3
    seed: int = 0


class Trainer:
    def __init__(self, model: Model, opt_cfg: OptConfig, tcfg: TrainerConfig,
                 policy: Optional[DitherPolicy | PolicyProgram] = None,
                 eval_fn: Optional[Callable] = None,
                 comm_policy: Optional[CommPolicy] = None,
                 topology=None, memory_policy=None, obs=None):
        from repro.memory.policy import as_memory_policy

        self.model = model
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        # a plain DitherPolicy is lifted into the degenerate PolicyProgram;
        # every step resolves per layer through the program path.
        self.policy = policy
        self.program = as_program(policy)
        # repro.memory MemoryPolicy (or spec string): residual codec /
        # remat per dithered layer. Static — baked into the jitted step's
        # closure; set it before fit(), not mid-run.
        self.memory_policy = as_memory_policy(memory_policy)
        self.eval_fn = eval_fn
        # gradient wire path: accumulated grads go through one
        # repro.comm.reducer built here (flat single-participant wire
        # model; bucket_bytes > 0 adds overlap scheduling transparently).
        # _comm_state holds the error-feedback residuals; it rides in the
        # checkpoint tree so a preempted topk_ef run resumes losslessly.
        self.comm_policy = comm_policy
        self._reducer = (comm_reducer(comm_policy, n_nodes=1, stacked=False)
                         if comm_policy is not None else None)
        # launch.mesh.NodeTopology of the deployment this run models: each
        # logged history row prices the step's measured wire bytes on the
        # fast (ICI) and, when the topology spans pods, slow (DCN) axis.
        self.topology = topology
        self._comm_state: Optional[Dict[str, Any]] = None
        # closed-loop sparsity controller: shared host-side protocol
        # (discover -> traced state -> per-step tick); the state rides the
        # checkpoint tree next to the EF residuals, the telemetry cursor is
        # host-only (re-measured from scratch on resume)
        self._ctrl = ControllerDriver(self.program)
        # repro.obs.RunObs: when set, the loop records step-phase spans
        # (data/dispatch/controller/checkpoint), per-step train metrics,
        # and monitor ticks, and drains everything into the run directory.
        # None keeps the loop observability-free (no per-step host sync).
        self.obs = obs
        self.guard = PreemptionGuard(install=False)
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)
                     if tcfg.ckpt_every and tcfg.ckpt_dir else None)
        # phase_policy is static: a PolicyProgram phase boundary retraces
        # exactly once; knob schedules / controller nudges are traced and
        # re-use the compiled step (tests/test_schedule.py pins this).
        self._jit_step = jax.jit(self._step, static_argnames=("phase_policy",))
        self.history: list = []

    # one optimizer step with optional micro-batch gradient accumulation
    def _step(self, params, opt_state, batches, base_key, comm_state,
              ctrl_state, phase_policy):
        step = opt_state["step"]
        ctx = None
        if phase_policy is not None and self.program.step_enabled(phase_policy):
            ctx = DitherCtx.for_step(base_key, step, phase_policy,
                                     program=self.program,
                                     ctrl=ctrl_state or None,
                                     memory=self.memory_policy)

        def one_loss(p, b, i):
            c = None
            if ctx is not None:
                # micro-batches get distinct noise: fold the slice index in
                c = ctx.with_key(jax.random.fold_in(ctx.key, i))
            return self.model.loss(p, b, ctx=c)

        n = self.tcfg.grad_accum
        if n == 1:
            with annotate("step/grad"):
                loss, grads = jax.value_and_grad(one_loss)(params, batches, 0)
        else:
            # accept flat batches: split the leading (batch) dim into
            # (n, batch/n, ...) microbatches
            def to_micro(x):
                if x.shape[0] == n:
                    return x
                assert x.shape[0] % n == 0, (x.shape, n)
                return x.reshape((n, x.shape[0] // n) + x.shape[1:])

            batches = jax.tree.map(to_micro, batches)

            def acc_fn(carry, ib):
                i, b = ib
                lv, g = jax.value_and_grad(one_loss)(params, b, i)
                loss_acc, g_acc = carry
                return (loss_acc + lv / n,
                        jax.tree.map(lambda a, x: a + x / n, g_acc, g)), None

            zero = (jnp.zeros(()),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            with annotate("step/grad"):
                (loss, grads), _ = jax.lax.scan(
                    acc_fn, zero, (jnp.arange(n), batches))
        if self._reducer is not None:
            # the reducer folds the step in; the 0xC033 salt keeps the
            # comm keys in the same stream they were pre-redesign, so
            # resumed runs and pinned tests stay bit-exact
            comm_key = jax.random.fold_in(base_key, 0xC033)
            with annotate("step/comm"):
                grads, tele, comm_state = self._reducer.reduce(
                    grads, comm_key, step, comm_state)
            metrics_comm = {"comm_wire_bytes": tele.wire_bytes,
                            "comm_dense_bytes": tele.dense_bytes}
        else:
            metrics_comm = {}
        with annotate("step/update"):
            params, opt_state, metrics = apply_updates(
                params, grads, opt_state, self.opt_cfg)
        metrics["loss"] = loss
        metrics.update(metrics_comm)
        return params, opt_state, metrics, comm_state

    def _init_comm_state(self, params) -> Dict[str, Any]:
        return (init_comm_state(params, self.comm_policy)
                if self.comm_policy is not None else {})

    def _ckpt_tree(self, params, opt_state) -> Dict[str, Any]:
        tree = {"params": params, "opt": opt_state}
        if self._comm_state:
            tree["comm"] = self._comm_state
        if self._ctrl.state:
            tree["ctrl"] = self._ctrl.state
        return tree

    def _init_ctrl_state(self, params, batch) -> None:
        """One-time controller setup (idempotent via the driver's flag).

        Layer names are discovered by an eval_shape trace of the loss (no
        FLOPs) so the {layer: log-scale} dict is complete before step 0 —
        growing it mid-run would change the jitted step's input structure
        and force a retrace."""
        if not self._ctrl.active or self._ctrl.ready:
            return
        names = self._ctrl.ensure_init(
            lambda p, b, ctx: self.model.loss(p, b, ctx=ctx), params, batch)
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            # the main restore ran before the batch (and thus the layer
            # names) existed; pick the controller subtree up now
            try:
                self._ctrl.state = self.ckpt.restore(
                    {"ctrl": self._ctrl.state})["ctrl"]
                log.info("restored controller state")
            except KeyError:
                pass  # checkpoint predates the controller: scales restart at 1
        log.info("sparsity controller: %d layers under control", len(names))

    def restore_or_init(self, key: jax.Array):
        params, specs = self.model.init(key)
        opt_state = init_opt_state(params, self.opt_cfg)
        self._comm_state = self._init_comm_state(params)
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            try:
                state = self.ckpt.restore(self._ckpt_tree(params, opt_state))
            except KeyError:
                # checkpoint predates the comm subtree: residuals restart at 0
                state = self.ckpt.restore({"params": params,
                                           "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            self._comm_state = state.get("comm", self._comm_state)
            # controller state is restored later, in _init_ctrl_state: its
            # template needs the layer names, which need the first batch
            log.info("restored checkpoint at step %d",
                     int(opt_state["step"]))
        return params, opt_state, specs

    def fit(self, batch_iter: Iterator, params=None, opt_state=None
            ) -> Dict[str, Any]:
        key = jax.random.PRNGKey(self.tcfg.seed)
        base_key = jax.random.fold_in(key, 0xD17E)
        if params is None:
            params, opt_state, _ = self.restore_or_init(key)
        start = int(opt_state["step"])
        if self._comm_state is None:  # caller passed params directly
            self._comm_state = self._init_comm_state(params)
        comm_state = self._comm_state
        # span factory: with obs attached every phase is timed into the
        # "phase" stream; without it the loop stays observability-free
        if self.obs is not None:
            sp = self.obs.span
        else:
            def sp(name):
                return contextlib.nullcontext()
        t0 = time.time()
        for step in range(start, self.tcfg.total_steps):
            if self.obs is not None:
                self.obs.set_step(step)
            if self.guard.should_stop:
                log.info("preemption: checkpointing at step %d and exiting",
                         step)
                if self.ckpt is not None:
                    with sp("checkpoint"):
                        self.ckpt.save(step,
                                       self._ckpt_tree(params, opt_state))
                        self.ckpt.wait()
                break
            with sp("data"):
                batch = next(batch_iter)
                if isinstance(batch, tuple):  # (step, batch) loaders
                    batch = batch[1]
            self._init_ctrl_state(params, batch)
            phase_policy = (self.program.phase_policy_at(step)
                            if self.program is not None else None)
            with sp("dispatch"):
                params, opt_state, metrics, comm_state = self._jit_step(
                    params, opt_state, batch, base_key, comm_state,
                    self._ctrl.state, phase_policy=phase_policy)
            self._comm_state = comm_state
            # controller tick: fold the step's per-layer telemetry into the
            # log-scales (host-side; the updated state is a traced input
            # next step, so no retrace)
            with sp("controller"):
                self._ctrl.tick()
            if self.obs is not None:
                # float() blocks on the step's device values — acceptable
                # only because obs is opt-in; monitors + run log need host
                # scalars
                self.obs.on_step(
                    step + 1, {k: float(v) for k, v in metrics.items()})
            if self.tcfg.log_every and (step + 1) % self.tcfg.log_every == 0:
                loss = float(metrics["loss"])
                row = {"step": step + 1, "loss": loss}
                if "comm_wire_bytes" in metrics:
                    wire = float(metrics["comm_wire_bytes"])
                    row["comm_wire_mb"] = wire / 1e6
                    if self.topology is not None:
                        from repro.launch.costmodel import price_step_comm
                        row.update(price_step_comm(
                            wire, pods=self.topology.pods))
                self.history.append(row)
                log.info("step %d loss %.4f (%.2f s)", step + 1, loss,
                         time.time() - t0)
            if (self.ckpt is not None and self.tcfg.ckpt_every
                    and (step + 1) % self.tcfg.ckpt_every == 0):
                with sp("checkpoint"):
                    self.ckpt.save(step + 1,
                                   self._ckpt_tree(params, opt_state))
        if self.ckpt is not None:
            self.ckpt.wait()
        if self.obs is not None:
            self.obs.finish()
        return {"params": params, "opt_state": opt_state,
                "history": self.history}
