"""Fault tolerance & elasticity for 1000+ node runs.

The pieces that can be *executed* in this container are implemented and
unit-tested (restart-from-checkpoint, elastic re-mesh + reshard, straggler
detection on step-time streams, preemption signal handling). The cluster-
specific wiring (GCE preemption notices, TPU health RPCs) enters through the
narrow ``HealthSource`` interface so the logic is testable offline.

Design (DESIGN.md §6):
* Restart: the trainer is a pure function of (checkpoint, data stream
  position); data is index-based (sample i = f(seed, i)) so resume is exact.
* Node failure: on a collective timeout / health event the runner rebuilds
  the mesh from surviving hosts (powers of two only, keeping the model axis
  intact — TP groups must stay whole) and restores the latest checkpoint
  with resharding (CheckpointManager.restore(shardings=...)).
* Stragglers: EWMA of per-host step times; hosts slower than
  ``straggler_factor`` x the p50 for ``patience`` consecutive steps are
  reported for replacement — mitigation, not exclusion, since SPMD cannot
  drop a participant mid-step.
* Preemption: SIGTERM flips a flag; the train loop checkpoints at the next
  step boundary and exits cleanly.
"""
from __future__ import annotations

import dataclasses
import signal
import threading
from typing import Dict, List, Optional, Sequence, Tuple


# --------------------------------------------------------------------------
# preemption
# --------------------------------------------------------------------------

class PreemptionGuard:
    """SIGTERM/SIGINT -> graceful checkpoint-and-exit at a step boundary."""

    def __init__(self, install: bool = True):
        self._flag = threading.Event()
        if install:
            try:
                signal.signal(signal.SIGTERM, self._handler)
            except ValueError:
                pass  # not on main thread (tests)

    def _handler(self, signum, frame):
        self._flag.set()

    def trigger(self) -> None:  # tests / manual drills
        self._flag.set()

    @property
    def should_stop(self) -> bool:
        return self._flag.is_set()


# --------------------------------------------------------------------------
# stragglers
# --------------------------------------------------------------------------

@dataclasses.dataclass
class StragglerConfig:
    factor: float = 1.5  # slower than factor * median = suspect
    patience: int = 5  # consecutive suspect steps before reporting
    ewma: float = 0.3


class StragglerDetector:
    def __init__(self, n_hosts: int, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.n = n_hosts
        self._t: List[Optional[float]] = [None] * n_hosts
        self._strikes = [0] * n_hosts

    def observe(self, step_times: Sequence[float]) -> List[int]:
        """Feed per-host step durations; returns hosts flagged this round.

        Strikes count *instantaneously* slow steps (a single blip clears on
        the next healthy step); the EWMA is kept for reporting/telemetry.
        """
        a = self.cfg.ewma
        for i, t in enumerate(step_times):
            self._t[i] = t if self._t[i] is None else a * t + (1 - a) * self._t[i]
        vals = sorted(step_times)
        med = vals[len(vals) // 2]
        flagged = []
        for i, v in enumerate(step_times):
            if v > self.cfg.factor * med:
                self._strikes[i] += 1
                if self._strikes[i] >= self.cfg.patience:
                    flagged.append(i)
            else:
                self._strikes[i] = 0
        return flagged


# --------------------------------------------------------------------------
# elastic re-mesh
# --------------------------------------------------------------------------

def plan_elastic_mesh(n_alive_chips: int, model_parallel: int
                      ) -> Optional[Tuple[Tuple[int, ...], Tuple[str, ...]]]:
    """Largest usable (data, model) mesh after failures.

    TP groups must stay whole (a model-parallel shard is useless without its
    peers), so we keep ``model_parallel`` fixed and round the data axis down
    to a power of two — gradient-accumulation compensates the lost batch.
    Returns None if fewer than one full TP group survives.
    """
    if n_alive_chips < model_parallel:
        return None
    data = n_alive_chips // model_parallel
    # round down to power of two for clean collective rings
    p = 1
    while p * 2 <= data:
        p *= 2
    return (p, model_parallel), ("data", "model")


@dataclasses.dataclass
class RestartPlan:
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    restore_step: Optional[int]
    grad_accum_scale: int  # multiply accumulation steps by this


def make_restart_plan(n_alive_chips: int, model_parallel: int,
                      original_data_parallel: int,
                      latest_step: Optional[int]) -> Optional[RestartPlan]:
    plan = plan_elastic_mesh(n_alive_chips, model_parallel)
    if plan is None:
        return None
    (data, _), axes = plan
    scale = max(1, original_data_parallel // data)
    return RestartPlan(mesh_shape=plan[0], mesh_axes=axes,
                       restore_step=latest_step, grad_accum_scale=scale)


# --------------------------------------------------------------------------
# health source interface (cluster wiring boundary)
# --------------------------------------------------------------------------

class HealthSource:
    """Override per cluster: report alive chip count + per-host step times."""

    def alive_chips(self) -> int:
        raise NotImplementedError

    def step_times(self) -> Dict[int, float]:
        raise NotImplementedError


class StaticHealthSource(HealthSource):
    """Offline/test implementation fed by the harness."""

    def __init__(self, chips: int):
        self._chips = chips
        self._times: Dict[int, float] = {}

    def fail(self, n: int) -> None:
        self._chips -= n

    def alive_chips(self) -> int:
        return self._chips

    def set_step_time(self, host: int, t: float) -> None:
        self._times[host] = t

    def step_times(self) -> Dict[int, float]:
        return dict(self._times)
