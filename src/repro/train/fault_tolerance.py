"""Fault tolerance & elasticity for 1000+ node runs.

The pieces that can be *executed* in this container are implemented and
unit-tested (restart-from-checkpoint, elastic re-mesh + reshard, straggler
detection on step-time streams, preemption signal handling). The cluster-
specific wiring (GCE preemption notices, TPU health RPCs) enters through the
narrow ``HealthSource`` interface so the logic is testable offline.

Design (DESIGN.md §6):
* Restart: the trainer is a pure function of (checkpoint, data stream
  position); data is index-based (sample i = f(seed, i)) so resume is exact.
* Node failure: on a collective timeout / health event the runner rebuilds
  the mesh from surviving hosts (powers of two only, keeping the model axis
  intact — TP groups must stay whole) and restores the latest checkpoint
  with resharding (CheckpointManager.restore(shardings=...)).
* Stragglers: EWMA of per-host step times; hosts slower than
  ``straggler_factor`` x the p50 for ``patience`` consecutive steps are
  reported for replacement — mitigation, not exclusion, since SPMD cannot
  drop a participant mid-step.
* Preemption: SIGTERM flips a flag; the train loop checkpoints at the next
  step boundary and exits cleanly.
"""
from __future__ import annotations

import dataclasses
import signal
import threading
from typing import Dict, List, Optional, Sequence, Tuple


# --------------------------------------------------------------------------
# preemption
# --------------------------------------------------------------------------

class PreemptionGuard:
    """SIGTERM/SIGINT -> graceful checkpoint-and-exit at a step boundary."""

    def __init__(self, install: bool = True):
        self._flag = threading.Event()
        if install:
            try:
                signal.signal(signal.SIGTERM, self._handler)
            except ValueError:
                pass  # not on main thread (tests)

    def _handler(self, signum, frame):
        self._flag.set()

    def trigger(self) -> None:  # tests / manual drills
        self._flag.set()

    @property
    def should_stop(self) -> bool:
        return self._flag.is_set()


# --------------------------------------------------------------------------
# stragglers
# --------------------------------------------------------------------------

@dataclasses.dataclass
class StragglerConfig:
    factor: float = 1.5  # slower than factor * median = suspect
    patience: int = 5  # consecutive suspect steps before reporting
    ewma: float = 0.3


class StragglerDetector:
    def __init__(self, n_hosts: int, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.n = n_hosts
        self._t: List[Optional[float]] = [None] * n_hosts
        self._strikes = [0] * n_hosts

    def observe(self, step_times: Sequence[float]) -> List[int]:
        """Feed per-host step durations; returns hosts flagged this round.

        Strikes count *instantaneously* slow steps (a single blip clears on
        the next healthy step); the EWMA is kept for reporting/telemetry.
        """
        a = self.cfg.ewma
        for i, t in enumerate(step_times):
            self._t[i] = t if self._t[i] is None else a * t + (1 - a) * self._t[i]
        vals = sorted(step_times)
        med = vals[len(vals) // 2]
        flagged = []
        for i, v in enumerate(step_times):
            if v > self.cfg.factor * med:
                self._strikes[i] += 1
                if self._strikes[i] >= self.cfg.patience:
                    flagged.append(i)
            else:
                self._strikes[i] = 0
        return flagged


# --------------------------------------------------------------------------
# elastic re-mesh
# --------------------------------------------------------------------------

def plan_elastic_mesh(n_alive_chips: int, model_parallel: int
                      ) -> Optional[Tuple[Tuple[int, ...], Tuple[str, ...]]]:
    """Largest usable (data, model) mesh after failures.

    TP groups must stay whole (a model-parallel shard is useless without its
    peers), so we keep ``model_parallel`` fixed and round the data axis down
    to a power of two — gradient-accumulation compensates the lost batch.
    Returns None if fewer than one full TP group survives.
    """
    if n_alive_chips < model_parallel:
        return None
    data = n_alive_chips // model_parallel
    # round down to power of two for clean collective rings
    p = 1
    while p * 2 <= data:
        p *= 2
    return (p, model_parallel), ("data", "model")


@dataclasses.dataclass
class RestartPlan:
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    restore_step: Optional[int]
    grad_accum_scale: int  # multiply accumulation steps by this


def make_restart_plan(n_alive_chips: int, model_parallel: int,
                      original_data_parallel: int,
                      latest_step: Optional[int]) -> Optional[RestartPlan]:
    plan = plan_elastic_mesh(n_alive_chips, model_parallel)
    if plan is None:
        return None
    (data, _), axes = plan
    scale = max(1, original_data_parallel // data)
    return RestartPlan(mesh_shape=plan[0], mesh_axes=axes,
                       restore_step=latest_step, grad_accum_scale=scale)


# --------------------------------------------------------------------------
# elastic synchronous SGD: node join/leave with state migration
# --------------------------------------------------------------------------

def snap_pods(pods: int, n_nodes: int) -> int:
    """Largest pod count <= ``pods`` that divides ``n_nodes``.

    An elastic resize changes the node count under a hier/butterfly comm
    policy whose ``pods`` may no longer divide it; the reduce needs
    N = pods * per_pod exactly, so the pod axis snaps down (gcd keeps as
    much inter-pod parallelism as the new world size allows).
    """
    import math
    if n_nodes < 1:
        raise ValueError(f"n_nodes must be >= 1, got {n_nodes}")
    return max(1, math.gcd(max(pods, 1), n_nodes))


class ElasticSSGD:
    """Elastic driver over ``repro.distributed.make_ssgd_step``.

    Runs synchronous SGD at ``n_nodes`` data-parallel workers and
    supports node JOIN and LEAVE between steps: ``resize(n)`` migrates
    the full training state — params, optimizer, comm error-feedback
    residuals and sparsity-controller state — through the existing
    checkpoint tree (save at the old world size, rebuild the step
    function for the new one, restore). The EF residuals live
    server-side (per LEAF, not per node — see
    ``repro.comm.reducer._StackedPSReducer``), so the restored residuals
    are bit-exact regardless of the node delta; tests/test_checkpoint_ft
    pins this for both directions.

    The dither scale follows ``SSGDConfig.s_for_n`` at the CURRENT world
    size (the paper's s(N) trade rides through resizes), and a
    hier/butterfly comm policy's pod count snaps to the new node count
    via :func:`snap_pods`.
    """

    def __init__(self, model, opt_cfg, base_policy, comm_policy=None, *,
                 ckpt_dir: str, n_nodes: int, s_schedule: str = "sqrt",
                 s_base: float = 1.0, grad_accum: int = 1, keep: int = 3,
                 phase_step: int = 0, memory=None):
        from repro.train.checkpoint import CheckpointManager

        self.model = model
        self.opt_cfg = opt_cfg
        self.base_policy = base_policy
        self.comm_policy = comm_policy
        self.s_schedule = s_schedule
        self.s_base = s_base
        self.grad_accum = grad_accum
        self.phase_step = phase_step
        self.memory = memory
        self.ckpt = CheckpointManager(ckpt_dir, keep=keep)
        self.params = None
        self.opt_state = None
        self.comm_state: Dict = {}
        self.ctrl_state: Dict = {}
        self.n_nodes = 0
        self._rebuild(n_nodes)

    def _rebuild(self, n_nodes: int) -> None:
        from repro.distributed.ssgd import SSGDConfig, make_ssgd_step

        comm = self.comm_policy
        if comm is not None and comm.pods > 1:
            comm = comm.replace(pods=snap_pods(comm.pods, n_nodes))
        dcfg = SSGDConfig(n_nodes=n_nodes, s_schedule=self.s_schedule,
                          s_base=self.s_base)
        self.step_fn, self.policy = make_ssgd_step(
            self.model, self.opt_cfg, dcfg, self.base_policy, comm,
            phase_step=self.phase_step, memory=self.memory,
            grad_accum=self.grad_accum)
        self.n_nodes = n_nodes
        self.active_comm_policy = comm

    # ------------------------------------------------------------- lifecycle
    def init(self, key) -> None:
        """Fresh state, or restore the latest checkpoint if one exists."""
        from repro.comm.compression import init_comm_state
        from repro.optim import init_opt_state

        self.params, _ = self.model.init(key)
        self.opt_state = init_opt_state(self.params, self.opt_cfg)
        self.comm_state = (init_comm_state(self.params, self.comm_policy)
                           if self.comm_policy is not None else {})
        if self.ckpt.latest_step() is not None:
            self._restore()

    def _ckpt_tree(self) -> Dict:
        tree = {"params": self.params, "opt": self.opt_state}
        if self.comm_state:
            tree["comm"] = self.comm_state
        if self.ctrl_state:
            tree["ctrl"] = self.ctrl_state
        return tree

    def save(self) -> int:
        step = int(self.opt_state["step"])
        self.ckpt.save(step, self._ckpt_tree())
        self.ckpt.wait()
        return step

    def _restore(self) -> None:
        try:
            state = self.ckpt.restore(self._ckpt_tree())
        except KeyError:
            # checkpoint predates a subtree (e.g. comm state grew since):
            # restore what exists, keep the rest at init
            state = self.ckpt.restore(
                {"params": self.params, "opt": self.opt_state})
        self.params = state["params"]
        self.opt_state = state["opt"]
        self.comm_state = state.get("comm", self.comm_state)
        self.ctrl_state = state.get("ctrl", self.ctrl_state)

    def resize(self, n_nodes: int) -> None:
        """Node join (grow) or leave (shrink): migrate state via checkpoint.

        The round trip through the checkpoint tree is deliberate — it is
        the same path a real elastic restart takes (survivors restore
        from disk onto the new world size), so tests exercising this
        method certify that path, not an in-memory shortcut.
        """
        if n_nodes == self.n_nodes:
            return
        self.save()
        self._rebuild(n_nodes)
        self._restore()

    def step(self, batch: Dict, key) -> Dict:
        """One synchronous step; ``batch`` leaves lead with a flat batch
        axis divisible by the current ``n_nodes``."""
        from repro.distributed.ssgd import shard_batch

        sb = shard_batch(batch, self.n_nodes)
        self.params, self.opt_state, metrics, self.comm_state = self.step_fn(
            self.params, self.opt_state, sb, key,
            self.ctrl_state or None, self.comm_state or None)
        return metrics


# --------------------------------------------------------------------------
# health source interface (cluster wiring boundary)
# --------------------------------------------------------------------------

class HealthSource:
    """Override per cluster: report alive chip count + per-host step times."""

    def alive_chips(self) -> int:
        raise NotImplementedError

    def step_times(self) -> Dict[int, float]:
        raise NotImplementedError


class StaticHealthSource(HealthSource):
    """Offline/test implementation fed by the harness."""

    def __init__(self, chips: int):
        self._chips = chips
        self._times: Dict[int, float] = {}

    def fail(self, n: int) -> None:
        self._chips -= n

    def alive_chips(self) -> int:
        return self._chips

    def set_step_time(self, host: int, t: float) -> None:
        self._times[host] = t

    def step_times(self) -> Dict[int, float]:
        return dict(self._times)
