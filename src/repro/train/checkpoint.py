"""Checkpointing: sharded save, async write, keep-k rotation, integrity
manifest, and RESHARDING restore (load a checkpoint onto a different mesh —
the elastic-downsize path).

Layout (one directory per step):
    <dir>/step_000100/
        manifest.json       {step, n_leaves, tree paths, shapes, dtypes, crc}
        shard_<host>.npz    this host's param/opt leaves (fully-addressable
                            slices only; single-host saves everything)
        _COMMITTED          written last; restores ignore dirs without it

The write path is crash-consistent: data first, marker last, rotation after.
Async mode pushes the (already host-local numpy) arrays to a writer thread
so the train loop only blocks for device->host transfer, not disk.

The async path is observable: the writer thread records ``ckpt_write``
spans (nested ``serialize`` / ``commit`` / ``rotate``) on the phase stream,
and the loop side records ``ckpt_gather`` (device->host), ``ckpt_drain``
(backpressure join on the previous in-flight write) and ``ckpt_wait``.
Span stacks are thread-local, so writer spans never nest under whatever
span the train loop is in when the write completes.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.obs.trace import span
from repro.utils import get_logger
from repro.utils.pytree import flatten_with_names

log = get_logger("checkpoint")

_MARKER = "_COMMITTED"


def _step_dir(base: str, step: int) -> str:
    return os.path.join(base, f"step_{step:08d}")


def list_steps(base: str) -> List[int]:
    if not os.path.isdir(base):
        return []
    out = []
    for d in os.listdir(base):
        if d.startswith("step_") and os.path.exists(
                os.path.join(base, d, _MARKER)):
            out.append(int(d.split("_")[1]))
    return sorted(out)


class CheckpointManager:
    def __init__(self, base_dir: str, *, keep: int = 3, async_write: bool = True):
        self.base = base_dir
        self.keep = keep
        self.async_write = async_write
        self._pending: Optional[threading.Thread] = None
        os.makedirs(base_dir, exist_ok=True)

    # ------------------------------------------------------------------ save
    @staticmethod
    def _to_savable(arr: np.ndarray) -> np.ndarray:
        """npz cannot store ml_dtypes (bf16/f16/f8); widen to f32 (exact)."""
        if arr.dtype.kind == "V" or str(arr.dtype) in (
                "bfloat16", "float16", "float8_e4m3fn", "float8_e5m2"):
            return arr.astype(np.float32)
        return arr

    def save(self, step: int, tree: Any) -> None:
        flat = flatten_with_names(tree)
        with span("ckpt_gather"):
            # device -> host (blocking part; disk write can go async)
            host_flat = [(name, self._to_savable(np.asarray(leaf)))
                         for name, leaf in flat]
        if self._pending is not None:
            with span("ckpt_drain"):
                self._pending.join()  # one checkpoint in flight at a time
            self._pending = None
        if self.async_write:
            t = threading.Thread(
                target=self._write, args=(step, host_flat), daemon=True)
            t.start()
            self._pending = t
        else:
            self._write(step, host_flat)

    def wait(self) -> None:
        if self._pending is not None:
            with span("ckpt_wait"):
                self._pending.join()
            self._pending = None

    def _write(self, step: int, host_flat: List[Tuple[str, np.ndarray]]):
        with span("ckpt_write"):
            self._write_spanned(step, host_flat)

    def _write_spanned(self, step: int,
                       host_flat: List[Tuple[str, np.ndarray]]):
        d = _step_dir(self.base, step)
        tmp = d + ".tmp"
        with span("serialize"):
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp, exist_ok=True)
            payload = {name: arr for name, arr in host_flat}
            shard_path = os.path.join(
                tmp, f"shard_{jax.process_index():05d}.npz")
            np.savez(shard_path, **payload)
            manifest = {
                "step": step,
                "leaves": [
                    {"name": n, "shape": list(a.shape), "dtype": str(a.dtype),
                     "crc": zlib.crc32(
                         np.ascontiguousarray(a).tobytes()) & 0xFFFFFFFF}
                    for n, a in host_flat
                ],
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
        with span("commit"):
            with open(os.path.join(tmp, _MARKER), "w") as f:
                f.write("ok")
            shutil.rmtree(d, ignore_errors=True)
            os.rename(tmp, d)
        log.info("saved checkpoint step=%d (%d leaves)", step, len(host_flat))
        with span("rotate"):
            self._rotate()

    def _rotate(self):
        steps = list_steps(self.base)
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(_step_dir(self.base, s), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = list_steps(self.base)
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None, verify: bool = True) -> Any:
        """Restore into the structure of ``tree_like``.

        ``shardings`` (optional pytree of NamedSharding) reshards on load —
        restoring onto a different mesh than the one that saved is supported
        because shards are host-complete npz files.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints under {self.base}")
        d = _step_dir(self.base, step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        crc_by_name = {leaf["name"]: leaf["crc"] for leaf in manifest["leaves"]}
        data: Dict[str, np.ndarray] = {}
        for fn in sorted(os.listdir(d)):
            if fn.startswith("shard_") and fn.endswith(".npz"):
                with np.load(os.path.join(d, fn)) as z:
                    for k in z.files:
                        data[k] = z[k]
        flat = flatten_with_names(tree_like)
        sh_flat = None
        if shardings is not None:
            sh_flat = [s for _, s in flatten_with_names(shardings)]
        out_leaves = []
        for i, (name, ref) in enumerate(flat):
            if name not in data:
                raise KeyError(f"checkpoint missing leaf {name}")
            arr = data[name]
            if verify:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF
                if crc != crc_by_name.get(name):
                    raise IOError(f"checksum mismatch for {name}")
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"shape mismatch for {name}: ckpt {arr.shape} vs {ref.shape}")
            arr = np.asarray(jax.numpy.asarray(arr).astype(ref.dtype))
            if sh_flat is not None:
                out_leaves.append(jax.device_put(arr, sh_flat[i]))
            else:
                out_leaves.append(jax.device_put(arr))
        treedef = jax.tree.structure(tree_like)
        return jax.tree.unflatten(treedef, out_leaves)
