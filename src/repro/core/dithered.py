"""Dithered backprop as composable JAX ops (the paper's eqs. 7-9).

Every weight-bearing contraction in the framework goes through ``dense`` /
``conv2d`` / ``dithered_einsum`` below. Forward is exact; the backward pass
intercepts the pre-activation cotangent ``g`` (= delta_z in the paper),
applies the resolved quantizer once, and reuses the quantized tensor for
BOTH backward products:

    delta_a = g~ . W^T        (activation gradient, eq. 8)
    delta_W = a^T . g~        (weight gradient,     eq. 9)

Bias gradients (a cheap reduction, not a matmul) use the exact cotangent.

Policy resolution is per layer name (``ctx.resolve(name)`` — rules, knob
schedules and the sparsity controller live in ``repro.core.schedule``). The
resolved result splits static from traced state:

* ``StaticSpec`` (variant / telemetry) is the custom_vjp's static argument;
* the numeric knobs ``[s, meprop_k_frac, row_alpha]`` arrive as a traced f32
  ``(3,)`` array, so a schedule that changes ``s`` every step re-uses the
  compiled backward — zero recompiles (pinned by tests/test_schedule.py).

Variants (spec.variant):
  off     plain backprop
  paper   NSD in f32, products in the layer dtype      [faithful baseline]
  int8    NSD to (int8 k, Delta) + absmax-int8 x/w, both products on the
          int8 MXU path, rescaled on exit              [beyond paper, TPU]
  row     structured row dither                        [beyond paper, TPU]
  meprop  top-k magnitude comparator                   [paper's baseline]
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import int8 as int8lib
from repro.core import meprop as meproplib
from repro.core import nsd
from repro.core import rowdither
from repro.core import stats as statslib
from repro.core.policy import (
    KNOB_MEPROP_K_FRAC,
    KNOB_ROW_ALPHA,
    KNOB_S,
    VARIANT_INT8,
    VARIANT_KERNEL,
    VARIANT_MEPROP,
    VARIANT_PAPER,
    VARIANT_ROW,
    DitherCtx,
    StaticSpec,
)


# --------------------------------------------------------------------------
# cotangent quantization dispatch
# --------------------------------------------------------------------------

def quantize_cotangent(
    g: jax.Array, key: jax.Array, knobs: jax.Array, spec: StaticSpec,
    name: str
) -> jax.Array:
    """Apply the resolved quantizer to a pre-activation cotangent.

    ``knobs`` is the traced [s, meprop_k_frac, row_alpha] vector; ``spec``
    carries the static variant/telemetry switches.
    """
    if spec.variant in (VARIANT_PAPER, VARIANT_INT8, VARIANT_KERNEL):
        delta = nsd.compute_delta(g, knobs[KNOB_S])
        k = nsd.nsd_indices(g, key, delta)
        if spec.collect_stats:
            statslib.emit(spec.stats_tag + name, nsd.quant_stats(k, delta))
        return (k.astype(jnp.float32) * delta).astype(g.dtype)
    if spec.variant == VARIANT_ROW:
        out = rowdither.row_dither(g, key, knobs[KNOB_ROW_ALPHA])
        if spec.collect_stats:
            zero = 1.0 - jnp.mean((out != 0).astype(jnp.float32))
            statslib.emit(
                spec.stats_tag + name,
                nsd.QuantStats(zero, jnp.float32(32), jnp.float32(0)),
            )
        return out
    if spec.variant == VARIANT_MEPROP:
        k_frac = (spec.meprop_k_static if spec.meprop_k_static is not None
                  else knobs[KNOB_MEPROP_K_FRAC])
        out = meproplib.meprop_sparsify(g, k_frac)
        if spec.collect_stats:
            zero = 1.0 - jnp.mean((out != 0).astype(jnp.float32))
            statslib.emit(
                spec.stats_tag + name,
                nsd.QuantStats(zero, jnp.float32(32), jnp.float32(0)),
            )
        return out
    return g


# --------------------------------------------------------------------------
# generic dithered op: works for any two-operand primal (conv, einsum, ...)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _make_dithered_op(primal_fn: Callable) -> Callable:
    """Wrap ``primal_fn(x, w) -> y`` so its bwd quantizes the cotangent once
    and pushes it through the *exact* vjp of the primal — this is precisely
    the paper's recipe and is correct for any linear primal."""

    @functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
    def op(x, w, key, knobs, spec, name):
        return primal_fn(x, w)

    def fwd(x, w, key, knobs, spec, name):
        return primal_fn(x, w), (x, w, key, knobs)

    def bwd(spec, name, res, g):
        x, w, key, knobs = res
        gq = quantize_cotangent(g, key, knobs, spec, name)
        _, vjp = jax.vjp(primal_fn, x, w)
        dx, dw = vjp(gq)
        return dx, dw, None, None

    op.defvjp(fwd, bwd)
    return op


# --------------------------------------------------------------------------
# dense (the paper's fully-connected case) with an explicit int8 backward
# --------------------------------------------------------------------------

def _plain_matmul(x, w):
    return jax.lax.dot_general(
        x, w, dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=x.dtype,
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _dithered_dense(x, w, key, knobs, spec, name):
    return _plain_matmul(x, w)


def _dd_fwd(x, w, key, knobs, spec, name):
    return _plain_matmul(x, w), (x, w, key, knobs)


def _kernel_shapes_ok(g2d, x2d, w, block=128):
    return (g2d.shape[0] % block == 0 and g2d.shape[1] % block == 0
            and x2d.shape[1] % block == 0)


def _dd_bwd(spec, name, res, g):
    x, w, key, knobs = res
    s = knobs[KNOB_S]
    kdim = x.shape[-1]
    x2d = x.reshape(-1, kdim)
    g2d = g.reshape(-1, g.shape[-1])

    if spec.variant == VARIANT_KERNEL and _kernel_shapes_ok(g2d, x2d, w):
        # Pallas path: fused NSD quantize + tile-skipping int8 matmuls
        # (interpret mode on CPU; compiled VMEM kernels on TPU). Falls back
        # to the jnp paper path for non-128-aligned layers.
        from repro.kernels.ops import dithered_backward_matmuls

        if spec.collect_stats:
            delta = nsd.compute_delta(g2d, s)
            k = nsd.nsd_indices(g2d, key, delta)
            statslib.emit(spec.stats_tag + name, nsd.quant_stats(k, delta))
        dx2d, dw = dithered_backward_matmuls(
            g2d, x2d, w, key, s, int8_operands=True)
        return dx2d.reshape(x.shape), dw, None, None

    if spec.variant == VARIANT_INT8:
        # NSD indices ARE an int8 tensor; x and w get absmax int8. Both
        # backward products then run on the int8 MXU path (2x bf16 on v5e).
        delta = nsd.compute_delta(g2d, s)
        k = nsd.nsd_indices(g2d, key, delta).astype(jnp.int8)
        if spec.collect_stats:
            statslib.emit(spec.stats_tag + name, nsd.quant_stats(k, delta))
        xq = int8lib.quantize_int8(x2d)
        wq = int8lib.quantize_int8(w)
        # dx = g~ @ W^T : contract over the output dim
        dx2d = jax.lax.dot_general(
            k, wq.q, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32,
        ).astype(jnp.float32) * (delta * wq.scale)
        # dW = x^T @ g~ : contract over the row (token) dim
        dw = jax.lax.dot_general(
            xq.q, k, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        ).astype(jnp.float32) * (xq.scale * delta)
        return (
            dx2d.astype(x.dtype).reshape(x.shape),
            dw.astype(w.dtype),
            None,
            None,
        )

    gq = quantize_cotangent(g2d, key, knobs, spec, name)
    dx2d = jax.lax.dot_general(
        gq, w, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=gq.dtype,
    )
    dw = jax.lax.dot_general(
        x2d, gq, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=x2d.dtype,
    )
    return dx2d.astype(x.dtype).reshape(x.shape), dw.astype(w.dtype), None, \
        None


_dithered_dense.defvjp(_dd_fwd, _dd_bwd)


def dense(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    ctx: Optional[DitherCtx] = None,
    name: str = "dense",
) -> jax.Array:
    """y = x @ w (+ b); dithered backward when resolution covers ``name``.

    When ctx is None (inference / serving / baseline) or the resolved
    per-layer policy is off, this is a plain matmul with no custom_vjp in
    the trace at all.
    """
    r = ctx.resolve(name) if ctx is not None else None
    if r is not None:
        y = _dithered_dense(x, w, r.key, r.knobs, r.spec, name)
    else:
        y = _plain_matmul(x, w)
    if b is not None:
        y = y + b
    return y


# --------------------------------------------------------------------------
# conv2d (the paper's convolutional case) — exact vjp of the quantized
# cotangent via the generic wrapper
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _conv_primal(strides, padding, lhs_dilation, rhs_dilation, feature_group_count):
    def primal(x, w):  # NHWC x HWIO -> NHWC
        return jax.lax.conv_general_dilated(
            x, w,
            window_strides=strides,
            padding=padding,
            lhs_dilation=lhs_dilation,
            rhs_dilation=rhs_dilation,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=feature_group_count,
        )
    return primal


def conv2d(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    strides=(1, 1),
    padding="SAME",
    lhs_dilation=(1, 1),
    rhs_dilation=(1, 1),
    feature_group_count: int = 1,
    ctx: Optional[DitherCtx] = None,
    name: str = "conv",
) -> jax.Array:
    primal = _conv_primal(
        tuple(strides), padding if isinstance(padding, str) else tuple(padding),
        tuple(lhs_dilation), tuple(rhs_dilation), feature_group_count,
    )
    r = ctx.resolve(name) if ctx is not None else None
    if r is not None:
        op = _make_dithered_op(primal)
        y = op(x, w, r.key, r.knobs, r.spec, name)
    else:
        y = primal(x, w)
    if b is not None:
        y = y + b
    return y


# --------------------------------------------------------------------------
# two-operand einsum (expert FFNs, attention projections with fused heads)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _einsum_primal(spec: str):
    def primal(x, w):
        return jnp.einsum(spec, x, w)
    return primal


def dithered_einsum(
    spec: str,
    x: jax.Array,
    w: jax.Array,
    *,
    ctx: Optional[DitherCtx] = None,
    name: str = "einsum",
) -> jax.Array:
    """einsum('...,...->...', x, w) with dithered backward on the cotangent."""
    primal = _einsum_primal(spec)
    r = ctx.resolve(name) if ctx is not None else None
    if r is not None:
        op = _make_dithered_op(primal)
        return op(x, w, r.key, r.knobs, r.spec, name)
    return primal(x, w)
