"""Dithered backprop as composable JAX ops (the paper's eqs. 7-9).

Every weight-bearing contraction in the framework goes through ``dense`` /
``conv2d`` / ``dithered_einsum`` below. Forward is exact; the backward pass
intercepts the pre-activation cotangent ``g`` (= delta_z in the paper),
applies the resolved quantizer once, and reuses the quantized tensor for
BOTH backward products:

    delta_a = g~ . W^T        (activation gradient, eq. 8)
    delta_W = a^T . g~        (weight gradient,     eq. 9)

Bias gradients (a cheap reduction, not a matmul) use the exact cotangent.

Policy resolution is per layer name (``ctx.resolve(name)`` — rules, knob
schedules and the sparsity controller live in ``repro.core.schedule``). The
resolved result splits static from traced state:

* ``StaticSpec`` (variant / telemetry / residual mode) is the custom_vjp's
  static argument;
* the numeric knobs ``[s, meprop_k_frac, row_alpha]`` arrive as a traced f32
  ``(3,)`` array, so a schedule that changes ``s`` every step re-uses the
  compiled backward — zero recompiles (pinned by tests/test_schedule.py).

Residual memory (``repro.memory``): the forward residual each op saves for
its backward — the activation ``x`` that the weight-gradient product
consumes — goes through the layer's resolved residual codec
(``spec.residual``): ``fwd`` stores ``codec.encode(x)`` instead of dense
fp32 and ``bwd`` decodes, so between the forward and backward passes only
the compressed form stays live. ``dx = g~ . W^T`` never touches ``x`` and
is bit-identical to the dense-residual path; only ``dW = x^T . g~`` sees
the (unbiased for nsd, scale/2-bounded for int8) reconstruction. Mode
``"remat"`` instead wraps the op in ``jax.checkpoint`` — the VJP
recomputes the forward from the op inputs rather than decoding. The codec
choice is static per layer; knob schedules still recompile nothing
(compile-counter pins in tests/test_memory.py).

Variants (spec.variant):
  off     plain backprop
  paper   NSD in f32, products in the layer dtype      [faithful baseline]
  int8    NSD to (int8 k, Delta) + absmax-int8 x/w, both products on the
          int8 MXU path, rescaled on exit              [beyond paper, TPU]
  row     structured row dither                        [beyond paper, TPU]
  meprop  top-k magnitude comparator                   [paper's baseline]
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import int8 as int8lib
from repro.core import meprop as meproplib
from repro.core import nsd
from repro.core import rowdither
from repro.obs import metrics as statslib
from repro.core.policy import (
    KNOB_MEPROP_K_FRAC,
    KNOB_ROW_ALPHA,
    KNOB_S,
    VARIANT_INT8,
    VARIANT_KERNEL,
    VARIANT_MEPROP,
    VARIANT_PAPER,
    VARIANT_ROW,
    DitherCtx,
    StaticSpec,
)


# --------------------------------------------------------------------------
# residual store: encode at fwd time, decode at bwd time
# --------------------------------------------------------------------------

def _residlib():
    # lazy: repro.quant imports repro.core — a module-level import here
    # would run mid-way through core/__init__
    from repro import quant

    return quant


def encode_residual(x: jax.Array, key: jax.Array, spec: StaticSpec,
                    name: str):
    """Encode a saved forward residual under the layer's static mode and,
    when telemetry is on, record its measured / capacity / dense byte
    counts (wire-equivalent occupancy, HBM-resident buffers, legacy fp32
    store — see repro.quant for the distinction)."""
    codec = _residlib()
    if spec.residual in ("fp32", "remat"):
        enc = x  # identity: the residual tuple matches the legacy trace
    else:
        enc = codec.encode(spec.residual, x, codec.resid_key(key))
    if spec.collect_stats:
        statslib.emit_memory(
            spec.stats_tag + name,
            codec.measured_bytes(spec.residual, enc),
            codec.capacity_bytes(spec.residual, enc),
            codec.dense_nbytes(x.shape, x.dtype))
    return enc


def decode_residual(enc, spec: StaticSpec) -> jax.Array:
    if spec.residual in ("fp32", "remat"):
        return enc
    return _residlib().decode(spec.residual, enc)


def _record_footprint(ctx, r, name: str, x: jax.Array) -> None:
    """Trace-time byte accounting for repro.memory.accounting reports."""
    if ctx is None or ctx.mem_recorder is None or r is None:
        return
    codec = _residlib()
    ctx.mem_recorder[name] = (
        codec.stored_nbytes(r.spec.residual, x.shape, x.dtype),
        codec.dense_nbytes(x.shape, x.dtype))


# Identity marker whose custom fwd runs only under differentiation: remat
# layers hang their memory-telemetry row on it so rows appear exactly when
# a backward will consume the residual — the same semantics as the codec
# paths, whose emit lives in the op's own custom_vjp fwd.
@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _remat_emit(y, tag, nbytes):
    return y


def _re_fwd(y, tag, nbytes):
    # remat stores the raw op inputs: measured == capacity == dense
    statslib.emit_memory(tag, nbytes, nbytes, nbytes)
    return y, None


def _re_bwd(tag, nbytes, res, g):
    return (g,)


_remat_emit.defvjp(_re_fwd, _re_bwd)


def _apply_op(op: Callable, x, w, r, name: str):
    """Invoke a dithered op under the layer's resolved residual mode.

    Mode "remat" recomputes the op's forward in the VJP instead of
    consuming stored residuals (jax.checkpoint; spec/name stay static
    through the boundary). io_callback effects cannot live inside a
    checkpointed region, so remat layers run the op with telemetry
    stripped and emit their (identity) residual byte row through
    ``_remat_emit`` outside the checkpoint: a remat layer contributes no
    sparsity rows (and is invisible to the sparsity controller), which is
    the price of the recompute path and is pinned in tests/test_memory.py.
    """
    spec = r.spec
    if spec.residual != "remat":
        return op(x, w, r.key, r.knobs, spec, name)
    collect = spec.collect_stats
    if collect:
        spec = dataclasses.replace(spec, collect_stats=False)
    y = jax.checkpoint(op, static_argnums=(4, 5))(
        x, w, r.key, r.knobs, spec, name)
    if collect:
        y = _remat_emit(y, r.spec.stats_tag + name,
                        _residlib().dense_nbytes(x.shape, x.dtype))
    return y


# --------------------------------------------------------------------------
# cotangent quantization dispatch
# --------------------------------------------------------------------------

def quantize_cotangent(
    g: jax.Array, key: jax.Array, knobs: jax.Array, spec: StaticSpec,
    name: str
) -> jax.Array:
    """Apply the resolved quantizer to a pre-activation cotangent.

    ``knobs`` is the traced [s, meprop_k_frac, row_alpha] vector; ``spec``
    carries the static variant/telemetry switches.

    When ``spec.grad_codec`` is set, the registered quant codec replaces
    the variant's built-in quantizer: the cotangent takes the codec's
    fake-quant round trip (e.g. ``"int4@g32"`` grouped-scale), so new
    formats reach gradients without a new variant.
    """
    if spec.grad_codec is not None:
        quant = _residlib()
        out = quant.quantize(spec.grad_codec, g, key).astype(g.dtype)
        if spec.collect_stats:
            zero = 1.0 - jnp.mean((out != 0).astype(jnp.float32))
            bits = quant.parse_spec(spec.grad_codec).bits
            statslib.emit(
                spec.stats_tag + name,
                nsd.QuantStats(zero, jnp.float32(bits), jnp.float32(0)),
            )
        return out
    if spec.variant in (VARIANT_PAPER, VARIANT_INT8, VARIANT_KERNEL):
        delta = nsd.compute_delta(g, knobs[KNOB_S])
        k = nsd.nsd_indices(g, key, delta)
        if spec.collect_stats:
            statslib.emit(spec.stats_tag + name, nsd.quant_stats(k, delta))
        return (k.astype(jnp.float32) * delta).astype(g.dtype)
    if spec.variant == VARIANT_ROW:
        out = rowdither.row_dither(g, key, knobs[KNOB_ROW_ALPHA])
        if spec.collect_stats:
            zero = 1.0 - jnp.mean((out != 0).astype(jnp.float32))
            statslib.emit(
                spec.stats_tag + name,
                nsd.QuantStats(zero, jnp.float32(32), jnp.float32(0)),
            )
        return out
    if spec.variant == VARIANT_MEPROP:
        k_frac = (spec.meprop_k_static if spec.meprop_k_static is not None
                  else knobs[KNOB_MEPROP_K_FRAC])
        out = meproplib.meprop_sparsify(g, k_frac)
        if spec.collect_stats:
            zero = 1.0 - jnp.mean((out != 0).astype(jnp.float32))
            statslib.emit(
                spec.stats_tag + name,
                nsd.QuantStats(zero, jnp.float32(32), jnp.float32(0)),
            )
        return out
    return g


# --------------------------------------------------------------------------
# VARIANT_KERNEL backward implementations (fused NSD + tile-skip matmuls)
# --------------------------------------------------------------------------

def _kernelops():
    # lazy: repro.kernels.ops imports repro.comm (wireformat) which imports
    # repro.core — a module-level import here would cycle
    from repro.kernels import ops

    return ops


def _emit_kernel_stats(q, g2d: jax.Array, spec: StaticSpec, name: str):
    """Telemetry from the SAME quantized tensor the kernels consume.

    ``q.k`` is the fused kernel's output (zero-padded); slicing back to the
    live region makes the stats bit-identical to the paper path's
    ``nsd.quant_stats(nsd_indices(g2d, key, delta))`` for the same key —
    pinned in tests/test_kernels.py so the applied gradient and the
    telemetry can never diverge again.
    """
    if spec.collect_stats:
        k_live = q.k[: g2d.shape[0], : g2d.shape[1]].astype(jnp.int32)
        statslib.emit(spec.stats_tag + name, nsd.quant_stats(k_live, q.delta))


def _dense_kernel_bwd(x, w, key, knobs, spec, name, g):
    """Tile-skipping backward for y = x @ w (any shape; padded to tiles)."""
    ops = _kernelops()
    kdim = x.shape[-1]
    g2d = g.reshape(-1, g.shape[-1])
    q = ops.quantize_and_mask(g2d, key, knobs[KNOB_S])
    _emit_kernel_stats(q, g2d, spec, name)
    dx2d, dw = ops.bsp_backward_from_quantized(
        q, x.reshape(-1, kdim), w, int8_operands=True)
    return dx2d.reshape(x.shape).astype(x.dtype), dw.astype(w.dtype)


@functools.lru_cache(maxsize=None)
def _conv_kernel_bwd(strides, padding, lhs_dilation, rhs_dilation,
                     feature_group_count):
    """Kernel-variant backward for conv2d via im2col.

    conv(x, w) == patches(x) @ w_mat with the patch feature axis ordered
    (Ci, kh, kw) — so both backward products are exactly the dense layer's
    tile-skipping matmuls on the im2col matrix, and dx folds back through
    the exact vjp of the (linear) patch extraction. Grouped or
    lhs-dilated convs fall back to the generic quantized path (counted in
    ``repro.kernels.ops.KERNEL_FALLBACKS``, never silent).
    """

    def kernel_bwd(x, w, key, knobs, spec, name, g):
        if feature_group_count != 1 or tuple(lhs_dilation) != (1, 1):
            _kernelops().note_fallback("conv:groups-or-lhs-dilation", name)
            return None
        ops = _kernelops()
        kh, kw, ci, co = w.shape
        kk = kh * kw * ci

        def patches_fn(xx):
            return jax.lax.conv_general_dilated_patches(
                xx, (kh, kw), strides, padding,
                rhs_dilation=rhs_dilation,
                dimension_numbers=("NHWC", "HWIO", "NHWC"))

        cols, unpatch = jax.vjp(patches_fn, x)
        g2d = g.reshape(-1, co)
        q = ops.quantize_and_mask(g2d, key, knobs[KNOB_S])
        _emit_kernel_stats(q, g2d, spec, name)
        w_mat = w.transpose(2, 0, 1, 3).reshape(kk, co)
        dcols2d, dw_mat = ops.bsp_backward_from_quantized(
            q, cols.reshape(-1, kk), w_mat, int8_operands=True)
        dx = unpatch(dcols2d.reshape(cols.shape))[0]
        dw = dw_mat.reshape(ci, kh, kw, co).transpose(1, 2, 0, 3)
        return dx.astype(x.dtype), dw.astype(w.dtype)

    return kernel_bwd


def _einsum_form(spec: str):
    """Classify a two-operand einsum for the kernel backward.

    Returns "dense2d" for ``...k,kn->...n`` (shared 2-D weight: flatten and
    run the dense pipeline), "batched" for ``B...k,Bkn->B...n`` (leading
    shared batch axis, per-slice 2-D matmul — the MoE expert-FFN shape), or
    None (unsupported: counted fallback to the generic quantized path).
    """
    if "->" not in spec or "." in spec:
        return None
    ins, out = spec.split("->")
    if "," not in ins:
        return None
    a, b = ins.split(",")
    if len(set(a)) != len(a) or len(set(b)) != len(b):
        return None
    if len(b) == 2 and len(a) >= 2 and a[-1] == b[0] \
            and out == a[:-1] + b[1] and b[1] not in a:
        return "dense2d"
    if len(b) == 3 and len(a) >= 3 and a[0] == b[0] \
            and a[-1] == b[1] and out == a[0] + a[1:-1] + b[2] \
            and b[2] not in a:
        return "batched"
    return None


@functools.lru_cache(maxsize=None)
def _einsum_kernel_bwd(spec_str: str):
    form = _einsum_form(spec_str)

    def kernel_bwd(x, w, key, knobs, spec, name, g):
        ops = _kernelops()
        if form is None:
            ops.note_fallback("einsum:unsupported-form:" + spec_str, name)
            return None
        if form == "dense2d":
            return _dense_kernel_bwd(x, w, key, knobs, spec, name, g)
        # batched: per-slice matmuls share ONE per-tensor quantization
        # (delta over the whole cotangent, noise over its full shape) so
        # the quantized values are bit-identical to the paper path; each
        # slice derives its own tile mask from its packed bitmap.
        n_b = x.shape[0]
        fdim = g.shape[-1]
        g2d = g.reshape(-1, fdim)
        q_full = ops.quantize_and_mask(g2d, key, knobs[KNOB_S])
        _emit_kernel_stats(q_full, g2d, spec, name)
        k3 = q_full.k[: g2d.shape[0], :fdim].reshape(n_b, -1, fdim)
        x3 = x.reshape(n_b, -1, x.shape[-1])
        dxs, dws = [], []
        for e in range(n_b):
            q_e = ops.quantized_from_indices(k3[e], q_full.delta)
            dx_e, dw_e = ops.bsp_backward_from_quantized(
                q_e, x3[e], w[e], int8_operands=True)
            dxs.append(dx_e)
            dws.append(dw_e)
        dx = jnp.stack(dxs).reshape(x.shape).astype(x.dtype)
        dw = jnp.stack(dws).astype(w.dtype)
        return dx, dw

    return kernel_bwd


# --------------------------------------------------------------------------
# generic dithered op: works for any two-operand primal (conv, einsum, ...)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _make_dithered_op(primal_fn: Callable,
                      kernel_bwd: Optional[Callable] = None) -> Callable:
    """Wrap ``primal_fn(x, w) -> y`` so its bwd quantizes the cotangent once
    and pushes it through the *exact* vjp of the primal — this is precisely
    the paper's recipe and is correct for any linear primal.

    ``kernel_bwd(x, w, key, knobs, spec, name, g) -> (dx, dw) | None``
    supplies the VARIANT_KERNEL tile-skipping backward; returning None
    (a counted structural fallback) drops to the generic quantized path.
    """

    @functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
    def op(x, w, key, knobs, spec, name):
        return primal_fn(x, w)

    def fwd(x, w, key, knobs, spec, name):
        enc = encode_residual(x, key, spec, name)
        return primal_fn(x, w), (enc, w, key, knobs)

    def bwd(spec, name, res, g):
        enc, w, key, knobs = res
        x = decode_residual(enc, spec)
        if spec.variant == VARIANT_KERNEL and kernel_bwd is not None \
                and spec.grad_codec is None:
            out = kernel_bwd(x, w, key, knobs, spec, name, g)
            if out is not None:
                dx, dw = out
                return dx, dw, None, None
        gq = quantize_cotangent(g, key, knobs, spec, name)
        _, vjp = jax.vjp(primal_fn, x, w)
        dx, dw = vjp(gq)
        return dx, dw, None, None

    op.defvjp(fwd, bwd)
    return op


# --------------------------------------------------------------------------
# dense (the paper's fully-connected case) with an explicit int8 backward
# --------------------------------------------------------------------------

def _plain_matmul(x, w):
    return jax.lax.dot_general(
        x, w, dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=x.dtype,
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _dithered_dense(x, w, key, knobs, spec, name):
    return _plain_matmul(x, w)


def _dd_fwd(x, w, key, knobs, spec, name):
    enc = encode_residual(x, key, spec, name)
    return _plain_matmul(x, w), (enc, w, key, knobs)


def _dd_bwd(spec, name, res, g):
    enc, w, key, knobs = res
    x = decode_residual(enc, spec)
    s = knobs[KNOB_S]
    kdim = x.shape[-1]
    x2d = x.reshape(-1, kdim)
    g2d = g.reshape(-1, g.shape[-1])

    # a grad_codec overrides the variant's built-in quantizer: skip the
    # NSD-specific kernel/int8 fast paths and take the generic route below
    if spec.variant == VARIANT_KERNEL and spec.grad_codec is None:
        # Pallas path: fused NSD quantize + tile-skipping int8 matmuls
        # (interpret mode on CPU; compiled VMEM kernels on TPU). Any layer
        # shape: operands are zero-padded to tile multiples, the padding
        # tiles quantize to all-zero and are masked off.
        dx, dw = _dense_kernel_bwd(x, w, key, knobs, spec, name, g)
        return dx, dw, None, None

    if spec.variant == VARIANT_INT8 and spec.grad_codec is None:
        # NSD indices ARE an int8 tensor; x and w get absmax int8. Both
        # backward products then run on the int8 MXU path (2x bf16 on v5e).
        delta = nsd.compute_delta(g2d, s)
        k = nsd.nsd_indices(g2d, key, delta).astype(jnp.int8)
        if spec.collect_stats:
            statslib.emit(spec.stats_tag + name, nsd.quant_stats(k, delta))
        xq = int8lib._quantize_int8(x2d)
        wq = int8lib._quantize_int8(w)
        # dx = g~ @ W^T : contract over the output dim
        dx2d = jax.lax.dot_general(
            k, wq.q, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32,
        ).astype(jnp.float32) * (delta * wq.scale)
        # dW = x^T @ g~ : contract over the row (token) dim
        dw = jax.lax.dot_general(
            xq.q, k, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        ).astype(jnp.float32) * (xq.scale * delta)
        return (
            dx2d.astype(x.dtype).reshape(x.shape),
            dw.astype(w.dtype),
            None,
            None,
        )

    gq = quantize_cotangent(g2d, key, knobs, spec, name)
    dx2d = jax.lax.dot_general(
        gq, w, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=gq.dtype,
    )
    dw = jax.lax.dot_general(
        x2d, gq, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=x2d.dtype,
    )
    return dx2d.astype(x.dtype).reshape(x.shape), dw.astype(w.dtype), None, \
        None


_dithered_dense.defvjp(_dd_fwd, _dd_bwd)


def dense(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    ctx: Optional[DitherCtx] = None,
    name: str = "dense",
) -> jax.Array:
    """y = x @ w (+ b); dithered backward when resolution covers ``name``.

    When ctx is None (inference / serving / baseline) or the resolved
    per-layer policy is off, this is a plain matmul with no custom_vjp in
    the trace at all.
    """
    r = ctx.resolve(name) if ctx is not None else None
    if r is not None:
        _record_footprint(ctx, r, name, x)
        y = _apply_op(_dithered_dense, x, w, r, name)
    else:
        y = _plain_matmul(x, w)
    if b is not None:
        y = y + b
    return y


# --------------------------------------------------------------------------
# conv2d (the paper's convolutional case) — exact vjp of the quantized
# cotangent via the generic wrapper
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _conv_primal(strides, padding, lhs_dilation, rhs_dilation, feature_group_count):
    def primal(x, w):  # NHWC x HWIO -> NHWC
        return jax.lax.conv_general_dilated(
            x, w,
            window_strides=strides,
            padding=padding,
            lhs_dilation=lhs_dilation,
            rhs_dilation=rhs_dilation,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=feature_group_count,
        )
    return primal


def conv2d(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    strides=(1, 1),
    padding="SAME",
    lhs_dilation=(1, 1),
    rhs_dilation=(1, 1),
    feature_group_count: int = 1,
    ctx: Optional[DitherCtx] = None,
    name: str = "conv",
) -> jax.Array:
    primal = _conv_primal(
        tuple(strides), padding if isinstance(padding, str) else tuple(padding),
        tuple(lhs_dilation), tuple(rhs_dilation), feature_group_count,
    )
    kernel_bwd = _conv_kernel_bwd(
        tuple(strides), padding if isinstance(padding, str) else tuple(padding),
        tuple(lhs_dilation), tuple(rhs_dilation), feature_group_count,
    )
    r = ctx.resolve(name) if ctx is not None else None
    if r is not None:
        _record_footprint(ctx, r, name, x)
        y = _apply_op(_make_dithered_op(primal, kernel_bwd), x, w, r, name)
    else:
        y = primal(x, w)
    if b is not None:
        y = y + b
    return y


# --------------------------------------------------------------------------
# two-operand einsum (expert FFNs, attention projections with fused heads)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _einsum_primal(spec: str):
    def primal(x, w):
        return jnp.einsum(spec, x, w)
    return primal


def dithered_einsum(
    spec: str,
    x: jax.Array,
    w: jax.Array,
    *,
    ctx: Optional[DitherCtx] = None,
    name: str = "einsum",
) -> jax.Array:
    """einsum('...,...->...', x, w) with dithered backward on the cotangent."""
    primal = _einsum_primal(spec)
    r = ctx.resolve(name) if ctx is not None else None
    if r is not None:
        _record_footprint(ctx, r, name, x)
        return _apply_op(_make_dithered_op(primal, _einsum_kernel_bwd(spec)),
                         x, w, r, name)
    return primal(x, w)
