"""Non-subtractive dithered (NSD) quantization — the paper's core operator.

    x_tilde = Q_Delta(x + nu) = Delta * floor((x + nu)/Delta + 1/2)
    nu ~ U(-Delta/2, Delta/2),   Delta = s * std(x)   (per tensor, per layer)

Properties (paper eqs. 5/6): E[x_tilde - x] = 0 and E[(x_tilde - x)^2] < Delta^2/4.
Quantized values are integer multiples of Delta; the integer index
k = x_tilde / Delta is what gets stored in int8 on the quantized path.
All internal arithmetic is f32 regardless of the input dtype.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Integer indices |k| are clipped here so the non-zeros always fit in int8.
# For Delta = s*sigma with s >= 1, P(|k| > 127) under a Gaussian model is
# P(|x| > 127*sigma) ~ 0; the clip is a numerical safety net, not a bias
# source in practice (verified in tests/test_nsd.py).
INT8_CLIP = 127


class QuantStats(NamedTuple):
    """Telemetry matching the paper's Table-1 metrics."""

    sparsity: jax.Array  # fraction of exact zeros after NSD, scalar f32
    max_bitwidth: jax.Array  # worst-case bits (incl. sign) for non-zero ks
    delta: jax.Array  # the step size used


def compute_delta(x: jax.Array, s: float) -> jax.Array:
    """Delta = s * std(x), computed in f32 over the whole tensor."""
    return s * jnp.std(x.astype(jnp.float32))


def dither_noise(key: jax.Array, shape, delta: jax.Array) -> jax.Array:
    """nu ~ U(-Delta/2, Delta/2), f32."""
    u = jax.random.uniform(key, shape, dtype=jnp.float32, minval=-0.5, maxval=0.5)
    return u * delta


def nsd_indices(x: jax.Array, key: jax.Array, delta: jax.Array) -> jax.Array:
    """Integer quantization indices k = floor((x + nu)/Delta + 1/2), int32.

    Guards delta == 0 (e.g. an all-zero gradient tensor) by emitting zeros.
    """
    xf = x.astype(jnp.float32)
    nu = dither_noise(key, x.shape, delta)
    safe = jnp.maximum(delta, jnp.finfo(jnp.float32).tiny)
    k = jnp.floor((xf + nu) / safe + 0.5).astype(jnp.int32)
    k = jnp.clip(k, -INT8_CLIP, INT8_CLIP)
    return jnp.where(delta > 0.0, k, jnp.zeros_like(k))


def nsd_quantize(x: jax.Array, key: jax.Array, s: float) -> jax.Array:
    """DEPRECATED: use :func:`repro.quant.nsd_fakequant` (same math).

    The canonical home moved to the quant engine; this wrapper composes
    the (undeprecated) primitives above, so it stays bit-exact.
    """
    import warnings

    warnings.warn(
        "repro.core.nsd.nsd_quantize is deprecated; use "
        "repro.quant.nsd_fakequant (bit-exact, same signature)",
        DeprecationWarning, stacklevel=2)
    delta = compute_delta(x, s)
    k = nsd_indices(x, key, delta)
    return (k.astype(jnp.float32) * delta).astype(x.dtype)


class QuantizedGrad(NamedTuple):
    """int8 representation of an NSD-quantized tensor: value = k * delta."""

    k: jax.Array  # int8 indices
    delta: jax.Array  # scalar f32 step

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return (self.k.astype(jnp.float32) * self.delta).astype(dtype)


def nsd_quantize_int8(x: jax.Array, key: jax.Array, s: float) -> QuantizedGrad:
    """DEPRECATED: use :func:`repro.quant.nsd_int8` (same math).

    Composes the (undeprecated) primitives above, so it stays bit-exact.
    """
    import warnings

    warnings.warn(
        "repro.core.nsd.nsd_quantize_int8 is deprecated; use "
        "repro.quant.nsd_int8 (bit-exact, same signature)",
        DeprecationWarning, stacklevel=2)
    delta = compute_delta(x, s)
    k = nsd_indices(x, key, delta)
    return QuantizedGrad(k=k.astype(jnp.int8), delta=delta)


def quant_stats(k: jax.Array, delta: jax.Array) -> QuantStats:
    """Sparsity & worst-case bit-width of the integer index tensor."""
    kf = k.astype(jnp.int32)
    nonzero = kf != 0
    sparsity = 1.0 - jnp.mean(nonzero.astype(jnp.float32))
    max_abs = jnp.max(jnp.abs(kf)).astype(jnp.float32)
    # bits = ceil(log2(max|k| + 1)) + 1 sign bit; 0 bits when all-zero.
    bits = jnp.where(
        max_abs > 0, jnp.ceil(jnp.log2(max_abs + 1.0)) + 1.0, 0.0
    )
    return QuantStats(sparsity=sparsity, max_bitwidth=bits, delta=delta)


def expected_sparsity_gaussian(s: float, n_mc: int = 200_000, seed: int = 0) -> float:
    """Monte-Carlo P(quantize-to-zero) for x~N(0,1), Delta=s — the paper's fig. 2.

    P(0) = P(|x + nu| < Delta/2) with nu~U(-Delta/2, Delta/2). Used by the
    benchmark harness to cross-check measured sparsity against theory.
    """
    key = jax.random.PRNGKey(seed)
    kx, kn = jax.random.split(key)
    x = jax.random.normal(kx, (n_mc,), dtype=jnp.float32)
    nu = jax.random.uniform(kn, (n_mc,), dtype=jnp.float32, minval=-s / 2, maxval=s / 2)
    k = jnp.floor((x + nu) / s + 0.5)
    return float(jnp.mean((k == 0).astype(jnp.float32)))
