"""Structured row dither — the TPU-native, beyond-paper variant.

The paper's elementwise NSD produces *unstructured* sparsity, which the MXU
(a 128x128 systolic array) cannot exploit: at 92% random element sparsity
the probability that a whole (8,128) VMEM tile is zero is 0.92^1024 ~ e^-85.
To make the sparsity structured we dither at *row* granularity (one row per
token/example of the pre-activation gradient):

    p_i   = min(1, ||g_i||_2 / (alpha * m))      m = mean row norm
    out_i = g_i * Bernoulli(p_i) / p_i

This is an importance-sampled row mask; like NSD it is exactly unbiased
(E[out] = g) with bounded variance, but the zeros now come as whole rows, so
a fixed-capacity gather compacts the survivors into a dense (C, n) matrix
the MXU can chew at full utilization. Rows are the natural unit on TPU: the
backward matmuls contract over the row axis, so dropping rows shrinks the
contraction dimension directly.

Composable with NSD: survivors can additionally be elementwise-dithered for
the int8 representation (``row_then_nsd``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import nsd


def _row_probs(g2d: jax.Array, alpha: float) -> jax.Array:
    norms = jnp.linalg.norm(g2d.astype(jnp.float32), axis=-1)
    mean = jnp.mean(norms)
    safe = jnp.maximum(alpha * mean, jnp.finfo(jnp.float32).tiny)
    return jnp.clip(norms / safe, 0.0, 1.0)


def row_dither(g: jax.Array, key: jax.Array, alpha: float = 1.0) -> jax.Array:
    """Unbiased Bernoulli row sampling with 1/p rescaling. Shape-preserving."""
    shape = g.shape
    g2d = g.reshape(-1, shape[-1])
    p = _row_probs(g2d, alpha)
    u = jax.random.uniform(key, p.shape, dtype=jnp.float32)
    keep = u < p
    scale = jnp.where(keep, 1.0 / jnp.maximum(p, jnp.finfo(jnp.float32).tiny), 0.0)
    out = g2d.astype(jnp.float32) * scale[:, None]
    return out.astype(g.dtype).reshape(shape)


class CompactRows(NamedTuple):
    """Fixed-capacity compaction of surviving rows (XLA-static shapes)."""

    rows: jax.Array  # (capacity, n) the scaled surviving rows (zero-padded)
    index: jax.Array  # (capacity,) source row index of each slot
    valid: jax.Array  # (capacity,) bool, slot occupied
    n_rows: jax.Array  # scalar, number of survivors (<= capacity)


def row_dither_compact(
    g: jax.Array, key: jax.Array, alpha: float, capacity: int
) -> CompactRows:
    """Row dither + gather survivors into a dense (capacity, n) matrix.

    If more than ``capacity`` rows survive, the lowest-probability extras are
    dropped *and* the kept rows are NOT re-scaled — callers choose capacity
    for a target overflow probability (< 1e-3 at capacity = 1.5x E[keep]);
    overflow is reported via ``n_rows > capacity`` so the trainer can log it.
    """
    shape = g.shape
    g2d = g.reshape(-1, shape[-1])
    p = _row_probs(g2d, alpha)
    u = jax.random.uniform(key, p.shape, dtype=jnp.float32)
    keep = u < p
    scale = jnp.where(keep, 1.0 / jnp.maximum(p, jnp.finfo(jnp.float32).tiny), 0.0)
    # order: survivors (by p desc) first, then non-survivors
    order_key = jnp.where(keep, p, -1.0)
    idx = jnp.argsort(-order_key)[:capacity]
    rows = (g2d.astype(jnp.float32) * scale[:, None])[idx]
    valid = keep[idx]
    rows = jnp.where(valid[:, None], rows, 0.0).astype(g.dtype)
    return CompactRows(
        rows=rows,
        index=idx.astype(jnp.int32),
        valid=valid,
        n_rows=jnp.sum(keep.astype(jnp.int32)),
    )


def scatter_rows(compact: CompactRows, n_total_rows: int) -> jax.Array:
    """Inverse of compaction (for testing / dense fallback)."""
    n = compact.rows.shape[-1]
    out = jnp.zeros((n_total_rows, n), compact.rows.dtype)
    safe_idx = jnp.where(compact.valid, compact.index, n_total_rows)  # OOB drop
    return out.at[safe_idx].add(jnp.where(compact.valid[:, None], compact.rows, 0))


def row_then_nsd(
    g: jax.Array, key: jax.Array, alpha: float, s: float
) -> jax.Array:
    """Row dither followed by elementwise NSD on the survivors."""
    k1, k2 = jax.random.split(key)
    rd = row_dither(g, k1, alpha)
    delta = nsd.compute_delta(rd, s)
    k = nsd.nsd_indices(rd, k2, delta)
    return (k.astype(jnp.float32) * delta).astype(rd.dtype)


def row_sparsity(g: jax.Array, key: jax.Array, alpha: float) -> jax.Array:
    """Fraction of rows dropped (structured sparsity actually realized)."""
    g2d = g.reshape(-1, g.shape[-1])
    p = _row_probs(g2d, alpha)
    u = jax.random.uniform(key, p.shape, dtype=jnp.float32)
    return 1.0 - jnp.mean((u < p).astype(jnp.float32))
