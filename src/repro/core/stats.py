"""DEPRECATED shim: the telemetry facade moved to :mod:`repro.obs.metrics`.

Historically this module owned three process-local sinks; those became the
typed metrics bus (``repro.obs.bus``) and the named read/write API now
lives in ``repro.obs.metrics`` (same functions, same streams, numerics
pinned bit-for-bit by the ``layer_sparsity`` / ``memory_bench`` zero-band
gates). Importing this module warns once per process; update imports::

    from repro.core import stats as statslib      # old
    from repro.obs import metrics as statslib     # new
"""
from __future__ import annotations

import warnings

from repro.obs.metrics import (  # noqa: F401
    STREAM_COMM, STREAM_DITHER, STREAM_MEMORY, _drain, comm_rows,
    comm_summary, comm_tags, emit, emit_comm, emit_memory, memory_rows,
    memory_summary, memory_tags, overall_max_bits,
    overall_residual_compression, overall_sparsity, reset, row_count, rows,
    rows_since, summary, tags)

warnings.warn(
    "repro.core.stats is deprecated; import repro.obs.metrics instead "
    "(same API over the same metrics bus)",
    DeprecationWarning, stacklevel=2)
