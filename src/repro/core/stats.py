"""Telemetry sink for dither statistics (sparsity / bit-width / delta).

The paper's Table 1 reports the average sparsity of the pre-activation
gradients over all layers and training iterations, and fig. 6b the
worst-case bit-width. Those numbers are produced *inside* the backward pass,
so we surface them with ``jax.experimental.io_callback`` into a process-local
sink. This is a single-host debugging/telemetry path — the policy flag
``collect_stats`` defaults to False and stays off for pjit multi-device runs.
"""
from __future__ import annotations

import threading
from collections import defaultdict
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.nsd import QuantStats

_LOCK = threading.Lock()
# tag -> list of (sparsity, bits, delta) rows
_SINK: Dict[str, List[np.ndarray]] = defaultdict(list)
# tag -> list of (wire_bytes, dense_bytes) rows — the comm-side counters
# (bytes-on-wire of compressed gradient exchange; see repro.comm.telemetry)
_COMM_SINK: Dict[str, List[np.ndarray]] = defaultdict(list)
# tag -> list of (measured, capacity, dense) byte rows — the residual-
# memory counters: occupancy-aware wire-equivalent bytes, the HBM-resident
# capacity of the encoded buffers, and the dense fp32 store they replace
# (see repro.memory.codec for the measured-vs-capacity distinction)
_MEM_SINK: Dict[str, List[np.ndarray]] = defaultdict(list)


def reset() -> None:
    with _LOCK:
        _SINK.clear()
        _COMM_SINK.clear()
        _MEM_SINK.clear()


def _record(tag: str, row: np.ndarray) -> np.ndarray:
    with _LOCK:
        _SINK[tag].append(np.asarray(row))
    return np.zeros((), np.int32)


def emit(tag: str, stats: QuantStats) -> None:
    """Call from inside a (possibly jitted) backward pass."""
    row = jnp.stack(
        [stats.sparsity, stats.max_bitwidth, stats.delta.astype(jnp.float32)]
    )
    jax.experimental.io_callback(
        lambda r, _tag=tag: _record(_tag, r),
        jax.ShapeDtypeStruct((), jnp.int32),
        row,
        ordered=False,
    )


def _drain() -> None:
    """Block until in-flight io_callbacks have landed (readers call this:
    emissions from a dispatched-but-unfinished step would otherwise race)."""
    jax.effects_barrier()


def rows(tag: str) -> np.ndarray:
    """(n, 3) array of [sparsity, bits, delta] records for a tag."""
    _drain()
    with _LOCK:
        if not _SINK[tag]:
            return np.zeros((0, 3), np.float32)
        return np.stack(_SINK[tag])


def rows_since(tag: str, start: int) -> np.ndarray:
    """Records from index ``start`` on, without restacking the history —
    per-step consumers (the sparsity controller's telemetry window) stay
    O(new records) instead of O(run length) per tick."""
    _drain()
    with _LOCK:
        new = _SINK[tag][start:]
        if not new:
            return np.zeros((0, 3), np.float32)
        return np.stack(new)


def row_count(tag: str) -> int:
    _drain()
    with _LOCK:
        return len(_SINK[tag])


def tags() -> List[str]:
    _drain()
    with _LOCK:
        return sorted(_SINK.keys())


def summary() -> Dict[str, Dict[str, float]]:
    """Per-tag mean sparsity, worst-case bits — the Table-1 aggregation."""
    out = {}
    for tag in tags():
        r = rows(tag)
        if len(r) == 0:
            continue
        out[tag] = {
            "mean_sparsity": float(r[:, 0].mean()),
            "max_bits": float(r[:, 1].max()),
            "mean_bits": float(r[:, 1].mean()),
            "n_records": int(len(r)),
        }
    return out


def overall_sparsity() -> float:
    """Average sparsity over every recorded layer x step, as in Table 1."""
    all_rows = [rows(t) for t in tags()]
    all_rows = [r for r in all_rows if len(r)]
    if not all_rows:
        return float("nan")
    cat = np.concatenate(all_rows, axis=0)
    return float(cat[:, 0].mean())


def overall_max_bits() -> float:
    all_rows = [rows(t) for t in tags()]
    all_rows = [r for r in all_rows if len(r)]
    if not all_rows:
        return float("nan")
    cat = np.concatenate(all_rows, axis=0)
    return float(cat[:, 1].max())


# ---------------------------------------------------------------------------
# comm counters: bytes-on-wire of compressed gradient exchange
# ---------------------------------------------------------------------------

def _record_comm(tag: str, row: np.ndarray) -> np.ndarray:
    with _LOCK:
        _COMM_SINK[tag].append(np.asarray(row))
    return np.zeros((), np.int32)


def emit_comm(tag: str, wire_bytes: jax.Array, dense_bytes: jax.Array) -> None:
    """Record one exchange's (wire, dense) byte counts from inside jit."""
    row = jnp.stack([jnp.asarray(wire_bytes, jnp.float32),
                     jnp.asarray(dense_bytes, jnp.float32)])
    jax.experimental.io_callback(
        lambda r, _tag=tag: _record_comm(_tag, r),
        jax.ShapeDtypeStruct((), jnp.int32),
        row,
        ordered=False,
    )


def comm_rows(tag: str) -> np.ndarray:
    """(n, 2) array of [wire_bytes, dense_bytes] records for a tag."""
    _drain()
    with _LOCK:
        if not _COMM_SINK[tag]:
            return np.zeros((0, 2), np.float32)
        return np.stack(_COMM_SINK[tag])


def comm_tags() -> List[str]:
    _drain()
    with _LOCK:
        return sorted(_COMM_SINK.keys())


def comm_summary() -> Dict[str, Dict[str, float]]:
    """Per-tag total wire/dense bytes and the achieved compression ratio."""
    out = {}
    for tag in comm_tags():
        r = comm_rows(tag)
        if len(r) == 0:
            continue
        wire, dense = float(r[:, 0].sum()), float(r[:, 1].sum())
        out[tag] = {
            "wire_bytes": wire,
            "dense_bytes": dense,
            "ratio": wire / dense if dense else float("nan"),
            "n_records": int(len(r)),
        }
    return out


# ---------------------------------------------------------------------------
# residual-memory counters: bytes the backward keeps alive per layer
# ---------------------------------------------------------------------------

def _record_memory(tag: str, row: np.ndarray) -> np.ndarray:
    with _LOCK:
        _MEM_SINK[tag].append(np.asarray(row))
    return np.zeros((), np.int32)


def emit_memory(tag: str, measured_bytes: jax.Array, capacity_bytes,
                dense_bytes) -> None:
    """Record one layer's (measured, capacity, dense) residual byte counts
    from inside a (possibly jitted) custom_vjp forward."""
    row = jnp.stack([jnp.asarray(measured_bytes, jnp.float32),
                     jnp.asarray(capacity_bytes, jnp.float32),
                     jnp.asarray(dense_bytes, jnp.float32)])
    jax.experimental.io_callback(
        lambda r, _tag=tag: _record_memory(_tag, r),
        jax.ShapeDtypeStruct((), jnp.int32),
        row,
        ordered=False,
    )


def memory_rows(tag: str) -> np.ndarray:
    """(n, 3) array of [measured, capacity, dense] byte records for a tag."""
    _drain()
    with _LOCK:
        if not _MEM_SINK[tag]:
            return np.zeros((0, 3), np.float32)
        return np.stack(_MEM_SINK[tag])


def memory_tags() -> List[str]:
    _drain()
    with _LOCK:
        return sorted(_MEM_SINK.keys())


def memory_summary() -> Dict[str, Dict[str, float]]:
    """Per-tag residual byte totals and the two compression factors:
    ``capacity_compression`` (dense / HBM-resident capacity — size batch
    headroom from THIS one) and ``occupancy_compression`` (dense /
    wire-equivalent measured bytes — what a byte-true compacted store
    would achieve)."""
    out = {}
    for tag in memory_tags():
        r = memory_rows(tag)
        if len(r) == 0:
            continue
        measured, cap, dense = (float(r[:, i].sum()) for i in range(3))
        out[tag] = {
            "measured_bytes": measured,
            "capacity_bytes": cap,
            "dense_bytes": dense,
            "occupancy_compression": (dense / measured if measured
                                      else float("nan")),
            "capacity_compression": dense / cap if cap else float("nan"),
            "n_records": int(len(r)),
        }
    return out


def overall_residual_compression(prefix: str = "", *,
                                 capacity: bool = False) -> float:
    """dense/measured (or dense/capacity) over every recorded layer x step
    under a tag prefix."""
    col = 1 if capacity else 0
    stored = dense = 0.0
    for tag in memory_tags():
        if not tag.startswith(prefix):
            continue
        r = memory_rows(tag)
        if len(r):
            stored += float(r[:, col].sum())
            dense += float(r[:, 2].sum())
    if stored <= 0:
        return float("nan")
    return dense / stored
