"""Dither policy — the single knob surface for the paper's technique.

The paper has exactly one global hyperparameter: the scale factor ``s`` in
``Delta = s * std(grad)``. The policy object carries that plus the framework
concerns around it (which layers participate, which backward variant runs,
whether telemetry is collected). It is a frozen (hashable) dataclass so it
can ride through ``jax.custom_vjp`` as a static argument.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import zlib


# Backward-pass variants. "paper" is the faithful baseline; everything else
# is a beyond-paper optimization kept strictly opt-in (see DESIGN.md §2).
VARIANT_OFF = "off"  # plain backprop (the paper's "Baseline" column)
VARIANT_PAPER = "paper"  # NSD on preactivation grads, matmuls in input dtype
VARIANT_INT8 = "int8"  # NSD + int8 MXU backward matmuls (8bit+dither column)
VARIANT_ROW = "row"  # structured row-dither (TPU-native, beyond paper)
VARIANT_MEPROP = "meprop"  # top-k comparator baseline from the paper
VARIANT_KERNEL = "kernel"  # Pallas kernel path: fused NSD + tile-skip matmuls
VARIANTS = (VARIANT_OFF, VARIANT_PAPER, VARIANT_INT8, VARIANT_ROW,
            VARIANT_MEPROP, VARIANT_KERNEL)


@dataclasses.dataclass(frozen=True)
class DitherPolicy:
    """Per-run configuration of dithered backprop."""

    variant: str = VARIANT_PAPER
    s: float = 2.0  # Delta = s * std(grad); the paper's global knob
    meprop_k_frac: float = 0.1  # fraction of entries kept by the meProp baseline
    row_alpha: float = 1.0  # row-dither aggressiveness (higher -> sparser)
    collect_stats: bool = False  # io_callback telemetry (single-host only)
    exclude: tuple = ()  # layer-name substrings exempted from dithering
    stats_tag: str = ""  # prefix for telemetry records

    def __post_init__(self):
        if self.variant not in VARIANTS:
            raise ValueError(f"unknown variant {self.variant!r}; one of {VARIANTS}")

    @property
    def enabled(self) -> bool:
        return self.variant != VARIANT_OFF

    def applies_to(self, name: str) -> bool:
        if not self.enabled:
            return False
        return not any(pat in name for pat in self.exclude)

    def replace(self, **kw) -> "DitherPolicy":
        return dataclasses.replace(self, **kw)


# A do-nothing policy: models built with ctx=None or this policy run plain
# backprop, which keeps inference/serving traces free of custom_vjp machinery.
OFF = DitherPolicy(variant=VARIANT_OFF)


def name_salt(name: str) -> int:
    """Stable 31-bit salt for folding a layer name into the step RNG key."""
    return zlib.crc32(name.encode()) & 0x7FFFFFFF


@dataclasses.dataclass
class DitherCtx:
    """Threaded through model ``apply`` — step RNG + policy.

    ``key`` must differ per optimization step (fold the step index in); each
    layer folds its own name in so dither noise is i.i.d. across layers,
    steps, and (via the caller folding in a worker id) data-parallel workers,
    which is what makes the distributed averaging argument of paper §3.6 hold.
    """

    key: jax.Array
    policy: DitherPolicy = dataclasses.field(default_factory=DitherPolicy)

    def key_for(self, name: str) -> jax.Array:
        return jax.random.fold_in(self.key, name_salt(name))

    @staticmethod
    def for_step(base_key: jax.Array, step: jax.Array, policy: DitherPolicy,
                 worker: int | jax.Array = 0) -> "DitherCtx":
        k = jax.random.fold_in(base_key, step)
        k = jax.random.fold_in(k, worker)
        return DitherCtx(key=k, policy=policy)


def maybe_ctx(ctx: Optional[DitherCtx], name: str) -> Optional[DitherCtx]:
    """Convenience: returns ctx only if the policy covers ``name``."""
    if ctx is None or not ctx.policy.applies_to(name):
        return None
    return ctx
