"""Dither policy — the knob surface for the paper's technique.

The paper has exactly one global hyperparameter: the scale factor ``s`` in
``Delta = s * std(grad)``. Historically this repo carried ``s`` (and the
other numeric knobs) as *static* ``custom_vjp`` arguments, so changing it
meant recompiling every backward matmul. The policy surface is now split in
two along the static/traced line:

* ``StaticSpec`` — the fields that legitimately shape the trace (backward
  variant, telemetry on/off, tag). These stay static arguments of the
  custom_vjp ops; changing them recompiles, which is correct and rare
  (a phase switch in a :class:`repro.core.schedule.PolicyProgram`).
* knobs — the numeric fields (``s``, ``meprop_k_frac``, ``row_alpha``),
  packed into a traced f32 ``(3,)`` array by :func:`knobs_array`. A
  schedule that changes ``s`` every step therefore triggers **zero**
  recompiles (pinned by tests/test_schedule.py).

``DitherPolicy`` remains the user-facing frozen dataclass; its numeric
fields are the *defaults* that get baked into knobs when a
``DitherCtx`` is built. Per-layer / per-step resolution lives in
``repro.core.schedule`` and enters through :meth:`DitherCtx.resolve`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Set

import jax
import jax.numpy as jnp
import zlib


# Backward-pass variants. "paper" is the faithful baseline; everything else
# is a beyond-paper optimization kept strictly opt-in (see DESIGN.md §2).
VARIANT_OFF = "off"  # plain backprop (the paper's "Baseline" column)
VARIANT_PAPER = "paper"  # NSD on preactivation grads, matmuls in input dtype
VARIANT_INT8 = "int8"  # NSD + int8 MXU backward matmuls (8bit+dither column)
VARIANT_ROW = "row"  # structured row-dither (TPU-native, beyond paper)
VARIANT_MEPROP = "meprop"  # top-k comparator baseline from the paper
VARIANT_KERNEL = "kernel"  # Pallas kernel path: fused NSD + tile-skip matmuls
VARIANTS = (VARIANT_OFF, VARIANT_PAPER, VARIANT_INT8, VARIANT_ROW,
            VARIANT_MEPROP, VARIANT_KERNEL)

# Index layout of the traced knobs array (see knobs_array()).
KNOB_S = 0
KNOB_MEPROP_K_FRAC = 1
KNOB_ROW_ALPHA = 2


def validate_knob_values(s: Any, meprop_k_frac: Any, row_alpha: Any,
                         owner: str) -> None:
    """Shared numeric validation for DitherPolicy / LayerRule fields.

    Only concrete (host-side) values are checked; ``None`` means "not
    overridden" (LayerRule). Schedule-typed fields are validated by their
    owner against every value the schedule can produce
    (``repro.core.schedule``), so a ramp cannot smuggle an illegal knob
    past construction.
    """
    if s is not None and not isinstance(s, jax.Array) and not s > 0:
        raise ValueError(f"{owner}: s must be > 0, got {s!r}")
    if meprop_k_frac is not None and not isinstance(meprop_k_frac, jax.Array) \
            and not 0 < meprop_k_frac <= 1:
        raise ValueError(
            f"{owner}: meprop_k_frac must be in (0, 1], got {meprop_k_frac!r}")
    if row_alpha is not None and not isinstance(row_alpha, jax.Array) \
            and not row_alpha > 0:
        raise ValueError(
            f"{owner}: row_alpha must be > 0, got {row_alpha!r}")


def knobs_array(s, meprop_k_frac, row_alpha) -> jax.Array:
    """Pack the numeric knobs as a traced f32 (3,) vector.

    This is THE boundary between policy configuration and the jitted
    backward pass: everything in here may change per step without
    retracing; everything in StaticSpec may not.
    """
    return jnp.stack([
        jnp.asarray(s, jnp.float32),
        jnp.asarray(meprop_k_frac, jnp.float32),
        jnp.asarray(row_alpha, jnp.float32),
    ])


@dataclasses.dataclass(frozen=True)
class StaticSpec:
    """The trace-shaping part of a resolved per-layer policy.

    Rides through ``jax.custom_vjp`` as a static (hashable) argument;
    deliberately excludes every numeric knob so knob schedules cannot
    invalidate the compile cache. The one exception is
    ``meprop_k_static``: an UNSCHEDULED meprop fraction is carried here so
    the backward keeps the cheap ``lax.top_k(k)`` path (k small) instead
    of the full per-row sort the traced path needs; it is set only for the
    meprop variant, and a scheduled ``meprop_k_frac`` leaves it None
    (traced, zero recompiles).
    """

    variant: str = VARIANT_PAPER
    collect_stats: bool = False
    stats_tag: str = ""
    meprop_k_static: Optional[float] = None
    # residual-memory mode for the layer's saved forward residual (see
    # repro.quant; any registered codec spec): "fp32" is the legacy dense
    # store; "remat"
    # wraps the op in jax.checkpoint; the codecs store x compressed. Static
    # per layer by construction — stamped from MemoryPolicy rules at trace
    # time in DitherCtx.resolve, so knob schedules cannot touch it.
    residual: str = "fp32"
    # registered quant codec spec (repro.quant, e.g. "int4@g32") applied to
    # the pre-activation cotangent INSTEAD of the variant's built-in NSD
    # quantizer; None keeps the variant's own path. Static per layer: codec
    # choice shapes the trace, its parameters live in the spec string.
    grad_codec: Optional[str] = None


class Resolved(NamedTuple):
    """What one layer's contraction gets after policy resolution."""

    spec: StaticSpec  # static: variant + telemetry switches
    knobs: jax.Array  # traced f32 (3,): [s, meprop_k_frac, row_alpha]
    key: jax.Array  # per-(step, layer) dither RNG key


@dataclasses.dataclass(frozen=True)
class DitherPolicy:
    """Per-run configuration of dithered backprop (the global defaults).

    Per-layer / per-step overrides are expressed as a
    :class:`repro.core.schedule.PolicyProgram` on top of this base.
    """

    variant: str = VARIANT_PAPER
    s: float = 2.0  # Delta = s * std(grad); the paper's global knob
    meprop_k_frac: float = 0.1  # fraction of entries kept by the meProp baseline
    row_alpha: float = 1.0  # row-dither aggressiveness (higher -> sparser)
    collect_stats: bool = False  # io_callback telemetry (single-host only)
    exclude: tuple = ()  # layer-name substrings exempted from dithering
    stats_tag: str = ""  # prefix for telemetry records
    # registered quant codec spec for the cotangent (see StaticSpec); None
    # keeps the variant's built-in NSD quantizer
    grad_codec: Optional[str] = None

    def __post_init__(self):
        if self.variant not in VARIANTS:
            raise ValueError(f"unknown variant {self.variant!r}; one of {VARIANTS}")
        validate_knob_values(self.s, self.meprop_k_frac, self.row_alpha,
                             owner="DitherPolicy")
        if self.grad_codec is not None:
            # lazy: repro.quant imports repro.core at module level
            from repro.quant.registry import validate_spec

            validate_spec(self.grad_codec)

    @property
    def enabled(self) -> bool:
        return self.variant != VARIANT_OFF

    def applies_to(self, name: str) -> bool:
        if not self.enabled:
            return False
        return not any(pat in name for pat in self.exclude)

    def replace(self, **kw) -> "DitherPolicy":
        return dataclasses.replace(self, **kw)

    def spec(self) -> StaticSpec:
        return StaticSpec(variant=self.variant,
                          collect_stats=self.collect_stats,
                          stats_tag=self.stats_tag,
                          meprop_k_static=(self.meprop_k_frac
                                           if self.variant == VARIANT_MEPROP
                                           else None),
                          grad_codec=self.grad_codec)

    def knobs(self) -> jax.Array:
        return knobs_array(self.s, self.meprop_k_frac, self.row_alpha)


# A do-nothing policy: models built with ctx=None or this policy run plain
# backprop, which keeps inference/serving traces free of custom_vjp machinery.
OFF = DitherPolicy(variant=VARIANT_OFF)


def name_salt(name: str) -> int:
    """Stable 31-bit salt for folding a layer name into the step RNG key."""
    return zlib.crc32(name.encode()) & 0x7FFFFFFF


@dataclasses.dataclass
class DitherCtx:
    """Threaded through model ``apply`` — step RNG + policy resolution.

    ``key`` must differ per optimization step (fold the step index in); each
    layer folds its own name in so dither noise is i.i.d. across layers,
    steps, and (via the caller folding in a worker id) data-parallel workers,
    which is what makes the distributed averaging argument of paper §3.6 hold.

    ``policy`` is the phase-resolved static base (see
    ``PolicyProgram.phase_policy_at``); when ``program`` is set, per-layer
    resolution (rules, knob schedules, controller scales) happens in
    :meth:`resolve` at trace time — layer names are static strings, so
    resolution costs nothing at run time and the resulting knobs are traced
    scalars (changing them never recompiles).
    """

    key: jax.Array
    policy: DitherPolicy = dataclasses.field(default_factory=DitherPolicy)
    # static PolicyProgram (repro.core.schedule); None = plain global policy
    program: Any = None
    # traced i32 step for knob schedules; None behaves as step 0
    step: Optional[jax.Array] = None
    # traced per-layer log-scale on s from the closed-loop sparsity
    # controller: {layer_name: f32 scalar}; rides the checkpoint tree
    ctrl: Optional[Dict[str, jax.Array]] = None
    # trace-time layer-name recorder (schedule.discover_layer_names)
    recorder: Optional[Set[str]] = None
    # static repro.memory.MemoryPolicy selecting the residual codec (or
    # remat) per layer name; None = legacy dense fp32 residuals
    memory: Any = None
    # trace-time residual-footprint recorder: {name: (stored, dense) bytes}
    # (repro.memory.accounting.residual_report)
    mem_recorder: Optional[Dict[str, tuple]] = None

    def key_for(self, name: str) -> jax.Array:
        return jax.random.fold_in(self.key, name_salt(name))

    def resolve(self, name: str) -> Optional[Resolved]:
        """Per-layer policy resolution; None = run plain backprop."""
        if self.recorder is not None:
            self.recorder.add(name)
        if self.program is not None:
            r = self.program.resolve_layer(self, name)
        elif not self.policy.applies_to(name):
            r = None
        else:
            r = Resolved(spec=self.policy.spec(), knobs=self.policy.knobs(),
                         key=self.key_for(name))
        # residual-memory resolution is centralized here so the plain-policy
        # and program paths cannot diverge; the mode lands in the STATIC
        # spec, never in the traced knobs.
        if r is not None and self.memory is not None:
            mode = self.memory.mode_for(name)
            if mode != r.spec.residual:
                r = Resolved(
                    spec=dataclasses.replace(r.spec, residual=mode),
                    knobs=r.knobs, key=r.key)
        return r

    def with_key(self, key: jax.Array) -> "DitherCtx":
        """Same resolution state, different RNG stream (micro-batches,
        shard_map bodies)."""
        return dataclasses.replace(self, key=key)

    @staticmethod
    def for_step(base_key: jax.Array, step, policy: DitherPolicy,
                 worker: int | jax.Array = 0, *, program: Any = None,
                 ctrl: Optional[Dict[str, jax.Array]] = None,
                 memory: Any = None) -> "DitherCtx":
        k = jax.random.fold_in(base_key, step)
        k = jax.random.fold_in(k, worker)
        return DitherCtx(key=k, policy=policy, program=program,
                         step=jnp.asarray(step, jnp.int32), ctrl=ctrl,
                         memory=memory)
