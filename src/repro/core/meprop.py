"""meProp comparator baseline (Sun et al. 2017), per the paper's §4.2.

meProp sparsifies the pre-activation gradient by keeping only the top-k
entries by magnitude. This is a *deterministic* operator on each vector, so
the resulting weight-update estimates are biased — exactly the property the
paper contrasts dithered backprop against (fig. 4 / fig. .9).

We implement the "unified" per-row variant: for gradient rows g (one row per
example/token), keep the k = ceil(frac * n) largest |g| entries per row.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def meprop_sparsify(g: jax.Array, k_frac) -> jax.Array:
    """Keep the top-``k_frac`` fraction of each row of ``g`` by magnitude.

    ``k_frac`` may be a Python float (static: per-row top_k threshold) or a
    traced f32 scalar (policy-program schedules: k becomes a traced index
    into the per-row sorted magnitudes, so stepping ``k_frac`` does not
    retrace). The two paths compute the same threshold — the k-th largest
    |g| per row — and are pinned equal in tests/test_schedule.py.
    """
    if g.ndim < 1:
        return g
    n = g.shape[-1]
    if isinstance(k_frac, jax.Array):
        flat = g.reshape(-1, n)
        mag = jnp.abs(flat.astype(jnp.float32))
        k = jnp.clip(jnp.round(k_frac * n).astype(jnp.int32), 1, n)
        sorted_desc = -jnp.sort(-mag, axis=-1)
        idx = jnp.broadcast_to(k - 1, (flat.shape[0], 1))
        thresh = jnp.take_along_axis(sorted_desc, idx, axis=-1)
        # k == n keeps every entry (mag >= row minimum is trivially true)
        mask = mag >= thresh
        out = jnp.where(mask, flat, jnp.zeros_like(flat))
        return out.reshape(g.shape)
    k = max(1, int(round(k_frac * n)))
    if k >= n:
        return g
    flat = g.reshape(-1, n)
    mag = jnp.abs(flat.astype(jnp.float32))
    # threshold per row = k-th largest magnitude
    thresh = jax.lax.top_k(mag, k)[0][:, -1:]
    mask = mag >= thresh
    out = jnp.where(mask, flat, jnp.zeros_like(flat))
    return out.reshape(g.shape)


def meprop_sparsity(g: jax.Array, k_frac: float) -> jax.Array:
    """Realized sparsity of the meProp mask (ties can keep a few extra)."""
    out = meprop_sparsify(g, k_frac)
    return 1.0 - jnp.mean((out != 0).astype(jnp.float32))
