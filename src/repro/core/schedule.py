"""Policy programs: per-layer, step-scheduled dithered backprop.

The paper's own evidence (Fig. 4/5, §3.3) is that gradient sparsity and
required bit-width vary widely per layer and per training phase — a single
frozen ``DitherPolicy(variant, s)`` leaves that structure on the table.
This module turns the policy surface into a small *program*:

* :class:`LayerRule` — ``pattern -> per-layer overrides`` of the variant
  and the numeric knobs. Patterns are globs (``L*.mlp.*``) when they
  contain glob metacharacters, plain substrings otherwise. Rules are
  ordered; for each knob the LAST matching rule that sets it wins.
* schedules (:class:`Const` / :class:`Piecewise` / :class:`Linear`) — any
  numeric knob may be a function of the step. Schedules evaluate on the
  *traced* step, so a per-step ``s`` ramp re-uses the compiled backward:
  zero recompiles (pinned by tests/test_schedule.py).
* :class:`PhaseSpec` — step-indexed *variant* switches (exact-backprop
  warmup -> ``paper`` -> ``int8``). The variant shapes the trace, so each
  phase boundary recompiles exactly once — resolved host-side via
  :meth:`PolicyProgram.phase_policy_at`.
* :class:`SparsityController` — a closed-loop integral controller that
  nudges each layer's ``s`` toward a target sparsity using the per-layer
  telemetry ``repro.core.stats`` already emits. Its state (per-layer
  log-scales) is a pytree of scalars that rides the checkpoint tree and is
  passed into the jitted step as a traced argument, so every data-parallel
  node resolves identical policies.

``PolicyProgram`` is hashable (frozen, tuple-valued) so it can sit in jit
static arguments and custom_vjp closures; everything numeric it produces is
traced.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import math
import re
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.policy import (
    VARIANT_OFF,
    VARIANT_PAPER,
    VARIANTS,
    DitherCtx,
    DitherPolicy,
    Resolved,
    StaticSpec,
    knobs_array,
    validate_knob_values,
)

__all__ = [
    "Const", "Piecewise", "Linear", "as_schedule", "eval_schedule",
    "LayerRule", "PhaseSpec", "SparsityController", "PolicyProgram",
    "as_program", "parse_program", "discover_layer_names",
    "ControllerDriver", "TelemetryWindow",
]


# ---------------------------------------------------------------------------
# step schedules (traced: evaluating at a new step never retraces)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Const:
    """A knob pinned to one value (the degenerate schedule)."""

    value: float

    def at(self, step: jax.Array) -> jax.Array:
        return jnp.asarray(self.value, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Piecewise:
    """Piecewise-constant: ``points = ((step0, v0), (step1, v1), ...)``.

    The value at ``step`` is the v of the last boundary <= step; steps
    before the first boundary clamp to the first value. Boundary steps
    belong to the NEW value (step == step1 -> v1), which is the convention
    the boundary tests pin.
    """

    points: Tuple[Tuple[int, float], ...]

    def __post_init__(self):
        if not self.points:
            raise ValueError("Piecewise: needs at least one (step, value) point")
        object.__setattr__(self, "points",
                           tuple((int(b), float(v)) for b, v in self.points))
        bounds = [b for b, _ in self.points]
        if bounds != sorted(set(bounds)):
            raise ValueError(
                f"Piecewise: boundaries must be strictly increasing, got {bounds}")

    def at(self, step: jax.Array) -> jax.Array:
        bounds = jnp.asarray([b for b, _ in self.points], jnp.int32)
        vals = jnp.asarray([v for _, v in self.points], jnp.float32)
        idx = jnp.sum((jnp.asarray(step, jnp.int32) >= bounds)
                      .astype(jnp.int32)) - 1
        return vals[jnp.clip(idx, 0, len(self.points) - 1)]


@dataclasses.dataclass(frozen=True)
class Linear:
    """Linear ramp from ``start`` to ``end`` over [start_step, end_step],
    clamped outside the window."""

    start_step: int
    end_step: int
    start: float
    end: float

    def __post_init__(self):
        if not self.end_step > self.start_step:
            raise ValueError(
                f"Linear: end_step must be > start_step, got "
                f"[{self.start_step}, {self.end_step}]")

    def at(self, step: jax.Array) -> jax.Array:
        t = (jnp.asarray(step, jnp.float32) - self.start_step) / (
            self.end_step - self.start_step)
        t = jnp.clip(t, 0.0, 1.0)
        return jnp.asarray(self.start, jnp.float32) + t * (
            jnp.asarray(self.end, jnp.float32)
            - jnp.asarray(self.start, jnp.float32))


ScheduleLike = Union[float, int, Const, Piecewise, Linear]
_SCHEDULE_TYPES = (Const, Piecewise, Linear)


def as_schedule(x: ScheduleLike) -> Union[Const, Piecewise, Linear]:
    if isinstance(x, _SCHEDULE_TYPES):
        return x
    return Const(float(x))


def eval_schedule(x: Optional[ScheduleLike], step: jax.Array):
    """float stays a (weak-typed) Python float — bit-identical to the legacy
    global-policy path; schedules evaluate on the traced step."""
    if isinstance(x, _SCHEDULE_TYPES):
        return x.at(step)
    return x


def _schedule_values(x: ScheduleLike) -> Tuple[float, ...]:
    """Every value a schedule can produce (endpoints/levels; Linear is
    monotone so its endpoints bound the range)."""
    if isinstance(x, Const):
        return (x.value,)
    if isinstance(x, Piecewise):
        return tuple(v for _, v in x.points)
    if isinstance(x, Linear):
        return (x.start, x.end)
    return (float(x),)


def _validate_knob_schedules(s, meprop_k_frac, row_alpha, owner: str) -> None:
    """Range-check knob fields whether they are plain floats or schedules —
    a ramp must not smuggle an illegal value past construction."""
    for field, value in (("s", s), ("meprop_k_frac", meprop_k_frac),
                         ("row_alpha", row_alpha)):
        if value is None:
            continue
        for v in _schedule_values(value):
            validate_knob_values(
                v if field == "s" else None,
                v if field == "meprop_k_frac" else None,
                v if field == "row_alpha" else None,
                owner=owner)


# ---------------------------------------------------------------------------
# per-layer rules
# ---------------------------------------------------------------------------

_GLOB_CHARS = re.compile(r"[*?\[]")


def pattern_matches(pattern: str, name: str) -> bool:
    """Glob when the pattern contains glob metacharacters, else substring."""
    if _GLOB_CHARS.search(pattern):
        return fnmatch.fnmatchcase(name, pattern)
    return pattern in name


@dataclasses.dataclass(frozen=True)
class LayerRule:
    """``pattern -> overrides``. Unset (None) fields inherit; ``variant``
    may be "off" to exempt the matching layers entirely."""

    pattern: str = "*"
    variant: Optional[str] = None
    s: Optional[ScheduleLike] = None
    meprop_k_frac: Optional[ScheduleLike] = None
    row_alpha: Optional[ScheduleLike] = None

    def __post_init__(self):
        if not self.pattern:
            raise ValueError("LayerRule: pattern must be a non-empty string")
        if self.variant is not None and self.variant not in VARIANTS:
            raise ValueError(
                f"LayerRule({self.pattern!r}): unknown variant "
                f"{self.variant!r}; one of {VARIANTS}")
        _validate_knob_schedules(self.s, self.meprop_k_frac, self.row_alpha,
                                 owner=f"LayerRule({self.pattern!r})")

    def matches(self, name: str) -> bool:
        return pattern_matches(self.pattern, name)


@dataclasses.dataclass(frozen=True)
class PhaseSpec:
    """From ``start`` (inclusive) onward, run ``variant`` — until the next
    phase takes over. Steps before the first phase use the base variant.

    A phase may also set per-phase knob *defaults* (plain floats): they
    replace the base policy's numerics while the phase is active and
    inherit through later phases that leave them unset. Precedence stays
    base < phase default < program-level schedule < rule < controller —
    schedules and rules override a phase default. Knob defaults ride the
    static phase policy, so a phase that only changes a default still
    retraces once at its boundary (like a variant switch); schedules
    remain the zero-retrace mechanism for per-step knob motion.
    """

    start: int
    variant: str
    s: Optional[float] = None
    meprop_k_frac: Optional[float] = None
    row_alpha: Optional[float] = None

    def __post_init__(self):
        if self.start < 0:
            raise ValueError(f"PhaseSpec: start must be >= 0, got {self.start}")
        if self.variant not in VARIANTS:
            raise ValueError(
                f"PhaseSpec@{self.start}: unknown variant {self.variant!r}; "
                f"one of {VARIANTS}")
        validate_knob_values(self.s, self.meprop_k_frac, self.row_alpha,
                             owner=f"PhaseSpec@{self.start}")


# ---------------------------------------------------------------------------
# closed-loop sparsity controller (host updates, traced application)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SparsityController:
    """Integral controller on log(s) per layer: sparsity below target ->
    raise s (bigger Delta -> more exact zeros), and vice versa.

    The state is ``{layer_name: f32 log-scale}``; :meth:`update` runs on the
    host between steps from the telemetry window, and the state enters the
    jitted step as a traced pytree — so s moves every step with zero
    recompiles, and checkpoints carry it (next to the EF residuals) for a
    lossless resume.
    """

    target: float  # target mean pre-activation-gradient sparsity in (0, 1)
    gain: float = 2.0  # log-space integral gain on (target - measured)
    min_scale: float = 0.25  # bounds on the multiplier applied to s
    max_scale: float = 4.0

    def __post_init__(self):
        if not 0.0 < self.target < 1.0:
            raise ValueError(
                f"SparsityController: target must be in (0, 1), got {self.target!r}")
        if not self.gain > 0:
            raise ValueError(
                f"SparsityController: gain must be > 0, got {self.gain!r}")
        if not 0 < self.min_scale <= 1.0 <= self.max_scale:
            raise ValueError(
                "SparsityController: need 0 < min_scale <= 1 <= max_scale, "
                f"got [{self.min_scale!r}, {self.max_scale!r}]")

    def init_state(self, names: Sequence[str]) -> Dict[str, jax.Array]:
        return {n: jnp.zeros((), jnp.float32) for n in sorted(names)}

    def update(self, state: Dict[str, jax.Array],
               measured: Dict[str, float]) -> Dict[str, jax.Array]:
        """One host-side controller tick. Names absent from ``state`` are
        ignored — the state's pytree structure never changes mid-run."""
        lo, hi = math.log(self.min_scale), math.log(self.max_scale)
        new = dict(state)
        for name, sparsity in measured.items():
            if name in new:
                nudged = jnp.asarray(new[name], jnp.float32) \
                    + self.gain * (self.target - float(sparsity))
                new[name] = jnp.clip(nudged, lo, hi)
        return new


# ---------------------------------------------------------------------------
# the program
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PolicyProgram:
    """Ordered per-layer rules + step schedules over a base DitherPolicy.

    Resolution order for a layer ``name`` at step ``t``:

    1. variant: base -> active phase (host-resolved, recompiles once per
       boundary) -> last matching rule that sets it. "off" exempts the layer.
    2. knobs: base numerics -> program-level schedules (``s`` /
       ``meprop_k_frac`` / ``row_alpha``) -> last matching rule that sets
       the knob -> controller log-scale on ``s``. All traced: never
       recompiles.

    A program whose only rule is the universal ``LayerRule()`` resolves to
    exactly the base policy — bit-for-bit, pinned by the ``layer_sparsity``
    benchmark's parity gate.
    """

    base: DitherPolicy = dataclasses.field(default_factory=DitherPolicy)
    rules: Tuple[LayerRule, ...] = ()
    phases: Tuple[PhaseSpec, ...] = ()
    s: Optional[ScheduleLike] = None
    meprop_k_frac: Optional[ScheduleLike] = None
    row_alpha: Optional[ScheduleLike] = None
    controller: Optional[SparsityController] = None

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(self.rules))
        object.__setattr__(self, "phases", tuple(self.phases))
        starts = [p.start for p in self.phases]
        if starts != sorted(set(starts)):
            raise ValueError(
                f"PolicyProgram: phase starts must be strictly increasing, "
                f"got {starts}")
        _validate_knob_schedules(self.s, self.meprop_k_frac, self.row_alpha,
                                 owner="PolicyProgram")
        if self.controller is not None and not self.base.collect_stats:
            raise ValueError(
                "PolicyProgram: the sparsity controller consumes per-layer "
                "telemetry — set collect_stats=True on the base policy")

    # -- host-side (static) resolution --------------------------------------

    def phase_policy_at(self, step: int) -> DitherPolicy:
        """The static base policy for host step ``step`` (phases applied:
        variant plus any per-phase knob defaults, which inherit through
        later phases that leave them unset).

        This is the value to pass as the jitted step's *static* policy
        argument: it only changes at phase boundaries, so a run with a knob
        schedule but no phases compiles exactly once.
        """
        variant = self.base.variant
        s, kf, ra = self.base.s, self.base.meprop_k_frac, self.base.row_alpha
        for ph in self.phases:
            if int(step) >= ph.start:
                variant = ph.variant
                if ph.s is not None:
                    s = ph.s
                if ph.meprop_k_frac is not None:
                    kf = ph.meprop_k_frac
                if ph.row_alpha is not None:
                    ra = ph.row_alpha
        if (variant, s, kf, ra) == (self.base.variant, self.base.s,
                                    self.base.meprop_k_frac,
                                    self.base.row_alpha):
            return self.base
        return self.base.replace(variant=variant, s=s, meprop_k_frac=kf,
                                 row_alpha=ra)

    def phase_boundaries(self) -> Tuple[int, ...]:
        return tuple(p.start for p in self.phases)

    @property
    def ever_enabled(self) -> bool:
        """True if any phase/rule can turn dithering on at some step."""
        if self.base.enabled:
            return True
        if any(p.variant != VARIANT_OFF for p in self.phases):
            return True
        return self.rules_enable

    @property
    def rules_enable(self) -> bool:
        """True if a rule pins an enabling variant — such layers dither even
        while the phase variant is "off", so steps must still build a ctx."""
        return any(r.variant not in (None, VARIANT_OFF) for r in self.rules)

    def step_enabled(self, phase_policy: DitherPolicy) -> bool:
        """Whether a step under ``phase_policy`` needs a DitherCtx at all."""
        return phase_policy.enabled or self.rules_enable

    # -- trace-time (per-layer) resolution ----------------------------------

    def resolve_layer(self, ctx: DitherCtx, name: str) -> Optional[Resolved]:
        base = ctx.policy
        if any(pat in name for pat in base.exclude):
            return None
        variant = base.variant
        s: Optional[ScheduleLike] = self.s if self.s is not None else base.s
        kf = (self.meprop_k_frac if self.meprop_k_frac is not None
              else base.meprop_k_frac)
        ra = self.row_alpha if self.row_alpha is not None else base.row_alpha
        for rule in self.rules:
            if rule.matches(name):
                if rule.variant is not None:
                    variant = rule.variant
                if rule.s is not None:
                    s = rule.s
                if rule.meprop_k_frac is not None:
                    kf = rule.meprop_k_frac
                if rule.row_alpha is not None:
                    ra = rule.row_alpha
        if variant == VARIANT_OFF:
            return None
        step = ctx.step if ctx.step is not None else jnp.zeros((), jnp.int32)
        s_val = eval_schedule(s, step)
        if ctx.ctrl:
            log_scale = ctx.ctrl.get(name)
            if log_scale is not None:
                s_val = jnp.asarray(s_val, jnp.float32) * jnp.exp(log_scale)
        knobs = knobs_array(s_val, eval_schedule(kf, step),
                            eval_schedule(ra, step))
        # unscheduled meprop fraction stays static -> cheap top_k backward;
        # Piecewise/Linear schedules leave it None (traced, no retraces)
        kf_static = None
        if variant == "meprop":
            if isinstance(kf, Const):
                kf_static = kf.value
            elif not isinstance(kf, _SCHEDULE_TYPES):
                kf_static = float(kf)
        spec = StaticSpec(variant=variant, collect_stats=base.collect_stats,
                          stats_tag=base.stats_tag, meprop_k_static=kf_static,
                          grad_codec=base.grad_codec)
        return Resolved(spec=spec, knobs=knobs, key=ctx.key_for(name))

    def replace(self, **kw) -> "PolicyProgram":
        return dataclasses.replace(self, **kw)


def as_program(policy) -> Optional[PolicyProgram]:
    """Lift a DitherPolicy (or pass through a PolicyProgram / None)."""
    if policy is None or isinstance(policy, PolicyProgram):
        return policy
    if isinstance(policy, DitherPolicy):
        return PolicyProgram(base=policy)
    raise TypeError(
        f"expected DitherPolicy, PolicyProgram or None, got {type(policy)!r}")


# ---------------------------------------------------------------------------
# layer-name discovery (stable controller-state structure from step 0)
# ---------------------------------------------------------------------------

def discover_layer_names(loss_fn, params, batch) -> List[str]:
    """All layer names that consult the policy in one loss evaluation.

    Runs ``jax.eval_shape`` (no FLOPs, no allocation) with a recording ctx;
    the trainer uses this before step 0 so the controller state's pytree
    structure — which would otherwise only be known after the first real
    step — is stable for the whole run (structure changes retrace).
    ``loss_fn(params, batch, ctx)`` must thread ctx like ``Model.loss``.
    """
    recorder: set = set()
    ctx = DitherCtx(key=jax.random.PRNGKey(0),
                    policy=DitherPolicy(variant=VARIANT_PAPER),
                    step=jnp.zeros((), jnp.int32), recorder=recorder)
    jax.eval_shape(lambda p, b: loss_fn(p, b, ctx), params, batch)
    return sorted(recorder)


class ControllerDriver:
    """Host-side protocol for a program's sparsity controller, shared by
    the Trainer and the benchmark harness so they cannot diverge:

    1. ``ensure_init`` — discover layer names once (eval_shape, no FLOPs)
       and build the {layer: log-scale} state with a stable structure;
    2. pass ``state`` into the jitted step as a traced argument;
    3. ``tick`` — after each step, fold the new telemetry into the state.

    No-ops throughout when the program has no controller.
    """

    def __init__(self, program: Optional[PolicyProgram]):
        self.program = program
        self.controller = program.controller if program is not None else None
        self.state: Dict[str, jax.Array] = {}
        self.window: Optional["TelemetryWindow"] = None
        self._inited = False

    @property
    def active(self) -> bool:
        return self.controller is not None

    @property
    def ready(self) -> bool:
        return self._inited

    def ensure_init(self, loss_fn, params, batch) -> List[str]:
        """Idempotent (an explicit flag, not dict truthiness: a ctx-less
        model legitimately discovers zero layers and must not re-trace the
        loss every step). Returns the discovered names."""
        if not self.active or self._inited:
            return sorted(self.state)
        names = discover_layer_names(loss_fn, params, batch)
        self.state = self.controller.init_state(names)
        self.window = TelemetryWindow(self.program.base.stats_tag)
        self._inited = True
        return names

    def tick(self) -> None:
        if self.window is None:
            return
        measured = self.window.measure()
        if measured:
            self.state = self.controller.update(self.state, measured)


class TelemetryWindow:
    """Host-side consumer of the per-layer sparsity telemetry: each
    ``measure()`` returns the mean sparsity of the rows that arrived since
    the previous call, keyed by layer name (tag minus the stats prefix).

    Cursors are primed to the sink's CURRENT row counts at construction —
    the global sink is never reset by the trainer, so without priming the
    first tick of a second run (or an in-process resume) would fold the
    previous run's entire history into the controller state."""

    def __init__(self, stats_tag: str = ""):
        from repro.obs import metrics as statslib

        self.stats_tag = stats_tag
        self._seen: Dict[str, int] = {
            tag: statslib.row_count(tag) for tag in statslib.tags()
            if tag.startswith(stats_tag)}

    def measure(self) -> Dict[str, float]:
        from repro.obs import metrics as statslib

        out: Dict[str, float] = {}
        for tag in statslib.tags():
            if not tag.startswith(self.stats_tag):
                continue
            n_seen = self._seen.get(tag, 0)
            new = statslib.rows_since(tag, n_seen)
            if len(new):
                out[tag[len(self.stats_tag):]] = float(new[:, 0].mean())
                self._seen[tag] = n_seen + len(new)
        return out


# ---------------------------------------------------------------------------
# spec-string parser (the --policy-program CLI surface)
# ---------------------------------------------------------------------------

_SPEC_DOC = """\
clauses separated by ';':
  phase@STEP=VARIANT[,KNOB=F...]
                              variant switch from STEP on (off|paper|int8|row|meprop|kernel);
                              optional per-phase knob DEFAULTS (s/k_frac/
                              row_alpha, plain floats) that rules and
                              schedules override
  s=EXPR | k_frac=EXPR | row_alpha=EXPR
                              program-wide knob (EXPR: FLOAT | lin(a,b,v0,v1)
                              | step(b0:v0,b1:v1,...))
  rule PATTERN:A[,A...]       per-layer overrides; A: off | variant=V | s=EXPR
                              | k_frac=EXPR | row_alpha=EXPR. Glob pattern when
                              it contains */?/[, substring otherwise; last
                              matching rule wins per knob.
  controller:target=F[,gain=F][,min=F][,max=F]
                              closed-loop per-layer s toward target sparsity
example:
  phase@0=off;phase@30=paper;s=lin(30,200,4.0,2.0);rule lm_head:off;rule L*.mlp.*:s=3.0
"""

_KNOB_ALIASES = {"s": "s", "k_frac": "meprop_k_frac",
                 "meprop_k_frac": "meprop_k_frac", "row_alpha": "row_alpha"}


def _split_top(text: str, sep: str) -> List[str]:
    """Split on ``sep`` outside parentheses."""
    parts, depth, cur = [], 0, []
    for ch in text:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        if ch == sep and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    parts.append("".join(cur))
    return [p for p in (p.strip() for p in parts) if p]


def _parse_expr(text: str, clause: str) -> ScheduleLike:
    text = text.strip()
    m = re.fullmatch(r"lin\(([^)]*)\)", text)
    if m:
        args = [a.strip() for a in m.group(1).split(",")]
        if len(args) != 4:
            raise ValueError(
                f"policy-program clause {clause!r}: lin() takes "
                f"(start_step, end_step, v0, v1), got {text!r}")
        return Linear(int(args[0]), int(args[1]), float(args[2]),
                      float(args[3]))
    m = re.fullmatch(r"step\(([^)]*)\)", text)
    if m:
        points = []
        for pt in m.group(1).split(","):
            if ":" not in pt:
                raise ValueError(
                    f"policy-program clause {clause!r}: step() points are "
                    f"STEP:VALUE, got {pt.strip()!r}")
            b, v = pt.split(":", 1)
            points.append((int(b.strip()), float(v.strip())))
        return Piecewise(tuple(points))
    try:
        return float(text)
    except ValueError:
        raise ValueError(
            f"policy-program clause {clause!r}: expected FLOAT, lin(...) or "
            f"step(...), got {text!r}") from None


def _parse_rule(body: str, clause: str) -> LayerRule:
    if ":" not in body:
        raise ValueError(
            f"policy-program clause {clause!r}: rule syntax is "
            f"'rule PATTERN:assign[,assign...]'")
    pattern, assigns = body.split(":", 1)
    kw: Dict[str, object] = {}
    for a in _split_top(assigns, ","):
        if a == "off":
            kw["variant"] = VARIANT_OFF
            continue
        if "=" not in a:
            raise ValueError(
                f"policy-program clause {clause!r}: bad assignment {a!r}")
        k, v = (t.strip() for t in a.split("=", 1))
        if k == "variant":
            kw["variant"] = v
        elif k in _KNOB_ALIASES:
            kw[_KNOB_ALIASES[k]] = _parse_expr(v, clause)
        else:
            raise ValueError(
                f"policy-program clause {clause!r}: unknown rule key {k!r}")
    return LayerRule(pattern=pattern.strip(), **kw)


def _parse_controller(body: str, clause: str) -> SparsityController:
    kw: Dict[str, float] = {}
    names = {"target": "target", "gain": "gain", "min": "min_scale",
             "max": "max_scale"}
    for a in _split_top(body, ","):
        if "=" not in a:
            raise ValueError(
                f"policy-program clause {clause!r}: bad assignment {a!r}")
        k, v = (t.strip() for t in a.split("=", 1))
        if k not in names:
            raise ValueError(
                f"policy-program clause {clause!r}: unknown controller key "
                f"{k!r} (one of {sorted(names)})")
        kw[names[k]] = float(v)
    if "target" not in kw:
        raise ValueError(
            f"policy-program clause {clause!r}: controller needs target=F")
    return SparsityController(**kw)


def parse_program(spec: str, base: Optional[DitherPolicy] = None
                  ) -> PolicyProgram:
    """Parse the ``--policy-program`` spec string (grammar: ``_SPEC_DOC``,
    printed verbatim in every parse error)."""
    base = base if base is not None else DitherPolicy()
    phases: List[PhaseSpec] = []
    rules: List[LayerRule] = []
    knobs: Dict[str, ScheduleLike] = {}
    controller: Optional[SparsityController] = None
    for clause in _split_top(spec, ";"):
        m = re.fullmatch(r"phase@(\d+)\s*=\s*(.+)", clause)
        if m:
            parts = _split_top(m.group(2), ",")
            kw: Dict[str, float] = {}
            for a in parts[1:]:
                if "=" not in a:
                    raise ValueError(
                        f"policy-program clause {clause!r}: phase knob "
                        f"defaults are KNOB=FLOAT, got {a!r}")
                k, v = (t.strip() for t in a.split("=", 1))
                if k not in _KNOB_ALIASES:
                    raise ValueError(
                        f"policy-program clause {clause!r}: unknown phase "
                        f"knob {k!r} (one of {sorted(_KNOB_ALIASES)})")
                kw[_KNOB_ALIASES[k]] = float(v)
            phases.append(PhaseSpec(int(m.group(1)), parts[0].strip(), **kw))
            continue
        if clause.startswith("rule "):
            rules.append(_parse_rule(clause[len("rule "):], clause))
            continue
        if clause.startswith("controller:"):
            controller = _parse_controller(clause[len("controller:"):], clause)
            continue
        if "=" in clause:
            k, v = (t.strip() for t in clause.split("=", 1))
            if k in _KNOB_ALIASES:
                knobs[_KNOB_ALIASES[k]] = _parse_expr(v, clause)
                continue
        raise ValueError(
            f"policy-program: cannot parse clause {clause!r}; grammar:\n"
            + _SPEC_DOC)
    if controller is not None and not base.collect_stats:
        base = base.replace(collect_stats=True,
                            stats_tag=base.stats_tag or "ctl/")
    return PolicyProgram(base=base, rules=tuple(rules), phases=tuple(phases),
                         controller=controller, **knobs)
