"""Core: the paper's contribution — NSD quantization + dithered backprop."""
from repro.core.nsd import (
    QuantStats,
    QuantizedGrad,
    compute_delta,
    dither_noise,
    expected_sparsity_gaussian,
    nsd_indices,
    nsd_quantize,
    nsd_quantize_int8,
    quant_stats,
)
from repro.core.policy import (
    OFF,
    VARIANT_INT8,
    VARIANT_KERNEL,
    VARIANT_MEPROP,
    VARIANT_OFF,
    VARIANT_PAPER,
    VARIANT_ROW,
    DitherCtx,
    DitherPolicy,
    StaticSpec,
    knobs_array,
)
from repro.core.schedule import (
    Const,
    LayerRule,
    Linear,
    PhaseSpec,
    Piecewise,
    PolicyProgram,
    SparsityController,
    as_program,
    parse_program,
)
from repro.core.dithered import (
    conv2d,
    dense,
    dithered_einsum,
    quantize_cotangent,
)
from repro.core import int8, meprop, probe, rowdither, schedule


def __getattr__(name):
    # `stats` is a deprecated shim over repro.obs.metrics that warns on
    # import; importing it lazily keeps `import repro.core` warning-free
    # while `from repro.core import stats` still resolves (and warns).
    if name == "stats":
        import repro.core.stats as stats
        return stats
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "QuantStats", "QuantizedGrad", "compute_delta", "dither_noise",
    "expected_sparsity_gaussian", "nsd_indices", "nsd_quantize",
    "nsd_quantize_int8", "quant_stats",
    "OFF", "VARIANT_INT8", "VARIANT_KERNEL", "VARIANT_MEPROP", "VARIANT_OFF",
    "VARIANT_PAPER", "VARIANT_ROW", "DitherCtx", "DitherPolicy", "StaticSpec",
    "knobs_array",
    "Const", "LayerRule", "Linear", "PhaseSpec", "Piecewise", "PolicyProgram",
    "SparsityController", "as_program", "parse_program",
    "conv2d", "dense", "dithered_einsum", "quantize_cotangent",
    "int8", "meprop", "probe", "rowdither", "schedule", "stats",
]
