"""Pre-activation gradient probe (the "tap" trick).

To measure the paper's Table-1 sparsity numbers we need the raw
pre-activation gradients delta_z per layer. Rather than instrumenting the
backward pass, models accept an optional ``taps`` pytree of zeros that are
*added* to each pre-activation; d(loss)/d(tap) is then exactly delta_z at
that site. This keeps measurement orthogonal to the training path.

Usage:
    taps = make_taps({"fc1": (B, 500), "fc2": (B, 500)})
    grads = grad_wrt_taps(loss_fn, params, taps, batch)
    # grads["fc1"] is delta_z of fc1
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import nsd


def make_taps(shapes: Dict[str, Tuple[int, ...]], dtype=jnp.float32):
    return {k: jnp.zeros(s, dtype) for k, s in shapes.items()}


def tap(x: jax.Array, taps, name: str) -> jax.Array:
    """Add the named tap (a zeros tensor) to a pre-activation, if present."""
    if taps is None or name not in taps:
        return x
    t = taps[name]
    return x + t.astype(x.dtype).reshape(x.shape)


def grad_wrt_taps(
    loss_fn: Callable, taps, *args, **kwargs
):
    """d(loss)/d(taps): exact per-layer pre-activation gradients."""

    def f(tp):
        return loss_fn(*args, taps=tp, **kwargs)

    return jax.grad(f)(taps)


def layer_nsd_stats(delta_z: jax.Array, key: jax.Array, s: float) -> nsd.QuantStats:
    """NSD stats that WOULD result from dithering this gradient tensor."""
    delta = nsd.compute_delta(delta_z, s)
    k = nsd.nsd_indices(delta_z, key, delta)
    return nsd.quant_stats(k, delta)


def baseline_sparsity(delta_z: jax.Array) -> jax.Array:
    """Sparsity of the raw (undithered) gradient — Table 1 'Baseline' column."""
    return 1.0 - jnp.mean((delta_z != 0).astype(jnp.float32))
