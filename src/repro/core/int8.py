"""8-bit forward-pass training, after Banner et al. 2018 (paper §3.5).

Per-tensor symmetric absmax int8 quantization of activations and weights;
the matmul itself runs int8 x int8 -> int32 (the MXU-native path on TPU) and
is rescaled on exit. Gradients flow through a straight-through estimator.
Combined with dithered backprop this reproduces the paper's
"8bit + dith. backprop" Table-1 column, and on TPU it is also the mechanism
that turns the paper's bit-width claim into real FLOP savings (DESIGN.md §4).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class QuantTensor(NamedTuple):
    q: jax.Array  # int8
    scale: jax.Array  # f32 scalar: value ~= q * scale


def absmax_scale(x: jax.Array) -> jax.Array:
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    return jnp.maximum(amax, jnp.finfo(jnp.float32).tiny) / 127.0


def _quantize_int8(x: jax.Array, key: Optional[jax.Array] = None) -> QuantTensor:
    """Absmax int8; stochastic rounding when ``key`` is given (grad-friendly)."""
    scale = absmax_scale(x)
    v = x.astype(jnp.float32) / scale
    if key is not None:
        v = v + jax.random.uniform(key, x.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(v), -127, 127).astype(jnp.int8)
    return QuantTensor(q=q, scale=scale)


def quantize_int8(x: jax.Array, key: Optional[jax.Array] = None) -> QuantTensor:
    """DEPRECATED: use :func:`repro.quant.absmax_int8` (same math).

    The canonical home moved to the quant engine (the ``int8_absmax``
    codec); this wrapper stays bit-exact via the local primitive.
    """
    import warnings

    warnings.warn(
        "repro.core.int8.quantize_int8 is deprecated; use "
        "repro.quant.absmax_int8 (bit-exact, same signature)",
        DeprecationWarning, stacklevel=2)
    return _quantize_int8(x, key)


def int8_matmul(xq: QuantTensor, wq: QuantTensor,
                out_dtype=jnp.float32) -> jax.Array:
    """(int8, int8) -> int32 accumulate -> rescale. MXU-native on TPU."""
    acc = jax.lax.dot_general(
        xq.q, wq.q,
        dimension_numbers=(((xq.q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return (acc.astype(jnp.float32) * (xq.scale * wq.scale)).astype(out_dtype)


@jax.custom_vjp
def int8_dense_ste(x: jax.Array, w: jax.Array) -> jax.Array:
    """Forward in int8, backward straight-through (exact f32 grads).

    This is the Banner-style forward; pairing it with dithered backprop on
    the *same* layer happens in ``core.dithered.dense`` which owns the bwd.
    """
    return int8_matmul(_quantize_int8(x), _quantize_int8(w), out_dtype=x.dtype)


def _fwd(x, w):
    return int8_dense_ste(x, w), (x, w)


def _bwd(res, g):
    x, w = res
    x2d = x.reshape(-1, x.shape[-1])
    g2d = g.reshape(-1, g.shape[-1])
    dx = (g2d @ w.T.astype(g2d.dtype)).reshape(x.shape)
    dw = (x2d.T @ g2d).astype(w.dtype)
    return dx.astype(x.dtype), dw


int8_dense_ste.defvjp(_fwd, _bwd)


def range_batchnorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
                    axis=0, eps: float = 1e-5) -> jax.Array:
    """Range-BN (Banner et al.): normalize by the batch *range*, not std.

    range/(sqrt(2 ln n)) is a consistent robust estimator of sigma for
    Gaussian data and is much friendlier to low-precision arithmetic.
    """
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=axis, keepdims=True)
    centered = xf - mean
    rng = jnp.max(centered, axis=axis, keepdims=True) - jnp.min(
        centered, axis=axis, keepdims=True
    )
    n = x.shape[axis] if isinstance(axis, int) else int(
        jnp.prod(jnp.array([x.shape[a] for a in axis]))
    )
    denom = rng / jnp.sqrt(2.0 * jnp.log(max(n, 2))) + eps
    return (gamma * centered / denom + beta).astype(x.dtype)
