"""Backend-aware defaults shared by every Pallas kernel wrapper.

The kernels run in two modes: ``interpret=True`` executes the kernel body
with jnp ops on the host backend (bit-exact validation anywhere), while
``interpret=False`` lowers through Mosaic and requires a real TPU. The
public wrappers take ``interpret=None`` and resolve it here — interpret
off-TPU, compiled on a TPU host — so a training run on hardware gets the
compiled kernels without every caller remembering to override, and the
CPU CI keeps exercising the interpret path (the carried-forward ROADMAP
item on compiled-mode verification; compiled-mode tests stay
``xfail(strict=False)`` as the red/green signal).
"""
from __future__ import annotations

from typing import Optional

import jax


def on_tpu() -> bool:
    """True when the default JAX backend is a TPU."""
    return jax.default_backend() == "tpu"


def default_interpret(interpret: Optional[bool]) -> bool:
    """Resolve an ``interpret=None`` kernel argument backend-aware.

    ``None`` -> interpret off-TPU, compiled on TPU; an explicit bool is
    passed through untouched (tests pin both modes explicitly).
    """
    if interpret is None:
        return not on_tpu()
    return interpret
