"""Chunk-local levels compact/expand kernels (Pallas, TPU-native layout).

The wire format (``repro.quant.wire``) stores the non-zero int8 levels of
each tensor compacted to the front in flat order. The jnp reference does
that with a full-length ``cumsum`` + scatter per encode — an O(n) serial
dependence over the whole tensor. These kernels replace the element-level
cumsum with a *chunk-local* compact: each wire chunk (256 elements) is
compacted independently inside VMEM, and the host-side assembly only
cumsums the per-chunk counts (n/256x shorter) before one scatter.

Layout follows ``repro.kernels.pack``: the tile is TRANSPOSED so the chunk
lies along the *sublane* axis (256 sublanes) and 128 chunks ride the lanes;
all data movement inside a chunk is then circular sublane rotation
(``pltpu.roll``), which Mosaic lowers natively — no gather, no minor-dim
reshape anywhere in the kernel bodies.

The compact itself is a butterfly permutation network. Each non-zero at row
``j`` must move LEFT (toward row 0) by ``rem = j - P[j]`` where ``P[j]``
counts the non-zeros in rows ``< j`` (one strictly-lower-triangular 256x256
matmul — exact in f32, counts <= 256). Eight LSB-first rounds then route
every survivor by one bit of its displacement: in round ``b`` the elements
whose remaining displacement has bit ``b`` set hop ``2^b`` rows up. This is
collision-free: after rounds ``< b`` every remaining displacement is a
multiple of ``2^b``, displacements are non-decreasing in ``j`` (ranks
``j - rem`` are strictly increasing and rounds preserve element order), so
a stayer and a hopper meeting at one row would need two elements with the
same final rank — impossible.

``expand`` is the inverse: the per-slot rightward displacement ``r[i]``
(distance from compacted slot ``i`` to the row of the i-th set mask bit)
is itself obtained by forward-compacting the displacement field, then eight
MSB-first rounds route the levels RIGHT. MSB-first is load-bearing —
rightward LSB-first can collide (mask 0101 routes both slots through row 1
in round 0); descending bit order keeps intermediate targets distinct.

Both kernels are bit-exact vs ``repro.quant.wire._compact``/``_expand``
composition in interpret mode for every shape, including all-zero and
all-nonzero chunks (tests/test_levels_kernel.py); compiled mode stays
``xfail(strict=False)`` pending a real-TPU host like the other kernels.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import default_interpret

CHUNK = 256  # the one supported chunk length (== wire DEFAULT_CHUNK)


def _prefix_counts(occ: jax.Array) -> jax.Array:
    """P[j, c] = number of occupied rows < j in column c (int32, exact).

    One (L, L) @ (L, bm) strictly-lower-triangular matmul on the MXU; f32
    accumulation is exact for counts <= 256.
    """
    L = occ.shape[0]
    j = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    i = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    sl = (i < j).astype(jnp.float32)
    p = jax.lax.dot_general(sl, occ.astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return p.astype(jnp.int32)


def _route_left(cur: jax.Array, rem: jax.Array, act: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """Move active elements UP by their displacement, LSB-first.

    ``cur``/``rem``/``act``: (L, bm) int32 values / remaining displacement /
    0-1 activity. Returns (routed values, final activity); inactive rows 0.
    """
    L = cur.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, cur.shape, 0)
    for b in range(8):
        sh = 1 << b
        cur_s = pltpu.roll(cur, L - sh, 0)  # cur_s[j] = cur[j + sh (mod L)]
        rem_s = pltpu.roll(rem, L - sh, 0)
        act_s = pltpu.roll(act, L - sh, 0)
        take = (act_s == 1) & ((rem_s & sh) != 0) & (rows < L - sh)
        keep = (act == 1) & ((rem & sh) == 0)
        cur = jnp.where(take, cur_s, jnp.where(keep, cur, 0))
        rem = jnp.where(take, rem_s - sh, rem)
        act = (take | keep).astype(jnp.int32)
    return cur, act


def _route_right(cur: jax.Array, rem: jax.Array, act: jax.Array
                 ) -> jax.Array:
    """Move active elements DOWN by their displacement, MSB-first."""
    L = cur.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, cur.shape, 0)
    for b in reversed(range(8)):
        sh = 1 << b
        cur_s = pltpu.roll(cur, sh, 0)  # cur_s[j] = cur[j - sh (mod L)]
        rem_s = pltpu.roll(rem, sh, 0)
        act_s = pltpu.roll(act, sh, 0)
        take = (act_s == 1) & ((rem_s & sh) != 0) & (rows >= sh)
        keep = (act == 1) & ((rem & sh) == 0)
        cur = jnp.where(take, cur_s, jnp.where(keep, cur, 0))
        rem = jnp.where(take, rem_s - sh, rem)
        act = (take | keep).astype(jnp.int32)
    return cur


def _compact_kernel(kt_ref, out_ref, cnt_ref):
    kt = kt_ref[...]  # (L, bm) int8: one chunk per lane column
    cur = kt.astype(jnp.int32)
    occ = (cur != 0).astype(jnp.int32)
    p = _prefix_counts(occ)
    rows = jax.lax.broadcasted_iota(jnp.int32, cur.shape, 0)
    routed, _ = _route_left(cur, rows - p, occ)
    out_ref[...] = routed.astype(jnp.int8)
    cnt_ref[...] = jnp.sum(occ, axis=0, keepdims=True)


def _expand_kernel(lv_ref, m_ref, out_ref):
    lv = lv_ref[...].astype(jnp.int32)  # (L, bm) chunk-local compacted
    occ = (m_ref[...] != 0).astype(jnp.int32)  # occupancy mask
    L = lv.shape[0]
    p = _prefix_counts(occ)
    rows = jax.lax.broadcasted_iota(jnp.int32, lv.shape, 0)
    cnt = jnp.sum(occ, axis=0, keepdims=True)  # (1, bm)
    # per-slot rightward displacement = forward-compact of the displacement
    # field d[j] = j - P[j] (# empty rows before the j-th row)
    d = rows - p
    r, _ = _route_left(d, d, occ)
    slot_act = (rows < cnt).astype(jnp.int32)
    routed = _route_right(lv, r, slot_act)
    out_ref[...] = (routed * occ).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def levels_compact_blocked(kt: jax.Array, *, bm: int = 128,
                           interpret: Optional[bool] = None
                           ) -> Tuple[jax.Array, jax.Array]:
    """Column-local stable compaction of (CHUNK, C) int8 chunk columns.

    Returns ``(compacted (CHUNK, C) int8, counts (C,) int32)``: column c of
    the output holds that chunk's non-zeros moved to the front in order,
    zero-padded; ``counts[c]`` is its non-zero count. C is padded to a
    multiple of ``bm`` internally (zero columns compact to zero).
    """
    interpret = default_interpret(interpret)
    L, C = kt.shape
    assert L == CHUNK, (kt.shape, CHUNK)
    pad = (-C) % bm
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, pad)))
    Cp = C + pad
    out, cnt = pl.pallas_call(
        _compact_kernel,
        grid=(Cp // bm,),
        in_specs=[pl.BlockSpec((L, bm), lambda c: (0, c))],
        out_specs=[pl.BlockSpec((L, bm), lambda c: (0, c)),
                   pl.BlockSpec((1, bm), lambda c: (0, c))],
        out_shape=[jax.ShapeDtypeStruct((L, Cp), jnp.int8),
                   jax.ShapeDtypeStruct((1, Cp), jnp.int32)],
        interpret=interpret,
    )(kt)
    return out[:, :C], cnt[0, :C]


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def levels_expand_blocked(lv: jax.Array, mask: jax.Array, *, bm: int = 128,
                          interpret: Optional[bool] = None) -> jax.Array:
    """Inverse of :func:`levels_compact_blocked` given the occupancy mask.

    ``lv``: (CHUNK, C) int8 column-local compacted levels; ``mask``:
    (CHUNK, C) int8/bool occupancy. Returns (CHUNK, C) int8 with each
    column's levels scattered back to its mask positions.
    """
    interpret = default_interpret(interpret)
    L, C = lv.shape
    assert L == CHUNK and mask.shape == lv.shape, (lv.shape, mask.shape)
    pad = (-C) % bm
    if pad:
        lv = jnp.pad(lv, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    Cp = C + pad
    out = pl.pallas_call(
        _expand_kernel,
        grid=(Cp // bm,),
        in_specs=[pl.BlockSpec((L, bm), lambda c: (0, c)),
                  pl.BlockSpec((L, bm), lambda c: (0, c))],
        out_specs=pl.BlockSpec((L, bm), lambda c: (0, c)),
        out_shape=jax.ShapeDtypeStruct((L, Cp), jnp.int8),
        interpret=interpret,
    )(lv, mask.astype(jnp.int8))
    return out[:, :C]
