"""Pure-jnp oracle for the chunk-local levels compact/expand kernels."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compact_columns_ref(kt: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Column-local stable compaction via per-column cumsum (the oracle)."""
    L = kt.shape[0]

    def one(col):
        nz = col != 0
        pos = jnp.cumsum(nz.astype(jnp.int32)) - 1
        tgt = jnp.where(nz, pos, L)
        out = jnp.zeros((L,), jnp.int8).at[tgt].set(col, mode="drop")
        return out, jnp.sum(nz.astype(jnp.int32))

    out, cnt = jax.vmap(one, in_axes=1, out_axes=(1, 0))(kt)
    return out, cnt


def expand_columns_ref(lv: jax.Array, mask: jax.Array) -> jax.Array:
    """Column-local inverse of :func:`compact_columns_ref`."""

    def one(col, m):
        m = m != 0
        pos = jnp.cumsum(m.astype(jnp.int32)) - 1
        return jnp.where(m, col[jnp.clip(pos, 0, None)],
                         jnp.zeros((), jnp.int8))

    return jax.vmap(one, in_axes=1, out_axes=1)(lv, mask)
