"""Pure-jnp oracle for the fused NSD quantization kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def nsd_quantize_blocked_ref(x: jax.Array, noise: jax.Array,
                             delta: jax.Array, *, bm: int = 128,
                             bn: int = 512):
    """Exact reference semantics of kernels.nsd_quant.nsd_quantize_blocked."""
    M, N = x.shape
    xf = x.astype(jnp.float32)
    nu = noise.astype(jnp.float32)
    d = delta.astype(jnp.float32)
    safe = jnp.maximum(d, jnp.finfo(jnp.float32).tiny)
    k = jnp.floor((xf + nu) / safe + 0.5)
    k = jnp.clip(k, -127.0, 127.0)
    k = jnp.where(d > 0.0, k, jnp.zeros_like(k)).astype(jnp.int8)
    tiles = (k != 0).astype(jnp.int32).reshape(M // bm, bm, N // bn, bn)
    nnz = jnp.sum(tiles, axis=(1, 3))
    return k, nnz
