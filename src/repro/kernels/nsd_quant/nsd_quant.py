"""Fused NSD quantization kernel (Pallas, TPU target, interpret-validated).

Per (bm, bn) VMEM tile of the pre-activation gradient:
    k     = clip(floor((x + nu)/Delta + 1/2), -127, 127)  as int8
    nnz   = number of non-zeros in the tile                (int32)
so a single pass over HBM produces both the int8 payload for the backward
matmuls and the tile-occupancy map the block-sparse matmul kernel uses for
tile skipping. Delta (= s * std, a per-tensor scalar) and the dither noise
are computed outside (std is a global reduction; noise comes from the
framework RNG so the kernel stays deterministic given its inputs).

Tiles are (8m, 128)-aligned: the VPU lane width is 128 and sublane 8, so
bm in {8,16,32,...}, bn multiple of 128.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import default_interpret


def _nsd_kernel(x_ref, noise_ref, delta_ref, k_ref, nnz_ref):
    x = x_ref[...].astype(jnp.float32)
    nu = noise_ref[...].astype(jnp.float32)
    delta = delta_ref[0, 0]
    safe = jnp.maximum(delta, jnp.finfo(jnp.float32).tiny)
    k = jnp.floor((x + nu) / safe + 0.5)
    k = jnp.clip(k, -127.0, 127.0)
    k = jnp.where(delta > 0.0, k, jnp.zeros_like(k)).astype(jnp.int32)
    k_ref[...] = k.astype(jnp.int8)
    nnz_ref[0, 0] = jnp.sum((k != 0).astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def nsd_quantize_blocked(x: jax.Array, noise: jax.Array, delta: jax.Array,
                         *, bm: int = 128, bn: int = 512,
                         interpret: Optional[bool] = None):
    """x, noise: (M, N) with M % bm == 0, N % bn == 0; delta: scalar f32.

    Returns (k int8 (M, N), nnz int32 (M//bm, N//bn)).
    """
    interpret = default_interpret(interpret)
    M, N = x.shape
    assert M % bm == 0 and N % bn == 0, (x.shape, bm, bn)
    grid = (M // bm, N // bn)
    delta2d = jnp.reshape(delta.astype(jnp.float32), (1, 1))
    k, nnz = pl.pallas_call(
        _nsd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), jnp.int8),
            jax.ShapeDtypeStruct((M // bm, N // bn), jnp.int32),
        ],
        interpret=interpret,
    )(x, noise, delta2d)
    return k, nnz
