"""Pure-jnp oracles for the block-sparse quantized matmul kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _apply_tile_mask(a: jax.Array, mask: jax.Array, bm: int, bk: int
                     ) -> jax.Array:
    """Zero out the tiles the kernel would skip (mask semantics oracle)."""
    M, K = a.shape
    m = jnp.repeat(jnp.repeat(mask != 0, bm, axis=0), bk, axis=1)
    return jnp.where(m, a, jnp.zeros_like(a))


def bsp_matmul_ref(k_q: jax.Array, delta: jax.Array, b: jax.Array,
                   mask: jax.Array, *, bm: int = 128, bk: int = 128,
                   bn: int = 128, out_dtype=jnp.float32) -> jax.Array:
    a = _apply_tile_mask(k_q.astype(jnp.float32), mask, bm, bk)
    out = (a * delta.astype(jnp.float32)) @ b.astype(jnp.float32)
    return out.astype(out_dtype)


def bsp_matmul_int8_ref(k_q: jax.Array, b_q: jax.Array, scale: jax.Array,
                        mask: jax.Array, *, bm: int = 128, bk: int = 128,
                        bn: int = 128, out_dtype=jnp.float32) -> jax.Array:
    a = _apply_tile_mask(k_q.astype(jnp.int32), mask, bm, bk)
    acc = jax.lax.dot_general(
        a, b_q.astype(jnp.int32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * scale.astype(jnp.float32)).astype(
        out_dtype)
