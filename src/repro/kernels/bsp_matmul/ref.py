"""Pure-jnp oracles for the block-sparse quantized matmul kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _apply_tile_mask(a: jax.Array, mask: jax.Array, bm: int, bk: int
                     ) -> jax.Array:
    """Zero out the tiles the kernel would skip (mask semantics oracle)."""
    M, K = a.shape
    m = jnp.repeat(jnp.repeat(mask != 0, bm, axis=0), bk, axis=1)
    return jnp.where(m, a, jnp.zeros_like(a))


def bsp_matmul_ref(k_q: jax.Array, delta: jax.Array, b: jax.Array,
                   mask: jax.Array, *, bm: int = 128, bk: int = 128,
                   bn: int = 128, out_dtype=jnp.float32) -> jax.Array:
    a = _apply_tile_mask(k_q.astype(jnp.float32), mask, bm, bk)
    out = (a * delta.astype(jnp.float32)) @ b.astype(jnp.float32)
    return out.astype(out_dtype)


def bsp_matmul_int8_ref(k_q: jax.Array, b_q: jax.Array, scale: jax.Array,
                        mask: jax.Array, *, bm: int = 128, bk: int = 128,
                        bn: int = 128, out_dtype=jnp.float32) -> jax.Array:
    a = _apply_tile_mask(k_q.astype(jnp.int32), mask, bm, bk)
    acc = jax.lax.dot_general(
        a, b_q.astype(jnp.int32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * scale.astype(jnp.float32)).astype(
        out_dtype)


def bsp_matmul_blocked_ref(k_q: jax.Array, delta: jax.Array, b: jax.Array,
                           mask: jax.Array, *, bm: int = 128, bk: int = 128,
                           bn: int = 128, out_dtype=jnp.float32) -> jax.Array:
    """f32 oracle that mirrors the kernel's *accumulation order* exactly.

    ``bsp_matmul_ref`` is the semantics oracle (one big masked matmul);
    floating-point addition is not associative, so it can differ from the
    kernel in the last ulp. This ref sums per-K-tile partial dots in the
    same order as the kernel's k-loop and multiplies delta once on exit,
    so interpret-mode ``bsp_matmul`` output is BIT-EXACT against it — the
    zero-band invariant the density-curve bench gates on. (The int8 kernel
    needs no blocked ref: int32 accumulation is exact in any order.)
    """
    M, K = k_q.shape
    _, N = b.shape
    af = k_q.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    d = delta.astype(jnp.float32)
    rows = []
    for it in range(M // bm):
        row = []
        for jt in range(N // bn):
            acc = jnp.zeros((bm, bn), jnp.float32)
            for kt in range(K // bk):
                part = jnp.dot(af[it * bm:(it + 1) * bm,
                                  kt * bk:(kt + 1) * bk],
                               bf[kt * bk:(kt + 1) * bk,
                                  jt * bn:(jt + 1) * bn],
                               preferred_element_type=jnp.float32)
                acc = acc + jnp.where(mask[it, kt] != 0, part, 0.0)
            row.append((acc * d).astype(out_dtype))
        rows.append(jnp.concatenate(row, axis=1))
    return jnp.concatenate(rows, axis=0)
