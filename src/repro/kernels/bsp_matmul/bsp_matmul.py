"""Block-sparse quantized matmul kernels (Pallas, TPU target).

TPU adaptation of the paper's sparse backward products (DESIGN.md §4):
element-granular sparsity cannot skip MACs on a 128x128 systolic MXU, so we
skip at *tile* granularity. The NSD kernel emits a (M/bm, K/bk) tile-
occupancy map; here, the k-loop body is wrapped in ``pl.when(mask != 0)`` so
fully-zero tiles of the quantized gradient contribute neither MXU issue
cycles nor (with the index-map trick below) HBM->VMEM traffic for the B
operand — the win that unstructured sparsity alone cannot deliver on TPU.

Two variants:
  * ``bsp_matmul``      — A is (int8 k, Delta) NSD output, B stays bf16/f32;
                          A is dequantized in VMEM before the dot.
  * ``bsp_matmul_int8`` — both operands int8, int32 MXU accumulation,
                          rescale on exit: the paper's "8bit + dithered"
                          column mapped onto the 2x-throughput int8 MXU path.

The mask rides in scalar-prefetch SMEM (PrefetchScalarGridSpec) so it is
available to the grid index maps *before* tiles are fetched.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bsp_kernel_dequant(mask_ref, a_ref, b_ref, delta_ref, o_ref, acc_ref):
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mask_ref[i, k] != 0)
    def _accum():
        a = a_ref[...].astype(jnp.float32)
        b = b_ref[...].astype(jnp.float32)
        acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] * delta_ref[0, 0]).astype(o_ref.dtype)


def _bsp_kernel_int8(mask_ref, a_ref, b_ref, scale_ref, o_ref, acc_ref):
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mask_ref[i, k] != 0)
    def _accum():
        # int8 x int8 -> int32: the MXU-native 2x-throughput path on v5e
        acc_ref[...] += jax.lax.dot_general(
            a_ref[...], b_ref[...],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _finish():
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * scale_ref[0, 0]).astype(o_ref.dtype)


def _grid_spec(M, K, N, bm, bk, bn, acc_dtype):
    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k, mask: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k, mask: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k, mask: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, mask: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
    )


@functools.partial(jax.jit,
                   static_argnames=("bm", "bk", "bn", "out_dtype",
                                    "interpret"))
def bsp_matmul(k_q: jax.Array, delta: jax.Array, b: jax.Array,
               mask: jax.Array, *, bm: int = 128, bk: int = 128,
               bn: int = 128, out_dtype=jnp.float32,
               interpret: bool = True) -> jax.Array:
    """(dequant(k_q) @ b) with tile skipping.

    k_q: (M, K) int8 NSD indices; delta: scalar; b: (K, N) f32/bf16;
    mask: (M//bm, K//bk) int32 tile-occupancy (0 = all-zero tile).
    """
    M, K = k_q.shape
    K2, N = b.shape
    assert K == K2 and M % bm == 0 and K % bk == 0 and N % bn == 0
    delta2d = jnp.reshape(delta.astype(jnp.float32), (1, 1))
    return pl.pallas_call(
        _bsp_kernel_dequant,
        grid_spec=_grid_spec(M, K, N, bm, bk, bn, jnp.float32),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        interpret=interpret,
    )(mask.astype(jnp.int32), k_q, b, delta2d)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bk", "bn", "out_dtype",
                                    "interpret"))
def bsp_matmul_int8(k_q: jax.Array, b_q: jax.Array, scale: jax.Array,
                    mask: jax.Array, *, bm: int = 128, bk: int = 128,
                    bn: int = 128, out_dtype=jnp.float32,
                    interpret: bool = True) -> jax.Array:
    """Full int8 MXU path: (k_q @ b_q) * scale with tile skipping.

    scale = delta_A * scale_B (per-tensor product of the two quant scales).
    """
    M, K = k_q.shape
    K2, N = b_q.shape
    assert K == K2 and M % bm == 0 and K % bk == 0 and N % bn == 0
    scale2d = jnp.reshape(scale.astype(jnp.float32), (1, 1))
    return pl.pallas_call(
        _bsp_kernel_int8,
        grid_spec=_grid_spec(M, K, N, bm, bk, bn, jnp.int32),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        interpret=interpret,
    )(mask.astype(jnp.int32), k_q, b_q, scale2d)
