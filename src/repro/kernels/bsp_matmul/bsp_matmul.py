"""Block-sparse quantized matmul kernels (Pallas, TPU target).

TPU adaptation of the paper's sparse backward products (DESIGN.md §4):
element-granular sparsity cannot skip MACs on a 128x128 systolic MXU, so we
skip at *tile* granularity. The NSD kernel emits a (M/bm, K/bk) tile-
occupancy map; here, the k-loop body is wrapped in ``pl.when(mask != 0)`` so
fully-zero tiles of the quantized gradient contribute no MXU issue cycles.

HBM->VMEM traffic is skipped through the *fetch map*: alongside the mask,
the wrappers prefetch ``fetch[i, k] = index of the last occupied K-tile at
or before k in row i`` (clamped to 0 when none). The A/B block index maps
return ``fetch[i, k]`` instead of ``k``, so every masked grid step re-names
the block it already holds — Pallas only issues a copy when the block index
*changes*, which means a masked tile costs neither MXU cycles nor operand
DMA for A or B. This is the win that unstructured sparsity alone cannot
deliver on TPU; the worst case is one redundant fetch per row when a row's
leading tiles are all masked (fetch clamps to 0).

Two variants:
  * ``bsp_matmul``      — A is (int8 k, Delta) NSD output, B stays bf16/f32;
                          A is dequantized in VMEM before the dot.
  * ``bsp_matmul_int8`` — both operands int8, int32 MXU accumulation,
                          rescale on exit: the paper's "8bit + dithered"
                          column mapped onto the 2x-throughput int8 MXU path.

The mask and fetch map ride in scalar-prefetch SMEM
(PrefetchScalarGridSpec) so they are available to the grid index maps
*before* tiles are fetched. ``interpret=None`` resolves backend-aware
(interpret off-TPU, compiled on TPU — ``repro.kernels.backend``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import default_interpret


def _bsp_kernel_dequant(mask_ref, fetch_ref, a_ref, b_ref, delta_ref, o_ref,
                        acc_ref):
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mask_ref[i, k] != 0)
    def _accum():
        a = a_ref[...].astype(jnp.float32)
        b = b_ref[...].astype(jnp.float32)
        acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] * delta_ref[0, 0]).astype(o_ref.dtype)


def _bsp_kernel_int8(mask_ref, fetch_ref, a_ref, b_ref, scale_ref, o_ref,
                     acc_ref):
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mask_ref[i, k] != 0)
    def _accum():
        # int8 x int8 -> int32: the MXU-native 2x-throughput path on v5e
        acc_ref[...] += jax.lax.dot_general(
            a_ref[...], b_ref[...],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _finish():
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * scale_ref[0, 0]).astype(o_ref.dtype)


def fetch_map(mask: jax.Array) -> jax.Array:
    """``fetch[i, k]`` = last occupied K-tile index <= k in row i (else 0).

    When ``mask[i, k] == 0`` the fetch index equals the previous step's, so
    the block index maps below re-name the resident block and Pallas skips
    the HBM->VMEM copy entirely.
    """
    kt = mask.shape[1]
    idx = jnp.where(mask != 0, jnp.arange(kt, dtype=jnp.int32)[None, :], -1)
    return jnp.maximum(jax.lax.cummax(idx, axis=1), 0).astype(jnp.int32)


def _grid_spec(M, K, N, bm, bk, bn, acc_dtype):
    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            # masked steps return fetch[i, k] == the previous occupied
            # index: same block index -> no new operand DMA
            pl.BlockSpec((bm, bk), lambda i, j, k, mask, fetch: (i, fetch[i, k])),
            pl.BlockSpec((bk, bn), lambda i, j, k, mask, fetch: (fetch[i, k], j)),
            pl.BlockSpec((1, 1), lambda i, j, k, mask, fetch: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, mask, fetch: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
    )


@functools.partial(jax.jit,
                   static_argnames=("bm", "bk", "bn", "out_dtype",
                                    "interpret"))
def bsp_matmul(k_q: jax.Array, delta: jax.Array, b: jax.Array,
               mask: jax.Array, *, bm: int = 128, bk: int = 128,
               bn: int = 128, out_dtype=jnp.float32,
               interpret: Optional[bool] = None) -> jax.Array:
    """(dequant(k_q) @ b) with tile skipping (compute AND operand fetch).

    k_q: (M, K) int8 NSD indices; delta: scalar; b: (K, N) f32/bf16;
    mask: (M//bm, K//bk) int32 tile-occupancy (0 = all-zero tile).
    """
    interpret = default_interpret(interpret)
    M, K = k_q.shape
    K2, N = b.shape
    assert K == K2 and M % bm == 0 and K % bk == 0 and N % bn == 0
    delta2d = jnp.reshape(delta.astype(jnp.float32), (1, 1))
    mask = mask.astype(jnp.int32)
    return pl.pallas_call(
        _bsp_kernel_dequant,
        grid_spec=_grid_spec(M, K, N, bm, bk, bn, jnp.float32),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        interpret=interpret,
    )(mask, fetch_map(mask), k_q, b, delta2d)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bk", "bn", "out_dtype",
                                    "interpret"))
def bsp_matmul_int8(k_q: jax.Array, b_q: jax.Array, scale: jax.Array,
                    mask: jax.Array, *, bm: int = 128, bk: int = 128,
                    bn: int = 128, out_dtype=jnp.float32,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Full int8 MXU path: (k_q @ b_q) * scale with tile skipping.

    scale = delta_A * scale_B (per-tensor product of the two quant scales).
    """
    interpret = default_interpret(interpret)
    M, K = k_q.shape
    K2, N = b_q.shape
    assert K == K2 and M % bm == 0 and K % bk == 0 and N % bn == 0
    scale2d = jnp.reshape(scale.astype(jnp.float32), (1, 1))
    mask = mask.astype(jnp.int32)
    return pl.pallas_call(
        _bsp_kernel_int8,
        grid_spec=_grid_spec(M, K, N, bm, bk, bn, jnp.int32),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        interpret=interpret,
    )(mask, fetch_map(mask), k_q, b_q, scale2d)
