"""Pure-jnp oracles for the bitmap pack/unpack kernels.

Semantics are shared with ``repro.quant.wire.pack_bitmap`` /
``unpack_bitmap`` (the wire-format reference); these wrappers only add the
blocked nnz map so kernel outputs compare exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.wire import pack_bitmap, unpack_bitmap


def bitmap_pack_blocked_ref(k: jax.Array, *, bm: int = 128, bn: int = 128):
    """Exact reference semantics of kernels.pack.bitmap_pack_blocked."""
    M, N = k.shape
    bitmap = pack_bitmap(k.reshape(M, N))
    tiles = (k != 0).astype(jnp.int32).reshape(M // bm, bm, N // bn, bn)
    nnz = jnp.sum(tiles, axis=(1, 3))
    return bitmap, nnz


def bitmap_unpack_blocked_ref(bitmap: jax.Array, *, bm: int = 128,
                              bn: int = 128) -> jax.Array:
    """Exact reference semantics of kernels.pack.bitmap_unpack_blocked."""
    return unpack_bitmap(bitmap).astype(jnp.int8)
