"""Occupancy-bitmap pack/unpack kernels (Pallas, TPU-native layout).

The wire format of ``repro.comm.wireformat`` sends one occupancy bit per
gradient element plus the non-zero int8 levels. Producing that bitmap is a
pure bandwidth problem — one pass over the int8 index tensor the fused NSD
kernel already emits — so it belongs in the same kernel family:

    pack:   per (bm, bn) VMEM tile of int8 k ->
                bitmap tile (bm, bn/8) uint8 (LSB-first within each byte)
                nnz       (int32)  per-tile non-zero count (wire accounting)
    unpack: bitmap tile -> int8 0/1 occupancy mask tile (bm, bn)

Bit order matches ``wireformat.pack_bitmap`` (bit j of byte b is element
8*b + j of the row).

Layout: Mosaic cannot lower a reshape that regroups the minor (lane)
dimension, which is what the obvious ``(bm, bn) -> (bm, bn/8, 8)`` byte
gather needs. The kernels therefore run on the TRANSPOSED tile so the 8
elements of each wire byte lie along the *sublane* dimension, where
grouping is free:

    1. weight each sublane's occupancy bit by its position in the byte
       (``bit << (sublane & 7)``),
    2. OR-reduce runs of 8 sublanes with a log-tree of circular sublane
       rotates (``pltpu.roll`` by bn-1, bn-2, bn-4), after which every
       sublane s ≡ 0 (mod 8) holds the finished byte for elements s..s+7,
    3. select those sublanes via the lane-preserving reshape
       ``(bn, bm) -> (bn/8, 8, bm)`` and a sublane index — physically a
       no-op regrouping Mosaic lowers directly.

The host-side wrappers feed the kernel ``k.T`` and transpose the bitmap
back, so the public API (shapes, bit order, nnz map) is unchanged; the
transposes are plain XLA ops outside ``pallas_call``. No reshape anywhere
in the kernel bodies touches the minor dimension —
``tests/test_pack_layout.py`` asserts that on the traced jaxpr. Tiles are
(8m, 128)-aligned as for the other kernels; bn must additionally be a
multiple of 8 (always true for 128-lane tiles).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import default_interpret


def _pack_kernel(kt_ref, bitmap_ref, nnz_ref):
    kt = kt_ref[...]  # (bn, bm): transposed tile, wire bytes along sublanes
    bn, bm = kt.shape
    bits = (kt != 0).astype(jnp.int32)
    sub = jax.lax.broadcasted_iota(jnp.int32, (bn, bm), 0)
    acc = bits << (sub & 7)  # bit weight 2^(s mod 8) per sublane
    # OR-tree over runs of 8 sublanes; rolls are circular and the wrap
    # never crosses a byte boundary at the s % 8 == 0 sublanes we keep.
    acc = acc | pltpu.roll(acc, bn - 1, 0)
    acc = acc | pltpu.roll(acc, bn - 2, 0)
    acc = acc | pltpu.roll(acc, bn - 4, 0)
    bitmap_ref[...] = acc.reshape(bn // 8, 8, bm)[:, 0, :].astype(jnp.uint8)
    nnz_ref[0, 0] = jnp.sum(bits)


def _unpack_kernel(bitmap_ref, mask_ref):
    bt = bitmap_ref[...].astype(jnp.int32)  # (bn/8, bm): transposed bitmap
    bnb, bm = bt.shape
    # replicate each byte across its 8 target sublanes (lane-preserving
    # broadcast + collapse), then select each sublane's bit
    rep = jnp.broadcast_to(bt[:, None, :], (bnb, 8, bm)).reshape(bnb * 8, bm)
    sub = jax.lax.broadcasted_iota(jnp.int32, (bnb * 8, bm), 0)
    mask_ref[...] = ((rep >> (sub & 7)) & 1).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def bitmap_pack_blocked(k: jax.Array, *, bm: int = 128, bn: int = 128,
                        interpret: Optional[bool] = None):
    """k: (M, N) int8 with M % bm == 0, N % bn == 0, bn % 8 == 0.

    Returns (bitmap uint8 (M, N//8), nnz int32 (M//bm, N//bn)).
    """
    interpret = default_interpret(interpret)
    M, N = k.shape
    assert M % bm == 0 and N % bn == 0 and bn % 8 == 0, (k.shape, bm, bn)
    grid = (N // bn, M // bm)
    bitmap_t, nnz = pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bn, bm), lambda j, i: (j, i))],
        out_specs=[
            pl.BlockSpec((bn // 8, bm), lambda j, i: (j, i)),
            pl.BlockSpec((1, 1), lambda j, i: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N // 8, M), jnp.uint8),
            jax.ShapeDtypeStruct((M // bm, N // bn), jnp.int32),
        ],
        interpret=interpret,
    )(k.T)
    return bitmap_t.T, nnz


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def bitmap_unpack_blocked(bitmap: jax.Array, *, bm: int = 128, bn: int = 128,
                          interpret: Optional[bool] = None) -> jax.Array:
    """bitmap: (M, N//8) uint8 -> int8 0/1 occupancy mask (M, N)."""
    interpret = default_interpret(interpret)
    M, NB = bitmap.shape
    N = NB * 8
    assert M % bm == 0 and N % bn == 0 and bn % 8 == 0, (bitmap.shape, bm, bn)
    grid = (N // bn, M // bm)
    mask_t = pl.pallas_call(
        _unpack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bn // 8, bm), lambda j, i: (j, i))],
        out_specs=pl.BlockSpec((bn, bm), lambda j, i: (j, i)),
        out_shape=jax.ShapeDtypeStruct((N, M), jnp.int8),
        interpret=interpret,
    )(bitmap.T)
    return mask_t.T
