"""Occupancy-bitmap pack/unpack kernels (Pallas, TPU target, interpret-validated).

The wire format of ``repro.comm.wireformat`` sends one occupancy bit per
gradient element plus the non-zero int8 levels. Producing that bitmap is a
pure bandwidth problem — one pass over the int8 index tensor the fused NSD
kernel already emits — so it belongs in the same kernel family:

    pack:   per (bm, bn) VMEM tile of int8 k ->
                bitmap tile (bm, bn/8) uint8 (LSB-first within each byte)
                nnz       (int32)  per-tile non-zero count (wire accounting)
    unpack: bitmap tile -> int8 0/1 occupancy mask tile (bm, bn)

Bit order matches ``wireformat.pack_bitmap`` (bit j of byte b is element
8*b + j of the row). The lane-dimension reshape used to gather 8 lanes per
byte compiles on the interpret path only; the TPU-native layout (sublane
rotate + OR-reduce) is a ROADMAP follow-up. Tiles are (8m, 128)-aligned as
for the other kernels; bn must additionally be a multiple of 8 (always true
for 128-lane tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

def _pack_kernel(k_ref, bitmap_ref, nnz_ref):
    k = k_ref[...]
    bm, bn = k.shape
    bits = (k != 0).astype(jnp.int32)
    b8 = bits.reshape(bm, bn // 8, 8)
    # bit weights 1,2,4,... via iota (a captured constant would not lower)
    shifts = jax.lax.broadcasted_iota(jnp.int32, (bm, bn // 8, 8), 2)
    bitmap_ref[...] = jnp.sum(b8 << shifts, axis=-1).astype(jnp.uint8)
    nnz_ref[0, 0] = jnp.sum(bits)


def _unpack_kernel(bitmap_ref, mask_ref):
    b = bitmap_ref[...].astype(jnp.int32)
    bm, bnb = b.shape
    shifts = jax.lax.broadcasted_iota(jnp.int32, (bm, bnb, 8), 2)
    bits = (b[:, :, None] >> shifts) & 1
    mask_ref[...] = bits.reshape(bm, bnb * 8).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def bitmap_pack_blocked(k: jax.Array, *, bm: int = 128, bn: int = 128,
                        interpret: bool = True):
    """k: (M, N) int8 with M % bm == 0, N % bn == 0, bn % 8 == 0.

    Returns (bitmap uint8 (M, N//8), nnz int32 (M//bm, N//bn)).
    """
    M, N = k.shape
    assert M % bm == 0 and N % bn == 0 and bn % 8 == 0, (k.shape, bm, bn)
    grid = (M // bm, N // bn)
    bitmap, nnz = pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bn // 8), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N // 8), jnp.uint8),
            jax.ShapeDtypeStruct((M // bm, N // bn), jnp.int32),
        ],
        interpret=interpret,
    )(k)
    return bitmap, nnz


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def bitmap_unpack_blocked(bitmap: jax.Array, *, bm: int = 128, bn: int = 128,
                          interpret: bool = True) -> jax.Array:
    """bitmap: (M, N//8) uint8 -> int8 0/1 occupancy mask (M, N)."""
    M, NB = bitmap.shape
    N = NB * 8
    assert M % bm == 0 and N % bn == 0 and bn % 8 == 0, (bitmap.shape, bm, bn)
    grid = (M // bm, N // bn)
    return pl.pallas_call(
        _unpack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn // 8), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int8),
        interpret=interpret,
    )(bitmap)
