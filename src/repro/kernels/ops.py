"""High-level jit'd wrappers over the Pallas kernels.

``dithered_backward_matmuls`` is the full TPU-native backward pass of one
dense layer (DESIGN.md §4): one fused NSD pass over the pre-activation
gradient, then both backward products as tile-skipping quantized matmuls.
The pipeline shares ONE occupancy representation with the wire format and
the residual store:

    fused NSD kernel  ->  int8 k + per-tile nnz map      (no second pass)
    pack kernel       ->  uint8 occupancy bitmap          (wire layout)
    tile mask         ->  popcount-style reduction of the BITMAP
                          (repro.comm.wireformat.tile_mask_from_bitmap) —
                          never a dense recompute over the int8 tensor

Non-128-aligned layers are zero-padded to tile multiples: padded elements
quantize to k == 0, so the padding tiles read 0 in the mask and are skipped
for free (no silent dense fallback remains — structural fallbacks that do
survive, e.g. unsupported einsum forms, are counted in
``KERNEL_FALLBACKS``). ``interpret=None`` resolves backend-aware: interpret
off-TPU, compiled on TPU (``repro.kernels.backend``).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import nsd
from repro.kernels.backend import default_interpret
from repro.kernels.bsp_matmul.bsp_matmul import bsp_matmul, bsp_matmul_int8
from repro.kernels.nsd_quant.nsd_quant import nsd_quantize_blocked
from repro.kernels.pack.pack import bitmap_pack_blocked
from repro.quant import wire as wireformat
from repro.quant.codecs import absmax_int8

# Trace-time counter of structural kernel-path fallbacks (unsupported
# einsum form, grouped/dilated conv, ...). Keyed by reason string; tests
# assert a fallback is COUNTED, never silent. Shape misalignment is not a
# reason anymore — padding handles it.
KERNEL_FALLBACKS: dict = {}


def note_fallback(reason: str, name: str) -> None:
    KERNEL_FALLBACKS[reason] = KERNEL_FALLBACKS.get(reason, 0) + 1


def _pad_to(x: jax.Array, m: int, n: int) -> jax.Array:
    M, N = x.shape
    pm, pn = (-M) % m, (-N) % n
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


class QuantizedGrad(NamedTuple):
    """A pre-activation gradient after the fused NSD pass, tile-mask ready.

    ``k`` is zero-padded to ``block`` multiples; ``nnz`` is the fused
    kernel's per-tile non-zero map (NOT recomputed from ``k``); ``bitmap``
    is the packed wire-format occupancy; ``mask`` is the tile mask the
    matmul kernels consume, derived from ``bitmap``. ``shape`` is the
    unpadded (M, N).
    """

    k: jax.Array  # (Mp, Np) int8
    delta: jax.Array  # f32 scalar
    nnz: jax.Array  # (Mp/block, Np/block) int32, from the fused NSD kernel
    bitmap: jax.Array  # (Mp, Np//8) uint8 packed occupancy
    mask: jax.Array  # (Mp/block, Np/block) int32, derived from ``bitmap``
    shape: Tuple[int, int]


def nsd_quantize_kernel(g: jax.Array, key: jax.Array, s, *,
                        bm: int = 128, bn: int = 512,
                        interpret: Optional[bool] = None):
    """NSD via the Pallas kernel. g: (M, N). Returns (k, delta, nnz_map).

    delta/std are global reductions (outside the kernel); dither noise comes
    from the framework RNG so results are bit-identical to repro.core.nsd
    given the same key. ``k`` is sliced back to the input shape; ``nnz``
    covers the padded tile grid (padding tiles are all-zero).
    """
    interpret = default_interpret(interpret)
    M, N = g.shape
    delta = nsd.compute_delta(g, s)
    noise = nsd.dither_noise(key, g.shape, delta)
    gp = _pad_to(g, bm, bn)
    np_ = _pad_to(noise, bm, bn)
    k, nnz = nsd_quantize_blocked(gp, np_, delta, bm=bm, bn=bn,
                                  interpret=interpret)
    return k[:M, :N], delta, nnz


def quantize_and_mask(g: jax.Array, key: jax.Array, s, *,
                      block: int = 128,
                      interpret: Optional[bool] = None) -> QuantizedGrad:
    """Fused NSD quantize + bitmap pack + bitmap-derived tile mask.

    One NSD pass produces the int8 payload and the per-tile nnz map; one
    pack pass produces the wire-format bitmap; the tile mask the matmul
    kernels consume comes from the bitmap (popcount-style reduction), so
    wire, residual store and backward compute share one representation.
    ``mask`` equals ``(nnz > 0)`` bit-exactly (pinned in tests).
    """
    interpret = default_interpret(interpret)
    M, N = g.shape
    delta = nsd.compute_delta(g, s)
    noise = nsd.dither_noise(key, g.shape, delta)
    gp = _pad_to(g, block, block)
    np_ = _pad_to(noise, block, block)
    k, nnz = nsd_quantize_blocked(gp, np_, delta, bm=block, bn=block,
                                  interpret=interpret)
    bitmap, _ = bitmap_pack_blocked(k, bm=block, bn=block,
                                    interpret=interpret)
    mask = wireformat.tile_mask_from_bitmap(bitmap, block, block)
    return QuantizedGrad(k=k, delta=delta, nnz=nnz, bitmap=bitmap,
                         mask=mask, shape=(M, N))


def quantized_from_indices(k: jax.Array, delta: jax.Array, *,
                           block: int = 128,
                           interpret: Optional[bool] = None) -> QuantizedGrad:
    """Build a :class:`QuantizedGrad` from precomputed NSD indices.

    For callers that already hold the int8 k tensor (an einsum slice of a
    jointly-quantized gradient, a gradient that arrived in wire format):
    pads, packs the bitmap, and derives the tile mask + per-tile nnz from
    the bitmap alone — no dense recompute.
    """
    interpret = default_interpret(interpret)
    M, N = k.shape
    kp = _pad_to(k.astype(jnp.int8), block, block)
    bitmap, _ = bitmap_pack_blocked(kp, bm=block, bn=block,
                                    interpret=interpret)
    mask = wireformat.tile_mask_from_bitmap(bitmap, block, block)
    nnz = wireformat.tile_nnz_from_bitmap(bitmap, block, block)
    return QuantizedGrad(k=kp, delta=delta, nnz=nnz, bitmap=bitmap,
                         mask=mask, shape=(M, N))


def bsp_backward_from_quantized(
    q: QuantizedGrad, x: jax.Array, w: jax.Array, *, block: int = 128,
    int8_operands: bool = True, interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Both backward products of y = x @ w from a quantized cotangent.

    q.k plays g~ (T, N) zero-padded; x: (..., K) reshaped to (T, K);
    w: (K, N). Returns (dx (T, K), dw (K, N)); operands are zero-padded to
    tile multiples and outputs sliced back, so any layer shape takes the
    tile-skipping kernel path.
    """
    interpret = default_interpret(interpret)
    T, N = q.shape
    K = x.shape[-1]
    x2d = _pad_to(x.reshape(-1, K), block, block)

    if int8_operands:
        wq = absmax_int8(w)
        xq = absmax_int8(x.reshape(-1, K))
        # dx = g~ @ w^T : tiles of g~ index rows; mask transposes with g~
        dx = bsp_matmul_int8(
            q.k, _pad_to(wq.q.T, block, block), q.delta * wq.scale, q.mask,
            bm=block, bk=block, bn=block, interpret=interpret)
        # dw = x^T @ g~ = (g~^T @ x)^T; mask for g~^T is mask^T
        dw_t = bsp_matmul_int8(
            q.k.T, _pad_to(xq.q, block, block), q.delta * xq.scale,
            q.mask.T, bm=block, bk=block, bn=block, interpret=interpret)
    else:
        dx = bsp_matmul(q.k, q.delta,
                        _pad_to(w.T.astype(jnp.float32), block, block),
                        q.mask, bm=block, bk=block, bn=block,
                        interpret=interpret)
        dw_t = bsp_matmul(q.k.T, q.delta, x2d.astype(jnp.float32), q.mask.T,
                          bm=block, bk=block, bn=block, interpret=interpret)
    return (dx[:T, :K].astype(x.dtype),
            dw_t[:N, :K].T.astype(w.dtype))


def dithered_backward_matmuls(
    g: jax.Array, x: jax.Array, w: jax.Array, key: jax.Array, s, *,
    block: int = 128, int8_operands: bool = True,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """TPU-native backward for y = x @ w given cotangent g.

    g: (T, N) pre-activation gradient; x: (T, K); w: (K, N) — any shapes
    (zero-padded to tile multiples internally). Returns (dx (T, K),
    dw (K, N)) using the fused NSD kernel + the tile-skipping quantized
    matmul kernels, with the tile mask derived from the packed bitmap.
    """
    q = quantize_and_mask(g, key, s, block=block, interpret=interpret)
    return bsp_backward_from_quantized(q, x, w, block=block,
                                       int8_operands=int8_operands,
                                       interpret=interpret)
