"""High-level jit'd wrappers over the Pallas kernels.

``dithered_backward_matmuls`` is the full TPU-native backward pass of one
dense layer (DESIGN.md §4): one fused NSD pass over the pre-activation
gradient, then both backward products as tile-skipping int8 matmuls. The
pure-jnp fallback path (interpret=False unavailable off-TPU) matches
``repro.core.dithered`` semantics; tests assert kernel == oracle == core.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import int8 as int8lib
from repro.core import nsd
from repro.kernels.bsp_matmul.bsp_matmul import bsp_matmul, bsp_matmul_int8
from repro.kernels.nsd_quant.nsd_quant import nsd_quantize_blocked


def _pad_to(x: jax.Array, m: int, n: int) -> jax.Array:
    M, N = x.shape
    pm, pn = (-M) % m, (-N) % n
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def nsd_quantize_kernel(g: jax.Array, key: jax.Array, s: float, *,
                        bm: int = 128, bn: int = 512,
                        interpret: bool = True):
    """NSD via the Pallas kernel. g: (M, N). Returns (k, delta, nnz_map).

    delta/std are global reductions (outside the kernel); dither noise comes
    from the framework RNG so results are bit-identical to repro.core.nsd
    given the same key.
    """
    M, N = g.shape
    delta = nsd.compute_delta(g, s)
    noise = nsd.dither_noise(key, g.shape, delta)
    gp = _pad_to(g, bm, bn)
    np_ = _pad_to(noise, bm, bn)
    k, nnz = nsd_quantize_blocked(gp, np_, delta, bm=bm, bn=bn,
                                  interpret=interpret)
    return k[:M, :N], delta, nnz


def dithered_backward_matmuls(
    g: jax.Array, x: jax.Array, w: jax.Array, key: jax.Array, s: float, *,
    block: int = 128, int8_operands: bool = True, interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """TPU-native backward for y = x @ w given cotangent g.

    g: (T, N) pre-activation gradient; x: (T, K); w: (K, N).
    Returns (dx (T, K), dw (K, N)) using the fused NSD kernel + the
    tile-skipping quantized matmul kernels.
    """
    T, N = g.shape
    K = x.shape[-1]
    assert T % block == 0 and N % block == 0 and K % block == 0, \
        (g.shape, x.shape, w.shape, block)
    k_q, delta, _ = nsd_quantize_kernel(g, key, s, bm=block, bn=block,
                                        interpret=interpret)
    nnz = (k_q != 0).astype(jnp.int32).reshape(
        T // block, block, N // block, block).sum((1, 3))
    mask_g = (nnz > 0).astype(jnp.int32)  # (T/b, N/b)

    if int8_operands:
        wq = int8lib.quantize_int8(w)
        xq = int8lib.quantize_int8(x.reshape(-1, K))
        # dx = g~ @ w^T : tiles of g~ index rows; mask transposes with g~
        dx = bsp_matmul_int8(
            k_q, wq.q.T, delta * wq.scale, mask_g,
            bm=block, bk=block, bn=block, interpret=interpret)
        # dw = x^T @ g~ = (g~^T @ x)^T; mask for g~^T is mask_g^T
        dw_t = bsp_matmul_int8(
            k_q.T, xq.q, delta * xq.scale, mask_g.T,
            bm=block, bk=block, bn=block, interpret=interpret)
        return dx.astype(x.dtype), dw_t.T.astype(w.dtype)

    dx = bsp_matmul(k_q, delta, w.T.astype(jnp.float32), mask_g,
                    bm=block, bk=block, bn=block, interpret=interpret)
    dw_t = bsp_matmul(k_q.T, delta, x.reshape(-1, K).astype(jnp.float32),
                      mask_g.T, bm=block, bk=block, bn=block,
                      interpret=interpret)
    return dx.astype(x.dtype), dw_t.T.astype(w.dtype)
