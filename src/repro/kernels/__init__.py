"""Pallas TPU kernels for the paper's hot spots (+ pure-jnp oracles).

nsd_quant/   fused NSD quantize -> (int8 k, tile-occupancy map)
bsp_matmul/  tile-skipping quantized matmuls (dequant + full-int8 variants)
pack/        occupancy-bitmap pack/unpack for the comm wire format
ops.py       jit'd high-level wrappers (full dithered backward of a dense layer)
"""
from repro.kernels.nsd_quant.nsd_quant import nsd_quantize_blocked
from repro.kernels.bsp_matmul.bsp_matmul import bsp_matmul, bsp_matmul_int8
from repro.kernels.pack.pack import bitmap_pack_blocked, bitmap_unpack_blocked
from repro.kernels import ops

__all__ = ["nsd_quantize_blocked", "bsp_matmul", "bsp_matmul_int8",
           "bitmap_pack_blocked", "bitmap_unpack_blocked", "ops"]
