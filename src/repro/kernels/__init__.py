"""Pallas TPU kernels for the paper's hot spots (+ pure-jnp oracles).

backend.py   backend-aware interpret default (interpret off-TPU, compiled on)
nsd_quant/   fused NSD quantize -> (int8 k, tile-occupancy map)
bsp_matmul/  tile-skipping quantized matmuls (dequant + full-int8 variants;
             masked tiles skip MXU issue AND operand DMA via fetch maps)
pack/        occupancy-bitmap pack/unpack for the comm wire format
levels/      chunk-local compact/expand of the wire's non-zero int8 levels
             (butterfly routing network; replaces the jnp full-cumsum
             compact behind repro.quant.wire's pallas backend)
ops.py       jit'd high-level wrappers: the full dithered backward pipeline
             (fused NSD -> wire bitmap -> bitmap-derived tile mask ->
             tile-skipping backward products) for any layer shape
"""
from repro.kernels.backend import default_interpret, on_tpu
from repro.kernels.nsd_quant.nsd_quant import nsd_quantize_blocked
from repro.kernels.bsp_matmul.bsp_matmul import (bsp_matmul, bsp_matmul_int8,
                                                 fetch_map)
from repro.kernels.pack.pack import bitmap_pack_blocked, bitmap_unpack_blocked
from repro.kernels.levels.levels import (levels_compact_blocked,
                                         levels_expand_blocked)
from repro.kernels import ops

__all__ = ["default_interpret", "on_tpu", "nsd_quantize_blocked",
           "bsp_matmul", "bsp_matmul_int8", "fetch_map",
           "bitmap_pack_blocked", "bitmap_unpack_blocked",
           "levels_compact_blocked", "levels_expand_blocked", "ops"]
