"""Step-phase tracing: host-side spans that land on the metrics bus.

``with span("dispatch"): ...`` measures the wall-clock of one phase of one
step and records a row on the ``"phase"`` stream, tagged by the span *path*
(nested spans join with ``/``: ``"dispatch/compile"``). Each span also
opens a ``jax.profiler.TraceAnnotation`` so the same phase shows up in XLA
profiler timelines under the same name — one taxonomy for host timing and
device profiles.

The span taxonomy used by the built-in drivers:

* ``data``        — batch construction / next(loader)
* ``dispatch``    — the jitted step call (async dispatch + any host sync
                    the caller performs inside)
* ``controller``  — the sparsity-controller host tick (includes the
                    effects-barrier telemetry drain)
* ``checkpoint``  — checkpoint save/wait (train-loop side)
* ``ckpt_gather`` / ``ckpt_drain`` / ``ckpt_wait`` — checkpoint
                    device->host transfer / backpressure join / final join
* ``ckpt_write``  — the async writer thread's disk work, with nested
                    ``serialize`` / ``commit`` / ``rotate`` phases (its own
                    root path: span stacks are thread-local)
* ``monitor``     — health-monitor evaluation (repro.obs.monitor)
* ``admit`` / ``decode`` — serving-engine tick phases
* ``lower`` / ``compile`` — dry-run cell phases

Inside *jitted* code host spans cannot run; use :func:`annotate` (a thin
``jax.named_scope``) there, which names the HLO region so device profiles
attribute time to the same taxonomy.

The module-level :func:`span` uses the process-default tracer, whose step
counter the training/serving loops advance with :func:`set_step`.
Recording is cheap (a perf_counter pair and a list append) and always on;
whether the rows go anywhere durable is the run-log exporter's decision.
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Iterator, Optional

import numpy as np

from repro.obs.bus import MetricsBus, get_bus
from repro.obs.streams import PHASE

_TLS = threading.local()


def annotate(name: str):
    """Named scope for *traced* code: spans inside jit land in the HLO /
    device profile under the same taxonomy as the host spans."""
    import jax

    return jax.named_scope(name)


class Tracer:
    """Span recorder bound to a bus; one per process is typical."""

    def __init__(self, bus: Optional[MetricsBus] = None):
        self._bus = bus
        self._step = 0

    @property
    def bus(self) -> MetricsBus:
        return self._bus if self._bus is not None else get_bus()

    def set_step(self, step: int) -> None:
        """Advance the step index stamped on subsequent span rows."""
        self._step = int(step)

    @property
    def step(self) -> int:
        return self._step

    def _stack(self) -> list:
        stack = getattr(_TLS, "span_stack", None)
        if stack is None:
            stack = _TLS.span_stack = []
        return stack

    @contextlib.contextmanager
    def span(self, name: str) -> Iterator[None]:
        """Measure one phase; nested spans record under a joined path."""
        import jax

        stack = self._stack()
        stack.append(name)
        path = "/".join(stack)
        t0 = time.perf_counter()
        try:
            with jax.profiler.TraceAnnotation(name):
                yield
        finally:
            dt = time.perf_counter() - t0
            stack.pop()
            self.bus.record(PHASE.name, path,
                            np.array([self._step, dt], np.float32))


_DEFAULT = Tracer()


def get_tracer() -> Tracer:
    return _DEFAULT


def span(name: str):
    """``with span("data"): ...`` on the process-default tracer."""
    return _DEFAULT.span(name)


def set_step(step: int) -> None:
    _DEFAULT.set_step(step)
