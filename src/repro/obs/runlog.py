"""Run log: drain the metrics bus into durable JSONL streams + a manifest.

A *run directory* is the unit of observability this subsystem produces::

    <run_dir>/
      manifest.json     provenance + declared stream schemas
      dither.jsonl      one object per telemetry row, columns named
      comm.jsonl        ...
      memory.jsonl
      phase.jsonl       step-phase spans (repro.obs.trace)
      train.jsonl       per-step headline metrics
      monitor.jsonl     structured monitor events (repro.obs.monitor)

Everything in the directory is strict JSON — ``allow_nan=False``, the
``benchmarks/suite.py`` artifact policy — with non-finite floats written as
``null`` so ``jq``/JS consumers never choke; the offline report
(``python -m repro.obs.report <run_dir>``) renders Table-1-style summaries
from these files alone, with no live process required.

The manifest reuses the ``repro.bench.schema`` provenance fields (git sha,
jax version, backend platform) so a run directory and a ``BENCH_*.json``
artifact from the same commit are joinable, and adds run identity
(``run_id``, creation time) plus caller context (argv, policy / memory
program strings).

:class:`RunLog` is the incremental exporter (cursor-based appends: a
``flush()`` writes only rows that arrived since the previous one);
:class:`RunObs` bundles the exporter with the span tracer and a monitor
suite into the single object the Trainer / launchers accept.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
import uuid
from typing import Any, Dict, List, Optional, Tuple

from repro.obs.bus import MetricsBus, get_bus
from repro.obs.monitor import MonitorSuite, default_monitors
from repro.obs.trace import Tracer, get_tracer
from repro.utils import get_logger
from repro.utils.logging import set_log_context

log = get_logger("obs.runlog")

MANIFEST_NAME = "manifest.json"
MANIFEST_SCHEMA_VERSION = 1


def _json_safe(v: float) -> Optional[float]:
    """Strict-JSON scalar: non-finite floats become null."""
    f = float(v)
    return f if math.isfinite(f) else None


def new_run_id() -> str:
    return time.strftime("%Y%m%d-%H%M%S") + "-" + uuid.uuid4().hex[:6]


class RunLog:
    """Append-only JSONL exporter over one bus."""

    def __init__(self, run_dir: str, *, bus: Optional[MetricsBus] = None,
                 context: Optional[Dict[str, Any]] = None,
                 run_id: Optional[str] = None):
        self.run_dir = run_dir
        self._bus = bus
        self.run_id = run_id or new_run_id()
        self.context = dict(context or {})
        self._cursors: Dict[Tuple[str, str], int] = {}
        self._event_cursor = 0
        os.makedirs(run_dir, exist_ok=True)
        self.write_manifest()

    @property
    def bus(self) -> MetricsBus:
        return self._bus if self._bus is not None else get_bus()

    # --------------------------------------------------------------- files
    def manifest_path(self) -> str:
        return os.path.join(self.run_dir, MANIFEST_NAME)

    def stream_path(self, stream: str) -> str:
        return os.path.join(self.run_dir, f"{stream}.jsonl")

    def write_manifest(self) -> str:
        from repro.bench.schema import git_sha

        import jax

        manifest = {
            "schema_version": MANIFEST_SCHEMA_VERSION,
            "run_id": self.run_id,
            "created_unix": time.time(),
            "git_sha": git_sha(),
            "jax_version": jax.__version__,
            "platform": jax.default_backend(),
            "context": self.context,
            "streams": {name: list(cols) for name, cols
                        in self.bus.registry.schema().items()},
        }
        path = self.manifest_path()
        with open(path, "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True, allow_nan=False)
            f.write("\n")
        return path

    # --------------------------------------------------------------- drain
    def flush(self) -> int:
        """Append every row/event that arrived since the last flush.

        Returns the number of JSONL lines written. Cursor-based: O(new
        records), so calling it every N steps is cheap on long runs.
        """
        bus = self.bus
        written = 0
        for (stream, tag), total in sorted(bus.cursors().items()):
            seen = self._cursors.get((stream, tag), 0)
            if total <= seen:
                continue
            new = bus.rows_since(stream, tag, seen)
            cols = bus.registry.get(stream).columns
            with open(self.stream_path(stream), "a") as f:
                for row in new:
                    obj = {"tag": tag}
                    obj.update({c: _json_safe(v) for c, v in zip(cols, row)})
                    json.dump(obj, f, allow_nan=False)
                    f.write("\n")
                    written += 1
            self._cursors[(stream, tag)] = total
        events = bus.events(self._event_cursor)
        if events:
            with open(self.stream_path("monitor"), "a") as f:
                for ev in events:
                    ev = {k: _json_safe(v) if isinstance(v, float) else v
                          for k, v in ev.items()}
                    json.dump(ev, f, allow_nan=False)
                    f.write("\n")
                    written += 1
            self._event_cursor += len(events)
        return written

    def close(self) -> None:
        self.flush()


def read_run(run_dir: str) -> Tuple[Dict[str, Any],
                                    Dict[str, List[Dict[str, Any]]]]:
    """Load a run directory back: (manifest, {stream: [row dicts]}).

    Parsing is strict: a bare ``NaN``/``Infinity`` literal in any line is
    an exporter bug and raises instead of silently round-tripping.
    """
    def _reject(const: str):
        raise ValueError(f"non-strict JSON constant {const!r} in run dir")

    with open(os.path.join(run_dir, MANIFEST_NAME)) as f:
        manifest = json.load(f, parse_constant=_reject)
    streams: Dict[str, List[Dict[str, Any]]] = {}
    for fname in sorted(os.listdir(run_dir)):
        if not fname.endswith(".jsonl"):
            continue
        name = fname[: -len(".jsonl")]
        rows = []
        with open(os.path.join(run_dir, fname)) as f:
            for line in f:
                line = line.strip()
                if line:
                    rows.append(json.loads(line, parse_constant=_reject))
        streams[name] = rows
    return manifest, streams


# ---------------------------------------------------------------------------
# RunObs: the bundle the Trainer / launchers accept
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RunObs:
    """One run's observability: exporter + tracer + monitors.

    Build with :func:`run_obs`; drive with :meth:`on_step` once per
    optimizer step and :meth:`finish` at the end. ``span`` is the tracing
    entry point loops should use so phase rows carry the current step.
    """

    runlog: RunLog
    tracer: Tracer
    monitors: MonitorSuite
    flush_every: int = 25

    def span(self, name: str):
        return self.tracer.span(name)

    def set_step(self, step: int) -> None:
        self.tracer.set_step(step)
        set_log_context(step=int(step))

    def on_step(self, step: int, metrics: Optional[Dict[str, float]] = None
                ) -> None:
        """Record per-step headline metrics + run monitors + maybe flush."""
        bus = self.runlog.bus
        metrics = metrics or {}
        if "loss" in metrics:
            bus.record("train", "train", [float(step),
                                          float(metrics["loss"])])
        if "comm_wire_bytes" in metrics:
            bus.record("comm", "step",
                       [float(metrics["comm_wire_bytes"]),
                        float(metrics.get("comm_dense_bytes", 0.0))])
        if "comm_error_bound" in metrics:
            bus.record("bound", "reduce",
                       [float(step), float(metrics["comm_error_bound"])])
        if "overlap_efficiency" in metrics:
            bus.record("overlap", "reduce",
                       [float(step),
                        float(metrics.get("overlap_n_buckets", 0.0)),
                        float(metrics.get("overlap_hidden_s", 0.0)),
                        float(metrics.get("overlap_exposed_s", 0.0)),
                        float(metrics["overlap_efficiency"])])
        with self.tracer.span("monitor"):
            self.monitors.tick(step)
        if self.flush_every and step % self.flush_every == 0:
            self.runlog.flush()

    def finish(self) -> None:
        self.monitors.tick(self.tracer.step)
        # snapshot cumulative kernel-path fallback counters into the run
        # artifact: a structural form silently falling off the kernel path
        # should show up in the run dir, not just in-process
        from repro.obs import metrics as obs_metrics

        obs_metrics.emit_kernel_fallbacks(bus=self.runlog.bus)
        self.runlog.close()
        set_log_context(run_id=None, step=None)
        log.info("run log closed: %s (run_id %s)", self.runlog.run_dir,
                 self.runlog.run_id)


def run_obs(run_dir: str, *, context: Optional[Dict[str, Any]] = None,
            monitors=None, escalate: bool = False,
            sparsity_setpoint: Optional[float] = None,
            flush_every: int = 25,
            bus: Optional[MetricsBus] = None) -> RunObs:
    """Standard RunObs: run log in ``run_dir``, default monitor set, the
    process tracer. ``sparsity_setpoint`` arms the collapse detector (pass
    the controller target when the run has one)."""
    runlog = RunLog(run_dir, bus=bus, context=context)
    set_log_context(run_id=runlog.run_id)
    suite = MonitorSuite(
        monitors if monitors is not None
        else default_monitors(sparsity_setpoint=sparsity_setpoint, bus=bus),
        escalate=escalate, bus=bus)
    return RunObs(runlog=runlog, tracer=get_tracer(), monitors=suite,
                  flush_every=flush_every)
