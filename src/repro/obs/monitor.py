"""Health monitors: rolling-window detectors over the metrics bus.

Each monitor consumes *new* rows of one stream per tick (cursor-based, so a
tick is O(new records)), folds them into a bounded rolling window, and
emits structured :class:`MonitorEvent`s when the window violates its
threshold. Events land in the bus's event log (drained into
``monitor.jsonl`` by the run-log exporter) and are logged as warnings; a
:class:`MonitorSuite` with ``escalate=True`` raises :class:`MonitorAlert`
on critical events so an unattended run dies loudly instead of training on
NaNs for a week.

Built-in detectors:

* :class:`LossMonitor`        — non-finite loss on the ``train`` stream
                                (critical).
* :class:`SparsityMonitor`    — rolling per-layer dither sparsity collapses
                                below ``setpoint - band`` (the controller's
                                target band made observable).
* :class:`CommRatioMonitor`   — wire/dense byte ratio drifts above a
                                ceiling (compression regression on the
                                gradient exchange).
* :class:`MemoryRatioMonitor` — residual-store compression (dense /
                                measured) drops below a floor.
* :class:`BoundMonitor`       — the compressed reduce's eq.-6-style
                                pointwise error bound blows past a ceiling.
* :class:`ServeMonitor`       — serving-engine stall (work pending, zero
                                tokens fed — critical) and queue backlog
                                on the ``serve`` stream.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.bus import MetricsBus, get_bus
from repro.utils import get_logger

log = get_logger("obs.monitor")

SEV_WARNING = "warning"
SEV_CRITICAL = "critical"


class MonitorAlert(RuntimeError):
    """Raised by an escalating MonitorSuite on a critical event."""

    def __init__(self, events: Sequence["MonitorEvent"]):
        self.events = list(events)
        super().__init__("; ".join(e.message for e in self.events))


@dataclasses.dataclass(frozen=True)
class MonitorEvent:
    """One structured detector trip."""

    kind: str  # detector id, e.g. "loss_nonfinite"
    severity: str  # "warning" | "critical"
    step: int
    message: str
    value: float  # the offending measurement
    threshold: float  # the limit it violated
    tag: str = ""  # stream tag (layer name etc.), when per-tag

    def to_dict(self) -> Dict:
        def safe(v):  # strict-JSON scalar: non-finite -> null
            return float(v) if np.isfinite(v) else None

        return {"kind": self.kind, "severity": self.severity,
                "step": int(self.step), "message": self.message,
                "value": safe(self.value),
                "threshold": safe(self.threshold), "tag": self.tag}


class Monitor:
    """Base: cursor-tracked consumer of one stream."""

    stream = ""
    kind = ""

    def __init__(self, *, window: int = 20, bus: Optional[MetricsBus] = None):
        self.window = int(window)
        self._bus = bus
        self._cursors: Dict[str, int] = {}
        self._windows: Dict[str, Deque[np.ndarray]] = {}

    @property
    def bus(self) -> MetricsBus:
        return self._bus if self._bus is not None else get_bus()

    def _consume(self) -> List[Tuple[str, np.ndarray]]:
        """(tag, new_rows) for every tag with fresh rows; updates cursors
        and rolling windows."""
        out = []
        bus = self.bus
        for tag in bus.tags(self.stream):
            seen = self._cursors.get(tag, 0)
            new = bus.rows_since(self.stream, tag, seen)
            if not len(new):
                continue
            self._cursors[tag] = seen + len(new)
            win = self._windows.setdefault(
                tag, collections.deque(maxlen=self.window))
            for r in new:
                win.append(r)
            out.append((tag, new))
        return out

    def window_rows(self, tag: str) -> np.ndarray:
        win = self._windows.get(tag)
        if not win:
            return np.zeros((0,), np.float32)
        return np.stack(list(win))

    def tick(self, step: int) -> List[MonitorEvent]:
        raise NotImplementedError


class LossMonitor(Monitor):
    """Critical on any non-finite loss row (stream ``train``)."""

    stream = "train"
    kind = "loss_nonfinite"

    def tick(self, step: int) -> List[MonitorEvent]:
        events = []
        for tag, new in self._consume():
            bad = new[~np.isfinite(new[:, 1])]
            if len(bad):
                events.append(MonitorEvent(
                    kind=self.kind, severity=SEV_CRITICAL,
                    step=int(bad[0, 0]) if np.isfinite(bad[0, 0]) else step,
                    message=f"non-finite loss at step "
                            f"{int(bad[0, 0]) if np.isfinite(bad[0, 0]) else step}",
                    value=float(bad[0, 1]), threshold=float("inf"), tag=tag))
        return events


class SparsityMonitor(Monitor):
    """Rolling per-layer dither sparsity below ``setpoint - band``.

    ``setpoint`` is the controller target (or the policy author's
    expectation, ~0.92 for the paper's s=2 regime); ``band`` is the slack
    before a warning fires. ``min_rows`` rows must be in a layer's window
    before it is judged, so warmup noise cannot trip it.
    """

    stream = "dither"
    kind = "sparsity_collapse"

    def __init__(self, setpoint: float = 0.92, band: float = 0.15, *,
                 min_rows: int = 5, window: int = 50,
                 bus: Optional[MetricsBus] = None):
        super().__init__(window=window, bus=bus)
        self.setpoint = float(setpoint)
        self.band = float(band)
        self.min_rows = int(min_rows)

    def tick(self, step: int) -> List[MonitorEvent]:
        events = []
        floor = self.setpoint - self.band
        for tag, _new in self._consume():
            win = self.window_rows(tag)
            if len(win) < self.min_rows:
                continue
            mean_sp = float(win[:, 0].mean())
            if mean_sp < floor:
                events.append(MonitorEvent(
                    kind=self.kind, severity=SEV_WARNING, step=step,
                    message=f"{tag}: rolling sparsity {mean_sp:.3f} below "
                            f"setpoint {self.setpoint:.2f} - band "
                            f"{self.band:.2f}",
                    value=mean_sp, threshold=floor, tag=tag))
        return events


class CommRatioMonitor(Monitor):
    """Wire/dense byte ratio above ``max_ratio`` over the rolling window —
    the compressed gradient exchange stopped compressing."""

    stream = "comm"
    kind = "comm_ratio_drift"

    def __init__(self, max_ratio: float = 0.5, *, min_rows: int = 3,
                 window: int = 50, bus: Optional[MetricsBus] = None):
        super().__init__(window=window, bus=bus)
        self.max_ratio = float(max_ratio)
        self.min_rows = int(min_rows)

    def tick(self, step: int) -> List[MonitorEvent]:
        events = []
        for tag, _new in self._consume():
            win = self.window_rows(tag)
            if len(win) < self.min_rows:
                continue
            wire, dense = float(win[:, 0].sum()), float(win[:, 1].sum())
            if dense <= 0:
                continue
            ratio = wire / dense
            if ratio > self.max_ratio:
                events.append(MonitorEvent(
                    kind=self.kind, severity=SEV_WARNING, step=step,
                    message=f"{tag}: wire/dense ratio {ratio:.3f} above "
                            f"{self.max_ratio:.3f}",
                    value=ratio, threshold=self.max_ratio, tag=tag))
        return events


class MemoryRatioMonitor(Monitor):
    """Residual compression (dense / measured bytes) below ``min_x``."""

    stream = "memory"
    kind = "residual_compression_drift"

    def __init__(self, min_x: float = 1.5, *, min_rows: int = 3,
                 window: int = 50, bus: Optional[MetricsBus] = None):
        super().__init__(window=window, bus=bus)
        self.min_x = float(min_x)
        self.min_rows = int(min_rows)

    def tick(self, step: int) -> List[MonitorEvent]:
        events = []
        for tag, _new in self._consume():
            win = self.window_rows(tag)
            if len(win) < self.min_rows:
                continue
            measured, dense = float(win[:, 0].sum()), float(win[:, 2].sum())
            if measured <= 0:
                continue
            x = dense / measured
            if x < self.min_x:
                events.append(MonitorEvent(
                    kind=self.kind, severity=SEV_WARNING, step=step,
                    message=f"{tag}: residual compression {x:.2f}x below "
                            f"{self.min_x:.2f}x floor",
                    value=x, threshold=self.min_x, tag=tag))
        return events


class BoundMonitor(Monitor):
    """Compressed-reduce pointwise error bound above ``max_bound`` —
    the eq.-6 error budget blowing up (stream ``bound``)."""

    stream = "bound"
    kind = "error_bound_blowup"

    def __init__(self, max_bound: float = 1.0, *,
                 window: int = 20, bus: Optional[MetricsBus] = None):
        super().__init__(window=window, bus=bus)
        self.max_bound = float(max_bound)

    def tick(self, step: int) -> List[MonitorEvent]:
        events = []
        for tag, new in self._consume():
            worst = float(np.max(new[:, 1]))
            if worst > self.max_bound or not np.isfinite(worst):
                events.append(MonitorEvent(
                    kind=self.kind, severity=SEV_WARNING, step=step,
                    message=f"{tag}: reduce error bound {worst:.3g} above "
                            f"{self.max_bound:.3g}",
                    value=worst, threshold=self.max_bound, tag=tag))
        return events


class ServeMonitor(Monitor):
    """Serving-engine health on the ``serve`` stream (tag = worker name).

    Two detectors in one consumer: a *stall* (critical) — rows show work in
    the system (active slots or queued requests) but no tokens fed for
    ``min_rows`` consecutive ticks, i.e. the engine is wedged — and a
    *backlog* (warning) — rolling mean queue depth above ``max_backlog``,
    i.e. admission is not keeping up with arrivals.
    """

    stream = "serve"
    kind = "serve_stall"

    def __init__(self, max_backlog: float = 32.0, *, min_rows: int = 8,
                 window: int = 50, bus: Optional[MetricsBus] = None):
        super().__init__(window=window, bus=bus)
        self.max_backlog = float(max_backlog)
        self.min_rows = int(min_rows)

    def tick(self, step: int) -> List[MonitorEvent]:
        events = []
        for tag, _new in self._consume():
            win = self.window_rows(tag)
            if len(win) < self.min_rows:
                continue
            tail = win[-self.min_rows:]
            busy = (tail[:, 1] + tail[:, 2]) > 0  # active_slots + queue
            fed = tail[:, 3]
            if busy.all() and float(fed.sum()) == 0.0:
                events.append(MonitorEvent(
                    kind=self.kind, severity=SEV_CRITICAL, step=step,
                    message=f"{tag}: {self.min_rows} ticks with work "
                            f"pending but zero tokens fed (engine stalled)",
                    value=0.0, threshold=1.0, tag=tag))
            backlog = float(win[:, 2].mean())
            if backlog > self.max_backlog:
                events.append(MonitorEvent(
                    kind="serve_backlog", severity=SEV_WARNING, step=step,
                    message=f"{tag}: rolling queue depth {backlog:.1f} "
                            f"above {self.max_backlog:.0f}",
                    value=backlog, threshold=self.max_backlog, tag=tag))
        return events


def default_monitors(*, sparsity_setpoint: Optional[float] = None,
                     bus: Optional[MetricsBus] = None) -> List[Monitor]:
    """The standard detector set for a training run. When the run carries a
    closed-loop sparsity controller, pass its target as the setpoint so the
    collapse band tracks the controller's own."""
    mons: List[Monitor] = [LossMonitor(bus=bus),
                           CommRatioMonitor(bus=bus),
                           MemoryRatioMonitor(bus=bus),
                           BoundMonitor(bus=bus)]
    if sparsity_setpoint is not None:
        mons.append(SparsityMonitor(setpoint=sparsity_setpoint, bus=bus))
    return mons


class MonitorSuite:
    """Runs a detector set each tick; records + logs + optionally raises.

    A condition that stays tripped is rate-limited: each (kind, tag) pair
    re-emits at most once per ``reemit_every`` steps, so a persistently
    uncompressed layer warns once per window instead of once per step.
    """

    def __init__(self, monitors: Sequence[Monitor], *,
                 escalate: bool = False,
                 raise_on: Sequence[str] = (SEV_CRITICAL,),
                 reemit_every: int = 50,
                 bus: Optional[MetricsBus] = None):
        self.monitors = list(monitors)
        self.escalate = bool(escalate)
        self.raise_on = tuple(raise_on)
        self.reemit_every = int(reemit_every)
        self._bus = bus
        self._last_emit: Dict[Tuple[str, str], int] = {}
        self.tripped: List[MonitorEvent] = []

    @property
    def bus(self) -> MetricsBus:
        return self._bus if self._bus is not None else get_bus()

    def tick(self, step: int) -> List[MonitorEvent]:
        raw: List[MonitorEvent] = []
        for mon in self.monitors:
            raw.extend(mon.tick(step))
        events: List[MonitorEvent] = []
        for ev in raw:
            key = (ev.kind, ev.tag)
            last = self._last_emit.get(key)
            if last is not None and step - last < self.reemit_every:
                continue
            self._last_emit[key] = step
            events.append(ev)
        for ev in events:
            self.bus.log_event(ev.to_dict())
            log.warning("[monitor] %s (%s): %s", ev.kind, ev.severity,
                        ev.message)
        self.tripped.extend(events)
        if self.escalate:
            fatal = [e for e in events if e.severity in self.raise_on]
            if fatal:
                raise MonitorAlert(fatal)
        return events
