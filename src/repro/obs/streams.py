"""Declared metric streams: the bus's typed schema surface.

A :class:`MetricStream` declares, once, what a family of telemetry rows
means: a stable stream name, ordered column names, and a one-line
description. Everything that used to be an ad-hoc sink in
``repro.core.stats`` (``_SINK`` / ``_COMM_SINK`` / ``_MEM_SINK``) is now a
registered stream, and every new telemetry family (step-phase timings,
per-step training metrics, monitor events) registers here too — so the
run-log exporter (``repro.obs.runlog``) and the offline report
(``repro.obs.report``) can name columns instead of guessing at positional
float tuples.

Registration is idempotent by value: re-registering an identical stream is
a no-op, re-registering a *different* schema under an existing name raises
(two subsystems disagreeing about what "comm" means is a bug, not a merge).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class MetricStream:
    """Schema of one telemetry stream on the bus.

    ``name``     stable stream id (also the JSONL file stem in a run dir)
    ``columns``  ordered column names; every row is a float vector of this
                 arity (dtype float32 on the wire — io_callback rows are
                 stacked f32 vectors)
    ``description``  what a row means, for humans and manifests
    """

    name: str
    columns: Tuple[str, ...]
    description: str = ""

    def __post_init__(self):
        if not self.name or "/" in self.name:
            raise ValueError(f"stream name must be non-empty, no '/': "
                             f"{self.name!r}")
        if not self.columns:
            raise ValueError(f"stream {self.name!r}: needs >= 1 column")

    @property
    def ncols(self) -> int:
        return len(self.columns)


# ---------------------------------------------------------------------------
# the built-in streams (the three legacy sinks + the new families)
# ---------------------------------------------------------------------------

# tag = stats_tag + layer name; one row per (layer, backward pass)
DITHER = MetricStream(
    "dither", ("sparsity", "bits", "delta"),
    "per-layer dither telemetry from inside the backward pass: induced "
    "sparsity fraction, worst-case bit-width, quantization step Delta "
    "(paper Table 1 / Fig. 6b)")

# one row per gradient exchange
COMM = MetricStream(
    "comm", ("wire_bytes", "dense_bytes"),
    "bytes-on-wire of compressed gradient exchange vs the dense f32 "
    "counterfactual (repro.comm)")

# one row per (layer, forward pass under differentiation)
MEMORY = MetricStream(
    "memory", ("measured_bytes", "capacity_bytes", "dense_bytes"),
    "residual-store bytes per layer: occupancy-aware wire-equivalent, "
    "HBM-resident capacity, dense fp32 counterfactual (repro.memory)")

# tag = span path ("dispatch", "data", "controller/tick", ...)
PHASE = MetricStream(
    "phase", ("step", "duration_s"),
    "host-side step-phase spans (repro.obs.trace): wall-clock seconds "
    "attributed to one phase of one step")

# one row per optimizer step when a RunObs is attached
TRAIN = MetricStream(
    "train", ("step", "loss"),
    "per-step training headline metrics (host-synced; recorded only when "
    "a run observer is attached)")

# eq.-6-style pointwise error bounds from compressed reduces
BOUND = MetricStream(
    "bound", ("step", "error_bound"),
    "per-step compressed-reduce pointwise error bound vs the dense mean")

# one row per serving-engine tick; tag = engine/worker name
SERVE = MetricStream(
    "serve", ("tick", "active_slots", "queue_depth", "fed_tokens",
              "gen_tokens", "kv_bytes", "kv_dense_bytes"),
    "serving engine occupancy + throughput per decode tick "
    "(repro.serve.engine): prompt/decode tokens fed into the step, tokens "
    "emitted, and KV-cache capacity bytes vs the dense fp32 counterfactual "
    "(paged mode prices sealed pages through repro.quant)")

# one row per priced step of an overlap-scheduled reduce; tag = stats tag
OVERLAP = MetricStream(
    "overlap", ("step", "n_buckets", "hidden_s", "exposed_s", "efficiency"),
    "modeled overlap accounting of a bucketed gradient reduce "
    "(repro.launch.costmodel.price_overlap): comm seconds hidden under "
    "backward vs exposed past it, and their ratio")

# tag = "kernels/" + fallback reason; one row per snapshot
FALLBACK = MetricStream(
    "fallback", ("count",),
    "cumulative trace-time kernel-path fallback counts "
    "(repro.kernels.ops.KERNEL_FALLBACKS), snapshotted at run end")

BUILTIN_STREAMS = (DITHER, COMM, MEMORY, PHASE, TRAIN, BOUND, SERVE,
                   OVERLAP, FALLBACK)


class StreamRegistry:
    """Name -> MetricStream map with conflict detection."""

    def __init__(self):
        self._streams: Dict[str, MetricStream] = {}
        for s in BUILTIN_STREAMS:
            self._streams[s.name] = s

    def register(self, stream: MetricStream) -> MetricStream:
        cur = self._streams.get(stream.name)
        if cur is not None and cur != stream:
            raise ValueError(
                f"stream {stream.name!r} already registered with a "
                f"different schema: {cur.columns} != {stream.columns}")
        self._streams[stream.name] = stream
        return stream

    def get(self, name: str) -> MetricStream:
        try:
            return self._streams[name]
        except KeyError:
            raise KeyError(
                f"unknown stream {name!r}; registered: "
                f"{sorted(self._streams)}") from None

    def names(self):
        return sorted(self._streams)

    def schema(self) -> Dict[str, Tuple[str, ...]]:
        """{stream: columns} — what a run manifest embeds."""
        return {n: s.columns for n, s in sorted(self._streams.items())}
