"""Offline run report: Table-1-style summaries from a run directory alone.

    PYTHONPATH=src python -m repro.obs.report <run-dir>

Reads the JSONL streams + manifest written by :mod:`repro.obs.runlog` (no
live process, no jax arrays) and renders:

* the run header (run id, git sha, jax version, backend, caller context)
* a per-layer dither table — mean sparsity %, worst-case / mean bits,
  record count per layer tag (the paper's Table 1 aggregation)
* comm totals — wire vs dense bytes and the achieved ratio per tag
* residual-memory totals — occupancy + capacity compression per layer
* a step-phase breakdown — total / mean / share of wall-clock per span
  path (the ``data`` / ``dispatch`` / ``controller`` / ``checkpoint``
  taxonomy from :mod:`repro.obs.trace`)
* monitor events, most recent last
"""
from __future__ import annotations

import argparse
import collections
from typing import Any, Dict, List

from repro.obs.runlog import read_run


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024 or unit == "TB":
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}TB"


def _by_tag(rows: List[Dict[str, Any]]) -> Dict[str, List[Dict[str, Any]]]:
    out: Dict[str, List[Dict[str, Any]]] = collections.defaultdict(list)
    for r in rows:
        out[r.get("tag", "")].append(r)
    return out


def _vals(rows: List[Dict[str, Any]], col: str) -> List[float]:
    return [r[col] for r in rows if r.get(col) is not None]


def dither_table(rows: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Per-layer-tag [mean sparsity %, max bits, mean bits, n] rows."""
    table = []
    for tag, rs in sorted(_by_tag(rows).items()):
        sp, bits = _vals(rs, "sparsity"), _vals(rs, "bits")
        if not sp:
            continue
        table.append({
            "tag": tag,
            "mean_sparsity_pct": 100.0 * sum(sp) / len(sp),
            "max_bits": max(bits) if bits else float("nan"),
            "mean_bits": sum(bits) / len(bits) if bits else float("nan"),
            "n": len(rs),
        })
    return table


def phase_table(rows: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Per-span-path total / mean duration and share of the traced total."""
    grand = 0.0
    agg: Dict[str, List[float]] = collections.defaultdict(list)
    for r in rows:
        d = r.get("duration_s")
        if d is None:
            continue
        agg[r.get("tag", "")].append(d)
        # only top-level spans count toward the grand total: nested span
        # time is already inside the parent's measurement
        if "/" not in r.get("tag", ""):
            grand += d
    table = []
    for tag, ds in sorted(agg.items()):
        total = sum(ds)
        table.append({
            "span": tag, "total_s": total, "mean_ms": 1e3 * total / len(ds),
            "n": len(ds),
            "share_pct": 100.0 * total / grand if grand > 0 else 0.0,
        })
    table.sort(key=lambda r: -r["total_s"])
    return table


def render(run_dir: str) -> str:
    manifest, streams = read_run(run_dir)
    out: List[str] = []
    ctx = manifest.get("context", {})
    out.append(f"run {manifest.get('run_id')}  "
               f"[git {manifest.get('git_sha')}, "
               f"jax {manifest.get('jax_version')}, "
               f"{manifest.get('platform')}]")
    for k in sorted(ctx):
        out.append(f"  {k}: {ctx[k]}")

    dt = dither_table(streams.get("dither", []))
    if dt:
        out.append("")
        out.append("per-layer dither telemetry (Table-1 aggregation)")
        out.append(f"  {'layer':<28} {'sparsity%':>9} {'max bits':>8} "
                   f"{'mean bits':>9} {'n':>6}")
        for r in dt:
            out.append(f"  {r['tag']:<28} {r['mean_sparsity_pct']:>9.2f} "
                       f"{r['max_bits']:>8.1f} {r['mean_bits']:>9.2f} "
                       f"{r['n']:>6d}")
        all_sp = _vals(streams["dither"], "sparsity")
        if all_sp:
            out.append(f"  overall sparsity: "
                       f"{100.0 * sum(all_sp) / len(all_sp):.2f}% over "
                       f"{len(all_sp)} layer x step records")

    comm = streams.get("comm", [])
    if comm:
        out.append("")
        out.append("comm: compressed gradient exchange")
        for tag, rs in sorted(_by_tag(comm).items()):
            wire = sum(_vals(rs, "wire_bytes"))
            dense = sum(_vals(rs, "dense_bytes"))
            ratio = wire / dense if dense else float("nan")
            out.append(f"  {tag:<28} wire {_fmt_bytes(wire):>10} / dense "
                       f"{_fmt_bytes(dense):>10}  ratio {ratio:.4f}")

    mem = streams.get("memory", [])
    if mem:
        out.append("")
        out.append("memory: residual store per layer")
        out.append(f"  {'layer':<28} {'measured':>10} {'capacity':>10} "
                   f"{'dense':>10} {'occ x':>6} {'cap x':>6}")
        for tag, rs in sorted(_by_tag(mem).items()):
            m = sum(_vals(rs, "measured_bytes"))
            c = sum(_vals(rs, "capacity_bytes"))
            d = sum(_vals(rs, "dense_bytes"))
            occ = d / m if m else float("nan")
            cap = d / c if c else float("nan")
            out.append(f"  {tag:<28} {_fmt_bytes(m):>10} {_fmt_bytes(c):>10} "
                       f"{_fmt_bytes(d):>10} {occ:>6.2f} {cap:>6.2f}")

    pt = phase_table(streams.get("phase", []))
    if pt:
        out.append("")
        out.append("step-phase breakdown (host spans)")
        out.append(f"  {'span':<24} {'total s':>9} {'mean ms':>9} "
                   f"{'n':>6} {'share%':>7}")
        for r in pt:
            out.append(f"  {r['span']:<24} {r['total_s']:>9.3f} "
                       f"{r['mean_ms']:>9.2f} {r['n']:>6d} "
                       f"{r['share_pct']:>7.1f}")

    train = streams.get("train", [])
    losses = [(r["step"], r["loss"]) for r in train
              if r.get("loss") is not None]
    if losses:
        out.append("")
        first, last = losses[0], losses[-1]
        out.append(f"train: {len(losses)} steps, loss "
                   f"{first[1]:.4f} (step {int(first[0])}) -> "
                   f"{last[1]:.4f} (step {int(last[0])})")

    events = streams.get("monitor", [])
    out.append("")
    if events:
        out.append(f"monitor events ({len(events)}):")
        for ev in events:
            out.append(f"  step {ev.get('step', '?'):>5} "
                       f"[{ev.get('severity')}] {ev.get('kind')}: "
                       f"{ev.get('message')}")
    else:
        out.append("monitor events: none")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="render Table-1-style summaries + step-time breakdown "
                    "from a run directory's JSONL streams")
    ap.add_argument("run_dir", help="directory written via --run-dir / "
                    "repro.obs.runlog.RunLog")
    args = ap.parse_args(argv)
    print(render(args.run_dir))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
