"""The metrics bus: one typed emission path for all in-jit telemetry.

Replaces the three copy-pasted sinks of ``repro.core.stats``
(``_SINK``/``_COMM_SINK``/``_MEM_SINK``) with a single registry-backed
store. Emission from inside jitted code — ``custom_vjp`` backward passes,
shard_map bodies — goes through one ``jax.experimental.io_callback`` path
(:func:`MetricsBus.emit`); host-side producers (the span tracer, the
trainer's per-step metrics) append directly via :func:`MetricsBus.record`.

Readers (``rows`` / ``rows_since`` / ``row_count`` / ``summary`` helpers in
``repro.core.stats``) first *drain*: ``jax.effects_barrier()`` blocks until
every dispatched-but-unfinished step's callbacks have landed, so a reader
never races an in-flight emission (the seed repo's flaky-telemetry fix,
now centralized here).

Stacked views are cached per (stream, tag) *generation*: ``rows()`` on an
unchanged tag returns the cached ``np.stack`` instead of restacking the
full history — end-of-run summaries on long runs used to be O(n^2) in the
row count (every ``summary()`` call restacked everything). The cache is
pinned by a call-count test on the stack path (tests/test_obs.py).

Monitor events are host-side structured dicts, not float rows; they live
in a parallel event log on the same bus so the run-log exporter drains
both through one cursor protocol.
"""
from __future__ import annotations

import functools
import threading
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.obs.streams import MetricStream, StreamRegistry


class MetricsBus:
    """Thread-safe process-local store of typed telemetry rows."""

    def __init__(self):
        self.registry = StreamRegistry()
        self._lock = threading.Lock()
        # (stream, tag) -> list of (ncols,) float32 rows
        self._rows: Dict[Tuple[str, str], List[np.ndarray]] = {}
        # (stream, tag) -> (generation == len at stack time, stacked view)
        self._stacked: Dict[Tuple[str, str], Tuple[int, np.ndarray]] = {}
        # structured (non-numeric) event records, in arrival order
        self._events: List[Dict[str, Any]] = []
        # instrumentation for the O(n^2)-restack regression pin
        self.stack_calls = 0

    # ------------------------------------------------------------ lifecycle
    def reset(self) -> None:
        with self._lock:
            self._rows.clear()
            self._stacked.clear()
            self._events.clear()

    @staticmethod
    def drain() -> None:
        """Block until in-flight io_callbacks have landed (readers call
        this: emissions from a dispatched-but-undrained step would
        otherwise race the read)."""
        import jax

        jax.effects_barrier()

    # ------------------------------------------------------------- writers
    def record(self, stream: str, tag: str, row) -> None:
        """Host-side append of one row (also the io_callback landing pad)."""
        spec = self.registry.get(stream)
        arr = np.asarray(row, np.float32).reshape(-1)
        if arr.shape != (spec.ncols,):
            raise ValueError(
                f"stream {stream!r} expects {spec.ncols} columns "
                f"{spec.columns}, got row of shape {arr.shape}")
        with self._lock:
            self._rows.setdefault((stream, tag), []).append(arr)

    def emit(self, stream: str, tag: str, values) -> None:
        """Record one row from inside a (possibly jitted) computation.

        ``values`` is a traced float vector matching the stream's declared
        arity; the row lands on whatever bus is current when the callback
        executes (so a test swapping the default bus mid-flight keeps the
        legacy sink semantics).
        """
        import jax
        import jax.numpy as jnp

        self.registry.get(stream)  # fail at trace time on unknown streams
        jax.experimental.io_callback(
            functools.partial(_landing_pad, stream, tag),
            jax.ShapeDtypeStruct((), jnp.int32),
            jnp.asarray(values, jnp.float32),
            ordered=False,
        )

    def log_event(self, event: Dict[str, Any]) -> None:
        """Append one structured (dict) event — monitor trips etc."""
        with self._lock:
            self._events.append(dict(event))

    # ------------------------------------------------------------- readers
    def _empty(self, stream: str) -> np.ndarray:
        return np.zeros((0, self.registry.get(stream).ncols), np.float32)

    def rows(self, stream: str, tag: str) -> np.ndarray:
        """(n, ncols) array of every recorded row for a (stream, tag).

        The stacked view is cached per generation: repeated reads of an
        unchanged tag cost O(1), not O(n) — and end-of-run summaries that
        loop tags x metrics stop being O(n^2) overall.
        """
        self.drain()
        key = (stream, tag)
        with self._lock:
            rows = self._rows.get(key)
            if not rows:
                return self._empty(stream)
            gen = len(rows)
            cached = self._stacked.get(key)
            if cached is not None and cached[0] == gen:
                return cached[1]
            stacked = np.stack(rows)
            self.stack_calls += 1
            self._stacked[key] = (gen, stacked)
            return stacked

    def rows_since(self, stream: str, tag: str, start: int) -> np.ndarray:
        """Rows from index ``start`` on, stacking only the new suffix —
        per-step consumers (controller telemetry windows, the run-log
        exporter) stay O(new records) per tick."""
        self.drain()
        with self._lock:
            new = self._rows.get((stream, tag), [])[start:]
            if not new:
                return self._empty(stream)
            self.stack_calls += 1
            return np.stack(new)

    def row_count(self, stream: str, tag: str) -> int:
        self.drain()
        with self._lock:
            return len(self._rows.get((stream, tag), []))

    def tags(self, stream: str) -> List[str]:
        self.drain()
        with self._lock:
            return sorted(t for (s, t), r in self._rows.items()
                          if s == stream and r)

    def streams_present(self) -> List[str]:
        """Stream names that hold at least one row."""
        self.drain()
        with self._lock:
            return sorted({s for (s, _t), r in self._rows.items() if r})

    def events(self, start: int = 0) -> List[Dict[str, Any]]:
        with self._lock:
            return [dict(e) for e in self._events[start:]]

    def event_count(self) -> int:
        with self._lock:
            return len(self._events)

    def cursors(self) -> Dict[Tuple[str, str], int]:
        """Snapshot of current row counts, for incremental exporters."""
        self.drain()
        with self._lock:
            return {k: len(v) for k, v in self._rows.items() if v}


def _landing_pad(stream: str, tag: str, row) -> np.ndarray:
    """io_callback target: route to whatever bus is default *now*."""
    get_bus().record(stream, tag, np.asarray(row))
    return np.zeros((), np.int32)


# ---------------------------------------------------------------------------
# the process default bus (what core/stats and the tracer write to)
# ---------------------------------------------------------------------------

_DEFAULT: Optional[MetricsBus] = None
_DEFAULT_LOCK = threading.Lock()


def get_bus() -> MetricsBus:
    global _DEFAULT
    if _DEFAULT is None:
        with _DEFAULT_LOCK:
            if _DEFAULT is None:
                _DEFAULT = MetricsBus()
    return _DEFAULT


def set_bus(bus: Optional[MetricsBus]) -> MetricsBus:
    """Swap the process default (tests); returns the new default."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        _DEFAULT = bus
    return get_bus()


def register_stream(stream: MetricStream) -> MetricStream:
    return get_bus().registry.register(stream)
