"""Telemetry facade for dither / comm / memory / overlap statistics.

The paper's Table 1 reports the average sparsity of the pre-activation
gradients over all layers and training iterations, and fig. 6b the
worst-case bit-width. Those numbers are produced *inside* jitted code, so
they surface through ``jax.experimental.io_callback`` into the typed
metrics bus (:mod:`repro.obs.bus`). This module is the named read/write
API over the built-in streams — the home the historical
``repro.core.stats`` facade moved to (that module is now a deprecation
shim delegating here; the ``layer_sparsity`` and ``memory_bench``
zero-band gates pin the numerics bit-for-bit across the move).

Stream mapping (see ``repro.obs.streams`` for the declared schemas):

* ``emit``/``rows``/``summary``            -> stream ``"dither"``
* ``emit_comm``/``comm_rows``/...          -> stream ``"comm"``
* ``emit_memory``/``memory_rows``/...      -> stream ``"memory"``
* ``emit_overlap``/``overlap_rows``        -> stream ``"overlap"``
* ``emit_kernel_fallbacks``/...            -> stream ``"fallback"``

This remains a single-host debugging/telemetry path — the policy flag
``collect_stats`` defaults to False and stays off for pjit multi-device
runs.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.nsd import QuantStats
from repro.obs.bus import get_bus

STREAM_DITHER = "dither"
STREAM_COMM = "comm"
STREAM_MEMORY = "memory"
STREAM_OVERLAP = "overlap"
STREAM_FALLBACK = "fallback"


def reset() -> None:
    """Clear every stream on the default bus (all legacy sinks at once)."""
    get_bus().reset()


def _drain() -> None:
    """Block until in-flight io_callbacks have landed (readers call this:
    emissions from a dispatched-but-undrained step would otherwise race)."""
    jax.effects_barrier()


# ---------------------------------------------------------------------------
# dither sparsity / bit-width / delta (stream "dither")
# ---------------------------------------------------------------------------

def emit(tag: str, stats: QuantStats) -> None:
    """Call from inside a (possibly jitted) backward pass."""
    row = jnp.stack(
        [stats.sparsity, stats.max_bitwidth, stats.delta.astype(jnp.float32)]
    )
    get_bus().emit(STREAM_DITHER, tag, row)


def rows(tag: str) -> np.ndarray:
    """(n, 3) array of [sparsity, bits, delta] records for a tag."""
    return get_bus().rows(STREAM_DITHER, tag)


def rows_since(tag: str, start: int) -> np.ndarray:
    """Records from index ``start`` on, without restacking the history —
    per-step consumers (the sparsity controller's telemetry window) stay
    O(new records) instead of O(run length) per tick."""
    return get_bus().rows_since(STREAM_DITHER, tag, start)


def row_count(tag: str) -> int:
    return get_bus().row_count(STREAM_DITHER, tag)


def tags() -> List[str]:
    return get_bus().tags(STREAM_DITHER)


def summary() -> Dict[str, Dict[str, float]]:
    """Per-tag mean sparsity, worst-case bits — the Table-1 aggregation."""
    out = {}
    for tag in tags():
        r = rows(tag)
        if len(r) == 0:
            continue
        out[tag] = {
            "mean_sparsity": float(r[:, 0].mean()),
            "max_bits": float(r[:, 1].max()),
            "mean_bits": float(r[:, 1].mean()),
            "n_records": int(len(r)),
        }
    return out


def overall_sparsity() -> float:
    """Average sparsity over every recorded layer x step, as in Table 1."""
    all_rows = [rows(t) for t in tags()]
    all_rows = [r for r in all_rows if len(r)]
    if not all_rows:
        return float("nan")
    cat = np.concatenate(all_rows, axis=0)
    return float(cat[:, 0].mean())


def overall_max_bits() -> float:
    all_rows = [rows(t) for t in tags()]
    all_rows = [r for r in all_rows if len(r)]
    if not all_rows:
        return float("nan")
    cat = np.concatenate(all_rows, axis=0)
    return float(cat[:, 1].max())


# ---------------------------------------------------------------------------
# comm counters: bytes-on-wire of compressed gradient exchange
# ---------------------------------------------------------------------------

def emit_comm(tag: str, wire_bytes: jax.Array, dense_bytes: jax.Array) -> None:
    """Record one exchange's (wire, dense) byte counts from inside jit."""
    row = jnp.stack([jnp.asarray(wire_bytes, jnp.float32),
                     jnp.asarray(dense_bytes, jnp.float32)])
    get_bus().emit(STREAM_COMM, tag, row)


def comm_rows(tag: str) -> np.ndarray:
    """(n, 2) array of [wire_bytes, dense_bytes] records for a tag."""
    return get_bus().rows(STREAM_COMM, tag)


def comm_tags() -> List[str]:
    return get_bus().tags(STREAM_COMM)


def comm_summary() -> Dict[str, Dict[str, float]]:
    """Per-tag total wire/dense bytes and the achieved compression ratio."""
    out = {}
    for tag in comm_tags():
        r = comm_rows(tag)
        if len(r) == 0:
            continue
        wire, dense = float(r[:, 0].sum()), float(r[:, 1].sum())
        out[tag] = {
            "wire_bytes": wire,
            "dense_bytes": dense,
            "ratio": wire / dense if dense else float("nan"),
            "n_records": int(len(r)),
        }
    return out


# ---------------------------------------------------------------------------
# residual-memory counters: bytes the backward keeps alive per layer
# ---------------------------------------------------------------------------

def emit_memory(tag: str, measured_bytes: jax.Array, capacity_bytes,
                dense_bytes) -> None:
    """Record one layer's (measured, capacity, dense) residual byte counts
    from inside a (possibly jitted) custom_vjp forward."""
    row = jnp.stack([jnp.asarray(measured_bytes, jnp.float32),
                     jnp.asarray(capacity_bytes, jnp.float32),
                     jnp.asarray(dense_bytes, jnp.float32)])
    get_bus().emit(STREAM_MEMORY, tag, row)


def memory_rows(tag: str) -> np.ndarray:
    """(n, 3) array of [measured, capacity, dense] byte records for a tag."""
    return get_bus().rows(STREAM_MEMORY, tag)


def memory_tags() -> List[str]:
    return get_bus().tags(STREAM_MEMORY)


def memory_summary() -> Dict[str, Dict[str, float]]:
    """Per-tag residual byte totals and the two compression factors:
    ``capacity_compression`` (dense / HBM-resident capacity — size batch
    headroom from THIS one) and ``occupancy_compression`` (dense /
    wire-equivalent measured bytes — what a byte-true compacted store
    would achieve)."""
    out = {}
    for tag in memory_tags():
        r = memory_rows(tag)
        if len(r) == 0:
            continue
        measured, cap, dense = (float(r[:, i].sum()) for i in range(3))
        out[tag] = {
            "measured_bytes": measured,
            "capacity_bytes": cap,
            "dense_bytes": dense,
            "occupancy_compression": (dense / measured if measured
                                      else float("nan")),
            "capacity_compression": dense / cap if cap else float("nan"),
            "n_records": int(len(r)),
        }
    return out


def overall_residual_compression(prefix: str = "", *,
                                 capacity: bool = False) -> float:
    """dense/measured (or dense/capacity) over every recorded layer x step
    under a tag prefix."""
    col = 1 if capacity else 0
    stored = dense = 0.0
    for tag in memory_tags():
        if not tag.startswith(prefix):
            continue
        r = memory_rows(tag)
        if len(r):
            stored += float(r[:, col].sum())
            dense += float(r[:, 2].sum())
    if stored <= 0:
        return float("nan")
    return dense / stored


# ---------------------------------------------------------------------------
# overlap accounting: per-step modeled hidden/exposed comm (stream "overlap")
# ---------------------------------------------------------------------------

def emit_overlap(tag: str, step: int, n_buckets: int, hidden_s: float,
                 exposed_s: float, efficiency: float) -> None:
    """Record one step's overlap pricing (host-side: the numbers come from
    ``repro.launch.costmodel.price_overlap``, not from inside jit)."""
    get_bus().record(STREAM_OVERLAP, tag,
                     [float(step), float(n_buckets), float(hidden_s),
                      float(exposed_s), float(efficiency)])


def overlap_rows(tag: str) -> np.ndarray:
    """(n, 5) array of [step, n_buckets, hidden_s, exposed_s, efficiency]."""
    return get_bus().rows(STREAM_OVERLAP, tag)


def overlap_tags() -> List[str]:
    return get_bus().tags(STREAM_OVERLAP)


# ---------------------------------------------------------------------------
# kernel fallback counters (stream "fallback")
# ---------------------------------------------------------------------------

def emit_kernel_fallbacks(prefix: str = "kernels/", *,
                          bus=None) -> Dict[str, int]:
    """Snapshot ``repro.kernels.ops.KERNEL_FALLBACKS`` onto the bus.

    One row per reason (tag = prefix + reason, value = cumulative trace-time
    count), so a run-dir artifact records WHICH structural forms fell off
    the kernel path — a silent-fallback regression shows up as a new tag or
    a count jump, not as an unexplained perf cliff. Returns the snapshot.
    Host-side; call at run end (RunObs.finish does)."""
    from repro.kernels.ops import KERNEL_FALLBACKS

    snap = dict(KERNEL_FALLBACKS)
    bus = bus if bus is not None else get_bus()
    for reason, count in sorted(snap.items()):
        bus.record(STREAM_FALLBACK, prefix + reason, [float(count)])
    return snap


def fallback_rows(tag: str) -> np.ndarray:
    """(n, 1) array of cumulative fallback counts recorded for a tag."""
    return get_bus().rows(STREAM_FALLBACK, tag)


def fallback_tags() -> List[str]:
    return get_bus().tags(STREAM_FALLBACK)
