"""repro.obs — unified observability: metrics bus, tracing, run logs.

One subsystem owns every telemetry path the training / serving stack
produces:

* :mod:`repro.obs.streams` + :mod:`repro.obs.bus` — the typed metrics bus
  (declared stream schemas, one io_callback emission path from inside
  jitted code, cached stacked reads). ``repro.core.stats`` is a thin
  compatibility shim over it.
* :mod:`repro.obs.trace` — host-side step-phase spans (``with
  span("dispatch")``) mirrored into XLA profiles, plus ``annotate`` for
  named scopes inside jit.
* :mod:`repro.obs.runlog` — append-only JSONL export of every stream into
  a run directory with a provenance manifest; :class:`RunObs` bundles
  exporter + tracer + monitors for ``Trainer(obs=...)`` / ``--run-dir``.
* :mod:`repro.obs.monitor` — rolling-window health detectors (loss
  NaN/inf, sparsity collapse, comm-ratio / residual-compression drift,
  error-bound blowup) with escalate-to-raise.
* :mod:`repro.obs.report` — ``python -m repro.obs.report <run-dir>``
  renders Table-1-style per-layer summaries and a step-time breakdown
  from the JSONL alone.
"""
from repro.obs.bus import MetricsBus, get_bus, register_stream, set_bus
from repro.obs.monitor import (BoundMonitor, CommRatioMonitor, LossMonitor,
                               MemoryRatioMonitor, Monitor, MonitorAlert,
                               MonitorEvent, MonitorSuite, ServeMonitor,
                               SparsityMonitor, default_monitors)
from repro.obs.runlog import RunLog, RunObs, read_run, run_obs
from repro.obs.streams import BUILTIN_STREAMS, MetricStream
from repro.obs.trace import Tracer, annotate, get_tracer, set_step, span

__all__ = [
    "BUILTIN_STREAMS",
    "BoundMonitor",
    "CommRatioMonitor",
    "LossMonitor",
    "MemoryRatioMonitor",
    "MetricStream",
    "MetricsBus",
    "Monitor",
    "MonitorAlert",
    "MonitorEvent",
    "MonitorSuite",
    "RunLog",
    "RunObs",
    "ServeMonitor",
    "SparsityMonitor",
    "Tracer",
    "annotate",
    "default_monitors",
    "get_bus",
    "get_tracer",
    "read_run",
    "register_stream",
    "run_obs",
    "set_bus",
    "set_step",
    "span",
]
