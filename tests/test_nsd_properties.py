"""Hypothesis property tests for the NSD operator.

Kept separate from test_nsd.py: hypothesis ships in the [test] extra, not
as a hard dependency, and a bare module-level import would abort the whole
suite's collection under -x when it is absent.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import nsd  # noqa: E402


@settings(max_examples=25, deadline=None)
@given(s=st.floats(0.5, 8.0), scale=st.floats(1e-3, 1e3),
       seed=st.integers(0, 2**31 - 1))
def test_property_quantized_values_on_grid(s, scale, seed):
    """Every output is an integer multiple of Delta (within f32 eps)."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (256,), jnp.float32) * scale
    delta = nsd.compute_delta(x, s)
    k = nsd.nsd_indices(x, jax.random.fold_in(key, 1), delta)
    q = k.astype(jnp.float32) * delta
    ratio = np.asarray(q) / max(float(delta), 1e-30)
    np.testing.assert_allclose(ratio, np.round(ratio), atol=1e-3)
    assert int(jnp.max(jnp.abs(k))) <= 127


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), s=st.floats(1.0, 4.0))
def test_property_error_bounded_by_delta(seed, s):
    """|x~ - x| <= Delta (pointwise worst case of NSD)."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (256,), jnp.float32)
    delta = float(nsd.compute_delta(x, s))
    q = nsd.nsd_quantize(x, jax.random.fold_in(key, 1), s)
    assert float(jnp.max(jnp.abs(q - x))) <= delta * 1.001
