"""Checkpoint manager (roundtrip, rotation, crash consistency, resharding
restore) and fault-tolerance logic (stragglers, elastic plans, preemption)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import (CheckpointManager, ElasticSSGD, StragglerConfig,
                         StragglerDetector, list_steps, make_restart_plan,
                         plan_elastic_mesh, snap_pods)


def _tree(key):
    return {
        "a": jax.random.normal(key, (8, 4)),
        "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                   "c": jnp.ones((3,), jnp.bfloat16)},
    }


class TestCheckpoint:
    def test_roundtrip(self, tmp_path, key):
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        tree = _tree(key)
        mgr.save(5, tree)
        restored = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, dtype=np.float32),
                                          np.asarray(b, dtype=np.float32))

    def test_rotation_keeps_k(self, tmp_path, key):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
        tree = _tree(key)
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        assert list_steps(str(tmp_path)) == [3, 4]

    def test_uncommitted_ignored(self, tmp_path, key):
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        tree = _tree(key)
        mgr.save(1, tree)
        mgr.save(2, tree)
        # simulate a crash mid-write on step 2: remove the marker
        os.remove(os.path.join(str(tmp_path), "step_00000002", "_COMMITTED"))
        assert mgr.latest_step() == 1

    def test_corruption_detected(self, tmp_path, key):
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        tree = {"a": jnp.ones((4,))}
        mgr.save(1, tree)
        shard = os.path.join(str(tmp_path), "step_00000001",
                             "shard_00000.npz")
        np.savez(shard, a=np.zeros((4,), np.float32))  # corrupt payload
        with pytest.raises(IOError):
            mgr.restore(tree)

    def test_async_save(self, tmp_path, key):
        mgr = CheckpointManager(str(tmp_path), async_write=True)
        tree = _tree(key)
        mgr.save(7, tree)
        mgr.wait()
        assert mgr.latest_step() == 7

    def test_shape_mismatch_raises(self, tmp_path, key):
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        mgr.save(1, {"a": jnp.ones((4,))})
        with pytest.raises(ValueError):
            mgr.restore({"a": jnp.ones((5,))})


RESHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.train import CheckpointManager

    base = sys.argv[1]
    from repro.launch import make_mesh
    mesh8 = make_mesh((8,), ("data",))
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    sharded = jax.device_put(
        tree["w"], NamedSharding(mesh8, P("data", None)))
    mgr = CheckpointManager(base, async_write=False)
    mgr.save(3, {"w": sharded})

    # restore onto a DIFFERENT mesh (4 devices wide) — elastic downsize
    mesh4 = make_mesh((4, 2), ("data", "model"))
    target_sh = {"w": NamedSharding(mesh4, P("data", None))}
    out = mgr.restore({"w": jnp.zeros((8, 8))}, shardings=target_sh)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.arange(64).reshape(8, 8))
    assert out["w"].sharding.is_equivalent_to(target_sh["w"], 2)
    print("RESHARD_OK")
""")


def test_reshard_restore_subprocess(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")))
    out = subprocess.run(
        [sys.executable, "-c", RESHARD_SCRIPT, str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=600)
    assert "RESHARD_OK" in out.stdout, out.stdout + out.stderr


class TestStragglers:
    def test_detects_consistent_straggler(self):
        det = StragglerDetector(4, StragglerConfig(factor=1.5, patience=3))
        flagged = []
        for step in range(6):
            times = [1.0, 1.0, 1.0, 3.0]  # host 3 always slow
            flagged = det.observe(times)
        assert flagged == [3]

    def test_transient_blip_not_flagged(self):
        det = StragglerDetector(4, StragglerConfig(factor=1.5, patience=3))
        det.observe([1.0, 1.0, 1.0, 5.0])
        flagged = det.observe([1.0, 1.0, 1.0, 1.0])
        for _ in range(4):
            flagged = det.observe([1.0, 1.0, 1.0, 1.0])
        assert flagged == []


class TestElastic:
    def test_plan_keeps_tp_groups_whole(self):
        shape, axes = plan_elastic_mesh(n_alive_chips=240, model_parallel=16)
        assert axes == ("data", "model")
        assert shape == (8, 16)  # 240//16=15 -> round down to 8

    def test_plan_none_when_tp_broken(self):
        assert plan_elastic_mesh(n_alive_chips=10, model_parallel=16) is None

    def test_restart_plan_scales_accum(self):
        plan = make_restart_plan(n_alive_chips=128, model_parallel=16,
                                 original_data_parallel=16, latest_step=42)
        assert plan.mesh_shape == (8, 16)
        assert plan.grad_accum_scale == 2  # half the data parallelism
        assert plan.restore_step == 42


class TestSnapPods:
    @pytest.mark.parametrize("pods,n,want", [
        (4, 8, 4),   # divides: unchanged
        (4, 6, 2),   # gcd(4, 6)
        (4, 3, 1),   # coprime: collapse to flat
        (6, 4, 2),
        (1, 5, 1),
        (0, 7, 1),   # degenerate pod counts clamp up
    ])
    def test_snaps_to_divisor(self, pods, n, want):
        got = snap_pods(pods, n)
        assert got == want
        assert n % got == 0 and got <= max(pods, 1)

    def test_invalid_world_size(self):
        with pytest.raises(ValueError):
            snap_pods(4, 0)


class TestElasticSSGD:
    def _driver(self, tmp_path, n_nodes, comm):
        from repro.configs import get_smoke_model
        from repro.core import DitherPolicy
        from repro.optim import OptConfig

        model = get_smoke_model("mamba2-370m")
        return model, ElasticSSGD(
            model, OptConfig(name="sgd", lr=1e-2),
            DitherPolicy(variant="paper"), comm,
            ckpt_dir=str(tmp_path), n_nodes=n_nodes)

    def _batch(self, model, key, batch=12):
        # 12 is divisible by every world size the tests visit (2, 4, 6)
        return {
            "tokens": jax.random.randint(key, (batch, 16), 0,
                                         model.cfg.vocab),
            "labels": jax.random.randint(key, (batch, 16), 0,
                                         model.cfg.vocab),
        }

    def test_join_leave_migrates_ef_and_ctrl_bit_exact(self, tmp_path, key):
        """Shrink then grow (4 -> 2 -> 6): the EF residuals and controller
        state ride the checkpoint tree through both resizes unchanged.
        Residuals are per LEAF on the node mean, so a world-size change
        must not perturb them at all."""
        from repro.comm import CommPolicy

        comm = CommPolicy(default="topk_ef", topk_frac=0.25,
                          min_leaf_size=1)
        model, el = self._driver(tmp_path, 4, comm)
        el.init(key)
        for i in range(2):
            el.step(self._batch(model, jax.random.fold_in(key, i)),
                    jax.random.fold_in(key, 100 + i))
        # a controller subtree as the trainer would populate it
        el.ctrl_state = {"blocks/fc0": jnp.float32(0.125),
                         "blocks/fc1": jnp.float32(-0.5)}
        ref_comm = jax.tree.map(np.asarray, el.comm_state)
        ref_params = jax.tree.map(np.asarray, el.params)

        for n in (2, 6):
            el.resize(n)
            assert el.n_nodes == n
            for name, st in el.comm_state.items():
                np.testing.assert_array_equal(
                    np.asarray(st.residual), ref_comm[name].residual,
                    err_msg=f"{name} @ n={n}")
            assert float(el.ctrl_state["blocks/fc0"]) == 0.125
            assert float(el.ctrl_state["blocks/fc1"]) == -0.5
            for a, b in zip(jax.tree.leaves(el.params),
                            jax.tree.leaves(ref_params)):
                np.testing.assert_array_equal(np.asarray(a), b)
        # and training continues at the new world size
        m = el.step(self._batch(model, key), jax.random.fold_in(key, 999))
        assert np.isfinite(float(m["loss"]))

    def test_resize_snaps_hier_pods(self, tmp_path, key):
        """A hier policy's pod axis follows the world size: 4 nodes/2 pods
        resized to 6 keeps pods=2; resized to 3 collapses to flat."""
        from repro.comm import CommPolicy

        comm = CommPolicy(default="nsd", s=1.0, topology="hier", pods=2)
        model, el = self._driver(tmp_path, 4, comm)
        el.init(key)
        assert el.active_comm_policy.pods == 2
        el.step(self._batch(model, key), key)
        el.resize(6)
        assert el.active_comm_policy.pods == 2
        el.resize(3)
        assert el.active_comm_policy.pods == 1
        m = el.step(self._batch(model, key), jax.random.fold_in(key, 1))
        assert np.isfinite(float(m["loss"]))

    def test_noop_resize_skips_checkpoint(self, tmp_path, key):
        from repro.comm import CommPolicy

        comm = CommPolicy(default="nsd", s=1.0)
        model, el = self._driver(tmp_path, 2, comm)
        el.init(key)
        before = el.ckpt.latest_step()
        el.resize(2)
        assert el.ckpt.latest_step() == before


class TestPreemption:
    def test_trainer_checkpoints_on_preemption(self, tmp_path, key):
        from repro.configs import get_smoke_model
        from repro.data import TokenStreamConfig, token_batch
        from repro.optim import OptConfig
        from repro.train import Trainer, TrainerConfig

        model = get_smoke_model("mamba2-370m")
        trainer = Trainer(model, OptConfig(lr=1e-3),
                          TrainerConfig(total_steps=50, log_every=0,
                                        ckpt_every=100,
                                        ckpt_dir=str(tmp_path)))
        tcfg = TokenStreamConfig(vocab=model.cfg.vocab, seq_len=16, batch=2)

        def it():
            i = 0
            while True:
                if i == 3:
                    trainer.guard.trigger()  # preemption notice mid-run
                yield token_batch(tcfg, i)
                i += 1

        trainer.fit(it())
        # the trigger fires while batch 3 is being fetched, so step 3 still
        # completes; the checkpoint lands at the NEXT boundary (step 4)
        assert trainer.ckpt.latest_step() == 4


class TestControllerResume:
    def test_controller_state_rides_checkpoint(self, tmp_path, key):
        """The sparsity controller's per-layer log-scales resume losslessly
        (restored in _init_ctrl_state once the first batch names layers)."""
        import numpy as np

        from repro.configs import get_smoke_model
        from repro.core import DitherPolicy, PolicyProgram, SparsityController
        from repro.data import TokenStreamConfig, token_batch
        from repro.optim import OptConfig
        from repro.train import Trainer, TrainerConfig

        model = get_smoke_model("mamba2-370m")
        tcfg = TokenStreamConfig(vocab=model.cfg.vocab, seq_len=16, batch=2)

        def it():
            i = 0
            while True:
                yield token_batch(tcfg, i)
                i += 1

        def make_trainer(total_steps):
            prog = PolicyProgram(
                base=DitherPolicy(variant="paper", collect_stats=True,
                                  stats_tag="cres/"),
                controller=SparsityController(target=0.95, gain=3.0))
            return Trainer(model, OptConfig(lr=1e-3),
                           TrainerConfig(total_steps=total_steps, log_every=0,
                                         ckpt_every=4,
                                         ckpt_dir=str(tmp_path)),
                           policy=prog)

        t1 = make_trainer(4)
        t1.fit(it())
        t1.ckpt.wait()
        saved = {k: float(v) for k, v in t1._ctrl.state.items()}
        assert saved and any(v != 0.0 for v in saved.values())

        # restore path in isolation: after the main restore (no batch yet),
        # _init_ctrl_state discovers the layer names and picks the ctrl
        # subtree up from the checkpoint — exactly, not re-zeroed
        t2 = make_trainer(6)
        params, _, _ = t2.restore_or_init(jax.random.PRNGKey(0))
        t2._init_ctrl_state(params, token_batch(tcfg, 0))
        restored = {k: float(v) for k, v in t2._ctrl.state.items()}
        assert restored == saved
        # and the resumed run continues from there
        out = t2.fit(it())
        assert int(out["opt_state"]["step"]) == 6
        assert set(t2._ctrl.state) == set(saved)
