"""The unified ``--program`` launcher DSL: section splitting, round-trip,
resolution into each subsystem's policy object, and the deprecated
per-DSL flag merge."""
import pytest

from repro.launch.program import (LaunchSpec, format_program,
                                  merge_legacy_flags, parse_program)

FULL = ("dither: phase@0=off;phase@30=paper;rule lm_head:off "
        "memory: default=nsd;rule fc0:int8 "
        "comm: topology=butterfly;pods=4;bucket_bytes=1048576")


class TestParse:
    def test_sections_split(self):
        spec = parse_program(FULL)
        assert spec.dither == "phase@0=off;phase@30=paper;rule lm_head:off"
        assert spec.memory == "default=nsd;rule fc0:int8"
        assert spec.comm == "topology=butterfly;pods=4;bucket_bytes=1048576"

    def test_clause_colons_do_not_open_sections(self):
        """``rule lm_head:off`` stays inside the dither section — only the
        three known prefixes start sections."""
        spec = parse_program("dither: rule lm_head:off rule fc0:int8")
        assert spec.dither == "rule lm_head:off rule fc0:int8"
        assert spec.memory == "" and spec.comm == ""

    def test_prefix_glued_to_first_token(self):
        spec = parse_program("comm:topology=ring;s=2.0")
        assert spec.comm == "topology=ring;s=2.0"

    def test_single_section(self):
        assert parse_program("memory: default=int8") == \
            LaunchSpec(memory="default=int8")

    def test_bare_spec_errors_with_migration_hint(self):
        with pytest.raises(ValueError, match="--policy-program"):
            parse_program("phase@0=off;phase@30=paper")

    def test_duplicate_section_errors(self):
        with pytest.raises(ValueError, match="duplicate"):
            parse_program("dither: a=b dither: c=d")

    def test_round_trip(self):
        spec = parse_program(FULL)
        assert parse_program(format_program(spec)) == spec
        assert format_program(LaunchSpec()) == ""


class TestResolution:
    def test_dither_section_resolves(self):
        from repro.core import DitherPolicy
        base = DitherPolicy(variant="paper")
        prog = parse_program(
            "dither: phase@0=off;phase@2=paper").dither_program(base)
        assert prog.phase_policy_at(0).variant == "off"
        assert prog.phase_policy_at(5).variant == "paper"
        assert parse_program("comm: s=1.0").dither_program(base) is None

    def test_memory_section_resolves(self):
        pol = parse_program("memory: default=nsd;rule fc0:int8") \
            .memory_policy()
        assert pol.mode_for("blocks/fc0/w") == "int8"
        assert pol.mode_for("blocks/fc1/w") == "nsd"
        assert parse_program("comm: s=1.0").memory_policy() is None

    def test_comm_section_resolves(self):
        pol = parse_program(FULL).comm_policy()
        assert pol.topology == "butterfly"
        assert pol.pods == 4 and pol.bucket_bytes == 1048576
        assert parse_program("dither: rule a:off").comm_policy() is None


class TestLegacyFlags:
    def test_legacy_flags_warn_and_map(self):
        with pytest.warns(DeprecationWarning, match="--policy-program"):
            spec = merge_legacy_flags("", policy_program="phase@0=off")
        assert spec.dither == "phase@0=off"
        with pytest.warns(DeprecationWarning, match="--memory-program"):
            spec = merge_legacy_flags("comm: s=2.0",
                                      memory_program="default=int8")
        assert spec.memory == "default=int8" and spec.comm == "s=2.0"

    def test_conflict_is_hard_error(self):
        with pytest.warns(DeprecationWarning):
            with pytest.raises(ValueError, match="conflicts"):
                merge_legacy_flags("dither: phase@0=off",
                                   policy_program="phase@0=paper")

    def test_no_flags_no_warning(self):
        import warnings as _w
        with _w.catch_warnings():
            _w.simplefilter("error")
            assert merge_legacy_flags("") == LaunchSpec()
