"""The unified reducer protocol (repro.comm.reducer): migration pins vs
the three legacy entry points, the comm-program DSL, EF semantics, and
pack-once-per-accumulated-step gradient accumulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (CommPolicy, HierConfig, RingConfig,
                        allreduce_compressed, compress_tree,
                        hier_allreduce_nsd, ring_allreduce_nsd)
from repro.comm.overlap import OverlapReducer
from repro.comm.reducer import (format_comm_program, parse_comm_program,
                                reducer)


def _grad_tree(key, scale=0.02):
    ks = jax.random.split(key, 3)
    return {
        "dense0": {"w": jax.random.normal(ks[0], (32, 16)) * scale,
                   "b": jax.random.normal(ks[1], (16,)) * scale},
        "lm_head": {"w": jax.random.normal(ks[2], (16, 8)) * scale},
    }


def _stacked_tree(key, n, scale=0.02):
    return jax.tree.map(
        lambda l: jnp.stack([l * (1 + 0.1 * i) for i in range(n)]),
        _grad_tree(key, scale))


class TestFactoryAndMigration:
    def test_flat_reducer_pins_compress_tree(self, key):
        """Single-participant reduce == the legacy compress_tree path,
        bit-for-bit (the Trainer migration pin)."""
        pol = CommPolicy(default="nsd", s=2.0)
        grads = _grad_tree(key)
        red = reducer(pol, n_nodes=1, stacked=False)
        k = jax.random.fold_in(key, 3)
        out, tele, _ = red.reduce(grads, k, step=5)
        legacy, _, lt = compress_tree(grads, jax.random.fold_in(k, 5), pol)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(legacy)):
            assert float(jnp.max(jnp.abs(a - b))) == 0.0
        assert float(tele.wire_bytes) == float(lt["wire_bytes"])
        assert float(tele.dense_bytes) == float(lt["dense_bytes"])

    @pytest.mark.parametrize("topo", ["ring", "hier"])
    def test_allreduce_reducer_pins_sims(self, key, topo):
        """Topology reducers == the legacy per-leaf sims with the same
        per-leaf key derivation (the ssgd migration pin)."""
        pol = CommPolicy(default="nsd", s=1.0, topology=topo, pods=2)
        grads = _stacked_tree(key, 4)
        red = reducer(pol, n_nodes=4, stacked=True)
        k = jax.random.fold_in(key, 9)
        out, tele, _ = red.reduce(grads, k, step=0)
        assert red.topology == topo
        assert float(tele.wire_bytes) > 0.0
        # reference: the sims leaf by leaf with the reducer's key schedule
        from repro.core.policy import name_salt
        from repro.utils.pytree import flatten_with_names
        k_step = jax.random.fold_in(k, 0)
        fn = (ring_allreduce_nsd if topo == "ring" else hier_allreduce_nsd)
        cfg = (RingConfig(s=1.0) if topo == "ring"
               else HierConfig(pods=2, s=1.0))
        for name, g in flatten_with_names(grads):
            if pol.mode_for(name, int(g.size) // 4) == "dense":
                ref = jnp.mean(g, axis=0)  # small leaves skip the wire
            else:
                k0 = jax.random.fold_in(k_step, name_salt(name))
                ref, _ = fn(g, k0, cfg)
            got = dict(flatten_with_names(out))[name]
            assert float(jnp.max(jnp.abs(got - ref))) == 0.0, name

    def test_bucket_bytes_wraps_overlap(self):
        pol = CommPolicy(default="nsd", bucket_bytes=1 << 16)
        assert isinstance(reducer(pol, n_nodes=1, stacked=False),
                          OverlapReducer)

    def test_pods_must_divide_nodes(self):
        pol = CommPolicy(default="nsd", topology="hier", pods=3)
        with pytest.raises(ValueError):
            reducer(pol, n_nodes=4, stacked=True)


class TestDeprecationShims:
    def test_allreduce_compressed_warns_and_matches(self, key):
        gs = jnp.stack([jax.random.normal(jax.random.fold_in(key, i), (65,))
                        for i in range(4)])
        cfg = RingConfig(s=1.0)
        ref, ref_tele = ring_allreduce_nsd(gs, key, cfg)
        with pytest.warns(DeprecationWarning, match="reducer"):
            mean, tele = allreduce_compressed(gs, key, cfg)
        assert float(jnp.max(jnp.abs(mean - ref))) == 0.0
        assert float(tele.wire_bytes) == float(ref_tele.wire_bytes)

    def test_make_hier_allreduce_warns(self):
        from repro.comm import hierarchy
        with pytest.warns(DeprecationWarning, match="reducer"):
            try:
                hierarchy.make_hier_allreduce(None, HierConfig(pods=2))
            except Exception:
                pass  # mesh=None is invalid; only the warning is under test

    def test_reduce_cfg_warns(self):
        pol = CommPolicy(default="nsd", topology="butterfly", pods=4)
        with pytest.warns(DeprecationWarning, match="reducer"):
            cfg = pol.reduce_cfg()
        assert cfg.pods == 4

    def test_core_stats_shim_warns_and_delegates(self):
        import importlib

        import repro.core.stats as shim
        from repro.obs import metrics
        with pytest.warns(DeprecationWarning, match="repro.obs.metrics"):
            shim = importlib.reload(shim)
        assert shim.emit_comm is metrics.emit_comm
        assert shim.overall_sparsity is metrics.overall_sparsity


class TestCommProgram:
    def test_round_trip(self):
        spec = ("topology=butterfly;pods=4;default=nsd;s=2.0;"
                "bucket_bytes=1048576;stats=1;tag=comm/;"
                "rule emb:dense;rule head:topk_ef")
        pol = parse_comm_program(spec)
        assert pol.topology == "butterfly" and pol.pods == 4
        assert pol.bucket_bytes == 1048576 and pol.collect_stats
        assert pol.overrides == (("emb", "dense"), ("head", "topk_ef"))
        assert parse_comm_program(format_comm_program(pol)) == pol

    def test_base_overlay(self):
        base = parse_comm_program("default=nsd;s=1.0")
        over = parse_comm_program("s=3.0;rule emb:dense", base)
        assert over.s == 3.0 and over.default == "nsd"
        assert over.overrides == (("emb", "dense"),)

    def test_bad_clause_raises(self):
        with pytest.raises(ValueError):
            parse_comm_program("topology=moebius")
        with pytest.raises(ValueError):
            parse_comm_program("frobnicate=1")


class TestErrorFeedback:
    def test_stacked_topk_ef_residual_threads(self, key):
        """Server-side EF: the residual lives per LEAF on the node mean, so
        state round-trips through reduce and closes the mass balance."""
        from repro.utils.pytree import flatten_with_names

        pol = CommPolicy(default="topk_ef", topk_frac=0.25, min_leaf_size=1)
        grads = _stacked_tree(key, 3)
        red = reducer(pol, n_nodes=3, stacked=True)
        state = red.init_state(grads)
        assert set(state) == {n for n, _ in
                              flatten_with_names(_grad_tree(key))}
        out, _, state2 = red.reduce(grads, key, 0, state)
        mean = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
        sent = dict(flatten_with_names(out))
        for name, g in flatten_with_names(mean):
            # sent + residual == mean + residual_in (== 0 here): exact
            res = state2[name].residual.reshape(g.shape)
            np.testing.assert_allclose(
                np.asarray(sent[name] + res), np.asarray(g),
                rtol=0, atol=1e-7)

    def test_ef_state_is_node_count_independent(self, key):
        """The same mean gradient at different world sizes produces the
        identical EF residual — the elastic-migration invariant."""
        pol = CommPolicy(default="topk_ef", topk_frac=0.25, min_leaf_size=1)
        base = _grad_tree(key)
        for n in (2, 4):
            stacked = jax.tree.map(
                lambda l: jnp.stack([l] * n), base)  # noqa: B023
            red = reducer(pol, n_nodes=n, stacked=True)
            _, _, st = red.reduce(stacked, key, 0, red.init_state(stacked))
            if n == 2:
                ref = st
            else:
                for name in ref:
                    assert float(jnp.max(jnp.abs(
                        ref[name].residual - st[name].residual))) == 0.0


class TestGradAccum:
    def test_pack_once_per_accumulated_step(self, key):
        """grad_accum > 1 dithers/packs ONCE per optimizer step: the comm
        stream gains exactly one row per step, same as grad_accum == 1."""
        from repro.configs import get_smoke_model
        from repro.core import DitherPolicy
        from repro.distributed import SSGDConfig, make_ssgd_step, shard_batch
        from repro.obs import metrics as statslib
        from repro.optim import OptConfig, init_opt_state

        model = get_smoke_model("mamba2-370m")
        params, _ = model.init(key)
        opt = OptConfig(name="sgd", lr=1e-2, grad_clip=None)
        batch = {
            "tokens": jax.random.randint(key, (8, 16), 0, model.cfg.vocab),
            "labels": jax.random.randint(key, (8, 16), 0, model.cfg.vocab),
        }
        dcfg = SSGDConfig(n_nodes=2, s_schedule="fixed", s_base=1.0)
        rows = {}
        for ga in (1, 4):
            statslib.reset()
            cp = CommPolicy(default="nsd", s=1.0, collect_stats=True,
                            stats_tag=f"ga{ga}/")
            fn, _ = make_ssgd_step(model, opt, dcfg,
                                   DitherPolicy(variant="paper"),
                                   comm_policy=cp, grad_accum=ga)
            st = init_opt_state(params, opt)
            for i in range(3):
                _, st, _, _ = fn(params, st, shard_batch(batch, 2),
                                 jax.random.fold_in(key, i))
            jax.effects_barrier()
            rows[ga] = sum(len(statslib.comm_rows(t))
                           for t in statslib.comm_tags())
        assert rows[1] == rows[4] == 3, rows

    def test_grad_accum_matches_single_micro_mean(self, key):
        """Without dither noise differences (variant off, no comm), the
        accumulated gradient step equals the full-batch step."""
        from repro.configs import get_smoke_model
        from repro.core import DitherPolicy
        from repro.distributed import SSGDConfig, make_ssgd_step, shard_batch
        from repro.optim import OptConfig, init_opt_state

        model = get_smoke_model("mamba2-370m")
        params, _ = model.init(key)
        opt = OptConfig(name="sgd", lr=1e-2, grad_clip=None)
        batch = {
            "tokens": jax.random.randint(key, (8, 16), 0, model.cfg.vocab),
            "labels": jax.random.randint(key, (8, 16), 0, model.cfg.vocab),
        }
        dcfg = SSGDConfig(n_nodes=2, s_schedule="fixed", s_base=1.0)
        pol = DitherPolicy(variant="off")
        fn1, _ = make_ssgd_step(model, opt, dcfg, pol, grad_accum=1)
        fn4, _ = make_ssgd_step(model, opt, dcfg, pol, grad_accum=4)
        sb = shard_batch(batch, 2)
        p1, _, m1, _ = fn1(params, init_opt_state(params, opt), sb, key)
        p4, _, m4, _ = fn4(params, init_opt_state(params, opt), sb, key)
        assert float(m1["loss"]) == pytest.approx(float(m4["loss"]),
                                                  rel=1e-5)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=1e-6)
