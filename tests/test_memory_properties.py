"""Hypothesis property tests for the residual codecs.

Kept separate from test_memory.py: hypothesis ships in the [test] extra,
not as a hard dependency, and a bare module-level import would abort the
whole suite's collection under -x when it is absent (same policy as
test_nsd_properties.py). Adversarial surface: non-multiple-of-8 shapes
(the wire format's bitmap/padding path), all-zero tensors (empty bitmap),
int8/NSD clip saturation, and single-element tensors.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import nsd  # noqa: E402
from repro.memory import decode, encode, measured_bytes, resid_key  # noqa: E402


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(1, 9), cols=st.integers(1, 41),
       s=st.floats(0.25, 4.0), scale=st.floats(1e-3, 1e3),
       seed=st.integers(0, 2**31 - 1))
def test_property_nsd_codec_bit_exact_any_shape(rows, cols, s, scale, seed):
    """encode->decode == the nsd reference for ANY shape — including sizes
    that are no multiple of the chunk (or even of 8), which exercise the
    bitmap padding and the truncation back to the original size."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (rows, cols), jnp.float32) * scale
    k = resid_key(jax.random.fold_in(key, 1))
    mode = f"nsd@{s}"
    dec = decode(mode, encode(mode, x, k))
    ref = nsd.nsd_quantize(x, k, s)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(ref))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 700), seed=st.integers(0, 2**31 - 1))
def test_property_all_zero_tensor_empty_bitmap(n, seed):
    """An all-zero residual packs to an EMPTY bitmap (no set bits, nnz=0),
    decodes to exact zeros, and its measured bytes are the fixed overhead
    alone."""
    x = jnp.zeros((n,), jnp.float32)
    k = resid_key(jax.random.PRNGKey(seed))
    enc = encode("nsd", x, k)
    assert int(enc.nnz) == 0
    assert int(jnp.sum(enc.bitmap.astype(jnp.int32))) == 0
    np.testing.assert_array_equal(np.asarray(decode("nsd", enc)),
                                  np.zeros((n,), np.float32))
    fixed = 4 + enc.n_chunks * (4 + enc.chunk // 8)
    assert int(measured_bytes("nsd", enc)) == fixed


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(1, 6), cols=st.integers(2, 33),
       outlier=st.floats(1e3, 1e7), seed=st.integers(0, 2**31 - 1))
def test_property_int8_bound_survives_saturation(rows, cols, outlier, seed):
    """Affine per-row int8 with a huge outlier: the quantizer saturates its
    code range yet every element's error stays within scale/2 (the scale
    absorbs the outlier; the bound is per row, not global)."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (rows, cols), jnp.float32)
    x = x.at[0, 0].set(outlier)
    enc = encode("int8", x, key)
    assert int(jnp.max(enc.q.astype(jnp.int32))) == 127  # saturated code
    err = jnp.abs(decode("int8", enc) - x).reshape(-1, cols)
    assert float(jnp.max(err / (enc.scale / 2.0))) <= 1.001


@settings(max_examples=25, deadline=None)
@given(scale=st.floats(1.0, 1e4), s=st.floats(0.25, 1.0),
       seed=st.integers(0, 2**31 - 1))
def test_property_nsd_codec_matches_reference_under_clip(scale, s, seed):
    """Heavy-tailed inputs push |k| past INT8_CLIP: the clip applies
    identically inside the codec and the reference, so the round trip
    stays bit-exact even when saturating."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (64,), jnp.float32)
    x = x.at[0].set(float(scale) * 1e3)  # guarantees k clipping at small s
    k = resid_key(jax.random.fold_in(key, 1))
    mode = f"nsd@{s}"
    enc = encode(mode, x, k)
    np.testing.assert_array_equal(
        np.asarray(decode(mode, enc)),
        np.asarray(nsd.nsd_quantize(x, k, s)))
    assert int(jnp.max(jnp.abs(enc.levels.astype(jnp.int32)))) <= nsd.INT8_CLIP


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), cols=st.integers(1, 19))
def test_property_int8_constant_rows_exact(seed, cols):
    """Zero-range rows (scale guard) decode exactly."""
    val = float(jax.random.uniform(jax.random.PRNGKey(seed), ()) * 10 - 5)
    x = jnp.full((3, cols), val, jnp.float32)
    dec = decode("int8", encode("int8", x, jax.random.PRNGKey(seed)))
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(x))
