"""The paper's core claims about NSD (eqs. 4-6, fig. 1-2) as tests.

Hypothesis-based property tests live in test_nsd_properties.py so this
module stays collectable when hypothesis (a [test]-extra, not a hard
dependency) is absent. Monte-Carlo tolerances derive from the paper's
eq. 6 bound via tests/stat_utils.py — no hand-tuned fudge factors.
"""
import jax
import jax.numpy as jnp
import numpy as np
import stat_utils

from repro.core import nsd


class TestUnbiasedness:
    def test_mean_error_goes_to_zero(self, key):
        """E[eps] = 0 (paper eq. 5): the MC mean of x~ converges to x."""
        n_draws = 4000

        def check(k):
            x = jax.random.normal(k, (512,), jnp.float32)
            keys = jax.random.split(jax.random.fold_in(k, 1), n_draws)
            qs = jax.vmap(lambda kk: nsd.nsd_quantize(x, kk, 2.0))(keys)
            bias = jnp.mean(qs, axis=0) - x
            delta = nsd.compute_delta(x, 2.0)
            tol = stat_utils.mc_mean_tol(delta, n_draws)
            # per-element max over 512 elements gets wider headroom
            assert float(jnp.max(jnp.abs(bias))) < 5 * tol
            assert abs(float(jnp.mean(bias))) < tol

        stat_utils.retry_with_wider_seed(check)

    def test_variance_bound(self, key):
        """E[eps^2] < Delta^2/4 (paper eq. 6)."""
        x = jax.random.normal(key, (512,), jnp.float32)
        n_draws = 2000
        for s in (1.0, 2.0, 4.0):
            delta = nsd.compute_delta(x, s)
            keys = jax.random.split(jax.random.fold_in(key, 2), n_draws)
            qs = jax.vmap(lambda k: nsd.nsd_quantize(x, k, s))(keys)
            var = jnp.mean(jnp.square(qs - x))
            assert float(var) < stat_utils.variance_bound(
                delta, n_draws * 512), s


class TestSparsity:
    def test_sparsity_increases_with_s(self, key):
        """Paper fig. 2: P(0) grows with the scale factor."""
        x = jax.random.normal(key, (4096,), jnp.float32)
        sparsities = []
        for s in (0.5, 1.0, 2.0, 4.0, 8.0):
            q = nsd.nsd_quantize(x, jax.random.fold_in(key, 3), s)
            sparsities.append(float(jnp.mean(q == 0)))
        assert all(b >= a - 0.02 for a, b in zip(sparsities, sparsities[1:]))
        # s=8 on a gaussian is very sparse: theory gives ~0.89 (see
        # expected_sparsity_gaussian), so 0.85 leaves MC headroom
        assert sparsities[-1] > 0.85

    def test_matches_theoretical_gaussian_sparsity(self, key):
        """Measured sparsity ~ convolution integral of fig. 2 (MC version)."""
        x = jax.random.normal(key, (100_000,), jnp.float32)
        for s in (1.0, 2.0, 4.0):
            q = nsd.nsd_quantize(x, jax.random.fold_in(key, 4), s)
            measured = float(jnp.mean(q == 0))
            theory = nsd.expected_sparsity_gaussian(s)
            assert abs(measured - theory) < 0.02, (s, measured, theory)


class TestBitwidth:
    def test_nonzeros_fit_8_bits(self, key):
        """Paper fig. 6b: worst-case bit-width of non-zeros <= 8."""
        x = jax.random.normal(key, (8192,), jnp.float32) * 3.0
        for s in (1.0, 2.0):
            q = nsd.nsd_quantize_int8(x, jax.random.fold_in(key, 5), s)
            stats = nsd.quant_stats(q.k.astype(jnp.int32), q.delta)
            assert float(stats.max_bitwidth) <= 8.0

    def test_int8_roundtrip_exact(self, key):
        """Quantized values are exactly representable as k * Delta."""
        x = jax.random.normal(key, (1024,), jnp.float32)
        k1 = jax.random.fold_in(key, 6)
        q = nsd.nsd_quantize_int8(x, k1, 2.0)
        dense = nsd.nsd_quantize(x, k1, 2.0)
        np.testing.assert_allclose(np.asarray(q.dequantize()),
                                   np.asarray(dense), rtol=0, atol=0)


class TestEdgeCases:
    def test_zero_tensor(self, key):
        q = nsd.nsd_quantize(jnp.zeros((64,)), key, 2.0)
        assert float(jnp.max(jnp.abs(q))) == 0.0

    def test_constant_tensor(self, key):
        # std = 0 -> delta = 0 -> passthrough-to-zero guard, no NaN
        q = nsd.nsd_quantize(jnp.full((64,), 3.14), key, 2.0)
        assert bool(jnp.all(jnp.isfinite(q)))

    def test_bf16_input(self, key):
        x = jax.random.normal(key, (256,), jnp.bfloat16)
        q = nsd.nsd_quantize(x, key, 2.0)
        assert q.dtype == jnp.bfloat16
        assert bool(jnp.all(jnp.isfinite(q.astype(jnp.float32))))
