"""repro.obs: metrics bus, tracing, run logs, monitors, report.

Pins the tentpole contracts: one io_callback emission path with
drain-before-read semantics, the per-generation stacked-view cache (the
O(n^2) summary fix), strict-JSON run directories that round-trip, monitor
trip/rate-limit/escalation behavior, and the offline report rendering from
a run dir alone.
"""
import json
import logging
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs.bus import MetricsBus, get_bus, set_bus
from repro.obs.monitor import (LossMonitor, MonitorAlert, MonitorSuite,
                               SparsityMonitor, default_monitors)
from repro.obs.runlog import RunLog, read_run, run_obs
from repro.obs.streams import MetricStream
from repro.obs.trace import Tracer


@pytest.fixture
def bus():
    """Fresh default bus per test; the process default is restored after."""
    old = get_bus()
    b = set_bus(MetricsBus())
    yield b
    set_bus(old)


# ---------------------------------------------------------------------------
# streams + registry
# ---------------------------------------------------------------------------

class TestStreams:
    def test_builtin_schema(self, bus):
        schema = bus.registry.schema()
        assert schema["dither"] == ("sparsity", "bits", "delta")
        assert schema["phase"] == ("step", "duration_s")
        assert "comm" in schema and "memory" in schema

    def test_register_idempotent_by_value(self, bus):
        s = MetricStream("custom", ("a", "b"), "test stream")
        assert bus.registry.register(s) is not None
        bus.registry.register(MetricStream("custom", ("a", "b"), "test stream"))
        with pytest.raises(ValueError):
            bus.registry.register(MetricStream("custom", ("a", "c"), "other"))

    def test_invalid_stream_names(self):
        with pytest.raises(ValueError):
            MetricStream("", ("a",), "")
        with pytest.raises(ValueError):
            MetricStream("has/slash", ("a",), "")
        with pytest.raises(ValueError):
            MetricStream("nocols", (), "")

    def test_record_arity_validated(self, bus):
        with pytest.raises(ValueError):
            bus.record("dither", "t", [1.0, 2.0])  # needs 3 columns
        with pytest.raises(KeyError):
            bus.record("no_such_stream", "t", [1.0])


# ---------------------------------------------------------------------------
# bus: emission, ordering, caching
# ---------------------------------------------------------------------------

class TestBus:
    def test_emit_from_jit_lands_after_drain(self, bus):
        @jax.jit
        def f(x):
            get_bus().emit("dither", "L0", jnp.stack(
                [jnp.mean(x), jnp.float32(4.0), jnp.float32(0.5)]))
            return x * 2

        for i in range(3):
            f(jnp.float32(i))
        rows = bus.rows("dither", "L0")  # rows() drains first
        assert rows.shape == (3, 3)
        np.testing.assert_allclose(rows[:, 0], [0.0, 1.0, 2.0])

    def test_per_tag_ordering_preserved(self, bus):
        @jax.jit
        def f(v):
            get_bus().emit("train", "seq", jnp.stack([v, v * 10]))
            return v

        for i in range(20):
            f(jnp.float32(i))
        rows = bus.rows("train", "seq")
        np.testing.assert_allclose(rows[:, 0], np.arange(20, dtype=np.float32))

    def test_stacked_view_cached_per_generation(self, bus):
        """The O(n^2) re-stack fix: repeated reads of an unchanged tag hit
        the cache; only a new row invalidates it."""
        for i in range(50):
            bus.record("train", "t", [float(i), 0.0])
        assert bus.stack_calls == 0
        for _ in range(10):
            r = bus.rows("train", "t")
        assert r.shape == (50, 2)
        assert bus.stack_calls == 1  # one stack for ten reads
        bus.record("train", "t", [50.0, 0.0])
        assert bus.rows("train", "t").shape == (51, 2)
        assert bus.stack_calls == 2

    def test_rows_since_stacks_only_suffix(self, bus):
        for i in range(10):
            bus.record("train", "t", [float(i), 0.0])
        new = bus.rows_since("train", "t", 7)
        assert new.shape == (3, 2)
        np.testing.assert_allclose(new[:, 0], [7.0, 8.0, 9.0])
        assert bus.rows_since("train", "t", 10).shape == (0, 2)

    def test_concurrent_recorders(self, bus):
        """Many threads appending to distinct + shared tags: no rows lost,
        per-thread-tag order preserved."""
        n_threads, n_rows = 8, 200
        errs = []

        def worker(t):
            try:
                for i in range(n_rows):
                    bus.record("train", f"w{t}", [float(i), float(t)])
                    bus.record("train", "shared", [float(t), float(i)])
            except Exception as e:  # pragma: no cover
                errs.append(e)

        threads = [threading.Thread(target=worker, args=(t,))
                   for t in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert not errs
        assert bus.row_count("train", "shared") == n_threads * n_rows
        for t in range(n_threads):
            rows = bus.rows("train", f"w{t}")
            np.testing.assert_allclose(
                rows[:, 0], np.arange(n_rows, dtype=np.float32))

    def test_events_and_cursors(self, bus):
        bus.log_event({"kind": "a"})
        bus.log_event({"kind": "b"})
        assert [e["kind"] for e in bus.events()] == ["a", "b"]
        assert [e["kind"] for e in bus.events(1)] == ["b"]
        bus.record("train", "t", [0.0, 0.0])
        assert bus.cursors() == {("train", "t"): 1}


# ---------------------------------------------------------------------------
# core.stats compatibility shim
# ---------------------------------------------------------------------------

class TestStatsShim:
    def test_emit_and_summary_round_trip(self, bus):
        from repro.core import stats as statslib
        from repro.core.nsd import QuantStats

        @jax.jit
        def f(s):
            statslib.emit("fc0", QuantStats(
                sparsity=s, max_bitwidth=jnp.float32(4.0),
                delta=jnp.float32(0.25)))
            return s

        f(jnp.float32(0.75))
        summ = statslib.summary()
        assert summ["fc0"]["mean_sparsity"] == pytest.approx(0.75)
        assert summ["fc0"]["max_bits"] == pytest.approx(4.0)
        assert statslib.overall_sparsity() == pytest.approx(0.75)

    def test_reset_clears_bus(self, bus):
        from repro.core import stats as statslib

        bus.record(statslib.STREAM_DITHER, "x", [0.5, 4.0, 0.1])
        statslib.reset()
        assert statslib.summary() == {}

    def test_rows_since_window(self, bus):
        from repro.core import stats as statslib

        for i in range(5):
            bus.record(statslib.STREAM_DITHER, "x", [i / 10, 4.0, 0.1])
        win = statslib.rows_since("x", 3)
        assert win.shape == (2, 3)


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------

class TestTrace:
    def test_span_paths_and_step_stamp(self, bus):
        tr = Tracer(bus)
        tr.set_step(7)
        with tr.span("outer"):
            with tr.span("inner"):
                pass
        outer = bus.rows("phase", "outer")
        inner = bus.rows("phase", "outer/inner")
        assert outer.shape == (1, 2) and inner.shape == (1, 2)
        assert outer[0, 0] == 7 and inner[0, 0] == 7
        assert outer[0, 1] >= inner[0, 1] >= 0

    def test_span_records_on_exception(self, bus):
        tr = Tracer(bus)
        with pytest.raises(RuntimeError):
            with tr.span("boom"):
                raise RuntimeError("x")
        assert bus.row_count("phase", "boom") == 1
        # the stack unwound: a following span is top-level again
        with tr.span("after"):
            pass
        assert bus.row_count("phase", "after") == 1

    def test_annotate_inside_jit(self, bus):
        from repro.obs.trace import annotate

        @jax.jit
        def f(x):
            with annotate("step/grad"):
                return x * 2

        assert float(f(jnp.float32(3.0))) == 6.0


# ---------------------------------------------------------------------------
# run log: JSONL round-trip
# ---------------------------------------------------------------------------

class TestRunLog:
    def test_round_trip_strict_json(self, bus, tmp_path):
        rd = str(tmp_path / "run")
        rl = RunLog(rd, bus=bus, context={"tool": "test"})
        bus.record("dither", "fc0", [0.9, 4.0, 0.25])
        bus.record("train", "train", [1.0, float("nan")])  # -> null
        bus.log_event({"kind": "trip", "severity": "warning"})
        assert rl.flush() == 3
        assert rl.flush() == 0  # cursor-based: nothing new

        manifest, streams = read_run(rd)
        assert manifest["run_id"] == rl.run_id
        assert manifest["context"] == {"tool": "test"}
        assert manifest["streams"]["dither"] == ["sparsity", "bits", "delta"]
        assert streams["dither"] == [
            {"tag": "fc0", "sparsity": pytest.approx(0.9),
             "bits": 4.0, "delta": 0.25}]
        assert streams["train"][0]["loss"] is None  # NaN -> null
        assert streams["monitor"][0]["kind"] == "trip"
        # strict: no bare NaN/Infinity anywhere in the files
        for fname in os.listdir(rd):
            with open(os.path.join(rd, fname)) as f:
                text = f.read()
            assert "NaN" not in text and "Infinity" not in text

    def test_incremental_flush(self, bus, tmp_path):
        rl = RunLog(str(tmp_path / "run"), bus=bus)
        bus.record("train", "t", [0.0, 1.0])
        assert rl.flush() == 1
        bus.record("train", "t", [1.0, 2.0])
        bus.record("comm", "t", [10.0, 100.0])
        assert rl.flush() == 2
        _, streams = read_run(str(tmp_path / "run"))
        assert len(streams["train"]) == 2 and len(streams["comm"]) == 1

    def test_read_rejects_nonstrict_json(self, bus, tmp_path):
        rd = str(tmp_path / "run")
        RunLog(rd, bus=bus)
        with open(os.path.join(rd, "train.jsonl"), "w") as f:
            f.write('{"tag": "t", "step": 1, "loss": NaN}\n')
        with pytest.raises(ValueError):
            read_run(rd)


# ---------------------------------------------------------------------------
# monitors
# ---------------------------------------------------------------------------

class TestMonitors:
    def test_loss_monitor_critical_on_nonfinite(self, bus):
        mon = LossMonitor(bus=bus)
        bus.record("train", "train", [1.0, 2.5])
        assert mon.tick(1) == []
        bus.record("train", "train", [2.0, float("nan")])
        events = mon.tick(2)
        assert len(events) == 1
        assert events[0].severity == "critical"
        assert events[0].to_dict()["value"] is None  # strict-JSON safe

    def test_sparsity_monitor_trips_below_band(self, bus):
        mon = SparsityMonitor(setpoint=0.9, band=0.1, min_rows=3, bus=bus)
        for _ in range(3):
            bus.record("dither", "fc0", [0.95, 4.0, 0.1])
        assert mon.tick(1) == []  # healthy
        for _ in range(10):
            bus.record("dither", "fc0", [0.2, 4.0, 0.1])
        events = mon.tick(2)
        assert len(events) == 1 and events[0].kind == "sparsity_collapse"

    def test_suite_rate_limits_persistent_trips(self, bus):
        mon = SparsityMonitor(setpoint=0.9, band=0.1, min_rows=1, bus=bus)
        suite = MonitorSuite([mon], reemit_every=10, bus=bus)
        bus.record("dither", "fc0", [0.1, 4.0, 0.1])
        assert len(suite.tick(1)) == 1
        for s in range(2, 10):
            bus.record("dither", "fc0", [0.1, 4.0, 0.1])
            assert suite.tick(s) == []  # same condition, inside the window
        bus.record("dither", "fc0", [0.1, 4.0, 0.1])
        assert len(suite.tick(11)) == 1  # window elapsed: re-emit
        assert bus.event_count() == 2

    def test_suite_escalates_critical(self, bus):
        suite = MonitorSuite([LossMonitor(bus=bus)], escalate=True, bus=bus)
        bus.record("train", "train", [3.0, float("inf")])
        with pytest.raises(MonitorAlert):
            suite.tick(3)

    def test_default_monitors_setpoint_arms_sparsity(self, bus):
        kinds = {m.kind for m in default_monitors(bus=bus)}
        assert "sparsity_collapse" not in kinds
        kinds = {m.kind for m in default_monitors(sparsity_setpoint=0.9,
                                                  bus=bus)}
        assert "sparsity_collapse" in kinds


# ---------------------------------------------------------------------------
# report + RunObs
# ---------------------------------------------------------------------------

class TestReport:
    def test_render_from_run_dir_alone(self, bus, tmp_path):
        from repro.obs.report import render

        rd = str(tmp_path / "run")
        rl = RunLog(rd, bus=bus, context={"arch": "toy"})
        for i in range(4):
            bus.record("dither", "fc0", [0.9, 4.0, 0.25])
            bus.record("dither", "lm_head", [0.99, 5.0, 0.5])
            bus.record("comm", "step", [250.0, 1000.0])
            bus.record("memory", "fc0", [100.0, 120.0, 400.0])
            bus.record("train", "train", [float(i), 3.0 - 0.1 * i])
        tr = Tracer(bus)
        with tr.span("dispatch"):
            pass
        rl.close()
        set_bus(MetricsBus())  # prove the report needs no live bus
        text = render(rd)
        assert "fc0" in text and "lm_head" in text
        assert "ratio 0.2500" in text
        assert "dispatch" in text
        assert "arch: toy" in text

    def test_report_cli(self, bus, tmp_path):
        from repro.obs import report

        rd = str(tmp_path / "run")
        rl = RunLog(rd, bus=bus)
        bus.record("train", "train", [1.0, 2.0])
        rl.close()
        assert report.main([rd]) == 0

    def test_run_obs_lifecycle(self, bus, tmp_path):
        rd = str(tmp_path / "run")
        obs = run_obs(rd, context={"t": 1}, flush_every=2, bus=bus)
        obs.set_step(0)
        with obs.span("dispatch"):
            pass
        obs.on_step(1, {"loss": 1.5, "comm_wire_bytes": 10.0,
                        "comm_dense_bytes": 40.0})
        obs.on_step(2, {"loss": float("nan")})
        obs.finish()
        _, streams = read_run(rd)
        assert len(streams["train"]) == 2
        assert streams["train"][1]["loss"] is None
        assert len(streams["comm"]) == 1
        # the NaN loss tripped the default LossMonitor
        assert any(e["kind"] == "loss_nonfinite" for e in streams["monitor"])
        assert any(r["tag"] == "monitor" for r in streams["phase"])


# ---------------------------------------------------------------------------
# structured JSON logging
# ---------------------------------------------------------------------------

class TestJsonLogging:
    def test_json_mode_carries_context(self, monkeypatch):
        from repro.utils.logging import (JsonFormatter, get_logger,
                                         set_log_context)

        monkeypatch.setenv("REPRO_LOG_FORMAT", "json")
        log = get_logger("obs.test_json_mode")  # fresh name -> new handler
        assert isinstance(log.handlers[0].formatter, JsonFormatter)
        set_log_context(run_id="r123", step=7)
        try:
            rec = log.makeRecord("obs.test_json_mode", logging.INFO, "f", 1,
                                 "hello %s", ("world",), None)
            obj = json.loads(log.handlers[0].formatter.format(rec))
        finally:
            set_log_context(run_id=None, step=None)
        assert obj["msg"] == "hello world"
        assert obj["level"] == "INFO"
        assert obj["run_id"] == "r123" and obj["step"] == 7

    def test_default_mode_unchanged(self, monkeypatch):
        from repro.utils.logging import JsonFormatter, get_logger

        monkeypatch.delenv("REPRO_LOG_FORMAT", raising=False)
        log = get_logger("obs.test_default_mode")
        assert not isinstance(log.handlers[0].formatter, JsonFormatter)
