"""Policy programs: rule precedence, schedules, phases, controller,
validation error messages, and the zero-recompile pin for traced knobs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Const, DitherCtx, DitherPolicy, LayerRule, Linear,
                        PhaseSpec, Piecewise, PolicyProgram,
                        SparsityController, dense, meprop, parse_program)
from repro.obs import metrics as statslib
from repro.core.schedule import as_program, discover_layer_names


def _resolve_s(prog, name, step=0, ctrl=None):
    ctx = DitherCtx.for_step(jax.random.PRNGKey(0), step,
                             prog.phase_policy_at(step), program=prog,
                             ctrl=ctrl)
    r = ctx.resolve(name)
    return None if r is None else float(r.knobs[0])


class TestValidation:
    def test_policy_s_must_be_positive(self):
        with pytest.raises(ValueError, match="s must be > 0"):
            DitherPolicy(s=0.0)
        with pytest.raises(ValueError, match="s must be > 0"):
            DitherPolicy(s=-1.5)

    def test_policy_meprop_k_frac_range(self):
        with pytest.raises(ValueError,
                           match=r"meprop_k_frac must be in \(0, 1\]"):
            DitherPolicy(meprop_k_frac=0.0)
        with pytest.raises(ValueError,
                           match=r"meprop_k_frac must be in \(0, 1\]"):
            DitherPolicy(meprop_k_frac=1.5)
        DitherPolicy(meprop_k_frac=1.0)  # boundary is legal

    def test_policy_row_alpha_positive(self):
        with pytest.raises(ValueError, match="row_alpha must be > 0"):
            DitherPolicy(row_alpha=-0.1)

    def test_rule_validation_carries_pattern(self):
        with pytest.raises(ValueError, match=r"LayerRule\('fc1'\).*s must"):
            LayerRule(pattern="fc1", s=-2.0)
        with pytest.raises(ValueError, match="unknown variant"):
            LayerRule(pattern="fc1", variant="bogus")
        with pytest.raises(ValueError, match="pattern must be a non-empty"):
            LayerRule(pattern="")

    def test_schedule_validation(self):
        with pytest.raises(ValueError, match="strictly increasing"):
            Piecewise(((5, 1.0), (5, 2.0)))
        with pytest.raises(ValueError, match="end_step must be >"):
            Linear(10, 10, 1.0, 2.0)

    def test_schedule_values_range_checked(self):
        """A ramp cannot smuggle an illegal knob value past construction."""
        with pytest.raises(ValueError, match="s must be > 0"):
            PolicyProgram(s=Linear(0, 10, -4.0, 2.0))
        with pytest.raises(ValueError, match="s must be > 0"):
            LayerRule(pattern="fc", s=Piecewise(((0, 2.0), (5, 0.0))))
        with pytest.raises(ValueError,
                           match=r"meprop_k_frac must be in \(0, 1\]"):
            PolicyProgram(meprop_k_frac=Const(1.5))
        with pytest.raises(ValueError, match="row_alpha must be > 0"):
            parse_program("rule fc:row_alpha=lin(0,5,1.0,-1.0)")
        # legal endpoints pass whether float or schedule
        PolicyProgram(s=Linear(0, 10, 4.0, 0.5),
                      meprop_k_frac=Piecewise(((0, 0.2), (5, 0.05))))

    def test_phase_validation(self):
        with pytest.raises(ValueError, match="unknown variant"):
            PhaseSpec(0, "nope")
        with pytest.raises(ValueError, match="strictly increasing"):
            PolicyProgram(phases=(PhaseSpec(10, "paper"), PhaseSpec(5, "int8")))

    def test_controller_validation(self):
        with pytest.raises(ValueError, match=r"target must be in \(0, 1\)"):
            SparsityController(target=1.0)
        with pytest.raises(ValueError, match="gain must be > 0"):
            SparsityController(target=0.9, gain=0.0)
        with pytest.raises(ValueError, match="collect_stats=True"):
            PolicyProgram(base=DitherPolicy(),
                          controller=SparsityController(target=0.9))


class TestRules:
    def test_last_match_wins_per_knob(self):
        prog = PolicyProgram(
            base=DitherPolicy(variant="paper", s=2.0),
            rules=(LayerRule(pattern="fc", s=3.0, row_alpha=0.5),
                   LayerRule(pattern="fc1", s=4.0)))
        # fc1 matches both: s from the LAST rule, row_alpha survives from
        # the earlier one (per-knob layering)
        assert _resolve_s(prog, "fc1") == 4.0
        ctx = DitherCtx.for_step(jax.random.PRNGKey(0), 0, prog.base,
                                 program=prog)
        assert float(ctx.resolve("fc1").knobs[2]) == 0.5
        assert _resolve_s(prog, "fc0") == 3.0
        assert _resolve_s(prog, "other") == 2.0

    def test_glob_vs_substring(self):
        prog = PolicyProgram(
            base=DitherPolicy(s=2.0),
            rules=(LayerRule(pattern="L*.mlp.*", s=3.0),
                   LayerRule(pattern="attn", s=4.0)))
        assert _resolve_s(prog, "L3.mlp.up") == 3.0
        assert _resolve_s(prog, "L3.attn.q") == 4.0  # substring
        assert _resolve_s(prog, "mlp.up") == 2.0  # glob needs the L prefix

    def test_off_rule_excludes_layer(self):
        prog = PolicyProgram(base=DitherPolicy(),
                             rules=(LayerRule(pattern="lm_head",
                                              variant="off"),))
        assert _resolve_s(prog, "lm_head") is None
        assert _resolve_s(prog, "fc0") is not None

    def test_base_exclude_still_respected(self):
        prog = as_program(DitherPolicy(exclude=("lm_head",)))
        assert _resolve_s(prog, "my_lm_head") is None
        assert _resolve_s(prog, "fc0") is not None

    def test_universal_rule_matches_global_policy_bitwise(self, key):
        x = jax.random.normal(key, (8, 16))
        w = jax.random.normal(jax.random.fold_in(key, 1), (16, 24)) * 0.1
        pol = DitherPolicy(variant="paper", s=2.0)

        def grad_with(ctx):
            return jax.grad(lambda w: jnp.sum(
                dense(x, w, ctx=ctx, name="fc") ** 2))(w)

        g_global = grad_with(DitherCtx.for_step(key, 3, pol))
        prog = PolicyProgram(base=pol, rules=(LayerRule(),))
        g_prog = grad_with(DitherCtx.for_step(key, 3, pol, program=prog))
        np.testing.assert_array_equal(np.asarray(g_global),
                                      np.asarray(g_prog))


class TestSchedules:
    def test_piecewise_boundary_steps(self):
        sched = Piecewise(((0, 1.0), (5, 2.0), (9, 3.0)))
        vals = [float(sched.at(jnp.int32(i))) for i in (0, 4, 5, 8, 9, 100)]
        assert vals == [1.0, 1.0, 2.0, 2.0, 3.0, 3.0]

    def test_piecewise_clamps_before_first_boundary(self):
        sched = Piecewise(((10, 5.0),))
        assert float(sched.at(jnp.int32(0))) == 5.0

    def test_linear_endpoints_and_clamp(self):
        sched = Linear(10, 20, 4.0, 2.0)
        assert float(sched.at(jnp.int32(0))) == 4.0
        assert float(sched.at(jnp.int32(10))) == 4.0
        assert float(sched.at(jnp.int32(15))) == pytest.approx(3.0)
        assert float(sched.at(jnp.int32(20))) == 2.0
        assert float(sched.at(jnp.int32(999))) == 2.0

    def test_const_and_program_level_schedule(self):
        prog = PolicyProgram(base=DitherPolicy(s=2.0), s=Const(3.5))
        assert _resolve_s(prog, "fc") == 3.5

    def test_phase_policy_at(self):
        prog = PolicyProgram(
            base=DitherPolicy(variant="paper"),
            phases=(PhaseSpec(0, "off"), PhaseSpec(10, "paper"),
                    PhaseSpec(20, "int8")))
        assert prog.phase_policy_at(0).variant == "off"
        assert prog.phase_policy_at(9).variant == "off"
        assert prog.phase_policy_at(10).variant == "paper"
        assert prog.phase_policy_at(25).variant == "int8"
        assert prog.ever_enabled

    def test_meprop_traced_matches_static(self, key):
        g = jax.random.normal(key, (16, 64))
        for frac in (0.05, 0.1, 0.33, 1.0):
            a = meprop.meprop_sparsify(g, frac)
            b = meprop.meprop_sparsify(g, jnp.float32(frac))
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_unscheduled_meprop_frac_stays_static(self):
        """A constant k_frac rides StaticSpec (cheap top_k backward); only
        a real schedule pays the traced per-row sort path."""
        base = DitherPolicy(variant="meprop", meprop_k_frac=0.25)
        assert base.spec().meprop_k_static == 0.25
        assert DitherPolicy(variant="paper").spec().meprop_k_static is None
        ctx = DitherCtx.for_step(jax.random.PRNGKey(0), 0, base,
                                 program=PolicyProgram(base=base))
        assert ctx.resolve("fc").spec.meprop_k_static == 0.25
        sched = PolicyProgram(base=base,
                              meprop_k_frac=Piecewise(((0, 0.2), (5, 0.1))))
        ctx2 = DitherCtx.for_step(jax.random.PRNGKey(0), 0, base,
                                  program=sched)
        assert ctx2.resolve("fc").spec.meprop_k_static is None

    def test_off_base_with_enabling_rule(self):
        """--dither off + a rule that turns a layer on: the step must build
        a ctx, and only the rule's layers dither."""
        prog = PolicyProgram(base=DitherPolicy(variant="off"),
                             rules=(LayerRule(pattern="probe",
                                              variant="paper"),))
        assert prog.rules_enable
        assert prog.step_enabled(prog.phase_policy_at(0))
        assert _resolve_s(prog, "probe") is not None
        assert _resolve_s(prog, "other") is None


class TestCompileCounter:
    def test_s_ramp_causes_no_rejit(self, key):
        """The acceptance pin: a stepwise s ramp over a multi-step loop
        compiles the backward exactly once per layer shape."""
        x = jax.random.normal(key, (8, 16))
        prog = PolicyProgram(
            base=DitherPolicy(variant="paper", collect_stats=True,
                              stats_tag="cc/"),
            s=Piecewise(((0, 1.0), (2, 2.0), (4, 4.0))))
        traces = []

        @jax.jit
        def step(w, i, k):
            traces.append(1)  # appended at trace time only
            ctx = DitherCtx.for_step(k, i, prog.base, program=prog)
            # two layer shapes under one program
            def loss(w):
                h = dense(x, w["w1"], ctx=ctx, name="fc1")
                return jnp.sum(dense(h, w["w2"], ctx=ctx, name="fc2") ** 2)
            g = jax.grad(loss)(w)
            return jax.tree.map(lambda a, b: a - 0.01 * b, w, g)

        statslib.reset()
        w = {"w1": jax.random.normal(key, (16, 24)) * 0.1,
             "w2": jax.random.normal(jax.random.fold_in(key, 1), (24, 8)) * 0.1}
        for i in range(6):
            w = step(w, jnp.int32(i), key)
        assert len(traces) == 1, f"s ramp retraced {len(traces)} times"
        # and the ramp actually took effect: deltas differ across steps
        jax.effects_barrier()
        deltas = statslib.rows("cc/fc1")[:, 2]
        assert len(np.unique(np.round(deltas / deltas[0], 3))) >= 3, deltas

    def test_phase_switch_retraces_exactly_once(self, key):
        x = jax.random.normal(key, (8, 16))
        w = jax.random.normal(jax.random.fold_in(key, 1), (16, 8)) * 0.1
        prog = PolicyProgram(base=DitherPolicy(variant="paper"),
                             phases=(PhaseSpec(0, "paper"),
                                     PhaseSpec(3, "int8")))
        traces = []

        def step(w, i, k, phase):
            traces.append(1)
            ctx = DitherCtx.for_step(k, i, phase, program=prog)
            g = jax.grad(lambda w: jnp.sum(
                dense(x, w, ctx=ctx, name="fc") ** 2))(w)
            return w - 0.01 * g

        jit_step = jax.jit(step, static_argnames=("phase",))
        for i in range(6):
            w = jit_step(w, jnp.int32(i), key,
                         phase=prog.phase_policy_at(i))
        assert len(traces) == 2, traces

    def test_controller_state_update_causes_no_rejit(self, key):
        x = jax.random.normal(key, (8, 16))
        prog = PolicyProgram(
            base=DitherPolicy(variant="paper", collect_stats=True,
                              stats_tag="cr/"),
            controller=SparsityController(target=0.9))
        traces = []

        def step(w, i, k, ctrl):
            traces.append(1)
            ctx = DitherCtx.for_step(k, i, prog.base, program=prog,
                                     ctrl=ctrl)
            g = jax.grad(lambda w: jnp.sum(
                dense(x, w, ctx=ctx, name="fc") ** 2))(w)
            return w - 0.01 * g

        jit_step = jax.jit(step)
        w = jax.random.normal(key, (16, 8)) * 0.1
        ctrl = prog.controller.init_state(["fc"])
        for i in range(5):
            w = jit_step(w, jnp.int32(i), key, ctrl)
            ctrl = prog.controller.update(ctrl, {"fc": 0.5 + 0.05 * i})
        assert len(traces) == 1, traces


class TestController:
    def test_converges_on_synthetic_plant(self):
        """Integral control against a monotone sparsity(s) response."""
        ctl = SparsityController(target=0.9, gain=2.0)
        state = ctl.init_state(["a", "b"])

        def plant(log_scale, base):
            # monotone saturating response of sparsity to s = base*exp(ls)
            s = base * float(jnp.exp(log_scale))
            return 1.0 - float(np.exp(-0.9 * s))

        for _ in range(50):
            measured = {"a": plant(state["a"], 1.0),
                        "b": plant(state["b"], 4.0)}
            state = ctl.update(state, measured)
        assert abs(plant(state["a"], 1.0) - 0.9) < 0.03
        assert abs(plant(state["b"], 4.0) - 0.9) < 0.03

    def test_clips_to_scale_bounds(self):
        ctl = SparsityController(target=0.99, gain=50.0, min_scale=0.5,
                                 max_scale=2.0)
        state = ctl.init_state(["a"])
        state = ctl.update(state, {"a": 0.0})  # huge positive error
        assert float(state["a"]) == pytest.approx(np.log(2.0))
        state = ctl.update(state, {"a": 1.0})  # error the other way
        assert float(state["a"]) >= np.log(0.5) - 1e-6

    def test_unknown_layer_names_ignored(self):
        ctl = SparsityController(target=0.9)
        state = ctl.init_state(["a"])
        new = ctl.update(state, {"ghost": 0.1})
        assert set(new) == {"a"} and float(new["a"]) == 0.0

    def test_telemetry_window_incremental(self, key):
        """measure() consumes only new rows (O(new) per tick) and never
        re-reports a row."""
        from repro.core import DitherPolicy as DP
        from repro.core.schedule import TelemetryWindow
        statslib.reset()
        x = jax.random.normal(key, (8, 16))
        w = jax.random.normal(jax.random.fold_in(key, 1), (16, 8))
        win = TelemetryWindow("tw/")
        pol = DP(variant="paper", collect_stats=True, stats_tag="tw/")
        for i in range(3):
            ctx = DitherCtx.for_step(key, i, pol)
            jax.grad(lambda w: jnp.sum(dense(x, w, ctx=ctx, name="fc") ** 2)
                     )(w)
            m = win.measure()
            assert set(m) == {"fc"} and 0.0 <= m["fc"] <= 1.0
        assert win.measure() == {}  # nothing new
        assert statslib.row_count("tw/fc") == 3
        # a SECOND window (new run / in-process resume) must not consume the
        # first run's history: cursors are primed at construction
        win2 = TelemetryWindow("tw/")
        assert win2.measure() == {}
        ctx = DitherCtx.for_step(key, 99, pol)
        jax.grad(lambda w: jnp.sum(dense(x, w, ctx=ctx, name="fc") ** 2))(w)
        assert set(win2.measure()) == {"fc"}

    def test_discover_layer_names(self, key):
        def loss(p, b, ctx):
            h = dense(b, p["w1"], ctx=ctx, name="enc.fc1")
            return jnp.sum(dense(h, p["w2"], ctx=ctx, name="enc.fc2") ** 2)

        params = {"w1": jnp.zeros((16, 8)), "w2": jnp.zeros((8, 4))}
        batch = jnp.zeros((2, 16))
        assert discover_layer_names(loss, params, batch) == [
            "enc.fc1", "enc.fc2"]


class TestPhaseDefaults:
    """Per-phase knob defaults (ROADMAP PR-4 open item): a phase may set
    default s / meprop_k_frac / row_alpha; precedence stays
    base < phase default < program schedule < rule < controller."""

    def test_phase_default_applies_from_its_start(self):
        prog = PolicyProgram(
            base=DitherPolicy(variant="paper", s=2.0),
            phases=(PhaseSpec(0, "paper"), PhaseSpec(10, "paper", s=4.0)))
        assert prog.phase_policy_at(0).s == 2.0
        assert prog.phase_policy_at(9).s == 2.0
        assert prog.phase_policy_at(10).s == 4.0
        assert _resolve_s(prog, "fc", step=9) == 2.0
        assert _resolve_s(prog, "fc", step=10) == 4.0

    def test_defaults_inherit_through_later_phases(self):
        prog = PolicyProgram(
            base=DitherPolicy(variant="paper", s=2.0, row_alpha=1.0),
            phases=(PhaseSpec(0, "paper", s=3.0, row_alpha=0.5),
                    PhaseSpec(10, "int8"),  # sets nothing: s=3.0 persists
                    PhaseSpec(20, "int8", s=2.5)))
        assert prog.phase_policy_at(5).s == 3.0
        p15 = prog.phase_policy_at(15)
        assert p15.variant == "int8" and p15.s == 3.0 and p15.row_alpha == 0.5
        assert prog.phase_policy_at(25).s == 2.5

    def test_program_schedule_overrides_phase_default(self):
        prog = PolicyProgram(
            base=DitherPolicy(variant="paper", s=2.0),
            phases=(PhaseSpec(0, "paper", s=4.0),),
            s=Const(3.5))
        assert _resolve_s(prog, "fc", step=5) == 3.5

    def test_rule_overrides_schedule_and_phase_default(self):
        prog = PolicyProgram(
            base=DitherPolicy(variant="paper", s=2.0),
            phases=(PhaseSpec(0, "paper", s=4.0),),
            s=Const(3.5),
            rules=(LayerRule(pattern="fc0", s=1.5),))
        assert _resolve_s(prog, "fc0", step=5) == 1.5
        assert _resolve_s(prog, "fc1", step=5) == 3.5

    def test_no_defaults_returns_base_object(self):
        base = DitherPolicy(variant="paper", s=2.0)
        prog = PolicyProgram(base=base, phases=(PhaseSpec(0, "paper"),))
        assert prog.phase_policy_at(5) is base

    def test_phase_knob_validation(self):
        with pytest.raises(ValueError, match=r"PhaseSpec@5.*s must be > 0"):
            PhaseSpec(5, "paper", s=-1.0)
        with pytest.raises(ValueError,
                           match=r"meprop_k_frac must be in \(0, 1\]"):
            PhaseSpec(0, "meprop", meprop_k_frac=2.0)

    def test_parser_phase_defaults(self):
        prog = parse_program("phase@0=off;phase@10=paper,s=3.0,k_frac=0.2;"
                             "phase@20=int8,row_alpha=0.5")
        assert prog.phases == (
            PhaseSpec(0, "off"),
            PhaseSpec(10, "paper", s=3.0, meprop_k_frac=0.2),
            PhaseSpec(20, "int8", row_alpha=0.5))

    def test_parser_phase_errors(self):
        with pytest.raises(ValueError, match="unknown phase knob"):
            parse_program("phase@0=paper,wat=1.0")
        with pytest.raises(ValueError, match="unknown variant"):
            parse_program("phase@0=bogus,s=2.0")

    def test_meprop_phase_default_stays_static(self):
        """A phase's constant k_frac default keeps the cheap top_k path
        (meprop_k_static), like a base-policy constant."""
        prog = PolicyProgram(
            base=DitherPolicy(variant="meprop", meprop_k_frac=0.1),
            phases=(PhaseSpec(0, "meprop", meprop_k_frac=0.25),))
        pol = prog.phase_policy_at(0)
        ctx = DitherCtx.for_step(jax.random.PRNGKey(0), 0, pol, program=prog)
        assert ctx.resolve("fc").spec.meprop_k_static == 0.25


class TestParser:
    def test_full_spec_round_trip(self):
        prog = parse_program(
            "phase@0=off;phase@30=paper;s=lin(30,200,4.0,2.0);"
            "k_frac=step(0:0.1,50:0.05);rule lm_head:off;"
            "rule L*.mlp.*:s=3.0,row_alpha=0.5;"
            "controller:target=0.9,gain=3.0,min=0.5,max=2.0",
            base=DitherPolicy(collect_stats=True, stats_tag="p/"))
        assert prog.phases == (PhaseSpec(0, "off"), PhaseSpec(30, "paper"))
        assert prog.s == Linear(30, 200, 4.0, 2.0)
        assert prog.meprop_k_frac == Piecewise(((0, 0.1), (50, 0.05)))
        assert prog.rules[0] == LayerRule(pattern="lm_head", variant="off")
        assert prog.rules[1].s == 3.0 and prog.rules[1].row_alpha == 0.5
        assert prog.controller == SparsityController(
            target=0.9, gain=3.0, min_scale=0.5, max_scale=2.0)

    def test_controller_forces_collect_stats(self):
        prog = parse_program("controller:target=0.9")
        assert prog.base.collect_stats

    def test_parse_errors_name_the_clause(self):
        with pytest.raises(ValueError, match="cannot parse clause 'bogus'"):
            parse_program("bogus")
        with pytest.raises(ValueError, match=r"lin\(\) takes"):
            parse_program("s=lin(1,2)")
        with pytest.raises(ValueError, match="unknown rule key"):
            parse_program("rule fc:wat=1")
        with pytest.raises(ValueError, match="controller needs target"):
            parse_program("controller:gain=2.0")

    def test_program_is_hashable_static_arg(self):
        prog = parse_program("s=lin(0,10,4.0,2.0);rule fc:off")
        assert hash(prog) == hash(parse_program("s=lin(0,10,4.0,2.0);rule fc:off"))
        d = {prog: 1}
        assert d[prog] == 1
