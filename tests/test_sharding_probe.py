"""Sharding rules, the pre-activation-gradient probe, int8 fwd, and the
serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

from repro.core import int8 as int8lib
from repro.core import probe
from repro.launch.mesh import host_device_mesh
from repro.parallel import axes as axlib


class TestRules:
    def _rules(self):
        mesh = host_device_mesh(n_model=1)  # 1 device: every axis size 1
        return axlib.tp_dp_rules(mesh)

    def test_divisibility_fallback(self):
        mesh = host_device_mesh(n_model=1)
        rules = axlib.Rules({"heads": "model"}, mesh)
        # axis of size 1 -> no sharding
        assert rules.pspec(("heads",), (40,)) == PartitionSpec(None)

    def test_pspec_no_duplicate_axes(self):
        mesh = host_device_mesh(n_model=1)
        rules = axlib.Rules({"a": "data", "b": "data"}, mesh)
        spec = rules.pspec(("a", "b"), (8, 8))
        # one mesh axis must not shard two dims
        used = [s for s in spec if s is not None]
        assert len(used) == len(set(used))

    def test_rank_mismatch_replicates(self):
        mesh = host_device_mesh(n_model=1)
        rules = axlib.tp_dp_rules(mesh)
        sh = axlib.spec_tree_to_shardings(
            {"w": ("embed", "mlp")}, rules, {"w": jnp.zeros(())})
        assert sh["w"].spec == PartitionSpec()

    def test_shard_act_noop_without_rules(self):
        x = jnp.ones((4, 4))
        y = axlib.shard_act(x, ("batch", "seq"))
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestProbe:
    def test_tap_gives_exact_preactivation_grad(self, key):
        """d(loss)/d(tap) == delta_z computed by hand."""
        w1 = jax.random.normal(key, (8, 16)) * 0.3
        w2 = jax.random.normal(jax.random.fold_in(key, 1), (16, 4)) * 0.3
        x = jax.random.normal(jax.random.fold_in(key, 2), (5, 8))

        def loss_fn(params, taps=None):
            z1 = probe.tap(x @ params["w1"], taps, "z1")
            h = jax.nn.relu(z1)
            z2 = h @ params["w2"]
            return jnp.sum(z2 ** 2)

        taps = probe.make_taps({"z1": (5, 16)})
        g = probe.grad_wrt_taps(lambda p, taps: loss_fn(p, taps),
                                taps, {"w1": w1, "w2": w2})
        # hand-computed: dL/dz1 = (dL/dh) * relu'(z1); dL/dh = 2 z2 w2^T
        z1 = x @ w1
        h = jax.nn.relu(z1)
        z2 = h @ w2
        dz1 = (2 * z2 @ w2.T) * (z1 > 0)
        np.testing.assert_allclose(np.asarray(g["z1"]), np.asarray(dz1),
                                   rtol=1e-5)

    def test_layer_nsd_stats(self, key):
        g = jax.random.normal(key, (64, 64)) * 0.01
        st = probe.layer_nsd_stats(g, key, 2.0)
        assert 0.3 < float(st.sparsity) < 0.9
        assert float(st.max_bitwidth) <= 8


class TestInt8Forward:
    def test_quantize_bounds(self, key):
        x = jax.random.normal(key, (256,)) * 10
        q = int8lib.quantize_int8(x)
        assert int(jnp.max(jnp.abs(q.q.astype(jnp.int32)))) <= 127
        rel = float(jnp.max(jnp.abs(q.q * q.scale - x)))
        assert rel <= float(q.scale) * 0.5 + 1e-6

    def test_int8_matmul_close(self, key):
        x = jax.random.normal(key, (32, 64))
        w = jax.random.normal(jax.random.fold_in(key, 1), (64, 16))
        y = int8lib.int8_matmul(int8lib.quantize_int8(x),
                                int8lib.quantize_int8(w))
        rel = float(jnp.linalg.norm(y - x @ w) / jnp.linalg.norm(x @ w))
        assert rel < 0.03, rel

    def test_ste_grads_exact(self, key):
        x = jax.random.normal(key, (8, 16))
        w = jax.random.normal(jax.random.fold_in(key, 1), (16, 4))
        g = jax.grad(lambda w: jnp.sum(int8lib.int8_dense_ste(x, w)))(w)
        g_ref = jax.grad(lambda w: jnp.sum(x @ w))(w)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                                   rtol=1e-5)

    def test_range_batchnorm(self, key):
        x = jax.random.normal(key, (128, 16)) * 3 + 1
        y = int8lib.range_batchnorm(x, jnp.ones((16,)), jnp.zeros((16,)))
        assert abs(float(jnp.mean(y))) < 0.05
        # range-normalized std is approximately 1 for gaussian data
        assert 0.5 < float(jnp.std(y)) < 1.5


class TestServeEngine:
    def test_engine_serves_batch(self, key):
        from repro.configs import get_smoke_model
        from repro.serve import Engine, Request, ServeConfig

        model = get_smoke_model("gemma-2b")
        params, _ = model.init(key)
        eng = Engine(model, params, ServeConfig(max_batch=4, max_len=64))
        rng = np.random.default_rng(0)
        for uid in range(3):
            eng.submit(Request(uid=uid,
                               prompt=rng.integers(0, 100, size=3),
                               max_new_tokens=4))
        done = eng.run(max_ticks=16)
        assert len(done) == 3
        assert all(len(t) == 4 for t in done.values())
        vocab = model.cfg.vocab
        assert all(0 <= tok < vocab for t in done.values() for tok in t)
