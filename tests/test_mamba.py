"""Mamba-2 SSD: the chunked algorithm vs a naive per-step recurrence oracle,
and decode-vs-train consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mamba as M


def _naive_ssd(x, dt, A, Bm, Cm):
    """Direct recurrence: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T."""
    Bsz, S, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)
    Ch = jnp.repeat(Cm, rep, axis=2)
    h = jnp.zeros((Bsz, H, N, Pd))
    ys = []
    for t in range(S):
        decay = jnp.exp(dt[:, t] * A)  # (B,H)
        h = h * decay[:, :, None, None] + jnp.einsum(
            "bh,bhn,bhp->bhnp", dt[:, t], Bh[:, t], x[:, t])
        ys.append(jnp.einsum("bhn,bhnp->bhp", Ch[:, t], h))
    return jnp.stack(ys, axis=1), h


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_ssd_matches_recurrence(key, chunk):
    Bsz, S, H, Pd, G, N = 2, 16, 4, 8, 2, 6
    cfg = M.SSMConfig(d_model=32, d_inner=H * Pd, head_dim=Pd, d_state=N,
                      n_groups=G, chunk=chunk)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (Bsz, S, H, Pd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (Bsz, S, G, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(key, 9), (Bsz, S, G, N)) * 0.5
    y_chunk, h_chunk = M._ssd_chunked(x, dt, A, Bm, Cm, cfg)
    y_naive, h_naive = _naive_ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               rtol=1e-4, atol=1e-4)
    # h_final layout (B,H,N,P)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_naive),
                               rtol=1e-4, atol=1e-4)


def test_mixer_decode_matches_train(key):
    """Feeding a sequence token-by-token through the decode step must
    reproduce the train-mode mixer outputs."""
    cfg = M.SSMConfig(d_model=16, d_inner=32, head_dim=8, d_state=6,
                      n_groups=1, chunk=4)
    params, _ = M.init_mamba_mixer(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, 16)) * 0.5
    y_train = M.mamba_mixer(params, x, cfg)
    cache = M.MambaCache.init(cfg, 2, jnp.float32)
    ys = []
    for t in range(8):
        y, cache = M.mamba_decode_step(params, x[:, t:t + 1], cache, cfg)
        ys.append(y)
    y_decode = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_decode), np.asarray(y_train),
                               rtol=2e-3, atol=2e-3)


def test_full_ssm_lm_decode_matches_forward(key):
    cfg = M.SSMLMConfig(
        name="t", n_layers=2, vocab=64,
        ssm=M.SSMConfig(d_model=16, d_inner=32, head_dim=8, d_state=6,
                        chunk=4),
        dtype=jnp.float32, remat=False)
    params, _ = M.init_ssm_lm(key, cfg)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (1, 8), 0, 64)
    logits_train, _ = M.forward(params, cfg, toks)
    cache = M.init_cache(cfg, 1, 8)
    outs = []
    for t in range(8):
        lg, cache = M.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                  jnp.asarray(t))
        outs.append(lg)
    logits_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_train), rtol=2e-3,
                               atol=2e-3)


def test_chunked_ssd_non_divisible_seq(key):
    """Seq not divisible by chunk (e.g. hymba's +meta_tokens prefill) must
    pad exactly — regression for the 32896 % 256 != 0 dry-run failure."""
    cfg = M.SSMConfig(d_model=32, d_inner=32, head_dim=8, d_state=6,
                      n_groups=2, chunk=8)
    ks = jax.random.split(key, 5)
    Bsz, S, H, Pd, G, N = 2, 13, 4, 8, 2, 6
    x = jax.random.normal(ks[0], (Bsz, S, H, Pd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (Bsz, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (Bsz, S, G, N)) * 0.5
    y_c, h_c = M._ssd_chunked(x, dt, A, Bm, Cm, cfg)
    y_n, h_n = _naive_ssd(x, dt, A, Bm, Cm)
    assert y_c.shape == (Bsz, S, H, Pd)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_n), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_n), rtol=1e-4,
                               atol=1e-4)
