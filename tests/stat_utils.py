"""Shared statistical assertion helpers for the NSD test-suite.

The paper's eq. 6 bounds the NSD quantization error's second moment:
E[eps^2] < Delta^2 / 4. Every Monte-Carlo tolerance in the suite should
derive from that bound instead of hand-tuned constants — ad-hoc "* 1.05"
factors scattered across files are how flaky tests are born. This module
is the single place those derivations live:

  * ``mc_mean_tol``      tolerance for the mean of n error draws
                         (std of the MC mean <= (Delta/2)/sqrt(n))
  * ``variance_bound``   the eq. 6 right-hand side with explicit MC slack
  * ``assert_within_bound``  pointwise |err| <= bound with only f32
                         arithmetic headroom (the telemetry bounds from
                         repro.comm are deterministic, not statistical)
  * ``retry_with_wider_seed``  escape hatch for genuinely statistical
                         checks: re-run on the next FIXED seed rather
                         than widening the tolerance. A test that fails
                         all listed seeds is broken, not unlucky.

Not collected by pytest (no ``test_`` prefix); import as ``stat_utils``.
"""
from __future__ import annotations

import math
from typing import Callable, Sequence, Tuple

import jax

# Multiplicative headroom for f32 accumulation error when asserting a
# measured value against an analytically exact bound. NOT a statistical
# fudge factor — use mc_mean_tol/variance_bound for those.
BOUND_SLACK = 1.001


def fixed_key(seed: int = 0) -> jax.Array:
    """The suite's canonical fixed-seed PRNG key."""
    return jax.random.PRNGKey(seed)


def mc_mean_tol(delta: float, n_draws: int, n_sigma: float = 5.0) -> float:
    """Tolerance for the Monte-Carlo mean of n_draws NSD errors.

    Eq. 6 gives Var[eps] < Delta^2/4, so the std of the mean of n draws is
    below (Delta/2)/sqrt(n); ``n_sigma`` standard deviations of headroom
    makes a false failure astronomically unlikely at fixed seed.
    """
    return n_sigma * float(delta) / 2.0 / math.sqrt(n_draws)


def variance_bound(delta: float, n_draws: int = 0,
                   n_sigma: float = 5.0) -> float:
    """Upper bound to assert an MC estimate of E[eps^2] against.

    The population bound is Delta^2/4 (eq. 6, strict). A finite-sample
    estimate fluctuates around the true value, so allow n_sigma sampling
    std-devs on top: Var of the mean of n draws of eps^2 is at most
    E[eps^4]/n <= Delta^4/16/n (|eps| <= Delta/2 pointwise).
    """
    b = float(delta) ** 2 / 4.0
    if n_draws:
        b += n_sigma * b / math.sqrt(n_draws)
    return b


def assert_within_bound(err, bound, slack: float = BOUND_SLACK,
                        msg: str = "") -> None:
    """Pointwise |err| <= bound, with f32-arithmetic headroom only."""
    e, b = float(err), float(bound)
    assert e <= b * slack, (msg, e, b)


def retry_with_wider_seed(check: Callable[[jax.Array], None],
                          seeds: Sequence[int] = (0, 1, 2)
                          ) -> Tuple[int, int]:
    """Run ``check(key)`` on successive fixed seeds; pass on the first
    success. Returns (passing seed, attempts). A genuinely statistical
    test drawing a 5-sigma outlier at one seed passes at the next; a
    broken invariant fails all of them and surfaces the last error.
    """
    last = None
    for i, seed in enumerate(seeds):
        try:
            check(jax.random.PRNGKey(seed))
            return seed, i + 1
        except AssertionError as e:  # noqa: PERF203 — retry is the point
            last = e
    raise AssertionError(
        f"failed for all fixed seeds {tuple(seeds)}; this is not MC "
        f"noise. Last failure: {last}")
