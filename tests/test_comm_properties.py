"""Hypothesis property tests for the comm subsystem over adversarial
inputs: non-chunk-multiple lengths, all-zero tensors, single-outlier
tensors that hit the INT8_CLIP guard, and the N=1 short-circuit — for the
wire format and BOTH compressed reduces (flat ring + hierarchy).

Kept separate from test_comm.py in the test_nsd_properties.py style:
hypothesis ships in the [test] extra, not as a hard dependency, and a
bare module-level import would abort the whole suite's collection under
-x when it is absent.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import stat_utils

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.comm import (HierConfig, RingConfig, hier_allreduce_nsd,  # noqa: E402
                        pack_nsd, ring_allreduce_nsd, unpack_nsd)
from repro.core import nsd  # noqa: E402


def _make_tensor(kind: str, n: int, seed: int) -> jax.Array:
    key = jax.random.PRNGKey(seed)
    if kind == "zero":
        return jnp.zeros((n,), jnp.float32)
    x = jax.random.normal(key, (n,), jnp.float32)
    if kind == "outlier":
        # one huge spike: its index k = outlier/Delta would overflow int8
        # by orders of magnitude without the INT8_CLIP guard
        x = x.at[0].set(1e6)
    return x


@settings(max_examples=20, deadline=None)
@given(kind=st.sampled_from(["normal", "zero", "outlier"]),
       n=st.integers(1, 700),  # almost never a chunk (256) multiple
       s=st.floats(0.5, 8.0),
       seed=st.integers(0, 2**31 - 1))
def test_property_wireformat_roundtrip_adversarial(kind, n, s, seed):
    """unpack(pack(x)) == nsd_quantize_int8(x).dequantize() bit-exactly
    for ANY length/content, including the clip guard path."""
    x = _make_tensor(kind, n, seed)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), 1)
    p = pack_nsd(x, key, s)
    want = nsd.nsd_quantize_int8(x, key, s).dequantize()
    np.testing.assert_array_equal(np.asarray(unpack_nsd(p)),
                                  np.asarray(want))
    if kind == "outlier":
        # int8 safety: no level escapes the clip guard, whatever the spike
        # (the guaranteed clip-saturation case is tier-1:
        # test_comm.py::TestWireFormat::test_outlier_hits_int8_clip_guard)
        assert int(jnp.max(jnp.abs(p.levels))) <= nsd.INT8_CLIP
    if kind == "zero":
        assert int(p.nnz) == 0


@settings(max_examples=12, deadline=None)
@given(kind=st.sampled_from(["normal", "zero", "outlier"]),
       n_nodes=st.integers(1, 5),
       n=st.integers(1, 600),
       seed=st.integers(0, 2**31 - 1))
def test_property_ring_within_bound(kind, n_nodes, n, seed):
    """The flat ring's result stays within its reported pointwise bound
    for adversarial inputs; N=1 short-circuits exactly with no wire."""
    gs = jnp.stack([_make_tensor(kind, n, seed + i)
                    for i in range(n_nodes)])
    key = jax.random.PRNGKey(seed)
    mean, tele = ring_allreduce_nsd(gs, key, RingConfig(s=2.0))
    dense = jnp.mean(gs, axis=0)
    if n_nodes == 1:
        np.testing.assert_array_equal(np.asarray(mean), np.asarray(gs[0]))
        assert float(tele.wire_bytes) == 0.0
        return
    stat_utils.assert_within_bound(
        jnp.max(jnp.abs(mean - dense)), tele.error_bound,
        msg=f"{kind} n={n} nodes={n_nodes}")
    assert float(tele.wire_bytes) > 0.0


@settings(max_examples=12, deadline=None)
@given(kind=st.sampled_from(["normal", "zero", "outlier"]),
       pods=st.integers(1, 3),
       per_pod=st.integers(1, 3),
       n=st.integers(1, 600),
       seed=st.integers(0, 2**31 - 1))
def test_property_hier_within_bound(kind, pods, per_pod, n, seed):
    """The hierarchical reduce holds the same contract for every (G, P)
    split, including non-power-of-two pod counts and degenerate axes."""
    n_nodes = pods * per_pod
    gs = jnp.stack([_make_tensor(kind, n, seed + i)
                    for i in range(n_nodes)])
    key = jax.random.PRNGKey(seed)
    mean, tele = hier_allreduce_nsd(gs, key, HierConfig(pods=pods, s=2.0))
    dense = jnp.mean(gs, axis=0)
    if n_nodes == 1:
        np.testing.assert_array_equal(np.asarray(mean), np.asarray(gs[0]))
        assert float(tele.wire_bytes) == 0.0
        return
    stat_utils.assert_within_bound(
        jnp.max(jnp.abs(mean - dense)), tele.error_bound,
        msg=f"{kind} n={n} G={pods} P={per_pod}")
    assert float(tele.wire_ici_bytes) + float(tele.wire_dcn_bytes) == \
        float(tele.wire_bytes)
