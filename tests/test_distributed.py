"""Distributed dithered training (paper §3.6/§4.3): noise cancellation with
N nodes, s(N) scaling, comm-compression analogues, sharded pjit step."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_model
from repro.core import DitherPolicy, nsd
from repro.obs import metrics as statslib
from repro.distributed import (SSGDConfig, int8_allreduce_sim, make_ssgd_step,
                               shard_batch, topk_error_feedback)
from repro.optim import OptConfig, init_opt_state


def _tiny_lm():
    return get_smoke_model("mamba2-370m")


class TestSSGD:
    def test_noise_cancels_with_more_nodes(self, key):
        """Variance of the server-side averaged gradient drops with N (the
        paper's cancellation argument), at FIXED s and FIXED per-node batch.

        Weak scaling is essential here: per-node Delta is s * std of the
        per-node gradient, so shrinking sub-batches (strong scaling) RAISES
        per-node Delta and the averaging cannot win — the paper's setup is
        each node bringing its own data. The batch is held constant across
        trials, so the trial-to-trial variance isolates the dither noise."""
        model = _tiny_lm()
        params, _ = model.init(key)
        full = {
            "tokens": jax.random.randint(key, (8, 16), 0, model.cfg.vocab),
            "labels": jax.random.randint(key, (8, 16), 0, model.cfg.vocab),
        }
        opt = OptConfig(lr=0.0, grad_clip=None)  # lr 0: inspect grads only

        def avg_grad_var(n_nodes, per_node=2, n_trials=6):
            batch = {k: v[: n_nodes * per_node] for k, v in full.items()}
            dcfg = SSGDConfig(n_nodes=n_nodes, s_schedule="fixed", s_base=3.0)
            step_fn, _ = make_ssgd_step(model, opt, dcfg,
                                        DitherPolicy(variant="paper"))
            sb = shard_batch(batch, n_nodes)
            grads = []
            for trial in range(n_trials):
                state = init_opt_state(params, opt)
                bk = jax.random.fold_in(key, 100 + trial)
                _, st, _, _ = step_fn(params, state, sb, bk)
                grads.append(st["mu"])  # momentum buffer == grads at step 1
            flat = [jnp.concatenate([g.reshape(-1) for g in
                                     jax.tree.leaves(t)]) for t in grads]
            stack = jnp.stack(flat)
            return float(jnp.mean(jnp.var(stack, axis=0)))

        v1, v4 = avg_grad_var(1), avg_grad_var(4)
        # per-node dither noise is i.i.d. (per-worker keys), so the server
        # average cancels it; the margin is large (~10x), not statistical
        assert v4 < v1 / 2, (v1, v4)

    def test_sparsity_grows_with_nodes(self, key):
        """Paper fig. 6a: s(N) scaling raises per-node sparsity with N."""
        model = _tiny_lm()
        params, _ = model.init(key)
        batch = {
            "tokens": jax.random.randint(key, (8, 16), 0, model.cfg.vocab),
            "labels": jax.random.randint(key, (8, 16), 0, model.cfg.vocab),
        }
        opt = OptConfig(lr=1e-3)
        sparsities = {}
        for n in (1, 4):
            statslib.reset()
            dcfg = SSGDConfig(n_nodes=n, s_schedule="linear", s_base=1.0)
            pol = DitherPolicy(variant="paper", collect_stats=True,
                               stats_tag=f"n{n}/")
            step_fn, used_policy = make_ssgd_step(model, opt, dcfg, pol)
            assert used_policy.s == pytest.approx(n * 1.0)
            state = init_opt_state(params, opt)
            step_fn(params, state, shard_batch(batch, n), key)
            # telemetry arrives via async io_callback: block before reading
            jax.effects_barrier()
            sparsities[n] = statslib.overall_sparsity()
        assert sparsities[4] > sparsities[1], sparsities

    def test_loss_still_decreases_with_dither_at_n4(self, key):
        model = _tiny_lm()
        from repro.data import TokenStreamConfig, token_batch
        tcfg = TokenStreamConfig(vocab=model.cfg.vocab, seq_len=16, batch=8)
        opt = OptConfig(lr=1e-3)
        dcfg = SSGDConfig(n_nodes=4, s_schedule="sqrt", s_base=1.0)
        step_fn, _ = make_ssgd_step(model, opt, dcfg,
                                    DitherPolicy(variant="paper"))
        params, _ = model.init(key)
        state = init_opt_state(params, opt)
        losses = []
        for i in range(25):
            sb = shard_batch(token_batch(tcfg, i), 4)
            params, state, m, _ = step_fn(params, state, sb, key)
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05, losses


class TestCompression:
    def test_int8_allreduce_error_bounded(self, key):
        gs = [jax.random.normal(jax.random.fold_in(key, i), (1024,))
              for i in range(8)]
        avg = sum(gs) / 8
        approx = int8_allreduce_sim(gs, key)
        delta = float(nsd.compute_delta(gs[0], 1.0))
        err = float(jnp.max(jnp.abs(approx - avg)))
        # unbiased per-node errors, bounded by delta; average shrinks them
        assert err < delta * 2.0

    def test_error_feedback_recovers_mass(self, key):
        g = jax.random.normal(key, (512,))
        state = None
        sent_total = jnp.zeros_like(g)
        for _ in range(50):
            sent, state = topk_error_feedback(g, state, k_frac=0.05)
            sent_total = sent_total + sent
        # after many rounds the cumulative sent mass approximates 50*g;
        # the steady-state residual for always-small coordinates keeps the
        # error away from 0 but it must be bounded and much smaller than
        # plain (no-feedback) top-k, which would lose 1-k_frac of the mass
        rel = float(jnp.linalg.norm(sent_total / 50 - g)
                    / jnp.linalg.norm(g))
        assert rel < 0.3, rel
        no_feedback = 1.0 - 0.05  # mass lost by plain top-k each round
        assert rel < no_feedback / 2


PJIT_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke_model
    from repro.core import DitherPolicy
    from repro.launch.steps import make_train_step
    from repro.optim import OptConfig, init_opt_state, opt_state_specs
    from repro.parallel import axes as axlib

    from repro.launch import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    model = get_smoke_model("qwen2.5-32b")
    key = jax.random.PRNGKey(0)
    rules = axlib.tp_dp_rules(mesh)
    with axlib.use_rules(rules):
        params, specs = model.init(key)
        opt_cfg = OptConfig(lr=1e-3)
        opt_state = init_opt_state(params, opt_cfg)
        shardings = axlib.spec_tree_to_shardings(specs, rules, params)
        params = jax.device_put(params, shardings)
        batch = {
            "tokens": jax.random.randint(key, (8, 16), 0, model.cfg.vocab),
            "labels": jax.random.randint(key, (8, 16), 0, model.cfg.vocab),
        }
        batch = {k: jax.device_put(v, NamedSharding(mesh, P("data", None)))
                 for k, v in batch.items()}
        step = jax.jit(make_train_step(model, opt_cfg,
                                       DitherPolicy(variant="paper")))
        p2, o2, m = step(params, opt_state, batch, key)
        p3, o3, m2 = step(p2, o2, batch, key)
    assert float(m2["loss"]) > 0 and float(m2["loss"]) < 20
    # dithered sharded step must equal itself deterministically
    print("PJIT_OK", float(m["loss"]), float(m2["loss"]))
""")


def test_sharded_dithered_train_step_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")))
    out = subprocess.run([sys.executable, "-c", PJIT_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "PJIT_OK" in out.stdout, out.stdout + out.stderr


class TestSSGDMemoryPolicy:
    """make_ssgd_step(memory=...) threads the residual-memory policy into
    every node's DitherCtx exactly as the Trainer / make_train_step path
    does (PR: obs subsystem satellite)."""

    def _setup(self, key):
        model = _tiny_lm()
        params, _ = model.init(key)
        opt = OptConfig(name="sgd", lr=1e-2, grad_clip=None)
        batch = {
            "tokens": jax.random.randint(key, (4, 16), 0, model.cfg.vocab),
            "labels": jax.random.randint(key, (4, 16), 0, model.cfg.vocab),
        }
        return model, params, opt, batch

    def test_single_node_parity_with_train_step(self, key):
        """n_nodes=1 ssgd step == make_train_step, same memory policy."""
        from repro.launch.steps import make_train_step

        model, params, opt, batch = self._setup(key)
        pol = DitherPolicy(variant="paper", s=1.5)
        mem = "default=nsd"
        dcfg = SSGDConfig(n_nodes=1, s_schedule="fixed", s_base=1.5)

        ssgd_fn, _ = make_ssgd_step(model, opt, dcfg, pol, memory=mem)
        train_fn = jax.jit(make_train_step(model, opt, pol, memory=mem))

        bk = jax.random.fold_in(key, 7)
        st = init_opt_state(params, opt)
        p_a, _, m_a, _ = ssgd_fn(params, st, shard_batch(batch, 1), bk)
        st = init_opt_state(params, opt)
        p_b, _, m_b = train_fn(params, st, batch, bk)

        assert float(m_a["loss"]) == pytest.approx(float(m_b["loss"]),
                                                   rel=1e-6)
        for a, b in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_b)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)

    def test_memory_policy_changes_backward(self, key):
        """An int8 residual codec must actually reach the backward pass:
        the step's gradients differ from the fp32-residual run."""
        model, params, opt, batch = self._setup(key)
        pol = DitherPolicy(variant="paper", s=1.5)
        dcfg = SSGDConfig(n_nodes=2, s_schedule="fixed", s_base=1.5)
        bk = jax.random.fold_in(key, 9)
        sb = shard_batch(batch, 2)

        fn_fp32, _ = make_ssgd_step(model, opt, dcfg, pol)
        fn_int8, _ = make_ssgd_step(model, opt, dcfg, pol,
                                    memory="default=int8")
        p_a, _, _, _ = fn_fp32(params, init_opt_state(params, opt), sb, bk)
        p_b, _, _, _ = fn_int8(params, init_opt_state(params, opt), sb, bk)
        diffs = [float(np.abs(np.asarray(a) - np.asarray(b)).max())
                 for a, b in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_b))]
        assert max(diffs) > 0.0
