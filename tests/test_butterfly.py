"""Butterfly (recursive-halving) inter-pod reduce: consensus, tree
differentials, DCN occupancy, and sim-vs-shard_map bit-exactness."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

import stat_utils

from repro.comm import (ButterflyConfig, HierConfig, butterfly_allreduce_nsd,
                        butterfly_rounds, hier_allreduce_nsd)


def _stack(key, n, shape):
    return jnp.stack([jax.random.normal(jax.random.fold_in(key, i), shape)
                      for i in range(n)])


class TestButterflySim:
    def test_rounds(self):
        assert [butterfly_rounds(g) for g in (1, 2, 3, 4, 6, 8)] == \
            [0, 1, 1, 2, 2, 3]

    @pytest.mark.parametrize("n,pods", [(4, 2), (8, 4), (6, 3), (12, 3)])
    def test_error_bounded(self, key, n, pods):
        gs = _stack(key, n, (67,))
        mean, tele = butterfly_allreduce_nsd(
            gs, key, ButterflyConfig(pods=pods, s=1.0))
        err = float(jnp.max(jnp.abs(mean - jnp.mean(gs, 0))))
        stat_utils.assert_within_bound(err, float(tele.error_bound))

    def test_g1_bit_exact_vs_tree(self, key):
        """pods == 1: the butterfly collapses to the hierarchy's degenerate
        path — same phase-1 packs, same final-pack key, zero tolerance."""
        gs = _stack(key, 4, (51, 3))
        m_b, t_b = butterfly_allreduce_nsd(gs, key, ButterflyConfig(pods=1))
        m_h, t_h = hier_allreduce_nsd(gs, key, HierConfig(pods=1))
        assert float(jnp.max(jnp.abs(m_b - m_h))) == 0.0
        assert float(t_b.wire_bytes) == float(t_h.wire_bytes)
        assert t_b.packs_per_segment == t_h.packs_per_segment

    @pytest.mark.parametrize("n,pods", [(4, 2), (6, 3), (8, 4), (12, 6)])
    def test_pack_depth_matches_tree(self, key, n, pods):
        """Sequential pack depth per segment equals the binomial tree's at
        every pod count, power of two or not — the same-pack-depth leg of
        the occupancy claim."""
        gs = _stack(key, n, (40,))
        _, t_b = butterfly_allreduce_nsd(gs, key, ButterflyConfig(pods=pods))
        _, t_h = hier_allreduce_nsd(gs, key, HierConfig(pods=pods))
        assert t_b.packs_per_segment == t_h.packs_per_segment, pods

    def test_peak_dcn_below_tree_at_4_pods(self, key):
        """From pods >= 4 the tree root's log-G funnel dominates header
        overhead and the butterfly's busiest DCN line wins."""
        gs = _stack(key, 8, (64, 16))
        _, t_b = butterfly_allreduce_nsd(gs, key, ButterflyConfig(pods=4))
        _, t_h = hier_allreduce_nsd(gs, key, HierConfig(pods=4))
        assert float(t_b.peak_dcn_bytes) <= float(t_h.peak_dcn_bytes), (
            float(t_b.peak_dcn_bytes), float(t_h.peak_dcn_bytes))

    def test_single_node_short_circuits(self, key):
        g = jax.random.normal(key, (1, 33))
        mean, tele = butterfly_allreduce_nsd(g, key, ButterflyConfig(pods=1))
        assert float(jnp.max(jnp.abs(mean - g[0]))) == 0.0
        assert float(tele.wire_bytes) == 0.0

    def test_deterministic(self, key):
        gs = _stack(key, 6, (29,))
        cfg = ButterflyConfig(pods=3, s=2.0)
        m1, _ = butterfly_allreduce_nsd(gs, key, cfg)
        m2, _ = butterfly_allreduce_nsd(gs, key, cfg)
        assert float(jnp.max(jnp.abs(m1 - m2))) == 0.0


# --- sim vs shard_map differential (virtual multi-device) -----------------

def _run_script(script: str) -> str:
    env = dict(os.environ, PYTHONPATH=os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    return out.stdout + out.stderr


BFLY_SHARDMAP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import functools
    import jax, jax.numpy as jnp
    from repro.comm import (ButterflyConfig, allreduce_butterfly,
                            butterfly_allreduce_nsd,
                            make_butterfly_allreduce)
    from repro.launch.mesh import NodeTopology, make_node_mesh
    key = jax.random.PRNGKey(0)
    gs = jnp.stack([jax.random.normal(jax.random.fold_in(key, i), (37, 13))
                    for i in range(8)])
    for pods, per_pod in ((2, 4), (4, 2)):
        mesh = make_node_mesh(NodeTopology(pods=pods, nodes_per_pod=per_pod))
        cfg = ButterflyConfig(pods=pods, s=1.0)
        means, w_ici, w_dcn, bounds, peak = \\
            make_butterfly_allreduce(mesh, cfg)(gs, key)
        sim = jax.jit(functools.partial(butterfly_allreduce_nsd, cfg=cfg))
        sim_mean, tele = sim(gs, key)
        # consensus: every node holds the identical result...
        for i in range(1, 8):
            assert float(jnp.max(jnp.abs(means[i] - means[0]))) == 0.0
        # ...bit-exactly equal to the simulation
        assert float(jnp.max(jnp.abs(means[0] - sim_mean))) == 0.0, pods
        assert float(jnp.sum(w_ici)) == float(tele.wire_ici_bytes)
        assert float(jnp.sum(w_dcn)) == float(tele.wire_dcn_bytes)
        assert float(jnp.max(peak)) == float(tele.peak_dcn_bytes)
        assert abs(float(bounds[0]) - float(tele.error_bound)) < 1e-6
        # dispatcher path agrees too
        mean_d, tele_d = allreduce_butterfly(gs, key, cfg, mesh=mesh)
        assert float(jnp.max(jnp.abs(mean_d - sim_mean))) == 0.0
        assert tele_d.packs_per_segment == tele.packs_per_segment
    print("BFLY_SHARDMAP_OK")
""")


BFLY_NONPOW2_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
    import functools
    import jax, jax.numpy as jnp
    from repro.comm import (ButterflyConfig, butterfly_allreduce_nsd,
                            make_butterfly_allreduce)
    from repro.launch.mesh import NodeTopology, make_node_mesh
    key = jax.random.PRNGKey(1)
    gs = jnp.stack([jax.random.normal(jax.random.fold_in(key, i), (40,))
                    for i in range(6)])
    # G=3: pod 2 pre-folds into pod 0 before the single halving round
    mesh = make_node_mesh(NodeTopology(pods=3, nodes_per_pod=2))
    cfg = ButterflyConfig(pods=3, s=1.0)
    means, w_ici, w_dcn, bounds, peak = \\
        make_butterfly_allreduce(mesh, cfg)(gs, key)
    sim_mean, tele = jax.jit(
        functools.partial(butterfly_allreduce_nsd, cfg=cfg))(gs, key)
    for i in range(6):
        assert float(jnp.max(jnp.abs(means[i] - sim_mean))) == 0.0, i
    assert float(jnp.sum(w_ici)) == float(tele.wire_ici_bytes)
    assert float(jnp.sum(w_dcn)) == float(tele.wire_dcn_bytes)
    err = float(jnp.max(jnp.abs(sim_mean - jnp.mean(gs, 0))))
    assert err <= float(tele.error_bound) * 1.001
    print("BFLY_NONPOW2_OK")
""")


def test_shardmap_butterfly_subprocess():
    """Recursive halving/doubling as pairwise ppermutes over the pod axis,
    bit-exact with the simulation (2x4 and 4x2 meshes)."""
    out = _run_script(BFLY_SHARDMAP_SCRIPT)
    assert "BFLY_SHARDMAP_OK" in out, out


def test_shardmap_butterfly_nonpow2_pods_subprocess():
    """Same differential with a non-power-of-two pod count (G=3)."""
    out = _run_script(BFLY_NONPOW2_SCRIPT)
    assert "BFLY_NONPOW2_OK" in out, out


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 (virtual) devices — run under "
                           "XLA_FLAGS=--xla_force_host_platform_device_"
                           "count=8 (the CI comm job does)")
def test_butterfly_shardmap_inprocess(key):
    """In-process variant for the multi-device CI job: no subprocess, so
    failures produce a real traceback."""
    import functools

    from repro.comm import make_butterfly_allreduce
    from repro.launch.mesh import NodeTopology, make_node_mesh

    mesh = make_node_mesh(NodeTopology(pods=4, nodes_per_pod=2))
    cfg = ButterflyConfig(pods=4, s=1.0)
    gs = _stack(key, 8, (129,))
    means, w_ici, w_dcn, bounds, peak = \
        make_butterfly_allreduce(mesh, cfg)(gs, key)
    sim_mean, tele = jax.jit(
        functools.partial(butterfly_allreduce_nsd, cfg=cfg))(gs, key)
    assert float(jnp.max(jnp.abs(means[0] - sim_mean))) == 0.0
    assert float(jnp.sum(w_ici)) == float(tele.wire_ici_bytes)
    assert float(jnp.sum(w_dcn)) == float(tele.wire_dcn_bytes)
    assert float(jnp.max(peak)) == float(tele.peak_dcn_bytes)
