"""Lowering-pattern guard for the bitmap pack/unpack kernels.

Mosaic (the TPU Pallas backend) cannot lower a reshape that regroups the
minor (lane) dimension — exactly the ``(bm, bn) -> (bm, bn/8, 8)`` byte
gather the original interpret-only kernels used. The rewrite routes the
byte grouping through the sublane dimension (rotate + OR-reduce), so the
invariant to protect is: *no reshape inside either kernel body changes
the trailing dimension*. This test walks the traced kernel jaxprs and
asserts that, turning the "does it compile on TPU" question into a
CPU-checkable structural property. Bit-exactness vs the wire format is
covered by tests/test_comm.py::TestPackKernels; a real-TPU run of the
compiled path stays the xfail red/green signal there.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.pack.pack import bitmap_pack_blocked, bitmap_unpack_blocked

LANE_CHANGERS = ("reshape",)


def _iter_jaxprs(jaxpr):
    yield jaxpr
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            inner = getattr(v, "jaxpr", v)
            if hasattr(inner, "eqns"):
                yield from _iter_jaxprs(inner)


def _kernel_jaxprs(closed):
    """The pallas kernel bodies inside a traced computation."""
    for j in _iter_jaxprs(closed.jaxpr):
        for eqn in j.eqns:
            if eqn.primitive.name == "pallas_call":
                yield eqn.params["jaxpr"]


def _assert_no_lane_reshape(kernel_jaxpr):
    for j in _iter_jaxprs(kernel_jaxpr):
        for eqn in j.eqns:
            if eqn.primitive.name not in LANE_CHANGERS:
                continue
            in_shape = eqn.invars[0].aval.shape
            out_shape = eqn.outvars[0].aval.shape
            assert in_shape[-1] == out_shape[-1], (
                f"lane-dim reshape {in_shape} -> {out_shape} — Mosaic "
                f"cannot lower this; keep byte grouping on the sublane dim")


@pytest.mark.parametrize("trace", [
    lambda k8: bitmap_pack_blocked(k8, interpret=True),
    lambda k8: bitmap_unpack_blocked(
        jnp.zeros((k8.shape[0], k8.shape[1] // 8), jnp.uint8),
        interpret=True),
], ids=["pack", "unpack"])
def test_kernel_has_no_lane_dim_reshape(trace):
    k8 = jnp.zeros((256, 256), jnp.int8)
    closed = jax.make_jaxpr(trace)(k8)
    kernels = list(_kernel_jaxprs(closed))
    assert kernels, "expected a pallas_call in the traced computation"
    for kj in kernels:
        _assert_no_lane_reshape(kj)


def test_guard_would_catch_the_old_layout():
    """Self-check: the assertion actually fires on a lane-dim regroup."""
    def old_style(x):
        bm, bn = x.shape
        return jnp.sum(x.reshape(bm, bn // 8, 8), axis=-1)

    closed = jax.make_jaxpr(old_style)(jnp.zeros((128, 128), jnp.int8))
    with pytest.raises(AssertionError, match="lane-dim reshape"):
        _assert_no_lane_reshape(closed.jaxpr)


def test_pack_uses_sublane_rotates():
    """The OR-reduce tree is built from TPU-native rolls, not gathers."""
    k8 = jnp.zeros((128, 128), jnp.int8)
    closed = jax.make_jaxpr(lambda k: bitmap_pack_blocked(k, interpret=True))(
        k8)
    prims = {e.primitive.name
             for kj in _kernel_jaxprs(closed)
             for j in _iter_jaxprs(kj)
             for e in j.eqns}
    assert "tpu_roll" in prims or "roll" in prims, sorted(prims)
