"""End-to-end behaviour: the paper's claims on a real (small) training run.

These are the system-level analogues of Table 1:
  * dithered backprop reaches high pre-activation-gradient sparsity,
  * at matched training quality (loss curves within noise),
  * with non-zeros in <= 8 bits,
  * and it composes with 8-bit forward layers.
"""
import jax
import numpy as np

from repro.configs.paper_models import mlp_mnist
from repro.core import DitherCtx, DitherPolicy
from repro.obs import metrics as statslib
from repro.data import ClassifConfig, classification_batch
from repro.models.cnn import accuracy
from repro.optim import OptConfig, init_opt_state, apply_updates


def _train(model, policy, steps=60, lr=0.05, seed=0):
    key = jax.random.PRNGKey(seed)
    params, _ = model.init(key)
    opt_cfg = OptConfig(name="sgd", lr=lr, momentum=0.9, weight_decay=5e-4,
                        grad_clip=None)
    state = init_opt_state(params, opt_cfg)
    dcfg = ClassifConfig(n_classes=10, img_size=28, channels=1, noise=0.5)

    @jax.jit
    def step_fn(params, state, batch, bk):
        ctx = None
        if policy is not None:
            ctx = DitherCtx.for_step(bk, state["step"], policy)
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, ctx=ctx))(params)
        params, state, m = apply_updates(params, grads, state, opt_cfg)
        return params, state, loss

    losses = []
    for i in range(steps):
        batch = classification_batch(dcfg, i, batch=64)
        params, state, loss = step_fn(params, state, batch, key)
        losses.append(float(loss))
    test_batch = classification_batch(dcfg, 10**6, batch=256)
    acc = float(accuracy(params, model.cfg, test_batch))
    return losses, acc


class TestPaperClaims:
    def test_dithered_matches_baseline_accuracy(self):
        """Table-1 claim: accuracy change between baseline and dithered is
        negligible (here: within 3 points on the synthetic set)."""
        model = mlp_mnist(hidden=(64, 64))
        _, acc_base = _train(model, None)
        _, acc_dith = _train(model, DitherPolicy(variant="paper", s=2.0))
        assert acc_base > 0.9, acc_base
        assert acc_dith > acc_base - 0.03, (acc_base, acc_dith)

    def test_high_sparsity_during_training(self):
        """Table-1 claim: dithered backprop induces very sparse delta_z
        (92% avg in the paper; synthetic MLP should exceed 70% at s=2)."""
        statslib.reset()
        model = mlp_mnist(hidden=(64, 64))
        pol = DitherPolicy(variant="paper", s=2.0, collect_stats=True,
                           stats_tag="sys/")
        _train(model, pol, steps=20)
        sp = statslib.overall_sparsity()
        bits = statslib.overall_max_bits()
        assert sp > 0.7, sp
        assert bits <= 8.0, bits

    def test_8bit_combo_trains(self):
        """int8 backward variant (the paper's '8bit + dith backprop')."""
        model = mlp_mnist(hidden=(64, 64))
        losses, acc = _train(model, DitherPolicy(variant="int8", s=2.0))
        assert acc > 0.85, acc
        assert losses[-1] < losses[0]

    def test_meprop_worse_than_dither_at_matched_sparsity(self):
        """Fig-4 claim (ordering): at comparable sparsity, biased top-k
        (meProp) trains no better than unbiased dither."""
        model = mlp_mnist(hidden=(64, 64))
        _, acc_d = _train(model, DitherPolicy(variant="paper", s=4.0),
                          steps=80)
        _, acc_m = _train(model, DitherPolicy(variant="meprop",
                                              meprop_k_frac=0.05), steps=80)
        assert acc_d >= acc_m - 0.02, (acc_d, acc_m)


class TestTrainServeRoundtrip:
    def test_train_then_serve(self, tmp_path, key):
        """Train a tiny LM, checkpoint, restore, serve tokens from it."""
        from repro.configs import get_smoke_model
        from repro.data import TokenStreamConfig, token_batch
        from repro.serve import Engine, Request, ServeConfig
        from repro.train import Trainer, TrainerConfig

        model = get_smoke_model("qwen2.5-32b")
        trainer = Trainer(model, OptConfig(lr=1e-3),
                          TrainerConfig(total_steps=10, log_every=0,
                                        ckpt_every=5,
                                        ckpt_dir=str(tmp_path)),
                          policy=DitherPolicy(variant="paper", s=2.0))
        tcfg = TokenStreamConfig(vocab=model.cfg.vocab, seq_len=16, batch=2)

        def it():
            i = 0
            while True:
                yield token_batch(tcfg, i)
                i += 1

        trainer.fit(it())
        assert trainer.ckpt.latest_step() == 10

        # restore into a fresh trainer and serve
        trainer2 = Trainer(model, OptConfig(lr=1e-3),
                           TrainerConfig(total_steps=10, log_every=0,
                                         ckpt_every=5,
                                         ckpt_dir=str(tmp_path)))
        params, opt_state, _ = trainer2.restore_or_init(key)
        assert int(opt_state["step"]) == 10
        eng = Engine(model, params, ServeConfig(max_batch=2, max_len=32))
        eng.submit(Request(uid=0, prompt=np.asarray([1, 2, 3]),
                           max_new_tokens=3))
        done = eng.run(max_ticks=8)
        assert len(done[0]) == 3
