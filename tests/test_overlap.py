"""Overlap-scheduled (bucketed) reduce: bucket planning, bit-exactness vs
the blocking reduce, edge cases (oversize leaf, one-layer model), and the
cost model's overlap pricing."""
import jax
import jax.numpy as jnp
import pytest

from repro.comm import CommPolicy
from repro.comm.overlap import BucketPlan, OverlapReducer, plan_buckets
from repro.comm.reducer import reducer
from repro.launch.costmodel import price_overlap


def _grads(key, n_nodes=0):
    ks = jax.random.split(key, 4)
    tree = {
        "emb": {"w": jax.random.normal(ks[0], (64, 32)) * 0.02},
        "dense0": {"w": jax.random.normal(ks[1], (32, 32)) * 0.02,
                   "b": jax.random.normal(ks[2], (32,)) * 0.02},
        "lm_head": {"w": jax.random.normal(ks[3], (32, 16)) * 0.02},
    }
    if n_nodes:
        tree = jax.tree.map(
            lambda l: jnp.stack([l * (1 + 0.1 * i) for i in range(n_nodes)]),
            tree)
    return tree


class TestBucketPlan:
    def test_reverse_layer_order(self):
        named = [("a/w", 400), ("b/w", 400), ("c/w", 400)]
        plan = plan_buckets(named, bucket_bytes=800)
        # reverse order: last layer's grads are ready first
        assert plan.buckets == (("c/w", "b/w"), ("a/w",))
        assert plan.bucket_bytes == (800, 400)
        assert plan.n_buckets == 2 and plan.total_bytes == 1200

    def test_oversize_leaf_gets_own_bucket(self):
        named = [("small", 100), ("huge", 5000), ("tail", 100)]
        plan = plan_buckets(named, bucket_bytes=1000)
        assert ("huge",) in plan.buckets
        assert plan.total_bytes == 5200

    def test_single_leaf_larger_than_bucket(self):
        """One layer bigger than bucket_bytes: the plan is one bucket and
        the reduce must still be exact (no silent split/truncation)."""
        plan = plan_buckets([("w", 1 << 20)], bucket_bytes=1024)
        assert plan.buckets == (("w",),)

    def test_invalid_bucket_bytes_raises(self):
        with pytest.raises(ValueError):
            plan_buckets([("a", 4)], bucket_bytes=0)

    def test_empty_tree(self):
        plan = plan_buckets([], bucket_bytes=1024)
        assert plan == BucketPlan(buckets=(), bucket_bytes=())


class TestOverlapEqualsBlocking:
    @pytest.mark.parametrize("topo,n", [("ps", 1), ("ps", 4), ("hier", 4),
                                        ("butterfly", 4)])
    def test_bit_exact(self, key, topo, n):
        """Per-leaf keys depend on the leaf path, not the bucket: the
        bucketed reduce equals the blocking one to the last bit, for every
        topology."""
        pol = CommPolicy(default="nsd", s=2.0, topology=topo,
                         pods=2 if topo != "ps" else 1, min_leaf_size=1)
        stacked = n > 1
        grads = _grads(key, n_nodes=n if stacked else 0)
        blk = reducer(pol, n_nodes=n, stacked=stacked)
        ovl = reducer(pol.replace(bucket_bytes=2048), n_nodes=n,
                      stacked=stacked)
        assert isinstance(ovl, OverlapReducer)
        out_b, tele_b, _ = blk.reduce(grads, key, 0)
        out_o, tele_o, _ = ovl.reduce(grads, key, 0)
        for a, b in zip(jax.tree.leaves(out_b), jax.tree.leaves(out_o)):
            assert float(jnp.max(jnp.abs(a - b))) == 0.0
        assert float(tele_b.wire_bytes) == float(tele_o.wire_bytes)
        assert float(tele_b.dense_bytes) == float(tele_o.dense_bytes)
        assert tele_o.n_buckets > 1

    def test_one_layer_model(self, key):
        """A single-leaf tree: one bucket, still exact, telemetry sane."""
        g = {"only": jax.random.normal(key, (128,)) * 0.01}
        pol = CommPolicy(default="nsd", s=1.0, min_leaf_size=1)
        blk = reducer(pol, n_nodes=1, stacked=False)
        ovl = reducer(pol.replace(bucket_bytes=64), n_nodes=1, stacked=False)
        out_b, tele_b, _ = blk.reduce(g, key, 0)
        out_o, tele_o, _ = ovl.reduce(g, key, 0)
        assert float(jnp.max(jnp.abs(out_b["only"] - out_o["only"]))) == 0.0
        assert tele_o.n_buckets == 1
        assert float(tele_b.wire_bytes) == float(tele_o.wire_bytes)

    def test_ef_residuals_bucket_independent(self, key):
        """Error-feedback state threads through buckets unchanged vs the
        blocking reduce — two steps deep, so residuals feed back."""
        pol = CommPolicy(default="topk_ef", topk_frac=0.25, min_leaf_size=1)
        grads = _grads(key)
        blk = reducer(pol, n_nodes=1, stacked=False)
        ovl = reducer(pol.replace(bucket_bytes=2048), n_nodes=1,
                      stacked=False)
        sb, so = blk.init_state(grads), ovl.init_state(grads)
        for step in range(2):
            _, _, sb = blk.reduce(grads, key, step, sb)
            _, _, so = ovl.reduce(grads, key, step, so)
        for name in sb:
            assert float(jnp.max(jnp.abs(
                sb[name].residual - so[name].residual))) == 0.0, name

    def test_jit_overlap_equals_jit_blocking(self, key):
        """Under one jit the traced programs must agree exactly (the
        contract the ssgd step relies on)."""
        pol = CommPolicy(default="nsd", s=2.0, min_leaf_size=1)
        grads = _grads(key)
        blk = reducer(pol, n_nodes=1, stacked=False)
        ovl = reducer(pol.replace(bucket_bytes=1024), n_nodes=1,
                      stacked=False)
        f_b = jax.jit(lambda g, k: blk.reduce(g, k, 0)[0])
        f_o = jax.jit(lambda g, k: ovl.reduce(g, k, 0)[0])
        for a, b in zip(jax.tree.leaves(f_b(grads, key)),
                        jax.tree.leaves(f_o(grads, key))):
            assert float(jnp.max(jnp.abs(a - b))) == 0.0


class TestPriceOverlap:
    def test_fully_hidden(self):
        # both buckets ready and drained well before backward finishes
        out = price_overlap([100, 100], [0.1, 0.1], bwd_s=10.0,
                            ready_s=[0.0, 1.0])
        assert out["exposed_s"] == 0.0
        assert out["overlap_efficiency"] == 1.0
        assert out["step_s"] == 10.0

    def test_blocking_tail_exposed(self):
        # all comm ready only at the end: everything is exposed
        out = price_overlap([100], [2.0], bwd_s=1.0, ready_s=[1.0])
        assert out["exposed_s"] == pytest.approx(2.0)
        assert out["overlap_efficiency"] == pytest.approx(0.0)
        assert out["serial_s"] == pytest.approx(3.0)

    def test_queueing_serializes_link(self):
        # bucket 1 ready at t=0 but the link is busy until t=2
        out = price_overlap([100, 100], [2.0, 1.0], bwd_s=4.0,
                            ready_s=[0.0, 0.0])
        assert out["launch_s"] == [0.0, 2.0]
        assert out["drain_s"] == [2.0, 3.0]
        assert out["exposed_s"] == 0.0

    def test_default_ready_proxy_monotone(self):
        out = price_overlap([300, 100, 100], [0.5, 0.5, 0.5], bwd_s=3.0)
        assert out["launch_s"] == sorted(out["launch_s"])
        assert 0.0 <= out["overlap_efficiency"] <= 1.0

    def test_mismatched_lengths_raise(self):
        with pytest.raises(ValueError):
            price_overlap([1, 2], [0.1], bwd_s=1.0)
