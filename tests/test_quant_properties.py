"""Hypothesis property tests over the quant registry.

Kept separate from test_quant.py: hypothesis ships in the [test] extra,
not as a hard dependency (same policy as test_memory_properties.py).
Adversarial surface: arbitrary shapes (including sizes that are no
multiple of the wire chunk or the int4 group), extreme scales, all-zero
tensors, and clip saturation — raced over EVERY registered codec through
the one front door, with each codec judged against its own
``error_bound``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.quant import (decode, encode, error_bound, nsd_fakequant,  # noqa: E402
                         parse_spec, quantize, resid_key, stored_nbytes)

# parameterized spec strings so the grammar is part of the raced surface
BOUNDED_SPECS = ("bf16", "int8", "int8_absmax", "int4@g32", "int4@g64",
                 "m8", "nsd@0.5", "nsd@2")
EXACT_SPECS = ("fp32", "remat")


def _tensor(spec, rows, cols, scale, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, cols),
                          jnp.float32) * scale
    if parse_spec(spec).codec == "u8":
        return jnp.square(x)
    return x


@settings(max_examples=20, deadline=None)
@given(spec=st.sampled_from(BOUNDED_SPECS + ("u8",)),
       rows=st.integers(1, 9), cols=st.integers(1, 67),
       scale=st.floats(1e-3, 1e3), seed=st.integers(0, 2**31 - 1))
def test_property_roundtrip_within_own_bound(spec, rows, cols, scale, seed):
    """decode(encode(x)) deviates from x by at most the codec's declared
    per-element error_bound — for ANY shape and scale."""
    x = _tensor(spec, rows, cols, scale, seed)
    key = resid_key(jax.random.PRNGKey(seed))
    enc = encode(spec, x, key)
    err = np.asarray(jnp.abs(decode(spec, enc) - x))
    bound = np.asarray(error_bound(spec, enc))
    assert (err <= bound * (1 + 1e-4) + 1e-12).all(), \
        (spec, float((err / (bound + 1e-12)).max()))


@settings(max_examples=10, deadline=None)
@given(spec=st.sampled_from(EXACT_SPECS), rows=st.integers(1, 9),
       cols=st.integers(1, 67), seed=st.integers(0, 2**31 - 1))
def test_property_identity_codecs_exact(spec, rows, cols, seed):
    x = _tensor(spec, rows, cols, 1.0, seed)
    key = resid_key(jax.random.PRNGKey(seed))
    np.testing.assert_array_equal(np.asarray(decode(spec, encode(spec, x, key))),
                                  np.asarray(x))
    assert error_bound(spec, encode(spec, x, key)) is None


@settings(max_examples=15, deadline=None)
@given(rows=st.integers(1, 9), cols=st.integers(1, 41),
       s=st.floats(0.25, 4.0), seed=st.integers(0, 2**31 - 1))
def test_property_nsd_registry_bit_exact(rows, cols, s, seed):
    """The registry's nsd codec IS the paper operator: bit-exact against
    the fakequant reference for any shape and scale, same key."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (rows, cols), jnp.float32)
    k = resid_key(jax.random.fold_in(key, 1))
    spec = f"nsd@{s}"
    np.testing.assert_array_equal(
        np.asarray(decode(spec, encode(spec, x, k))),
        np.asarray(nsd_fakequant(x, k, s)))


@settings(max_examples=15, deadline=None)
@given(spec=st.sampled_from(BOUNDED_SPECS + EXACT_SPECS + ("u8",)),
       n=st.integers(1, 300), seed=st.integers(0, 2**31 - 1))
def test_property_all_zero_decodes_to_zero(spec, n, seed):
    """Zero is representable in every format: an all-zero tensor survives
    any codec exactly (the re-encode fixed point moments rely on)."""
    x = jnp.zeros((n,), jnp.float32)
    key = resid_key(jax.random.PRNGKey(seed))
    np.testing.assert_array_equal(
        np.asarray(decode(spec, encode(spec, x, key))),
        np.zeros((n,), np.float32))


@settings(max_examples=15, deadline=None)
@given(spec=st.sampled_from(BOUNDED_SPECS), rows=st.integers(1, 5),
       cols=st.integers(2, 33), outlier=st.floats(1e4, 1e7),
       seed=st.integers(0, 2**31 - 1))
def test_property_outlier_saturation_stays_finite(spec, rows, cols, outlier,
                                                  seed):
    """A huge outlier saturates the integer range but never produces
    non-finite decodes, and the outlier's own reconstruction still honors
    the (outlier-widened) bound."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (rows, cols), jnp.float32)
    x = x.at[0, 0].set(jnp.float32(outlier))
    k = resid_key(jax.random.fold_in(key, 1))
    enc = encode(spec, x, k)
    dec = np.asarray(decode(spec, enc))
    assert np.isfinite(dec).all(), spec
    err = np.abs(dec - np.asarray(x))
    bound = np.asarray(error_bound(spec, enc))
    assert (err <= bound * (1 + 1e-4) + 1e-12).all(), spec


@settings(max_examples=15, deadline=None)
@given(spec=st.sampled_from(BOUNDED_SPECS + ("u8",)),
       rows=st.integers(1, 9), cols=st.integers(1, 67),
       seed=st.integers(0, 2**31 - 1))
def test_property_quantize_matches_encode_decode(spec, rows, cols, seed):
    """The fake-quant shortcut is exactly the round trip."""
    x = _tensor(spec, rows, cols, 2.0, seed)
    key = resid_key(jax.random.PRNGKey(seed))
    np.testing.assert_array_equal(
        np.asarray(quantize(spec, x, key)),
        np.asarray(decode(spec, encode(spec, x, key))))


@settings(max_examples=15, deadline=None)
@given(spec=st.sampled_from(BOUNDED_SPECS + ("u8",)),
       rows=st.integers(1, 9), cols=st.integers(1, 67))
def test_property_stored_nbytes_beats_dense_above_threshold(spec, rows,
                                                            cols):
    """Static byte accounting: every sub-32-bit codec stores strictly
    fewer bytes than dense fp32 once the tensor amortizes its scale
    metadata (one full group/row/chunk)."""
    from repro.quant import dense_nbytes

    ps = parse_spec(spec)
    n = rows * cols
    amortized = {"group": ps.group or 1, "chunk": 512,
                 "row": 4 * cols, "tensor": 8}[ps.granularity]
    if n < amortized:
        return  # metadata-dominated sizes may legitimately exceed dense
    assert (stored_nbytes(spec, (rows, cols), jnp.float32)
            < dense_nbytes((rows, cols), jnp.float32)), spec
