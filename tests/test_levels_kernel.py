"""The Pallas levels compact/expand kernels (repro.kernels.levels): the
chunk-local butterfly routing is BIT-EXACT against the cumsum oracle and
against the wire format's global `_compact`/`_expand`, interpret mode on
any host; compiled Mosaic is xfail(strict=False) off-TPU (same policy as
tests/test_kernels.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.levels.levels import (levels_compact_blocked,
                                         levels_expand_blocked)
from repro.kernels.levels.ref import compact_columns_ref, expand_columns_ref
from repro.quant import wire

CHUNK = 256

INTERPRET_MODES = [
    pytest.param(True, id="interpret"),
    pytest.param(False, id="compiled", marks=pytest.mark.xfail(
        strict=False, reason="compiled Mosaic needs a TPU host")),
]


@pytest.fixture(params=INTERPRET_MODES)
def interpret(request):
    return request.param


def _sparse_cols(key, cols, density=0.3):
    k = jax.random.fold_in(key, 17)
    vals = jax.random.randint(k, (CHUNK, cols), -127, 128, jnp.int32)
    keep = jax.random.uniform(jax.random.fold_in(k, 1),
                              (CHUNK, cols)) < density
    return jnp.where(keep, vals, 0).astype(jnp.int8)


@pytest.mark.parametrize("cols", [1, 3, 128, 200])
def test_compact_vs_ref(key, cols, interpret):
    kt = _sparse_cols(key, cols)
    lv, cnt = levels_compact_blocked(kt, interpret=interpret)
    lv_ref, cnt_ref = compact_columns_ref(kt)
    np.testing.assert_array_equal(np.asarray(lv), np.asarray(lv_ref))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_ref))


@pytest.mark.parametrize("cols", [1, 3, 128, 200])
def test_expand_inverts_compact(key, cols, interpret):
    kt = _sparse_cols(key, cols)
    lv, _ = levels_compact_blocked(kt, interpret=interpret)
    mask = (kt != 0).astype(jnp.int8)
    back = levels_expand_blocked(lv, mask, interpret=interpret)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(kt))
    np.testing.assert_array_equal(
        np.asarray(expand_columns_ref(lv, mask)), np.asarray(kt))


@pytest.mark.parametrize("density", [0.0, 1.0])
def test_degenerate_densities(key, density, interpret):
    """All-zero columns (empty routing) and fully-dense columns (identity
    permutation) both round-trip."""
    kt = _sparse_cols(key, 8, density=density)
    lv, cnt = levels_compact_blocked(kt, interpret=interpret)
    lv_ref, cnt_ref = compact_columns_ref(kt)
    np.testing.assert_array_equal(np.asarray(lv), np.asarray(lv_ref))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_ref))
    back = levels_expand_blocked(lv, (kt != 0).astype(jnp.int8),
                                 interpret=interpret)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(kt))


class TestWireBackend:
    """The kernels as the wire format's backend="pallas" (interpret mode):
    identical packed bytes to the jnp backend, including odd sizes that
    exercise the chunk padding."""

    @pytest.mark.parametrize("n", [CHUNK, 3 * CHUNK, 1000, 7])
    def test_compact_assembly_bit_exact(self, key, n):
        k = jax.random.randint(key, (n,), -127, 128, jnp.int32)
        keep = jax.random.uniform(jax.random.fold_in(key, 1), (n,)) < 0.25
        k_flat = jnp.where(keep, k, 0).astype(jnp.int8)
        pad = (-n) % CHUNK
        k_pad = jnp.pad(k_flat, (0, pad))
        lv_ref, nnz_ref = wire._compact(k_pad)
        lv, nnz = wire._compact_pallas(k_pad, CHUNK)
        np.testing.assert_array_equal(np.asarray(lv), np.asarray(lv_ref))
        assert int(nnz) == int(nnz_ref)
        mask = k_pad != 0
        np.testing.assert_array_equal(
            np.asarray(wire._expand_pallas(lv, mask, CHUNK)),
            np.asarray(wire._expand(lv_ref, mask)))

    def test_pack_unpack_nsd_pallas_backend(self, key):
        """End to end through the public wire API: pallas backend decodes
        to the same tensor as the jnp backend, bit for bit."""
        x = jax.random.normal(key, (7, 93), jnp.float32)
        delta = jnp.float32(0.25)
        k = jnp.round(x / delta).clip(-127, 127).astype(jnp.int32)
        p_jnp = wire.pack_indices(k, delta, x.shape, x.dtype)
        p_pl = wire.pack_indices(k, delta, x.shape, x.dtype,
                                 backend="pallas")
        np.testing.assert_array_equal(np.asarray(p_pl.levels),
                                      np.asarray(p_jnp.levels))
        np.testing.assert_array_equal(np.asarray(p_pl.bitmap),
                                      np.asarray(p_jnp.bitmap))
        np.testing.assert_array_equal(
            np.asarray(wire.unpack_nsd(p_pl, backend="pallas")),
            np.asarray(wire.unpack_nsd(p_jnp)))
