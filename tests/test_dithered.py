"""Dithered backprop operators: eqs. 7-9 semantics, unbiased weight updates,
variant dispatch, telemetry."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DitherCtx, DitherPolicy, conv2d, dense,
                        dithered_einsum, nsd)
from repro.obs import metrics as statslib
from repro.core import rowdither


def _ctx(key, variant="paper", step=0, **kw):
    return DitherCtx.for_step(key, step, DitherPolicy(variant=variant, **kw))


class TestDense:
    def test_forward_is_exact(self, key):
        """Dithering touches ONLY the backward pass (paper: fwd unchanged)."""
        x = jax.random.normal(key, (8, 16))
        w = jax.random.normal(jax.random.fold_in(key, 1), (16, 24))
        y_d = dense(x, w, ctx=_ctx(key))
        y_p = x @ w
        np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_p),
                                   rtol=1e-5)

    def test_weight_grad_uses_quantized_cotangent(self, key):
        """dw == x^T @ NSD(g) with the layer's fold-in key (eq. 9)."""
        x = jax.random.normal(key, (8, 16))
        w = jax.random.normal(jax.random.fold_in(key, 1), (16, 24)) * 0.1
        ctx = _ctx(key, s=2.0)
        name = "fcX"

        def loss(w):
            return jnp.sum(jnp.sin(dense(x, w, ctx=ctx, name=name)))

        gw = jax.grad(loss)(w)
        # reconstruct by hand
        y = x @ w
        g = jnp.cos(y)  # d/dy sum(sin(y))
        layer_key = ctx.key_for(name)
        gq = nsd.nsd_quantize(g, layer_key, 2.0)
        np.testing.assert_allclose(np.asarray(gw), np.asarray(x.T @ gq),
                                   rtol=1e-4, atol=1e-5)

    def test_update_unbiased_across_keys(self, key):
        """E[dithered grad] == exact grad (the convergence precondition)."""
        x = jax.random.normal(key, (16, 32))
        w = jax.random.normal(jax.random.fold_in(key, 1), (32, 8)) * 0.1

        def gexact(w):
            return jax.grad(lambda w: jnp.sum(jnp.tanh(x @ w) ** 2))(w)

        def gdith(w, step):
            ctx = _ctx(key, step=step, s=2.0)
            return jax.grad(lambda w: jnp.sum(
                jnp.tanh(dense(x, w, ctx=ctx, name="fc")) ** 2))(w)

        gs = jnp.stack([gdith(w, i) for i in range(600)])
        mean_g = jnp.mean(gs, axis=0)
        exact = gexact(w)
        err = float(jnp.linalg.norm(mean_g - exact) / jnp.linalg.norm(exact))
        assert err < 0.08, err

    def test_int8_variant_close_to_paper_variant(self, key):
        x = jax.random.normal(key, (32, 64))
        w = jax.random.normal(jax.random.fold_in(key, 1), (64, 32)) * 0.1

        def g(variant):
            ctx = _ctx(key, variant=variant, s=2.0)
            return jax.grad(lambda w: jnp.sum(
                dense(x, w, ctx=ctx, name="fc") ** 2))(w)

        g_paper, g_int8 = g("paper"), g("int8")
        rel = float(jnp.linalg.norm(g_paper - g_int8)
                    / jnp.linalg.norm(g_paper))
        assert rel < 0.05, rel  # absmax-int8 of x/w adds <5% here

    def test_policy_exclusion(self, key):
        x = jax.random.normal(key, (8, 16))
        w = jnp.eye(16)
        pol = DitherPolicy(variant="paper", exclude=("lm_head",))
        ctx = DitherCtx.for_step(key, 0, pol)
        g1 = jax.grad(lambda w: jnp.sum(
            dense(x, w, ctx=ctx, name="lm_head") ** 2))(w)
        g2 = jax.grad(lambda w: jnp.sum((x @ w) ** 2))(w)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5)

    def test_off_policy_is_plain(self, key):
        x = jax.random.normal(key, (8, 16))
        w = jnp.eye(16)
        ctx = _ctx(key, variant="off")
        g1 = jax.grad(lambda w: jnp.sum(dense(x, w, ctx=ctx) ** 2))(w)
        g2 = jax.grad(lambda w: jnp.sum((x @ w) ** 2))(w)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5)


class TestConvEinsum:
    def test_conv_grad_unbiased(self, key):
        x = jax.random.normal(key, (4, 8, 8, 3))
        w = jax.random.normal(jax.random.fold_in(key, 1), (3, 3, 3, 8)) * 0.2

        exact = jax.grad(lambda w: jnp.sum(
            jax.lax.conv_general_dilated(
                x, w, (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC")) ** 2))(w)

        gs = []
        for i in range(300):
            ctx = _ctx(key, step=i, s=2.0)
            gs.append(jax.grad(lambda w: jnp.sum(
                conv2d(x, w, ctx=ctx, name="c") ** 2))(w))
        mean_g = jnp.mean(jnp.stack(gs), axis=0)
        err = float(jnp.linalg.norm(mean_g - exact) / jnp.linalg.norm(exact))
        assert err < 0.1, err

    def test_einsum_variant(self, key):
        x = jax.random.normal(key, (4, 8, 16))
        w = jax.random.normal(jax.random.fold_in(key, 1), (4, 16, 8)) * 0.2
        ctx = _ctx(key)
        y = dithered_einsum("ecd,edf->ecf", x, w, ctx=ctx, name="exp")
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(jnp.einsum("ecd,edf->ecf", x, w)),
            rtol=1e-5)
        g = jax.grad(lambda w: jnp.sum(dithered_einsum(
            "ecd,edf->ecf", x, w, ctx=ctx, name="exp") ** 2))(w)
        assert bool(jnp.all(jnp.isfinite(g)))


class TestVariants:
    def test_meprop_sparsifies(self, key):
        x = jax.random.normal(key, (32, 64))
        w = jax.random.normal(jax.random.fold_in(key, 1), (64, 128)) * 0.1
        statslib.reset()
        ctx = DitherCtx.for_step(key, 0, DitherPolicy(
            variant="meprop", meprop_k_frac=0.1, collect_stats=True,
            stats_tag="m/"))
        jax.grad(lambda w: jnp.sum(dense(x, w, ctx=ctx, name="fc") ** 2))(w)
        summ = statslib.summary()
        assert summ["m/fc"]["mean_sparsity"] >= 0.85

    def test_row_dither_unbiased(self, key):
        g = jax.random.normal(key, (64, 32)) * jnp.exp(
            jax.random.normal(jax.random.fold_in(key, 2), (64, 1)))
        outs = jnp.stack([
            rowdither.row_dither(g, jax.random.fold_in(key, i), alpha=1.0)
            for i in range(800)
        ])
        mean = jnp.mean(outs, axis=0)
        err = float(jnp.linalg.norm(mean - g) / jnp.linalg.norm(g))
        assert err < 0.15, err

    def test_row_dither_compact_roundtrip(self, key):
        g = jax.random.normal(key, (32, 16))
        c = rowdither.row_dither_compact(g, key, alpha=0.5, capacity=32)
        back = rowdither.scatter_rows(c, 32)
        # full capacity -> lossless (every kept row present)
        dense_version = rowdither.row_dither(g, key, alpha=0.5)
        np.testing.assert_allclose(np.asarray(back),
                                   np.asarray(dense_version), rtol=1e-4,
                                   atol=1e-5)


class TestStats:
    def test_stats_sink_collects_per_layer(self, key):
        statslib.reset()
        x = jax.random.normal(key, (16, 32))
        w = jax.random.normal(jax.random.fold_in(key, 1), (32, 16))
        ctx = DitherCtx.for_step(key, 0, DitherPolicy(
            variant="paper", s=2.0, collect_stats=True, stats_tag="t/"))
        for name in ("a", "b"):
            jax.grad(lambda w: jnp.sum(dense(x, w, ctx=ctx, name=name) ** 2)
                     )(w)
        assert set(statslib.tags()) == {"t/a", "t/b"}
        assert 0.0 <= statslib.overall_sparsity() <= 1.0
