"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp ref oracles,
swept over shapes and dtypes, plus equivalence with the core (non-kernel)
dithered backward.

The direct kernel tests run parametrized over BOTH interpret modes:
interpret=True is the CPU-validated path; interpret=False (compiled
Mosaic) is xfail(strict=False) — it fails structurally on a CPU host and
starts passing the day the suite runs on a TPU runner, without edits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import wireformat
from repro.core import (DitherCtx, DitherPolicy, Piecewise, PolicyProgram,
                        conv2d, dense, dithered_einsum, nsd)
from repro.obs import metrics as statslib
from repro.kernels import ops as kernelops
from repro.kernels.bsp_matmul.bsp_matmul import (bsp_matmul, bsp_matmul_int8,
                                                 fetch_map)
from repro.kernels.bsp_matmul.ref import (bsp_matmul_blocked_ref,
                                          bsp_matmul_int8_ref,
                                          bsp_matmul_ref)
from repro.kernels.nsd_quant.nsd_quant import nsd_quantize_blocked
from repro.kernels.nsd_quant.ref import nsd_quantize_blocked_ref
from repro.kernels.ops import dithered_backward_matmuls, nsd_quantize_kernel


SHAPES = [(128, 128), (256, 512), (384, 128)]
DTYPES = [jnp.float32, jnp.bfloat16]

INTERPRET_MODES = [
    pytest.param(True, id="interpret"),
    pytest.param(False, id="compiled", marks=pytest.mark.xfail(
        strict=False, reason="compiled Pallas lowering needs a TPU host")),
]


@pytest.fixture(params=INTERPRET_MODES)
def interpret(request):
    return request.param


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_nsd_kernel_vs_ref(key, shape, dtype, interpret):
    x = (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)
    delta = nsd.compute_delta(x, 2.0)
    noise = nsd.dither_noise(key, shape, delta)
    bm, bn = 128, 128
    k_k, nnz_k = nsd_quantize_blocked(x, noise, delta, bm=bm, bn=bn,
                                      interpret=interpret)
    k_r, nnz_r = nsd_quantize_blocked_ref(x, noise, delta, bm=bm, bn=bn)
    np.testing.assert_array_equal(np.asarray(k_k), np.asarray(k_r))
    np.testing.assert_array_equal(np.asarray(nnz_k), np.asarray(nnz_r))


def test_nsd_kernel_vs_core(key):
    """Same RNG key => kernel output bit-identical to repro.core.nsd."""
    g = jax.random.normal(key, (256, 256), jnp.float32) * 0.01
    k_q, delta, _ = nsd_quantize_kernel(g, key, 2.0, bm=128, bn=128)
    k_core = nsd.nsd_indices(g, key, nsd.compute_delta(g, 2.0))
    np.testing.assert_array_equal(np.asarray(k_q, dtype=np.int32),
                                  np.asarray(k_core))


def test_nsd_kernel_zero_delta(key):
    x = jnp.zeros((128, 128))
    k, nnz = nsd_quantize_blocked(x, jnp.zeros_like(x), jnp.zeros(()),
                                  bm=128, bn=128)
    assert int(jnp.sum(jnp.abs(k.astype(jnp.int32)))) == 0
    assert int(jnp.sum(nnz)) == 0


@pytest.mark.parametrize("mkn", [(128, 128, 128), (256, 384, 128),
                                 (128, 256, 256)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_bsp_matmul_vs_ref(key, mkn, dtype, interpret):
    M, K, N = mkn
    k_q = jax.random.randint(key, (M, K), -4, 5, jnp.int32).astype(jnp.int8)
    delta = jnp.float32(0.033)
    b = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    b = b.astype(dtype)
    mask = jax.random.bernoulli(
        jax.random.fold_in(key, 2), 0.6, (M // 128, K // 128)
    ).astype(jnp.int32)
    out_k = bsp_matmul(k_q, delta, b, mask, interpret=interpret)
    out_r = bsp_matmul_ref(k_q, delta, b, mask)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-2, atol=1e-2)


def test_bsp_matmul_skips_tiles(key, interpret):
    """A masked-off tile contributes nothing even if its data is nonzero."""
    M = K = N = 256
    k_q = jnp.ones((M, K), jnp.int8)
    b = jnp.ones((K, N), jnp.float32)
    mask = jnp.asarray([[1, 0], [0, 0]], jnp.int32)
    out = bsp_matmul(k_q, jnp.float32(1.0), b, mask, interpret=interpret)
    # row block 0: only first K-tile active -> 128; row block 1: all skipped
    np.testing.assert_allclose(np.asarray(out[:128]), 128.0)
    np.testing.assert_allclose(np.asarray(out[128:]), 0.0)


@pytest.mark.parametrize("mkn", [(128, 128, 128), (256, 128, 384)])
def test_bsp_matmul_int8_vs_ref(key, mkn, interpret):
    M, K, N = mkn
    k_q = jax.random.randint(key, (M, K), -8, 9, jnp.int32).astype(jnp.int8)
    b_q = jax.random.randint(jax.random.fold_in(key, 1), (K, N), -127, 128,
                             jnp.int32).astype(jnp.int8)
    scale = jnp.float32(1.7e-3)
    mask = jnp.ones((M // 128, K // 128), jnp.int32)
    out_k = bsp_matmul_int8(k_q, b_q, scale, mask, interpret=interpret)
    out_r = bsp_matmul_int8_ref(k_q, b_q, scale, mask)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5)


class TestFullBackward:
    def test_matches_core_dithered_semantics(self, key):
        T, K, N = 256, 128, 256
        g = jax.random.normal(key, (T, N), jnp.float32) * 0.01
        x = jax.random.normal(jax.random.fold_in(key, 1), (T, K))
        w = jax.random.normal(jax.random.fold_in(key, 2), (K, N)) * 0.1
        dx, dw = dithered_backward_matmuls(g, x, w, key, 2.0,
                                           int8_operands=False)
        gq = nsd.nsd_quantize(g, key, 2.0)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(gq @ w.T),
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(dw), np.asarray(x.T @ gq),
                                   rtol=1e-3, atol=1e-4)

    def test_int8_operand_path_error_small(self, key):
        T, K, N = 256, 128, 256
        g = jax.random.normal(key, (T, N), jnp.float32) * 0.01
        x = jax.random.normal(jax.random.fold_in(key, 1), (T, K))
        w = jax.random.normal(jax.random.fold_in(key, 2), (K, N)) * 0.1
        dx8, dw8 = dithered_backward_matmuls(g, x, w, key, 2.0,
                                             int8_operands=True)
        gq = nsd.nsd_quantize(g, key, 2.0)
        rel_dx = float(jnp.linalg.norm(dx8 - gq @ w.T)
                       / (jnp.linalg.norm(gq @ w.T) + 1e-12))
        rel_dw = float(jnp.linalg.norm(dw8 - x.T @ gq)
                       / (jnp.linalg.norm(x.T @ gq) + 1e-12))
        assert rel_dx < 0.03 and rel_dw < 0.03, (rel_dx, rel_dw)

    def test_high_sparsity_skips_most_tiles(self, key):
        g = jax.random.normal(key, (512, 512), jnp.float32) * 0.01
        # NOTE: the dither key must be independent of the data key, else the
        # noise correlates with the signal and sparsity drops (a real
        # pitfall this test documents)
        qkey = jax.random.fold_in(key, 1234)
        k_q, delta, nnz = nsd_quantize_kernel(g, qkey, 16.0, bm=128, bn=128)
        sparsity = float(jnp.mean(k_q == 0))
        assert sparsity > 0.93, sparsity


# ---------------------------------------------------------------------------
# fetch map: the index-map trick that suppresses operand DMA on masked tiles
# ---------------------------------------------------------------------------

class TestFetchMap:
    def test_values(self):
        mask = jnp.asarray([[0, 1, 0, 0, 1],
                            [0, 0, 0, 0, 0],
                            [1, 0, 1, 0, 0]], jnp.int32)
        f = np.asarray(fetch_map(mask))
        # masked step re-names the last occupied tile at-or-before it;
        # leading masked tiles (and all-zero rows) clamp to 0
        np.testing.assert_array_equal(f, [[0, 1, 1, 1, 4],
                                          [0, 0, 0, 0, 0],
                                          [0, 0, 2, 2, 2]])

    def test_full_mask_is_identity(self):
        mask = jnp.ones((3, 7), jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(fetch_map(mask)),
            np.broadcast_to(np.arange(7, dtype=np.int32), (3, 7)))

    def test_masked_steps_never_change_block_index(self):
        mask = (jax.random.bernoulli(jax.random.PRNGKey(5), 0.4, (6, 9))
                .astype(jnp.int32))
        f = np.asarray(fetch_map(mask))
        m = np.asarray(mask)
        # occupied step fetches itself; masked step repeats the previous
        # fetch index (so Pallas skips the HBM->VMEM copy)
        for i in range(6):
            for k in range(9):
                if m[i, k]:
                    assert f[i, k] == k
                elif k > 0:
                    assert f[i, k] == f[i, k - 1]
                else:
                    assert f[i, k] == 0


# ---------------------------------------------------------------------------
# occupancy: one representation — fused-kernel nnz == bitmap mask == dense
# ---------------------------------------------------------------------------

def _dense_tile_mask(k, bm=128, bk=128):
    """Dense oracle: tile mask recomputed from the int8 tensor itself."""
    occ = (np.asarray(k) != 0).astype(np.int64)
    M, K = occ.shape
    occ = np.pad(occ, ((0, (-M) % bm), (0, (-K) % bk)))
    t = occ.reshape(occ.shape[0] // bm, bm, occ.shape[1] // bk, bk).sum((1, 3))
    return (t > 0).astype(np.int32)


class TestOccupancySingleSource:
    def test_fused_nnz_matches_dense_recompute(self, key):
        """Satellite pin: the nnz map the fused kernel emits equals the
        dense ``reshape(...).sum((1, 3))`` recompute bit-exactly — so the
        pipeline keeping the kernel's map (instead of discarding it, the
        pre-fix behavior) changes nothing but the extra pass."""
        g = jax.random.normal(key, (200, 300), jnp.float32) * 0.01
        q = kernelops.quantize_and_mask(g, key, 2.0)
        occ = (np.asarray(q.k) != 0).astype(np.int64)
        Mp, Np = occ.shape
        dense_nnz = occ.reshape(Mp // 128, 128, Np // 128, 128).sum((1, 3))
        np.testing.assert_array_equal(np.asarray(q.nnz), dense_nnz)

    def test_mask_derived_from_bitmap_matches_nnz_and_dense(self, key):
        g = jax.random.normal(key, (96, 200), jnp.float32) * 0.01
        q = kernelops.quantize_and_mask(g, key, 2.0)
        np.testing.assert_array_equal(np.asarray(q.mask),
                                      (np.asarray(q.nnz) > 0).astype(np.int32))
        np.testing.assert_array_equal(np.asarray(q.mask),
                                      _dense_tile_mask(q.k))
        np.testing.assert_array_equal(
            np.asarray(wireformat.tile_nnz_from_bitmap(q.bitmap)),
            np.asarray(q.nnz))

    def test_padding_tiles_are_masked_off(self, key):
        """Zero inputs (incl. the zero padding) quantize to k == 0 — so a
        tile holding only zeros + padding reads 0 in the mask and is
        skipped. This is the property that replaced the silent
        ``_kernel_shapes_ok`` dense fallback."""
        g = jax.random.normal(key, (96, 200), jnp.float32)  # pads to 128x256
        g = g.at[:, 128:].set(0.0)  # tile col 1 = zero live cols + padding
        q = kernelops.quantize_and_mask(g, key, 0.5)  # dense-ish quantizer
        kq = np.asarray(q.k)
        assert kq[:, 200:].max() == 0 and kq[:, 200:].min() == 0
        assert int(np.asarray(q.mask)[:, -1].max()) == 0  # all-zero+pad tile
        assert int(np.asarray(q.mask)[:, 0].max()) == 1   # live tile kept

    def test_kernel_nnz_matches_ref_nnz_after_pipeline(self, key):
        g = jax.random.normal(key, (256, 256), jnp.float32) * 0.01
        k_q, delta, nnz = nsd_quantize_kernel(g, key, 2.0, bm=128, bn=128)
        occ = (np.asarray(k_q) != 0).astype(np.int64)
        np.testing.assert_array_equal(
            np.asarray(nnz), occ.reshape(2, 128, 2, 128).sum((1, 3)))


ADVERSARIAL_SHAPES = [
    (128, 128),   # exactly one tile
    (1, 8),       # single sub-tile row, byte-aligned
    (96, 200),    # non-multiple of the tile in both dims
    (130, 72),    # crosses a tile boundary by 2 rows
    (257, 384),   # one row over two tiles
    (37, 129),    # K % 8 != 0: bitmap bytes straddle rows
]


class TestBitmapTileMaskProperties:
    """Packed-bitmap tile mask == dense-recomputed mask, adversarially."""

    @pytest.mark.parametrize("shape", ADVERSARIAL_SHAPES)
    @pytest.mark.parametrize("fill", ["random", "zero", "dense"])
    def test_from_packed_matches_dense(self, key, shape, fill):
        if fill == "zero":
            k = jnp.zeros(shape, jnp.int8)
        elif fill == "dense":
            k = jnp.ones(shape, jnp.int8)
        else:
            k = jnp.where(
                jax.random.bernoulli(key, 0.05, shape),
                jax.random.randint(jax.random.fold_in(key, 1), shape, 1, 127,
                                   jnp.int32),
                0).astype(jnp.int8)
        p = wireformat.pack_indices(k, jnp.float32(0.1), shape, jnp.float32)
        got = np.asarray(wireformat.tile_mask_from_packed(p))
        np.testing.assert_array_equal(got, _dense_tile_mask(k))

    @pytest.mark.parametrize("shape", [(128, 128), (96, 200), (130, 72),
                                       (1, 8)])
    def test_from_bitmap_matches_dense(self, key, shape):
        k = jnp.where(jax.random.bernoulli(key, 0.03, shape), 7, 0
                      ).astype(jnp.int8)
        bitmap = wireformat.pack_bitmap(
            jnp.pad(k, ((0, 0), (0, (-shape[1]) % 8))) != 0)
        got = np.asarray(wireformat.tile_mask_from_bitmap(bitmap))
        np.testing.assert_array_equal(got, _dense_tile_mask(k))

    def test_popcount(self):
        x = jnp.arange(256, dtype=jnp.uint8)
        np.testing.assert_array_equal(
            np.asarray(wireformat.popcount_u8(x)),
            np.asarray([bin(i).count("1") for i in range(256)]))


# ---------------------------------------------------------------------------
# bit-exactness: interpret-mode kernels vs order-exact oracles
# ---------------------------------------------------------------------------

class TestBitExactOracles:
    def test_f32_kernel_bit_exact_vs_blocked_ref(self, key):
        M, K, N = 256, 384, 128
        k_q = jax.random.randint(key, (M, K), -8, 9, jnp.int32
                                 ).astype(jnp.int8)
        b = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
        mask = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.5,
                                    (M // 128, K // 128)).astype(jnp.int32)
        delta = jnp.float32(0.033)
        out = bsp_matmul(k_q, delta, b, mask, interpret=True)
        ref = bsp_matmul_blocked_ref(k_q, delta, b, mask)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_int8_kernel_bit_exact_vs_ref(self, key):
        M, K, N = 256, 256, 128
        k_q = jax.random.randint(key, (M, K), -127, 128, jnp.int32
                                 ).astype(jnp.int8)
        b_q = jax.random.randint(jax.random.fold_in(key, 1), (K, N), -127,
                                 128, jnp.int32).astype(jnp.int8)
        mask = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.5,
                                    (M // 128, K // 128)).astype(jnp.int32)
        out = bsp_matmul_int8(k_q, b_q, jnp.float32(1e-3), mask,
                              interpret=True)
        ref = bsp_matmul_int8_ref(k_q, b_q, jnp.float32(1e-3), mask)
        # int32 accumulation is exact in any order -> bit-exact, not close
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# VARIANT_KERNEL end-to-end through dense / conv2d / dithered_einsum
# ---------------------------------------------------------------------------

def _ctx(key, variant, **kw):
    return DitherCtx.for_step(key, 0, DitherPolicy(variant=variant, s=1.0,
                                                   **kw))


def _rel(a, b):
    return float(jnp.linalg.norm(a - b) / (jnp.linalg.norm(b) + 1e-12))


class TestKernelVariantParity:
    """kernel variant vs the paper path on the SAME key: the only source
    of divergence is the int8 operand quantization of x/w (<3% rel)."""

    def test_dense_nonaligned(self, key):
        x = jax.random.normal(jax.random.fold_in(key, 1), (96, 200))
        w = jax.random.normal(jax.random.fold_in(key, 2), (200, 72)) * 0.1

        def loss(x, w, c):
            return 0.5 * jnp.sum(dense(x, w, ctx=c, name="fc") ** 2)

        gk = jax.grad(loss, argnums=(0, 1))(x, w, _ctx(key, "kernel"))
        gp = jax.grad(loss, argnums=(0, 1))(x, w, _ctx(key, "paper"))
        assert _rel(gk[0], gp[0]) < 0.03, _rel(gk[0], gp[0])
        assert _rel(gk[1], gp[1]) < 0.03, _rel(gk[1], gp[1])

    def test_conv2d_vs_paper(self, key):
        x = jax.random.normal(jax.random.fold_in(key, 1), (2, 10, 10, 7))
        w = jax.random.normal(jax.random.fold_in(key, 2), (3, 3, 7, 13)) * 0.2

        def loss(x, w, c):
            return 0.5 * jnp.sum(
                conv2d(x, w, strides=(1, 1), padding="SAME", ctx=c,
                       name="cv") ** 2)

        gk = jax.grad(loss, argnums=(0, 1))(x, w, _ctx(key, "kernel"))
        gp = jax.grad(loss, argnums=(0, 1))(x, w, _ctx(key, "paper"))
        assert _rel(gk[0], gp[0]) < 0.03, _rel(gk[0], gp[0])
        assert _rel(gk[1], gp[1]) < 0.03, _rel(gk[1], gp[1])

    def test_conv2d_strided_valid_vs_paper(self, key):
        x = jax.random.normal(jax.random.fold_in(key, 1), (2, 9, 9, 5))
        w = jax.random.normal(jax.random.fold_in(key, 2), (3, 3, 5, 8)) * 0.2

        def loss(x, w, c):
            return 0.5 * jnp.sum(
                conv2d(x, w, strides=(2, 2), padding="VALID", ctx=c,
                       name="cv2") ** 2)

        gk = jax.grad(loss, argnums=(0, 1))(x, w, _ctx(key, "kernel"))
        gp = jax.grad(loss, argnums=(0, 1))(x, w, _ctx(key, "paper"))
        assert _rel(gk[0], gp[0]) < 0.03, _rel(gk[0], gp[0])
        assert _rel(gk[1], gp[1]) < 0.03, _rel(gk[1], gp[1])

    @pytest.mark.parametrize("spec,xs,ws", [
        ("ecd,edf->ecf", (3, 17, 19), (3, 19, 11)),  # batched (expert FFN)
        ("tk,kn->tn", (33, 21), (21, 9)),            # plain 2-D
        ("btk,kn->btn", (2, 15, 21), (21, 9)),       # leading batch, 2-D w
    ])
    def test_einsum_vs_paper(self, key, spec, xs, ws):
        x = jax.random.normal(jax.random.fold_in(key, 1), xs)
        w = jax.random.normal(jax.random.fold_in(key, 2), ws) * 0.3

        def loss(x, w, c):
            return 0.5 * jnp.sum(
                dithered_einsum(spec, x, w, ctx=c, name="ex") ** 2)

        gk = jax.grad(loss, argnums=(0, 1))(x, w, _ctx(key, "kernel"))
        gp = jax.grad(loss, argnums=(0, 1))(x, w, _ctx(key, "paper"))
        assert _rel(gk[0], gp[0]) < 0.03, _rel(gk[0], gp[0])
        assert _rel(gk[1], gp[1]) < 0.03, _rel(gk[1], gp[1])

    def test_unsupported_einsum_counts_fallback_and_still_correct(self, key):
        x = jax.random.normal(jax.random.fold_in(key, 1), (5, 7, 6))
        w = jax.random.normal(jax.random.fold_in(key, 2), (5, 7, 4))
        reason = "einsum:unsupported-form:bcd,bcf->bdf"
        before = kernelops.KERNEL_FALLBACKS.get(reason, 0)

        def loss(x, w, c):
            return jnp.sum(
                dithered_einsum("bcd,bcf->bdf", x, w, ctx=c, name="fb") ** 2)

        gk = jax.grad(loss, argnums=(0, 1))(x, w, _ctx(key, "kernel"))
        assert kernelops.KERNEL_FALLBACKS.get(reason, 0) > before
        # the fallback is the generic quantized path == paper semantics
        gp = jax.grad(loss, argnums=(0, 1))(x, w, _ctx(key, "paper"))
        np.testing.assert_array_equal(np.asarray(gk[0]), np.asarray(gp[0]))
        np.testing.assert_array_equal(np.asarray(gk[1]), np.asarray(gp[1]))

    def test_grouped_conv_counts_fallback(self, key):
        x = jax.random.normal(jax.random.fold_in(key, 1), (1, 6, 6, 4))
        w = jax.random.normal(jax.random.fold_in(key, 2), (3, 3, 2, 4)) * 0.2
        reason = "conv:groups-or-lhs-dilation"
        before = kernelops.KERNEL_FALLBACKS.get(reason, 0)

        def loss(x, w, c):
            return jnp.sum(conv2d(x, w, feature_group_count=2, ctx=c,
                                  name="gcv") ** 2)

        g = jax.grad(loss, argnums=(0, 1))(x, w, _ctx(key, "kernel"))
        assert kernelops.KERNEL_FALLBACKS.get(reason, 0) > before
        assert all(bool(jnp.all(jnp.isfinite(a))) for a in g)


class TestKernelTelemetryDedup:
    def test_emitted_stats_match_core_quantizer(self, key):
        """Satellite pin: the kernel path's telemetry comes from the SAME
        k tensor the matmuls consume — bit-identical to
        ``quant_stats(nsd_indices(g2d, key, delta))`` for the same key, so
        the applied gradient and the reported sparsity can never diverge."""
        x = jax.random.normal(jax.random.fold_in(key, 1), (40, 60))
        w = jax.random.normal(jax.random.fold_in(key, 2), (60, 24)) * 0.1
        ctx = _ctx(key, "kernel", collect_stats=True, stats_tag="kd/")

        def loss(x, w):
            return 0.5 * jnp.sum(dense(x, w, ctx=ctx, name="fc") ** 2)

        statslib.reset()
        jax.grad(loss, argnums=(0, 1))(x, w)
        jax.effects_barrier()
        row = statslib.rows("kd/fc")[0]
        # reproduce the cotangent (g = y for this loss) and the layer key
        g2d = x @ w
        lkey = ctx.resolve("fc").key
        delta = nsd.compute_delta(g2d, 1.0)
        k = nsd.nsd_indices(g2d, lkey, delta)
        expect = nsd.quant_stats(k, delta)
        np.testing.assert_array_equal(
            row, np.asarray([float(expect.sparsity),
                             float(expect.max_bitwidth),
                             float(expect.delta)], np.float32))


class TestPolicyProgramClause:
    def test_dsl_rule_enables_kernel_variant_per_layer(self, key):
        """Acceptance pin: a --policy-program clause turns the kernel
        backward on for matching layers only."""
        from repro.core.schedule import parse_program

        prog = parse_program("rule fc*:variant=kernel")
        ctx = DitherCtx.for_step(key, 0, prog.base, program=prog)
        assert ctx.resolve("fc1").spec.variant == "kernel"
        assert ctx.resolve("fc_out").spec.variant == "kernel"
        # non-matching layers keep the (paper) base variant
        assert ctx.resolve("conv0").spec.variant == "paper"


class TestKernelVariantRecompile:
    def test_s_ramp_zero_recompiles_across_all_ops(self, key):
        """Acceptance pin: a scheduled s ramp with variant=kernel compiles
        the step exactly once — dense, conv2d and dithered_einsum kernel
        backwards all take s as traced data."""
        prog = PolicyProgram(
            base=DitherPolicy(variant="kernel"),
            s=Piecewise(((0, 1.0), (2, 2.0), (4, 4.0))))
        xd = jax.random.normal(key, (8, 16))
        xc = jax.random.normal(jax.random.fold_in(key, 1), (2, 6, 6, 3))
        xe = jax.random.normal(jax.random.fold_in(key, 2), (2, 7, 9))
        traces = []

        @jax.jit
        def step(w, i, k):
            traces.append(1)  # appended at trace time only
            ctx = DitherCtx.for_step(k, i, prog.base, program=prog)

            def loss(w):
                a = dense(xd, w["wd"], ctx=ctx, name="fc")
                b = conv2d(xc, w["wc"], ctx=ctx, name="cv")
                c = dithered_einsum("ecd,edf->ecf", xe, w["we"], ctx=ctx,
                                    name="ex")
                return (jnp.sum(a ** 2) + jnp.sum(b ** 2)
                        + jnp.sum(c ** 2))

            g = jax.grad(loss)(w)
            return jax.tree.map(lambda a, b: a - 0.01 * b, w, g)

        w = {"wd": jax.random.normal(key, (16, 8)) * 0.1,
             "wc": jax.random.normal(jax.random.fold_in(key, 3),
                                     (3, 3, 3, 5)) * 0.1,
             "we": jax.random.normal(jax.random.fold_in(key, 4),
                                     (2, 9, 5)) * 0.1}
        for i in range(6):
            w = step(w, jnp.int32(i), key)
        assert len(traces) == 1, f"s ramp retraced {len(traces)} times"
