"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp ref oracles,
swept over shapes and dtypes, plus equivalence with the core (non-kernel)
dithered backward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import nsd
from repro.kernels.bsp_matmul.bsp_matmul import bsp_matmul, bsp_matmul_int8
from repro.kernels.bsp_matmul.ref import bsp_matmul_int8_ref, bsp_matmul_ref
from repro.kernels.nsd_quant.nsd_quant import nsd_quantize_blocked
from repro.kernels.nsd_quant.ref import nsd_quantize_blocked_ref
from repro.kernels.ops import dithered_backward_matmuls, nsd_quantize_kernel


SHAPES = [(128, 128), (256, 512), (384, 128)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_nsd_kernel_vs_ref(key, shape, dtype):
    x = (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)
    delta = nsd.compute_delta(x, 2.0)
    noise = nsd.dither_noise(key, shape, delta)
    bm, bn = 128, 128
    k_k, nnz_k = nsd_quantize_blocked(x, noise, delta, bm=bm, bn=bn)
    k_r, nnz_r = nsd_quantize_blocked_ref(x, noise, delta, bm=bm, bn=bn)
    np.testing.assert_array_equal(np.asarray(k_k), np.asarray(k_r))
    np.testing.assert_array_equal(np.asarray(nnz_k), np.asarray(nnz_r))


def test_nsd_kernel_vs_core(key):
    """Same RNG key => kernel output bit-identical to repro.core.nsd."""
    g = jax.random.normal(key, (256, 256), jnp.float32) * 0.01
    k_q, delta, _ = nsd_quantize_kernel(g, key, 2.0, bm=128, bn=128)
    k_core = nsd.nsd_indices(g, key, nsd.compute_delta(g, 2.0))
    np.testing.assert_array_equal(np.asarray(k_q, dtype=np.int32),
                                  np.asarray(k_core))


def test_nsd_kernel_zero_delta(key):
    x = jnp.zeros((128, 128))
    k, nnz = nsd_quantize_blocked(x, jnp.zeros_like(x), jnp.zeros(()),
                                  bm=128, bn=128)
    assert int(jnp.sum(jnp.abs(k.astype(jnp.int32)))) == 0
    assert int(jnp.sum(nnz)) == 0


@pytest.mark.parametrize("mkn", [(128, 128, 128), (256, 384, 128),
                                 (128, 256, 256)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_bsp_matmul_vs_ref(key, mkn, dtype):
    M, K, N = mkn
    k_q = jax.random.randint(key, (M, K), -4, 5, jnp.int32).astype(jnp.int8)
    delta = jnp.float32(0.033)
    b = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    b = b.astype(dtype)
    mask = jax.random.bernoulli(
        jax.random.fold_in(key, 2), 0.6, (M // 128, K // 128)
    ).astype(jnp.int32)
    out_k = bsp_matmul(k_q, delta, b, mask)
    out_r = bsp_matmul_ref(k_q, delta, b, mask)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-2, atol=1e-2)


def test_bsp_matmul_skips_tiles(key):
    """A masked-off tile contributes nothing even if its data is nonzero."""
    M = K = N = 256
    k_q = jnp.ones((M, K), jnp.int8)
    b = jnp.ones((K, N), jnp.float32)
    mask = jnp.asarray([[1, 0], [0, 0]], jnp.int32)
    out = bsp_matmul(k_q, jnp.float32(1.0), b, mask)
    # row block 0: only first K-tile active -> 128; row block 1: all skipped
    np.testing.assert_allclose(np.asarray(out[:128]), 128.0)
    np.testing.assert_allclose(np.asarray(out[128:]), 0.0)


@pytest.mark.parametrize("mkn", [(128, 128, 128), (256, 128, 384)])
def test_bsp_matmul_int8_vs_ref(key, mkn):
    M, K, N = mkn
    k_q = jax.random.randint(key, (M, K), -8, 9, jnp.int32).astype(jnp.int8)
    b_q = jax.random.randint(jax.random.fold_in(key, 1), (K, N), -127, 128,
                             jnp.int32).astype(jnp.int8)
    scale = jnp.float32(1.7e-3)
    mask = jnp.ones((M // 128, K // 128), jnp.int32)
    out_k = bsp_matmul_int8(k_q, b_q, scale, mask)
    out_r = bsp_matmul_int8_ref(k_q, b_q, scale, mask)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5)


class TestFullBackward:
    def test_matches_core_dithered_semantics(self, key):
        T, K, N = 256, 128, 256
        g = jax.random.normal(key, (T, N), jnp.float32) * 0.01
        x = jax.random.normal(jax.random.fold_in(key, 1), (T, K))
        w = jax.random.normal(jax.random.fold_in(key, 2), (K, N)) * 0.1
        dx, dw = dithered_backward_matmuls(g, x, w, key, 2.0,
                                           int8_operands=False)
        gq = nsd.nsd_quantize(g, key, 2.0)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(gq @ w.T),
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(dw), np.asarray(x.T @ gq),
                                   rtol=1e-3, atol=1e-4)

    def test_int8_operand_path_error_small(self, key):
        T, K, N = 256, 128, 256
        g = jax.random.normal(key, (T, N), jnp.float32) * 0.01
        x = jax.random.normal(jax.random.fold_in(key, 1), (T, K))
        w = jax.random.normal(jax.random.fold_in(key, 2), (K, N)) * 0.1
        dx8, dw8 = dithered_backward_matmuls(g, x, w, key, 2.0,
                                             int8_operands=True)
        gq = nsd.nsd_quantize(g, key, 2.0)
        rel_dx = float(jnp.linalg.norm(dx8 - gq @ w.T)
                       / (jnp.linalg.norm(gq @ w.T) + 1e-12))
        rel_dw = float(jnp.linalg.norm(dw8 - x.T @ gq)
                       / (jnp.linalg.norm(x.T @ gq) + 1e-12))
        assert rel_dx < 0.03 and rel_dw < 0.03, (rel_dx, rel_dw)

    def test_high_sparsity_skips_most_tiles(self, key):
        g = jax.random.normal(key, (512, 512), jnp.float32) * 0.01
        # NOTE: the dither key must be independent of the data key, else the
        # noise correlates with the signal and sparsity drops (a real
        # pitfall this test documents)
        qkey = jax.random.fold_in(key, 1234)
        k_q, delta, nnz = nsd_quantize_kernel(g, qkey, 16.0, bm=128, bn=128)
        sparsity = float(jnp.mean(k_q == 0))
        assert sparsity > 0.93, sparsity
