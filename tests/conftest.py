import jax
import pytest

# Tests run on the single real CPU device (the 512-device override is
# dry-run-only by design). Keep x64 off; models under test use f32.
jax.config.update("jax_platforms", "cpu")


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
