"""Serving tier: chunked prefill, paged quantized KV, scheduler, workers.

Pins the two historical engine bugs (teacher-forced prefill that only
wrote the last prompt token into the KV cache; one shared position counter
across slots) with parity tests against the ``Model.prefill`` reference
path, and covers the paged cache, scheduler edge cases, preemption, the
serve health monitor, and the launcher spec parser.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_model
from repro.memory import codec
from repro.obs.bus import MetricsBus, set_bus
from repro.obs.monitor import ServeMonitor
from repro.serve import (Engine, PagePool, Request, Scheduler,
                         SchedulerConfig, ServeConfig, Supervisor,
                         greedy_generate, kvcache)
from repro.serve.kvcache import init_paged, pages_for


@functools.lru_cache(maxsize=None)
def _model(arch):
    model = get_smoke_model(arch)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


def _prompts(vocab, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=n).astype(np.int32) for n in sizes]


def _refs(model, params, prompts, n_new, max_len=64):
    return [greedy_generate(model, params, p, n_new, max_len=max_len)
            for p in prompts]


class TestEngineParity:
    def test_engine_matches_greedy_generate(self):
        """Regression for the teacher-forced-prefill bug: with only the
        last prompt token in the KV cache, multi-token prompts diverge
        from the reference immediately."""
        model, params = _model("gemma-2b")
        prompts = _prompts(model.cfg.vocab, (3, 9, 5))
        refs = _refs(model, params, prompts, 6)
        eng = Engine(model, params, ServeConfig(max_batch=4, max_len=64))
        for uid, p in enumerate(prompts):
            assert eng.submit(Request(uid, p, max_new_tokens=6))
        out = eng.run(max_ticks=64)
        assert {u: out[u] for u in out} == dict(enumerate(refs))

    def test_staggered_admission_parity(self):
        """Regression for the shared position counter: a request admitted
        mid-run must write cache position 0, not the engine's tick."""
        model, params = _model("gemma-2b")
        prompts = _prompts(model.cfg.vocab, (9, 11), seed=1)
        refs = _refs(model, params, prompts, 8)
        eng = Engine(model, params,
                     ServeConfig(max_batch=2, max_len=64, chunk=4))
        assert eng.submit(Request(0, prompts[0], max_new_tokens=8))
        for _ in range(3):  # slot 0 is several positions in before slot 1
            eng.step()
        assert eng.submit(Request(1, prompts[1], max_new_tokens=8))
        done = dict(eng.run(max_ticks=64))
        assert done[0] == refs[0]
        assert done[1] == refs[1]

    def test_chunk_size_invariant(self):
        """Prefill chunking is a scheduling choice, not a numerics one."""
        model, params = _model("gemma-2b")
        prompts = _prompts(model.cfg.vocab, (7, 13), seed=2)
        outs = []
        for chunk in (1, 4, 16):
            eng = Engine(model, params,
                         ServeConfig(max_batch=2, max_len=64, chunk=chunk))
            for uid, p in enumerate(prompts):
                eng.submit(Request(uid, p, max_new_tokens=5))
            outs.append(dict(eng.run(max_ticks=96)))
        assert outs[0] == outs[1] == outs[2]

    @pytest.mark.parametrize("arch", ["mamba2-370m", "hymba-1.5b"])
    def test_families_match_greedy(self, arch):
        model, params = _model(arch)
        prompts = _prompts(model.cfg.vocab, (3, 6), seed=3)
        refs = _refs(model, params, prompts, 4, max_len=32)
        eng = Engine(model, params,
                     ServeConfig(max_batch=2, max_len=32, chunk=4))
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid, p, max_new_tokens=4))
        out = eng.run(max_ticks=64)
        assert out == dict(enumerate(refs))


class TestPrefill:
    """Model.prefill is the uniform reference across decoding families."""

    @pytest.mark.parametrize("arch", ["gemma-2b", "mamba2-370m",
                                      "hymba-1.5b"])
    def test_prefill_matches_stepwise_decode(self, arch):
        model, params = _model(arch)
        toks = _prompts(model.cfg.vocab, (6,), seed=4)[0][None]
        logits, cache, t = model.prefill(params, jnp.asarray(toks), 16)
        assert logits.shape[:2] == (1, 6)
        # feeding one more token continues from the prefilled state
        nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        step_logits, _ = model.decode_step(params, cache, nxt, t + 1)
        assert np.isfinite(np.asarray(step_logits)).all()

    def test_encdec_prefill_greedy(self):
        model, params = _model("whisper-small")
        frames = np.asarray(jax.random.normal(
            jax.random.PRNGKey(1), (1, model.cfg.n_frames,
                                    model.cfg.d_model)))
        toks = greedy_generate(model, params, np.array([1, 7, 3], np.int32),
                               4, max_len=32, frames=frames)
        assert len(toks) == 4
        assert all(0 <= t < model.cfg.vocab for t in toks)

    def test_encdec_engine_refused(self):
        model, params = _model("whisper-small")
        with pytest.raises(ValueError, match="greedy_generate"):
            Engine(model, params, ServeConfig(max_batch=2, max_len=32))

    def test_greedy_generate_zero_new_tokens(self):
        model, params = _model("gemma-2b")
        assert greedy_generate(model, params, np.array([1, 2], np.int32),
                               0) == []


class TestPagedKV:
    @pytest.mark.parametrize("mode", kvcache.KV_MODES)
    def test_engine_paged_modes(self, mode):
        model, params = _model("gemma-2b")
        prompts = _prompts(model.cfg.vocab, (3, 9), seed=5)
        refs = _refs(model, params, prompts, 5)
        eng = Engine(model, params, ServeConfig(
            max_batch=2, max_len=64, kv_mode=mode, kv_page=8))
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid, p, max_new_tokens=5))
        out = eng.run(max_ticks=64)
        assert sorted(out) == [0, 1]
        if mode in ("fp32", "bf16"):
            # fp32 passthrough is bit-exact by construction; bf16 holds on
            # this model because KV magnitudes sit well inside bf16 range
            assert out == dict(enumerate(refs))

    def test_fp32_pages_bit_exact_vs_dense(self):
        model, params = _model("gemma-2b")
        prompts = _prompts(model.cfg.vocab, (17, 4, 11), seed=6)
        refs = _refs(model, params, prompts, 7)
        eng = Engine(model, params, ServeConfig(
            max_batch=4, max_len=64, kv_mode="fp32", kv_page=16))
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid, p, max_new_tokens=7))
        assert eng.run(max_ticks=96) == dict(enumerate(refs))

    @pytest.mark.parametrize("mode", kvcache.KV_MODES)
    def test_page_roundtrip(self, mode):
        """Seal-and-read through update_and_view reproduces the written
        values (exactly for fp32, within codec tolerance otherwise)."""
        key = jax.random.PRNGKey(7)
        pk = init_paged(mode, batch=1, max_len=16, n_pages=4, page=4,
                        n_kv=2, hd=8, dtype=jnp.float32, key=key)
        pk = pk.with_table(jnp.array([[0, 1, 2, 3]], jnp.int32))
        vals = jax.random.normal(key, (8, 2, 8))
        for t in range(8):
            K, V, k_pos, valid, pk = pk.update_and_view(
                vals[t][None, None], vals[t][None, None],
                jnp.array([t], jnp.int32))
        assert bool(valid[0, :8].all()) and not bool(valid[0, 8:].any())
        got = np.asarray(K[0, :8])
        want = np.asarray(vals)
        if mode in ("fp32",):
            np.testing.assert_array_equal(got, want)
        elif mode == "bf16":
            np.testing.assert_allclose(got, want, atol=0.02, rtol=0.02)
        else:
            # quantized: sealed page (first 4 positions) within codec
            # error; unsealed tail (last 4) still exact
            np.testing.assert_array_equal(got[4:], want[4:])
            assert np.abs(got[:4] - want[:4]).max() < 1.0

    def test_inactive_slot_never_writes(self):
        pk = init_paged("fp32", batch=2, max_len=8, n_pages=4, page=4,
                        n_kv=1, hd=4, dtype=jnp.float32,
                        key=jax.random.PRNGKey(0))
        pk = pk.with_table(jnp.array([[0, 1], [2, 3]], jnp.int32))
        one = jnp.ones((2, 1, 1, 4))
        K, V, _, valid, pk = pk.update_and_view(
            one, one, jnp.array([0, -1], jnp.int32))
        assert not bool(valid[1].any())  # inactive slot fully masked
        assert float(jnp.abs(pk.tail_k[1]).max()) == 0.0  # write parked

    def test_capacity_compression_floor(self):
        """int8/NSD pages hold >= 3x the tokens of fp32 pages at equal
        capacity bytes (the serve_bench gate, checked statically)."""
        for mode in ("int8", "nsd"):
            enc = kvcache.page_stored_nbytes(mode, 16, 1, 32)
            dense = kvcache.page_dense_nbytes(16, 1, 32)
            assert dense / enc >= 3.0, (mode, dense / enc)

    def test_pages_for(self):
        assert pages_for(1, 8) == 1
        assert pages_for(8, 8) == 1
        assert pages_for(9, 8) == 2


class TestScheduler:
    def test_pool_alloc_all_or_nothing(self):
        pool = PagePool(4, page=8)
        got = pool.alloc(3)
        assert len(got) == 3 and pool.free_pages == 1
        assert pool.alloc(2) is None  # short -> nothing taken
        assert pool.free_pages == 1
        pool.free(got)
        assert pool.free_pages == 4

    def test_pool_double_free_raises(self):
        pool = PagePool(2, page=4)
        ids = pool.alloc(1)
        pool.free(ids)
        with pytest.raises(ValueError):
            pool.free(ids)

    def test_queue_bound_rejects(self):
        sched = Scheduler(SchedulerConfig(max_queue=2), max_batch=2)
        assert sched.submit("a", tokens_worst_case=4)
        assert sched.submit("b", tokens_worst_case=4)
        assert not sched.submit("c", tokens_worst_case=4)
        assert sched.rejected == 1

    def test_token_budget_blocks_admission(self):
        sched = Scheduler(SchedulerConfig(max_active_tokens=10),
                          max_batch=4)
        sched.submit("a", tokens_worst_case=6)
        assert sched.next_request(8, lambda r: 6) is None  # 8 + 6 > 10
        assert sched.next_request(4, lambda r: 6) == "a"

    def test_impossible_request_rejected_at_submit(self):
        pool = PagePool(2, page=4)
        sched = Scheduler(SchedulerConfig(), max_batch=2,
                          max_pages_per_slot=8, pool=pool)
        with pytest.raises(ValueError, match="pool caps"):
            sched.submit("big", tokens_worst_case=100)

    def test_table_reflects_mappings(self):
        pool = PagePool(4, page=4)
        sched = Scheduler(SchedulerConfig(), max_batch=2,
                          max_pages_per_slot=2, pool=pool)
        assert sched.ensure(0, 6)  # 2 pages
        t = sched.table()
        assert (t[0] >= 0).sum() == 2 and (t[1] == -1).all()
        sched.release(0)
        assert pool.free_pages == 4


class TestEngineEdgeCases:
    def test_max_new_tokens_zero(self):
        model, params = _model("gemma-2b")
        eng = Engine(model, params, ServeConfig(max_batch=2, max_len=32))
        eng.submit(Request(0, np.array([1, 2, 3], np.int32),
                           max_new_tokens=0))
        out = eng.run(max_ticks=8)
        assert out == {0: []}

    def test_eos_on_first_decoded_token(self):
        model, params = _model("gemma-2b")
        p = _prompts(model.cfg.vocab, (5,), seed=8)[0]
        first = greedy_generate(model, params, p, 1, max_len=32)[0]
        eng = Engine(model, params,
                     ServeConfig(max_batch=2, max_len=32, eos_id=first))
        eng.submit(Request(0, p, max_new_tokens=16))
        out = eng.run(max_ticks=32)
        assert out == {0: [first]}  # stopped immediately on eos

    def test_queue_outlives_max_ticks(self):
        """Work left when the tick budget runs out stays pending and
        completes on the next run() call."""
        model, params = _model("gemma-2b")
        prompts = _prompts(model.cfg.vocab, (4, 4, 4), seed=9)
        refs = _refs(model, params, prompts, 6, max_len=32)
        eng = Engine(model, params,
                     ServeConfig(max_batch=1, max_len=32, chunk=4))
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid, p, max_new_tokens=6))
        first = eng.run(max_ticks=3)  # not enough for even one request
        assert len(first) < 3 and eng.sched.queue_depth > 0
        done = dict(first)
        for _ in range(10):
            done.update(eng.run(max_ticks=16))
            if len(done) == 3:
                break
        assert done == dict(enumerate(refs))

    def test_pool_exhaustion_preempts_and_completes(self):
        model, params = _model("gemma-2b")
        prompts = _prompts(model.cfg.vocab, (9, 11, 6, 4), seed=10)
        refs = _refs(model, params, prompts, 8)
        eng = Engine(model, params, ServeConfig(
            max_batch=4, max_len=32, kv_mode="fp32", kv_page=4,
            kv_pool_pages=6))
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid, p, max_new_tokens=8))
        out = eng.run(max_ticks=400)
        assert eng.preemptions > 0  # the pool really was short
        assert out == dict(enumerate(refs))  # recompute is lossless


class TestWorkerAndMonitor:
    def test_supervisor_routes_and_drains(self):
        sup = Supervisor()
        for arch in ("gemma-2b", "mamba2-370m"):
            model, params = _model(arch)
            sup.add_worker(arch, model, params,
                           ServeConfig(max_batch=2, max_len=32, chunk=4))
        rng = np.random.default_rng(11)
        uids = [sup.submit(rng.integers(0, 512, 4), 3, model=a)
                for a in ("gemma-2b", "mamba2-370m")]
        out = sup.run(max_ticks=32)
        assert sorted(out) == sorted(uids)
        for h in sup.health():
            assert h.idle and h.finished == 1
        assert sup.result(uids[0]) == out[uids[0]]

    def test_serve_monitor_stall_and_backlog(self):
        bus = MetricsBus()
        mon = ServeMonitor(max_backlog=4.0, min_rows=3, bus=bus)
        # healthy ticks: work present, tokens flowing
        for i in range(3):
            bus.record("serve", "w0", [i, 2, 0, 8, 2, 0, 0])
        assert mon.tick(3) == []
        # stalled: active slots but zero fed tokens for min_rows ticks
        for i in range(3, 6):
            bus.record("serve", "w0", [i, 2, 0, 0, 0, 0, 0])
        kinds = {e.kind for e in mon.tick(6)}
        assert "serve_stall" in kinds
        # backlog: queue depth persistently above the ceiling
        bus2 = MetricsBus()
        mon2 = ServeMonitor(max_backlog=4.0, min_rows=3, bus=bus2)
        for i in range(6):
            bus2.record("serve", "w1", [i, 1, 9, 4, 1, 0, 0])
        kinds = {e.kind for e in mon2.tick(6)}
        assert "serve_backlog" in kinds and "serve_stall" not in kinds

    def test_engine_records_serve_rows(self):
        bus = MetricsBus()
        set_bus(bus)
        try:
            model, params = _model("gemma-2b")
            eng = Engine(model, params, ServeConfig(
                max_batch=2, max_len=32, kv_mode="int8", kv_page=8),
                name="rowtest")
            eng.submit(Request(0, np.array([1, 2, 3, 4], np.int32),
                               max_new_tokens=3))
            eng.run(max_ticks=16)
            rows = bus.rows_since("serve", "rowtest", 0)
            assert len(rows) >= 2
            busy = rows[rows[:, 1] > 0]
            # quantized pages must undercut their dense counterfactual
            sealed = busy[busy[:, 5] > 0]
            assert len(sealed) and (sealed[:, 5] < sealed[:, 6]).all()
        finally:
            set_bus(None)


class TestServeSpec:
    def test_parse_multi_worker(self):
        from repro.launch.serve import parse_serve_spec, serve_config
        secs = parse_serve_spec(
            "worker gemma-2b: batch=4;kv=int8;page=16;chunk=8 "
            "worker mamba2-370m: batch=2;queue=8")
        assert [a for a, _ in secs] == ["gemma-2b", "mamba2-370m"]
        cfg = serve_config(secs[0][1])
        assert (cfg.max_batch, cfg.kv_mode, cfg.kv_page,
                cfg.chunk) == (4, "int8", 16, 8)
        cfg2 = serve_config(secs[1][1])
        assert cfg2.max_batch == 2 and cfg2.max_queue == 8

    def test_parse_rejects_bad_spec(self):
        from repro.launch.serve import parse_serve_spec
        with pytest.raises(ValueError, match="must start"):
            parse_serve_spec("batch=4")
        with pytest.raises(ValueError, match="unknown arch"):
            parse_serve_spec("worker nosuch: batch=1")
        with pytest.raises(ValueError, match="unknown serve key"):
            parse_serve_spec("worker gemma-2b: widgets=7")
