"""Optimizer + schedules + data-pipeline tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import ClassifConfig, TokenStreamConfig, classification_batch, \
    token_batch
from repro.data.pipeline import ShardedLoader
from repro.optim import (OptConfig, apply_updates, clip_by_global_norm,
                         init_opt_state, schedule_lr)


class TestOptimizers:
    @pytest.mark.parametrize("name", ["adamw", "sgd"])
    def test_converges_on_quadratic(self, name, key):
        w_star = jax.random.normal(key, (16,))
        params = {"w": jnp.zeros((16,))}
        cfg = OptConfig(name=name, lr=0.1 if name == "sgd" else 0.05,
                        grad_clip=None, weight_decay=0.0)
        state = init_opt_state(params, cfg)
        for _ in range(200):
            grads = {"w": params["w"] - w_star}
            params, state, _ = apply_updates(params, grads, state, cfg)
        assert float(jnp.linalg.norm(params["w"] - w_star)) < 1e-2

    def test_bf16_master_weights(self, key):
        """bf16 params accumulate through an f32 master copy: many tiny
        updates must not be lost to bf16 rounding."""
        params = {"w": jnp.ones((8,), jnp.bfloat16)}
        cfg = OptConfig(name="sgd", lr=1e-4, momentum=0.0, grad_clip=None)
        state = init_opt_state(params, cfg)
        for _ in range(100):
            params, state, _ = apply_updates(
                params, {"w": jnp.ones((8,), jnp.float32)}, state, cfg)
        # 100 * 1e-4 = 0.01 total; bf16 alone would swallow each 1e-4 step
        master = state["master"]["w"]
        np.testing.assert_allclose(np.asarray(master), 1.0 - 0.01, rtol=1e-4)

    def test_grad_clipping(self):
        g = {"a": jnp.full((4,), 100.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(200.0)
        assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0,
                                                                     rel=1e-4)

    def test_schedules(self):
        cfg = OptConfig(lr=1.0, schedule="cosine", warmup_steps=10,
                        total_steps=110, min_lr_ratio=0.1)
        lr0 = float(schedule_lr(cfg, jnp.asarray(0)))
        lr9 = float(schedule_lr(cfg, jnp.asarray(9)))
        lr_end = float(schedule_lr(cfg, jnp.asarray(110)))
        assert lr0 < lr9 <= 1.0
        assert lr_end == pytest.approx(0.1, rel=1e-3)
        # paper's step decay: 0.1 every 100 steps
        cfg2 = OptConfig(lr=1.0, schedule="step", step_decay_every=100,
                         step_decay_rate=0.1)
        assert float(schedule_lr(cfg2, jnp.asarray(99))) == pytest.approx(1.0)
        assert float(schedule_lr(cfg2, jnp.asarray(100))) == pytest.approx(0.1)

    def test_sgd_matches_paper_recipe(self):
        """momentum 0.9 + wd 5e-4: one step against hand computation."""
        params = {"w": jnp.asarray([1.0])}
        cfg = OptConfig(name="sgd", lr=0.1, momentum=0.9, weight_decay=5e-4,
                        grad_clip=None)
        state = init_opt_state(params, cfg)
        g = {"w": jnp.asarray([2.0])}
        params, state, _ = apply_updates(params, g, state, cfg)
        expected = 1.0 - 0.1 * (2.0 + 5e-4 * 1.0)
        np.testing.assert_allclose(np.asarray(params["w"]), [expected],
                                   rtol=1e-6)


class TestData:
    def test_token_stream_deterministic(self):
        cfg = TokenStreamConfig(vocab=128, seq_len=16, batch=4, seed=3)
        b1, b2 = token_batch(cfg, 7), token_batch(cfg, 7)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))
        b3 = token_batch(cfg, 8)
        assert not np.array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b3["tokens"]))

    def test_labels_shifted(self):
        cfg = TokenStreamConfig(vocab=128, seq_len=16, batch=2)
        b = token_batch(cfg, 0)
        assert b["tokens"].shape == b["labels"].shape == (2, 16)
        assert int(jnp.max(b["tokens"])) < 128

    def test_classification_learnable(self):
        cfg = ClassifConfig(n_classes=4, img_size=8, channels=1, noise=0.1)
        b = classification_batch(cfg, 0, batch=64)
        # nearest-prototype classification must beat chance by a lot
        from repro.data.synthetic import _prototypes
        protos = _prototypes(cfg).reshape(4, -1)
        x = np.asarray(b["images"]).reshape(64, -1)
        pred = np.argmin(
            ((x[:, None, :] - protos[None]) ** 2).sum(-1), axis=1)
        acc = (pred == np.asarray(b["labels"])).mean()
        assert acc > 0.95

    def test_sharded_loader_prefetch(self):
        cfg = TokenStreamConfig(vocab=64, seq_len=8, batch=2)
        loader = ShardedLoader(lambda s: token_batch(cfg, s), prefetch=2)
        steps = []
        for _ in range(3):
            s, batch = next(loader)
            steps.append(s)
            assert batch["tokens"].shape == (2, 8)
        loader.close()
        assert steps == [0, 1, 2]


class TestGradAccum:
    def test_flat_batch_split_into_microbatches(self, key):
        """grad_accum=2 must accept a flat batch and split it (regression:
        the elastic-restart path scales accumulation after a downsize)."""
        from repro.configs import get_smoke_model
        from repro.core import DitherPolicy
        from repro.data import TokenStreamConfig, token_batch
        from repro.train import Trainer, TrainerConfig

        model = get_smoke_model("mamba2-370m")
        trainer = Trainer(model, OptConfig(lr=1e-3),
                          TrainerConfig(total_steps=4, grad_accum=2,
                                        log_every=1),
                          policy=DitherPolicy(variant="paper", s=2.0))
        tcfg = TokenStreamConfig(vocab=model.cfg.vocab, seq_len=16, batch=8)

        def it():
            i = 0
            while True:
                yield token_batch(tcfg, i)
                i += 1

        out = trainer.fit(it())
        assert len(out["history"]) == 4
        assert all(np.isfinite(h["loss"]) for h in out["history"])
