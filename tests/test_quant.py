"""The quant engine: registry front door, facade bit-exactness against the
legacy entry points (now deprecation shims), the new codecs (int4 grouped,
m8/u8 moments), compute-on-packed, grad_codec threading, and the
``quant:`` launcher DSL section."""
import dataclasses
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import quant
from repro.core import DitherCtx, DitherPolicy, dense, int8 as int8lib, nsd
from repro.optim import OptConfig, apply_updates, init_opt_state
from repro.quant import (QuantSpec, codec_names, decode, dense_nbytes,
                         encode, error_bound, get_codec, measured_bytes,
                         parse_quant_program, parse_spec, quantize,
                         resid_key, stored_nbytes, validate_spec)


class TestRegistry:
    def test_all_builtins_registered(self):
        assert set(codec_names()) >= {"fp32", "remat", "bf16", "int8", "nsd",
                                      "int8_absmax", "int4", "m8", "u8"}

    def test_parse_spec_is_cached_and_canonical(self):
        s1 = parse_spec("nsd@0.5")
        assert s1 is parse_spec("nsd@0.5")  # lru_cache
        assert s1.mode == "nsd@0.5"
        assert parse_spec("int4@g64").mode == "int4@g64"
        assert parse_spec("int4").group == quant.DEFAULT_INT4_GROUP

    def test_unknown_codec_names_the_registry(self):
        with pytest.raises(ValueError, match="unknown codec"):
            validate_spec("fp64")

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            quant.register(get_codec("int8"))

    def test_spec_is_static_and_hashable(self):
        spec = parse_spec("int4@g32")
        assert isinstance(spec, QuantSpec)
        assert hash(spec) == hash(spec.replace())


class TestLegacyPins:
    """The old entry points are shims over repro.quant — bit-exact."""

    def test_memory_codec_shim_reexports_same_objects(self):
        import repro.memory.codec as legacy

        assert legacy.encode is quant.encode
        assert legacy.decode is quant.decode
        assert legacy.parse_mode is quant.parse_mode

    def test_comm_wireformat_shim_reexports_same_objects(self):
        import repro.comm.wireformat as legacy

        assert legacy.pack_nsd is quant.wire.pack_nsd
        assert legacy.unpack_nsd is quant.wire.unpack_nsd

    def test_shim_modules_warn_on_import(self):
        import repro.comm.wireformat as wf_shim
        import repro.memory.codec as mem_shim

        for mod in (mem_shim, wf_shim):
            with pytest.deprecated_call():
                importlib.reload(mod)

    def test_nsd_quantize_warns_and_matches_quant(self, key):
        x = jax.random.normal(key, (16, 48))
        with pytest.deprecated_call():
            ref = nsd.nsd_quantize(x, key, 1.5)
        np.testing.assert_array_equal(
            np.asarray(quant.nsd_fakequant(x, key, 1.5)), np.asarray(ref))

    def test_quantize_int8_warns_and_matches_quant(self, key):
        x = jax.random.normal(key, (16, 48))
        with pytest.deprecated_call():
            q_ref, s_ref = int8lib.quantize_int8(x)
        q, s = quant.absmax_int8(x)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
        assert float(s) == float(s_ref)

    def test_nsd_mode_bit_exact_through_registry(self, key):
        """Registry dispatch adds nothing: decode(encode()) == reference."""
        x = jax.nn.relu(jax.random.normal(key, (13, 77)))
        k = resid_key(jax.random.fold_in(key, 1))
        dec = decode("nsd@2", encode("nsd@2", x, k))
        np.testing.assert_array_equal(
            np.asarray(dec), np.asarray(quant.nsd_fakequant(x, k, 2.0)))


class TestErrorBounds:
    @pytest.mark.parametrize("mode", ["bf16", "int8", "int8_absmax",
                                      "int4@g32", "int4@g64", "m8"])
    def test_roundtrip_within_bound(self, key, mode):
        x = jax.random.normal(key, (24, 96)) * 5.0
        enc = encode(mode, x, key)
        err = jnp.abs(decode(mode, enc) - x)
        bound = error_bound(mode, enc)
        assert float(jnp.max(err / (bound + 1e-12))) <= 1.0 + 1e-4

    def test_u8_bound_in_squared_domain(self, key):
        v = jnp.square(jax.random.normal(key, (8, 64)) * 3.0)
        enc = encode("u8", v, key)
        err = jnp.abs(decode("u8", enc) - v)
        assert float(jnp.max(err / (error_bound("u8", enc) + 1e-12))) <= 1.0 + 1e-4
        assert float(jnp.min(decode("u8", enc))) >= 0.0

    def test_exact_modes_have_no_bound(self, key):
        x = jax.random.normal(key, (4, 4))
        for mode in ("fp32", "remat"):
            assert error_bound(mode, encode(mode, x, key)) is None


class TestInt4Grouped:
    def test_grammar(self):
        assert parse_spec("int4@g32") == parse_spec("int4@32")
        with pytest.raises(ValueError):
            validate_spec("int4@g0")
        with pytest.raises(ValueError):
            validate_spec("int4@gx")

    def test_stored_bytes_formula(self):
        # 8x64 = 512 elems, g=32 -> 16 groups: 16*16 nibble bytes + 16*4 scale
        assert stored_nbytes("int4@g32", (8, 64), jnp.float32) == 16 * 16 + 64
        assert dense_nbytes((8, 64), jnp.float32) == 2048

    def test_non_multiple_shape_roundtrips(self, key):
        x = jax.random.normal(key, (5, 13))  # 65 elems, g=32 -> padded
        enc = encode("int4@g32", x, key)
        dec = decode("int4@g32", enc)
        assert dec.shape == x.shape and dec.dtype == x.dtype
        bound = error_bound("int4@g32", enc)
        assert float(jnp.max(jnp.abs(dec - x) / (bound + 1e-12))) <= 1.0 + 1e-4

    def test_all_zero_is_exact(self, key):
        x = jnp.zeros((4, 32))
        np.testing.assert_array_equal(
            np.asarray(decode("int4@g32", encode("int4@g32", x, key))),
            np.zeros((4, 32), np.float32))


class TestComputeOnPacked:
    def test_nsd_jnp_backend_matches_decode_matmul(self, key):
        g = jax.random.normal(key, (16, 128))
        x = jax.random.normal(jax.random.fold_in(key, 1), (16, 64))
        w = jax.random.normal(jax.random.fold_in(key, 2), (64, 128))
        enc = encode("nsd", g, key)
        dx, dw = get_codec("nsd").compute_on_packed(
            parse_spec("nsd"), enc, x, w, backend="jnp")
        g_hat = decode("nsd", enc)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(g_hat @ w.T),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(dw), np.asarray(x.T @ g_hat),
                                   rtol=1e-5, atol=1e-5)


class TestGradCodec:
    def test_policy_validates_spec(self):
        with pytest.raises(ValueError, match="unknown codec"):
            DitherPolicy(variant="paper", grad_codec="fp64")

    def test_fp32_grad_codec_recovers_plain_backprop(self, key):
        """grad_codec replaces the variant's NSD quantizer; the identity
        codec therefore yields EXACTLY the undithered gradient."""
        x = jax.random.normal(key, (8, 16))
        w = jax.random.normal(jax.random.fold_in(key, 1), (16, 24)) * 0.1

        def g(policy):
            ctx = (DitherCtx.for_step(key, 0, policy)
                   if policy is not None else None)
            return jax.grad(lambda w: jnp.sum(
                jnp.sin(dense(x, w, ctx=ctx, name="fc"))))(w)

        g_plain = g(None)
        g_fp32 = g(DitherPolicy(variant="paper", s=2.0, grad_codec="fp32"))
        np.testing.assert_array_equal(np.asarray(g_fp32), np.asarray(g_plain))

    def test_registry_codec_on_cotangent(self, key):
        """dw == x^T @ codec(g): eq. 9 with the registry codec swapped in."""
        x = jax.random.normal(key, (8, 16))
        w = jax.random.normal(jax.random.fold_in(key, 1), (16, 24)) * 0.1
        pol = DitherPolicy(variant="paper", s=2.0, grad_codec="int4@g32")
        ctx = DitherCtx.for_step(key, 0, pol)

        def loss(w):
            return jnp.sum(jnp.sin(dense(x, w, ctx=ctx, name="fcQ")))

        gw = jax.grad(loss)(w)
        g = jnp.cos(x @ w)
        gq = quantize("int4@g32", g, ctx.key_for("fcQ"))
        np.testing.assert_allclose(np.asarray(gw), np.asarray(x.T @ gq),
                                   rtol=1e-4, atol=1e-5)

    def test_program_base_carries_grad_codec(self, key):
        from repro.core.schedule import parse_program

        base = DitherPolicy(variant="paper", s=2.0, grad_codec="int8_absmax")
        prog = parse_program("rule other:off", base=base)
        ctx = DitherCtx.for_step(key, 0, base, program=prog)
        r = ctx.resolve("fc0")
        assert r is not None and r.spec.grad_codec == "int8_absmax"


class TestMomentCodecs:
    def _run(self, cfg, steps=5, key=None):
        key = key if key is not None else jax.random.PRNGKey(0)
        params = {"w": jax.random.normal(key, (8, 8)) * 0.1}
        state = init_opt_state(params, cfg)
        for i in range(steps):
            grads = {"w": jax.random.normal(jax.random.fold_in(key, i),
                                            (8, 8))}
            params, state, _ = apply_updates(params, grads, state, cfg)
        return params, state

    def test_needs_key_codec_rejected(self):
        with pytest.raises(ValueError, match="deterministic"):
            OptConfig(mu_codec="nsd")

    def test_adamw_encoded_moments_step(self):
        cfg = OptConfig(name="adamw", lr=1e-2, mu_codec="m8", nu_codec="u8")
        params, state = self._run(cfg)
        assert isinstance(state["mu"]["w"], quant.RowQuant8)
        assert isinstance(state["nu"]["w"], quant.SqrtRowQuant8)
        assert np.isfinite(np.asarray(params["w"])).all()

    def test_sgd_encoded_momentum_tracks_fp32(self):
        key = jax.random.PRNGKey(3)
        dense_cfg = OptConfig(name="sgd", lr=1e-2, grad_clip=None)
        enc_cfg = dataclasses.replace(dense_cfg, mu_codec="m8")
        p_dense, _ = self._run(dense_cfg, key=key)
        p_enc, _ = self._run(enc_cfg, key=key)
        # 8-bit row-quantized momentum: same trajectory to ~1% of movement
        moved = float(jnp.max(jnp.abs(p_dense["w"])))
        drift = float(jnp.max(jnp.abs(p_dense["w"] - p_enc["w"])))
        assert drift <= 0.05 * max(moved, 1e-6), (drift, moved)

    def test_state_specs_match_encoded_structure(self):
        from repro.optim import opt_state_specs

        cfg = OptConfig(name="adamw", mu_codec="m8", nu_codec="u8")
        params = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
        state = init_opt_state(params, cfg)
        specs = opt_state_specs({"w": ("a", "b"), "b": ("a",)}, cfg)
        # one spec leaf (None = replicated) per encoded-container leaf, so
        # sharded dry-runs can zip the two trees positionally
        n_state = len(jax.tree.leaves(state))
        n_specs = len(jax.tree.leaves(specs,
                                      is_leaf=lambda x: x is None))
        assert n_state == n_specs, (n_state, n_specs)


class TestCommRegistryModes:
    def test_compress_leaf_any_registered_codec(self, key):
        from repro.comm import CommPolicy
        from repro.comm.compression import compress_leaf

        g = jax.random.normal(key, (32, 64))
        pol = CommPolicy(default="int4@g32")
        g_hat, nbytes, _ = compress_leaf(g, key, "int4@g32", pol, None)
        enc = encode("int4@g32", g, key)
        np.testing.assert_array_equal(
            np.asarray(g_hat), np.asarray(decode("int4@g32", enc)))
        assert int(nbytes) == int(measured_bytes("int4@g32", enc))

    def test_policy_rejects_unknown_mode(self):
        from repro.comm import CommPolicy

        with pytest.raises(ValueError, match="unknown comm mode"):
            CommPolicy(default="fp64")


class TestKVRegistryModes:
    def test_init_paged_accepts_registered_spec(self, key):
        from repro.serve.kvcache import init_paged

        init_paged("nsd@1", batch=1, max_len=16, n_pages=2, page=8,
                   n_kv=1, hd=4, dtype=jnp.float32, key=key)

    def test_init_paged_rejects_unknown(self, key):
        from repro.serve.kvcache import init_paged

        with pytest.raises(ValueError, match="kv mode"):
            init_paged("fp64", batch=1, max_len=16, n_pages=2, page=8,
                       n_kv=1, hd=4, dtype=jnp.float32, key=key)


class TestQuantProgramDSL:
    def test_parse_and_roundtrip(self):
        qp = parse_quant_program("grad=int4@g32;mu=m8;nu=u8")
        assert (qp.grad, qp.mu, qp.nu) == ("int4@g32", "m8", "u8")
        assert qp.wire is None and qp.resid is None
        assert quant.format_quant_program(qp) == "grad=int4@g32;mu=m8;nu=u8"
        assert not parse_quant_program("")

    def test_errors(self):
        with pytest.raises(ValueError, match="cannot parse quant clause"):
            parse_quant_program("kv=int8")
        with pytest.raises(ValueError, match="unknown codec"):
            parse_quant_program("grad=fp64")
        with pytest.raises(ValueError, match="deterministic"):
            parse_quant_program("mu=nsd@1")
        with pytest.raises(ValueError, match="duplicate"):
            parse_quant_program("grad=int8;grad=int8")

    def test_launch_program_quant_section(self):
        from repro.launch.program import format_program, parse_program

        spec = parse_program("dither: rule a:off quant: grad=int8_absmax")
        assert spec.quant == "grad=int8_absmax"
        assert spec.quant_overrides().grad == "int8_absmax"
        assert parse_program(format_program(spec)) == spec

    def test_importing_owners_is_warning_free(self):
        """Only the LEGACY entry points warn; the migrated owners must not
        (a regression here means someone re-imported a shim)."""
        import subprocess
        import sys

        code = ("import warnings; warnings.simplefilter('error', "
                "DeprecationWarning); import repro.core, repro.comm, "
                "repro.memory, repro.quant, repro.serve.kvcache, "
                "repro.launch.program, repro.optim")
        proc = subprocess.run([sys.executable, "-c", code],
                              capture_output=True, text=True)
        assert proc.returncode == 0, proc.stderr
