"""repro.bench: schema round-trip + regression-comparator policy.

The comparator is the thing CI trusts, so every policy branch is pinned
here: missing baseline file, brand-new bench name, within-tolerance
drift, injected regression (must fail), dropped bench (must fail), and
the exact JSON round trip of the schema. The suite runner's CLI gate is
exercised end-to-end on the cheap roofline suite.
"""
import json

import pytest

from repro.bench import (BenchResult, Gate, SuiteRun, compare_runs,
                         make_suite_run)


def _result(name="table1/lenet5", value=100.0, acc=97.0, sparsity=90.0,
            **over):
    kw = dict(
        name=name, value=value, unit="us/step",
        derived={"acc": acc, "sparsity": sparsity},
        gates={"acc": Gate(abs=2.0, direction="low"),
               "sparsity": Gate(rel=0.05, direction="low")},
        context={"model": "lenet5"})
    kw.update(over)
    return BenchResult(**kw)


def _run(results, suite="table1_sparsity", quick=True):
    return SuiteRun(suite=suite, results=results, git_sha="abc1234",
                    jax_version="0.4.37", platform="cpu", quick=quick)


class TestSchemaRoundTrip:
    def test_bench_result_json_round_trip(self):
        r = _result()
        r2 = BenchResult.from_dict(json.loads(json.dumps(r.to_dict())))
        assert r2 == r

    def test_suite_run_json_round_trip(self):
        run = _run([_result(), _result(name="table1/mlp", acc=99.0)])
        run2 = SuiteRun.from_dict(json.loads(json.dumps(run.to_dict())))
        assert run2 == run
        assert run2.by_name()["table1/lenet5"].gates["acc"].direction == "low"

    def test_provenance_stamped(self):
        run = make_suite_run("kernel_bench", [_result()], quick=True)
        assert run.jax_version != "unknown"
        assert run.platform in ("cpu", "tpu", "gpu", "METAL")

    def test_derived_str_is_legacy_csv_cell(self):
        s = _result().derived_str()
        assert "acc=97" in s and "model=lenet5" in s


class TestComparatorPolicy:
    def test_missing_baseline_file_passes(self):
        report = compare_runs(_run([_result()]), None)
        assert report.ok
        assert [f.status for f in report.findings] == ["no-baseline"]

    def test_brand_new_bench_name_passes(self):
        base = _run([_result()])
        cur = _run([_result(), _result(name="table1/resnet18", acc=80.0)])
        report = compare_runs(cur, base)
        assert report.ok
        assert {f.status for f in report.findings} >= {"new", "ok"}

    def test_within_tolerance_drift_passes(self):
        base = _run([_result(acc=97.0, sparsity=90.0)])
        cur = _run([_result(acc=95.5, sparsity=86.0)])  # inside both bands
        report = compare_runs(cur, base)
        assert report.ok, report.render(verbose=True)

    def test_injected_regression_fails(self):
        base = _run([_result(acc=97.0)])
        cur = _run([_result(acc=90.0)])  # 7 points below a ±2.0 band
        report = compare_runs(cur, base)
        assert not report.ok
        (bad,) = report.regressions
        assert (bad.bench, bad.metric) == ("table1/lenet5", "acc")

    def test_dropped_bench_fails(self):
        base = _run([_result(), _result(name="table1/mlp")])
        cur = _run([_result()])
        report = compare_runs(cur, base)
        assert [f.status for f in report.regressions] == ["missing"]

    def test_timing_drift_never_fails(self):
        base = _run([_result(value=100.0)])
        cur = _run([_result(value=5000.0)])  # 50x slower, ungated
        assert compare_runs(cur, base).ok

    def test_gate_direction_low_allows_improvement(self):
        base = _run([_result(acc=90.0, sparsity=85.0)])
        cur = _run([_result(acc=99.9, sparsity=95.0)])  # strictly better
        assert compare_runs(cur, base).ok

    def test_gate_direction_high_blocks_increase_only(self):
        g = {"wire_ratio": Gate(rel=0.10, direction="high")}
        base = _run([_result(derived={"wire_ratio": 0.06}, gates=g)])
        up = _run([_result(derived={"wire_ratio": 0.09}, gates=g)])
        down = _run([_result(derived={"wire_ratio": 0.01}, gates=g)])
        assert not compare_runs(up, base).ok
        assert compare_runs(down, base).ok

    def test_exact_gate_abs_zero(self):
        g = {"packs": Gate(abs=0.0, direction="both")}
        base = _run([_result(derived={"packs": 10.0}, gates=g)])
        same = _run([_result(derived={"packs": 10.0}, gates=g)])
        off = _run([_result(derived={"packs": 11.0}, gates=g)])
        assert compare_runs(same, base).ok
        assert not compare_runs(off, base).ok

    def test_gate_on_missing_metric_is_suite_bug(self):
        g = {"ghost": Gate(abs=1.0)}
        base = _run([_result(gates=g)])
        cur = _run([_result(gates=g)])
        report = compare_runs(cur, base)
        assert not report.ok  # gate names a metric the suite never emitted

    def test_quick_vs_full_mismatch_is_visible_not_gated(self):
        """Full-mode numbers (bigger shapes, more steps) are incomparable
        to a quick-mode baseline — the comparator must surface the
        mismatch instead of failing spuriously."""
        base = _run([_result(acc=97.0)], quick=True)
        cur = _run([_result(acc=10.0)], quick=False)  # would hard-fail
        report = compare_runs(cur, base)
        assert report.ok
        assert [f.status for f in report.findings] == ["mode-mismatch"]

    def test_current_gates_are_authoritative(self):
        """Retightening a band in suite code takes effect immediately even
        though the committed baseline still carries the old gate."""
        base = _run([_result(acc=97.0,
                             gates={"acc": Gate(abs=50.0, direction="low")})])
        cur = _run([_result(acc=90.0,
                            gates={"acc": Gate(abs=2.0, direction="low")})])
        assert not compare_runs(cur, base).ok


class TestSuiteRunnerGate:
    """End-to-end CLI gate on the cheapest suite (roofline reads files)."""

    @pytest.fixture()
    def dirs(self, tmp_path):
        res, base = tmp_path / "results", tmp_path / "baselines"
        res.mkdir(), base.mkdir()
        return str(res), str(base)

    def test_check_passes_without_baseline_and_writes_json(self, dirs):
        from benchmarks import suite as suitelib
        res, base = dirs
        rc = suitelib.main(["--only", "roofline_table", "--check",
                            "--results-dir", res, "--baseline-dir", base])
        assert rc == 0
        out = json.load(open(suitelib.result_path("roofline_table", res)))
        assert out["suite"] == "roofline_table"
        assert out["schema_version"] == 1
        assert out["results"], "suite must emit at least one result"

    def test_check_fails_on_injected_regression(self, dirs):
        from benchmarks import suite as suitelib
        res, base = dirs
        # baseline expects a bench the current run doesn't produce
        phantom = _run([_result(name="roofline/phantom")],
                       suite="roofline_table")
        suitelib.write_run(phantom,
                           suitelib.baseline_path("roofline_table", base))
        rc = suitelib.main(["--only", "roofline_table", "--check",
                            "--results-dir", res, "--baseline-dir", base])
        assert rc == 1

    def test_rebaseline_then_check_is_green(self, dirs):
        from benchmarks import suite as suitelib
        res, base = dirs
        rc = suitelib.main(["--only", "roofline_table", "--rebaseline",
                            "--results-dir", res, "--baseline-dir", base])
        assert rc == 0
        rc = suitelib.main(["--only", "roofline_table", "--check",
                            "--results-dir", res, "--baseline-dir", base])
        assert rc == 0

    def test_rebaseline_plus_check_gates_against_old_baseline(self, dirs):
        """--check must compare against the PRE-rebaseline files; running
        both flags together may not become a vacuous always-green gate."""
        from benchmarks import suite as suitelib
        res, base = dirs
        phantom = _run([_result(name="roofline/phantom")],
                       suite="roofline_table")
        suitelib.write_run(phantom,
                           suitelib.baseline_path("roofline_table", base))
        rc = suitelib.main(["--only", "roofline_table", "--rebaseline",
                            "--check", "--results-dir", res,
                            "--baseline-dir", base])
        assert rc == 1  # phantom bench was missing vs the OLD baseline
        refreshed = json.load(
            open(suitelib.baseline_path("roofline_table", base)))
        names = {r["name"] for r in refreshed["results"]}
        assert "roofline/phantom" not in names  # but baselines refreshed

    def test_nan_metric_fails_one_suite_not_the_runner(self, dirs,
                                                       monkeypatch):
        """strict-JSON write errors (NaN metric) count as that suite's
        failure; later suites still run and persist."""
        from benchmarks import suite as suitelib

        def fns():
            return {
                "bad": lambda quick=True: [
                    _result(derived={"acc": float("nan")})],
                "good": lambda quick=True: [_result()],
            }

        monkeypatch.setattr(suitelib, "_suite_fns", fns)
        res, _ = dirs
        runs, failed = suitelib.run_suites(["bad", "good"],
                                           results_dir=res)
        assert failed == ["bad"]
        assert "good" in runs
        json.load(open(suitelib.result_path("good", res)))  # intact

    def test_roofline_summary_names_are_stable(self):
        """The committed baseline holds roofline/{baseline,optimized};
        those names must exist whether or not the grid file does, so
        generating the grid later can never flip them to `missing`."""
        from benchmarks import roofline_table
        names = {r.name for r in roofline_table.bench()}
        assert {"roofline/baseline", "roofline/optimized"} <= names

    def test_suite_exception_exits_nonzero(self, dirs, monkeypatch):
        """A raising suite prints its traceback and fails the run — the
        legacy swallow-and-continue-green behavior must not come back."""
        from benchmarks import suite as suitelib

        def boom():
            def bench(quick=True):
                raise RuntimeError("injected suite failure")
            return {"roofline_table": bench}

        monkeypatch.setattr(suitelib, "_suite_fns", boom)
        res, base = dirs
        rc = suitelib.main(["--only", "roofline_table",
                            "--results-dir", res, "--baseline-dir", base])
        assert rc == 1
