"""repro.comm.hierarchy: the two-level (intra-pod ring + inter-pod tree)
compressed reduce — correctness vs the dense mean, acceptance criteria vs
the flat ring (strictly fewer sequential packs per segment AND a strictly
tighter error bound on the same input), telemetry accounting, topology
threading (CommPolicy / ssgd / Trainer / costmodel / mesh descriptors),
and sim-vs-shard_map differential tests including a non-power-of-two pod
count.

Differential methodology: the shard_map program and the simulation share
per-hop math AND per-hop PRNG keys (repro.comm.reduce_base.hop_key), so
their final states must agree bit-exactly — and because every hop's
output is the next hop's input, final-state equality transitively pins
every intermediate hop. Both sides are compared under jit: XLA fuses
eager and jitted elementwise chains differently (1-ulp FMA-style
divergence), which is a compiler artifact, not hop math.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
import stat_utils

from repro.comm import (CommPolicy, HierConfig, RingConfig,
                        hier_allreduce_nsd, ring_allreduce_nsd, tree_rounds)
from repro.launch.costmodel import LinkPricing, price_reduce
from repro.launch.mesh import NodeTopology, make_node_mesh


def _stack(key, n, shape=(1000,), scale=1.0):
    return jnp.stack([
        jax.random.normal(jax.random.fold_in(key, i), shape) * scale
        for i in range(n)])


class TestHierSim:
    def test_matches_dense_mean_within_bound(self, key):
        """N=8 in 2 pods vs dense average, within the documented bound
        (acceptance criterion)."""
        gs = _stack(key, 8)
        mean, tele = hier_allreduce_nsd(gs, key, HierConfig(pods=2, s=1.0))
        err = jnp.max(jnp.abs(mean - jnp.mean(gs, axis=0)))
        stat_utils.assert_within_bound(err, tele.error_bound)

    @pytest.mark.parametrize("pods,per_pod", [(2, 4), (4, 2), (2, 2),
                                              (3, 2), (1, 4), (4, 1)])
    def test_shapes_and_bounds(self, key, pods, per_pod):
        """Every (G, P) split reduces correctly: pure ring (G=1), pure
        tree (P=1), non-power-of-two pod count (G=3) included."""
        n = pods * per_pod
        gs = _stack(key, n, (300,))
        mean, tele = hier_allreduce_nsd(gs, key, HierConfig(pods=pods))
        err = jnp.max(jnp.abs(mean - jnp.mean(gs, axis=0)))
        stat_utils.assert_within_bound(err, tele.error_bound)
        assert tele.packs_per_segment == \
            (per_pod - 1) + tree_rounds(pods) + 1
        assert tele.pods == pods and tele.per_pod == per_pod

    def test_strictly_beats_flat_ring_at_pod_scale(self, key):
        """THE acceptance criterion: for N >= 8 nodes in >= 2 pods, the
        hierarchy re-quantizes each segment strictly fewer times and
        reports a strictly tighter error bound than the flat ring on the
        SAME input."""
        gs = _stack(key, 8)
        _, ring_tele = ring_allreduce_nsd(gs, key, RingConfig(s=1.0))
        for pods in (2, 4):
            _, hier_tele = hier_allreduce_nsd(gs, key,
                                              HierConfig(pods=pods, s=1.0))
            assert hier_tele.packs_per_segment < ring_tele.packs_per_segment
            assert float(hier_tele.error_bound) < float(ring_tele.error_bound)

    def test_wire_split_sums_to_total(self, key):
        gs = _stack(key, 8)
        _, tele = hier_allreduce_nsd(gs, key, HierConfig(pods=2))
        assert float(tele.wire_ici_bytes) + float(tele.wire_dcn_bytes) == \
            float(tele.wire_bytes)
        assert float(tele.wire_dcn_bytes) > 0  # the tree actually ran
        assert float(tele.wire_bytes) < float(tele.dense_bytes)

    def test_single_pod_has_no_dcn_traffic(self, key):
        gs = _stack(key, 4)
        _, tele = hier_allreduce_nsd(gs, key, HierConfig(pods=1))
        # G=1: only the once-packed broadcast segment, no tree hops
        assert float(tele.wire_dcn_bytes) == 0.0
        assert tele.packs_per_segment == 4  # same depth as the flat ring

    def test_single_node_is_exact_and_free(self, key):
        g = jax.random.normal(key, (7, 11))[None]
        mean, tele = hier_allreduce_nsd(g, key, HierConfig(pods=1))
        np.testing.assert_array_equal(np.asarray(mean), np.asarray(g[0]))
        assert float(tele.wire_bytes) == 0.0

    def test_indivisible_pods_rejected(self, key):
        gs = _stack(key, 6, (64,))
        with pytest.raises(ValueError, match="divisible"):
            hier_allreduce_nsd(gs, key, HierConfig(pods=4))

    def test_mesh_without_pod_axis_rejected(self, key):
        """Handing a flat-ring mesh to the hierarchy must fail with the
        module's descriptive ValueError, not a raw KeyError."""
        from repro.comm import allreduce_compressed, allreduce_hier
        gs = _stack(key, 2, (64,))
        mesh = make_node_mesh(NodeTopology.flat(jax.device_count()))
        with pytest.raises(ValueError, match="2-D"):
            allreduce_hier(gs, key, HierConfig(pods=2), mesh=mesh)
        with pytest.raises(ValueError, match="2-D"):
            allreduce_compressed(gs, key, HierConfig(pods=2), mesh=mesh)

    def test_deterministic(self, key):
        gs = _stack(key, 6, (256,))
        m1, _ = hier_allreduce_nsd(gs, key, HierConfig(pods=3))
        m2, _ = hier_allreduce_nsd(gs, key, HierConfig(pods=3))
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))

    def test_error_shrinks_with_smaller_s(self, key):
        gs = _stack(key, 8, (512,))
        dense = jnp.mean(gs, axis=0)
        errs = {}
        for s in (0.25, 4.0):
            mean, _ = hier_allreduce_nsd(gs, key, HierConfig(pods=2, s=s))
            errs[s] = float(jnp.max(jnp.abs(mean - dense)))
        assert errs[0.25] < errs[4.0], errs

    def test_bf16_dtype_preserved(self, key):
        gs = _stack(key, 4, (320,)).astype(jnp.bfloat16)
        mean, _ = hier_allreduce_nsd(gs, key, HierConfig(pods=2))
        assert mean.dtype == jnp.bfloat16


class TestTopologyThreading:
    def test_comm_policy_selects_reduce_cfg(self):
        assert CommPolicy().reduce_cfg() is None
        r = CommPolicy(topology="ring", s=2.0).reduce_cfg()
        assert isinstance(r, RingConfig) and r.s == 2.0
        h = CommPolicy(topology="hier", pods=4, s=0.5).reduce_cfg()
        assert isinstance(h, HierConfig) and h.pods == 4 and h.s == 0.5

    def test_bad_topology_rejected(self):
        with pytest.raises(ValueError, match="topology"):
            CommPolicy(topology="mesh2d")
        with pytest.raises(ValueError, match="pods"):
            CommPolicy(topology="hier", pods=0)

    def test_node_topology_descriptor(self):
        topo = NodeTopology(pods=2, nodes_per_pod=4)
        assert topo.n_nodes == 8
        assert topo.link_kind("pods") == "dcn"
        assert topo.link_kind("nodes") == "ici"
        flat = NodeTopology.flat(4)
        assert flat.pods == 1 and flat.n_nodes == 4

    def test_node_topology_builds_mesh(self):
        # single CPU device in tier-1: only the degenerate mesh builds
        topo = NodeTopology(pods=1, nodes_per_pod=jax.device_count())
        mesh = make_node_mesh(topo)
        assert mesh.shape[topo.node_axis] == jax.device_count()

    def test_price_reduce_prefers_hier_across_pods(self, key):
        """The cost model must show the tree winning once the reduce
        spans pods (the flat ring is gated by DCN every round)."""
        gs = _stack(key, 8, (64, 64), scale=0.01)
        _, ring_tele = ring_allreduce_nsd(gs, key, RingConfig(s=2.0))
        _, hier_tele = hier_allreduce_nsd(gs, key, HierConfig(pods=2, s=2.0))
        ring_t = price_reduce(ring_tele, nodes=8, pods=2)
        hier_t = price_reduce(hier_tele, nodes=8, pods=2)
        assert hier_t["dcn_s"] < ring_t["dcn_s"]
        assert hier_t["total_s"] < ring_t["total_s"]
        # single-pod ring pays no DCN
        assert price_reduce(ring_tele, nodes=8, pods=1)["dcn_s"] == 0.0

    def test_price_reduce_custom_bandwidths(self, key):
        gs = _stack(key, 4, (256,))
        _, tele = hier_allreduce_nsd(gs, key, HierConfig(pods=2))
        cheap = price_reduce(tele, nodes=4, pods=2,
                             pricing=LinkPricing(dcn_bw=1e9))
        fast = price_reduce(tele, nodes=4, pods=2,
                            pricing=LinkPricing(dcn_bw=1e12))
        assert cheap["dcn_s"] > fast["dcn_s"]

    def test_ssgd_step_topologies_learn_and_report(self, key):
        from repro.configs import paper_models as pm
        from repro.core import DitherPolicy
        from repro.data import ClassifConfig, classification_batch
        from repro.distributed import SSGDConfig, make_ssgd_step, shard_batch
        from repro.optim import OptConfig, init_opt_state

        model = pm.mlp_mnist(hidden=(32, 32))
        params, _ = model.init(key)
        opt = OptConfig(lr=1e-2)
        batch = classification_batch(
            ClassifConfig(n_classes=10, img_size=28, channels=1), 0, batch=8)
        for topo, pods in (("ring", 1), ("hier", 2)):
            dcfg = SSGDConfig(n_nodes=4)
            step_fn, _ = make_ssgd_step(
                model, opt, dcfg, DitherPolicy(variant="paper"),
                comm_policy=CommPolicy(default="nsd", s=1.0,
                                       topology=topo, pods=pods))
            state = init_opt_state(params, opt)
            _, _, m, _ = step_fn(params, state, shard_batch(batch, 4), key)
            assert float(m["loss"]) > 0, topo
            assert 0 < float(m["comm_wire_bytes"]) < \
                float(m["comm_dense_bytes"]), topo
            assert float(m["comm_error_bound"]) > 0, topo

    def test_trainer_prices_topology_in_history(self, key):
        from repro.configs import get_smoke_model
        from repro.data import TokenStreamConfig, token_batch
        from repro.optim import OptConfig
        from repro.train.trainer import Trainer, TrainerConfig

        model = get_smoke_model("mamba2-370m")
        tscfg = TokenStreamConfig(vocab=model.cfg.vocab, seq_len=16, batch=8)
        trainer = Trainer(
            model, OptConfig(lr=1e-3),
            TrainerConfig(total_steps=4, log_every=2),
            comm_policy=CommPolicy(default="nsd", s=0.5),
            topology=NodeTopology(pods=2, nodes_per_pod=4))
        out = trainer.fit(iter(token_batch(tscfg, i) for i in range(20)))
        row = out["history"][-1]
        assert row["comm_wire_mb"] > 0
        assert row["comm_ici_s"] > 0
        assert row["comm_dcn_s"] > row["comm_ici_s"]  # DCN is the slow axis

    def test_benchmark_compare_topologies_json_fields(self, tmp_path):
        sys.path.insert(0, os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..")))
        from benchmarks.distributed_nodes import (compare_topologies,
                                                  write_topology_json)
        result = compare_topologies(n_nodes=4, pods=2, shape=(64, 64))
        path = write_topology_json(result, str(tmp_path / "topo.json"))
        import json
        with open(path) as f:
            loaded = json.load(f)
        by_topo = {r["topology"]: r for r in loaded["rows"]}
        assert set(by_topo) == {"ring", "hier", "butterfly"}
        for r in by_topo.values():  # the acceptance-criterion fields
            for field in ("wire_bytes", "ici_s", "dcn_s", "total_s",
                          "error_bound", "packs_per_segment"):
                assert field in r, field
            stat_utils.assert_within_bound(r["max_err"], r["error_bound"])
        for topo in ("hier", "butterfly"):
            assert "wire_dcn_bytes" in by_topo[topo]
            assert "peak_dcn_bytes" in by_topo[topo]


# --- sim vs shard_map differential tests (virtual multi-device) ---------

def _run_script(script: str) -> str:
    env = dict(os.environ, PYTHONPATH=os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    return out.stdout + out.stderr


HIER_SHARDMAP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import functools
    import jax, jax.numpy as jnp
    from repro.comm import (HierConfig, allreduce_hier, hier_allreduce_nsd,
                            make_hier_allreduce)
    from repro.launch.mesh import NodeTopology, make_node_mesh
    key = jax.random.PRNGKey(0)
    gs = jnp.stack([jax.random.normal(jax.random.fold_in(key, i), (37, 13))
                    for i in range(8)])
    for pods, per_pod in ((2, 4), (4, 2)):
        mesh = make_node_mesh(NodeTopology(pods=pods, nodes_per_pod=per_pod))
        cfg = HierConfig(pods=pods, s=1.0)
        means, w_ici, w_dcn, bounds = make_hier_allreduce(mesh, cfg)(gs, key)
        sim = jax.jit(functools.partial(hier_allreduce_nsd, cfg=cfg))
        sim_mean, tele = sim(gs, key)
        # every node holds the identical result...
        for i in range(1, 8):
            assert float(jnp.max(jnp.abs(means[i] - means[0]))) == 0.0
        # ...bit-exactly equal to the simulation (same hop math and keys;
        # final-state equality transitively pins every hop)
        assert float(jnp.max(jnp.abs(means[0] - sim_mean))) == 0.0, pods
        # measured wire bytes agree per link class, bound per segment sum
        assert float(jnp.sum(w_ici)) == float(tele.wire_ici_bytes)
        assert float(jnp.sum(w_dcn)) == float(tele.wire_dcn_bytes)
        assert abs(float(bounds[0]) - float(tele.error_bound)) < 1e-6
        # dispatcher path + telemetry consistency
        mean_d, tele_d = allreduce_hier(gs, key, cfg, mesh=mesh)
        assert float(jnp.max(jnp.abs(mean_d - sim_mean))) == 0.0
        assert float(tele_d.dense_bytes) == float(tele.dense_bytes)
        assert tele_d.packs_per_segment == tele.packs_per_segment
    # node/mesh mismatch must be rejected, not silently dropped
    mesh = make_node_mesh(NodeTopology(pods=2, nodes_per_pod=4))
    try:
        allreduce_hier(gs[:6], key, HierConfig(pods=2), mesh=mesh)
    except ValueError:
        pass
    else:
        raise AssertionError("node/mesh mismatch not rejected")
    print("HIER_SHARDMAP_OK")
""")


NONPOW2_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
    import functools
    import jax, jax.numpy as jnp
    from repro.comm import HierConfig, hier_allreduce_nsd, make_hier_allreduce
    from repro.launch.mesh import NodeTopology, make_node_mesh
    key = jax.random.PRNGKey(1)
    gs = jnp.stack([jax.random.normal(jax.random.fold_in(key, i), (40,))
                    for i in range(6)])
    # G=3 pods: the binomial tree has an absent partner in round 2
    mesh = make_node_mesh(NodeTopology(pods=3, nodes_per_pod=2))
    cfg = HierConfig(pods=3, s=1.0)
    means, w_ici, w_dcn, bounds = make_hier_allreduce(mesh, cfg)(gs, key)
    sim_mean, tele = jax.jit(
        functools.partial(hier_allreduce_nsd, cfg=cfg))(gs, key)
    for i in range(6):
        assert float(jnp.max(jnp.abs(means[i] - sim_mean))) == 0.0, i
    assert float(jnp.sum(w_ici)) == float(tele.wire_ici_bytes)
    assert float(jnp.sum(w_dcn)) == float(tele.wire_dcn_bytes)
    assert abs(float(bounds[0]) - float(tele.error_bound)) < 1e-6
    err = float(jnp.max(jnp.abs(sim_mean - jnp.mean(gs, 0))))
    assert err <= float(tele.error_bound) * 1.001
    print("NONPOW2_OK")
""")


def test_shardmap_hier_subprocess():
    """The real two-level exchange: packed pytrees ppermute over BOTH mesh
    axes and agree bit-exactly with the simulation (2x4 and 4x2)."""
    out = _run_script(HIER_SHARDMAP_SCRIPT)
    assert "HIER_SHARDMAP_OK" in out, out


def test_shardmap_hier_nonpow2_pods_subprocess():
    """Same differential with a non-power-of-two pod-group count (G=3)."""
    out = _run_script(NONPOW2_SCRIPT)
    assert "NONPOW2_OK" in out, out


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 (virtual) devices — run under "
                           "XLA_FLAGS=--xla_force_host_platform_device_"
                           "count=8 (the CI comm job does)")
def test_hier_shardmap_inprocess(key):
    """In-process variant for the multi-device CI job: no subprocess, so
    failures produce a real traceback."""
    import functools
    from repro.comm import make_hier_allreduce
    mesh = make_node_mesh(NodeTopology(pods=2, nodes_per_pod=4))
    cfg = HierConfig(pods=2, s=1.0)
    gs = _stack(key, 8, (129,))
    means, w_ici, w_dcn, bounds = make_hier_allreduce(mesh, cfg)(gs, key)
    sim_mean, tele = jax.jit(
        functools.partial(hier_allreduce_nsd, cfg=cfg))(gs, key)
    assert float(jnp.max(jnp.abs(means[0] - sim_mean))) == 0.0
    assert float(jnp.sum(w_ici)) == float(tele.wire_ici_bytes)
    assert float(jnp.sum(w_dcn)) == float(tele.wire_dcn_bytes)
