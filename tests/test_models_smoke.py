"""Per-assigned-architecture smoke tests: instantiate the REDUCED config of
the same family, run one forward/train step on CPU, assert output shapes and
finiteness (the full configs are exercised only via the AOT dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_model
from repro.core import DitherCtx, DitherPolicy


def _batch_for(model, key, batch=2, seq=16):
    cfg = model.cfg
    vocab = getattr(cfg, "vocab", 512)
    b = {
        "tokens": jax.random.randint(key, (batch, seq), 0, vocab),
        "labels": jax.random.randint(key, (batch, seq), 0, vocab),
    }
    if model.family == "audio":
        b["frames"] = jax.random.normal(key, (batch, cfg.n_frames,
                                               cfg.d_model))
    if model.family == "vlm" and cfg.vlm_patches:
        b["patch_embeds"] = jax.random.normal(
            key, (batch, cfg.vlm_patches, cfg.vit_dim))
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, key):
    model = get_smoke_model(arch)
    params, specs = model.init(key)
    batch = _batch_for(model, key)
    out = model.forward(params, batch)
    logits = out[0] if isinstance(out, tuple) else out
    vocab = model.cfg.vocab
    assert logits.shape[-1] == vocab
    assert logits.shape[0] == 2
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # spec tree mirrors the param tree
    assert (jax.tree.structure(jax.tree.map(lambda _: 0, params))
            == jax.tree.structure(jax.tree.map(
                lambda _: 0, specs,
                is_leaf=lambda s: isinstance(s, tuple) and all(
                    a is None or isinstance(a, str) for a in s))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_dithered_train_step(arch, key):
    model = get_smoke_model(arch)
    params, _ = model.init(key)
    batch = _batch_for(model, key)
    ctx = DitherCtx.for_step(key, 0, DitherPolicy(variant="paper", s=2.0))
    loss, grads = jax.value_and_grad(
        lambda p: model.loss(p, batch, ctx=ctx))(params)
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "gemma3-4b", "hymba-1.5b",
                                  "mamba2-370m", "whisper-small"])
def test_decode_step_runs(arch, key):
    model = get_smoke_model(arch)
    if model.decode_step is None:
        pytest.skip("no decode")
    params, _ = model.init(key)
    cache = model.init_cache(2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, new_cache = model.decode_step(params, cache, tok,
                                          jnp.asarray(0, jnp.int32))
    assert logits.shape == (2, 1, model.cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_loss_decreases_tiny_lm(key):
    """A few steps of real training on the planted-bigram stream must
    reduce loss (uses the qwen-family smoke config)."""
    from repro.data import TokenStreamConfig, token_batch
    from repro.optim import OptConfig
    from repro.train import Trainer, TrainerConfig

    model = get_smoke_model("gemma-2b")
    tcfg = TokenStreamConfig(vocab=model.cfg.vocab, seq_len=32, batch=8)
    trainer = Trainer(model, OptConfig(name="adamw", lr=1e-3),
                      TrainerConfig(total_steps=30, log_every=5),
                      policy=DitherPolicy(variant="paper", s=2.0))

    def it():
        i = 0
        while True:
            yield token_batch(tcfg, i)
            i += 1

    out = trainer.fit(it())
    hist = out["history"]
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.1, hist
